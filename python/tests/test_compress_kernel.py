"""L1 bitmask-stats kernel vs oracle: exact integer agreement over
hypothesis-generated block batches."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.compress import BLOCK_WORDS, MASK_WORDS, bitmask_stats
from compile.kernels.ref import bitmask_stats_ref


def _blocks(seed, batch, density):
    key = jax.random.PRNGKey(seed)
    kv, km = jax.random.split(key)
    x = jax.random.normal(kv, (batch, BLOCK_WORDS), jnp.float32)
    mask = jax.random.uniform(km, (batch, BLOCK_WORDS)) < density
    return jnp.where(mask, x, 0.0)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 8),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_stats_match_ref(batch, density, seed):
    x = _blocks(seed, batch, density)
    m1, n1 = bitmask_stats(x)
    m2, n2 = bitmask_stats_ref(x)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))


def test_all_zero_and_all_dense():
    z = jnp.zeros((2, BLOCK_WORDS))
    m, n = bitmask_stats(z)
    assert np.asarray(m).sum() == 0 and np.asarray(n).sum() == 0
    d = jnp.ones((2, BLOCK_WORDS))
    m, n = bitmask_stats(d)
    # Every mask word = 0xFFFF; as signed i32 via the weights sum: 65535.
    assert np.all(np.asarray(m) == 65535)
    assert np.all(np.asarray(n) == BLOCK_WORDS)


def test_single_nonzero_positions():
    # Bit i of word j covers element 16*j + i (the Rust codec layout).
    for pos in [0, 1, 15, 16, 17, 511]:
        x = jnp.zeros((1, BLOCK_WORDS)).at[0, pos].set(3.5)
        m, n = bitmask_stats(x)
        m = np.asarray(m)[0]
        assert np.asarray(n)[0] == 1
        assert m[pos // 16] == 1 << (pos % 16), (pos, m[pos // 16])
        assert np.count_nonzero(m) == 1


def test_mask_word_count():
    assert MASK_WORDS == 32
