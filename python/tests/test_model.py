"""L2 model + AOT lowering checks: shapes, sparsity, manifest, and HLO
text emission (the exact interchange the Rust runtime consumes)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def _image(seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), model.INPUT_SHAPE)


def test_forward_shapes_match_manifest():
    outs = model.cnn_forward(_image())
    assert len(outs) == len(model.LAYER_SPECS)
    for o, (h, w, c) in zip(outs, model.layer_shapes()):
        assert o.shape == (h, w, c)


def test_activations_are_relu_sparse():
    outs = model.cnn_forward(_image(3))
    for i, o in enumerate(outs):
        a = np.asarray(o)
        assert (a >= 0).all(), f"layer {i} has negatives"
        density = (a != 0).mean()
        assert 0.2 < density < 0.9, f"layer {i} density {density}"


def test_forward_is_deterministic():
    a = model.cnn_forward(_image(1))
    b = model.cnn_forward(_image(1))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_weights_are_seeded_constants():
    w1 = model.init_weights()
    w2 = model.init_weights()
    for a, b in zip(w1, w2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_declares_all_layers():
    text = aot.manifest_text()
    assert "artifact cnn model.hlo.txt" in text
    assert f"outs={len(model.LAYER_SPECS)}" in text
    for i, (h, w, c) in enumerate(model.layer_shapes()):
        assert f"layer cnn {i} h={h} w={w} c={c}" in text
    assert "artifact compress_stats" in text


def test_hlo_text_lowering():
    # The interchange contract: parseable HLO text with an entry module,
    # f32 tuple results, and no Mosaic custom-calls (interpret=True).
    hlo = aot.lower_cnn()
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    assert "custom-call" not in hlo.lower() or "mosaic" not in hlo.lower()
    hlo2 = aot.lower_compress_stats()
    assert "HloModule" in hlo2
    assert "s32" in hlo2  # integer outputs present


def test_interpret_matches_compiled_jit():
    # jit(cnn_forward) (what aot lowers) == eager interpret path.
    img = _image(9)
    eager = model.cnn_forward(img)
    jitted = jax.jit(model.cnn_forward)(img)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5
        )


def test_relu_sparsity_increases_with_negative_bias_shift():
    # Sanity of the sparsity mechanism itself: shifting activations
    # negative must increase zeros after ReLU.
    img = _image(11)
    outs = model.cnn_forward(img)
    base = float((np.asarray(outs[-1]) != 0).mean())
    shifted = jnp.maximum(outs[-1] - 0.5, 0.0)
    assert float((np.asarray(shifted) != 0).mean()) < base
