"""L1 conv kernel vs the pure-jnp oracle (the core build-time
correctness signal). Hypothesis sweeps shapes, strides, dilations and
sparsity; every case must match `ref.py` to float32 tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv import conv2d_same
from compile.kernels.ref import conv2d_same_ref


def _random_sparse(key, shape, density):
    kv, km = jax.random.split(key)
    x = jax.random.normal(kv, shape, jnp.float32)
    mask = jax.random.uniform(km, shape) < density
    return jnp.where(mask, x, 0.0)


def _check(h, w, cin, cout, ks, stride, dilation, density, seed, row_block=8):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = _random_sparse(kx, (h, w, cin), density)
    wgt = jax.random.normal(kw, (ks, ks, cin, cout), jnp.float32)
    got = conv2d_same(x, wgt, stride=stride, dilation=dilation, row_block=row_block)
    want = conv2d_same_ref(x, wgt, stride=stride, dilation=dilation)
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(5, 24),
    w=st.integers(5, 24),
    cin=st.sampled_from([1, 3, 4, 8]),
    cout=st.sampled_from([1, 4, 8]),
    ks=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref_hypothesis(h, w, cin, cout, ks, stride, density, seed):
    _check(h, w, cin, cout, ks, stride, 1, density, seed)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(8, 20),
    w=st.integers(8, 20),
    dilation=st.sampled_from([2, 3]),
    ks=st.sampled_from([3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dilated_conv_matches_ref(h, w, dilation, ks, seed):
    # The paper's Fig. 6b geometry: G = {-kd, kd-s+1}.
    _check(h, w, 4, 4, ks, 1, dilation, 0.5, seed)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("ks", [1, 3, 5])
def test_table1_layer_geometries(ks, stride):
    # The (kernel, stride) pairs of paper Table I.
    _check(27, 27, 8, 8, ks, stride, 1, 0.4, 7)


def test_row_block_boundary_cases():
    # H_out not a multiple of the row block; tiny row blocks.
    _check(13, 13, 4, 4, 3, 1, 1, 0.4, 1, row_block=8)
    _check(13, 13, 4, 4, 3, 2, 1, 0.4, 2, row_block=4)
    _check(9, 9, 2, 2, 3, 1, 1, 0.4, 3, row_block=2)


def test_all_zero_input_gives_all_zero_output():
    x = jnp.zeros((16, 16, 4))
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 4, 8))
    out = conv2d_same(x, w)
    assert float(jnp.abs(out).max()) == 0.0


def test_pointwise_conv_is_channel_mix():
    # 1x1 conv == per-pixel matmul.
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (10, 10, 4))
    w = jax.random.normal(key, (1, 1, 4, 6))
    got = conv2d_same(x, w)
    want = jnp.einsum("hwc,cd->hwd", x, w[0, 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
