"""AOT interchange contract tests: the properties the Rust runtime
relies on (HLO text parseability markers, tuple outputs, dtype layout,
and the manifest ↔ lowering agreement)."""

import re

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.compress import BLOCK_WORDS, bitmask_stats


def test_cnn_hlo_has_single_entry_with_tuple_root():
    hlo = aot.lower_cnn()
    assert hlo.count("ENTRY") == 1
    # The entry computation's ROOT is a tuple of n f32 arrays (lowered
    # with return_tuple=True). Find the ENTRY block's ROOT line.
    entry = hlo[hlo.index("ENTRY") :]
    m = re.search(r"ROOT [^=]+= \((.*?)\) tuple", entry)
    assert m, "entry ROOT tuple not found"
    outs = [o.strip() for o in m.group(1).split(", ")]
    n = len(model.LAYER_SPECS)
    assert len(outs) == n, outs
    assert all(o.startswith("f32[") for o in outs), outs


def test_cnn_hlo_output_shapes_match_manifest():
    hlo = aot.lower_cnn()
    for h, w, c in model.layer_shapes():
        assert f"f32[{h},{w},{c}]" in hlo, (h, w, c)


def test_compress_hlo_has_i32_tuple():
    hlo = aot.lower_compress_stats()
    assert f"s32[{aot.STATS_BATCH},32]" in hlo
    assert f"s32[{aot.STATS_BATCH}]" in hlo


def test_no_64bit_ids_required():
    # The text path exists because serialized protos with 64-bit ids are
    # rejected by xla_extension 0.5.1; text must not be empty and must
    # carry the module header the parser needs.
    for hlo in [aot.lower_cnn(), aot.lower_compress_stats()]:
        assert hlo.lstrip().startswith("HloModule")


def test_stats_batch_contract():
    # The Rust smoke test feeds exactly (STATS_BATCH, BLOCK_WORDS).
    x = jnp.zeros((aot.STATS_BATCH, BLOCK_WORDS))
    mask, nnz = bitmask_stats(x)
    assert mask.shape == (aot.STATS_BATCH, 32)
    assert nnz.shape == (aot.STATS_BATCH,)


def test_lowering_is_deterministic():
    assert aot.lower_compress_stats() == aot.lower_compress_stats()


def test_manifest_paths_are_relative():
    text = aot.manifest_text()
    for line in text.splitlines():
        if line.startswith("artifact"):
            fname = line.split()[2]
            assert "/" not in fname, f"artifact path must be relative: {fname}"


def test_activations_feed_gratetile_densities():
    # The e2e example's premise: at least one layer in the operating
    # range where GrateTile's ~55% saving story applies (30-70% density).
    img = jax.random.uniform(jax.random.PRNGKey(0), model.INPUT_SHAPE)
    outs = model.cnn_forward(img)
    densities = [float((np.asarray(o) != 0).mean()) for o in outs]
    assert any(0.3 < d < 0.7 for d in densities), densities
