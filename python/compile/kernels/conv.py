"""L1: tiled direct-convolution Pallas kernel.

The paper's compute hot-spot is the tiled convolution consuming the
windows that GrateTile fetches. On TPU the natural mapping (DESIGN.md
§Hardware-Adaptation) is:

* the processing tile (paper Table I) becomes a VMEM block: the grid
  iterates output *row blocks*, and each step loads the halo'd input
  rows it needs (the HBM->VMEM schedule the paper's memory controller
  performs with sub-tensor fetches);
* the per-tap inner product is phrased as a ``(tile_pixels x Cin) @
  (Cin x Cout)`` matmul per kernel tap - the MXU-native shape - instead
  of a GPU-style im2col + WMMA;
* sparsity is exploited on the *bandwidth* side (L3 storage), not by
  gating the MXU: exactly the paper's "independent of the PE design"
  claim.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is checked against ``ref.py`` by pytest and
the lowered HLO is what `aot.py` ships to the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, ks, s, th, w_out, cin, cout):
    """One grid step: convolve `th` output rows.

    x_ref: (H_pad, W_pad, Cin) padded input (full, dynamically sliced).
    w_ref: (ks, ks, Cin, Cout) weights.
    o_ref: (th, w_out, Cout) output block.
    """
    i = pl.program_id(0)
    rows = (th - 1) * s + ks
    # Halo'd row block for this output tile (the "fetch" of Fig. 5).
    x = pl.load(
        x_ref,
        (pl.ds(i * th * s, rows), slice(None), slice(None)),
    )  # (rows, W_pad, cin)

    acc = jnp.zeros((th * w_out, cout), jnp.float32)
    for ky in range(ks):
        for kx in range(ks):
            # Strided patch for this tap: (th, w_out, cin).
            patch = jax.lax.slice(
                x,
                (ky, kx, 0),
                (ky + (th - 1) * s + 1, kx + (w_out - 1) * s + 1, cin),
                (s, s, 1),
            )
            # MXU-shaped matmul: (th*w_out, cin) @ (cin, cout).
            acc = acc + jnp.dot(
                patch.reshape(th * w_out, cin),
                w_ref[ky, kx],
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = acc.reshape(th, w_out, cout)


def conv2d_same(x, w, *, stride=1, dilation=1, row_block=8, interpret=True):
    """2-D convolution, SAME padding, HWC layout, via the Pallas kernel.

    x: (H, W, Cin) float32.  w: (ks, ks, Cin, Cout).
    Returns (ceil(H/s), ceil(W/s), Cout) float32.

    Dilation is handled by dilating the kernel taps into an equivalent
    dense kernel footprint before the Pallas call (tap loop indices are
    Python-static), matching the paper's Fig. 6b window geometry.
    """
    h, w_in, cin = x.shape
    ks = w.shape[0]
    assert w.shape[:2] == (ks, ks) and ks % 2 == 1, "odd square kernels"
    assert w.shape[2] == cin
    cout = w.shape[3]
    k = (ks - 1) // 2

    if dilation > 1:
        # Embed the dilated kernel in a dense (2*k*d+1)^2 footprint.
        ks_d = 2 * k * dilation + 1
        wd = jnp.zeros((ks_d, ks_d, cin, cout), w.dtype)
        wd = wd.at[::dilation, ::dilation].set(w)
        w = wd
        ks = ks_d
        k = k * dilation

    s = stride
    h_out = -(-h // s)
    w_out = -(-w_in // s)

    # SAME padding for the walker geometry of the paper (§III-B): the
    # first window starts at -k; the last ends at (out-1)*s + k + 1.
    pad_top = k
    pad_bot = max(0, (h_out - 1) * s + k + 1 - h)
    pad_l = k
    pad_r = max(0, (w_out - 1) * s + k + 1 - w_in)
    xp = jnp.pad(x, ((pad_top, pad_bot), (pad_l, pad_r), (0, 0)))

    # Row-block the grid; pad H_out to a multiple of the block.
    th = min(row_block, h_out)
    grid = -(-h_out // th)
    h_out_pad = grid * th
    if h_out_pad != h_out:
        # Extend the padded input so the last block's halo'd rows exist.
        need_rows = (h_out_pad - 1) * s + ks
        extra = need_rows - xp.shape[0]
        if extra > 0:
            xp = jnp.pad(xp, ((0, extra), (0, 0), (0, 0)))

    kernel = functools.partial(
        _conv_kernel, ks=ks, s=s, th=th, w_out=w_out, cin=cin, cout=cout
    )
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            # Full (unblocked) refs: halo'd row blocks overlap, so the
            # kernel slices dynamically.
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((th, w_out, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out_pad, w_out, cout), jnp.float32),
        interpret=interpret,
    )(xp, w)
    return out[:h_out]
