"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest asserts kernel == ref over hypothesis-generated sweeps).
"""

import jax.numpy as jnp
from jax import lax


def conv2d_same_ref(x, w, *, stride=1, dilation=1):
    """Reference 2-D convolution, SAME padding, HWC layout.

    Matches the paper's window geometry (§III-B): output oy reads input
    rows oy*s - k*d .. oy*s + k*d, zero-padded at the borders; output
    size is ceil(H/s) x ceil(W/s).
    """
    h, w_in, _ = x.shape
    ks = w.shape[0]
    k = (ks - 1) // 2
    s = stride
    h_out = -(-h // s)
    w_out = -(-w_in // s)
    kd = k * dilation
    pad_top = kd
    pad_bot = max(0, (h_out - 1) * s + kd + 1 - h)
    pad_l = kd
    pad_r = max(0, (w_out - 1) * s + kd + 1 - w_in)
    out = lax.conv_general_dilated(
        x[None].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(s, s),
        padding=((pad_top, pad_bot), (pad_l, pad_r)),
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0]


def bitmask_stats_ref(blocks):
    """Reference bitmask stats: (B, 512) f32 -> ((B, 32) i32, (B,) i32)."""
    b, n = blocks.shape
    nz = (blocks != 0.0).astype(jnp.int32)
    bits = nz.reshape(b, n // 16, 16)
    weights = (1 << jnp.arange(16, dtype=jnp.int32)).astype(jnp.int32)
    mask = jnp.sum(bits * weights[None, None, :], axis=2, dtype=jnp.int32)
    nnz = jnp.sum(nz, axis=1, dtype=jnp.int32)
    return mask, nnz
