"""L1: bitmask compression-statistics Pallas kernel.

The storage-side hot-spot: computing the bitmask words and nonzero
counts of every 512-word storage block (paper Fig. 4 / Fig. 7). The L3
packer uses exactly these quantities to size and address compressed
sub-tensors; this kernel is the on-device (TPU) formulation, validated
against ``ref.py`` and shipped to the Rust runtime as an AOT artifact.

VMEM mapping: one grid step owns one block row of 512 words (= one
8x8x8 sub-tensor) - comfortably VMEM-resident - and reduces it to a
32-word mask plus a scalar count, so the HBM write-back is ~6% of the
read traffic.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_WORDS = 512
MASK_WORDS = BLOCK_WORDS // 16


def _stats_kernel(x_ref, mask_ref, nnz_ref):
    """x_ref: (1, 512) f32 -> mask_ref: (1, 32) i32, nnz_ref: (1, 1) i32."""
    x = x_ref[0, :]
    nz = (x != 0.0).astype(jnp.int32)  # (512,)
    bits = nz.reshape(MASK_WORDS, 16)
    weights = (1 << jnp.arange(16, dtype=jnp.int32)).astype(jnp.int32)
    mask_ref[0, :] = jnp.sum(bits * weights[None, :], axis=1, dtype=jnp.int32)
    nnz_ref[0, 0] = jnp.sum(nz, dtype=jnp.int32)


def bitmask_stats(blocks, *, interpret=True):
    """Per-block bitmask stats.

    blocks: (B, 512) float32.
    Returns (mask: (B, 32) int32, nnz: (B,) int32); mask word j of block
    b has bit i set iff blocks[b, 16*j + i] != 0 - the exact layout the
    Rust `compress::Bitmask` codec uses.
    """
    b, n = blocks.shape
    assert n == BLOCK_WORDS, f"blocks must be (B, {BLOCK_WORDS})"
    mask, nnz = pl.pallas_call(
        _stats_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, BLOCK_WORDS), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, MASK_WORDS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, MASK_WORDS), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(blocks)
    return mask, nnz[:, 0]
