"""L2: the JAX CNN whose ReLU activations feed the GrateTile simulator.

A small conv-ReLU stack (VDSR-flavoured: 3x3 kernels, one strided
stage) built ON the L1 Pallas conv kernel, so the whole model lowers
into a single HLO module. `aot.py` lowers `cnn_forward` once; the Rust
runtime then produces *real* activation sparsity for the end-to-end
example without Python on the request path.

Weights are deterministic (seeded) constants baked into the HLO: the
artifact is self-contained and reproducible.
"""

import jax
import jax.numpy as jnp

from .kernels.conv import conv2d_same

# (kernel_size, stride, c_out) per layer; c_in chains from the input.
LAYER_SPECS = [
    (3, 1, 8),
    (3, 1, 16),
    (3, 2, 16),
    (3, 1, 8),
]

INPUT_SHAPE = (32, 32, 1)  # H, W, C of the input image
SEED = 2020  # the paper's year


def layer_shapes():
    """Output (h, w, c) of each layer, for the artifact manifest."""
    h, w, _ = INPUT_SHAPE
    shapes = []
    for _, s, c_out in LAYER_SPECS:
        h = -(-h // s)
        w = -(-w // s)
        shapes.append((h, w, c_out))
    return shapes


def init_weights():
    """He-initialised deterministic weights, mixed-sign (so ReLU yields
    realistic 40-70% sparsity)."""
    key = jax.random.PRNGKey(SEED)
    weights = []
    c_in = INPUT_SHAPE[2]
    for ks, _s, c_out in LAYER_SPECS:
        key, sub = jax.random.split(key)
        scale = (2.0 / (ks * ks * c_in)) ** 0.5
        w = jax.random.normal(sub, (ks, ks, c_in, c_out), jnp.float32) * scale
        weights.append(w)
        c_in = c_out
    return weights


def cnn_forward(image, *, interpret=True):
    """Run the stack; returns the tuple of every layer's post-ReLU
    activation map (the feature maps GrateTile stores and fetches).

    image: (32, 32, 1) float32.
    """
    weights = init_weights()
    x = image
    activations = []
    for (ks, s, _c_out), w in zip(LAYER_SPECS, weights):
        del ks
        x = conv2d_same(x, w, stride=s, interpret=interpret)
        x = jnp.maximum(x, 0.0)  # ReLU: the sparsity source
        activations.append(x)
    return tuple(activations)
