//! Standalone entry for the self-hosted invariant linter — the same
//! pass as `gratetile lint`, packaged as its own binary so CI and
//! pre-commit hooks can run it without the full CLI:
//!
//! ```text
//! gratetile-lint [--root DIR] [--deny-warnings] [--report FILE]
//! ```
//!
//! Exit status: 0 when clean (under `--deny-warnings`, clean also means
//! no stale suppressions), 1 otherwise.

use gratetile::cli::Cli;
use gratetile::log_error;

fn main() {
    // Reuse the `Cli` parser with a synthetic subcommand slot.
    let args = std::iter::once("lint".to_string()).chain(std::env::args().skip(1));
    let cli = Cli::parse(args);
    let deny = cli.has_flag("deny-warnings");
    match gratetile::analysis::run_cli(cli.opt("root"), deny, cli.opt("report")) {
        Ok((rendered, ok)) => {
            print!("{rendered}");
            if !ok {
                std::process::exit(1);
            }
        }
        Err(e) => {
            log_error!("{e:#}");
            std::process::exit(1);
        }
    }
}
