//! The Fig. 1 power model: a SCALE-sim-style analytic simulation of the
//! benchmark networks on a 16×16 systolic array, priced with Horowitz
//! ISSCC'14 energy numbers.
//!
//! The paper uses this figure to motivate GrateTile: DRAM feature reads
//! consume over half the power, and the MAC share shrinks from ~35 %
//! (AlexNet, 2012) to ~15 % (2016-era networks). We reproduce the same
//! methodology — analytic access counts per layer (no cycle-accurate
//! simulation; SCALE-sim itself is analytic about DRAM traffic) — with
//! every assumption documented in [`systolic`].

pub mod energy;
pub mod roofline;
pub mod systolic;

pub use energy::EnergyTable;
pub use roofline::{roofline, roofline_measured, MacSource, Machine, Roofline};
pub use systolic::{network_power, ArrayConfig, LayerCounts, PowerBreakdown};
