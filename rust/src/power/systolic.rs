//! Analytic 16×16 output-stationary systolic-array model (SCALE-sim
//! methodology, paper refs [12]–[14]).
//!
//! Assumptions (documented, per DESIGN.md §2):
//!
//! * **Array**: 16×16 PEs, output stationary — each pass pins a
//!   16-output-channel × 16-pixel tile of outputs and streams inputs
//!   and weights through.
//! * **DRAM feature reads**: the input tile is re-read from DRAM once
//!   per group of 16 output channels (`ceil(c_out/16)` passes), the
//!   dominant reuse limit of an OS array whose buffer holds one input
//!   tile. Halo overlap uses the exact tile-walker fetch.
//! * **DRAM weight reads**: weights stream once per pass over the
//!   spatial tiles unless the layer's weights fit in half the global
//!   buffer, in which case they are read once.
//! * **DRAM output writes**: each output word written once.
//! * **SRAM**: every MAC consumes one input and one weight word from
//!   SRAM through row/column broadcast over 16 PEs (2·MACs/16 reads)
//!   and each output accumulates once per input-channel slice
//!   (MACs/256 writes + final drain).
//!
//! These choices reproduce the Fig. 1 narrative: the MAC share falls
//! from ~35 % (AlexNet) to ~15 % (2016 networks), and DRAM feature
//! reads consume over half of the non-MAC power.

use super::energy::EnergyTable;
use crate::config::hardware::Platform;
use crate::config::layer::ConvLayer;
use crate::config::zoo::{full_conv_stack, Network};
use crate::sim::walker::TileWalker;

/// Systolic array configuration (SCALE-sim-class SRAM sizing: separate
/// megabyte-scale input and filter buffers).
#[derive(Debug, Clone, Copy)]
pub struct ArrayConfig {
    pub rows: usize,
    pub cols: usize,
    /// Input/output global buffer in 16-bit words (512 KB).
    pub buffer_words: usize,
    /// Dedicated filter buffer in 16-bit words (2 MB): layers whose
    /// weights fit are weight-resident (read once from DRAM).
    pub weight_buffer_words: usize,
}

impl Default for ArrayConfig {
    fn default() -> Self {
        Self {
            rows: 16,
            cols: 16,
            buffer_words: 256 * 1024,
            weight_buffer_words: 1024 * 1024,
        }
    }
}

/// Raw access counts for one layer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerCounts {
    pub macs: u64,
    pub dram_feature_words: u64,
    pub dram_weight_words: u64,
    pub dram_output_words: u64,
    pub sram_words: u64,
}

impl LayerCounts {
    pub fn add(&mut self, o: &LayerCounts) {
        self.macs += o.macs;
        self.dram_feature_words += o.dram_feature_words;
        self.dram_weight_words += o.dram_weight_words;
        self.dram_output_words += o.dram_output_words;
        self.sram_words += o.sram_words;
    }
}

/// Count accesses for one layer on the array.
pub fn layer_counts(cfg: &ArrayConfig, layer: &ConvLayer) -> LayerCounts {
    let macs = layer.macs();

    // Exact tiled fetch (with halo overlap) via the shared walker, on the
    // large-tile platform the buffer corresponds to.
    let hw = Platform::EyerissLargeTile.hardware();
    let tile = hw.tile_for_layer(layer);
    let walker = TileWalker::new(*layer, tile);
    let one_pass_feature = walker.baseline_words();

    // OS array: one pass per 16-output-channel group re-reads the input.
    let cout_passes = layer.c_out.div_ceil(cfg.cols) as u64;
    let dram_feature_words = one_pass_feature * cout_passes;

    // Weights: resident if they fit the filter buffer, else streamed
    // once per spatial tile.
    let weight_words = layer.weight_words();
    let spatial_tiles = (walker.n_ty * walker.n_tx) as u64;
    let dram_weight_words = if (weight_words as usize) <= cfg.weight_buffer_words {
        weight_words
    } else {
        weight_words * spatial_tiles
    };

    let dram_output_words = layer.output_words();

    // SRAM traffic: 2 operand reads per MAC amortised over a 16-wide
    // broadcast + accumulator writeback per 16x16 tile drain.
    let sram_words = 2 * macs / cfg.rows as u64 + layer.output_words();

    LayerCounts { macs, dram_feature_words, dram_weight_words, dram_output_words, sram_words }
}

/// Energy breakdown for a network (the Fig. 1 bar).
#[derive(Debug, Clone)]
pub struct PowerBreakdown {
    pub network: Network,
    pub counts: LayerCounts,
    pub mac_pj: f64,
    pub dram_feature_pj: f64,
    pub dram_weight_pj: f64,
    pub dram_output_pj: f64,
    pub sram_pj: f64,
}

impl PowerBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.mac_pj + self.dram_feature_pj + self.dram_weight_pj + self.dram_output_pj + self.sram_pj
    }

    pub fn mac_share(&self) -> f64 {
        self.mac_pj / self.total_pj()
    }

    pub fn dram_feature_share(&self) -> f64 {
        self.dram_feature_pj / self.total_pj()
    }

    /// DRAM feature read share of the *non-MAC* power — the paper's
    /// "over half of the remaining power" claim.
    pub fn dram_feature_share_of_rest(&self) -> f64 {
        self.dram_feature_pj / (self.total_pj() - self.mac_pj)
    }

    /// Fractions per category, in Fig. 1 legend order:
    /// [MAC, DRAM feature read, DRAM weight read, DRAM output write, SRAM].
    pub fn shares(&self) -> [f64; 5] {
        let t = self.total_pj();
        [
            self.mac_pj / t,
            self.dram_feature_pj / t,
            self.dram_weight_pj / t,
            self.dram_output_pj / t,
            self.sram_pj / t,
        ]
    }
}

/// Simulate a full network (Fig. 1 bar).
pub fn network_power(
    cfg: &ArrayConfig,
    energy: &EnergyTable,
    net: Network,
) -> PowerBreakdown {
    let mut total = LayerCounts::default();
    for layer in full_conv_stack(net) {
        total.add(&layer_counts(cfg, &layer));
    }
    PowerBreakdown {
        network: net,
        counts: total,
        mac_pj: total.macs as f64 * energy.mac_pj,
        dram_feature_pj: total.dram_feature_words as f64 * energy.dram_word_pj,
        dram_weight_pj: total.dram_weight_words as f64 * energy.dram_word_pj,
        dram_output_pj: total.dram_output_words as f64 * energy.dram_word_pj,
        sram_pj: total.sram_words as f64 * energy.sram_word_pj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breakdown(net: Network) -> PowerBreakdown {
        network_power(&ArrayConfig::default(), &EnergyTable::default(), net)
    }

    #[test]
    fn shares_sum_to_one() {
        for net in Network::all() {
            let b = breakdown(net);
            let s: f64 = b.shares().iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{net:?}");
        }
    }

    /// Fig. 1 headline: DRAM feature read dominates the non-MAC power.
    #[test]
    fn dram_feature_read_is_primary_draw() {
        for net in Network::all() {
            let b = breakdown(net);
            assert!(
                b.dram_feature_share_of_rest() > 0.5,
                "{net:?}: feature share of rest {}",
                b.dram_feature_share_of_rest()
            );
        }
    }

    /// Fig. 1 trend: the MAC share shrinks from 2012 (AlexNet) to the
    /// later networks with smaller kernels / deeper stacks.
    #[test]
    fn mac_share_declines_over_network_generations() {
        let alex = breakdown(Network::AlexNet).mac_share();
        let r18 = breakdown(Network::ResNet18).mac_share();
        let r50 = breakdown(Network::ResNet50).mac_share();
        assert!(alex > r18, "alexnet {alex} vs resnet18 {r18}");
        assert!(alex > r50, "alexnet {alex} vs resnet50 {r50}");
        // Magnitudes in the paper's ballpark (35% -> 15%).
        assert!((0.15..0.45).contains(&alex), "alexnet {alex}");
        assert!(r18 < 0.25, "resnet18 {r18}");
        assert!(breakdown(Network::Vdsr).mac_share() < 0.35);
    }

    #[test]
    fn counts_scale_with_network_size() {
        let a = breakdown(Network::AlexNet).counts;
        let v = breakdown(Network::Vgg16).counts;
        assert!(v.macs > 10 * a.macs);
        assert!(v.dram_feature_words > a.dram_feature_words);
    }

    #[test]
    fn weight_residency_kicks_in_for_small_layers() {
        let cfg = ArrayConfig::default();
        // Tiny layer: weights resident, read once.
        let small = ConvLayer::new(1, 1, 56, 56, 16, 16);
        let c = layer_counts(&cfg, &small);
        assert_eq!(c.dram_weight_words, small.weight_words());
        // Huge layer: weights streamed per spatial tile.
        let big = ConvLayer::new(1, 1, 56, 56, 512, 512);
        let cb = layer_counts(&cfg, &big);
        assert!(cb.dram_weight_words > big.weight_words());
    }
}
