//! Energy-per-operation table (Horowitz, "Computing's energy problem",
//! ISSCC 2014 — the paper's reference [11]), 45 nm, scaled to the
//! 16-bit datapath the simulator uses.
//!
//! Values are picojoules per 16-bit word / operation. Absolute numbers
//! are process-dependent; what Fig. 1 relies on is the *ratio* — DRAM
//! access ≈ 50–200× SRAM ≈ 100–1000× a MAC — which these preserve.

/// Energy in pJ per elementary operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyTable {
    /// One 16-bit multiply-accumulate (fixed point).
    pub mac_pj: f64,
    /// One 16-bit word from a ~100 KB on-chip SRAM.
    pub sram_word_pj: f64,
    /// One 16-bit word from DRAM (LPDDR-class, incl. I/O).
    pub dram_word_pj: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        // Horowitz 45nm: 32b int mult 3.1 pJ, 8b add 0.03 pJ, 32b SRAM
        // (8KB) 5 pJ, 32b DRAM 640 pJ. Scaled to 16-bit words and a
        // 100KB-class buffer:
        Self { mac_pj: 1.0, sram_word_pj: 6.0, dram_word_pj: 320.0 }
    }
}

impl EnergyTable {
    /// Sanity ratios used by the Fig. 1 narrative.
    pub fn dram_to_mac_ratio(&self) -> f64 {
        self.dram_word_pj / self.mac_pj
    }

    pub fn dram_to_sram_ratio(&self) -> f64 {
        self.dram_word_pj / self.sram_word_pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_match_horowitz_orders_of_magnitude() {
        let e = EnergyTable::default();
        assert!(e.dram_to_mac_ratio() > 100.0);
        assert!(e.dram_to_sram_ratio() > 20.0);
        assert!(e.sram_word_pj > e.mac_pj);
    }
}
