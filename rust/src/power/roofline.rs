//! Roofline analysis: is a layer compute- or memory-bound, and what
//! does GrateTile's bandwidth saving buy in *runtime*?
//!
//! The paper's motivation (§I) is that "an algorithm can become
//! increasingly memory bound for future architectures" — compression is
//! worth silicon exactly when the feature stream is the binding
//! constraint. This analysis makes that quantitative per layer:
//!
//! * compute time = MACs / (array MACs/cycle),
//! * memory time = DRAM words / (bus words/cycle), with the feature
//!   stream scaled by a division mode's measured bandwidth saving,
//! * bound = max of the two (perfect overlap assumption, the same one
//!   double-buffering targets).

use super::systolic::{layer_counts, ArrayConfig};
use crate::compress::CodecPolicy;
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::sim::experiment::run_layer;
use crate::tensor::FeatureMap;
use crate::tiling::division::{DivisionError, DivisionMode};

/// Machine balance for the roofline.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    pub array: ArrayConfig,
    /// DRAM bus throughput in 16-bit words per array cycle.
    pub bus_words_per_cycle: f64,
}

impl Default for Machine {
    fn default() -> Self {
        // 256-MAC array @ 1 GHz vs ~8 GB/s effective DRAM: 4 words/cycle.
        Self { array: ArrayConfig::default(), bus_words_per_cycle: 4.0 }
    }
}

/// Roofline verdict for one layer.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub compute_cycles: f64,
    pub memory_cycles_dense: f64,
    pub memory_cycles_compressed: f64,
    /// Bandwidth saving applied to the feature stream.
    pub feature_saving: f64,
}

impl Roofline {
    pub fn bound_dense(&self) -> &'static str {
        if self.memory_cycles_dense > self.compute_cycles {
            "memory"
        } else {
            "compute"
        }
    }

    pub fn runtime_dense(&self) -> f64 {
        self.compute_cycles.max(self.memory_cycles_dense)
    }

    pub fn runtime_compressed(&self) -> f64 {
        self.compute_cycles.max(self.memory_cycles_compressed)
    }

    /// End-to-end speedup from compressing the feature stream.
    pub fn speedup(&self) -> f64 {
        self.runtime_dense() / self.runtime_compressed()
    }
}

/// Analyse one layer: measure the division mode's feature saving on
/// `fm`, then place the layer on the roofline with and without it.
pub fn roofline(
    machine: &Machine,
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
) -> Result<Roofline, DivisionError> {
    let counts = layer_counts(&machine.array, layer);
    let report = run_layer(hw, layer, fm, mode, policy)?;
    let saving = report.saving_with_meta().max(0.0);

    let macs_per_cycle = (machine.array.rows * machine.array.cols) as f64;
    let compute_cycles = counts.macs as f64 / macs_per_cycle;

    let feature = counts.dram_feature_words as f64;
    let other = (counts.dram_weight_words + counts.dram_output_words) as f64;
    let memory_cycles_dense = (feature + other) / machine.bus_words_per_cycle;
    let memory_cycles_compressed =
        (feature * (1.0 - saving) + other) / machine.bus_words_per_cycle;

    Ok(Roofline {
        compute_cycles,
        memory_cycles_dense,
        memory_cycles_compressed,
        feature_saving: saving,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn analyse(layer: ConvLayer, density: f64) -> Roofline {
        let machine = Machine::default();
        let hw = Platform::EyerissLargeTile.hardware();
        let fm = generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(density, 3));
        roofline(&machine, &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
            .unwrap()
    }

    /// A 1x1 conv (low arithmetic intensity: 1 MAC/word per cout-group)
    /// is memory-bound; GrateTile's saving translates into speedup.
    #[test]
    fn pointwise_is_memory_bound_and_speeds_up() {
        let r = analyse(ConvLayer::new(0, 1, 56, 56, 256, 64), 0.35);
        assert_eq!(r.bound_dense(), "memory");
        assert!(r.speedup() > 1.2, "speedup {}", r.speedup());
    }

    /// A 3x3 conv with many output channels is compute-bound; the
    /// bandwidth saving then buys little runtime (the honest flip side).
    #[test]
    fn fat_conv_is_compute_bound() {
        let r = analyse(ConvLayer::new(1, 1, 28, 28, 256, 512), 0.35);
        assert_eq!(r.bound_dense(), "compute");
        assert!(r.speedup() < 1.1, "speedup {}", r.speedup());
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let layer = ConvLayer::new(0, 1, 56, 56, 256, 64);
        let sparse = analyse(layer, 0.15);
        let dense = analyse(layer, 0.80);
        assert!(sparse.speedup() >= dense.speedup());
    }

    #[test]
    fn compressed_memory_never_exceeds_dense() {
        let r = analyse(ConvLayer::new(1, 1, 56, 56, 64, 64), 0.4);
        assert!(r.memory_cycles_compressed <= r.memory_cycles_dense);
        assert!(r.runtime_compressed() <= r.runtime_dense());
    }
}
