//! Roofline analysis: is a layer compute- or memory-bound, and what
//! does GrateTile's bandwidth saving buy in *runtime*?
//!
//! The paper's motivation (§I) is that "an algorithm can become
//! increasingly memory bound for future architectures" — compression is
//! worth silicon exactly when the feature stream is the binding
//! constraint. This analysis makes that quantitative per layer:
//!
//! * compute time = MACs / (array MACs/cycle),
//! * memory time = DRAM words / (bus words/cycle), with the feature
//!   stream scaled by a division mode's measured bandwidth saving,
//! * bound = max of the two (perfect overlap assumption, the same one
//!   double-buffering targets).

use super::systolic::{layer_counts, ArrayConfig};
use crate::compress::CodecPolicy;
use crate::compute::GemmStats;
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::sim::experiment::run_layer;
use crate::tensor::FeatureMap;
use crate::tiling::division::{DivisionError, DivisionMode};

/// Where a roofline's MAC count came from. Reports must say which —
/// the analytic `ConvLayer::macs()` closed form is an *estimate*
/// (it counts SAME-padding clipped taps the kernel never executes and
/// assumes a dense input); kernel counters are *measured*. Exactly one
/// source prices a layer, never a mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacSource {
    /// MACs executed by the GEMM compute backend.
    Measured,
    /// Analytic estimate — no compute backend ran.
    Estimate,
}

impl MacSource {
    pub fn name(&self) -> &'static str {
        match self {
            MacSource::Measured => "measured",
            MacSource::Estimate => "estimate",
        }
    }
}

/// Machine balance for the roofline.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    pub array: ArrayConfig,
    /// DRAM bus throughput in 16-bit words per array cycle.
    pub bus_words_per_cycle: f64,
}

impl Default for Machine {
    fn default() -> Self {
        // 256-MAC array @ 1 GHz vs ~8 GB/s effective DRAM: 4 words/cycle.
        Self { array: ArrayConfig::default(), bus_words_per_cycle: 4.0 }
    }
}

/// Roofline verdict for one layer.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub compute_cycles: f64,
    pub memory_cycles_dense: f64,
    pub memory_cycles_compressed: f64,
    /// Bandwidth saving applied to the feature stream.
    pub feature_saving: f64,
    /// MACs that priced `compute_cycles`, and where they came from.
    pub macs: u64,
    pub mac_source: MacSource,
}

impl Roofline {
    pub fn bound_dense(&self) -> &'static str {
        if self.memory_cycles_dense > self.compute_cycles {
            "memory"
        } else {
            "compute"
        }
    }

    pub fn runtime_dense(&self) -> f64 {
        self.compute_cycles.max(self.memory_cycles_dense)
    }

    pub fn runtime_compressed(&self) -> f64 {
        self.compute_cycles.max(self.memory_cycles_compressed)
    }

    /// End-to-end speedup from compressing the feature stream.
    pub fn speedup(&self) -> f64 {
        self.runtime_dense() / self.runtime_compressed()
    }
}

/// Analyse one layer: measure the division mode's feature saving on
/// `fm`, then place the layer on the roofline with and without it.
/// Compute time is priced from the analytic MAC *estimate* (labelled
/// [`MacSource::Estimate`]); pass the GEMM backend's counters through
/// [`roofline_measured`] when a compute backend ran.
pub fn roofline(
    machine: &Machine,
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
) -> Result<Roofline, DivisionError> {
    roofline_inner(machine, hw, layer, fm, mode, policy.into(), None)
}

/// [`roofline`] with the compute side priced from **measured** kernel
/// counters instead of the analytic estimate — use when the GEMM
/// compute backend ran. A zero `stats` (no backend run) falls back to
/// the estimate and is labelled so: exactly one source prices the
/// layer, never both.
pub fn roofline_measured(
    machine: &Machine,
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
    stats: &GemmStats,
) -> Result<Roofline, DivisionError> {
    let measured = (stats.dense_macs > 0).then_some(stats.macs);
    roofline_inner(machine, hw, layer, fm, mode, policy.into(), measured)
}

fn roofline_inner(
    machine: &Machine,
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: CodecPolicy,
    measured_macs: Option<u64>,
) -> Result<Roofline, DivisionError> {
    let counts = layer_counts(&machine.array, layer);
    let report = run_layer(hw, layer, fm, mode, policy)?;
    let saving = report.saving_with_meta().max(0.0);

    let (macs, mac_source) = match measured_macs {
        Some(m) => (m, MacSource::Measured),
        None => (counts.macs, MacSource::Estimate),
    };
    let macs_per_cycle = (machine.array.rows * machine.array.cols) as f64;
    let compute_cycles = macs as f64 / macs_per_cycle;

    let feature = counts.dram_feature_words as f64;
    let other = (counts.dram_weight_words + counts.dram_output_words) as f64;
    let memory_cycles_dense = (feature + other) / machine.bus_words_per_cycle;
    let memory_cycles_compressed =
        (feature * (1.0 - saving) + other) / machine.bus_words_per_cycle;

    Ok(Roofline {
        compute_cycles,
        memory_cycles_dense,
        memory_cycles_compressed,
        feature_saving: saving,
        macs,
        mac_source,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn analyse(layer: ConvLayer, density: f64) -> Roofline {
        let machine = Machine::default();
        let hw = Platform::EyerissLargeTile.hardware();
        let fm = generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(density, 3));
        roofline(&machine, &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
            .unwrap()
    }

    /// A 1x1 conv (low arithmetic intensity: 1 MAC/word per cout-group)
    /// is memory-bound; GrateTile's saving translates into speedup.
    #[test]
    fn pointwise_is_memory_bound_and_speeds_up() {
        let r = analyse(ConvLayer::new(0, 1, 56, 56, 256, 64), 0.35);
        assert_eq!(r.bound_dense(), "memory");
        assert!(r.speedup() > 1.2, "speedup {}", r.speedup());
    }

    /// A 3x3 conv with many output channels is compute-bound; the
    /// bandwidth saving then buys little runtime (the honest flip side).
    #[test]
    fn fat_conv_is_compute_bound() {
        let r = analyse(ConvLayer::new(1, 1, 28, 28, 256, 512), 0.35);
        assert_eq!(r.bound_dense(), "compute");
        assert!(r.speedup() < 1.1, "speedup {}", r.speedup());
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let layer = ConvLayer::new(0, 1, 56, 56, 256, 64);
        let sparse = analyse(layer, 0.15);
        let dense = analyse(layer, 0.80);
        assert!(sparse.speedup() >= dense.speedup());
    }

    #[test]
    fn compressed_memory_never_exceeds_dense() {
        let r = analyse(ConvLayer::new(1, 1, 56, 56, 64, 64), 0.4);
        assert!(r.memory_cycles_compressed <= r.memory_cycles_dense);
        assert!(r.runtime_compressed() <= r.runtime_dense());
    }

    /// Measured kernel counters shrink the compute roof on sparse
    /// inputs and flip the label; a zero `GemmStats` (no backend run)
    /// falls back to the estimate — one source, never both.
    #[test]
    fn measured_macs_replace_the_estimate() {
        use crate::compute::{GemmBackend, SkipPolicy};
        use crate::coordinator::conv::Weights;
        let machine = Machine::default();
        let hw = Platform::EyerissLargeTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.3, 5));
        let w = Weights::random(&layer, 2);
        let mode = DivisionMode::GrateTile { n: 8 };
        let run = GemmBackend::new(hw)
            .with_mode(mode)
            .with_skip(SkipPolicy::ZeroSkip)
            .conv_relu(&layer, &w, &fm)
            .unwrap();
        let est = roofline(&machine, &hw, &layer, &fm, mode, Scheme::Bitmask).unwrap();
        let meas =
            roofline_measured(&machine, &hw, &layer, &fm, mode, Scheme::Bitmask, &run.stats)
                .unwrap();
        assert_eq!(est.mac_source, MacSource::Estimate);
        assert_eq!(est.macs, layer.macs());
        assert_eq!(meas.mac_source, MacSource::Measured);
        assert_eq!(meas.macs, run.stats.macs);
        assert!(meas.compute_cycles < est.compute_cycles, "sparse input must shrink the roof");
        // Memory side is MAC-source independent.
        assert_eq!(meas.memory_cycles_dense, est.memory_cycles_dense);
        // No backend run ⇒ honest fallback to the estimate.
        let zero = roofline_measured(
            &machine, &hw, &layer, &fm, mode, Scheme::Bitmask, &GemmStats::default(),
        )
        .unwrap();
        assert_eq!(zero.mac_source, MacSource::Estimate);
        assert_eq!(zero.macs, est.macs);
    }
}
