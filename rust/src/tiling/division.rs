//! Concrete sub-tensor grids over a feature map.
//!
//! A [`Division`] partitions an `H × W × C` feature map into sub-tensors:
//! a list of spatial segments per axis (uneven for GrateTile, even for
//! the uniform baselines) crossed with fixed-depth channel groups (the
//! paper never divides along channels, §III-B; the 8-deep group is the
//! storage block depth of Fig. 7).
//!
//! The division also carries the Fig. 7 *metadata block* grouping: every
//! mod-N period (or uniform block) owns one pointer record; GrateTile
//! records additionally hold the compressed sizes of the up-to-4 uneven
//! sub-tensors inside the period.

use super::grate::GrateConfig;
use crate::config::hardware::Hardware;
use crate::config::layer::{ConvLayer, TileShape};

/// Channel depth of storage sub-tensors/blocks (Fig. 7: 8×8×8 blocks).
pub const BLOCK_CHANNELS: usize = 8;

/// One segment along a spatial axis: `[start, start + len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Seg {
    pub start: usize,
    pub len: usize,
}

impl Seg {
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// How to divide a feature map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DivisionMode {
    /// Uniform `edge × edge × 8` blocks (the §IV baselines; `edge = 1`
    /// is the compact-packed upper bound with 32-bit pointers).
    Uniform { edge: usize },
    /// GrateTile with hardware modulus `n` (paper recommends 8).
    GrateTile { n: usize },
    /// No spatial division: one sub-tensor per channel group (the
    /// whole-channel ablation of §IV-B(3)).
    WholeMap,
    /// Uniform `edge × edge × 8` grid with an *explicit* cut anchor
    /// (cuts at positions ≡ `anchor` (mod `edge`)) instead of the
    /// left-window-boundary anchor [`DivisionMode::Uniform`] derives
    /// from the layer halo. This is the tuner's split-point axis: it
    /// exposes shifted grids (including deliberately bad ones — split
    /// at 1, split at `edge-1`) as first-class candidates. `edge ≥ 2`
    /// and `anchor < edge`; `Uniform{edge}` ≡ `Anchored{edge, -halo mod
    /// edge}` by construction.
    Anchored { edge: usize, anchor: usize },
}

impl DivisionMode {
    pub fn name(&self) -> String {
        match self {
            DivisionMode::Uniform { edge } => format!("Uniform {edge}x{edge}x8"),
            DivisionMode::GrateTile { n } => format!("GrateTile (mod {n})"),
            DivisionMode::WholeMap => "WholeMap".to_string(),
            DivisionMode::Anchored { edge, anchor } => format!("Anchored {edge}x{edge}@{anchor}"),
        }
    }

    /// Stable machine key: round-trips through [`DivisionMode::parse`]
    /// and is the `mode=` value in tuned manifests and CLI `--mode`.
    pub fn key(&self) -> String {
        match self {
            DivisionMode::Uniform { edge } => format!("uniform{edge}"),
            DivisionMode::GrateTile { n } => format!("grate{n}"),
            DivisionMode::WholeMap => "wholemap".to_string(),
            DivisionMode::Anchored { edge, anchor } => format!("anchored{edge}@{anchor}"),
        }
    }

    /// Parse a [`DivisionMode::key`]-style name. THE one parser: the CLI
    /// `--mode` flag and the tuned-manifest reader both delegate here.
    pub fn parse(s: &str) -> Result<DivisionMode, DivisionError> {
        let bad = |what: &str| DivisionError::Invalid(format!("{what} in mode '{s}'"));
        if let Some(n) = s.strip_prefix("grate") {
            let n: usize = n.parse().map_err(|_| bad("bad modulus"))?;
            return Ok(DivisionMode::GrateTile { n });
        }
        if let Some(e) = s.strip_prefix("uniform") {
            let e: usize = e.parse().map_err(|_| bad("bad edge"))?;
            return Ok(DivisionMode::Uniform { edge: e });
        }
        if let Some(rest) = s.strip_prefix("anchored") {
            let (e, a) = rest.split_once('@').ok_or_else(|| bad("missing '@anchor'"))?;
            let edge: usize = e.parse().map_err(|_| bad("bad edge"))?;
            let anchor: usize = a.parse().map_err(|_| bad("bad anchor"))?;
            return Ok(DivisionMode::Anchored { edge, anchor });
        }
        if s == "wholemap" {
            return Ok(DivisionMode::WholeMap);
        }
        Err(DivisionError::Invalid(format!(
            "unknown mode '{s}' (grate4|grate8|grate16|uniform8|uniform4|uniform2|uniform1|\
             wholemap|anchored<E>@<A>)"
        )))
    }

    /// The division modes compared in Table III, in the paper's row order.
    pub fn table3_modes() -> Vec<DivisionMode> {
        vec![
            DivisionMode::GrateTile { n: 4 },
            DivisionMode::GrateTile { n: 8 },
            DivisionMode::GrateTile { n: 16 },
            DivisionMode::Uniform { edge: 8 },
            DivisionMode::Uniform { edge: 4 },
            DivisionMode::Uniform { edge: 2 },
            DivisionMode::Uniform { edge: 1 },
        ]
    }
}

/// Why a division cannot be built for a layer/tile combination.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DivisionError {
    /// Paper Table III footnote a: the fetched tile is smaller than one
    /// sub-tensor period, or `n` does not divide the window step — the
    /// GrateTile configuration does not exist for this tile.
    NotApplicable { n: usize, reason: String },
    Invalid(String),
}

impl std::fmt::Display for DivisionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DivisionError::NotApplicable { n, reason } => {
                write!(f, "GrateTile mod {n} not applicable: {reason}")
            }
            DivisionError::Invalid(msg) => write!(f, "invalid division parameter: {msg}"),
        }
    }
}

impl std::error::Error for DivisionError {}

/// Reference to one sub-tensor in a division grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SubTensorRef {
    pub iy: usize,
    pub ix: usize,
    pub icg: usize,
}

/// A concrete division of an `h × w × c` feature map.
#[derive(Debug, Clone)]
pub struct Division {
    pub mode: DivisionMode,
    pub fm_h: usize,
    pub fm_w: usize,
    pub fm_c: usize,
    /// Spatial segments (cover `[0,h)` / `[0,w)` exactly, no overlap).
    pub ys: Vec<Seg>,
    pub xs: Vec<Seg>,
    /// Channel group depth (8) and count.
    pub cd: usize,
    pub n_cgroups: usize,
    /// Metadata block id per segment index, per axis (non-decreasing).
    pub block_of_y: Vec<usize>,
    pub block_of_x: Vec<usize>,
    pub n_blocks_y: usize,
    pub n_blocks_x: usize,
    /// Metadata bits per (block_y, block_x, cgroup) record.
    pub meta_bits_per_block: usize,
    /// Compact packing (Uniform 1×1×8): sub-tensors are not line-aligned.
    pub compact: bool,
}

/// Split `[0, len)` at the given sorted cut positions.
fn segments_from_cuts(len: usize, cuts: &[usize]) -> Vec<Seg> {
    let mut segs = Vec::with_capacity(cuts.len() + 1);
    let mut prev = 0usize;
    for &c in cuts {
        debug_assert!(c > prev && c < len);
        segs.push(Seg { start: prev, len: c - prev });
        prev = c;
    }
    if prev < len || len == 0 {
        if len > 0 {
            segs.push(Seg { start: prev, len: len - prev });
        }
    }
    segs
}

/// Segments of a uniform `edge`-grid over `[0, len)` with cuts at
/// positions ≡ `anchor` (mod `edge`) — shared by the Uniform and
/// Anchored build arms.
fn uniform_segments(len: usize, edge: usize, anchor: usize) -> Vec<Seg> {
    let first = if anchor == 0 { edge } else { anchor };
    let cuts: Vec<usize> = (0..).map(|i| first + i * edge).take_while(|&p| p < len).collect();
    segments_from_cuts(len, &cuts)
}

/// Group segments into metadata blocks: a new block starts at every
/// segment whose start ≡ `anchor` (mod `n`). Returns (block_of, n_blocks).
fn group_blocks(segs: &[Seg], n: usize, anchor: usize) -> (Vec<usize>, usize) {
    let mut block_of = Vec::with_capacity(segs.len());
    let mut bid = 0usize;
    for (i, s) in segs.iter().enumerate() {
        if i > 0 && s.start % n == anchor {
            bid += 1;
        }
        block_of.push(bid);
    }
    (block_of, if segs.is_empty() { 0 } else { bid + 1 })
}

impl Division {
    /// Build a division for a feature map processed by `layer` with
    /// processing tile `tile` on hardware `hw`.
    pub fn build(
        mode: DivisionMode,
        layer: &ConvLayer,
        tile: &TileShape,
        hw: &Hardware,
        fm_h: usize,
        fm_w: usize,
        fm_c: usize,
    ) -> Result<Division, DivisionError> {
        let cd = BLOCK_CHANNELS;
        let n_cgroups = fm_c.div_ceil(cd);
        match mode {
            DivisionMode::Uniform { edge } => {
                if edge == 0 {
                    return Err(DivisionError::Invalid("edge must be > 0".into()));
                }
                // The uniform grid is anchored at the *left window
                // boundary* residue −k·d (the B_l progression of Fig. 5):
                // the strongest uniform baseline, and the one the paper's
                // accelerators [15], [16] use — a grid anchored at 0
                // would double the halo over-fetch for free. GrateTile
                // additionally cuts at B_r; uniform cuts at B_l only.
                let anchor = crate::util::umod(-(layer.halo() as i64), edge as i64) as usize;
                let ys = uniform_segments(fm_h, edge, anchor);
                let xs = uniform_segments(fm_w, edge, anchor);
                let (block_of_y, n_blocks_y) = group_blocks(&ys, edge, anchor);
                let (block_of_x, n_blocks_x) = group_blocks(&xs, edge, anchor);
                // Table II: aligned uniform blocks carry a 28-bit pointer;
                // the compact 1×1×8 scheme uses full 32-bit addresses.
                let (meta_bits, compact) =
                    if edge == 1 { (32, true) } else { (hw.pointer_bits, false) };
                Ok(Division {
                    mode,
                    fm_h,
                    fm_w,
                    fm_c,
                    ys,
                    xs,
                    cd,
                    n_cgroups,
                    block_of_y,
                    block_of_x,
                    n_blocks_y,
                    n_blocks_x,
                    meta_bits_per_block: meta_bits,
                    compact,
                })
            }
            DivisionMode::GrateTile { n } => {
                if n == 0 {
                    return Err(DivisionError::Invalid("modulus must be > 0".into()));
                }
                // Native configurations per axis; the hardware modulus n
                // must divide both window steps (divisor property).
                let gy = GrateConfig::for_axis(layer, tile.th);
                let gx = GrateConfig::for_axis(layer, tile.tw);
                let gy = gy.reduce(n).ok_or_else(|| DivisionError::NotApplicable {
                    n,
                    reason: format!(
                        "mod {n} does not divide the vertical window step {}",
                        layer.s * tile.th
                    ),
                })?;
                let gx = gx.reduce(n).ok_or_else(|| DivisionError::NotApplicable {
                    n,
                    reason: format!(
                        "mod {n} does not divide the horizontal window step {}",
                        layer.s * tile.tw
                    ),
                })?;
                // Table III footnote a: a fetched tile smaller than one
                // period cannot amortise the block — not applicable.
                if tile.in_h(layer) < n || tile.in_w(layer) < n {
                    return Err(DivisionError::NotApplicable {
                        n,
                        reason: format!(
                            "fetched tile {}x{} is smaller than the mod-{n} sub-tensor period",
                            tile.in_h(layer),
                            tile.in_w(layer)
                        ),
                    });
                }
                let ys = segments_from_cuts(fm_h, &gy.cuts(fm_h));
                let xs = segments_from_cuts(fm_w, &gx.cuts(fm_w));
                let (block_of_y, n_blocks_y) = group_blocks(&ys, n, gy.residues[0]);
                let (block_of_x, n_blocks_x) = group_blocks(&xs, n, gx.residues[0]);
                // Fig. 7b record: 28-bit pointer + 20 size bits (§III-C).
                let meta_bits = hw.pointer_bits + hw.size_field_bits;
                Ok(Division {
                    mode,
                    fm_h,
                    fm_w,
                    fm_c,
                    ys,
                    xs,
                    cd,
                    n_cgroups,
                    block_of_y,
                    block_of_x,
                    n_blocks_y,
                    n_blocks_x,
                    meta_bits_per_block: meta_bits,
                    compact: false,
                })
            }
            DivisionMode::WholeMap => {
                let ys = vec![Seg { start: 0, len: fm_h }];
                let xs = vec![Seg { start: 0, len: fm_w }];
                Ok(Division {
                    mode,
                    fm_h,
                    fm_w,
                    fm_c,
                    ys,
                    xs,
                    cd,
                    n_cgroups,
                    block_of_y: vec![0],
                    block_of_x: vec![0],
                    n_blocks_y: 1,
                    n_blocks_x: 1,
                    meta_bits_per_block: hw.pointer_bits,
                    compact: false,
                })
            }
            DivisionMode::Anchored { edge, anchor } => {
                // Explicit-anchor grids exist so the tuner can search
                // split points; edge 1 would shadow the compact
                // Uniform{1} scheme with different metadata economics,
                // so it is rejected rather than silently aliased.
                if edge < 2 {
                    return Err(DivisionError::Invalid(
                        "anchored edge must be >= 2 (use uniform1 for compact packing)".into(),
                    ));
                }
                if anchor >= edge {
                    return Err(DivisionError::Invalid(format!(
                        "anchor {anchor} must be < edge {edge}"
                    )));
                }
                let ys = uniform_segments(fm_h, edge, anchor);
                let xs = uniform_segments(fm_w, edge, anchor);
                let (block_of_y, n_blocks_y) = group_blocks(&ys, edge, anchor);
                let (block_of_x, n_blocks_x) = group_blocks(&xs, edge, anchor);
                Ok(Division {
                    mode,
                    fm_h,
                    fm_w,
                    fm_c,
                    ys,
                    xs,
                    cd,
                    n_cgroups,
                    block_of_y,
                    block_of_x,
                    n_blocks_y,
                    n_blocks_x,
                    meta_bits_per_block: hw.pointer_bits,
                    compact: false,
                })
            }
        }
    }

    /// Total sub-tensor count.
    pub fn n_subtensors(&self) -> usize {
        self.ys.len() * self.xs.len() * self.n_cgroups
    }

    /// Total metadata record count.
    pub fn n_blocks(&self) -> usize {
        self.n_blocks_y * self.n_blocks_x * self.n_cgroups
    }

    /// Total metadata bits for the map.
    pub fn total_meta_bits(&self) -> u64 {
        self.n_blocks() as u64 * self.meta_bits_per_block as u64
    }

    /// Sub-tensor slots per metadata record: the maximum number of
    /// sub-tensors any block holds (records are fixed-width, so every
    /// record carries this many size/tag fields — up to 4 for GrateTile
    /// blocks, 1 for uniform/whole-map blocks).
    pub fn record_slots(&self) -> usize {
        let max_run = |blocks: &[usize]| -> usize {
            // Block ids are non-decreasing along each axis; the longest
            // run of one id is that axis's per-block segment maximum.
            let mut best = 1;
            let mut cur = 1;
            for w in blocks.windows(2) {
                if w[1] == w[0] {
                    cur += 1;
                    best = best.max(cur);
                } else {
                    cur = 1;
                }
            }
            best
        };
        max_run(&self.block_of_y) * max_run(&self.block_of_x)
    }

    /// Channel depth of group `icg` (last group may be partial).
    pub fn cg_depth(&self, icg: usize) -> usize {
        debug_assert!(icg < self.n_cgroups);
        self.cd.min(self.fm_c - icg * self.cd)
    }

    /// Words in sub-tensor `(iy, ix, icg)`.
    pub fn subtensor_words(&self, r: SubTensorRef) -> usize {
        self.ys[r.iy].len * self.xs[r.ix].len * self.cg_depth(r.icg)
    }

    /// Linear index of a sub-tensor.
    pub fn linear(&self, r: SubTensorRef) -> usize {
        (r.iy * self.xs.len() + r.ix) * self.n_cgroups + r.icg
    }

    /// Inverse of [`Division::linear`] (the packing engine iterates
    /// sub-tensors by linear index).
    pub fn subtensor_coords(&self, li: usize) -> SubTensorRef {
        debug_assert!(li < self.n_subtensors());
        let icg = li % self.n_cgroups;
        let ix = (li / self.n_cgroups) % self.xs.len();
        let iy = li / (self.n_cgroups * self.xs.len());
        SubTensorRef { iy, ix, icg }
    }

    /// Linear index of the metadata block owning sub-tensor `r`.
    pub fn block_linear(&self, r: SubTensorRef) -> usize {
        (self.block_of_y[r.iy] * self.n_blocks_x + self.block_of_x[r.ix]) * self.n_cgroups
            + r.icg
    }

    /// Index range into `ys` of the segments owned by metadata block row
    /// `by` (`block_of_y` is non-decreasing, so this is a binary search).
    pub fn y_segs_of_block(&self, by: usize) -> std::ops::Range<usize> {
        let first = self.block_of_y.partition_point(|&b| b < by);
        let last = self.block_of_y.partition_point(|&b| b <= by);
        first..last
    }

    /// Index range into `xs` of the segments owned by block column `bx`.
    pub fn x_segs_of_block(&self, bx: usize) -> std::ops::Range<usize> {
        let first = self.block_of_x.partition_point(|&b| b < bx);
        let last = self.block_of_x.partition_point(|&b| b <= bx);
        first..last
    }

    /// Decompose a linear block id (as produced by
    /// [`Division::block_linear`]) into `(by, bx, icg)`.
    pub fn block_coords(&self, b: usize) -> (usize, usize, usize) {
        debug_assert!(b < self.n_blocks());
        let icg = b % self.n_cgroups;
        let bx = (b / self.n_cgroups) % self.n_blocks_x;
        let by = b / (self.n_cgroups * self.n_blocks_x);
        (by, bx, icg)
    }

    /// Indices of segments on `axis` intersecting `[lo, hi)`.
    /// Returns an index range into `ys`/`xs`.
    pub fn covering(segs: &[Seg], lo: usize, hi: usize) -> std::ops::Range<usize> {
        if lo >= hi || segs.is_empty() {
            return 0..0;
        }
        // First segment with end > lo.
        let first = segs.partition_point(|s| s.end() <= lo);
        // First segment with start >= hi.
        let last = segs.partition_point(|s| s.start < hi);
        first..last
    }

    /// Iterate sub-tensor refs intersecting a window
    /// `[y0,y1) × [x0,x1) × [c0,c1)` (clipped to the map by the caller).
    pub fn intersecting(
        &self,
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
    ) -> Vec<SubTensorRef> {
        let yr = Self::covering(&self.ys, y0, y1);
        let xr = Self::covering(&self.xs, x0, x1);
        let cg0 = c0 / self.cd;
        let cg1 = c1.div_ceil(self.cd).min(self.n_cgroups);
        let mut out =
            Vec::with_capacity(yr.len() * xr.len() * cg1.saturating_sub(cg0));
        for iy in yr {
            for ix in xr.clone() {
                for icg in cg0..cg1 {
                    out.push(SubTensorRef { iy, ix, icg });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;

    fn hw() -> Hardware {
        Platform::NvidiaSmallTile.hardware()
    }

    fn layer31() -> ConvLayer {
        ConvLayer::new(1, 1, 56, 56, 64, 64)
    }

    fn build(mode: DivisionMode) -> Division {
        let l = layer31();
        let t = hw().tile_for_layer(&l);
        Division::build(mode, &l, &t, &hw(), l.h, l.w, l.c_in).unwrap()
    }

    /// Invariant: segments tile each axis exactly, in order, no overlap.
    fn assert_covers(segs: &[Seg], len: usize) {
        let mut pos = 0;
        for s in segs {
            assert_eq!(s.start, pos, "gap/overlap at {pos}");
            assert!(s.len > 0);
            pos = s.end();
        }
        assert_eq!(pos, len, "segments must cover [0,{len})");
    }

    #[test]
    fn uniform_division_covers_and_counts() {
        for edge in [1usize, 2, 4, 8] {
            let d = build(DivisionMode::Uniform { edge });
            assert_covers(&d.ys, 56);
            assert_covers(&d.xs, 56);
            // Anchored at -k mod edge: one extra clipped segment when the
            // anchor is nonzero (edge > 1 here since k=1 -> anchor edge-1).
            let expect = if edge == 1 { 56 } else { 56 / edge + 1 };
            assert_eq!(d.ys.len(), expect, "edge {edge}");
            assert_eq!(d.n_cgroups, 8);
            // Uniform: one block per segment.
            assert_eq!(d.n_blocks_y, d.ys.len());
            assert_eq!(d.compact, edge == 1);
        }
    }

    /// The uniform grid anchors at the left window boundary (B_l): for a
    /// 3×3 kernel (k=1), cuts sit at 7, 15, ... (≡ -1 mod 8), so every
    /// window's *left* edge is block-aligned and only the right halo
    /// spills into one neighbouring block (the Fig. 3a waste).
    #[test]
    fn uniform_grid_anchors_at_left_boundary() {
        let d = build(DivisionMode::Uniform { edge: 8 });
        assert_eq!(d.ys[0], Seg { start: 0, len: 7 });
        assert_eq!(d.ys[1], Seg { start: 7, len: 8 });
        // Window of tile row 1: [7, 17) -> exactly 2 blocks ([7,15),[15,23)).
        let cover = Division::covering(&d.ys, 7, 17);
        assert_eq!(cover.len(), 2);
    }

    #[test]
    fn gratetile_mod8_segments_are_6_2_pattern() {
        let d = build(DivisionMode::GrateTile { n: 8 });
        assert_covers(&d.ys, 56);
        // G = {1,7} mod 8 on a 56-long axis: 1,6,2,6,2,...,6,2,...
        // Boundaries at 1,7,9,...,49,55: clipped 1-long edge segments at
        // both ends, alternating 6/2 in the interior.
        let lens: Vec<usize> = d.ys.iter().map(|s| s.len).collect();
        assert_eq!(lens[0], 1);
        assert_eq!(*lens.last().unwrap(), 1);
        let interior = &lens[1..lens.len() - 1];
        assert!(
            interior.chunks(2).all(|c| c[0] == 6 && (c.len() == 1 || c[1] == 2)),
            "lens {lens:?}"
        );
        assert_eq!(lens.iter().sum::<usize>(), 56);
        // Interior blocks hold exactly 2 segments.
        assert_eq!(d.n_blocks_y, 8); // boundaries at 1,9,...,49 -> 8 blocks
        assert_eq!(d.meta_bits_per_block, 48); // Table II, mod 8
    }

    #[test]
    fn gratetile_mod16_not_applicable_on_small_tile() {
        // Small tile (NVIDIA): (3,1) window step is 8 vertically — mod 16
        // does not exist (Table III footnote a).
        let l = layer31();
        let t = hw().tile_for_layer(&l);
        let e = Division::build(DivisionMode::GrateTile { n: 16 }, &l, &t, &hw(), 56, 56, 64);
        assert!(matches!(e, Err(DivisionError::NotApplicable { n: 16, .. })));
    }

    #[test]
    fn gratetile_mod16_applicable_on_large_tile() {
        let l = layer31();
        let ehw = Platform::EyerissLargeTile.hardware();
        let t = ehw.tile_for_layer(&l);
        let d =
            Division::build(DivisionMode::GrateTile { n: 16 }, &l, &t, &ehw, 56, 56, 64).unwrap();
        assert_covers(&d.ys, 56);
        // Metadata per 16x16x8 block is still 48 bits -> 12 bits/KB
        // (Table II row 3).
        assert_eq!(d.meta_bits_per_block, 48);
        let words: usize = 56 * 56 * 64;
        let bits_per_kb = d.total_meta_bits() as f64 / (words as f64 / 512.0);
        assert!(bits_per_kb < 48.0, "mod16 metadata {bits_per_kb} bits/KB");
    }

    #[test]
    fn wholemap_single_subtensor_per_cgroup() {
        let d = build(DivisionMode::WholeMap);
        assert_eq!(d.n_subtensors(), 8);
        assert_eq!(d.n_blocks(), 8);
    }

    #[test]
    fn block_segment_ranges_partition_axes() {
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 4 }] {
            let d = build(mode);
            let mut seen = 0usize;
            for by in 0..d.n_blocks_y {
                let r = d.y_segs_of_block(by);
                assert_eq!(r.start, seen, "{mode:?} block {by}");
                assert!(!r.is_empty());
                for iy in r.clone() {
                    assert_eq!(d.block_of_y[iy], by);
                }
                seen = r.end;
            }
            assert_eq!(seen, d.ys.len());
        }
    }

    #[test]
    fn block_coords_invert_block_linear() {
        let d = build(DivisionMode::GrateTile { n: 8 });
        for iy in 0..d.ys.len() {
            for ix in 0..d.xs.len() {
                for icg in 0..d.n_cgroups {
                    let r = SubTensorRef { iy, ix, icg };
                    let b = d.block_linear(r);
                    let (by, bx, cg) = d.block_coords(b);
                    assert_eq!((by, bx, cg), (d.block_of_y[iy], d.block_of_x[ix], icg));
                }
            }
        }
    }

    #[test]
    fn subtensor_coords_inverts_linear() {
        let d = build(DivisionMode::GrateTile { n: 8 });
        for iy in 0..d.ys.len() {
            for ix in 0..d.xs.len() {
                for icg in 0..d.n_cgroups {
                    let r = SubTensorRef { iy, ix, icg };
                    assert_eq!(d.subtensor_coords(d.linear(r)), r);
                }
            }
        }
    }

    #[test]
    fn covering_binary_search() {
        let segs = vec![
            Seg { start: 0, len: 1 },
            Seg { start: 1, len: 6 },
            Seg { start: 7, len: 2 },
            Seg { start: 9, len: 6 },
            Seg { start: 15, len: 2 },
        ];
        assert_eq!(Division::covering(&segs, 0, 1), 0..1);
        assert_eq!(Division::covering(&segs, 0, 2), 0..2);
        assert_eq!(Division::covering(&segs, 7, 9), 2..3);
        assert_eq!(Division::covering(&segs, 8, 10), 2..4);
        assert_eq!(Division::covering(&segs, 16, 17), 4..5);
        assert_eq!(Division::covering(&segs, 5, 5), 0..0);
    }

    /// Defining GrateTile invariant at the grid level: every window the
    /// tile walker fetches is exactly tiled by whole sub-tensors (no
    /// partial sub-tensor access).
    #[test]
    fn windows_never_split_subtensors() {
        let l = layer31();
        let t = hw().tile_for_layer(&l);
        let d = build(DivisionMode::GrateTile { n: 8 });
        let halo = l.halo() as i64;
        for ty in 0..l.out_h().div_ceil(t.th) {
            for tx in 0..l.out_w().div_ceil(t.tw) {
                let y0 = ((ty * t.th * l.s) as i64 - halo).max(0) as usize;
                let y1 = ((((ty + 1) * t.th - 1) * l.s) as i64 + halo + 1).min(l.h as i64) as usize;
                let x0 = ((tx * t.tw * l.s) as i64 - halo).max(0) as usize;
                let x1 = ((((tx + 1) * t.tw - 1) * l.s) as i64 + halo + 1).min(l.w as i64) as usize;
                for iy in Division::covering(&d.ys, y0, y1) {
                    assert!(d.ys[iy].start >= y0 && d.ys[iy].end() <= y1,
                        "tile ({ty},{tx}) splits y-segment {iy}: window [{y0},{y1}) seg [{},{})",
                        d.ys[iy].start, d.ys[iy].end());
                }
                for ix in Division::covering(&d.xs, x0, x1) {
                    assert!(d.xs[ix].start >= x0 && d.xs[ix].end() <= x1);
                }
            }
        }
    }

    /// Uniform divisions DO split windows (the Fig. 3a waste) — sanity
    /// check that the contrast the paper draws actually shows up.
    #[test]
    fn uniform_splits_windows() {
        let l = layer31();
        let t = hw().tile_for_layer(&l);
        let d = build(DivisionMode::Uniform { edge: 8 });
        // Window of tile (0,0): rows [0, 10). Segment [8,16) intersects
        // and is split.
        let y1 = ((t.th - 1) * l.s + l.halo() + 1).min(l.h);
        let cover = Division::covering(&d.ys, 0, y1);
        let splits = cover.clone().any(|iy| d.ys[iy].end() > y1);
        assert!(splits, "uniform 8x8 should over-hang the 10-row window");
    }

    #[test]
    fn intersecting_counts_match_paper_example() {
        // Paper §III-B: a 10×10 interior window over G={1,7} mod 8
        // decomposes into 1×(6×6) + 2×(2×6) + 2×(6×2) + 4×(2×2) = 9
        // sub-tensors per channel group.
        let l = ConvLayer::new(1, 1, 64, 64, 8, 8);
        let t = TileShape::new(8, 8, 8);
        let d = Division::build(DivisionMode::GrateTile { n: 8 }, &l, &t, &hw(), 64, 64, 8)
            .unwrap();
        // Interior window [7, 17) x [7, 17).
        let subs = d.intersecting(7, 17, 7, 17, 0, 8);
        assert_eq!(subs.len(), 9);
        let count = |sh: (usize, usize)| {
            subs.iter()
                .filter(|r| (d.ys[r.iy].len, d.xs[r.ix].len) == sh)
                .count()
        };
        assert_eq!(count((6, 6)), 1, "one 6x6");
        assert_eq!(count((2, 6)), 2, "two 2x6");
        assert_eq!(count((6, 2)), 2, "two 6x2");
        assert_eq!(count((2, 2)), 4, "four 2x2");
        let total: usize = subs.iter().map(|r| d.subtensor_words(*r)).sum();
        assert_eq!(total, 10 * 10 * 8);
    }

    /// Anchored with the halo-derived anchor reproduces the Uniform grid
    /// exactly (same segments, same blocks) — the tuner's dedup relies
    /// on this equivalence.
    #[test]
    fn anchored_at_halo_matches_uniform() {
        let l = layer31();
        let anchor = crate::util::umod(-(l.halo() as i64), 8) as usize;
        let u = build(DivisionMode::Uniform { edge: 8 });
        let a = build(DivisionMode::Anchored { edge: 8, anchor });
        assert_eq!(u.ys, a.ys);
        assert_eq!(u.xs, a.xs);
        assert_eq!(u.block_of_y, a.block_of_y);
        assert_eq!(u.meta_bits_per_block, a.meta_bits_per_block);
        assert!(!a.compact);
    }

    /// Split-at-1 / split-at-(edge-1) edge geometries: the clipped rim
    /// segments still cover the axis exactly and record_slots stays 1.
    #[test]
    fn anchored_edge_geometries_cover() {
        for anchor in [1usize, 7] {
            let d = build(DivisionMode::Anchored { edge: 8, anchor });
            assert_covers(&d.ys, 56);
            assert_covers(&d.xs, 56);
            assert_eq!(d.ys[0], Seg { start: 0, len: anchor });
            assert_eq!(d.record_slots(), 1, "uniform-style grids hold 1 sub-tensor/record");
        }
    }

    #[test]
    fn anchored_rejects_bad_params() {
        let l = layer31();
        let t = hw().tile_for_layer(&l);
        for mode in [
            DivisionMode::Anchored { edge: 1, anchor: 0 },
            DivisionMode::Anchored { edge: 8, anchor: 8 },
        ] {
            let e = Division::build(mode, &l, &t, &hw(), 56, 56, 64);
            assert!(matches!(e, Err(DivisionError::Invalid(_))), "{mode:?}");
        }
    }

    /// `parse` inverts `key` for every mode the tuner can emit, and
    /// rejects junk with a useful message.
    #[test]
    fn mode_key_round_trips_through_parse() {
        let mut modes = DivisionMode::table3_modes();
        modes.push(DivisionMode::WholeMap);
        modes.push(DivisionMode::Anchored { edge: 8, anchor: 3 });
        for m in modes {
            assert_eq!(DivisionMode::parse(&m.key()).unwrap(), m, "{}", m.name());
        }
        for junk in ["grate", "uniformx", "anchored8", "anchored8@x", "diagonal"] {
            assert!(DivisionMode::parse(junk).is_err(), "{junk}");
        }
    }

    #[test]
    fn partial_channel_group() {
        let l = ConvLayer::new(1, 1, 16, 16, 12, 8);
        let t = TileShape::new(8, 8, 8);
        let d = Division::build(DivisionMode::Uniform { edge: 8 }, &l, &t, &hw(), 16, 16, 12)
            .unwrap();
        assert_eq!(d.n_cgroups, 2);
        assert_eq!(d.cg_depth(0), 8);
        assert_eq!(d.cg_depth(1), 4);
        let words: usize = (0..d.ys.len())
            .flat_map(|iy| (0..d.xs.len()).flat_map(move |ix| (0..2).map(move |icg| (iy, ix, icg))))
            .map(|(iy, ix, icg)| d.subtensor_words(SubTensorRef { iy, ix, icg }))
            .sum();
        assert_eq!(words, 16 * 16 * 12);
    }
}
