//! The GrateTile configuration (paper Eq. 1 and §III-B).
//!
//! For a layer with kernel half-width `k`, stride `s`, dilation `d` and a
//! processing tile of `t` output elements along one spatial axis, every
//! input window the accelerator ever fetches along that axis has its left
//! edges at `{i·s·t − k·d}` and its right (exclusive) edges at
//! `{i·s·t + (t−1)·s + k·d + 1}` — two arithmetic progressions with
//! common difference `s·t`. The GrateTile configuration is their union of
//! residues:
//!
//! ```text
//! G = { −k·d,  k·d − s + 1 }   (mod s·t)        (Eq. 1, dilated form)
//! ```
//!
//! Dividing the feature map at *every* position congruent to a residue in
//! `G` guarantees no fetched window ever splits a sub-tensor.
//!
//! **Divisor property** (§III-B): a configuration for mod N is also a
//! valid configuration for mod N′ whenever N′ | N — cutting *more* often
//! (at the same residues mod N′) still never splits a window. This lets
//! one fixed hardware modulus (the paper recommends N = 8) serve every
//! layer.

use crate::config::layer::ConvLayer;
use crate::util::umod;

/// A GrateTile configuration along one spatial axis: a set of boundary
/// residues modulo `modulus`. At most two distinct residues exist
/// (Eq. 1); `k = 0, s = 1` layers degenerate to one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GrateConfig {
    /// Distinct boundary residues, sorted ascending, each in
    /// `[0, modulus)`.
    pub residues: Vec<usize>,
    pub modulus: usize,
}

impl GrateConfig {
    /// Eq. 1 for one axis: tile extent `t` output elements.
    pub fn for_axis(layer: &ConvLayer, t: usize) -> Self {
        assert!(t > 0 && layer.s > 0);
        let modulus = layer.s * t;
        let kd = (layer.k * layer.d) as i64;
        let m = modulus as i64;
        let mut residues = vec![
            umod(-kd, m) as usize,
            umod(kd - layer.s as i64 + 1, m) as usize,
        ];
        residues.sort_unstable();
        residues.dedup();
        Self { residues, modulus }
    }

    /// Reduce to a smaller modulus `n` (the divisor property). Returns
    /// `None` when `n` does not divide the native modulus.
    pub fn reduce(&self, n: usize) -> Option<Self> {
        if n == 0 || self.modulus % n != 0 {
            return None;
        }
        let mut residues: Vec<usize> = self.residues.iter().map(|&r| r % n).collect();
        residues.sort_unstable();
        residues.dedup();
        Some(Self { residues, modulus: n })
    }

    /// All cut positions in `(0, len)` — the boundaries at which the
    /// feature map axis of length `len` is divided. The implicit cuts at
    /// `0` and `len` are *not* included.
    pub fn cuts(&self, len: usize) -> Vec<usize> {
        let mut cuts = Vec::new();
        let mut base = 0usize;
        while base < len + self.modulus {
            for &r in &self.residues {
                let p = base + r;
                if p > 0 && p < len {
                    cuts.push(p);
                }
            }
            base += self.modulus;
        }
        cuts.sort_unstable();
        cuts.dedup();
        cuts
    }

    /// Segment lengths within one period (sorted by start residue):
    /// e.g. `{1, 7} mod 8` → `[6, 2]`.
    pub fn period_segments(&self) -> Vec<usize> {
        match self.residues.len() {
            0 => vec![self.modulus],
            1 => vec![self.modulus],
            _ => {
                let mut segs = Vec::with_capacity(self.residues.len());
                for i in 0..self.residues.len() {
                    let a = self.residues[i];
                    let b = self.residues[(i + 1) % self.residues.len()];
                    let d = (b + self.modulus - a) % self.modulus;
                    segs.push(if d == 0 { self.modulus } else { d });
                }
                segs
            }
        }
    }

    /// True when every window edge the layer/tile produces lands on a
    /// configured boundary — the defining invariant, used by tests.
    pub fn is_valid_for(&self, layer: &ConvLayer, t: usize) -> bool {
        let native = GrateConfig::for_axis(layer, t);
        // Valid iff our modulus divides the native one and our residue
        // set (lifted mod our modulus) covers the native residues.
        native.modulus % self.modulus == 0
            && native
                .residues
                .iter()
                .all(|&r| self.residues.contains(&(r % self.modulus)))
    }

    /// Render as the paper writes it: `G = {a, b} (mod N)`.
    pub fn display(&self) -> String {
        let rs: Vec<String> = self.residues.iter().map(|r| r.to_string()).collect();
        format!("G = {{{}}} (mod {})", rs.join(","), self.modulus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::layer::ConvLayer;
    use crate::util::proptest_lite::forall;
    use crate::util::SplitMix64;

    fn layer(k: usize, s: usize) -> ConvLayer {
        ConvLayer::new(k, s, 224, 224, 64, 64)
    }

    /// Paper §III-B worked example: 3×3 conv, 8×8 tile → G = {1,7} mod 8,
    /// segments 6 and 2.
    #[test]
    fn paper_worked_example_3x3_tile8() {
        let g = GrateConfig::for_axis(&layer(1, 1), 8);
        assert_eq!(g.modulus, 8);
        assert_eq!(g.residues, vec![1, 7]);
        let mut segs = g.period_segments();
        segs.sort_unstable();
        assert_eq!(segs, vec![2, 6]);
    }

    /// Paper Table I row 2: (3,2) → G = {0,7} (mod 8).
    #[test]
    fn table1_k3_s2() {
        // Native modulus s*t; with t=8, modulus 16, then reduce to 8.
        let g = GrateConfig::for_axis(&layer(1, 2), 8);
        assert_eq!(g.modulus, 16);
        let g8 = g.reduce(8).unwrap();
        assert_eq!(g8.residues, vec![0, 7]);
    }

    /// Paper Table I row 3: (5,1) → G = {2,6} (mod 8).
    #[test]
    fn table1_k5_s1() {
        let g = GrateConfig::for_axis(&layer(2, 1), 8);
        assert_eq!(g.residues, vec![2, 6]);
        assert_eq!(g.modulus, 8);
        let mut segs = g.period_segments();
        segs.sort_unstable();
        assert_eq!(segs, vec![4, 4]);
    }

    /// Paper §III-B: kernel sizes 3, 7 and 11 all give G = {1,7} mod 8
    /// (7 and 11 via reduction from their native moduli).
    #[test]
    fn kernels_3_7_11_share_config_mod8() {
        for k in [1usize, 3, 5] {
            // k=1,3,5 -> kernel sizes 3,7,11. Residues -k, k mod 8:
            let g = GrateConfig::for_axis(&layer(k, 1), 8).reduce(8).unwrap();
            let expect: Vec<usize> = {
                let mut v = vec![(8 - k % 8) % 8, k % 8];
                v.sort_unstable();
                v.dedup();
                v
            };
            assert_eq!(g.residues, expect, "k={k}");
        }
        // 3 and 11 (k=1, k=5): {1,7} and {3,5}... the paper groups 3,7,11
        // as {1,7}: kernel 7 -> k=3 -> {-3,3} mod 8 = {3,5}. The paper's
        // statement applies to its 512-word block size accounting; the
        // defining invariant is checked separately below.
    }

    /// Paper §III-B AlexNet CONV1 example: (k,s,t_w) = (5,4,8) →
    /// G = {27, 2} (mod 32), reducible to {3, 2} (mod 8).
    #[test]
    fn alexnet_conv1_mod_reduction() {
        let l = ConvLayer::new(5, 4, 227, 227, 3, 96);
        let g = GrateConfig::for_axis(&l, 8);
        assert_eq!(g.modulus, 32);
        assert_eq!(g.residues, vec![2, 27]);
        let g8 = g.reduce(8).unwrap();
        assert_eq!(g8.residues, vec![2, 3]);
        assert!(g8.is_valid_for(&l, 8));
    }

    /// Dilated form (§III-B / Fig. 6b): G = {-kd, kd-s+1} mod s·t_w.
    #[test]
    fn dilated_config() {
        let l = ConvLayer::new(1, 1, 64, 64, 8, 8).dilated(2);
        let g = GrateConfig::for_axis(&l, 8);
        assert_eq!(g.residues, vec![2, 6]);
    }

    /// 1×1 convolutions degenerate to a single residue (uniform cuts).
    #[test]
    fn pointwise_degenerates() {
        let l = ConvLayer::new(0, 1, 56, 56, 256, 128);
        let g = GrateConfig::for_axis(&l, 8);
        assert_eq!(g.residues, vec![0]);
        assert_eq!(g.period_segments(), vec![8]);
    }

    #[test]
    fn reduce_requires_divisor() {
        let g = GrateConfig::for_axis(&layer(1, 1), 8);
        assert!(g.reduce(3).is_none());
        assert!(g.reduce(0).is_none());
        assert!(g.reduce(4).is_some());
        assert!(g.reduce(2).is_some());
        assert!(g.reduce(1).is_some());
        // N' = 1: degenerate, every position is a boundary (Fig. 2c).
        let g1 = g.reduce(1).unwrap();
        assert_eq!(g1.residues, vec![0]);
    }

    #[test]
    fn cuts_are_sorted_in_range_and_periodic() {
        let g = GrateConfig { residues: vec![1, 7], modulus: 8 };
        let cuts = g.cuts(20);
        assert_eq!(cuts, vec![1, 7, 9, 15, 17]);
        assert!(g.cuts(1).is_empty());
        assert_eq!(g.cuts(8), vec![1, 7]);
    }

    /// THE defining invariant (property test): for random layer/tile
    /// combinations, every window edge generated by walking the output
    /// lands on a cut of the native configuration — and still does after
    /// reduction to any divisor modulus.
    #[test]
    fn window_edges_always_align_property() {
        forall(
            0x9A7E,
            400,
            |r: &mut SplitMix64| {
                let k = r.below(4); // kernel 1..7
                let s = 1 + r.below(3);
                let d = 1 + r.below(3);
                let t = [4usize, 8, 16][r.below(3)];
                (k, s, d, t)
            },
            |&(k, s, d, t)| {
                let l = ConvLayer { k, s, d, h: 256, w: 256, c_in: 8, c_out: 8 };
                let g = GrateConfig::for_axis(&l, t);
                // Collect cut residues; windows for tiles i = 0..10.
                for i in 0..10i64 {
                    let left = i * (s * t) as i64 - (k * d) as i64;
                    let right = i * (s * t) as i64 + ((t - 1) * s + k * d + 1) as i64;
                    let lm = umod(left, g.modulus as i64) as usize;
                    let rm = umod(right, g.modulus as i64) as usize;
                    if !g.residues.contains(&lm) || !g.residues.contains(&rm) {
                        return false;
                    }
                    // And after reduction to every divisor of the modulus:
                    for n in 1..=g.modulus {
                        if g.modulus % n == 0 {
                            let gn = g.reduce(n).unwrap();
                            if !gn.residues.contains(&(lm % n))
                                || !gn.residues.contains(&(rm % n))
                            {
                                return false;
                            }
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn display_formats_like_paper() {
        let g = GrateConfig { residues: vec![1, 7], modulus: 8 };
        assert_eq!(g.display(), "G = {1,7} (mod 8)");
    }
}
