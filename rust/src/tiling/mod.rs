//! Feature-map division: the paper's core contribution (§III-B).
//!
//! * [`grate::GrateConfig`] — Eq. 1: `G = {-k·d, k·d - s + 1} (mod s·t)`
//!   per spatial axis, plus the divisor-reduction property (a mod-N
//!   configuration is valid for any N′ | N).
//! * [`division::Division`] — a concrete sub-tensor grid over one
//!   feature map, buildable as uniform (the baselines of §IV) or
//!   GrateTile (uneven, boundary-aligned) divisions, with the metadata
//!   block grouping of Fig. 7.

pub mod division;
pub mod grate;

pub use division::{Division, DivisionError, DivisionMode, Seg, SubTensorRef};
pub use grate::GrateConfig;
