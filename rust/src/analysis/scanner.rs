//! A comment/string-stripping scanner for Rust source text.
//!
//! The invariant linter never parses Rust properly (no `syn` on the
//! offline image, and none needed): every rule is a token query over
//! *code* text, so the only job here is to strip the three places a
//! token can hide without being code — comments, string/char literals,
//! and raw strings — while keeping the comment text around separately
//! (that is where [`crate::analysis::pragma`] pragmas live).
//!
//! The state machine handles the lexical shapes that actually occur in
//! this crate and its tests: line comments, nested block comments,
//! (multi-line) string literals with escapes, byte strings, raw strings
//! `r#"…"#` with any number of hashes, char literals (including
//! escaped quotes), and lifetimes (`'a` is *not* an unterminated char
//! literal). Stripped regions are replaced by a single space so tokens
//! on either side never fuse.
//!
//! Test regions: from the first line whose code contains `#[cfg(test)]`
//! to the end of the file, lines are marked [`ScannedLine::in_test`].
//! This matches the crate-wide convention that the unit-test module is
//! the last item of a file; rules that exempt test code (panics in
//! decoder tests, bless knobs in fixtures) key off this flag.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct ScannedLine {
    /// The line with comments and string/char literal *contents*
    /// removed (each stripped region collapses to one space).
    pub code: String,
    /// The comment text of the line (line-comment tail and/or block
    /// comment content) — pragma syntax is searched here.
    pub comment: String,
    /// True from the first top-level `#[cfg(test)]` line to EOF.
    pub in_test: bool,
}

/// A whole scanned file: repo-relative path (forward slashes) plus its
/// lines, 1-indexed by convention (`lines[0]` is line 1).
#[derive(Debug, Clone)]
pub struct ScannedFile {
    pub path: String,
    pub lines: Vec<ScannedLine>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    /// Nested depth (Rust block comments nest).
    BlockComment(u32),
    Str,
    /// Number of `#` marks that close the raw string.
    RawStr(usize),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scan `text` into per-line code/comment channels.
pub fn scan(path: &str, text: &str) -> ScannedFile {
    let chars: Vec<char> = text.chars().collect();
    let mut lines: Vec<ScannedLine> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut prev_code_char = ' ';
    let mut i = 0usize;
    let n = chars.len();
    let at = |j: usize| if j < n { chars[j] } else { '\0' };
    while i < n {
        let c = chars[i];
        if c == '\n' {
            // Line comments end here; multi-line states persist.
            if state == State::LineComment {
                state = State::Code;
            }
            lines.push(ScannedLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && at(i + 1) == '/' {
                    state = State::LineComment;
                    code.push(' ');
                    prev_code_char = ' ';
                    comment.push_str("//");
                    i += 2;
                } else if c == '/' && at(i + 1) == '*' {
                    state = State::BlockComment(1);
                    code.push(' ');
                    prev_code_char = ' ';
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push(' ');
                    i += 1;
                } else if (c == 'r' || (c == 'b' && at(i + 1) == 'r')) && !is_ident(prev_code_char)
                {
                    // Possible raw (byte) string: r"…", r#"…"#, br"…", …
                    let mut j = i + if c == 'b' { 2 } else { 1 };
                    let mut hashes = 0usize;
                    while at(j) == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if at(j) == '"' {
                        state = State::RawStr(hashes);
                        code.push(' ');
                        i = j + 1;
                    } else {
                        code.push(c);
                        prev_code_char = c;
                        i += 1;
                    }
                } else if c == 'b' && at(i + 1) == '"' && !is_ident(prev_code_char) {
                    state = State::Str;
                    code.push(' ');
                    i += 2;
                } else if c == '\'' || (c == 'b' && at(i + 1) == '\'' && !is_ident(prev_code_char))
                {
                    let q = if c == 'b' { i + 1 } else { i };
                    // Char literal vs lifetime: a quote starts a char
                    // literal when its content is an escape (`'\n'`) or a
                    // single char followed by a closing quote (`'x'`);
                    // otherwise it is a lifetime tick (`'a`, `'static`).
                    if at(q + 1) == '\\' {
                        let mut j = q + 1;
                        while j < n {
                            if chars[j] == '\\' {
                                j += 2;
                            } else if chars[j] == '\'' {
                                j += 1;
                                break;
                            } else {
                                j += 1;
                            }
                        }
                        code.push(' ');
                        prev_code_char = ' ';
                        i = j;
                    } else if at(q + 2) == '\'' && at(q + 1) != '\'' {
                        code.push(' ');
                        prev_code_char = ' ';
                        i = q + 3;
                    } else {
                        // Lifetime (or the `b` was an ordinary ident char).
                        code.push(c);
                        prev_code_char = c;
                        i += 1;
                    }
                } else {
                    code.push(c);
                    prev_code_char = c;
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '*' && at(i + 1) == '/' {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && at(i + 1) == '*' {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    state = State::Code;
                    prev_code_char = ' ';
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes {
                        if at(i + 1 + k) != '#' {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        state = State::Code;
                        prev_code_char = ' ';
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(ScannedLine { code, comment, in_test: false });
    }
    // Mark the trailing test region (crate convention: `#[cfg(test)]
    // mod tests` is the last item of a file).
    let test_from = lines.iter().position(|l| l.code.contains("#[cfg(test)]"));
    if let Some(from) = test_from {
        for l in lines.iter_mut().skip(from) {
            l.in_test = true;
        }
    }
    ScannedFile { path: path.to_string(), lines }
}

/// Find `token` in `code` at identifier boundaries: when the token
/// starts (or ends) with an identifier char, the adjacent source char
/// must not be one — `HashMap` must not match inside `MyHashMapLike`.
/// Returns the byte offset of the first boundary-respecting match.
pub fn find_token(code: &str, token: &str) -> Option<usize> {
    let t0 = token.chars().next()?;
    let t1 = token.chars().next_back()?;
    for (pos, _) in code.match_indices(token) {
        if is_ident(t0) {
            if let Some(prev) = code[..pos].chars().next_back() {
                if is_ident(prev) {
                    continue;
                }
            }
        }
        if is_ident(t1) {
            if let Some(next) = code[pos + token.len()..].chars().next() {
                if is_ident(next) {
                    continue;
                }
            }
        }
        return Some(pos);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(text: &str) -> Vec<String> {
        scan("t.rs", text).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_block_comments() {
        let c = code_of("let x = 1; // HashMap here\n/* HashMap */ let y = 2;\n");
        assert!(c[0].contains("let x = 1;") && !c[0].contains("HashMap"));
        assert!(c[1].contains("let y = 2;") && !c[1].contains("HashMap"));
    }

    #[test]
    fn comment_text_is_kept_for_pragmas() {
        let f = scan("t.rs", "let x = 1; // lint: allow(r, why)\n");
        assert!(f.lines[0].comment.contains("lint: allow(r, why)"));
        assert!(!f.lines[0].code.contains("lint"));
    }

    #[test]
    fn nested_block_comments() {
        let c = code_of("/* a /* HashMap */ still */ let z = 3;\n");
        assert!(c[0].contains("let z = 3;") && !c[0].contains("HashMap"));
    }

    #[test]
    fn strips_string_contents_including_escapes_and_multiline() {
        let c = code_of("let s = \"HashMap \\\" quoted\"; keep(s);\nlet m = \"line1\nline2 HashMap\"; tail();\n");
        assert!(c[0].contains("keep(s);") && !c[0].contains("HashMap"));
        assert!(!c[1].contains("line1"));
        assert!(!c[2].contains("HashMap") && c[2].contains("tail();"));
    }

    #[test]
    fn strips_raw_strings_with_hashes() {
        let c = code_of("let r = r#\"HashMap \" inner\"#; after();\n");
        assert!(c[0].contains("after();") && !c[0].contains("HashMap"));
        let c = code_of("let r = r\"plain HashMap\"; after();\n");
        assert!(c[0].contains("after();") && !c[0].contains("HashMap"));
    }

    #[test]
    fn char_literals_strip_but_lifetimes_survive() {
        let c = code_of("let q: &'static str = f('\"'); let e = '\\''; g::<'a>();\n");
        // The quote chars inside literals must not open strings.
        assert!(c[0].contains("g::<'a>();"), "{:?}", c[0]);
        assert!(c[0].contains("&'static str"), "{:?}", c[0]);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let c = code_of("let b = b\"HashMap\"; let c = b'x'; done();\n");
        assert!(c[0].contains("done();") && !c[0].contains("HashMap"));
    }

    #[test]
    fn cfg_test_marks_the_tail_region() {
        let f = scan("t.rs", "fn a() {}\n#[cfg(test)]\nmod tests {\n}\n");
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[3].in_test);
    }

    #[test]
    fn token_boundaries() {
        assert!(find_token("use std::collections::HashMap;", "HashMap").is_some());
        assert!(find_token("struct MyHashMapLike;", "HashMap").is_none());
        assert!(find_token("x.unwrap();", ".unwrap()").is_some());
        assert!(find_token("x.unwrap_or(0);", ".unwrap()").is_none());
        assert!(find_token("eprintln!(\"\")", "println!").is_none());
        assert_eq!(find_token("", "HashMap"), None);
    }

    #[test]
    fn line_numbers_are_stable_across_multiline_literals() {
        let f = scan("t.rs", "a();\n\"x\ny\"; b();\nc();\n");
        assert_eq!(f.lines.len(), 4);
        assert!(f.lines[3].code.contains("c();"));
    }
}
