//! Suppression surfaces: per-line pragmas and the checked-in allowlist.
//!
//! Two ways to accept a finding, both requiring a written reason:
//!
//! * **Pragma** — a line comment `lint: allow(<rule>, <reason>)` on the
//!   flagged line, or on its own comment-only line immediately above.
//!   For invariants that hold at one specific site ("clamped by the
//!   `min()` above").
//! * **Allowlist entry** — a `<rule> <path> <justification…>` line in
//!   `lint.allow`, suppressing a whole rule for a whole file. For
//!   by-design surfaces (the `--wall` path reads host clocks; the bench
//!   reporter prints to stdout).
//!
//! Both are themselves linted: a pragma without a reason or with an
//! unknown rule id is a `bad-pragma` warning, and a pragma or allowlist
//! entry that suppresses nothing is an `unused-allow` warning — under
//! `--deny-warnings` (CI) stale suppressions fail the build, so the
//! allowlist can only shrink as findings get fixed.

use crate::util::error::Result;
use crate::{bail, err};

/// One parsed `lint: allow(rule, reason)` pragma.
#[derive(Debug, Clone)]
pub struct Pragma {
    /// Rule id the pragma suppresses (`*` is not supported on purpose —
    /// every suppression names exactly one invariant).
    pub rule: String,
    /// The written justification (must be non-empty).
    pub reason: String,
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// 1-based line the pragma applies to (its own line, or the next
    /// line when the pragma is the only thing on its line).
    pub applies_to: usize,
    /// Set when a finding was suppressed through this pragma.
    pub used: bool,
    /// Parse defect (missing reason / malformed syntax), reported as a
    /// `bad-pragma` warning.
    pub defect: Option<String>,
}

/// Extract pragmas from a scanned file's comment channel.
///
/// `code_blank[i]` says whether line `i+1` has no code (pure comment
/// line) — such a pragma applies to the next line instead.
pub fn collect_pragmas(comments: &[String], code_blank: &[bool]) -> Vec<Pragma> {
    let mut out = Vec::new();
    for (idx, comment) in comments.iter().enumerate() {
        let line = idx + 1;
        // Doc comments are documentation, not suppressions — prose
        // describing the pragma syntax must not itself be a pragma.
        let t = comment.trim_start();
        if t.starts_with("///") || t.starts_with("//!") {
            continue;
        }
        let Some(at) = comment.find("lint:") else { continue };
        let rest = comment[at + "lint:".len()..].trim_start();
        let Some(body) = rest.strip_prefix("allow(") else {
            out.push(Pragma {
                rule: String::new(),
                reason: String::new(),
                line,
                applies_to: line,
                used: false,
                defect: Some("expected `lint: allow(<rule>, <reason>)`".to_string()),
            });
            continue;
        };
        let applies_to = if code_blank[idx] { line + 1 } else { line };
        let Some(close) = body.rfind(')') else {
            out.push(Pragma {
                rule: String::new(),
                reason: String::new(),
                line,
                applies_to,
                used: false,
                defect: Some("unclosed `lint: allow(` pragma".to_string()),
            });
            continue;
        };
        let inner = &body[..close];
        let (rule, reason) = match inner.split_once(',') {
            Some((r, why)) => (r.trim().to_string(), why.trim().to_string()),
            None => (inner.trim().to_string(), String::new()),
        };
        let defect = if rule.is_empty() {
            Some("pragma names no rule".to_string())
        } else if reason.is_empty() {
            Some(format!("pragma for '{rule}' carries no reason — justify the allow"))
        } else {
            None
        };
        out.push(Pragma { rule, reason, line, applies_to, used: false, defect });
    }
    out
}

/// One `lint.allow` entry: suppress `rule` everywhere in `path`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub justification: String,
    /// 1-based line in the allowlist file.
    pub line: usize,
    pub used: bool,
}

/// The parsed checked-in allowlist.
#[derive(Debug, Clone, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// Parse allowlist text. Malformed lines and entries without a
    /// justification are hard errors (a suppression must never land
    /// without a written reason), reported with their line number.
    pub fn parse(text: &str) -> Result<Allowlist> {
        let mut entries = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let l = raw.trim();
            if l.is_empty() || l.starts_with('#') {
                continue;
            }
            let mut parts = l.splitn(3, char::is_whitespace);
            let rule = parts.next().unwrap_or_default().to_string();
            let path = parts
                .next()
                .ok_or_else(|| err!("lint.allow:{line}: expected `<rule> <path> <justification>`"))?
                .to_string();
            let justification = parts.next().unwrap_or("").trim().to_string();
            if justification.is_empty() {
                bail!("lint.allow:{line}: entry '{rule} {path}' carries no justification");
            }
            entries.push(AllowEntry { rule, path, justification, line, used: false });
        }
        Ok(Allowlist { entries })
    }

    /// Mark-and-test: does an entry cover `(rule, path)`? The first
    /// matching entry is marked used.
    pub fn allows(&mut self, rule: &str, path: &str) -> bool {
        for e in &mut self.entries {
            if e.rule == rule && e.path == path {
                e.used = true;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pragmas(lines: &[(&str, bool)]) -> Vec<Pragma> {
        let comments: Vec<String> = lines.iter().map(|(c, _)| c.to_string()).collect();
        let blank: Vec<bool> = lines.iter().map(|&(_, b)| b).collect();
        collect_pragmas(&comments, &blank)
    }

    #[test]
    fn trailing_pragma_applies_to_its_own_line() {
        let p = pragmas(&[("// lint: allow(nondet-iter, lookup-only map)", false)]);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].rule, "nondet-iter");
        assert_eq!(p[0].reason, "lookup-only map");
        assert_eq!(p[0].applies_to, 1);
        assert!(p[0].defect.is_none());
    }

    #[test]
    fn standalone_pragma_applies_to_next_line() {
        let p = pragmas(&[("// lint: allow(wall-clock, bench timer)", true), ("", false)]);
        assert_eq!(p[0].applies_to, 2);
    }

    #[test]
    fn reason_is_mandatory() {
        let p = pragmas(&[("// lint: allow(nondet-iter)", false)]);
        assert!(p[0].defect.as_deref().unwrap_or("").contains("no reason"));
        let p = pragmas(&[("// lint: allow(nondet-iter, )", false)]);
        assert!(p[0].defect.is_some());
    }

    #[test]
    fn malformed_pragmas_are_defects_not_ignored() {
        assert!(pragmas(&[("// lint: deny(x)", false)])[0].defect.is_some());
        assert!(pragmas(&[("// lint: allow(oops, no close", false)])[0].defect.is_some());
        assert!(pragmas(&[("// plain comment", false)]).is_empty());
    }

    #[test]
    fn doc_comments_are_not_pragma_sites() {
        assert!(pragmas(&[("/// write `lint: allow(rule, reason)`", false)]).is_empty());
        assert!(pragmas(&[("//! syntax: `lint: allow(rule, reason)`", false)]).is_empty());
    }

    #[test]
    fn reasons_may_contain_parens() {
        let p = pragmas(&[("// lint: allow(panic-in-decoder, clamped by min() above)", false)]);
        assert_eq!(p[0].reason, "clamped by min() above");
        assert!(p[0].defect.is_none());
    }

    #[test]
    fn allowlist_round_trip() {
        let mut a = Allowlist::parse(
            "# comment\n\nwall-clock src/x.rs the --wall path reads host time by design\n",
        )
        .unwrap();
        assert_eq!(a.entries.len(), 1);
        assert!(a.allows("wall-clock", "src/x.rs"));
        assert!(a.entries[0].used);
        assert!(!a.allows("wall-clock", "src/y.rs"));
        assert!(!a.allows("nondet-iter", "src/x.rs"));
    }

    #[test]
    fn allowlist_requires_justification() {
        assert!(Allowlist::parse("wall-clock src/x.rs\n").is_err());
        assert!(Allowlist::parse("wall-clock src/x.rs   \n").is_err());
        let e = Allowlist::parse("wall-clock\n").unwrap_err().to_string();
        assert!(e.contains("lint.allow:1"), "{e}");
    }
}
