//! Deterministic finding collection and rendering.
//!
//! The report is itself subject to the invariants it enforces: findings
//! are sorted by `(path, line, rule)` so the rendered text is
//! byte-identical run-to-run and host-to-host, and rendering returns a
//! `String` (only the CLI entry points print).

use std::fmt::Write as _;

/// How a finding affects the exit status.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Rule violations: always fail the lint.
    Error,
    /// Suppression hygiene (`bad-pragma`, `unused-allow`): fail only
    /// under `--deny-warnings` (the CI mode).
    Warning,
}

/// One reported finding.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path with forward slashes (or `lint.allow`).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
    pub hint: &'static str,
    pub severity: Severity,
}

/// The outcome of a lint pass.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Findings accepted through a pragma or allowlist entry.
    pub suppressed: usize,
}

impl LintReport {
    /// Canonical order: `(path, line, rule)`. Called once by the driver
    /// after all files are checked.
    pub fn sort(&mut self) {
        self.findings
            .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    }

    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// Exit-status policy: errors always fail; warnings fail only when
    /// denied (CI runs `--deny-warnings` so stale suppressions cannot
    /// accumulate).
    pub fn ok(&self, deny_warnings: bool) -> bool {
        self.errors() == 0 && (!deny_warnings || self.warnings() == 0)
    }

    /// Render the full report. Deterministic: sorted findings, fixed
    /// summary line, no timestamps.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let sev = match f.severity {
                Severity::Error => "error",
                Severity::Warning => "warning",
            };
            let _ = writeln!(out, "{}:{}: {sev}[{}]: {}", f.path, f.line, f.rule, f.message);
            let _ = writeln!(out, "    hint: {}", f.hint);
        }
        let _ = writeln!(
            out,
            "lint: {} files, {} errors, {} warnings, {} suppressed",
            self.files_scanned,
            self.errors(),
            self.warnings(),
            self.suppressed
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(path: &str, line: usize, rule: &'static str, sev: Severity) -> Finding {
        Finding {
            path: path.to_string(),
            line,
            rule,
            message: "m".to_string(),
            hint: "h",
            severity: sev,
        }
    }

    #[test]
    fn sorted_and_rendered_deterministically() {
        let mut r = LintReport {
            findings: vec![
                f("src/b.rs", 9, "wall-clock", Severity::Error),
                f("src/a.rs", 3, "nondet-iter", Severity::Error),
                f("src/b.rs", 9, "nondet-iter", Severity::Error),
            ],
            files_scanned: 2,
            suppressed: 1,
        };
        r.sort();
        let text = r.render();
        let a = text.find("src/a.rs:3").unwrap();
        let b1 = text.find("src/b.rs:9: error[nondet-iter]").unwrap();
        let b2 = text.find("src/b.rs:9: error[wall-clock]").unwrap();
        assert!(a < b1 && b1 < b2);
        assert!(text.ends_with("lint: 2 files, 3 errors, 0 warnings, 1 suppressed\n"));
    }

    #[test]
    fn warning_policy() {
        let mut r = LintReport::default();
        assert!(r.ok(true));
        r.findings.push(f("src/a.rs", 1, "unused-allow", Severity::Warning));
        assert!(r.ok(false));
        assert!(!r.ok(true));
        r.findings.push(f("src/a.rs", 2, "nondet-iter", Severity::Error));
        assert!(!r.ok(false));
    }
}
