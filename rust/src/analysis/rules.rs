//! The rule catalog: every repo invariant the linter enforces.
//!
//! Rules are token queries over comment/string-stripped code (see
//! [`crate::analysis::scanner`]), scoped by path and test-region:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `nondet-iter` | no `HashMap`/`HashSet` anywhere — iteration order is nondeterministic and one stray iteration in an output-adjacent module breaks byte-stable goldens. Use `BTreeMap`/`BTreeSet` or collect-and-sort; justify lookup-only maps with an allow. |
//! | `wall-clock` | no host-clock reads (`std::time`, `Instant::now`, `SystemTime`) — reports are *simulated* cycles, byte-stable across hosts. The explicit `--wall` path and the bench harness are allowlisted by design; benches are out of scope. |
//! | `panic-in-decoder` | no `unwrap`/`expect`/`panic!`-family/untrusted-buffer indexing in the fault-hardened decode surfaces (`compress/*`, `store/container.rs`, `layout/fetcher.rs`): corrupt payloads must decode to garbage or typed errors, never a panic (PR 8's property-tested contract). Test modules are exempt. |
//! | `stray-print` | no `println!`/`eprintln!`/`dbg!` outside `main.rs`, the lint binary, and `obs::log` — study tables render to `String` (printed by `main`), diagnostics go through the leveled `log_*` macros. |
//! | `env-read` | no `std::env` reads outside `config`/`util`/log setup (`env::args` in entry points is fine) — environment must not steer packing, pricing or serving output. Tests may read bless/temp knobs. |
//!
//! Adding a rule: add a [`RuleSpec`] here, its scope+tokens in
//! [`check_file`], a positive and negative fixture in `tests/lint.rs`,
//! and a row in DESIGN.md §Static analysis.

use super::scanner::{find_token, ScannedFile};

/// Static description of one rule (id, invariant, fix hint).
#[derive(Debug, Clone, Copy)]
pub struct RuleSpec {
    pub id: &'static str,
    pub summary: &'static str,
    pub hint: &'static str,
}

/// The enforced rules, in report order.
pub const RULES: &[RuleSpec] = &[
    RuleSpec {
        id: "nondet-iter",
        summary: "HashMap/HashSet iteration order is nondeterministic",
        hint: "use BTreeMap/BTreeSet (or collect-and-sort before rendering); a provably \
               lookup-only map may carry `// lint: allow(nondet-iter, <why>)`",
    },
    RuleSpec {
        id: "wall-clock",
        summary: "host clock read outside the --wall path",
        hint: "reports are simulated cycles; thread cycle counts through the timing pass \
               instead, or allowlist the file if it IS the --wall/bench surface",
    },
    RuleSpec {
        id: "panic-in-decoder",
        summary: "panic path in a fault-hardened decode surface",
        hint: "corrupt payloads must never panic: return typed errors or clamp \
               (`get`/`split_at(len.min(..))`); justify provable invariants with \
               `// lint: allow(panic-in-decoder, <why>)`",
    },
    RuleSpec {
        id: "stray-print",
        summary: "direct stdout/stderr print outside main/obs::log",
        hint: "render tables to String (main prints them) or use \
               log_error!/log_warn!/log_info!/log_debug!",
    },
    RuleSpec {
        id: "env-read",
        summary: "environment read outside config/util/log setup",
        hint: "plumb the knob through a config struct or CLI flag so runs are \
               reproducible from the command line alone",
    },
];

/// Warning-severity meta rules the driver emits (suppressions are
/// themselves linted).
pub const META_RULES: &[RuleSpec] = &[
    RuleSpec {
        id: "bad-pragma",
        summary: "malformed lint pragma",
        hint: "write `// lint: allow(<rule>, <reason>)` with a known rule id and a \
               non-empty reason",
    },
    RuleSpec {
        id: "unused-allow",
        summary: "suppression that suppresses nothing",
        hint: "the finding it covered is gone — delete the stale pragma/allowlist entry",
    },
];

pub fn is_known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

pub fn rule_spec(id: &str) -> Option<&'static RuleSpec> {
    RULES.iter().chain(META_RULES.iter()).find(|r| r.id == id)
}

/// One raw rule hit before suppression: `(line, rule id, message)`.
pub type RawFinding = (usize, &'static str, String);

fn is_test_path(path: &str) -> bool {
    path.starts_with("tests/")
}

/// The fault-hardened decode surfaces (PR 8).
fn is_decoder_path(path: &str) -> bool {
    path.starts_with("src/compress/")
        || path == "src/store/container.rs"
        || path == "src/layout/fetcher.rs"
}

/// Files allowed to print directly: the CLI entry points and the log
/// sink itself. (Study-table renderers return `String`s — they never
/// print, which is why they need no exemption.)
fn may_print(path: &str) -> bool {
    path == "src/main.rs" || path == "src/bin/gratetile-lint.rs" || path == "src/obs/log.rs"
}

/// Modules whose *job* is reading the environment: config loading,
/// util (thread-count / bench knobs) and log-level setup.
fn may_read_env(path: &str) -> bool {
    path.starts_with("src/util/") || path.starts_with("src/config/") || path == "src/obs/log.rs"
}

/// `std::env` occurrences that are not the `env::args` entry-point read.
fn env_read_hit(code: &str) -> bool {
    let mut from = 0usize;
    while let Some(pos) = code[from..].find("std::env") {
        let at = from + pos;
        let after = &code[at + "std::env".len()..];
        if !after.starts_with("::args") {
            return true;
        }
        from = at + "std::env".len();
    }
    false
}

/// Run every rule over one scanned file. Pragma/allowlist suppression
/// happens in the driver — this returns raw hits only, at most one per
/// (line, rule).
pub fn check_file(f: &ScannedFile) -> Vec<RawFinding> {
    let mut out = Vec::new();
    let decoder = is_decoder_path(&f.path);
    let test_file = is_test_path(&f.path);
    let src_file = f.path.starts_with("src/");
    for (idx, l) in f.lines.iter().enumerate() {
        let line = idx + 1;
        let code = l.code.as_str();
        if code.is_empty() {
            continue;
        }
        // nondet-iter: everywhere, test code included (a nondeterministic
        // test is a flaky test).
        for tok in ["HashMap", "HashSet"] {
            if find_token(code, tok).is_some() {
                out.push((line, "nondet-iter", format!("`{tok}` has nondeterministic iteration order")));
                break;
            }
        }
        // wall-clock: everywhere (benches are not scanned; the --wall
        // path is allowlisted, not exempted).
        for tok in ["std::time", "Instant::now", "SystemTime", "UNIX_EPOCH"] {
            if find_token(code, tok).is_some() {
                out.push((line, "wall-clock", format!("`{tok}` reads host time")));
                break;
            }
        }
        // panic-in-decoder: the hardened decode surfaces, non-test code.
        if decoder && !l.in_test {
            for tok in [
                ".unwrap()",
                ".expect(",
                "panic!",
                "unreachable!",
                "todo!",
                "unimplemented!",
                ".words[",
                "bytes[",
            ] {
                if find_token(code, tok).is_some() {
                    let what = if tok.ends_with('[') {
                        format!("`{tok}..]` indexes an untrusted payload buffer")
                    } else {
                        format!("`{tok}` can panic on corrupt payloads")
                    };
                    out.push((line, "panic-in-decoder", what));
                    break;
                }
            }
        }
        // stray-print: production src code only.
        if src_file && !test_file && !l.in_test && !may_print(&f.path) {
            for tok in ["println!", "eprintln!", "print!", "eprint!", "dbg!"] {
                if find_token(code, tok).is_some() {
                    out.push((line, "stray-print", format!("`{tok}` bypasses obs::log")));
                    break;
                }
            }
        }
        // env-read: production src code only.
        if src_file && !test_file && !l.in_test && !may_read_env(&f.path) && env_read_hit(code) {
            out.push((line, "env-read", "`std::env` read outside config/util/log".to_string()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::scan;

    fn hits(path: &str, text: &str) -> Vec<(usize, &'static str)> {
        check_file(&scan(path, text)).into_iter().map(|(l, r, _)| (l, r)).collect()
    }

    #[test]
    fn nondet_iter_fires_everywhere_including_tests() {
        assert_eq!(
            hits("tests/x.rs", "use std::collections::HashMap;\n"),
            vec![(1, "nondet-iter")]
        );
        assert!(hits("src/sim/x.rs", "use std::collections::BTreeMap;\n").is_empty());
    }

    #[test]
    fn wall_clock_matches_clock_reads() {
        assert_eq!(hits("src/sim/x.rs", "let t = Instant::now();\n"), vec![(1, "wall-clock")]);
        assert!(hits("src/sim/x.rs", "let cycles: u64 = 0;\n").is_empty());
    }

    #[test]
    fn panic_rule_scopes_to_decoder_paths_and_skips_tests() {
        let text = "fn d(v: &[u16]) { v.first().unwrap(); }\n";
        assert_eq!(hits("src/compress/x.rs", text), vec![(1, "panic-in-decoder")]);
        assert!(hits("src/sim/x.rs", text).is_empty());
        let tested = "fn ok() {}\n#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }\n";
        assert!(hits("src/compress/x.rs", tested).is_empty());
    }

    #[test]
    fn unwrap_or_is_not_a_panic_path() {
        assert!(hits("src/compress/x.rs", "let v = m.get(i).copied().unwrap_or(0);\n").is_empty());
    }

    #[test]
    fn stray_print_exempts_entry_points_and_tests() {
        let text = "fn f() { println!(\"x\"); }\n";
        assert_eq!(hits("src/sim/x.rs", text), vec![(1, "stray-print")]);
        assert!(hits("src/main.rs", text).is_empty());
        assert!(hits("src/obs/log.rs", text).is_empty());
        assert!(hits("tests/x.rs", text).is_empty());
    }

    #[test]
    fn env_read_carves_out_args_and_owner_modules() {
        assert_eq!(
            hits("src/sim/x.rs", "let v = std::env::var(\"X\");\n"),
            vec![(1, "env-read")]
        );
        assert!(hits("src/util/x.rs", "let v = std::env::var(\"X\");\n").is_empty());
        assert!(hits("src/main.rs", "let a = std::env::args();\n").is_empty());
        // args alone is carved out, a second real read on the line is not.
        assert_eq!(
            hits("src/sim/x.rs", "std::env::args(); std::env::var(\"X\");\n"),
            vec![(1, "env-read")]
        );
    }

    #[test]
    fn rule_specs_are_well_formed() {
        for r in RULES.iter().chain(META_RULES) {
            assert!(!r.id.is_empty() && !r.summary.is_empty() && !r.hint.is_empty());
        }
        assert!(is_known_rule("nondet-iter"));
        assert!(!is_known_rule("unused-allow"), "meta rules are not pragma targets");
        assert!(rule_spec("unused-allow").is_some());
    }
}
