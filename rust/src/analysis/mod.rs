//! Self-hosted invariant linter (`gratetile lint`).
//!
//! A dependency-free static-analysis pass over the crate's own sources
//! (`src/` + `tests/`): the [`scanner`] strips comments and string
//! literals, [`rules`] runs token queries for the five repo invariants
//! (determinism, clock discipline, panic-free decoding, print and env
//! hygiene), [`pragma`] resolves per-line `// lint: allow(rule, reason)`
//! suppressions plus the checked-in `lint.allow` file, and [`report`]
//! renders findings in a deterministic `(path, line, rule)` order.
//!
//! The pass lints itself — the analyzer's own sources are part of the
//! scanned tree — and runs three ways: `gratetile lint`, the standalone
//! `gratetile-lint` binary, and the tier-1 `tests/lint.rs` suite.

pub mod pragma;
pub mod report;
pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

use crate::err;
use crate::util::error::{Context as _, Result};
use pragma::{collect_pragmas, Allowlist};
use report::{Finding, LintReport, Severity};
use scanner::ScannedFile;

/// Name of the checked-in allowlist, resolved against the crate root.
pub const ALLOWLIST_FILE: &str = "lint.allow";

/// Run every rule over one scanned file, resolving suppressions.
/// Suppressed findings bump `report.suppressed`; everything else lands
/// in `report.findings` (rule hits as errors, suppression defects as
/// warnings).
fn lint_scanned(f: &ScannedFile, allow: &mut Allowlist, rep: &mut LintReport) {
    let comments: Vec<String> = f.lines.iter().map(|l| l.comment.clone()).collect();
    let code_blank: Vec<bool> = f.lines.iter().map(|l| l.code.trim().is_empty()).collect();
    let mut pragmas = collect_pragmas(&comments, &code_blank);
    for (line, rule, message) in rules::check_file(f) {
        let mut suppressed = false;
        for p in pragmas.iter_mut() {
            if p.defect.is_none() && p.rule == rule && p.applies_to == line {
                p.used = true;
                suppressed = true;
            }
        }
        if !suppressed && allow.allows(rule, &f.path) {
            suppressed = true;
        }
        if suppressed {
            rep.suppressed += 1;
            continue;
        }
        rep.findings.push(Finding {
            path: f.path.clone(),
            line,
            rule,
            message,
            hint: rules::rule_spec(rule).map(|r| r.hint).unwrap_or(""),
            severity: Severity::Error,
        });
    }
    // Suppressions are linted too: malformed or unknown-rule pragmas and
    // pragmas that suppress nothing are warnings (CI denies them).
    for p in &pragmas {
        let (rule, message): (&'static str, String) = if let Some(d) = &p.defect {
            ("bad-pragma", d.clone())
        } else if !rules::is_known_rule(&p.rule) {
            ("bad-pragma", format!("pragma names unknown rule '{}'", p.rule))
        } else if !p.used {
            ("unused-allow", format!("pragma for '{}' suppresses nothing", p.rule))
        } else {
            continue;
        };
        rep.findings.push(Finding {
            path: f.path.clone(),
            line: p.line,
            rule,
            message,
            hint: rules::rule_spec(rule).map(|r| r.hint).unwrap_or(""),
            severity: Severity::Warning,
        });
    }
}

/// Emit `unused-allow` warnings for allowlist entries that covered
/// nothing, then fix the report order. Called once, after the last file.
fn finish(allow: &Allowlist, mut rep: LintReport) -> LintReport {
    for e in &allow.entries {
        if !e.used {
            rep.findings.push(Finding {
                path: ALLOWLIST_FILE.to_string(),
                line: e.line,
                rule: "unused-allow",
                message: format!("entry '{} {}' suppresses nothing", e.rule, e.path),
                hint: rules::rule_spec("unused-allow").map(|r| r.hint).unwrap_or(""),
                severity: Severity::Warning,
            });
        }
    }
    rep.sort();
    rep
}

/// Lint one in-memory source against an in-memory allowlist. This is
/// the fixture entry point used by `tests/lint.rs`; `path` decides rule
/// scoping exactly as on disk (`src/compress/x.rs` is a decoder file).
pub fn lint_text(path: &str, text: &str, allow_text: &str) -> Result<LintReport> {
    let mut allow = Allowlist::parse(allow_text)?;
    let mut rep = LintReport { files_scanned: 1, ..LintReport::default() };
    lint_scanned(&scanner::scan(path, text), &mut allow, &mut rep);
    Ok(finish(&allow, rep))
}

/// Collect every `.rs` file under `<crate_root>/src` and
/// `<crate_root>/tests`, as sorted `(repo-relative path, absolute path)`
/// pairs. Directory order is sorted explicitly — `read_dir` order is
/// platform-dependent and the report must not be.
fn collect_sources(crate_root: &Path) -> Result<Vec<(String, PathBuf)>> {
    fn walk(dir: &Path, rel: &str, out: &mut Vec<(String, PathBuf)>) -> Result<()> {
        let mut entries = Vec::new();
        for e in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
            entries.push(e.with_context(|| format!("reading {}", dir.display()))?);
        }
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let name = e.file_name().to_string_lossy().into_owned();
            let child_rel = format!("{rel}/{name}");
            let p = e.path();
            if p.is_dir() {
                walk(&p, &child_rel, out)?;
            } else if name.ends_with(".rs") {
                out.push((child_rel, p));
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for top in ["src", "tests"] {
        let dir = crate_root.join(top);
        if dir.is_dir() {
            walk(&dir, top, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// Lint the whole tree rooted at `crate_root` (the directory holding
/// `src/`, `tests/` and `lint.allow`). A missing allowlist is an empty
/// allowlist; a malformed one is a hard error.
pub fn lint_tree(crate_root: &Path) -> Result<LintReport> {
    let allow_path = crate_root.join(ALLOWLIST_FILE);
    let allow_text = if allow_path.is_file() {
        std::fs::read_to_string(&allow_path)
            .with_context(|| format!("reading {}", allow_path.display()))?
    } else {
        String::new()
    };
    let mut allow = Allowlist::parse(&allow_text)?;
    let mut rep = LintReport::default();
    for (rel, abs) in collect_sources(crate_root)? {
        let text = std::fs::read_to_string(&abs)
            .with_context(|| format!("reading {}", abs.display()))?;
        lint_scanned(&scanner::scan(&rel, &text), &mut allow, &mut rep);
        rep.files_scanned += 1;
    }
    Ok(finish(&allow, rep))
}

/// Locate the crate root from `start`: the first of `start` itself and
/// `start/rust` that contains `src/lib.rs`. Lets the linter run from
/// the repo root or from `rust/` identically.
pub fn find_crate_root(start: &Path) -> Option<PathBuf> {
    for cand in [start.to_path_buf(), start.join("rust")] {
        if cand.join("src").join("lib.rs").is_file() {
            return Some(cand);
        }
    }
    None
}

/// Shared driver for the two CLI entries (`gratetile lint` and the
/// standalone `gratetile-lint`): resolve the crate root, run the pass,
/// optionally write the report file. Returns the rendered report and
/// whether the pass passed — printing is the caller's job (the
/// `stray-print` rule exempts only the entry points).
pub fn run_cli(
    root: Option<&str>,
    deny_warnings: bool,
    report_path: Option<&str>,
) -> Result<(String, bool)> {
    let root = match root {
        Some(r) => PathBuf::from(r),
        None => find_crate_root(Path::new("."))
            .ok_or_else(|| err!("lint: no src/lib.rs under '.' or './rust' (pass --root)"))?,
    };
    let rep = lint_tree(&root)?;
    let rendered = rep.render();
    if let Some(p) = report_path {
        std::fs::write(p, &rendered).with_context(|| format!("writing lint report {p}"))?;
    }
    Ok((rendered, rep.ok(deny_warnings)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_suppresses_and_is_marked_used() {
        let rep = lint_text(
            "src/x.rs",
            "use std::collections::HashMap; // lint: allow(nondet-iter, lookup-only)\n",
            "",
        )
        .unwrap();
        assert_eq!(rep.errors(), 0);
        assert_eq!(rep.warnings(), 0);
        assert_eq!(rep.suppressed, 1);
    }

    #[test]
    fn standalone_pragma_covers_next_line() {
        let rep = lint_text(
            "src/x.rs",
            "// lint: allow(nondet-iter, lookup-only)\nuse std::collections::HashMap;\n",
            "",
        )
        .unwrap();
        assert_eq!(rep.errors(), 0);
        assert_eq!(rep.suppressed, 1);
    }

    #[test]
    fn allowlist_suppresses_by_rule_and_path() {
        let rep = lint_text(
            "src/obs/pipeline.rs",
            "let t = Instant::now();\n",
            "wall-clock src/obs/pipeline.rs the --wall path reads host time by design\n",
        )
        .unwrap();
        assert_eq!(rep.errors(), 0);
        assert_eq!(rep.suppressed, 1);
    }

    #[test]
    fn unsuppressed_finding_is_an_error_with_location() {
        let rep = lint_text("src/x.rs", "fn f() {}\nlet t = Instant::now();\n", "").unwrap();
        assert_eq!(rep.errors(), 1);
        let f = &rep.findings[0];
        assert_eq!((f.path.as_str(), f.line, f.rule), ("src/x.rs", 2, "wall-clock"));
        assert!(!rep.ok(false));
    }

    #[test]
    fn wrong_rule_pragma_does_not_suppress() {
        let rep = lint_text(
            "src/x.rs",
            "use std::collections::HashMap; // lint: allow(wall-clock, wrong rule)\n",
            "",
        )
        .unwrap();
        assert_eq!(rep.errors(), 1, "{}", rep.render());
        // And the pragma itself is flagged as suppressing nothing.
        assert_eq!(rep.warnings(), 1);
    }

    #[test]
    fn stale_suppressions_warn_and_fail_under_deny() {
        let rep = lint_text(
            "src/x.rs",
            "fn clean() {} // lint: allow(nondet-iter, stale)\n",
            "wall-clock src/other.rs stale entry\n",
        )
        .unwrap();
        assert_eq!(rep.errors(), 0);
        assert_eq!(rep.warnings(), 2);
        assert!(rep.ok(false) && !rep.ok(true));
        let allow_warn = rep.findings.iter().find(|f| f.path == ALLOWLIST_FILE).unwrap();
        assert_eq!(allow_warn.rule, "unused-allow");
    }

    #[test]
    fn bad_pragmas_warn() {
        let rep =
            lint_text("src/x.rs", "fn f() {} // lint: allow(nondet-iter)\n", "").unwrap();
        assert_eq!(rep.warnings(), 1);
        assert_eq!(rep.findings[0].rule, "bad-pragma");
        let rep =
            lint_text("src/x.rs", "fn f() {} // lint: allow(no-such-rule, why)\n", "").unwrap();
        assert_eq!(rep.findings[0].rule, "bad-pragma");
    }
}
