//! Benchmark network zoo (paper §IV).
//!
//! The paper simulates representative layers from AlexNet, VGG-16,
//! ResNet-18, ResNet-50 and VDSR, selected exactly as described:
//!
//! * **AlexNet** — all conv layers except CONV1 (dense input image);
//! * **VGG-16** — the layers right before each pooling layer;
//! * **ResNet-18** — the layers right after the (stage) pooling /
//!   down-sampling points;
//! * **ResNet-50** — the down-sampling conv layers and the layers
//!   before them;
//! * **VDSR** — every fourth layer (all 18 layers share one shape).
//!
//! **Substitution note (DESIGN.md §2):** the paper measures real ImageNet
//! activation sparsity. We do not have ImageNet, so each layer carries a
//! calibrated `density` (nonzero fraction) taken from the published
//! ReLU-sparsity literature (Cnvlutin, Eyeriss and SCNN report 40–90 %
//! zeros depending on depth; VDSR's residual maps are very sparse). The
//! synthetic generator in `tensor::sparsity` reproduces the clustered
//! spatial statistics; the e2e example additionally uses *real* ReLU
//! activations produced by the AOT-compiled JAX CNN.

use super::layer::ConvLayer;

/// Benchmark networks used in the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Network {
    AlexNet,
    Vgg16,
    ResNet18,
    ResNet50,
    Vdsr,
}

impl Network {
    pub fn name(&self) -> &'static str {
        match self {
            Network::AlexNet => "AlexNet",
            Network::Vgg16 => "VGG16",
            Network::ResNet18 => "ResNet18",
            Network::ResNet50 => "ResNet50",
            Network::Vdsr => "VDSR",
        }
    }

    pub fn all() -> [Network; 5] {
        [
            Network::AlexNet,
            Network::Vgg16,
            Network::ResNet18,
            Network::ResNet50,
            Network::Vdsr,
        ]
    }
}

/// One benchmark layer: geometry + calibrated activation density of its
/// *input* feature map.
#[derive(Debug, Clone)]
pub struct BenchLayer {
    pub network: Network,
    pub name: &'static str,
    pub layer: ConvLayer,
    /// Nonzero fraction of the input feature map (1 - sparsity).
    pub density: f64,
}

impl BenchLayer {
    fn new(
        network: Network,
        name: &'static str,
        layer: ConvLayer,
        density: f64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&density));
        Self { network, name, layer, density }
    }
}

/// Layers for one network (geometry from the original papers; densities
/// per the substitution note above).
pub fn network_layers(net: Network) -> Vec<BenchLayer> {
    use Network::*;
    let l = ConvLayer::new;
    match net {
        // AlexNet: CONV2..CONV5 (CONV1 skipped — dense input image).
        // Input fm geometry after the preceding pool layers.
        AlexNet => vec![
            BenchLayer::new(AlexNet, "CONV2", l(2, 1, 27, 27, 96, 256), 0.50),
            BenchLayer::new(AlexNet, "CONV3", l(1, 1, 13, 13, 256, 384), 0.40),
            BenchLayer::new(AlexNet, "CONV4", l(1, 1, 13, 13, 384, 384), 0.38),
            BenchLayer::new(AlexNet, "CONV5", l(1, 1, 13, 13, 384, 256), 0.37),
        ],
        // VGG-16: the conv right before each of the five pools.
        Vgg16 => vec![
            BenchLayer::new(Vgg16, "CONV1_2", l(1, 1, 224, 224, 64, 64), 0.52),
            BenchLayer::new(Vgg16, "CONV2_2", l(1, 1, 112, 112, 128, 128), 0.45),
            BenchLayer::new(Vgg16, "CONV3_3", l(1, 1, 56, 56, 256, 256), 0.35),
            BenchLayer::new(Vgg16, "CONV4_3", l(1, 1, 28, 28, 512, 512), 0.27),
            BenchLayer::new(Vgg16, "CONV5_3", l(1, 1, 14, 14, 512, 512), 0.22),
        ],
        // ResNet-18: the 3x3 layers right after each down-sampling point.
        ResNet18 => vec![
            BenchLayer::new(ResNet18, "CONV2_1", l(1, 1, 56, 56, 64, 64), 0.55),
            BenchLayer::new(ResNet18, "CONV3_1", l(1, 2, 56, 56, 64, 128), 0.48),
            BenchLayer::new(ResNet18, "CONV4_1", l(1, 2, 28, 28, 128, 256), 0.42),
            BenchLayer::new(ResNet18, "CONV5_1", l(1, 2, 14, 14, 256, 512), 0.38),
        ],
        // ResNet-50: down-sampling 3x3 convs + the 1x1 layers feeding them.
        ResNet50 => vec![
            BenchLayer::new(ResNet50, "CONV3_1x1", l(0, 1, 56, 56, 256, 128), 0.50),
            BenchLayer::new(ResNet50, "CONV3_3x3s2", l(1, 2, 56, 56, 128, 128), 0.45),
            BenchLayer::new(ResNet50, "CONV4_1x1", l(0, 1, 28, 28, 512, 256), 0.42),
            BenchLayer::new(ResNet50, "CONV4_3x3s2", l(1, 2, 28, 28, 256, 256), 0.38),
            BenchLayer::new(ResNet50, "CONV5_1x1", l(0, 1, 14, 14, 1024, 512), 0.35),
            BenchLayer::new(ResNet50, "CONV5_3x3s2", l(1, 2, 14, 14, 512, 512), 0.33),
        ],
        // VDSR: 18 identical 3x3x64 layers at HR resolution; every 4th.
        // Residual super-resolution maps are extremely sparse.
        Vdsr => vec![
            BenchLayer::new(Vdsr, "CONV4", l(1, 1, 256, 256, 64, 64), 0.18),
            BenchLayer::new(Vdsr, "CONV8", l(1, 1, 256, 256, 64, 64), 0.14),
            BenchLayer::new(Vdsr, "CONV12", l(1, 1, 256, 256, 64, 64), 0.12),
            BenchLayer::new(Vdsr, "CONV16", l(1, 1, 256, 256, 64, 64), 0.12),
        ],
    }
}

/// The full benchmark suite (all five networks), Fig. 8/9 workload.
pub fn benchmark_suite() -> Vec<BenchLayer> {
    Network::all().iter().flat_map(|&n| network_layers(n)).collect()
}

/// The *complete* convolution stack of a network (every conv layer,
/// including the dense-input first layer) — the Fig. 1 power-model
/// workload, which unlike the bandwidth suite needs whole networks.
/// Geometry from the original papers; fully-connected layers are
/// excluded (Fig. 1 simulates the conv pipelines).
pub fn full_conv_stack(net: Network) -> Vec<ConvLayer> {
    let l = ConvLayer::new;
    match net {
        Network::AlexNet => vec![
            // CONV1 is 11x11/s4 on the 227x227x3 image.
            ConvLayer { k: 5, s: 4, d: 1, h: 227, w: 227, c_in: 3, c_out: 96 },
            l(2, 1, 27, 27, 96, 256),
            l(1, 1, 13, 13, 256, 384),
            l(1, 1, 13, 13, 384, 384),
            l(1, 1, 13, 13, 384, 256),
        ],
        Network::Vgg16 => {
            let mut v = Vec::new();
            let stages: [(usize, usize, usize, usize); 5] = [
                (224, 64, 3, 2),   // (res, width, cin_first, convs)
                (112, 128, 64, 2),
                (56, 256, 128, 3),
                (28, 512, 256, 3),
                (14, 512, 512, 3),
            ];
            for (res, width, cin_first, convs) in stages {
                for i in 0..convs {
                    let cin = if i == 0 { cin_first } else { width };
                    v.push(l(1, 1, res, res, cin, width));
                }
            }
            v
        }
        Network::ResNet18 => {
            let mut v = vec![ConvLayer { k: 3, s: 2, d: 1, h: 224, w: 224, c_in: 3, c_out: 64 }];
            let stages: [(usize, usize, usize, usize); 4] = [
                (56, 64, 64, 1),   // (res_in, width, cin, stride_first)
                (56, 128, 64, 2),
                (28, 256, 128, 2),
                (14, 512, 256, 2),
            ];
            for (res, width, cin, s_first) in stages {
                // Two basic blocks of two 3x3 convs each.
                v.push(l(1, s_first, res, res, cin, width));
                let r = res / s_first;
                for _ in 0..3 {
                    v.push(l(1, 1, r, r, width, width));
                }
            }
            v
        }
        Network::ResNet50 => {
            let mut v = vec![ConvLayer { k: 3, s: 2, d: 1, h: 224, w: 224, c_in: 3, c_out: 64 }];
            // Bottleneck stages: (res_in, mid, out, blocks, stride_first).
            let stages: [(usize, usize, usize, usize, usize); 4] = [
                (56, 64, 256, 3, 1),
                (56, 128, 512, 4, 2),
                (28, 256, 1024, 6, 2),
                (14, 512, 2048, 3, 2),
            ];
            let mut cin = 64;
            for (res, mid, cout, blocks, s_first) in stages {
                for b in 0..blocks {
                    let s = if b == 0 { s_first } else { 1 };
                    let r_in = if b == 0 { res } else { res / s_first };
                    v.push(l(0, 1, r_in, r_in, cin, mid)); // 1x1 reduce
                    v.push(l(1, s, r_in, r_in, mid, mid)); // 3x3
                    let r_out = r_in / s;
                    v.push(l(0, 1, r_out, r_out, mid, cout)); // 1x1 expand
                    cin = cout;
                }
            }
            v
        }
        Network::Vdsr => (0..18)
            .map(|i| {
                let cin = if i == 0 { 1 } else { 64 };
                let cout = if i == 17 { 1 } else { 64 };
                l(1, 1, 256, 256, cin, cout)
            })
            .collect(),
    }
}

/// Mean conv-layer activation density for a network (used by the power
/// model to weight compressed-traffic what-ifs; same calibration source
/// as the per-layer values above).
pub fn network_mean_density(net: Network) -> f64 {
    let layers = network_layers(net);
    layers.iter().map(|b| b.density).sum::<f64>() / layers.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_all_networks() {
        let suite = benchmark_suite();
        for net in Network::all() {
            assert!(
                suite.iter().any(|b| b.network == net),
                "{} missing from suite",
                net.name()
            );
        }
        assert_eq!(suite.len(), 4 + 5 + 4 + 6 + 4);
    }

    #[test]
    fn densities_are_valid_fractions() {
        for b in benchmark_suite() {
            assert!(b.density > 0.0 && b.density < 1.0, "{}", b.name);
        }
    }

    #[test]
    fn alexnet_skips_conv1() {
        let layers = network_layers(Network::AlexNet);
        assert!(layers.iter().all(|b| b.name != "CONV1"));
        assert_eq!(layers.len(), 4);
    }

    #[test]
    fn geometry_sanity() {
        for b in benchmark_suite() {
            assert!(b.layer.h >= 13 && b.layer.w >= 13, "{}", b.name);
            assert!(b.layer.c_in >= 64 || b.network == Network::AlexNet);
            // All kernels in the suite are 1x1, 3x3 or 5x5.
            assert!(b.layer.k <= 2, "{}", b.name);
        }
    }

    #[test]
    fn resnet50_has_pointwise_layers() {
        let layers = network_layers(Network::ResNet50);
        assert!(layers.iter().any(|b| b.layer.k == 0));
        assert!(layers.iter().any(|b| b.layer.s == 2));
    }

    #[test]
    fn full_stacks_have_expected_layer_counts() {
        assert_eq!(full_conv_stack(Network::AlexNet).len(), 5);
        assert_eq!(full_conv_stack(Network::Vgg16).len(), 13);
        assert_eq!(full_conv_stack(Network::ResNet18).len(), 17);
        assert_eq!(full_conv_stack(Network::ResNet50).len(), 1 + 3 * (3 + 4 + 6 + 3));
        assert_eq!(full_conv_stack(Network::Vdsr).len(), 18);
    }

    #[test]
    fn full_stack_macs_match_published_magnitudes() {
        // Conv-only MAC counts (within ~20% of the published numbers:
        // AlexNet ~1.07 GMAC ungrouped — the classic 0.66 GMAC figure
        // assumes its 2-way grouped convs, which we model ungrouped —
        // VGG-16 ~15.3 GMAC, ResNet-18 ~1.8 GMAC).
        let gmacs = |n: Network| -> f64 {
            full_conv_stack(n).iter().map(|l| l.macs() as f64).sum::<f64>() / 1e9
        };
        let a = gmacs(Network::AlexNet);
        assert!((0.9..1.3).contains(&a), "AlexNet {a} GMAC");
        let v = gmacs(Network::Vgg16);
        assert!((13.0..17.5).contains(&v), "VGG16 {v} GMAC");
        let r = gmacs(Network::ResNet18);
        assert!((1.4..2.4).contains(&r), "ResNet18 {r} GMAC");
        let r50 = gmacs(Network::ResNet50);
        assert!((3.0..5.0).contains(&r50), "ResNet50 {r50} GMAC");
    }

    #[test]
    fn channel_chaining_is_consistent() {
        // Each layer's c_in must equal the previous layer's c_out within
        // a sequential stack (AlexNet, VGG, VDSR are strictly sequential).
        for net in [Network::AlexNet, Network::Vgg16, Network::Vdsr] {
            let stack = full_conv_stack(net);
            for w in stack.windows(2) {
                assert_eq!(w[1].c_in, w[0].c_out, "{net:?}");
            }
        }
    }

    #[test]
    fn mean_density_matches_paper_operating_point() {
        // The paper's geomean saving is ~55% with bitmask compression;
        // that requires the suite's average density to sit near 0.35.
        let suite = benchmark_suite();
        let mean: f64 =
            suite.iter().map(|b| b.density).sum::<f64>() / suite.len() as f64;
        assert!((0.25..=0.45).contains(&mean), "mean density {mean}");
    }
}
