//! CNN layer descriptors.
//!
//! The paper (§III-B) characterises every modern CNN layer by three
//! parameters: kernel half-width `k` (kernel size `2k+1`), output stride
//! `s`, and dilation `d`. We add the input feature-map geometry and the
//! output channel count so the simulator and the power model can derive
//! exact access counts.

/// One convolution layer, as seen from its *input* feature map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvLayer {
    /// Kernel half-width; kernel size is `2k+1` (paper notation).
    pub k: usize,
    /// Output stride `s >= 1`.
    pub s: usize,
    /// Dilation `d >= 1` (paper's dilated-CNN extension, Fig. 6b).
    pub d: usize,
    /// Input feature map height.
    pub h: usize,
    /// Input feature map width.
    pub w: usize,
    /// Input channels.
    pub c_in: usize,
    /// Output channels (used by the power model / e2e pipeline).
    pub c_out: usize,
}

impl ConvLayer {
    /// Standard (non-dilated) layer.
    pub fn new(k: usize, s: usize, h: usize, w: usize, c_in: usize, c_out: usize) -> Self {
        Self { k, s, d: 1, h, w, c_in, c_out }
    }

    /// Dilated variant.
    pub fn dilated(mut self, d: usize) -> Self {
        assert!(d >= 1);
        self.d = d;
        self
    }

    /// Kernel size along one spatial axis (`2k+1`).
    pub fn kernel_size(&self) -> usize {
        2 * self.k + 1
    }

    /// Effective kernel reach (`k * d`) — the halo half-width.
    pub fn halo(&self) -> usize {
        self.k * self.d
    }

    /// Output spatial dims under SAME padding (paper's setting: windows
    /// may start at `-k*d`, i.e. zero padding of the halo).
    pub fn out_h(&self) -> usize {
        self.h.div_ceil(self.s)
    }

    pub fn out_w(&self) -> usize {
        self.w.div_ceil(self.s)
    }

    /// Words in the input feature map (1 word = 1 element).
    pub fn input_words(&self) -> usize {
        self.h * self.w * self.c_in
    }

    /// MAC count for the full layer (for the power model).
    pub fn macs(&self) -> u64 {
        self.out_h() as u64
            * self.out_w() as u64
            * self.c_out as u64
            * self.c_in as u64
            * (self.kernel_size() * self.kernel_size()) as u64
    }

    /// Kernel (weight) word count.
    pub fn weight_words(&self) -> u64 {
        (self.kernel_size() * self.kernel_size()) as u64 * self.c_in as u64 * self.c_out as u64
    }

    /// Output feature-map word count.
    pub fn output_words(&self) -> u64 {
        self.out_h() as u64 * self.out_w() as u64 * self.c_out as u64
    }
}

/// An output processing tile: the unit of work the accelerator schedules
/// (paper §III-B, Table I). `th x tw` output pixels over `tc` input
/// channels are produced from one halo'd input window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileShape {
    pub th: usize,
    pub tw: usize,
    /// Channels of the *input* feature map processed per tile pass.
    pub tc: usize,
}

impl TileShape {
    pub fn new(th: usize, tw: usize, tc: usize) -> Self {
        assert!(th > 0 && tw > 0 && tc > 0);
        Self { th, tw, tc }
    }

    /// Input window height fetched for one tile: `(th-1)*s + 2*k*d + 1`.
    pub fn in_h(&self, layer: &ConvLayer) -> usize {
        (self.th - 1) * layer.s + 2 * layer.halo() + 1
    }

    /// Input window width fetched for one tile.
    pub fn in_w(&self, layer: &ConvLayer) -> usize {
        (self.tw - 1) * layer.s + 2 * layer.halo() + 1
    }

    /// Words in the halo'd input window for one tile.
    pub fn input_window_words(&self, layer: &ConvLayer) -> usize {
        self.in_h(layer) * self.in_w(layer) * self.tc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_size_and_halo() {
        let l = ConvLayer::new(1, 1, 32, 32, 8, 8);
        assert_eq!(l.kernel_size(), 3);
        assert_eq!(l.halo(), 1);
        let ld = ConvLayer::new(1, 1, 32, 32, 8, 8).dilated(2);
        assert_eq!(ld.kernel_size(), 3);
        assert_eq!(ld.halo(), 2);
    }

    #[test]
    fn output_dims_same_padding() {
        let l = ConvLayer::new(1, 1, 13, 13, 384, 384);
        assert_eq!(l.out_h(), 13);
        let l2 = ConvLayer::new(1, 2, 56, 56, 64, 128);
        assert_eq!(l2.out_h(), 28);
        let l3 = ConvLayer::new(1, 2, 13, 13, 8, 8);
        assert_eq!(l3.out_h(), 7); // ceil(13/2)
    }

    #[test]
    fn table1_input_window_shapes() {
        // Paper Table I: (3,1) small tile -> 10x18x8 input window.
        let l31 = ConvLayer::new(1, 1, 224, 224, 64, 64);
        let t = TileShape::new(8, 16, 8);
        assert_eq!(t.in_h(&l31), 10);
        assert_eq!(t.in_w(&l31), 18);
        assert_eq!(t.input_window_words(&l31), 10 * 18 * 8);

        // (3,2) small tile -> 9x17x8.
        let l32 = ConvLayer::new(1, 2, 224, 224, 64, 64);
        let t2 = TileShape::new(4, 8, 8);
        assert_eq!(t2.in_h(&l32), 9);
        assert_eq!(t2.in_w(&l32), 17);

        // (5,1) small tile -> 12x20x8.
        let l51 = ConvLayer::new(2, 1, 224, 224, 64, 64);
        let t3 = TileShape::new(8, 16, 8);
        assert_eq!(t3.in_h(&l51), 12);
        assert_eq!(t3.in_w(&l51), 20);

        // Large-tile (Eyeriss) rows of Table I.
        let te = TileShape::new(16, 16, 16);
        assert_eq!(te.in_h(&l31), 18);
        assert_eq!(te.in_w(&l31), 18);
        let te2 = TileShape::new(8, 8, 16);
        assert_eq!(te2.in_h(&l32), 17);
        let te3 = TileShape::new(16, 16, 16);
        assert_eq!(te3.in_h(&l51), 20);
    }

    #[test]
    fn macs_count() {
        let l = ConvLayer::new(1, 1, 4, 4, 2, 3);
        // 4*4 outputs * 3 cout * 2 cin * 9 taps
        assert_eq!(l.macs(), 16 * 3 * 2 * 9);
    }
}
