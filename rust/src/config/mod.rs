//! Configuration: CNN layer descriptors, hardware platform descriptors,
//! and the benchmark network zoo from the paper's §IV.

pub mod file;
pub mod hardware;
pub mod layer;
pub mod zoo;

pub use file::{ConfigLayer, FileConfig};
pub use hardware::{Hardware, Platform, WORDS_PER_LINE};
pub use layer::{ConvLayer, TileShape};
pub use zoo::{benchmark_suite, network_layers, BenchLayer, Network};
