//! Hardware platform descriptors (paper §IV-A).
//!
//! Two platforms bound the design space in the paper's evaluation:
//!
//! * **Small tile** (modelled after an NVIDIA SM with 64 KB shared
//!   memory): the processing tile must fit a 4 K-word input window.
//! * **Large tile** (modelled after Eyeriss with a 108 KB global buffer):
//!   16 K-word input windows.
//!
//! Both use 8-word (128-bit) memory alignment — one "cache line" in this
//! crate's terminology — matching the AXI bus width of [15] and NVIDIA's
//! L1 sector granularity.

use super::layer::{ConvLayer, TileShape};

/// Words per cache line / DRAM alignment unit (8 words = 128 bits at
/// 16-bit words). Every aligned fetch moves whole lines.
pub const WORDS_PER_LINE: usize = 8;

/// Bytes per word (16-bit feature words, paper §IV-A).
pub const BYTES_PER_WORD: usize = 2;

/// Named platform presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    /// Small-tile configuration (NVIDIA Volta SM, 64 KB shared memory).
    NvidiaSmallTile,
    /// Large-tile configuration (Eyeriss, 108 KB global buffer).
    EyerissLargeTile,
}

impl Platform {
    pub fn name(&self) -> &'static str {
        match self {
            Platform::NvidiaSmallTile => "NVIDIA",
            Platform::EyerissLargeTile => "Eyeriss",
        }
    }

    pub fn hardware(&self) -> Hardware {
        match self {
            Platform::NvidiaSmallTile => Hardware {
                name: "NVIDIA (small tile)",
                tile_budget_words: 4 * 1024,
                base_tile: TileShape::new(8, 16, 8),
                words_per_line: WORDS_PER_LINE,
                pointer_bits: 28,
                size_field_bits: 20,
            },
            Platform::EyerissLargeTile => Hardware {
                name: "Eyeriss (large tile)",
                tile_budget_words: 16 * 1024,
                base_tile: TileShape::new(16, 16, 16),
                words_per_line: WORDS_PER_LINE,
                pointer_bits: 28,
                size_field_bits: 20,
            },
        }
    }
}

/// A hardware configuration: buffer budget, alignment, metadata widths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hardware {
    pub name: &'static str,
    /// Max words of one input tile window (≈ ¼ of the on-chip buffer,
    /// leaving room for double buffering + kernels; paper §IV-A).
    pub tile_budget_words: usize,
    /// Output-tile shape at stride 1; shrinks with stride (see
    /// [`Hardware::tile_for_layer`]).
    pub base_tile: TileShape,
    /// Words per aligned line (8 = 128 bits).
    pub words_per_line: usize,
    /// Pointer width for block metadata: 32-bit addresses with 16-byte
    /// alignment → 28 bits (paper §III-C).
    pub pointer_bits: usize,
    /// Total bits for the four sub-tensor size fields (paper takes the
    /// max over supported kernel sizes → 20 bits, §III-C).
    pub size_field_bits: usize,
}

impl Hardware {
    /// Bytes per line.
    pub fn line_bytes(&self) -> usize {
        self.words_per_line * BYTES_PER_WORD
    }

    /// Choose the processing tile for a layer (reproduces Table I).
    ///
    /// The output tile keeps a roughly constant *input* window: spatial
    /// output dims shrink by the stride; the window is then verified
    /// against the buffer budget and halved (h, then w) until it fits.
    pub fn tile_for_layer(&self, layer: &ConvLayer) -> TileShape {
        let mut th = (self.base_tile.th / layer.s).max(1);
        let mut tw = (self.base_tile.tw / layer.s).max(1);
        let mut tc = self.base_tile.tc.min(layer.c_in.next_power_of_two());
        loop {
            let t = TileShape::new(th, tw, tc);
            if t.input_window_words(layer) <= self.tile_budget_words {
                return t;
            }
            // Shrink spatial dims first (keeps channel-group width, which
            // metadata blocks are sized for), then the channel group.
            if th > 1 || tw > 1 {
                if th >= tw {
                    th = (th / 2).max(1);
                } else {
                    tw = (tw / 2).max(1);
                }
            } else if tc > 1 {
                tc = (tc / 2).max(1);
            } else {
                // Degenerate: a single halo'd pixel over one channel
                // exceeds the buffer; return it anyway (caller checks).
                return t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_geometry() {
        let hw = Platform::NvidiaSmallTile.hardware();
        assert_eq!(hw.words_per_line, 8);
        assert_eq!(hw.line_bytes(), 16);
    }

    #[test]
    fn table1_tiles_small() {
        let hw = Platform::NvidiaSmallTile.hardware();
        // (3,1) -> input window 10x18x8 (Table I row 1).
        let l = ConvLayer::new(1, 1, 224, 224, 64, 64);
        let t = hw.tile_for_layer(&l);
        assert_eq!((t.in_h(&l), t.in_w(&l), t.tc), (10, 18, 8));
        // (3,2) -> 9x17x8 (row 2).
        let l2 = ConvLayer::new(1, 2, 224, 224, 64, 64);
        let t2 = hw.tile_for_layer(&l2);
        assert_eq!((t2.in_h(&l2), t2.in_w(&l2), t2.tc), (9, 17, 8));
        // (5,1) -> 12x20x8 (row 3).
        let l3 = ConvLayer::new(2, 1, 224, 224, 64, 64);
        let t3 = hw.tile_for_layer(&l3);
        assert_eq!((t3.in_h(&l3), t3.in_w(&l3), t3.tc), (12, 20, 8));
    }

    #[test]
    fn table1_tiles_large() {
        let hw = Platform::EyerissLargeTile.hardware();
        let l = ConvLayer::new(1, 1, 224, 224, 64, 64);
        let t = hw.tile_for_layer(&l);
        assert_eq!((t.in_h(&l), t.in_w(&l), t.tc), (18, 18, 16));
        let l2 = ConvLayer::new(1, 2, 224, 224, 64, 64);
        let t2 = hw.tile_for_layer(&l2);
        assert_eq!((t2.in_h(&l2), t2.in_w(&l2), t2.tc), (17, 17, 16));
        let l3 = ConvLayer::new(2, 1, 224, 224, 64, 64);
        let t3 = hw.tile_for_layer(&l3);
        assert_eq!((t3.in_h(&l3), t3.in_w(&l3), t3.tc), (20, 20, 16));
    }

    #[test]
    fn budget_is_respected_for_large_kernels() {
        let hw = Platform::NvidiaSmallTile.hardware();
        // A huge dilated kernel must still produce a window within budget.
        let l = ConvLayer::new(5, 1, 224, 224, 64, 64).dilated(4);
        let t = hw.tile_for_layer(&l);
        assert!(t.input_window_words(&l) <= hw.tile_budget_words);
    }

    #[test]
    fn narrow_channel_input_clamps_tc() {
        let hw = Platform::EyerissLargeTile.hardware();
        let l = ConvLayer::new(1, 1, 64, 64, 3, 64);
        let t = hw.tile_for_layer(&l);
        assert!(t.tc <= 4);
    }
}
