//! Config-file support: define custom hardware platforms and layer
//! workloads without recompiling (the launcher-grade entry point).
//!
//! Dependency-free INI-style format (no serde/toml in the offline
//! environment):
//!
//! ```ini
//! [hardware]
//! name = my-accel
//! tile_budget_words = 8192
//! base_tile = 8x16x8          # th x tw x tc at stride 1
//!
//! [layer conv3_1]
//! k = 1        # kernel half-width (kernel = 2k+1)
//! s = 2
//! d = 1
//! h = 56
//! w = 56
//! c_in = 64
//! c_out = 128
//! density = 0.45
//! ```
//!
//! Used by `gratetile sweep --config <file>` and available to library
//! users for custom studies.

use super::hardware::{Hardware, Platform, WORDS_PER_LINE};
use super::layer::{ConvLayer, TileShape};
use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::path::Path;

/// A layer entry from a config file.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigLayer {
    pub name: String,
    pub layer: ConvLayer,
    pub density: f64,
}

/// Parsed configuration.
#[derive(Debug, Clone)]
pub struct FileConfig {
    /// Custom hardware, if a `[hardware]` section was present.
    pub hardware: Option<Hardware>,
    pub layers: Vec<ConfigLayer>,
}

impl FileConfig {
    pub fn load(path: &Path) -> Result<FileConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// The effective hardware (custom or a platform default).
    pub fn hardware_or(&self, default: Platform) -> Hardware {
        self.hardware.unwrap_or_else(|| default.hardware())
    }

    pub fn parse(text: &str) -> Result<FileConfig> {
        let mut cfg = FileConfig { hardware: None, layers: Vec::new() };
        let mut section: Option<(String, Vec<(String, String)>)> = None;

        let flush = |sec: Option<(String, Vec<(String, String)>)>,
                         cfg: &mut FileConfig|
         -> Result<()> {
            let Some((header, kvs)) = sec else { return Ok(()) };
            let get = |key: &str| -> Option<&str> {
                kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
            };
            let req_usize = |key: &str| -> Result<usize> {
                get(key)
                    .ok_or_else(|| err!("[{header}] missing '{key}'"))?
                    .parse()
                    .map_err(|e| err!("[{header}] {key}: {e}"))
            };
            if header == "hardware" {
                let tile = get("base_tile").unwrap_or("8x16x8");
                let dims: Vec<usize> = tile
                    .split('x')
                    .map(|d| d.trim().parse())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| err!("[hardware] base_tile: {e}"))?;
                if dims.len() != 3 {
                    bail!("[hardware] base_tile must be th x tw x tc");
                }
                cfg.hardware = Some(Hardware {
                    name: "custom",
                    tile_budget_words: req_usize("tile_budget_words")?,
                    base_tile: TileShape::new(dims[0], dims[1], dims[2]),
                    words_per_line: WORDS_PER_LINE,
                    pointer_bits: get("pointer_bits")
                        .map(|v| v.parse())
                        .transpose()?
                        .unwrap_or(28),
                    size_field_bits: get("size_field_bits")
                        .map(|v| v.parse())
                        .transpose()?
                        .unwrap_or(20),
                });
            } else if let Some(name) = header.strip_prefix("layer") {
                let name = name.trim();
                if name.is_empty() {
                    bail!("layer sections need a name: [layer conv1]");
                }
                let layer = ConvLayer {
                    k: req_usize("k")?,
                    s: get("s").map(|v| v.parse()).transpose()?.unwrap_or(1),
                    d: get("d").map(|v| v.parse()).transpose()?.unwrap_or(1),
                    h: req_usize("h")?,
                    w: req_usize("w")?,
                    c_in: req_usize("c_in")?,
                    c_out: get("c_out")
                        .map(|v| v.parse())
                        .transpose()?
                        .unwrap_or(req_usize("c_in")?),
                };
                if layer.s == 0 || layer.d == 0 || layer.h == 0 || layer.w == 0 {
                    bail!("[{header}] dims/stride/dilation must be positive");
                }
                let density: f64 = get("density")
                    .map(|v| v.parse())
                    .transpose()?
                    .unwrap_or(0.4);
                if !(0.0..=1.0).contains(&density) {
                    bail!("[{header}] density must be in [0,1]");
                }
                cfg.layers.push(ConfigLayer { name: name.to_string(), layer, density });
            } else {
                bail!("unknown section [{header}]");
            }
            Ok(())
        };

        for (ln, raw) in text.lines().enumerate() {
            // Strip comments (# or ;) and whitespace.
            let line = raw.split(['#', ';']).next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let header = h
                    .strip_suffix(']')
                    .ok_or_else(|| err!("line {}: unterminated section", ln + 1))?
                    .trim()
                    .to_string();
                flush(section.take(), &mut cfg)?;
                section = Some((header, Vec::new()));
            } else if let Some((k, v)) = line.split_once('=') {
                let Some((_, kvs)) = &mut section else {
                    bail!("line {}: key outside a section", ln + 1);
                };
                kvs.push((k.trim().to_string(), v.trim().to_string()));
            } else {
                bail!("line {}: expected 'key = value' or '[section]'", ln + 1);
            }
        }
        flush(section.take(), &mut cfg)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
# custom platform
[hardware]
name = my-accel
tile_budget_words = 8192
base_tile = 8x16x8

[layer conv3_1]
k = 1
s = 2
h = 56
w = 56
c_in = 64
c_out = 128
density = 0.45

[layer pw]   ; pointwise
k = 0
h = 28
w = 28
c_in = 512
";

    #[test]
    fn parses_hardware_and_layers() {
        let cfg = FileConfig::parse(SAMPLE).unwrap();
        let hw = cfg.hardware.unwrap();
        assert_eq!(hw.tile_budget_words, 8192);
        assert_eq!((hw.base_tile.th, hw.base_tile.tw, hw.base_tile.tc), (8, 16, 8));
        assert_eq!(cfg.layers.len(), 2);
        let c = &cfg.layers[0];
        assert_eq!(c.name, "conv3_1");
        assert_eq!((c.layer.k, c.layer.s, c.layer.h), (1, 2, 56));
        assert_eq!(c.layer.c_out, 128);
        assert!((c.density - 0.45).abs() < 1e-12);
        // Defaults: d=1, c_out=c_in, density=0.4.
        let p = &cfg.layers[1];
        assert_eq!(p.layer.d, 1);
        assert_eq!(p.layer.c_out, 512);
        assert!((p.density - 0.4).abs() < 1e-12);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = FileConfig::parse("# only comments\n\n; more\n").unwrap();
        assert!(cfg.hardware.is_none());
        assert!(cfg.layers.is_empty());
    }

    #[test]
    fn errors_are_located() {
        assert!(FileConfig::parse("[layer x]\nk = 1\n").is_err()); // missing h/w/c_in
        assert!(FileConfig::parse("key = 1\n").is_err()); // outside section
        assert!(FileConfig::parse("[bogus]\na = 1\n").is_err());
        assert!(FileConfig::parse("[layer]\nk = 1\n").is_err()); // unnamed
        assert!(FileConfig::parse("[layer x]\nk=1\nh=8\nw=8\nc_in=8\ndensity=1.5\n").is_err());
        assert!(FileConfig::parse("not a kv line\n").is_err());
    }

    #[test]
    fn hardware_or_falls_back() {
        let cfg = FileConfig::parse("[layer x]\nk=1\nh=8\nw=8\nc_in=8\n").unwrap();
        let hw = cfg.hardware_or(Platform::EyerissLargeTile);
        assert_eq!(hw.tile_budget_words, 16 * 1024);
    }

    #[test]
    fn custom_hardware_drives_tiling() {
        let cfg = FileConfig::parse(SAMPLE).unwrap();
        let hw = cfg.hardware.unwrap();
        let t = hw.tile_for_layer(&cfg.layers[0].layer);
        assert!(t.input_window_words(&cfg.layers[0].layer) <= 8192);
    }
}
