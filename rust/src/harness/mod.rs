//! The evaluation harness: regenerates every table and figure of the
//! paper's evaluation (§IV) plus the ablations DESIGN.md calls out.
//!
//! Each function returns a [`Table`] printing the same rows the paper
//! reports; the CLI (`gratetile <subcommand>`) and the bench targets
//! drive these, and every run also lands as CSV under `results/`.

pub mod ablation;
pub mod extended;
pub mod figures;
pub mod tables;
pub mod tuning;

pub use ablation::{ablation_codecs, ablation_dilated, ablation_sweep, ablation_whole_channel};
pub use tuning::{tune_study, tune_study_with, tune_table, TUNE_STUDY_NETWORKS};
pub use extended::{
    access_table, chaos_table, codec_datapath_table, gemm_table, metacache_table, network_table,
    roofline_table, serve_scaling_table, store_compare_table, trace_rollup_table,
};
pub use figures::{fig1, fig8, fig9};
pub use tables::{table1, table2, table3};
