//! Ablations beyond the paper's headline tables (DESIGN.md §6):
//! codec cost/benefit, the whole-channel limitation (§IV-B(3)), and a
//! modulus × compressor × sparsity sensitivity sweep.

use crate::compress::{CodecPolicy, Registry, Scheme};
use crate::config::hardware::Platform;
use crate::config::layer::ConvLayer;
use crate::config::zoo::{network_layers, Network};
use crate::sim::experiment::{bench_feature_map, run_layer};
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tiling::division::DivisionMode;
use crate::util::table::Table;

/// §V codec comparison: compression on the suite's operating point plus
/// the hardware cost proxy, with the per-sub-tensor adaptive policy
/// (`--codec auto`) as the final row (no single datapath cost applies —
/// an adaptive fetcher provisions every decoder).
pub fn ablation_codecs() -> Table {
    let mut t = Table::new("Ablation — compression codecs (§V)")
        .header(vec![
            "Codec",
            "Saving @ d=0.37 %",
            "Saving @ d=0.15 %",
            "Dec words/cycle (8 lanes)",
            "Area (kGates, 8 lanes)",
            "Words/cycle per kGate",
        ]);
    let hw = Platform::EyerissLargeTile.hardware();
    let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
    let saving = |policy: CodecPolicy, d: f64| {
        let fm = generate(56, 56, 64, SparsityParams::clustered(d, 31));
        run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, policy)
            .map(|r| format!("{:.1}", r.saving_with_meta() * 100.0))
            .unwrap_or("N/A".into())
    };
    for scheme in Registry::global().schemes() {
        let policy = CodecPolicy::Fixed(scheme);
        let cost = Registry::global().compressor(scheme).cost();
        t.row(vec![
            scheme.name().to_string(),
            saving(policy, 0.37),
            saving(policy, 0.15),
            format!("{:.1}", cost.decode_words_per_cycle(8)),
            format!("{:.1}", cost.area_gates(8) as f64 / 1000.0),
            if cost.area_gates(8) == 0 {
                "inf".to_string()
            } else {
                format!("{:.2}", cost.throughput_per_kgate(8))
            },
        ]);
    }
    t.row(vec![
        "auto".to_string(),
        saving(CodecPolicy::Adaptive, 0.37),
        saving(CodecPolicy::Adaptive, 0.15),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);
    // The auto-tuner as the final row: per-layer search over division ×
    // codec × order (see `crate::tune`), verified here through the same
    // independent pack-and-price path as every fixed row.
    let tuned = |d: f64| {
        let fm = generate(56, 56, 64, SparsityParams::clustered(d, 31));
        let r = crate::tune::Tuner::new(hw).tune_layer(&layer, &fm);
        run_layer(&hw, &layer, &fm, r.plan.mode, r.plan.policy)
            .map(|x| format!("{:.1}", x.saving_with_meta() * 100.0))
            .unwrap_or("N/A".into())
    };
    t.row(vec![
        "tuned".to_string(),
        tuned(0.37),
        tuned(0.15),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);
    t
}

/// §IV-B(3): the whole-channel-processing limitation. When the tile
/// covers the whole spatial map (AlexNet CONV5 / VGG CONV5_3-like
/// layers), GrateTile's extra cuts cost bandwidth vs not dividing.
pub fn ablation_whole_channel() -> Table {
    let mut t = Table::new(
        "Ablation — whole-channel processing (§IV-B(3) limitation)",
    )
    .header(vec!["Layer", "GrateTile mod 8 %", "WholeMap (no division) %", "Penalty pp"]);
    let hw = Platform::EyerissLargeTile.hardware();
    // The paper's examples: 13x13/14x14 maps where one uniform 16x16
    // sub-tensor would contain the whole input.
    let candidates: Vec<_> = [Network::AlexNet, Network::Vgg16]
        .iter()
        .flat_map(|&n| network_layers(n))
        .filter(|b| b.layer.h <= 16)
        .collect();
    for b in candidates {
        let fm = bench_feature_map(&b);
        let g = run_layer(&hw, &b.layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let w = run_layer(&hw, &b.layer, &fm, DivisionMode::WholeMap, Scheme::Bitmask);
        if let (Ok(g), Ok(w)) = (g, w) {
            t.row(vec![
                format!("{} {}", b.network.name(), b.name),
                format!("{:.1}", g.saving_with_meta() * 100.0),
                format!("{:.1}", w.saving_with_meta() * 100.0),
                format!("{:+.1}", (w.saving_with_meta() - g.saving_with_meta()) * 100.0),
            ]);
        }
    }
    t
}

/// Sensitivity sweep: modulus × codec × density (and iid vs clustered).
pub fn ablation_sweep() -> Table {
    let mut t = Table::new("Ablation — modulus x codec x density sweep (saving %, with metadata)")
        .header(vec!["Density", "Model", "Codec", "mod 4", "mod 8", "mod 16"]);
    let hw = Platform::EyerissLargeTile.hardware();
    let layer = ConvLayer::new(1, 1, 64, 64, 64, 64);
    for &density in &[0.15, 0.37, 0.60, 0.85] {
        for clustered in [true, false] {
            for scheme in [Scheme::Bitmask, Scheme::Zrlc] {
                let params = if clustered {
                    SparsityParams::clustered(density, 57)
                } else {
                    SparsityParams::iid(density, 57)
                };
                let fm = generate(64, 64, 64, params);
                let mut row = vec![
                    format!("{density:.2}"),
                    if clustered { "clustered" } else { "iid" }.to_string(),
                    scheme.name().to_string(),
                ];
                for n in [4usize, 8, 16] {
                    row.push(
                        run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n }, scheme)
                            .map(|r| format!("{:.1}", r.saving_with_meta() * 100.0))
                            .unwrap_or("N/A".into()),
                    );
                }
                t.row(row);
            }
        }
    }
    t
}

/// Dilated-conv configurations (§III-B / Fig. 6b): Eq. 1's dilated form
/// over a sweep of (k, s, d), verifying applicability and savings.
pub fn ablation_dilated() -> Table {
    let mut t = Table::new("Ablation — dilated convolutions (Fig. 6b)")
        .header(vec!["(k,s,d)", "Config", "Saving mod 8 %"]);
    let hw = Platform::EyerissLargeTile.hardware();
    for (k, s, d) in [(1usize, 1usize, 2usize), (1, 1, 4), (2, 1, 2), (1, 2, 2)] {
        let layer = ConvLayer::new(k, s, 64, 64, 64, 64).dilated(d);
        let tile = hw.tile_for_layer(&layer);
        let g = crate::tiling::grate::GrateConfig::for_axis(&layer, tile.th);
        let g8 = g.reduce(8);
        let fm = generate(64, 64, 64, SparsityParams::clustered(0.37, 91));
        let saving = run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
            .map(|r| format!("{:.1}", r.saving_with_meta() * 100.0))
            .unwrap_or("N/A".into());
        t.row(vec![
            format!("({},{},{})", 2 * k + 1, s, d),
            g8.map(|c| c.display()).unwrap_or_else(|| g.display()),
            saving,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_ablation_has_all_codecs_auto_and_tuned() {
        let csv = ablation_codecs().render_csv();
        for name in ["bitmask", "zrlc", "dictionary", "raw", "auto", "tuned"] {
            assert!(csv.contains(name), "{csv}");
        }
        // The auto row's saving must track the best fixed codec at both
        // densities: its payload is the per-sub-tensor min, and the tag
        // overhead is ~0.1pp of baseline at this geometry (plus up to
        // 0.1pp of display rounding on each side). The tuned row also
        // searches divisions, so it must track auto in turn.
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| {
                l.split(',')
                    .skip(1)
                    .take(2)
                    .map(|v| v.parse().unwrap_or(f64::NAN))
                    .collect()
            })
            .collect();
        let tuned = rows.last().unwrap();
        let auto = &rows[rows.len() - 2];
        for fixed in &rows[..rows.len() - 2] {
            for (&a, &f) in auto.iter().zip(fixed) {
                assert!(a >= f - 0.3, "auto {auto:?} vs fixed {fixed:?}");
            }
        }
        for (&t, &a) in tuned.iter().zip(auto) {
            assert!(t >= a - 0.3, "tuned {tuned:?} vs auto {auto:?}");
        }
    }

    /// §IV-B(3): not dividing must beat GrateTile on whole-map tiles —
    /// the paper quotes ~4% penalty.
    #[test]
    fn whole_channel_penalty_is_positive_and_small() {
        let t = ablation_whole_channel();
        let csv = t.render_csv();
        let mut found = 0;
        for line in csv.lines().skip(1) {
            let pp: f64 = line.split(',').next_back().unwrap().parse().unwrap();
            assert!(pp > -1.0, "whole-map should not lose: {line}");
            assert!(pp < 15.0, "penalty should be small: {line}");
            found += 1;
        }
        assert!(found >= 4, "need the AlexNet 13x13 and VGG 14x14 layers");
    }

    #[test]
    fn sweep_savings_decrease_with_density() {
        let csv = ablation_sweep().render_csv();
        // First and last bitmask/clustered rows: d=0.15 saves more than
        // d=0.85.
        let rows: Vec<&str> = csv
            .lines()
            .filter(|l| l.contains("clustered,bitmask"))
            .collect();
        let first: f64 = rows[0].split(',').nth(4).unwrap().parse().unwrap();
        let last: f64 = rows.last().unwrap().split(',').nth(4).unwrap().parse().unwrap();
        assert!(first > last + 20.0, "{first} vs {last}");
    }

    #[test]
    fn dilated_rows_present() {
        let csv = ablation_dilated().render_csv();
        assert!(csv.contains("(3,1,2)"));
        assert_eq!(csv.lines().count(), 5);
    }
}
