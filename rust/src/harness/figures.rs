//! Figures 1, 8 and 9 of the paper.

use crate::compress::CodecPolicy;
use crate::config::hardware::Platform;
use crate::config::zoo::Network;
use crate::power::{network_power, ArrayConfig, EnergyTable};
use crate::sim::experiment::{run_suite_shared, run_suites};
use crate::tiling::division::DivisionMode;
use crate::util::table::Table;

/// Fig. 1: power breakdown of the benchmark networks on a 16×16
/// systolic array (SCALE-sim methodology × Horowitz energies).
pub fn fig1() -> Table {
    let cfg = ArrayConfig::default();
    let energy = EnergyTable::default();
    let mut t = Table::new(
        "Fig. 1 — Power breakdown (16x16 systolic array, Horowitz 45nm energies)",
    )
    .header(vec![
        "Network",
        "MAC %",
        "DRAM feature read %",
        "DRAM weight read %",
        "DRAM output write %",
        "SRAM %",
        "Total (mJ)",
    ]);
    for net in Network::all() {
        let b = network_power(&cfg, &energy, net);
        let s = b.shares();
        t.row(vec![
            net.name().to_string(),
            format!("{:.1}", s[0] * 100.0),
            format!("{:.1}", s[1] * 100.0),
            format!("{:.1}", s[2] * 100.0),
            format!("{:.1}", s[3] * 100.0),
            format!("{:.1}", s[4] * 100.0),
            format!("{:.2}", b.total_pj() / 1e9),
        ]);
    }
    t
}

/// Fig. 8: overall (geomean) bandwidth reduction per division mode on
/// both platforms, with the optimal (zero-fraction) line.
pub fn fig8(policy: impl Into<CodecPolicy>) -> Table {
    let policy = policy.into();
    let modes = DivisionMode::table3_modes();
    let mut t = Table::new(&format!(
        "Fig. 8 — Overall bandwidth reduction (geomean, {} compression, with metadata)",
        policy.name()
    ))
    .header(vec!["Division mode", "NVIDIA %", "Eyeriss %"]);
    let hws = [
        Platform::NvidiaSmallTile.hardware(),
        Platform::EyerissLargeTile.hardware(),
    ];
    let suites = run_suites(&hws, &modes, policy);
    let fmt = |v: Option<f64>| v.map(|x| format!("{:.1}", x * 100.0)).unwrap_or("N/A".into());
    for (i, mode) in modes.iter().enumerate() {
        t.row(vec![
            mode.name(),
            fmt(suites[0].geomean_saving(i, true)),
            fmt(suites[1].geomean_saving(i, true)),
        ]);
    }
    t.row(vec![
        "Optimal (zero ratio)".to_string(),
        format!("{:.1}", suites[0].geomean_optimal() * 100.0),
        format!("{:.1}", suites[1].geomean_optimal() * 100.0),
    ]);
    t
}

/// Fig. 9a/b: per-layer bandwidth reduction breakdown for one platform.
pub fn fig9(platform: Platform, policy: impl Into<CodecPolicy>) -> Table {
    let policy = policy.into();
    let modes = DivisionMode::table3_modes();
    let suite = run_suite_shared(&platform.hardware(), &modes, policy);
    let sub = match platform {
        Platform::NvidiaSmallTile => "a) small tile platform (NVIDIA Volta)",
        Platform::EyerissLargeTile => "b) large tile platform (Eyeriss)",
    };
    let mut header = vec!["Layer".to_string(), "Optimal %".to_string()];
    header.extend(modes.iter().map(|m| m.name()));
    let mut t = Table::new(&format!(
        "Fig. 9{sub} — per-layer bandwidth reduction ({}, with metadata)",
        policy.name()
    ))
    .header(header);
    for (li, layer_name) in suite.layers.iter().enumerate() {
        let mut row = vec![layer_name.clone()];
        let density = suite
            .results
            .iter()
            .find_map(|m| m[li].as_ref())
            .map(|r| r.density)
            .unwrap_or(f64::NAN);
        row.push(format!("{:.1}", (1.0 - density) * 100.0));
        for (mi, _) in modes.iter().enumerate() {
            row.push(match &suite.results[mi][li] {
                Some(r) => format!("{:.1}", r.saving_with_meta() * 100.0),
                None => "N/A".into(),
            });
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_rows_for_all_networks() {
        let t = fig1();
        let csv = t.render_csv();
        for net in Network::all() {
            assert!(csv.contains(net.name()), "{csv}");
        }
        // Fig. 1 headline: DRAM feature read is the largest share for
        // the deeper networks.
        for line in csv.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let feature: f64 = cells[2].parse().unwrap();
            assert!(feature > 25.0, "{line}");
        }
    }
}
