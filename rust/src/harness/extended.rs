//! Extended studies beyond the paper's tables: whole-network traffic,
//! DRAM access efficiency, metadata caching, and the codec datapath.

use crate::compress::hwmodel::{decode_block, DecoderConfig};
use crate::compress::{CodecPolicy, Scheme};
use crate::config::hardware::Platform;
use crate::config::layer::ConvLayer;
use crate::config::zoo::{full_conv_stack, Network};
use crate::coordinator::simserver::{
    simulate, simulate_traced, ServingPolicy, SimServer, SimServerConfig,
};
use crate::coordinator::{PipelineConfig, Weights};
use crate::fault::FaultPlan;
use crate::layout::IntegrityPolicy;
use crate::obs::TraceRecorder;
use crate::sim::access::access_study;
use crate::sim::metacache::{metadata_cache_study, TileOrder};
use crate::sim::network::{depth_density, run_network_bandwidth, writeback_cost};
use crate::store::{StoreWriter, TensorStore};
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tiling::division::{Division, DivisionMode};
use crate::util::table::Table;

/// Whole-network fetch + write-back traffic per division mode.
pub fn network_table(policy: impl Into<CodecPolicy>) -> Table {
    let policy = policy.into();
    let hw = Platform::EyerissLargeTile.hardware();
    let mut t = Table::new(&format!(
        "Whole-network DRAM traffic saving ({} compression, Eyeriss, read+write)",
        policy.name()
    ))
    .header(vec!["Network", "GrateTile mod 8 %", "Uniform 8x8x8 %", "Uniform 4x4x8 %"]);
    for net in Network::all() {
        let cell = |mode| {
            let r = run_network_bandwidth(&hw, net, mode, policy, 17);
            format!("{:.1}", r.total_saving() * 100.0)
        };
        t.row(vec![
            net.name().to_string(),
            cell(DivisionMode::GrateTile { n: 8 }),
            cell(DivisionMode::Uniform { edge: 8 }),
            cell(DivisionMode::Uniform { edge: 4 }),
        ]);
    }
    t
}

/// Functional vs. analytic producer-side write-back, per network: each
/// intermediate map (same synthesis seed as [`network_table`]) is
/// streamed through the [`StoreWriter`] in 8-row tile bands, and the
/// report's exact bits are set against `sim::network::writeback_cost`'s
/// closed form. The Match column must read `exact` everywhere — the
/// functional store and the analytic simulator are one model.
pub fn store_compare_table(policy: impl Into<CodecPolicy>) -> Table {
    let policy = policy.into();
    let hw = Platform::EyerissLargeTile.hardware();
    let mode = DivisionMode::GrateTile { n: 8 };
    let mut t = Table::new(&format!(
        "Store write-back: functional (streamed) vs analytic bits ({}, GrateTile mod 8, Eyeriss)",
        policy.name()
    ))
    .header(vec![
        "Network",
        "Map",
        "Functional payload+meta bits",
        "Analytic payload+meta bits",
        "Meta %",
        "Match",
    ]);
    for net in Network::all() {
        let stack = full_conv_stack(net);
        let n = stack.len();
        for (i, layer) in stack.iter().enumerate().skip(1).take(2) {
            let density = depth_density(net, i, n);
            let fm = generate(
                layer.h,
                layer.w,
                layer.c_in,
                SparsityParams::clustered(density, 17 ^ (i as u64) << 8),
            );
            let Ok((payload, meta)) = writeback_cost(&hw, layer, &fm, mode, policy) else {
                continue;
            };
            let tile = hw.tile_for_layer(layer);
            let div = Division::build(mode, layer, &tile, &hw, fm.h, fm.w, fm.c)
                .expect("writeback_cost built the same division");
            let mut store = TensorStore::new();
            let mut w = StoreWriter::new(&mut store, "t", div, policy);
            for y0 in (0..fm.h).step_by(8) {
                let y1 = (y0 + 8).min(fm.h);
                let band = fm.extract_block(y0, 0, 0, y1 - y0, fm.w, fm.c);
                w.write_tile(y0, y1, 0, fm.w, 0, fm.c, &band);
            }
            let rep = w.finish().expect("full map streamed");
            let functional = rep.writeback_bits();
            let analytic = payload + meta;
            t.row(vec![
                net.name().to_string(),
                format!("conv{i} {}x{}x{}", fm.h, fm.w, fm.c),
                functional.to_string(),
                analytic.to_string(),
                format!("{:.2}", meta as f64 / payload as f64 * 100.0),
                if functional == analytic { "exact".into() } else { "MISMATCH".to_string() },
            ]);
        }
    }
    t
}

/// DRAM access-efficiency study (row hits, transactions, bus
/// efficiency) per division mode.
pub fn access_table() -> Table {
    let hw = Platform::EyerissLargeTile.hardware();
    let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
    let fm = generate(56, 56, 64, SparsityParams::clustered(0.37, 27));
    let mut t = Table::new(
        "DRAM access efficiency (56x56x64 layer, d=0.37; timed LPDDR4-class model)",
    )
    .header(vec!["Mode", "Transactions", "Row hit %", "Bus efficiency %"]);
    for mode in DivisionMode::table3_modes() {
        if let Ok(s) = access_study(&hw, &layer, &fm, mode, Scheme::Bitmask) {
            t.row(vec![
                mode.name(),
                format!("{}", s.requests),
                format!("{:.1}", s.row_hit_rate * 100.0),
                format!("{:.1}", s.bus_efficiency * 100.0),
            ]);
        }
    }
    t
}

/// Metadata cache study: absorption per mode × cache size × tile order.
pub fn metacache_table() -> Table {
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
    let fm = generate(56, 56, 64, SparsityParams::clustered(0.37, 29));
    let mut t = Table::new(
        "Metadata SRAM cache absorption (56x56x64 layer; % of metadata traffic served on-chip)",
    )
    .header(vec!["Mode", "1KB spatial", "4KB spatial", "4KB channel-major"]);
    for mode in [
        DivisionMode::GrateTile { n: 8 },
        DivisionMode::Uniform { edge: 8 },
        DivisionMode::Uniform { edge: 2 },
        DivisionMode::Uniform { edge: 1 },
    ] {
        let cell = |bytes: usize, order: TileOrder| {
            metadata_cache_study(&hw, &layer, &fm, mode, bytes, order)
                .map(|s| format!("{:.1}", s.absorbed() * 100.0))
                .unwrap_or("N/A".into())
        };
        t.row(vec![
            mode.name(),
            cell(1024, TileOrder::SpatialMajor),
            cell(4096, TileOrder::SpatialMajor),
            cell(4096, TileOrder::ChannelMajor),
        ]);
    }
    t
}

/// Codec datapath cycle study (hwmodel): words/cycle and stalls at 4/8/16
/// lanes for each codec.
pub fn codec_datapath_table() -> Table {
    let mut t = Table::new(
        "Codec decode datapath (cycle model; 512-word block at d=0.4)",
    )
    .header(vec!["Codec", "4 lanes w/cyc", "8 lanes w/cyc", "16 lanes w/cyc", "util @8"]);
    let mut rng = crate::util::SplitMix64::new(41);
    let data: Vec<f32> = (0..512)
        .map(|_| if rng.chance(0.4) { rng.next_f32() + 0.01 } else { 0.0 })
        .collect();
    for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
        let comp = scheme.build().compress(&data);
        let run = |lanes: usize| {
            decode_block(
                scheme,
                &DecoderConfig { lanes, fifo_words: 16 * lanes, fill_rate: 2.0 * lanes as f64 },
                &comp,
            )
        };
        let s8 = run(8);
        t.row(vec![
            scheme.name().to_string(),
            format!("{:.1}", run(4).words_per_cycle()),
            format!("{:.1}", s8.words_per_cycle()),
            format!("{:.1}", run(16).words_per_cycle()),
            format!("{:.0}%", s8.utilisation() * 100.0),
        ]);
    }
    t
}

/// Serve-scaling study: the discrete-event serving simulator swept over
/// workers × queue depth × input density. One functional pass per
/// density produces the request traces; every (workers, queue) cell
/// re-simulates the *same* traces under a fresh bank-contended DRAM, so
/// the table isolates scheduling/contention effects from data effects.
/// All quantities are simulated cycles — the table is deterministic and
/// golden-filed (`tests/golden.rs`).
pub fn serve_scaling_table() -> Table {
    let l1 = ConvLayer::new(1, 1, 24, 24, 8, 16);
    let l2 = ConvLayer::new(1, 2, 24, 24, 16, 8);
    let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
    let base = SimServerConfig::new(PipelineConfig::new(
        Platform::NvidiaSmallTile.hardware(),
    ));
    let server = SimServer::new(base, layers);
    let mut t = Table::new(
        "Serve scaling — discrete-event simulator, 2-layer 24x24 net, 12 requests (simulated cycles)",
    )
    .header(vec![
        "Density",
        "Workers",
        "Queue",
        "Makespan kcyc",
        "Req/Mcyc",
        "p50 kcyc",
        "p99 kcyc",
        "Queue p99 kcyc",
        "Row hit %",
    ]);
    for &density in &[0.25, 0.6] {
        let reqs = server.synthetic_requests(12, density, 11);
        let traces = server.functional_pass(&reqs).expect("functional pass");
        for &workers in &[1usize, 2, 4] {
            for &queue in &[2usize, 8] {
                let mut cfg = base;
                cfg.workers = workers;
                cfg.queue_depth = queue;
                let r = simulate(&cfg, &traces);
                t.row(vec![
                    format!("{density:.2}"),
                    workers.to_string(),
                    queue.to_string(),
                    format!("{:.1}", r.makespan_cycles as f64 / 1e3),
                    format!("{:.2}", r.throughput_rpmc()),
                    format!("{:.1}", r.latency_percentile(0.50) as f64 / 1e3),
                    format!("{:.1}", r.latency_percentile(0.99) as f64 / 1e3),
                    format!("{:.1}", r.queue_percentile(0.99) as f64 / 1e3),
                    format!("{:.1}", r.row_hit_rate() * 100.0),
                ]);
            }
        }
    }
    t
}

/// Chaos study: deterministic fault injection swept over fault rate ×
/// defense policy. Every cell re-runs the *functional* pass under a
/// seeded [`FaultPlan`] (payload bit-flips, metadata corruption, bank
/// spikes, worker stalls, arrival bursts) and re-simulates serving, so
/// the table shows what each defense layer actually buys:
///
/// * `none` — faults land undetected; the *Silent corrupt* column
///   counts requests whose output checksum silently diverged from the
///   fault-free reference.
/// * `verify+retry` — per-sub-tensor checksums verified on fetch, with
///   bounded re-fetch retries; transient faults heal, persistent ones
///   degrade gracefully to zero-filled sub-tensors (flagged, counted).
/// * `verify+shed` — additionally enables serving deadlines, retry
///   budgets and Batch-class overload shedding.
///
/// Fault decisions are pure hashes of (seed, site, request, address),
/// so every cell is byte-stable across hosts and `--jobs` — golden-filed
/// in `tests/golden.rs`.
pub fn chaos_table() -> Table {
    let l1 = ConvLayer::new(1, 1, 24, 24, 8, 16);
    let l2 = ConvLayer::new(1, 2, 24, 24, 16, 8);
    let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
    let base = SimServerConfig::new(PipelineConfig::new(
        Platform::NvidiaSmallTile.hardware(),
    ));
    // Fault-free reference outputs: silent corruption is any served
    // request whose checksum diverges from these without being flagged.
    let reference = SimServer::new(base, layers.clone());
    let reqs = reference.synthetic_requests(12, 0.4, 11);
    let clean: Vec<u64> = reference
        .functional_pass(&reqs)
        .expect("clean pass")
        .iter()
        .map(|t| t.output_checksum)
        .collect();
    let defended = ServingPolicy {
        deadline_cycles: 40_000_000,
        retry_budget: 1,
        shed_batch_on_overload: true,
        waiting_depth: 0,
    };
    let policies: [(&str, Option<IntegrityPolicy>, ServingPolicy); 3] = [
        ("none", None, ServingPolicy::default()),
        ("verify+retry", Some(IntegrityPolicy::default()), ServingPolicy::default()),
        ("verify+shed", Some(IntegrityPolicy::default()), defended),
    ];
    let mut t = Table::new(
        "Chaos study — seeded faults x defense policy, 2-layer 24x24 net, 12 requests (simulated cycles)",
    )
    .header(vec![
        "Fault rate",
        "Defense",
        "Completed",
        "Degraded",
        "Silent corrupt",
        "Shed",
        "Timed out",
        "Recovery %",
        "Goodput req/Mcyc",
        "p99 kcyc",
    ]);
    for &rate in &[0.0, 0.05, 0.2] {
        for (name, integrity, serving) in &policies {
            let mut cfg = base;
            cfg.pipeline.fault = Some(FaultPlan::uniform(97, rate));
            cfg.pipeline.integrity = *integrity;
            cfg.serving = *serving;
            let server = SimServer::new(cfg, layers.clone());
            let traces = server.functional_pass(&reqs).expect("chaos pass");
            let rep = simulate(server.cfg(), &traces);
            let silent = traces
                .iter()
                .enumerate()
                .filter(|(i, tr)| tr.output_checksum != clean[*i] && !tr.degraded())
                .count();
            let healed = rep.recovered_reads + rep.degraded_subtensors;
            let recovery = if healed > 0 {
                format!("{:.1}", rep.recovered_reads as f64 / healed as f64 * 100.0)
            } else {
                "-".to_string()
            };
            t.row(vec![
                format!("{rate:.2}"),
                name.to_string(),
                rep.completed.to_string(),
                rep.degraded_requests.to_string(),
                silent.to_string(),
                rep.shed.to_string(),
                rep.timed_out.to_string(),
                recovery,
                format!("{:.2}", rep.goodput_rpmc()),
                format!("{:.1}", rep.latency_percentile(0.99) as f64 / 1e3),
            ]);
        }
    }
    t
}

/// The golden trace scenario: run the serving simulator with tracing
/// enabled over a tiny fixed net and roll the recorded counter series
/// up into a table. Everything is simulated cycles computed from
/// functional-pass data, so the table is byte-stable across hosts and
/// `--jobs` — golden-filed in `tests/golden.rs` alongside the serving
/// report.
pub fn trace_rollup_table() -> Table {
    let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
    let l2 = ConvLayer::new(1, 2, 16, 16, 8, 8);
    let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
    let cfg = SimServerConfig::new(PipelineConfig::new(
        Platform::NvidiaSmallTile.hardware(),
    ));
    let server = SimServer::new(cfg, layers);
    let reqs = server.synthetic_requests(6, 0.5, 7);
    let traces = server.functional_pass(&reqs).expect("functional pass");
    let mut rec = TraceRecorder::enabled();
    simulate_traced(server.cfg(), &traces, &mut rec);
    rec.rollup_table()
}

/// Roofline: compute/memory bound per benchmark layer and the runtime
/// speedup GrateTile's bandwidth saving buys. The suite layers are too
/// large to run the GEMM backend in a study table, so the compute roof
/// is the analytic MAC count — *labelled* as an estimate per row
/// ([`gemm_table`] is the measured-count counterpart).
pub fn roofline_table(policy: impl Into<CodecPolicy>) -> Table {
    use crate::power::{roofline, Machine};
    use crate::sim::experiment::suite_feature_maps;
    let policy = policy.into();
    let machine = Machine::default();
    let hw = Platform::EyerissLargeTile.hardware();
    let mut t = Table::new(
        "Roofline — layer bound and runtime speedup from GrateTile mod 8 (Eyeriss)",
    )
    .header(vec!["Layer", "Bound (dense)", "Feature saving %", "MACs (source)", "Speedup"]);
    for (b, fm) in suite_feature_maps() {
        if let Ok(r) =
            roofline(&machine, &hw, &b.layer, fm, DivisionMode::GrateTile { n: 8 }, policy)
        {
            t.row(vec![
                format!("{} {}", b.network.name(), b.name),
                r.bound_dense().to_string(),
                format!("{:.1}", r.feature_saving * 100.0),
                format!("{} ({})", r.macs, r.mac_source.name()),
                format!("{:.2}x", r.speedup()),
            ]);
        }
    }
    t
}

/// GEMM compute-backend study: measured kernel work per layer × input
/// density × skip policy. Every cell runs the real backend — MAC
/// counts are kernel counters (not estimates), the skip columns are
/// the fetch/kernel elision counters, and `Bit-exact` asserts the
/// output against the direct-conv oracle word for word. Deterministic
/// (seeded inputs, no host parallelism) — golden-filed in
/// `tests/golden.rs`.
pub fn gemm_table() -> Table {
    use crate::compute::{GemmBackend, SkipPolicy};
    use crate::coordinator::conv::direct_conv_relu;
    let hw = Platform::NvidiaSmallTile.hardware();
    let layers = [
        ("conv3x3 24x24x16->16", ConvLayer::new(1, 1, 24, 24, 16, 16)),
        ("pointwise 16x16x32->8", ConvLayer::new(0, 1, 16, 16, 32, 8)),
        ("strided3x3 24x24x8->16", ConvLayer::new(1, 2, 24, 24, 8, 16)),
    ];
    let mut t = Table::new(
        "GEMM backend — measured MACs and zero-skip elision per layer x density x policy (Nvidia small-tile, GrateTile mod 8, bitmask)",
    )
    .header(vec![
        "Layer",
        "Density",
        "Policy",
        "MACs",
        "Dense MACs",
        "MAC red %",
        "Rows skipped",
        "Subtensors skipped",
        "Spans skipped",
        "Bit-exact",
    ]);
    for (name, layer) in &layers {
        for &density in &[0.1, 0.25, 0.6, 0.9] {
            let fm = generate(
                layer.h,
                layer.w,
                layer.c_in,
                SparsityParams::clustered(density, 31 ^ (layer.c_in as u64) << 4),
            );
            let w = Weights::random(layer, 13);
            let oracle = direct_conv_relu(layer, &w, &fm);
            for skip in SkipPolicy::all() {
                let run = GemmBackend::new(hw)
                    .with_skip(skip)
                    .conv_relu(layer, &w, &fm)
                    .expect("backend run");
                t.row(vec![
                    name.to_string(),
                    format!("{density:.2}"),
                    skip.name().to_string(),
                    run.stats.macs.to_string(),
                    run.stats.dense_macs.to_string(),
                    format!("{:.1}", run.stats.mac_reduction() * 100.0),
                    run.stats.skipped_rows.to_string(),
                    run.skipped_subtensors.to_string(),
                    run.skipped_spans.to_string(),
                    if run.out.as_slice() == oracle.as_slice() {
                        "exact".into()
                    } else {
                        "MISMATCH".to_string()
                    },
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_compare_table_is_exact_everywhere() {
        let csv = store_compare_table(Scheme::Bitmask).render_csv();
        assert!(csv.lines().count() > 4, "{csv}");
        assert!(!csv.contains("MISMATCH"), "{csv}");
        assert!(csv.contains("exact"));
    }

    /// Adaptive functional == analytic, tag bits included: the streamed
    /// writer's per-sub-tensor codec choices and 2-bit record tags must
    /// land on exactly the closed form's bits for every network map.
    #[test]
    fn store_compare_table_is_exact_under_adaptive() {
        let csv = store_compare_table(CodecPolicy::Adaptive).render_csv();
        assert!(csv.lines().count() > 4, "{csv}");
        assert!(!csv.contains("MISMATCH"), "{csv}");
        assert!(csv.contains("exact"));
    }

    #[test]
    fn access_table_has_all_applicable_modes() {
        let csv = access_table().render_csv();
        assert!(csv.contains("GrateTile (mod 8)"));
        assert!(csv.contains("Uniform 1x1x8"));
    }

    #[test]
    fn metacache_table_shows_gratetile_advantage() {
        let csv = metacache_table().render_csv();
        let row = csv.lines().find(|l| l.starts_with("GrateTile (mod 8)")).unwrap();
        let absorbed_4k: f64 = row.split(',').nth(2).unwrap().parse().unwrap();
        assert!(absorbed_4k > 80.0, "{row}");
    }

    #[test]
    fn roofline_table_finds_memory_bound_layers() {
        let csv = roofline_table(Scheme::Bitmask).render_csv();
        assert!(csv.contains("memory"), "{csv}");
        // Memory-bound sparse layers must show real speedup.
        let best: f64 = csv
            .lines()
            .skip(1)
            .filter(|l| l.contains("memory"))
            .map(|l| l.rsplit(',').next().unwrap().trim_end_matches('x').parse().unwrap())
            .fold(1.0, f64::max);
        assert!(best > 1.3, "best speedup {best}");
    }

    /// Every cell of the GEMM study is bit-exact against the oracle,
    /// and zero-skip strictly reduces measured MACs on sparse inputs.
    #[test]
    fn gemm_table_is_exact_and_skips_pay_off() {
        let csv = gemm_table().render_csv();
        // 3 layers x 4 densities x 3 policies + header.
        assert_eq!(csv.lines().count(), 37, "{csv}");
        assert!(!csv.contains("MISMATCH"), "{csv}");
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        for chunk in rows.chunks(3) {
            let [dense, vskip, zskip] = chunk else { panic!("policy triple") };
            let dm: u64 = dense[3].parse().unwrap();
            let vm: u64 = vskip[3].parse().unwrap();
            let zm: u64 = zskip[3].parse().unwrap();
            assert_eq!(dense[3], dense[4], "dense executes everything: {dense:?}");
            assert!(vm <= dm && zm <= vm, "skip ladder must be monotone: {chunk:?}");
            let density: f64 = dense[1].parse().unwrap();
            if density <= 0.25 {
                assert!(zm < dm, "sparse input must skip MACs: {chunk:?}");
            }
        }
    }

    #[test]
    fn serve_scaling_more_workers_never_slower() {
        let csv = serve_scaling_table().render_csv();
        // 2 densities x 3 worker counts x 2 queue depths + header.
        assert_eq!(csv.lines().count(), 13, "{csv}");
        // Within one (density, queue) slice, makespan is non-increasing
        // in the worker count.
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        for density in ["0.25", "0.60"] {
            for queue in ["2", "8"] {
                let makespans: Vec<f64> = rows
                    .iter()
                    .filter(|r| r[0] == density && r[2] == queue)
                    .map(|r| r[3].parse().unwrap())
                    .collect();
                assert_eq!(makespans.len(), 3);
                assert!(
                    makespans[0] >= makespans[1] && makespans[1] >= makespans[2],
                    "d={density} q={queue}: {makespans:?}"
                );
            }
        }
    }

    /// The chaos study's core claims: fault-free cells are clean
    /// (nothing degraded, nothing silently corrupt), the undefended
    /// column exposes silent corruption under faults, and *every*
    /// checksummed cell has zero silent corruption — integrity either
    /// heals the read or flags the request, it never lies.
    #[test]
    fn chaos_table_defenses_eliminate_silent_corruption() {
        let csv = chaos_table().render_csv();
        // 3 fault rates x 3 defense policies + header.
        assert_eq!(csv.lines().count(), 10, "{csv}");
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        for r in rows.iter().filter(|r| r[0] == "0.00") {
            assert_eq!(r[3], "0", "fault-free row degraded: {r:?}");
            assert_eq!(r[4], "0", "fault-free row silently corrupt: {r:?}");
            assert_eq!(r[6], "0", "fault-free row timed out: {r:?}");
        }
        let undefended = rows.iter().find(|r| r[0] == "0.20" && r[1] == "none").unwrap();
        assert!(
            undefended[4].parse::<u64>().unwrap() > 0,
            "undefended faults must corrupt silently: {undefended:?}"
        );
        for r in rows.iter().filter(|r| r[1] != "none") {
            assert_eq!(r[4], "0", "checksummed cell silently corrupt: {r:?}");
        }
        // At a nonzero fault rate the verify path must show recoveries.
        let verified = rows.iter().find(|r| r[0] == "0.20" && r[1] == "verify+retry").unwrap();
        assert_ne!(verified[7], "-", "verify cell must report recovery: {verified:?}");
    }

    #[test]
    fn codec_datapath_bitmask_scales() {
        let csv = codec_datapath_table().render_csv();
        let row = csv.lines().find(|l| l.starts_with("bitmask")).unwrap();
        let c4: f64 = row.split(',').nth(1).unwrap().parse().unwrap();
        let c16: f64 = row.split(',').nth(3).unwrap().parse().unwrap();
        assert!(c16 > 2.0 * c4, "{row}");
    }
}
