//! Tables I–III of the paper.

use crate::compress::CodecPolicy;
use crate::config::hardware::Platform;
use crate::config::layer::ConvLayer;
use crate::layout::metadata::{metadata_bits_per_kb, metadata_overhead_fraction};
use crate::sim::experiment::run_suites;
use crate::tiling::division::DivisionMode;
use crate::tiling::grate::GrateConfig;
use crate::util::table::Table;

/// Table I: processing tile shapes and GrateTile configurations for the
/// (kernel, stride) classes of the benchmark networks.
pub fn table1() -> Table {
    let mut t = Table::new("Table I — GrateTile configurations used in our experiments")
        .header(vec![
            "CNN type (kernel,stride)",
            "Tile (NVIDIA)",
            "Tile (Eyeriss)",
            "GrateTile configuration",
        ]);
    let classes: [(usize, usize); 3] = [(1, 1), (1, 2), (2, 1)];
    for (k, s) in classes {
        let layer = ConvLayer::new(k, s, 224, 224, 64, 64);
        let tiles: Vec<String> = [Platform::NvidiaSmallTile, Platform::EyerissLargeTile]
            .iter()
            .map(|p| {
                let hw = p.hardware();
                let tile = hw.tile_for_layer(&layer);
                format!("{}x{}x{}", tile.in_h(&layer), tile.in_w(&layer), tile.tc)
            })
            .collect();
        // Mod-8 configuration (the paper's recommended hardware modulus).
        let hw = Platform::NvidiaSmallTile.hardware();
        let tile = hw.tile_for_layer(&layer);
        let g = GrateConfig::for_axis(&layer, tile.th).reduce(8).unwrap();
        t.row(vec![
            format!("({},{})", 2 * k + 1, s),
            tiles[0].clone(),
            tiles[1].clone(),
            g.display(),
        ]);
    }
    t
}

/// Table II: metadata size per KB of feature map, per division mode.
pub fn table2() -> Table {
    let hw = Platform::NvidiaSmallTile.hardware();
    let mut t = Table::new("Table II — Feature map metadata overhead")
        .header(vec!["Subdivision mode", "Bits per KB feature map", "Percentage"]);
    for mode in DivisionMode::table3_modes() {
        t.row(vec![
            mode.name(),
            format!("{:.0}", metadata_bits_per_kb(mode, &hw)),
            format!("{:.2}%", metadata_overhead_fraction(mode, &hw) * 100.0),
        ]);
    }
    t
}

/// Table III: bandwidth saved with/without metadata overhead on both
/// platforms, full benchmark suite.
pub fn table3(policy: impl Into<CodecPolicy>) -> Table {
    let policy = policy.into();
    let mut t = Table::new(&format!(
        "Table III — Impact of metadata on bandwidth reduction ({} compression)",
        policy.name()
    ))
    .header(vec![
        "Division mode",
        "w/o ovh NVIDIA",
        "w/o ovh Eyeriss",
        "with ovh NVIDIA",
        "with ovh Eyeriss",
    ]);
    let modes = DivisionMode::table3_modes();
    // One pool over (platform × mode × layer): 2 × 7 × 23 pricing units.
    let hws = [
        Platform::NvidiaSmallTile.hardware(),
        Platform::EyerissLargeTile.hardware(),
    ];
    let suites = run_suites(&hws, &modes, policy);
    let fmt = |v: Option<f64>| {
        v.map(|x| format!("{:.1}", x * 100.0)).unwrap_or_else(|| "N/A (a)".into())
    };
    for (i, mode) in modes.iter().enumerate() {
        t.row(vec![
            mode.name(),
            fmt(suites[0].geomean_saving(i, false)),
            fmt(suites[1].geomean_saving(i, false)),
            fmt(suites[0].geomean_saving(i, true)),
            fmt(suites[1].geomean_saving(i, true)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I must literally reproduce the paper's cells.
    #[test]
    fn table1_matches_paper() {
        let t = table1();
        let csv = t.render_csv();
        assert!(csv.contains("(3,1),10x18x8,18x18x16,G = {1,7} (mod 8)"), "{csv}");
        assert!(csv.contains("(3,2),9x17x8,17x17x16,G = {0,7} (mod 8)"), "{csv}");
        assert!(csv.contains("(5,1),12x20x8,20x20x16,G = {2,6} (mod 8)"), "{csv}");
    }

    /// Table II must reproduce the paper's bits-per-KB column.
    #[test]
    fn table2_matches_paper() {
        let csv = table2().render_csv();
        for expect in [
            "GrateTile (mod 4),192,2.34%",
            "GrateTile (mod 8),48,0.59%",
            "GrateTile (mod 16),12,0.15%",
            "Uniform 8x8x8,28,0.34%",
            "Uniform 4x4x8,112,1.37%",
            "Uniform 2x2x8,448,5.47%",
            "Uniform 1x1x8,2048,25.00%",
        ] {
            assert!(csv.contains(expect), "missing {expect} in\n{csv}");
        }
    }
}
