//! The `gratetile tune` study: per-layer tuned plans vs the fixed
//! presets, over the benchmark layer zoo.
//!
//! Each row is one zoo layer: the default plan's priced cost, the best
//! fixed preset (any Table III division × any codec policy), the tuned
//! plan the branch-and-bound search found, its priced fetch/metadata
//! split, the saving over the best preset, and the search accounting
//! (nodes priced, nodes pruned, memo hits). The emitted
//! [`TunedManifest`] is the machine half of the same study — what
//! `store pack --tuned` and the serving simulator consume.

use crate::config::hardware::Platform;
use crate::config::zoo::{network_layers, Network};
use crate::sim::experiment::bench_feature_map;
use crate::tune::{feature_map_sig, TunedManifest, Tuner};
use crate::util::table::Table;

/// The networks the default study covers. AlexNet + ResNet-18 span
/// small ragged maps, strides and pointwise layers while keeping the
/// cold-search cost CI-friendly (the VGG/VDSR maps are megaword-scale).
pub const TUNE_STUDY_NETWORKS: &[Network] = &[Network::AlexNet, Network::ResNet18];

/// Run the tuning study over `networks` with a caller-owned [`Tuner`]:
/// repeated layer specs — within this call or remembered from earlier
/// studies on the same tuner — are memo hits (`memo` column, zero
/// nodes). Returns the rendered table plus the tuned manifest.
pub fn tune_study_with(tuner: &mut Tuner, networks: &[Network]) -> (Table, TunedManifest) {
    let mut t = Table::new("Auto-tuned plans vs fixed presets (priced bits)").header(vec![
        "Layer",
        "d",
        "default bits",
        "best preset",
        "preset bits",
        "tuned plan",
        "fetch bits",
        "meta bits",
        "vs preset %",
        "nodes",
        "pruned",
        "memo",
    ]);
    let mut manifest = TunedManifest::default();
    for &net in networks {
        for b in network_layers(net) {
            let fm = bench_feature_map(&b);
            let r = tuner.tune_layer(&b.layer, &fm);
            let name = format!("{}.{}", net.name(), b.name);
            manifest.entries.push((name.clone(), r.entry(feature_map_sig(&fm))));
            let total = r.total_bits();
            let delta = if r.best_preset_total == 0 {
                "0.00".to_string()
            } else {
                format!(
                    "{:+.2}",
                    (total as f64 - r.best_preset_total as f64) / r.best_preset_total as f64
                        * 100.0
                )
            };
            t.row(vec![
                name,
                format!("{:.2}", b.density),
                r.default_total.to_string(),
                r.best_preset.key(),
                r.best_preset_total.to_string(),
                r.plan.key(),
                r.cost.fetched_bits.to_string(),
                r.cost.metadata_bits.to_string(),
                delta,
                r.nodes.to_string(),
                r.pruned.to_string(),
                if r.memo_hit { "hit" } else { "-" }.to_string(),
            ]);
        }
    }
    (t, manifest)
}

/// The study with a fresh tuner on the Eyeriss-class platform (what the
/// CLI and the golden fixture run).
pub fn tune_study(networks: &[Network]) -> (Table, TunedManifest) {
    let mut tuner = Tuner::new(Platform::EyerissLargeTile.hardware());
    tune_study_with(&mut tuner, networks)
}

/// The default study ([`TUNE_STUDY_NETWORKS`]).
pub fn tune_table() -> Table {
    tune_study(TUNE_STUDY_NETWORKS).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_rows_never_worse_and_warm_rerun_is_all_memo_hits() {
        let mut tuner = Tuner::new(Platform::EyerissLargeTile.hardware());
        let (cold, m_cold) = tune_study_with(&mut tuner, &[Network::AlexNet]);
        let csv = cold.render_csv();
        for line in csv.lines().skip(1) {
            let cols: Vec<&str> = line.split(',').collect();
            let default: u64 = cols[2].parse().unwrap();
            let preset: u64 = cols[4].parse().unwrap();
            let fetch: u64 = cols[6].parse().unwrap();
            let meta: u64 = cols[7].parse().unwrap();
            assert!(fetch + meta <= preset, "tuned worse than best preset: {line}");
            assert!(preset <= default, "best preset worse than default: {line}");
            assert_eq!(cols[11], "-", "cold pass must not memo-hit: {line}");
        }
        // Same tuner, same study: every layer is a memo hit with zero
        // search nodes, and the manifest bytes are identical.
        let (warm, m_warm) = tune_study_with(&mut tuner, &[Network::AlexNet]);
        for line in warm.render_csv().lines().skip(1) {
            let cols: Vec<&str> = line.split(',').collect();
            assert_eq!(cols[11], "hit", "warm pass must memo-hit: {line}");
            assert_eq!(cols[9], "0", "memo hits price no nodes: {line}");
        }
        assert_eq!(m_cold.render(), m_warm.render());
        assert_eq!(tuner.memo_hits, 4);
        // The manifest round-trips through its text form.
        assert_eq!(TunedManifest::parse(&m_cold.render()).unwrap(), m_cold);
    }
}
