//! Sparsity statistics over feature maps and their sub-blocks.

use super::dense::FeatureMap;

/// Summary statistics of the zero structure of a feature map.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityStats {
    pub words: usize,
    pub nonzeros: usize,
    /// Per-8×8×8-block densities (row-major over blocks).
    pub block_densities: Vec<f64>,
}

impl SparsityStats {
    /// Compute stats with the given block edge (spatial) and depth.
    pub fn compute(fm: &FeatureMap, block_edge: usize, block_depth: usize) -> Self {
        let nonzeros = fm.as_slice().iter().filter(|&&v| v != 0.0).count();
        let mut block_densities = Vec::new();
        let mut by = 0;
        while by < fm.h {
            let bh = block_edge.min(fm.h - by);
            let mut bx = 0;
            while bx < fm.w {
                let bw = block_edge.min(fm.w - bx);
                let mut bc0 = 0;
                while bc0 < fm.c {
                    let bc = block_depth.min(fm.c - bc0);
                    let blk = fm.extract_block(by, bx, bc0, bh, bw, bc);
                    let nnz = blk.iter().filter(|&&v| v != 0.0).count();
                    block_densities.push(nnz as f64 / blk.len() as f64);
                    bc0 += bc;
                }
                bx += bw;
            }
            by += bh;
        }
        Self { words: fm.words(), nonzeros, block_densities }
    }

    pub fn density(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.nonzeros as f64 / self.words as f64
        }
    }

    /// Mean of per-block densities.
    pub fn block_density_mean(&self) -> f64 {
        if self.block_densities.is_empty() {
            return 0.0;
        }
        self.block_densities.iter().sum::<f64>() / self.block_densities.len() as f64
    }

    /// Variance of per-block densities (clustering indicator).
    pub fn block_density_var(&self) -> f64 {
        if self.block_densities.is_empty() {
            return 0.0;
        }
        let m = self.block_density_mean();
        self.block_densities.iter().map(|d| (d - m).powi(2)).sum::<f64>()
            / self.block_densities.len() as f64
    }

    /// Fraction of blocks that are entirely zero (free wins for any
    /// compressor with a per-block size field).
    pub fn all_zero_block_fraction(&self) -> f64 {
        if self.block_densities.is_empty() {
            return 0.0;
        }
        self.block_densities.iter().filter(|&&d| d == 0.0).count() as f64
            / self.block_densities.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::sparsity::{generate, SparsityParams};

    #[test]
    fn stats_on_zero_map() {
        let fm = FeatureMap::zeros(16, 16, 8);
        let s = SparsityStats::compute(&fm, 8, 8);
        assert_eq!(s.density(), 0.0);
        assert_eq!(s.all_zero_block_fraction(), 1.0);
        assert_eq!(s.block_densities.len(), 4);
    }

    #[test]
    fn stats_on_dense_map() {
        let fm = FeatureMap::from_vec(8, 8, 8, vec![1.0; 512]);
        let s = SparsityStats::compute(&fm, 8, 8);
        assert_eq!(s.density(), 1.0);
        assert_eq!(s.all_zero_block_fraction(), 0.0);
        assert_eq!(s.block_densities, vec![1.0]);
    }

    #[test]
    fn block_mean_tracks_global_density() {
        let fm = generate(32, 32, 8, SparsityParams::iid(0.37, 3));
        let s = SparsityStats::compute(&fm, 8, 8);
        assert!((s.block_density_mean() - s.density()).abs() < 1e-9);
    }

    #[test]
    fn ragged_edges_are_covered() {
        // 13x13x384-style non-multiple geometry must still partition.
        let fm = FeatureMap::from_vec(13, 13, 12, vec![1.0; 13 * 13 * 12]);
        let s = SparsityStats::compute(&fm, 8, 8);
        // Blocks: 2x2 spatial x 2 channel groups = 8.
        assert_eq!(s.block_densities.len(), 8);
        assert_eq!(s.density(), 1.0);
    }
}
