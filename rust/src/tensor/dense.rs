//! Dense H×W×C feature map.
//!
//! Storage layout is channel-minor (HWC): `data[(y*w + x)*c + ch]`. This
//! matches the paper's storage unit — a sub-tensor is a contiguous-ish
//! spatial patch over a channel group — and makes per-block extraction a
//! strided copy.
//!
//! Values are `f32` in the API but quantised to bf16 on ingest so that
//! compression round-trips are exact at the 16-bit word granularity the
//! simulator uses (paper §IV-A: 8-word = 128-bit alignment → 16-bit
//! words).

/// Quantise an `f32` to bf16 (round-to-nearest-even) and back.
#[inline]
pub fn bf16_quantise(x: f32) -> f32 {
    let bits = x.to_bits();
    // Round to nearest even on the truncated 16 mantissa bits.
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// Encode an f32 as a bf16 word (upper 16 bits, RNE).
#[inline]
pub fn bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    (rounded >> 16) as u16
}

/// Decode a bf16 word to f32.
#[inline]
pub fn bf16_from_bits(w: u16) -> f32 {
    f32::from_bits((w as u32) << 16)
}

/// A dense feature map of shape `h × w × c`, HWC layout, bf16-quantised.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    data: Vec<f32>,
}

impl FeatureMap {
    /// All-zero map.
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0.0; h * w * c] }
    }

    /// Build from raw values (len must be `h*w*c`); quantises to bf16.
    pub fn from_vec(h: usize, w: usize, c: usize, mut data: Vec<f32>) -> Self {
        assert_eq!(data.len(), h * w * c, "shape/data mismatch");
        for v in &mut data {
            *v = bf16_quantise(*v);
        }
        Self { h, w, c, data }
    }

    /// Total elements (= words; 1 word per element).
    pub fn words(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn index(&self, y: usize, x: usize, ch: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[self.index(y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: f32) {
        let i = self.index(y, x, ch);
        self.data[i] = bf16_quantise(v);
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Nonzero fraction.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let nnz = self.data.iter().filter(|&&v| v != 0.0).count();
        nnz as f64 / self.data.len() as f64
    }

    /// Extract a spatial×channel block `[y0,y0+bh) × [x0,x0+bw) ×
    /// [c0,c0+bc)` into a row-major (bh,bw,bc) vector. The block must be
    /// fully inside the map.
    pub fn extract_block(
        &self,
        y0: usize,
        x0: usize,
        c0: usize,
        bh: usize,
        bw: usize,
        bc: usize,
    ) -> Vec<f32> {
        assert!(y0 + bh <= self.h && x0 + bw <= self.w && c0 + bc <= self.c);
        let mut out = Vec::with_capacity(bh * bw * bc);
        for y in y0..y0 + bh {
            for x in x0..x0 + bw {
                let base = (y * self.w + x) * self.c + c0;
                out.extend_from_slice(&self.data[base..base + bc]);
            }
        }
        out
    }

    /// Extract a block into a preallocated buffer (hot-path variant;
    /// avoids per-block allocation in the packer). `out` is truncated
    /// and refilled.
    pub fn extract_block_into(
        &self,
        y0: usize,
        x0: usize,
        c0: usize,
        bh: usize,
        bw: usize,
        bc: usize,
        out: &mut Vec<f32>,
    ) {
        assert!(y0 + bh <= self.h && x0 + bw <= self.w && c0 + bc <= self.c);
        out.clear();
        out.reserve(bh * bw * bc);
        for y in y0..y0 + bh {
            for x in x0..x0 + bw {
                let base = (y * self.w + x) * self.c + c0;
                out.extend_from_slice(&self.data[base..base + bc]);
            }
        }
    }

    /// Write a block back (inverse of [`FeatureMap::extract_block`]).
    pub fn write_block(
        &mut self,
        y0: usize,
        x0: usize,
        c0: usize,
        bh: usize,
        bw: usize,
        bc: usize,
        block: &[f32],
    ) {
        assert_eq!(block.len(), bh * bw * bc);
        let mut i = 0;
        for y in y0..y0 + bh {
            for x in x0..x0 + bw {
                let base = (y * self.w + x) * self.c + c0;
                self.data[base..base + bc].copy_from_slice(&block[i..i + bc]);
                i += bc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_roundtrip_is_idempotent() {
        for &x in &[0.0f32, 1.0, -2.5, 3.1415926, 1e-20, 1e20, -0.0] {
            let q = bf16_quantise(x);
            assert_eq!(bf16_quantise(q), q, "quantise must be idempotent for {x}");
            assert_eq!(bf16_from_bits(bf16_bits(q)), q);
        }
    }

    #[test]
    fn bf16_zero_stays_zero() {
        assert_eq!(bf16_quantise(0.0), 0.0);
        assert_eq!(bf16_bits(0.0), 0);
    }

    #[test]
    fn indexing_and_accessors() {
        let mut fm = FeatureMap::zeros(4, 5, 3);
        fm.set(2, 3, 1, 7.5);
        assert_eq!(fm.get(2, 3, 1), 7.5);
        assert_eq!(fm.get(0, 0, 0), 0.0);
        assert_eq!(fm.words(), 60);
    }

    #[test]
    fn density_counts_nonzeros() {
        let mut fm = FeatureMap::zeros(2, 2, 2);
        fm.set(0, 0, 0, 1.0);
        fm.set(1, 1, 1, 2.0);
        assert!((fm.density() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn block_extract_write_roundtrip() {
        let mut fm = FeatureMap::zeros(8, 8, 4);
        let mut v = 0.0f32;
        for y in 0..8 {
            for x in 0..8 {
                for ch in 0..4 {
                    fm.set(y, x, ch, v);
                    v += 0.25;
                }
            }
        }
        let block = fm.extract_block(2, 3, 1, 4, 2, 2);
        assert_eq!(block.len(), 4 * 2 * 2);
        assert_eq!(block[0], fm.get(2, 3, 1));
        let mut fm2 = FeatureMap::zeros(8, 8, 4);
        fm2.write_block(2, 3, 1, 4, 2, 2, &block);
        for y in 2..6 {
            for x in 3..5 {
                for ch in 1..3 {
                    assert_eq!(fm2.get(y, x, ch), fm.get(y, x, ch));
                }
            }
        }
    }

    #[test]
    fn extract_block_into_matches_extract_block() {
        let fm = FeatureMap::from_vec(4, 4, 2, (0..32).map(|i| i as f32).collect());
        let a = fm.extract_block(1, 1, 0, 2, 3, 2);
        let mut b = Vec::new();
        fm.extract_block_into(1, 1, 0, 2, 3, 2, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_block_panics() {
        let fm = FeatureMap::zeros(4, 4, 2);
        let _ = fm.extract_block(3, 3, 0, 2, 2, 2);
    }
}
