//! Synthetic sparse feature-map generation.
//!
//! ReLU activations are not i.i.d.-sparse: zeros cluster spatially (a
//! dark image region silences whole patches across many channels) and
//! per-channel densities vary. Compression studies are sensitive to this
//! clustering — i.i.d. masks *understate* per-block density variance and
//! therefore understate what bitmask/ZRLC can save on the best blocks —
//! so the generator supports both models and the benchmarks default to
//! the clustered one (DESIGN.md §2 substitution note).

use super::dense::FeatureMap;
use crate::util::SplitMix64;

/// Which spatial statistics the zero mask follows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SparsityModel {
    /// Independent Bernoulli per element.
    Iid,
    /// Spatially clustered: a low-resolution Perlin-like activation field
    /// shared across channel groups is thresholded to hit the target
    /// density; mimics ReLU maps.
    Clustered {
        /// Spatial correlation length in pixels (blob size).
        scale: usize,
    },
}

/// Parameters for synthetic generation.
#[derive(Debug, Clone, Copy)]
pub struct SparsityParams {
    /// Target nonzero fraction.
    pub density: f64,
    pub model: SparsityModel,
    pub seed: u64,
}

impl SparsityParams {
    pub fn clustered(density: f64, seed: u64) -> Self {
        Self { density, model: SparsityModel::Clustered { scale: 4 }, seed }
    }

    pub fn iid(density: f64, seed: u64) -> Self {
        Self { density, model: SparsityModel::Iid, seed }
    }
}

/// Generate an `h × w × c` feature map with the requested sparsity.
/// Nonzero values are positive (post-ReLU) with a decaying magnitude
/// distribution.
pub fn generate(h: usize, w: usize, c: usize, p: SparsityParams) -> FeatureMap {
    let mut rng = SplitMix64::new(p.seed);
    match p.model {
        SparsityModel::Iid => {
            let data = (0..h * w * c)
                .map(|_| {
                    if rng.chance(p.density) {
                        relu_magnitude(&mut rng)
                    } else {
                        0.0
                    }
                })
                .collect();
            FeatureMap::from_vec(h, w, c, data)
        }
        SparsityModel::Clustered { scale } => generate_clustered(h, w, c, p, scale, &mut rng),
    }
}

/// Post-ReLU magnitude model: exponential-ish positive values.
fn relu_magnitude(rng: &mut SplitMix64) -> f32 {
    let u = rng.next_f32().max(1e-6);
    // -ln(u) gives an Exp(1) draw; scale into a typical activation range.
    (-u.ln()) * 0.5 + 0.01
}

/// Clustered model: bilinear-upsampled random field + per-element jitter,
/// thresholded at the empirical quantile to hit the target density.
fn generate_clustered(
    h: usize,
    w: usize,
    c: usize,
    p: SparsityParams,
    scale: usize,
    rng: &mut SplitMix64,
) -> FeatureMap {
    let scale = scale.max(1);
    let gh = h.div_ceil(scale) + 1;
    let gw = w.div_ceil(scale) + 1;
    // A coarse field per channel *group* of 8 (channels within a group
    // share spatial structure, as convolution outputs do).
    let groups = c.div_ceil(8);
    let mut fields: Vec<Vec<f32>> = Vec::with_capacity(groups);
    for _ in 0..groups {
        fields.push((0..gh * gw).map(|_| rng.next_f32()).collect());
    }

    // Score every element: coarse field (bilinear) + fine jitter.
    let mut scores = vec![0.0f32; h * w * c];
    for y in 0..h {
        let fy = y as f32 / scale as f32;
        let y0 = fy.floor() as usize;
        let ty = fy - y0 as f32;
        for x in 0..w {
            let fx = x as f32 / scale as f32;
            let x0 = fx.floor() as usize;
            let tx = fx - x0 as f32;
            for ch in 0..c {
                let f = &fields[ch / 8];
                let at = |yy: usize, xx: usize| f[yy.min(gh - 1) * gw + xx.min(gw - 1)];
                let coarse = at(y0, x0) * (1.0 - ty) * (1.0 - tx)
                    + at(y0 + 1, x0) * ty * (1.0 - tx)
                    + at(y0, x0 + 1) * (1.0 - ty) * tx
                    + at(y0 + 1, x0 + 1) * ty * tx;
                let jitter = rng.next_f32();
                scores[(y * w + x) * c + ch] = 0.7 * coarse + 0.3 * jitter;
            }
        }
    }

    // Threshold at the (1 - density) quantile. Perf (§Perf): estimated
    // from a 64K sample with select_nth instead of sorting the full
    // score array — the sampling error on the realised density is
    // ~0.3%, far below the generator's tolerance, and generation of a
    // VDSR-sized map drops ~5x.
    let cut = {
        const SAMPLE: usize = 1 << 16;
        let mut sample: Vec<f32> = if scores.len() <= SAMPLE {
            scores.clone()
        } else {
            let mut srng = rng.split();
            (0..SAMPLE).map(|_| scores[srng.below(scores.len())]).collect()
        };
        let cut_idx = ((1.0 - p.density) * (sample.len() as f64 - 1.0)).round() as usize;
        let cut_idx = cut_idx.min(sample.len() - 1);
        *sample
            .select_nth_unstable_by(cut_idx, |a, b| a.partial_cmp(b).unwrap())
            .1
    };

    let data = scores
        .iter()
        .map(|&s| if s > cut { relu_magnitude(rng) } else { 0.0 })
        .collect();
    FeatureMap::from_vec(h, w, c, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_density_is_close_to_target() {
        let fm = generate(64, 64, 16, SparsityParams::iid(0.4, 1));
        assert!((fm.density() - 0.4).abs() < 0.02, "density {}", fm.density());
    }

    #[test]
    fn clustered_density_is_close_to_target() {
        for &d in &[0.1, 0.35, 0.6, 0.9] {
            let fm = generate(64, 64, 16, SparsityParams::clustered(d, 2));
            assert!((fm.density() - d).abs() < 0.03, "target {d} got {}", fm.density());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(16, 16, 8, SparsityParams::clustered(0.5, 7));
        let b = generate(16, 16, 8, SparsityParams::clustered(0.5, 7));
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(16, 16, 8, SparsityParams::iid(0.5, 7));
        let b = generate(16, 16, 8, SparsityParams::iid(0.5, 8));
        assert_ne!(a, b);
    }

    #[test]
    fn nonzeros_are_positive_post_relu() {
        let fm = generate(32, 32, 8, SparsityParams::clustered(0.5, 3));
        assert!(fm.as_slice().iter().all(|&v| v >= 0.0));
        assert!(fm.as_slice().iter().any(|&v| v > 0.0));
    }

    /// Clustered masks must have higher per-block density variance than
    /// iid — that is the property the model exists to provide.
    #[test]
    fn clustered_has_higher_block_variance_than_iid() {
        let var_of = |fm: &FeatureMap| {
            let mut vars = Vec::new();
            for by in (0..fm.h).step_by(8) {
                for bx in (0..fm.w).step_by(8) {
                    let blk = fm.extract_block(by, bx, 0, 8, 8, fm.c);
                    let d = blk.iter().filter(|&&v| v != 0.0).count() as f64
                        / blk.len() as f64;
                    vars.push(d);
                }
            }
            let m = vars.iter().sum::<f64>() / vars.len() as f64;
            vars.iter().map(|v| (v - m).powi(2)).sum::<f64>() / vars.len() as f64
        };
        let iid = generate(64, 64, 8, SparsityParams::iid(0.4, 5));
        let cl = generate(64, 64, 8, SparsityParams::clustered(0.4, 5));
        assert!(
            var_of(&cl) > 2.0 * var_of(&iid),
            "clustered {} vs iid {}",
            var_of(&cl),
            var_of(&iid)
        );
    }
}
