//! Dense feature-map container and synthetic sparsity generation.

pub mod dense;
pub mod sparsity;
pub mod stats;

pub use dense::FeatureMap;
pub use sparsity::{SparsityModel, SparsityParams};
pub use stats::SparsityStats;
