//! Line-granular DRAM model with per-stream counters.

use crate::config::hardware::WORDS_PER_LINE;

/// Traffic streams, matching the Fig. 1 power-breakdown categories plus
/// the producer-side index stream (the paper bounds GrateTile metadata
/// at 0.6% of feature traffic — written as well as read).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    FeatureRead,
    WeightRead,
    OutputWrite,
    MetadataRead,
    MetadataWrite,
}

const N_STREAMS: usize = 5;

impl Stream {
    pub const ALL: [Stream; N_STREAMS] = [
        Stream::FeatureRead,
        Stream::WeightRead,
        Stream::OutputWrite,
        Stream::MetadataRead,
        Stream::MetadataWrite,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stream::FeatureRead => "feature_read",
            Stream::WeightRead => "weight_read",
            Stream::OutputWrite => "output_write",
            Stream::MetadataRead => "metadata_read",
            Stream::MetadataWrite => "metadata_write",
        }
    }

    fn index(&self) -> usize {
        match self {
            Stream::FeatureRead => 0,
            Stream::WeightRead => 1,
            Stream::OutputWrite => 2,
            Stream::MetadataRead => 3,
            Stream::MetadataWrite => 4,
        }
    }
}

/// One recorded access (when tracing is enabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub stream: Stream,
    /// Word address of the request start.
    pub addr_words: u64,
    pub words: u64,
    /// Lines actually moved (span of touched lines).
    pub lines: u64,
}

/// DRAM access accounting. `words_per_line` defaults to the global
/// 8-word alignment; all counters are in lines and words.
#[derive(Debug, Clone)]
pub struct Dram {
    words_per_line: u64,
    lines: [u64; N_STREAMS],
    words: [u64; N_STREAMS],
    trace: Option<Vec<Access>>,
}

impl Default for Dram {
    fn default() -> Self {
        Self::new(WORDS_PER_LINE)
    }
}

impl Dram {
    pub fn new(words_per_line: usize) -> Self {
        assert!(words_per_line > 0);
        Self {
            words_per_line: words_per_line as u64,
            lines: [0; N_STREAMS],
            words: [0; N_STREAMS],
            trace: None,
        }
    }

    /// Enable trace recording (tests/debugging).
    pub fn with_trace(mut self) -> Self {
        self.trace = Some(Vec::new());
        self
    }

    /// Lines spanned by a `[addr, addr+words)` request.
    pub fn span_lines(&self, addr_words: u64, words: u64) -> u64 {
        if words == 0 {
            return 0;
        }
        let first = addr_words / self.words_per_line;
        let last = (addr_words + words - 1) / self.words_per_line;
        last - first + 1
    }

    /// Issue a request; returns lines moved.
    pub fn access(&mut self, stream: Stream, addr_words: u64, words: u64) -> u64 {
        let lines = self.span_lines(addr_words, words);
        let i = stream.index();
        self.lines[i] += lines;
        self.words[i] += words;
        if let Some(t) = &mut self.trace {
            t.push(Access { stream, addr_words, words, lines });
        }
        lines
    }

    /// Account an already-line-quantified transfer (e.g. the simulator's
    /// precomputed sub-tensor fetch costs).
    pub fn account_lines(&mut self, stream: Stream, lines: u64) {
        self.lines[stream.index()] += lines;
        self.words[stream.index()] += lines * self.words_per_line;
    }

    /// Account a raw bit quantity (metadata records), converted to words
    /// at the 16-bit word size; lines are credited fractionally upward
    /// only when flushed via [`Dram::lines_of`]'s rounding.
    pub fn account_bits(&mut self, stream: Stream, bits: u64) {
        let words = bits.div_ceil(16);
        self.words[stream.index()] += words;
        self.lines[stream.index()] += words.div_ceil(self.words_per_line);
    }

    pub fn lines_of(&self, stream: Stream) -> u64 {
        self.lines[stream.index()]
    }

    pub fn words_of(&self, stream: Stream) -> u64 {
        self.words[stream.index()]
    }

    pub fn total_lines(&self) -> u64 {
        self.lines.iter().sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_lines() * self.words_per_line * 2
    }

    pub fn trace(&self) -> Option<&[Access]> {
        self.trace.as_deref()
    }

    pub fn reset(&mut self) {
        self.lines = [0; N_STREAMS];
        self.words = [0; N_STREAMS];
        if let Some(t) = &mut self.trace {
            t.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_lines_alignment() {
        let d = Dram::new(8);
        assert_eq!(d.span_lines(0, 8), 1);
        assert_eq!(d.span_lines(0, 9), 2);
        assert_eq!(d.span_lines(7, 2), 2); // straddles a boundary
        assert_eq!(d.span_lines(8, 8), 1);
        assert_eq!(d.span_lines(3, 0), 0);
        assert_eq!(d.span_lines(3, 1), 1);
    }

    #[test]
    fn per_stream_counters() {
        let mut d = Dram::new(8);
        d.access(Stream::FeatureRead, 0, 16);
        d.access(Stream::WeightRead, 4, 8);
        d.access(Stream::FeatureRead, 100, 1);
        assert_eq!(d.lines_of(Stream::FeatureRead), 2 + 1);
        assert_eq!(d.lines_of(Stream::WeightRead), 2);
        assert_eq!(d.words_of(Stream::FeatureRead), 17);
        assert_eq!(d.total_lines(), 5);
    }

    #[test]
    fn trace_records_accesses() {
        let mut d = Dram::new(8).with_trace();
        d.access(Stream::OutputWrite, 8, 8);
        let t = d.trace().unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0], Access { stream: Stream::OutputWrite, addr_words: 8, words: 8, lines: 1 });
        d.reset();
        assert!(d.trace().unwrap().is_empty());
        assert_eq!(d.total_lines(), 0);
    }

    #[test]
    fn bits_accounting() {
        let mut d = Dram::new(8);
        d.account_bits(Stream::MetadataRead, 48);
        assert_eq!(d.words_of(Stream::MetadataRead), 3);
    }

    #[test]
    fn metadata_write_is_a_distinct_stream() {
        let mut d = Dram::new(8);
        d.account_bits(Stream::MetadataWrite, 48);
        d.access(Stream::OutputWrite, 0, 8);
        assert_eq!(d.words_of(Stream::MetadataWrite), 3);
        assert_eq!(d.words_of(Stream::MetadataRead), 0);
        assert_eq!(d.total_lines(), 2);
        assert_eq!(Stream::ALL.len(), 5);
        assert_eq!(Stream::MetadataWrite.name(), "metadata_write");
    }
}
