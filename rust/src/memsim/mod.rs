//! DRAM access accounting at cache-line granularity (§III-A).
//!
//! Modern memory hierarchies move whole aligned lines (8 words = 128
//! bits here, §IV-A); partial-line requests still cost a full line. This
//! module is the substrate under both the bandwidth simulator ([`crate::sim`])
//! and the coordinator's fetch engine: every read is attributed to a
//! stream (feature / weight / output / metadata) and accounted in lines,
//! with optional trace recording for tests and debugging.

pub mod cache;
pub mod dram;
pub mod timing;

pub use cache::Cache;
pub use dram::{Access, Dram, Stream};
pub use timing::{BankSpan, DramTiming, SharedDram, TimedDram};
