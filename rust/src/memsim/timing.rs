//! DRAM timing model: banks, row buffers, burst accounting.
//!
//! The paper's §III-A argument is that "modern memory hierarchies, like
//! DRAM or cache, favor aligned and coalesced access, and the variable
//! size of compressed data can result in fragmentation and wasted
//! bandwidth". The line-count simulator quantifies the *bytes*; this
//! model quantifies the *access efficiency*: scattered small fetches
//! (Uniform 1×1×8 compact, fragmented sub-tensors) cause more DRAM row
//! activations per byte than GrateTile's long aligned sub-tensor reads.
//!
//! Simplified LPDDR4-class geometry: `n_banks` banks, `row_bytes` row
//! buffers, open-page policy. Each request is split into line transfers;
//! a transfer to the currently open row of its bank is a *row hit*
//! (`t_ccd` cycles), otherwise a *row miss* (`t_rp + t_rcd` extra).

use crate::config::hardware::WORDS_PER_LINE;

/// Timing/geometry parameters (cycles at the DRAM command clock).
#[derive(Debug, Clone, Copy)]
pub struct DramTiming {
    pub n_banks: usize,
    pub row_bytes: usize,
    /// Line-to-line transfer within an open row.
    pub t_ccd: u64,
    /// Precharge + activate penalty on a row miss.
    pub t_rp_rcd: u64,
    /// Per-request command/addressing overhead (one AXI-class
    /// transaction per `read` call) — what makes many tiny fetches
    /// expensive even when they raster nicely (§III-A).
    pub t_cmd: u64,
}

impl Default for DramTiming {
    fn default() -> Self {
        // LPDDR4-ish: 8 banks, 2 KB rows, CCD 4, RP+RCD 36, CMD 8.
        Self { n_banks: 8, row_bytes: 2048, t_ccd: 4, t_rp_rcd: 36, t_cmd: 8 }
    }
}

/// Open-page DRAM with per-bank row buffers.
#[derive(Debug, Clone)]
pub struct TimedDram {
    timing: DramTiming,
    open_rows: Vec<Option<u64>>,
    pub row_hits: u64,
    pub row_misses: u64,
    pub cycles: u64,
    pub lines: u64,
    pub requests: u64,
}

impl TimedDram {
    pub fn new(timing: DramTiming) -> Self {
        Self {
            timing,
            open_rows: vec![None; timing.n_banks],
            row_hits: 0,
            row_misses: 0,
            cycles: 0,
            lines: 0,
            requests: 0,
        }
    }

    /// Address mapping: line-interleaved across banks, rows above.
    fn map(&self, byte_addr: u64) -> (usize, u64) {
        let line = byte_addr / 16;
        let bank = (line % self.timing.n_banks as u64) as usize;
        let row = byte_addr / self.timing.row_bytes as u64 / self.timing.n_banks as u64;
        (bank, row)
    }

    /// Issue a read of `words` 16-bit words at word address `addr_words`.
    /// One call = one transaction (pays `t_cmd` once).
    pub fn read(&mut self, addr_words: u64, words: u64) {
        if words == 0 {
            return;
        }
        self.cycles += self.timing.t_cmd;
        self.requests += 1;
        let first_line = addr_words / WORDS_PER_LINE as u64;
        let last_line = (addr_words + words - 1) / WORDS_PER_LINE as u64;
        for line in first_line..=last_line {
            let byte_addr = line * 16;
            let (bank, row) = self.map(byte_addr);
            if self.open_rows[bank] == Some(row) {
                self.row_hits += 1;
                self.cycles += self.timing.t_ccd;
            } else {
                self.row_misses += 1;
                self.cycles += self.timing.t_ccd + self.timing.t_rp_rcd;
                self.open_rows[bank] = Some(row);
            }
            self.lines += 1;
        }
    }

    /// Fraction of line transfers that hit an open row.
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Effective bandwidth efficiency vs. the streaming ideal (every
    /// transfer a row hit).
    pub fn efficiency(&self) -> f64 {
        if self.lines == 0 {
            return 1.0;
        }
        (self.lines * self.timing.t_ccd) as f64 / self.cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_is_mostly_hits() {
        let mut d = TimedDram::new(DramTiming::default());
        // 64 KB sequential: one miss per (row, bank) opening.
        d.read(0, 32 * 1024);
        assert!(d.row_hit_rate() > 0.95, "hit rate {}", d.row_hit_rate());
        assert!(d.efficiency() > 0.8);
    }

    #[test]
    fn random_small_reads_thrash_rows() {
        let mut d = TimedDram::new(DramTiming::default());
        let mut rng = crate::util::SplitMix64::new(3);
        for _ in 0..2000 {
            let addr = (rng.below(1 << 22) as u64) & !7; // random line
            d.read(addr, 8);
        }
        assert!(d.row_hit_rate() < 0.30, "hit rate {}", d.row_hit_rate());
        assert!(d.efficiency() < 0.5);
    }

    #[test]
    fn straddling_reads_touch_both_lines() {
        let mut d = TimedDram::new(DramTiming::default());
        d.read(7, 2); // words 7..9: lines 0 and 1
        assert_eq!(d.lines, 2);
    }

    #[test]
    fn empty_read_is_free() {
        let mut d = TimedDram::new(DramTiming::default());
        d.read(100, 0);
        assert_eq!(d.cycles, 0);
        assert_eq!(d.lines, 0);
    }

    #[test]
    fn efficiency_bounded() {
        let mut d = TimedDram::new(DramTiming::default());
        d.read(0, 8);
        assert!(d.efficiency() > 0.0 && d.efficiency() <= 1.0);
    }
}
