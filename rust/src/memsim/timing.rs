//! DRAM timing model: banks, row buffers, burst accounting.
//!
//! The paper's §III-A argument is that "modern memory hierarchies, like
//! DRAM or cache, favor aligned and coalesced access, and the variable
//! size of compressed data can result in fragmentation and wasted
//! bandwidth". The line-count simulator quantifies the *bytes*; this
//! model quantifies the *access efficiency*: scattered small fetches
//! (Uniform 1×1×8 compact, fragmented sub-tensors) cause more DRAM row
//! activations per byte than GrateTile's long aligned sub-tensor reads.
//!
//! Simplified LPDDR4-class geometry: `n_banks` banks, `row_bytes` row
//! buffers, open-page policy. Each request is split into line transfers;
//! a transfer to the currently open row of its bank is a *row hit*
//! (`t_ccd` cycles), otherwise a *row miss* (`t_rp + t_rcd` extra).

use crate::config::hardware::WORDS_PER_LINE;

/// Timing/geometry parameters (cycles at the DRAM command clock).
#[derive(Debug, Clone, Copy)]
pub struct DramTiming {
    pub n_banks: usize,
    pub row_bytes: usize,
    /// Line-to-line transfer within an open row.
    pub t_ccd: u64,
    /// Precharge + activate penalty on a row miss.
    pub t_rp_rcd: u64,
    /// Per-request command/addressing overhead (one AXI-class
    /// transaction per `read` call) — what makes many tiny fetches
    /// expensive even when they raster nicely (§III-A).
    pub t_cmd: u64,
}

impl Default for DramTiming {
    fn default() -> Self {
        // LPDDR4-ish: 8 banks, 2 KB rows, CCD 4, RP+RCD 36, CMD 8.
        Self { n_banks: 8, row_bytes: 2048, t_ccd: 4, t_rp_rcd: 36, t_cmd: 8 }
    }
}

/// Open-page DRAM with per-bank row buffers.
#[derive(Debug, Clone)]
pub struct TimedDram {
    timing: DramTiming,
    open_rows: Vec<Option<u64>>,
    pub row_hits: u64,
    pub row_misses: u64,
    pub cycles: u64,
    pub lines: u64,
    pub requests: u64,
}

impl TimedDram {
    pub fn new(timing: DramTiming) -> Self {
        Self {
            timing,
            open_rows: vec![None; timing.n_banks],
            row_hits: 0,
            row_misses: 0,
            cycles: 0,
            lines: 0,
            requests: 0,
        }
    }

    /// Address mapping: line-interleaved across banks, rows above.
    fn map(&self, byte_addr: u64) -> (usize, u64) {
        let line = byte_addr / 16;
        let bank = (line % self.timing.n_banks as u64) as usize;
        let row = byte_addr / self.timing.row_bytes as u64 / self.timing.n_banks as u64;
        (bank, row)
    }

    /// Issue a read of `words` 16-bit words at word address `addr_words`.
    /// One call = one transaction (pays `t_cmd` once).
    pub fn read(&mut self, addr_words: u64, words: u64) {
        if words == 0 {
            return;
        }
        self.cycles += self.timing.t_cmd;
        self.requests += 1;
        let first_line = addr_words / WORDS_PER_LINE as u64;
        let last_line = (addr_words + words - 1) / WORDS_PER_LINE as u64;
        for line in first_line..=last_line {
            let byte_addr = line * 16;
            let (bank, row) = self.map(byte_addr);
            if self.open_rows[bank] == Some(row) {
                self.row_hits += 1;
                self.cycles += self.timing.t_ccd;
            } else {
                self.row_misses += 1;
                self.cycles += self.timing.t_ccd + self.timing.t_rp_rcd;
                self.open_rows[bank] = Some(row);
            }
            self.lines += 1;
        }
    }

    /// Fraction of line transfers that hit an open row.
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Effective bandwidth efficiency vs. the streaming ideal (every
    /// transfer a row hit).
    pub fn efficiency(&self) -> f64 {
        if self.lines == 0 {
            return 1.0;
        }
        (self.lines * self.timing.t_ccd) as f64 / self.cycles as f64
    }
}

/// One coalesced busy interval on a DRAM bank, in simulated cycles.
///
/// Produced by [`SharedDram`] when busy tracing is on
/// ([`SharedDram::with_busy_trace`]): back-to-back line services on
/// the same bank (next start == previous finish) extend one span, so
/// the per-bank spans are **disjoint** and their lengths sum exactly
/// to that bank's `bank_busy_cycles` entry — the reconciliation
/// `tests/obs.rs` asserts against the serving report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankSpan {
    pub bank: usize,
    pub start: u64,
    pub end: u64,
}

/// Bank-contended DRAM shared by every simulated worker of the serving
/// simulator ([`crate::coordinator::simserver`]).
///
/// [`TimedDram`] answers "how many cycles would this trace take alone";
/// `SharedDram` answers "when does this transfer *finish* given what
/// everyone else has already queued". Each request is issued at an
/// explicit **virtual** cycle (`now`) and split into line transfers;
/// a line starts when both its bank is free (`busy_until`) and the
/// request has been issued (`now + t_cmd`), pays the open-page row
/// hit/miss cost, and extends its bank's reservation. Distinct banks
/// proceed in parallel — the bank-level parallelism that makes "more
/// banks ⇒ fewer cycles" under concurrent traffic. Requests are
/// serviced strictly in call order (FCFS at transaction granularity);
/// the serving simulator's event loop orders the callers, granting
/// same-cycle requestors round-robin.
///
/// Every line's service cycles are charged to its bank's occupancy
/// counter, so `sum(bank_busy_cycles) == transfer_cycles` always —
/// the conservation invariant `tests/property.rs` asserts.
#[derive(Debug, Clone)]
pub struct SharedDram {
    timing: DramTiming,
    open_rows: Vec<Option<u64>>,
    /// Cycle each bank's current reservation ends.
    busy_until: Vec<u64>,
    /// Total transfer cycles charged per bank (occupancy).
    bank_busy_cycles: Vec<u64>,
    pub row_hits: u64,
    pub row_misses: u64,
    pub lines: u64,
    pub requests: u64,
    /// Sum of all per-line service cycles across banks.
    pub transfer_cycles: u64,
    /// Coalesced per-bank busy intervals; `None` unless enabled via
    /// [`Self::with_busy_trace`] (the common, allocation-free case).
    busy_spans: Option<Vec<BankSpan>>,
    /// Index into `busy_spans` of each bank's most recent span
    /// (`usize::MAX` = none yet) — O(1) coalescing.
    last_span: Vec<usize>,
}

impl SharedDram {
    /// `n_banks` is clamped to at least 1 (a zero-bank geometry would
    /// divide by zero in the address mapping — reachable from
    /// `gratetile serve --banks 0`).
    pub fn new(mut timing: DramTiming) -> Self {
        timing.n_banks = timing.n_banks.max(1);
        Self {
            timing,
            open_rows: vec![None; timing.n_banks],
            busy_until: vec![0; timing.n_banks],
            bank_busy_cycles: vec![0; timing.n_banks],
            row_hits: 0,
            row_misses: 0,
            lines: 0,
            requests: 0,
            transfer_cycles: 0,
            busy_spans: None,
            last_span: Vec::new(),
        }
    }

    /// Enable busy tracing: [`Self::busy_spans`] will return the
    /// coalesced per-bank occupancy intervals of every serviced line.
    pub fn with_busy_trace(mut self) -> Self {
        self.busy_spans = Some(Vec::new());
        self.last_span = vec![usize::MAX; self.timing.n_banks];
        self
    }

    /// The coalesced busy intervals (`None` when tracing is off). Spans
    /// are appended in service order; per bank they are disjoint and
    /// non-decreasing in `start`.
    pub fn busy_spans(&self) -> Option<&[BankSpan]> {
        self.busy_spans.as_deref()
    }

    pub fn timing(&self) -> DramTiming {
        self.timing
    }

    /// Same line-interleaved mapping as [`TimedDram`].
    fn map(&self, byte_addr: u64) -> (usize, u64) {
        let line = byte_addr / 16;
        let bank = (line % self.timing.n_banks as u64) as usize;
        let row = byte_addr / self.timing.row_bytes as u64 / self.timing.n_banks as u64;
        (bank, row)
    }

    /// Service a transfer of `words` 16-bit words at word address
    /// `addr_words`, issued at virtual cycle `now`; returns the
    /// completion cycle. Zero-word transfers complete immediately.
    pub fn service(&mut self, now: u64, addr_words: u64, words: u64) -> u64 {
        if words == 0 {
            return now;
        }
        self.requests += 1;
        // All lines of one transaction are issued together after the
        // command/addressing overhead; bank queues then serialise them.
        let issue = now + self.timing.t_cmd;
        let mut done = issue;
        let first_line = addr_words / WORDS_PER_LINE as u64;
        let last_line = (addr_words + words - 1) / WORDS_PER_LINE as u64;
        for line in first_line..=last_line {
            let (bank, row) = self.map(line * 16);
            let cost = if self.open_rows[bank] == Some(row) {
                self.row_hits += 1;
                self.timing.t_ccd
            } else {
                self.row_misses += 1;
                self.open_rows[bank] = Some(row);
                self.timing.t_ccd + self.timing.t_rp_rcd
            };
            let start = issue.max(self.busy_until[bank]);
            let finish = start + cost;
            if let Some(spans) = self.busy_spans.as_mut() {
                // `start >= busy_until[bank]` (the previous finish), so
                // per-bank intervals never overlap; back-to-back ones
                // coalesce into the span opened by the last service.
                let last = self.last_span[bank];
                if last != usize::MAX && spans[last].end == start {
                    spans[last].end = finish;
                } else {
                    self.last_span[bank] = spans.len();
                    spans.push(BankSpan { bank, start, end: finish });
                }
            }
            self.busy_until[bank] = finish;
            self.bank_busy_cycles[bank] += cost;
            self.transfer_cycles += cost;
            self.lines += 1;
            done = done.max(finish);
        }
        done
    }

    /// Per-bank occupancy (total transfer cycles charged to each bank).
    pub fn bank_busy_cycles(&self) -> &[u64] {
        &self.bank_busy_cycles
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Occupancy of the busiest bank over `horizon` cycles (0 when the
    /// horizon is empty).
    pub fn peak_bank_utilisation(&self, horizon: u64) -> f64 {
        if horizon == 0 {
            return 0.0;
        }
        self.bank_busy_cycles.iter().copied().max().unwrap_or(0) as f64 / horizon as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_is_mostly_hits() {
        let mut d = TimedDram::new(DramTiming::default());
        // 64 KB sequential: one miss per (row, bank) opening.
        d.read(0, 32 * 1024);
        assert!(d.row_hit_rate() > 0.95, "hit rate {}", d.row_hit_rate());
        assert!(d.efficiency() > 0.8);
    }

    #[test]
    fn random_small_reads_thrash_rows() {
        let mut d = TimedDram::new(DramTiming::default());
        let mut rng = crate::util::SplitMix64::new(3);
        for _ in 0..2000 {
            let addr = (rng.below(1 << 22) as u64) & !7; // random line
            d.read(addr, 8);
        }
        assert!(d.row_hit_rate() < 0.30, "hit rate {}", d.row_hit_rate());
        assert!(d.efficiency() < 0.5);
    }

    #[test]
    fn straddling_reads_touch_both_lines() {
        let mut d = TimedDram::new(DramTiming::default());
        d.read(7, 2); // words 7..9: lines 0 and 1
        assert_eq!(d.lines, 2);
    }

    #[test]
    fn empty_read_is_free() {
        let mut d = TimedDram::new(DramTiming::default());
        d.read(100, 0);
        assert_eq!(d.cycles, 0);
        assert_eq!(d.lines, 0);
    }

    #[test]
    fn efficiency_bounded() {
        let mut d = TimedDram::new(DramTiming::default());
        d.read(0, 8);
        assert!(d.efficiency() > 0.0 && d.efficiency() <= 1.0);
    }

    #[test]
    fn shared_zero_words_completes_immediately() {
        let mut d = SharedDram::new(DramTiming::default());
        assert_eq!(d.service(123, 40, 0), 123);
        assert_eq!(d.lines, 0);
        assert_eq!(d.transfer_cycles, 0);
        assert_eq!(d.requests, 0);
    }

    #[test]
    fn shared_zero_banks_clamps_instead_of_panicking() {
        let mut d = SharedDram::new(DramTiming { n_banks: 0, ..DramTiming::default() });
        assert_eq!(d.timing().n_banks, 1);
        let done = d.service(0, 0, 8);
        assert!(done > 0);
        assert_eq!(d.bank_busy_cycles().len(), 1);
    }

    #[test]
    fn shared_single_line_pays_cmd_and_miss() {
        let t = DramTiming::default();
        let mut d = SharedDram::new(t);
        let done = d.service(10, 0, 8);
        // Cold bank: command + activate + transfer.
        assert_eq!(done, 10 + t.t_cmd + t.t_ccd + t.t_rp_rcd);
        assert_eq!(d.row_misses, 1);
        // Same line again from the open row: hit, queued behind nothing.
        let done2 = d.service(done, 0, 8);
        assert_eq!(done2, done + t.t_cmd + t.t_ccd);
        assert_eq!(d.row_hits, 1);
    }

    #[test]
    fn shared_same_bank_contention_serialises() {
        // Two transfers issued at the same cycle to the SAME line queue
        // on one bank; to different banks they overlap.
        let t = DramTiming::default();
        let mut d = SharedDram::new(t);
        let a = d.service(0, 0, 8); // line 0 -> bank 0
        let b = d.service(0, 0, 8); // same bank: starts after `a`
        assert_eq!(b, a + t.t_ccd, "hit queued behind the first transfer");
        let mut d2 = SharedDram::new(t);
        let a2 = d2.service(0, 0, 8); // bank 0
        let b2 = d2.service(0, 8, 8); // line 1 -> bank 1: parallel
        assert_eq!(a2, b2, "distinct banks service concurrently");
    }

    #[test]
    fn shared_bank_occupancy_conserves_transfer_cycles() {
        let mut d = SharedDram::new(DramTiming::default());
        let mut now = 0;
        for i in 0..50u64 {
            now = d.service(now, i * 37, 1 + (i % 40));
        }
        assert_eq!(d.bank_busy_cycles().iter().sum::<u64>(), d.transfer_cycles);
        assert_eq!(d.row_hits + d.row_misses, d.lines);
        assert!(d.peak_bank_utilisation(now) <= 1.0);
        assert_eq!(d.peak_bank_utilisation(0), 0.0);
    }

    #[test]
    fn busy_trace_spans_reconcile_with_bank_busy_cycles() {
        let mut d = SharedDram::new(DramTiming::default()).with_busy_trace();
        let mut now = 0;
        for i in 0..50u64 {
            now = d.service(now + (i % 3) * 11, i * 37, 1 + (i % 40));
        }
        let spans = d.busy_spans().expect("tracing enabled");
        assert!(!spans.is_empty());
        let n = d.timing().n_banks;
        let mut per_bank = vec![0u64; n];
        let mut last_end = vec![0u64; n];
        for s in spans {
            assert!(s.end > s.start, "empty span {s:?}");
            assert!(s.start >= last_end[s.bank], "overlap on bank {}", s.bank);
            last_end[s.bank] = s.end;
            per_bank[s.bank] += s.end - s.start;
        }
        assert_eq!(per_bank, d.bank_busy_cycles(), "coalesced spans must sum exactly");
    }

    #[test]
    fn busy_trace_coalesces_back_to_back_lines() {
        // One 4-line read on a single bank: all lines queue back to
        // back, so tracing yields exactly one coalesced span.
        let timing = DramTiming { n_banks: 1, ..DramTiming::default() };
        let mut d = SharedDram::new(timing).with_busy_trace();
        d.service(0, 0, 32);
        assert_eq!(d.lines, 4);
        let spans = d.busy_spans().unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].end - spans[0].start, d.transfer_cycles);
        // Untraced DRAM allocates nothing.
        let mut plain = SharedDram::new(timing);
        plain.service(0, 0, 32);
        assert!(plain.busy_spans().is_none());
    }

    #[test]
    fn shared_single_bank_serialises_everything() {
        let timing = DramTiming { n_banks: 1, ..DramTiming::default() };
        let mut d = SharedDram::new(timing);
        let a = d.service(0, 0, 16); // 2 lines, both bank 0
        // With one bank every line queues; completion covers the sum of
        // both line costs.
        assert!(a >= timing.t_cmd + 2 * timing.t_ccd + timing.t_rp_rcd);
        assert_eq!(d.bank_busy_cycles().len(), 1);
        assert_eq!(d.bank_busy_cycles()[0], d.transfer_cycles);
    }
}
