//! Set-associative cache model, used for the metadata-residency study.
//!
//! §III-C: "this pointer index can be too big for the on-chip SRAM, or
//! contribute to additional latency and bandwidth if stored in the
//! DRAM". GrateTile's 0.6 % metadata *can* be cached effectively; a
//! Uniform 1×1×8 index (25 %) cannot. This model lets the ablation
//! quantify that: metadata records stream through a small SRAM cache
//! and only misses pay DRAM traffic.

use crate::util::ceil_div;

/// LRU set-associative cache over line addresses.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_bytes: usize,
    /// tags[set * ways + way] = Some(tag); LRU order in `stamp`.
    tags: Vec<Option<u64>>,
    stamp: Vec<u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    /// Build a cache of `capacity_bytes` with `ways` associativity.
    pub fn new(capacity_bytes: usize, ways: usize, line_bytes: usize) -> Self {
        assert!(ways > 0 && line_bytes > 0);
        let lines = ceil_div(capacity_bytes, line_bytes).max(ways);
        let sets = (lines / ways).max(1);
        Self {
            sets,
            ways,
            line_bytes,
            tags: vec![None; sets * ways],
            stamp: vec![0; sets * ways],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access `bytes` at `byte_addr`; returns the number of missed lines.
    pub fn access(&mut self, byte_addr: u64, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let first = byte_addr / self.line_bytes as u64;
        let last = (byte_addr + bytes - 1) / self.line_bytes as u64;
        let mut missed = 0;
        for line in first..=last {
            if !self.touch(line) {
                missed += 1;
            }
        }
        missed
    }

    /// Access one line; true on hit.
    fn touch(&mut self, line: u64) -> bool {
        self.tick += 1;
        let set = (line % self.sets as u64) as usize;
        let tag = line / self.sets as u64;
        let base = set * self.ways;
        // Hit?
        for w in 0..self.ways {
            if self.tags[base + w] == Some(tag) {
                self.stamp[base + w] = self.tick;
                self.hits += 1;
                return true;
            }
        }
        // Miss: evict LRU way.
        self.misses += 1;
        let mut victim = 0;
        for w in 1..self.ways {
            if self.stamp[base + w] < self.stamp[base + victim] {
                victim = w;
            }
        }
        self.tags[base + victim] = Some(tag);
        self.stamp[base + victim] = self.tick;
        false
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.sets * self.ways * self.line_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 4, 16);
        assert_eq!(c.access(0, 16), 1); // cold miss
        assert_eq!(c.access(0, 16), 0); // hit
        assert_eq!(c.access(4, 4), 0); // same line
        assert!(c.hit_rate() > 0.6);
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = Cache::new(256, 2, 16); // 16 lines
        // Cyclic sweep over 64 lines: every access misses after warmup.
        for round in 0..4 {
            for line in 0..64u64 {
                let missed = c.access(line * 16, 16);
                if round > 0 {
                    assert_eq!(missed, 1, "line {line} should thrash");
                }
            }
        }
        assert!(c.hit_rate() < 0.05);
    }

    #[test]
    fn working_set_smaller_than_cache_stays_resident() {
        let mut c = Cache::new(1024, 4, 16); // 64 lines
        for _ in 0..10 {
            for line in 0..32u64 {
                c.access(line * 16, 16);
            }
        }
        assert!(c.hit_rate() > 0.85, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = Cache::new(32, 2, 16); // 1 set, 2 ways
        c.access(0, 1); // line 0
        c.access(16, 1); // line 1
        c.access(0, 1); // refresh line 0
        c.access(32, 1); // line 2 evicts line 1 (LRU)
        assert_eq!(c.access(0, 1), 0, "line 0 must still be resident");
        assert_eq!(c.access(16, 1), 1, "line 1 must have been evicted");
    }

    #[test]
    fn multi_line_access_counts_per_line() {
        let mut c = Cache::new(1024, 4, 16);
        assert_eq!(c.access(8, 32), 3); // spans lines 0..2
    }
}
