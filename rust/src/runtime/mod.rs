//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 JAX CNN (whose conv layers call the L1 Pallas kernel)
//! to **HLO text** in `artifacts/`. This module loads that text via the
//! `xla` crate (`HloModuleProto::from_text_file` → compile on the PJRT
//! CPU client → execute) so the request path is pure Rust.
//!
//! HLO *text* — not a serialized `HloModuleProto` — is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, LoadedModel};
pub use manifest::{ArtifactEntry, Manifest};
