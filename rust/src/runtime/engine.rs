//! The PJRT execution engine: compile-once, execute-many.
//!
//! The real engine wraps the `xla` crate's PJRT CPU client and is gated
//! behind the `pjrt-xla` cargo feature, because the offline build image
//! has no crates.io access (see DESIGN.md §Runtime: enabling that
//! feature requires adding the vendored `xla` dependency to
//! `Cargo.toml`). Every other build — the default AND the plain `pjrt`
//! feature (CI's feature-matrix leg) — compiles a stub with the same
//! API whose methods return clean, actionable errors, so the simulator,
//! harness and tests are fully usable without the PJRT toolchain.

#[cfg(feature = "pjrt-xla")]
mod imp {
    use crate::runtime::manifest::ArtifactEntry;
    use crate::tensor::FeatureMap;
    use crate::util::error::{Context, Result};
    use crate::{bail, err};
    use std::path::Path;

    /// Wraps the PJRT CPU client. One engine per process.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Engine> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| err!("PJRT cpu client: {e:?}"))?;
            Ok(Engine { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo(&self, path: &Path) -> Result<LoadedModel> {
            if !path.exists() {
                bail!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                );
            }
            let path_str = path
                .to_str()
                .ok_or_else(|| err!("non-utf8 path {}", path.display()))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| err!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err!("compiling {}: {e:?}", path.display()))?;
            Ok(LoadedModel { exe, name: path.display().to_string() })
        }

        /// Load an artifact described by a manifest entry.
        pub fn load_entry(&self, entry: &ArtifactEntry) -> Result<LoadedModel> {
            self.load_hlo(&entry.file)
        }
    }

    /// A compiled executable plus invocation helpers.
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl LoadedModel {
        /// Execute on raw f32 inputs; returns the raw output literals of the
        /// result tuple, in order.
        ///
        /// All artifacts are lowered with `return_tuple=True`, so the result
        /// literal is always a tuple (see `python/compile/aot.py`).
        pub fn run_literals(
            &self,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<xla::Literal>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims_i64)
                        .map_err(|e| err!("reshape to {dims:?}: {e:?}"))
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| err!("execute {}: {e:?}", self.name))?;
            let literal = result[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetch result: {e:?}"))?;
            literal.to_tuple().map_err(|e| err!("untuple result: {e:?}"))
        }

        /// Execute and flatten every tuple output to f32 payloads.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            self.run_literals(inputs)?
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}")))
                .collect()
        }

        /// Execute a CNN-style artifact: image in, per-layer activation
        /// feature maps out (shapes from the manifest entry).
        pub fn run_cnn(
            &self,
            entry: &ArtifactEntry,
            image: &[f32],
        ) -> Result<Vec<FeatureMap>> {
            let expect: usize = entry.input_dims.iter().product();
            if image.len() != expect {
                bail!(
                    "input has {} elements, artifact expects {:?} = {expect}",
                    image.len(),
                    entry.input_dims
                );
            }
            let outs = self.run_f32(&[(image, &entry.input_dims)])?;
            if outs.len() != entry.n_outputs {
                bail!(
                    "artifact returned {} outputs, manifest says {}",
                    outs.len(),
                    entry.n_outputs
                );
            }
            if entry.layer_shapes.len() != outs.len() {
                bail!(
                    "manifest declares {} layer shapes for {} outputs",
                    entry.layer_shapes.len(),
                    outs.len()
                );
            }
            outs.into_iter()
                .zip(&entry.layer_shapes)
                .map(|(data, &(h, w, c))| {
                    if data.len() != h * w * c {
                        bail!("layer payload {} != {h}x{w}x{c}", data.len());
                    }
                    Ok(FeatureMap::from_vec(h, w, c, data))
                })
                .collect::<Result<Vec<_>>>()
                .context("assembling feature maps")
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
mod imp {
    use crate::bail;
    use crate::runtime::manifest::ArtifactEntry;
    use crate::tensor::FeatureMap;
    use crate::util::error::Result;
    use std::path::Path;

    const HINT: &str =
        "this build has no PJRT runtime — rebuild with `--features pjrt-xla` \
         (requires the offline `xla` crate; see DESIGN.md §Runtime)";

    /// Stub engine: same API as the PJRT-backed one, clean errors for
    /// every path that would need the real runtime.
    pub struct Engine {
        _priv: (),
    }

    impl Engine {
        pub fn cpu() -> Result<Engine> {
            Ok(Engine { _priv: () })
        }

        pub fn platform(&self) -> String {
            "cpu (stub; enable the `pjrt-xla` feature for real PJRT)".to_string()
        }

        pub fn load_hlo(&self, path: &Path) -> Result<LoadedModel> {
            if !path.exists() {
                bail!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                );
            }
            bail!("cannot compile {}: {HINT}", path.display());
        }

        pub fn load_entry(&self, entry: &ArtifactEntry) -> Result<LoadedModel> {
            self.load_hlo(&entry.file)
        }
    }

    /// Stub model: never constructed (loading always errors), but keeps
    /// the call sites of the real API type-checking.
    pub struct LoadedModel {
        pub name: String,
    }

    /// Stub stand-in for `xla::Literal` (never constructed): keeps
    /// `run_literals` call sites — `tests/runtime_smoke.rs` under the
    /// plain `pjrt` feature — type-checking without the vendored crate.
    pub struct Literal {
        _priv: (),
    }

    impl Literal {
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            bail!("stub literal holds no data: {HINT}");
        }
    }

    impl LoadedModel {
        pub fn run_literals(
            &self,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Literal>> {
            bail!("cannot execute {}: {HINT}", self.name);
        }

        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            bail!("cannot execute {}: {HINT}", self.name);
        }

        pub fn run_cnn(
            &self,
            _entry: &ArtifactEntry,
            _image: &[f32],
        ) -> Result<Vec<FeatureMap>> {
            bail!("cannot execute {}: {HINT}", self.name);
        }
    }
}

pub use imp::{Engine, LoadedModel};

#[cfg(test)]
mod tests {
    //! Engine tests that need real artifacts live in
    //! `rust/tests/runtime_smoke.rs` (they require `make artifacts`).
    //! These contract tests hold for both the PJRT and the stub engine.
    use super::*;
    use std::path::Path;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let engine = Engine::cpu().expect("cpu client");
        let err = match engine.load_hlo(Path::new("/nonexistent/x.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing artifact"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn cpu_client_reports_platform() {
        let engine = Engine::cpu().expect("cpu client");
        let p = engine.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("host"), "{p}");
    }
}
