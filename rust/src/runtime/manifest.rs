//! Artifact manifest: what `aot.py` produced and how to feed it.
//!
//! A deliberately dependency-free line format (no serde in the offline
//! build environment):
//!
//! ```text
//! # comments and blank lines ignored
//! artifact <name> <file> in=<d0>x<d1>x...xf32 outs=<n>
//! layer <model> <idx> h=<h> w=<w> c=<c>
//! container <name> <file.grate> [codec=<name>|auto]
//! tunedv 1
//! tuned <name> mode=<key> codec=<key> [order=<key>] [cost=<bits>] [sig=<hex16>]
//! ```
//!
//! `container` lines register `.grate` tensor-store files (see
//! [`crate::store::container`]) alongside the compiled artifacts, so a
//! deployment manifest can name both the model and the packed
//! activation sets it serves from. `tuned` lines (gated by a `tunedv`
//! version header) carry per-layer plans from `gratetile tune` — field
//! parsing is shared with [`crate::tune::plan::TunedManifest`].
//!
//! Every directive rejects unknown `key=` options with an error naming
//! the key and line — a typo'd option must never silently fall back to
//! a default.

use crate::compress::{CodecPolicy, Registry};
use crate::tune::plan::{parse_tuned_fields, TunedEntry, TUNED_MANIFEST_VERSION};
use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    /// Input dims (single f32 input).
    pub input_dims: Vec<usize>,
    /// Number of tuple outputs.
    pub n_outputs: usize,
    /// Output feature-map shapes `(h, w, c)` per layer, when declared.
    pub layer_shapes: Vec<(usize, usize, usize)>,
}

/// A registered `.grate` container: its path plus the codec policy to
/// (re-)pack its tensors under (`None` = whatever the file carries).
#[derive(Debug, Clone, PartialEq)]
pub struct ContainerRef {
    pub path: PathBuf,
    pub policy: Option<CodecPolicy>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Artifacts by name. `BTreeMap`: the not-found error messages
    /// below render the key list, so map order reaches user-visible
    /// bytes — sorted order keeps them stable across runs and hosts.
    pub entries: BTreeMap<String, ArtifactEntry>,
    /// Registered `.grate` container files, by name.
    pub containers: BTreeMap<String, ContainerRef>,
    /// Per-layer tuned plans in declaration order (order is load-bearing:
    /// consumers map entries onto network layers positionally).
    pub tuned: Vec<(String, TunedEntry)>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut m = Manifest {
            entries: BTreeMap::new(),
            containers: BTreeMap::new(),
            tuned: Vec::new(),
            dir: dir.to_path_buf(),
        };
        let mut tuned_version: Option<u32> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("artifact") => {
                    let name = parts.next().ok_or_else(|| err!("line {ln}: name"))?;
                    let file = parts.next().ok_or_else(|| err!("line {ln}: file"))?;
                    let mut input_dims = Vec::new();
                    let mut n_outputs = 0usize;
                    for kv in parts {
                        if let Some(spec) = kv.strip_prefix("in=") {
                            let spec = spec
                                .strip_suffix("xf32")
                                .ok_or_else(|| err!("line {ln}: only f32 inputs supported"))?;
                            input_dims = spec
                                .split('x')
                                .map(|d| d.parse::<usize>().map_err(|e| err!("line {ln}: {e}")))
                                .collect::<Result<_>>()?;
                        } else if let Some(n) = kv.strip_prefix("outs=") {
                            n_outputs = n.parse().map_err(|e| err!("line {ln}: {e}"))?;
                        } else {
                            let key = kv.split('=').next().unwrap_or(kv);
                            bail!("line {ln}: unknown artifact option '{key}' (in, outs)");
                        }
                    }
                    if input_dims.is_empty() || n_outputs == 0 {
                        bail!("line {ln}: artifact needs in= and outs=");
                    }
                    m.entries.insert(
                        name.to_string(),
                        ArtifactEntry {
                            name: name.to_string(),
                            file: dir.join(file),
                            input_dims,
                            n_outputs,
                            layer_shapes: Vec::new(),
                        },
                    );
                }
                Some("layer") => {
                    let model = parts.next().ok_or_else(|| err!("line {ln}: model"))?;
                    let _idx: usize = parts
                        .next()
                        .ok_or_else(|| err!("line {ln}: idx"))?
                        .parse()?;
                    let mut h = 0;
                    let mut w = 0;
                    let mut c = 0;
                    for kv in parts {
                        if let Some(v) = kv.strip_prefix("h=") {
                            h = v.parse()?;
                        } else if let Some(v) = kv.strip_prefix("w=") {
                            w = v.parse()?;
                        } else if let Some(v) = kv.strip_prefix("c=") {
                            c = v.parse()?;
                        } else {
                            let key = kv.split('=').next().unwrap_or(kv);
                            bail!("line {ln}: unknown layer option '{key}' (h, w, c)");
                        }
                    }
                    m.entries
                        .get_mut(model)
                        .ok_or_else(|| err!("line {ln}: unknown model {model}"))?
                        .layer_shapes
                        .push((h, w, c));
                }
                Some("container") => {
                    let name = parts.next().ok_or_else(|| err!("line {ln}: container name"))?;
                    let file = parts.next().ok_or_else(|| err!("line {ln}: container file"))?;
                    let mut policy = None;
                    for kv in parts {
                        if let Some(c) = kv.strip_prefix("codec=") {
                            // THE codec-name parser (the registry):
                            // unknown names list the valid codecs.
                            policy = Some(
                                Registry::global()
                                    .parse_policy(c)
                                    .map_err(|e| err!("line {ln}: {e}"))?,
                            );
                        } else {
                            bail!("line {ln}: unknown container option '{kv}'");
                        }
                    }
                    m.containers
                        .insert(name.to_string(), ContainerRef { path: dir.join(file), policy });
                }
                Some("tunedv") => {
                    let v: u32 = parts
                        .next()
                        .ok_or_else(|| err!("line {ln}: tunedv needs a version"))?
                        .parse()
                        .map_err(|e| err!("line {ln}: {e}"))?;
                    if v != TUNED_MANIFEST_VERSION {
                        bail!(
                            "line {ln}: unsupported tuned-manifest version {v} \
                             (this build reads version {TUNED_MANIFEST_VERSION})"
                        );
                    }
                    tuned_version = Some(v);
                }
                Some("tuned") => {
                    if tuned_version.is_none() {
                        bail!("line {ln}: 'tuned' before 'tunedv' version header");
                    }
                    m.tuned.push(parse_tuned_fields(ln, parts)?);
                }
                Some(other) => bail!("line {ln}: unknown directive {other}"),
                None => {}
            }
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| err!("artifact '{name}' not in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()))
    }

    /// Path of a registered `.grate` container.
    pub fn container(&self, name: &str) -> Result<&Path> {
        self.container_ref(name).map(|c| c.path.as_path())
    }

    /// The tuned plan list in declaration order (what
    /// [`crate::coordinator::LayerRunner::with_plans`] consumes).
    pub fn tuned_plans(&self) -> Vec<crate::tune::LayerPlan> {
        self.tuned.iter().map(|(_, e)| e.plan).collect()
    }

    /// Full container reference (path + declared codec policy).
    pub fn container_ref(&self, name: &str) -> Result<&ContainerRef> {
        self.containers
            .get(name)
            .ok_or_else(|| err!("container '{name}' not in manifest (have: {:?})",
                self.containers.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# demo manifest
artifact cnn model.hlo.txt in=1x32x32x1xf32 outs=4
layer cnn 0 h=32 w=32 c=8
layer cnn 1 h=32 w=32 c=16

artifact stats compress.hlo.txt in=512xf32 outs=2
container acts acts.grate codec=auto
container fixed fixed.grate codec=zrlc
container plain plain.grate
tunedv 1
tuned CONV1 mode=grate8 codec=auto order=spatial
tuned CONV2 mode=anchored8@1 codec=zrlc order=channel cost=4096
";

    #[test]
    fn parses_entries_and_layers() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let cnn = m.get("cnn").unwrap();
        assert_eq!(cnn.input_dims, vec![1, 32, 32, 1]);
        assert_eq!(cnn.n_outputs, 4);
        assert_eq!(cnn.layer_shapes, vec![(32, 32, 8), (32, 32, 16)]);
        assert_eq!(cnn.file, Path::new("/tmp/a/model.hlo.txt"));
        let st = m.get("stats").unwrap();
        assert_eq!(st.input_dims, vec![512]);
        assert_eq!(st.n_outputs, 2);
        assert_eq!(m.container("acts").unwrap(), Path::new("/tmp/a/acts.grate"));
        assert_eq!(m.container_ref("acts").unwrap().policy, Some(CodecPolicy::Adaptive));
        assert_eq!(
            m.container_ref("fixed").unwrap().policy,
            Some(CodecPolicy::Fixed(crate::compress::Scheme::Zrlc))
        );
        assert_eq!(m.container_ref("plain").unwrap().policy, None);
        assert!(m.container("nope").is_err());
        // Tuned directives: ordered, fully parsed.
        assert_eq!(m.tuned.len(), 2);
        assert_eq!(m.tuned[0].0, "CONV1");
        let plans = m.tuned_plans();
        assert_eq!(plans[0].policy, CodecPolicy::Adaptive);
        assert_eq!(
            plans[1].mode,
            crate::tiling::division::DivisionMode::Anchored { edge: 8, anchor: 1 }
        );
        assert_eq!(m.tuned[1].1.cost_bits, Some(4096));
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn unknown_container_codec_lists_valid_names() {
        let e = Manifest::parse("container a a.grate codec=nope", Path::new("/tmp"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("bitmask") && e.contains("auto"), "{e}");
        assert!(Manifest::parse("container a a.grate bogus=1", Path::new("/tmp")).is_err());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Manifest::parse("artifact x", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("bogus directive", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("layer nocnn 0 h=1 w=1 c=1", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("artifact x f in=4xf64 outs=1", Path::new("/tmp")).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = Manifest::parse("# nothing\n\n", Path::new("/tmp")).unwrap();
        assert!(m.entries.is_empty());
    }

    /// ISSUE 9 satellite (bugfix regression): kv loops used to silently
    /// skip unknown keys — a misspelled `codec=` in a tuned line (or any
    /// typo'd option) must be an error naming the key and the line.
    #[test]
    fn unknown_option_keys_rejected_with_key_and_line() {
        let e = Manifest::parse("tunedv 1\ntuned L mode=grate8 codecc=auto", Path::new("/tmp"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("codecc"), "must name the bad key: {e}");
        assert!(e.contains("line 1"), "must name the line: {e}");

        let e = Manifest::parse("artifact x f in=4xf32 outs=1 inn=2xf32", Path::new("/tmp"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("inn") && e.contains("line 0"), "{e}");

        let e = Manifest::parse(
            "artifact m f in=1xf32 outs=1\nlayer m 0 h=1 w=1 cc=1",
            Path::new("/tmp"),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("cc") && e.contains("line 1"), "{e}");
    }

    /// ISSUE 10 satellite (lint-driven fix regression): the not-found
    /// errors render the artifact/container key lists, so map order
    /// reaches user-visible bytes. With `BTreeMap` the rendered message
    /// must be byte-identical however the manifest declared the names.
    #[test]
    fn not_found_errors_are_byte_identical_across_insertion_orders() {
        let fwd = "artifact zeta f1 in=4xf32 outs=1\n\
                   artifact alpha f2 in=4xf32 outs=1\n\
                   artifact mid f3 in=4xf32 outs=1\n\
                   container c2 p2.grate\ncontainer c1 p1.grate\n";
        let rev = "container c1 p1.grate\ncontainer c2 p2.grate\n\
                   artifact mid f3 in=4xf32 outs=1\n\
                   artifact alpha f2 in=4xf32 outs=1\n\
                   artifact zeta f1 in=4xf32 outs=1\n";
        let a = Manifest::parse(fwd, Path::new("/tmp")).unwrap();
        let b = Manifest::parse(rev, Path::new("/tmp")).unwrap();
        let ea = a.get("missing").unwrap_err().to_string();
        let eb = b.get("missing").unwrap_err().to_string();
        assert_eq!(ea, eb);
        assert!(ea.contains("alpha") && ea.contains("zeta"), "{ea}");
        // Sorted, not insertion, order:
        assert!(ea.find("alpha").unwrap() < ea.find("mid").unwrap(), "{ea}");
        assert!(ea.find("mid").unwrap() < ea.find("zeta").unwrap(), "{ea}");
        let ca = a.container_ref("nope").unwrap_err().to_string();
        let cb = b.container_ref("nope").unwrap_err().to_string();
        assert_eq!(ca, cb);
        assert!(ca.find("c1").unwrap() < ca.find("c2").unwrap(), "{ca}");
    }

    #[test]
    fn tuned_requires_version_header_and_known_version() {
        assert!(Manifest::parse("tuned L mode=grate8 codec=auto", Path::new("/tmp")).is_err());
        let e = Manifest::parse("tunedv 9", Path::new("/tmp")).unwrap_err().to_string();
        assert!(e.contains("version 9"), "{e}");
    }
}
