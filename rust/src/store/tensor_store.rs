//! The tensor store: multiple named packed feature maps in one
//! simulated DRAM address space.
//!
//! A deployed GrateTile system keeps every live feature map compressed
//! in DRAM; the store models that memory: an [`Arena`] hands out
//! line-aligned extents, `mem` is the word-addressed DRAM image, and
//! each tensor is a [`PackedFeatureMap`] layout whose `addr_words` are
//! *absolute* store addresses — so the fetch path and the timing model
//! see real, scattered addresses instead of every map starting at 0.
//!
//! Tensors enter the store either wholesale ([`TensorStore::insert_packed`],
//! a `Packer`-materialised map copied into one extent) or streamed
//! block-by-block by the [`crate::store::writer::StoreWriter`] as a
//! layer's compute lane produces output tiles.

use super::arena::Arena;
use crate::config::hardware::WORDS_PER_LINE;
use crate::layout::fetcher::{Fetcher, SegmentPayload};
use crate::layout::metadata::MetadataTable;
use crate::layout::packer::PackedFeatureMap;
use crate::memsim::Dram;
use crate::tensor::FeatureMap;
use crate::tiling::division::SubTensorRef;
use crate::util::error::Result;
use crate::util::round_up;
use crate::{bail, err};
use std::collections::BTreeMap;

/// One tensor resident in the store.
#[derive(Debug, Clone)]
pub struct StoredTensor {
    /// Layout with absolute store addresses; `payload` is always `None`
    /// (the words live in the store's DRAM image).
    pub packed: PackedFeatureMap,
    /// Arena extents `(base_addr, line-rounded words)` backing the
    /// tensor, sorted by base.
    pub extents: Vec<(u64, u64)>,
}

impl StoredTensor {
    /// Map shape `(h, w, c)`.
    pub fn shape(&self) -> (usize, usize, usize) {
        let d = &self.packed.division;
        (d.fm_h, d.fm_w, d.fm_c)
    }
}

/// Multiple named compressed tensors in one simulated DRAM space.
#[derive(Debug, Clone, Default)]
pub struct TensorStore {
    pub(crate) arena: Arena,
    pub(crate) mem: Vec<u16>,
    /// Tensors by name. `BTreeMap` so every iteration surface —
    /// `names()`, whole-store export, capacity accounting — is
    /// deterministic without remembering to sort.
    pub(crate) tensors: BTreeMap<String, StoredTensor>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn ensure_mem(&mut self, end_words: u64) {
        if self.mem.len() < end_words as usize {
            self.mem.resize(end_words as usize, 0);
        }
    }

    /// Copy a payload-packed map into the store under `name` as one
    /// contiguous extent, rebasing its addresses. Replaces (and frees)
    /// any tensor previously stored under the name.
    pub fn insert_packed(&mut self, name: &str, packed: &PackedFeatureMap) -> Result<u64> {
        let payload = packed
            .payload
            .as_ref()
            .ok_or_else(|| err!("store insert '{name}': map has no payload"))?;
        self.remove_if_present(name);
        let len = round_up(packed.total_words.max(1) as usize, WORDS_PER_LINE) as u64;
        let base = self.arena.alloc(len);
        self.ensure_mem(base + len);
        self.mem[base as usize..base as usize + payload.len()].copy_from_slice(payload);
        let mut stored = packed.clone();
        stored.payload = None;
        for a in &mut stored.addr_words {
            *a += base;
        }
        for r in &mut stored.metadata.records {
            r.pointer_words += base;
        }
        self.tensors
            .insert(name.to_string(), StoredTensor { packed: stored, extents: vec![(base, len)] });
        Ok(base)
    }

    /// Remove `name`, returning its extents to the arena's free list.
    pub fn remove(&mut self, name: &str) -> Result<()> {
        if !self.remove_if_present(name) {
            bail!("store remove: no tensor '{name}'");
        }
        Ok(())
    }

    pub(crate) fn remove_if_present(&mut self, name: &str) -> bool {
        match self.tensors.remove(name) {
            Some(t) => {
                for &(base, _) in &t.extents {
                    self.arena.free(base);
                }
                true
            }
            None => false,
        }
    }

    pub fn get(&self, name: &str) -> Option<&StoredTensor> {
        self.tensors.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    /// Tensor names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut n: Vec<String> = self.tensors.keys().cloned().collect();
        n.sort();
        n
    }

    /// Allocator view (live/free/footprint stats).
    pub fn arena(&self) -> &Arena {
        &self.arena
    }

    /// Owned snapshot of one tensor — its absolute-address layout plus
    /// the payload words of its extents — for a reader running
    /// concurrently with writes to *other* tensors (the pipeline's
    /// prefetch lane).
    pub fn snapshot(&self, name: &str) -> Result<(PackedFeatureMap, SegmentPayload)> {
        let t = self
            .tensors
            .get(name)
            .ok_or_else(|| err!("store snapshot: no tensor '{name}'"))?;
        let segs = t
            .extents
            .iter()
            .map(|&(base, len)| {
                let end = ((base + len) as usize).min(self.mem.len());
                (base, self.mem[base as usize..end].to_vec())
            })
            .collect();
        Ok((t.packed.clone(), SegmentPayload { segs }))
    }

    /// Fetch a tensor fully dense (traffic accounted on `dram`).
    pub fn fetch_dense(&self, name: &str, dram: &mut Dram) -> Result<FeatureMap> {
        let (packed, payload) = self.snapshot(name)?;
        let (h, w, c) = (packed.division.fm_h, packed.division.fm_w, packed.division.fm_c);
        let mut fetcher = Fetcher::with_source(&packed, Box::new(payload));
        let win = fetcher.fetch_window(dram, 0, h, 0, w, 0, c);
        Ok(FeatureMap::from_vec(h, w, c, win.data))
    }

    /// Re-pack a stored tensor into a contiguous, payload-carrying map
    /// (block-raster order, addresses starting at 0) — the canonical
    /// form the `.grate` container serialises.
    pub fn export(&self, name: &str) -> Result<PackedFeatureMap> {
        let t = self
            .tensors
            .get(name)
            .ok_or_else(|| err!("store export: no tensor '{name}'"))?;
        let src = &t.packed;
        let div = &src.division;
        let wpl = src.line_words();
        let n = div.n_subtensors();
        let mut addr_words = vec![0u64; n];
        let mut payload: Vec<u16> = Vec::with_capacity(src.total_words as usize);
        let mut records = Vec::with_capacity(div.n_blocks());
        let mut cursor: u64 = 0;
        let adaptive = src.policy.is_adaptive();
        for by in 0..div.n_blocks_y {
            let yr = div.y_segs_of_block(by);
            for bx in 0..div.n_blocks_x {
                let xr = div.x_segs_of_block(bx);
                for icg in 0..div.n_cgroups {
                    if !div.compact {
                        cursor = round_up(cursor as usize, wpl) as u64;
                    }
                    let pointer_words = cursor;
                    let mut rec_sizes = Vec::with_capacity(yr.len() * xr.len());
                    let mut rec_tags =
                        Vec::with_capacity(if adaptive { yr.len() * xr.len() } else { 0 });
                    for iy in yr.clone() {
                        for ix in xr.clone() {
                            let li = div.linear(SubTensorRef { iy, ix, icg });
                            let size = src.sizes_words[li] as usize;
                            if !div.compact {
                                cursor = round_up(cursor as usize, wpl) as u64;
                            }
                            addr_words[li] = cursor;
                            let at = src.addr_words[li] as usize;
                            let end = cursor as usize + size;
                            if payload.len() < end {
                                payload.resize(end, 0);
                            }
                            payload[cursor as usize..end]
                                .copy_from_slice(&self.mem[at..at + size]);
                            cursor += size as u64;
                            rec_sizes.push(size as u32);
                            if adaptive {
                                rec_tags.push(src.tags[li]);
                            }
                        }
                    }
                    records.push(crate::layout::metadata::BlockRecord {
                        pointer_words,
                        sizes_words: rec_sizes,
                        codec_tags: rec_tags,
                    });
                }
            }
        }
        let total_words =
            if div.compact { cursor } else { round_up(cursor as usize, wpl) as u64 };
        Ok(PackedFeatureMap {
            division: div.clone(),
            policy: src.policy,
            tags: src.tags.clone(),
            sizes_words: src.sizes_words.clone(),
            sizes_bits: src.sizes_bits.clone(),
            addr_words,
            metadata: MetadataTable {
                records,
                bits_per_record: src.metadata.bits_per_record,
            },
            payload: Some(payload),
            // Content-addressed, so rebasing leaves them valid as-is.
            checksums: src.checksums.clone(),
            total_words,
            words_per_line: wpl,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::layout::packer::Packer;
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tiling::division::{Division, DivisionMode};

    fn packed(seed: u64) -> (FeatureMap, PackedFeatureMap) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division =
            Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 24, 24, 16)
                .unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, seed));
        let p = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, true);
        (fm, p)
    }

    #[test]
    fn insert_fetch_roundtrip_at_rebased_addresses() {
        let mut store = TensorStore::new();
        let (fm_a, p_a) = packed(1);
        let (fm_b, p_b) = packed(2);
        let base_a = store.insert_packed("a", &p_a).unwrap();
        let base_b = store.insert_packed("b", &p_b).unwrap();
        assert_ne!(base_a, base_b, "tensors share one address space");
        for (name, fm) in [("a", &fm_a), ("b", &fm_b)] {
            let mut dram = Dram::default();
            let got = store.fetch_dense(name, &mut dram).unwrap();
            assert_eq!(got.as_slice(), fm.as_slice(), "{name}");
        }
        store.arena.check().unwrap();
    }

    #[test]
    fn remove_frees_and_space_is_reused() {
        let mut store = TensorStore::new();
        let (_, p) = packed(3);
        store.insert_packed("x", &p).unwrap();
        let end = store.arena.end_words();
        store.remove("x").unwrap();
        assert_eq!(store.arena.live_words(), 0);
        // Re-inserting reuses the freed extent, not new space.
        store.insert_packed("y", &p).unwrap();
        assert_eq!(store.arena.end_words(), end);
        store.arena.check().unwrap();
        assert!(store.remove("x").is_err());
    }

    #[test]
    fn export_is_canonical_contiguous_pack() {
        let mut store = TensorStore::new();
        let (_, p) = packed(4);
        // Push the tensor past address 0 so export really rebases.
        let (_, filler) = packed(5);
        store.insert_packed("filler", &filler).unwrap();
        store.insert_packed("t", &p).unwrap();
        let ex = store.export("t").unwrap();
        assert_eq!(ex.sizes_words, p.sizes_words);
        assert_eq!(ex.addr_words, p.addr_words, "canonical layout matches the packer's");
        assert_eq!(ex.total_words, p.total_words);
        assert_eq!(ex.payload.as_ref().unwrap(), p.payload.as_ref().unwrap());
        assert_eq!(ex.checksums, p.checksums, "checksums survive the rebase");
        let recs_ex: Vec<u64> =
            ex.metadata.records.iter().map(|r| r.pointer_words).collect();
        let recs_p: Vec<u64> =
            p.metadata.records.iter().map(|r| r.pointer_words).collect();
        assert_eq!(recs_ex, recs_p);
    }

    #[test]
    fn replacing_a_name_frees_the_old_extent() {
        let mut store = TensorStore::new();
        let (_, p) = packed(6);
        store.insert_packed("t", &p).unwrap();
        let live_once = store.arena.live_words();
        store.insert_packed("t", &p).unwrap();
        assert_eq!(store.arena.live_words(), live_once, "no leak on replace");
        store.arena.check().unwrap();
    }
}
