//! The tensor store: compressed, randomly accessible feature-map
//! storage with a real write path (paper §I — GrateTile keeps feature
//! maps "in a compressed yet randomly accessible format"; this module
//! is the storage engine a whole-network deployment of that claim
//! needs).
//!
//! * [`arena`] — a line-aligned extent allocator with a coalescing free
//!   list over one simulated DRAM address space; compressed sizes change
//!   on every rewrite, so freed space is reused first-fit.
//! * [`tensor_store`] — multiple named packed maps resident in that
//!   space, with absolute addresses feeding the fetch path and the
//!   DRAM timing model.
//! * [`writer`] — streaming tile-granular write-back: sub-tensors are
//!   compressed the moment the compute lane completes them, blocks are
//!   allocated and committed with their Fig. 7 records as they fill —
//!   no dense intermediate map ever materialises.
//! * [`container`] — the versioned `.grate` on-disk format (header +
//!   checksummed TOC + aligned payload segments) with random-access
//!   window reads off the file.

pub mod arena;
pub mod container;
pub mod tensor_store;
pub mod writer;

pub use arena::Arena;
pub use container::{Container, ContainerEntry};
pub use tensor_store::{StoredTensor, TensorStore};
pub use writer::{StoreWriter, WriteReport};
