//! Streaming tile-granular write-back: compress and store a layer's
//! output *as it is produced*, never materialising a dense intermediate
//! map.
//!
//! The compute lane hands the writer each finished output tile. The
//! writer scatters the tile into per-sub-tensor staging buffers (the
//! division is the one the *consumer* of this map will fetch under);
//! the moment a sub-tensor is fully covered it is compressed and its
//! staging freed, and the moment every sub-tensor of a Fig. 7 metadata
//! block is compressed the block is allocated from the store's arena,
//! its payload committed at real line-aligned addresses, its metadata
//! record emitted, and the DRAM write traffic accounted
//! ([`Stream::OutputWrite`] for payload lines, [`Stream::MetadataWrite`]
//! for the index).
//!
//! Accounting is bit-exact with the analytic producer model: the padded
//! payload bits equal `PackedFeatureMap::total_words × 16` of a
//! stop-the-world re-pack of the same map, and the metadata bits equal
//! `n_blocks × record_bits_for(division, policy)` (which is
//! `Division::total_meta_bits` under a fixed codec, plus 2 tag bits per
//! record slot under the adaptive policy) — asserted by
//! `tests/store_roundtrip.rs` against `sim::network::writeback_cost`.

use super::tensor_store::{StoredTensor, TensorStore};
use crate::compress::{stats, CodecPolicy, DistinctTracker, Registry};
use crate::layout::metadata::{record_bits_for, BlockRecord, MetadataTable};
use crate::layout::packer::PackedFeatureMap;
use crate::memsim::{Dram, Stream};
use crate::tensor::dense::bf16_quantise;
use crate::tiling::division::{Division, SubTensorRef};
use crate::util::error::Result;
use crate::util::round_up;
use crate::bail;

/// What one streamed write produced.
#[derive(Debug, Clone)]
pub struct WriteReport {
    /// Payload bits written, line-padded for aligned divisions — equals
    /// the analytic `total_words × 16`.
    pub payload_bits: u64,
    /// Metadata bits written (`n_blocks × bits_per_record`).
    pub metadata_bits: u64,
    /// High-water mark of dense staging, in words; bounded by a few
    /// tile rows, not the map (the "no dense intermediate" guarantee).
    pub peak_staged_words: usize,
    pub blocks: usize,
    pub subtensors: usize,
    /// Traffic with per-access trace (real addresses, for the timing
    /// model replay).
    pub dram: Dram,
}

impl WriteReport {
    /// Total producer-side bits (payload + index).
    pub fn writeback_bits(&self) -> u64 {
        self.payload_bits + self.metadata_bits
    }
}

/// Streams one tensor into a [`TensorStore`], tile by tile. Under
/// [`CodecPolicy::Adaptive`] every completed sub-tensor is sized for all
/// registered codecs from one fused stats scan of its staging buffer
/// and compressed with the winner — the same deterministic selection
/// rule the packer plans with, so a streamed write stays bit-exact with
/// a stop-the-world pack of the same map.
pub struct StoreWriter<'s> {
    store: &'s mut TensorStore,
    name: String,
    division: Division,
    policy: CodecPolicy,
    /// Distinct-value tracker for adaptive stats sizing (None when the
    /// policy needs no distinct tracking).
    tracker: Option<DistinctTracker>,
    /// Per-sub-tensor codec tags (adaptive only).
    tags: Vec<u8>,
    /// Record width in bits, codec tags included (`record_bits_for`).
    record_bits: usize,
    wpl: usize,
    /// Dense staging per sub-tensor, allocated on first touch, freed on
    /// compression.
    staging: Vec<Option<Vec<f32>>>,
    filled: Vec<u32>,
    /// Compressed payloads awaiting their block's completion.
    pending: Vec<Option<Vec<u16>>>,
    sizes_words: Vec<u32>,
    sizes_bits: Vec<u32>,
    /// Per-sub-tensor FNV-1a-64 over the compressed words — the v3
    /// integrity table, hashed at compression time (the words are
    /// already in cache) so it rides the streamed write for free.
    checksums: Vec<u64>,
    addr_words: Vec<u64>,
    records: Vec<Option<BlockRecord>>,
    block_remaining: Vec<u32>,
    extents: Vec<(u64, u64)>,
    dram: Dram,
    payload_bits: u64,
    meta_bits: u64,
    staged_words: usize,
    peak_staged_words: usize,
    completed_subs: usize,
}

impl<'s> StoreWriter<'s> {
    /// Start streaming tensor `name` under `division` (built for the
    /// map's consumer) and `policy`.
    pub fn new(
        store: &'s mut TensorStore,
        name: &str,
        division: Division,
        policy: impl Into<CodecPolicy>,
    ) -> Self {
        let policy = policy.into();
        let n = division.n_subtensors();
        let mut block_remaining = vec![0u32; division.n_blocks()];
        for iy in 0..division.ys.len() {
            for ix in 0..division.xs.len() {
                for icg in 0..division.n_cgroups {
                    block_remaining[division.block_linear(SubTensorRef { iy, ix, icg })] += 1;
                }
            }
        }
        let wpl = store.arena.words_per_line();
        let needs_tracker =
            policy.is_adaptive() && Registry::global().max_stats_dict_cap() > 0;
        Self {
            store,
            name: name.to_string(),
            tracker: needs_tracker.then(DistinctTracker::new),
            tags: if policy.is_adaptive() { vec![0; n] } else { Vec::new() },
            record_bits: record_bits_for(&division, policy),
            policy,
            wpl,
            staging: vec![None; n],
            filled: vec![0; n],
            pending: vec![None; n],
            sizes_words: vec![0; n],
            sizes_bits: vec![0; n],
            checksums: vec![0; n],
            addr_words: vec![0; n],
            records: vec![None; division.n_blocks()],
            block_remaining,
            division,
            extents: Vec::new(),
            dram: Dram::default().with_trace(),
            payload_bits: 0,
            meta_bits: 0,
            staged_words: 0,
            peak_staged_words: 0,
            completed_subs: 0,
        }
    }

    /// Write one output tile `[y0,y1) × [x0,x1) × [c0,c1)`; `data` is
    /// the tile in row-major (y, x, c) order. Tiles must partition the
    /// map (each element written exactly once); values are
    /// bf16-quantised on ingest like every stored map.
    pub fn write_tile(
        &mut self,
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
        data: &[f32],
    ) {
        debug_assert_eq!(data.len(), (y1 - y0) * (x1 - x0) * (c1 - c0));
        let (tw, tc) = (x1 - x0, c1 - c0);
        for r in self.division.intersecting(y0, y1, x0, x1, c0, c1) {
            let li = self.division.linear(r);
            let sy = self.division.ys[r.iy];
            let sx = self.division.xs[r.ix];
            let scg0 = r.icg * self.division.cd;
            let cd = self.division.cg_depth(r.icg);
            let n = sy.len * sx.len * cd;
            if self.staging[li].is_none() {
                self.staging[li] = Some(vec![0.0; n]);
                self.staged_words += n;
                self.peak_staged_words = self.peak_staged_words.max(self.staged_words);
            }
            let buf = self.staging[li].as_mut().unwrap();
            let iy0 = sy.start.max(y0);
            let iy1 = sy.end().min(y1);
            let ix0 = sx.start.max(x0);
            let ix1 = sx.end().min(x1);
            let ic0 = scg0.max(c0);
            let ic1 = (scg0 + cd).min(c1);
            let mut copied = 0u32;
            for y in iy0..iy1 {
                for x in ix0..ix1 {
                    for ch in ic0..ic1 {
                        let src = ((y - y0) * tw + (x - x0)) * tc + (ch - c0);
                        let dst = ((y - sy.start) * sx.len + (x - sx.start)) * cd + (ch - scg0);
                        buf[dst] = bf16_quantise(data[src]);
                        copied += 1;
                    }
                }
            }
            self.filled[li] += copied;
            debug_assert!(self.filled[li] as usize <= n, "element written twice");
            if self.filled[li] as usize == n {
                self.complete_subtensor(li, r);
            }
        }
    }

    /// A sub-tensor is fully covered: compress it, free its staging,
    /// and commit its block if it was the last one outstanding. In
    /// adaptive mode the codec is chosen here — one stats scan of the
    /// staging buffer sizes every registered codec exactly, and the
    /// shared deterministic min rule picks the winner the packer's plan
    /// pass would pick for the same data.
    fn complete_subtensor(&mut self, li: usize, r: SubTensorRef) {
        let buf = self.staging[li].take().expect("sub-tensor completed twice");
        self.staged_words -= buf.len();
        let reg = Registry::global();
        let codec = match self.policy {
            CodecPolicy::Fixed(s) => reg.compressor(s),
            CodecPolicy::Adaptive => {
                let stats = stats::scan(&buf, reg.max_stats_dict_cap(), self.tracker.as_mut());
                let mut sizes = Vec::with_capacity(reg.entries().len());
                // Same sizing substrate + min rule as the packer's plan
                // pass — the streamed selection cannot drift from it.
                reg.sizes_from(&stats, Some(&buf), &mut sizes);
                let tag = reg.select(&sizes, self.division.compact);
                self.tags[li] = tag;
                reg.compressor_of_tag(tag)
            }
        };
        // Single pass: the codec reports the idealised bit size of the
        // same encode (the old compress + compressed_bits re-scanned
        // every block).
        let (comp, bits) = codec.compress_with_bits(&buf);
        self.sizes_words[li] = comp.words.len() as u32;
        self.sizes_bits[li] = bits as u32;
        self.checksums[li] = super::container::fnv1a64_words(&comp.words);
        self.pending[li] = Some(comp.words);
        self.completed_subs += 1;
        let b = self.division.block_linear(r);
        self.block_remaining[b] -= 1;
        if self.block_remaining[b] == 0 {
            self.complete_block(b);
        }
    }

    /// Every sub-tensor of metadata block `b` is compressed: allocate
    /// the block's extent, commit payloads at line-aligned addresses in
    /// raster order (the Fig. 7b two-step layout), emit the record, and
    /// account the write traffic.
    fn complete_block(&mut self, b: usize) {
        let (by, bx, icg) = self.division.block_coords(b);
        let yr = self.division.y_segs_of_block(by);
        let xr = self.division.x_segs_of_block(bx);
        // Extent size: line-padded per sub-tensor for aligned modes,
        // word-compact otherwise.
        let mut extent = 0u64;
        for iy in yr.clone() {
            for ix in xr.clone() {
                let li = self.division.linear(SubTensorRef { iy, ix, icg });
                let sz = self.sizes_words[li] as u64;
                extent += if self.division.compact {
                    sz
                } else {
                    round_up(sz as usize, self.wpl) as u64
                };
            }
        }
        let alloc_len = round_up(extent.max(1) as usize, self.wpl) as u64;
        let base = self.store.arena.alloc(alloc_len);
        self.store.ensure_mem(base + alloc_len);
        let mut cursor = base;
        let mut rec_sizes = Vec::with_capacity(yr.len() * xr.len());
        let mut rec_tags =
            Vec::with_capacity(if self.policy.is_adaptive() { yr.len() * xr.len() } else { 0 });
        for iy in yr {
            for ix in xr.clone() {
                let li = self.division.linear(SubTensorRef { iy, ix, icg });
                let words = self.pending[li].take().expect("block completed twice");
                if !self.division.compact {
                    cursor = round_up(cursor as usize, self.wpl) as u64;
                }
                self.addr_words[li] = cursor;
                self.store.mem[cursor as usize..cursor as usize + words.len()]
                    .copy_from_slice(&words);
                let padded = if self.division.compact {
                    words.len() as u64
                } else {
                    round_up(words.len(), self.wpl) as u64
                };
                self.dram.access(Stream::OutputWrite, cursor, padded);
                self.payload_bits += padded * 16;
                cursor += words.len() as u64;
                rec_sizes.push(words.len() as u32);
                if self.policy.is_adaptive() {
                    rec_tags.push(self.tags[li]);
                }
            }
        }
        self.records[b] = Some(BlockRecord {
            pointer_words: base,
            sizes_words: rec_sizes,
            codec_tags: rec_tags,
        });
        // Tag-aware record width: adaptive records carry their 2-bit
        // codec tags, and the producer-side index traffic pays for them.
        self.meta_bits += self.record_bits as u64;
        self.dram.account_bits(Stream::MetadataWrite, self.record_bits as u64);
        self.extents.push((base, alloc_len));
    }

    /// Finish the stream: every sub-tensor must have been written.
    /// Installs the tensor in the store (replacing any previous tensor
    /// of the same name) and returns the write report.
    pub fn finish(self) -> Result<WriteReport> {
        let n = self.division.n_subtensors();
        if self.completed_subs != n {
            bail!(
                "store writer '{}': {} of {n} sub-tensors never fully written",
                self.name,
                n - self.completed_subs
            );
        }
        let StoreWriter {
            store,
            name,
            division,
            policy,
            tags,
            record_bits,
            wpl,
            sizes_words,
            sizes_bits,
            checksums,
            addr_words,
            records,
            block_remaining,
            mut extents,
            dram,
            payload_bits,
            meta_bits,
            peak_staged_words,
            ..
        } = self;
        let records: Vec<BlockRecord> =
            records.into_iter().map(|r| r.expect("block not committed")).collect();
        let packed = PackedFeatureMap {
            division,
            policy,
            tags,
            sizes_words,
            sizes_bits,
            addr_words,
            metadata: MetadataTable { records, bits_per_record: record_bits },
            payload: None,
            checksums,
            total_words: payload_bits / 16,
            words_per_line: wpl,
        };
        extents.sort_unstable();
        store.remove_if_present(&name);
        store.tensors.insert(name, StoredTensor { packed, extents });
        Ok(WriteReport {
            payload_bits,
            metadata_bits: meta_bits,
            peak_staged_words,
            blocks: block_remaining.len(),
            subtensors: n,
            dram,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::layout::packer::Packer;
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tensor::FeatureMap;
    use crate::tiling::division::DivisionMode;

    fn division(mode: DivisionMode, h: usize, w: usize, c: usize) -> Division {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, h, w, c, c);
        let tile = TileShape::new(8, 8, 8);
        Division::build(mode, &layer, &tile, &hw, h, w, c).unwrap()
    }

    /// Stream a map through the writer in 8×8 output tiles and compare
    /// against a stop-the-world pack of the same map: identical sizes,
    /// identical padded footprint, identical codec tags, identical
    /// fetched contents — for fixed codecs AND the adaptive policy.
    #[test]
    fn streamed_write_matches_monolithic_pack() {
        let hw = Platform::NvidiaSmallTile.hardware();
        for mode in [
            DivisionMode::GrateTile { n: 8 },
            DivisionMode::Uniform { edge: 4 },
            DivisionMode::Uniform { edge: 1 },
        ] {
            for policy in [
                CodecPolicy::Fixed(Scheme::Bitmask),
                CodecPolicy::Fixed(Scheme::Zrlc),
                CodecPolicy::Adaptive,
            ] {
                let fm = generate(24, 24, 16, SparsityParams::clustered(0.45, 7));
                let div = division(mode, 24, 24, 16);
                let reference = Packer::new(hw, policy).pack(&fm, &div, true);

                let mut store = TensorStore::new();
                let mut w = StoreWriter::new(&mut store, "t", div.clone(), policy);
                for ty in 0..3 {
                    for tx in 0..3 {
                        let (y0, x0) = (ty * 8, tx * 8);
                        let block = fm.extract_block(y0, x0, 0, 8, 8, 16);
                        w.write_tile(y0, y0 + 8, x0, x0 + 8, 0, 16, &block);
                    }
                }
                let report = w.finish().unwrap();
                let t = store.get("t").unwrap();
                assert_eq!(t.packed.sizes_words, reference.sizes_words, "{mode:?} {policy:?}");
                assert_eq!(t.packed.tags, reference.tags, "{mode:?} {policy:?} tags");
                assert_eq!(
                    t.packed.checksums, reference.checksums,
                    "{mode:?} {policy:?} checksums"
                );
                assert_eq!(t.packed.total_words, reference.total_words);
                assert_eq!(
                    report.metadata_bits,
                    reference.meta_total_bits(),
                    "{mode:?} {policy:?} meta bits"
                );
                if !policy.is_adaptive() {
                    assert_eq!(report.metadata_bits, div.total_meta_bits());
                }
                assert_eq!(report.payload_bits, reference.total_words * 16);
                assert!(report.peak_staged_words > 0);
                store.arena.check().unwrap();

                let mut dram = Dram::default();
                let got = store.fetch_dense("t", &mut dram).unwrap();
                assert_eq!(got.as_slice(), fm.as_slice(), "{mode:?} {policy:?}");
            }
        }
    }

    /// Interleave a reader of tensor A with a streamed write of tensor B
    /// in the same store: addresses never collide.
    #[test]
    fn write_alongside_resident_tensor() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let fm_a = generate(24, 24, 16, SparsityParams::clustered(0.5, 1));
        let fm_b = generate(24, 24, 16, SparsityParams::clustered(0.3, 2));
        let div = division(DivisionMode::GrateTile { n: 8 }, 24, 24, 16);
        let mut store = TensorStore::new();
        let packed_a = Packer::new(hw, Scheme::Bitmask).pack(&fm_a, &div, true);
        store.insert_packed("a", &packed_a).unwrap();
        let (snap_a, seg_a) = store.snapshot("a").unwrap();

        let mut w = StoreWriter::new(&mut store, "b", div.clone(), Scheme::Bitmask);
        let mut fetcher = crate::layout::Fetcher::with_source(&snap_a, Box::new(seg_a));
        let mut dram = Dram::default();
        for ty in 0..3 {
            for tx in 0..3 {
                let (y0, x0) = (ty * 8, tx * 8);
                // Reader and writer interleaved.
                let _ = fetcher.fetch_window(&mut dram, y0, y0 + 8, x0, x0 + 8, 0, 16);
                let block = fm_b.extract_block(y0, x0, 0, 8, 8, 16);
                w.write_tile(y0, y0 + 8, x0, x0 + 8, 0, 16, &block);
            }
        }
        w.finish().unwrap();
        store.arena.check().unwrap();
        let mut d2 = Dram::default();
        assert_eq!(store.fetch_dense("a", &mut d2).unwrap().as_slice(), fm_a.as_slice());
        assert_eq!(store.fetch_dense("b", &mut d2).unwrap().as_slice(), fm_b.as_slice());
    }

    #[test]
    fn incomplete_write_errors() {
        let div = division(DivisionMode::GrateTile { n: 8 }, 24, 24, 16);
        let mut store = TensorStore::new();
        let mut w = StoreWriter::new(&mut store, "t", div, Scheme::Bitmask);
        let fm = FeatureMap::zeros(24, 24, 16);
        let block = fm.extract_block(0, 0, 0, 8, 8, 16);
        w.write_tile(0, 8, 0, 8, 0, 16, &block);
        let e = w.finish().unwrap_err();
        assert!(e.to_string().contains("never fully written"), "{e}");
    }
}
