//! Line-aligned arena allocator over the simulated DRAM address space.
//!
//! The paper stores sub-tensors "in aligned addresses" (§III-C); a
//! deployment that keeps every intermediate map compressed needs a real
//! allocator on top of that rule, because compressed sizes change on
//! every rewrite (a map's activations differ request to request). The
//! arena hands out cache-line-aligned extents from one word-addressed
//! space, keeps a sorted coalescing free list, and reuses freed space
//! first-fit — so a long-running server's address space stays bounded
//! by its live compressed footprint, not its allocation history.
//!
//! All sizes are in 16-bit words; every extent starts and ends on a
//! line boundary (`words_per_line` words). Invariants (property-tested
//! in `tests/property.rs`):
//!
//! * live extents never overlap each other or the free list;
//! * `live_words + free_words == end_words` at all times;
//! * adjacent free extents are always coalesced.

use crate::util::round_up;
use std::collections::BTreeMap;

/// A line-aligned extent allocator with a coalescing free list.
#[derive(Debug, Clone)]
pub struct Arena {
    words_per_line: usize,
    /// Sorted, coalesced free extents `(addr_words, len_words)`.
    free: Vec<(u64, u64)>,
    /// Live extents `addr -> len` (for invariant checks and stats).
    live: BTreeMap<u64, u64>,
    /// End of the address space in words (high-water mark).
    end_words: u64,
    /// Counters.
    pub allocs: u64,
    pub frees: u64,
}

impl Default for Arena {
    fn default() -> Self {
        Self::new(crate::config::hardware::WORDS_PER_LINE)
    }
}

impl Arena {
    pub fn new(words_per_line: usize) -> Self {
        assert!(words_per_line > 0);
        Self {
            words_per_line,
            free: Vec::new(),
            live: BTreeMap::new(),
            end_words: 0,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn words_per_line(&self) -> usize {
        self.words_per_line
    }

    fn lines(&self, words: u64) -> u64 {
        round_up(words as usize, self.words_per_line) as u64
    }

    /// Allocate an extent of at least `words` words (rounded up to whole
    /// lines). First-fit from the free list, else grows the space.
    /// Returns the line-aligned word address.
    pub fn alloc(&mut self, words: u64) -> u64 {
        let need = self.lines(words.max(1));
        self.allocs += 1;
        // First fit.
        for i in 0..self.free.len() {
            let (addr, len) = self.free[i];
            if len >= need {
                if len == need {
                    self.free.remove(i);
                } else {
                    self.free[i] = (addr + need, len - need);
                }
                self.live.insert(addr, need);
                return addr;
            }
        }
        // Grow.
        let addr = self.end_words;
        self.end_words += need;
        self.live.insert(addr, need);
        addr
    }

    /// Free a previously allocated extent by address. Panics on a
    /// double-free or an address that was never allocated.
    pub fn free(&mut self, addr: u64) {
        let len = self.live.remove(&addr).expect("arena: free of unallocated address");
        self.frees += 1;
        // Insert sorted, then coalesce with both neighbours.
        let i = self.free.partition_point(|&(a, _)| a < addr);
        self.free.insert(i, (addr, len));
        // Coalesce right.
        if i + 1 < self.free.len() && self.free[i].0 + self.free[i].1 == self.free[i + 1].0 {
            self.free[i].1 += self.free[i + 1].1;
            self.free.remove(i + 1);
        }
        // Coalesce left.
        if i > 0 && self.free[i - 1].0 + self.free[i - 1].1 == self.free[i].0 {
            self.free[i - 1].1 += self.free[i].1;
            self.free.remove(i);
        }
    }

    /// Reallocate: free `addr` and allocate `new_words` (the compressed
    /// size changed on rewrite). The freed extent is eligible for the
    /// new allocation, so an in-place or shrinking rewrite reuses its
    /// own space.
    pub fn realloc(&mut self, addr: u64, new_words: u64) -> u64 {
        self.free(addr);
        self.alloc(new_words)
    }

    /// Words currently allocated (line-rounded).
    pub fn live_words(&self) -> u64 {
        self.live.values().sum()
    }

    /// Words currently on the free list.
    pub fn free_words(&self) -> u64 {
        self.free.iter().map(|&(_, l)| l).sum()
    }

    /// Total address-space size in words (high-water mark).
    pub fn end_words(&self) -> u64 {
        self.end_words
    }

    /// Number of live extents.
    pub fn live_extents(&self) -> usize {
        self.live.len()
    }

    /// Fraction of the address space currently live (1.0 = no holes).
    pub fn utilization(&self) -> f64 {
        if self.end_words == 0 {
            return 1.0;
        }
        self.live_words() as f64 / self.end_words as f64
    }

    /// Check every structural invariant; returns a description of the
    /// first violation, if any.
    pub fn check(&self) -> Result<(), String> {
        // Live extents: line-aligned, in-bounds, non-overlapping.
        let mut prev_end = 0u64;
        for (&addr, &len) in &self.live {
            if addr % self.words_per_line as u64 != 0 {
                return Err(format!("live extent at {addr} not line-aligned"));
            }
            if len % self.words_per_line as u64 != 0 {
                return Err(format!("live extent len {len} not line-granular"));
            }
            if addr < prev_end {
                return Err(format!("live extents overlap at {addr}"));
            }
            prev_end = addr + len;
        }
        if prev_end > self.end_words {
            return Err(format!("live extent past end {prev_end} > {}", self.end_words));
        }
        // Free list: sorted, coalesced, disjoint from live.
        for w in self.free.windows(2) {
            let ((a0, l0), (a1, _)) = (w[0], w[1]);
            if a0 + l0 > a1 {
                return Err(format!("free extents overlap at {a1}"));
            }
            if a0 + l0 == a1 {
                return Err(format!("free extents not coalesced at {a1}"));
            }
        }
        for &(addr, len) in &self.free {
            // Any live extent starting inside [addr, addr+len)?
            if self.live.range(addr..addr + len).next().is_some() {
                return Err(format!("free extent at {addr} overlaps a live extent"));
            }
            // Any live extent covering addr?
            if let Some((&la, &ll)) = self.live.range(..addr).next_back() {
                if la + ll > addr {
                    return Err(format!("live extent at {la} overlaps free extent at {addr}"));
                }
            }
        }
        // Accounting closes.
        if self.live_words() + self.free_words() != self.end_words {
            return Err(format!(
                "accounting leak: live {} + free {} != end {}",
                self.live_words(),
                self.free_words(),
                self.end_words
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_line_aligned_and_rounded() {
        let mut a = Arena::new(8);
        let p = a.alloc(3);
        assert_eq!(p % 8, 0);
        let q = a.alloc(9);
        assert_eq!(q, 8); // 3 words consumed one full line
        assert_eq!(a.end_words(), 8 + 16);
        a.check().unwrap();
    }

    #[test]
    fn free_coalesces_and_is_reused() {
        let mut a = Arena::new(8);
        let p0 = a.alloc(8);
        let p1 = a.alloc(8);
        let p2 = a.alloc(8);
        a.free(p0);
        a.free(p2);
        a.check().unwrap();
        assert_eq!(a.free_words(), 16);
        a.free(p1); // middle free must merge all three into one extent
        a.check().unwrap();
        assert_eq!(a.free_words(), 24);
        // A 24-word alloc now fits without growing.
        let end = a.end_words();
        let r = a.alloc(24);
        assert_eq!(r, 0);
        assert_eq!(a.end_words(), end);
        a.check().unwrap();
    }

    #[test]
    fn realloc_reuses_own_space_when_shrinking() {
        let mut a = Arena::new(8);
        let p = a.alloc(64);
        let _other = a.alloc(8);
        let q = a.realloc(p, 32);
        assert_eq!(q, p, "shrink should land first-fit in its own hole");
        a.check().unwrap();
        assert_eq!(a.free_words(), 32);
    }

    #[test]
    #[should_panic(expected = "unallocated")]
    fn double_free_panics() {
        let mut a = Arena::new(8);
        let p = a.alloc(8);
        a.free(p);
        a.free(p);
    }

    #[test]
    fn utilization_and_counters() {
        let mut a = Arena::new(8);
        assert_eq!(a.utilization(), 1.0);
        let p = a.alloc(8);
        let _q = a.alloc(8);
        a.free(p);
        assert!((a.utilization() - 0.5).abs() < 1e-12);
        assert_eq!(a.allocs, 2);
        assert_eq!(a.frees, 1);
        assert_eq!(a.live_extents(), 1);
    }
}
