//! The `.grate` container: a versioned on-disk format for packed
//! feature maps, supporting random-access window reads.
//!
//! ```text
//! ┌────────────────────────────────────────────────────────────┐
//! │ header   magic "GRTC" · u32 version · u32 n_tensors        │
//! │          u64 toc_len · u64 toc_fnv1a64                     │
//! ├────────────────────────────────────────────────────────────┤
//! │ TOC      per tensor: name · codec policy (v2; + packed     │
//! │          2-bit tag table for adaptive tensors) · division ·│
//! │          sizes/addr tables · per-sub-tensor fnv1a64        │
//! │          checksum table (v3) · Fig. 7 block records ·      │
//! │          payload (offset, words, fnv1a64)                  │
//! ├────────────────────────────────────────────────────────────┤
//! │ payload  one 16-byte-aligned segment per tensor,           │
//! │ segments little-endian u16 words, block-raster layout      │
//! └────────────────────────────────────────────────────────────┘
//! ```
//!
//! The TOC is small and loaded eagerly (checksum-verified); payloads
//! stay on disk. [`Container::fetch_window`] reads only the compressed
//! sub-tensors a window touches, via a seeking [`PayloadSource`] — the
//! on-disk analogue of the paper's "compressed yet randomly accessible"
//! claim. Addresses in a container tensor are relative to its payload
//! segment and identical to a fresh `Packer` layout (canonical form),
//! so `serve → fetch` round-trips bit-exactly against the in-memory
//! path.

// Decoder surface: unwrap() is a denied panic path in production
// code (tests may unwrap freely).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use crate::compress::{CodecPolicy, Registry};
use crate::layout::fetcher::{DenseWindow, Fetcher, PayloadSource};
use crate::layout::metadata::{BlockRecord, MetadataTable};
use crate::layout::packer::PackedFeatureMap;
use crate::memsim::Dram;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionMode, Seg, SubTensorRef};
use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"GRTC";
/// Current write version. v2 added the codec *policy* byte and, for
/// adaptive tensors, the packed 2-bit codec tag table in the TOC. v3
/// added the per-sub-tensor integrity checksum table (FNV-1a-64 over
/// each sub-tensor's compressed words) the fetcher verifies on every
/// payload read. The reader accepts v1 (implicit uniform codec from
/// the scheme byte), v2, and v3 — pre-v3 tensors decode with an empty
/// checksum table, which disables per-sub-tensor verification.
const VERSION: u32 = 3;
const MIN_VERSION: u32 = 1;
const HEADER_BYTES: u64 = 4 + 4 + 4 + 8 + 8;

/// FNV-1a 64-bit offset basis (seed for [`fnv1a64_continue`]).
pub const FNV1A64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit (dependency-free checksum), one-shot.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_continue(FNV1A64_OFFSET, bytes)
}

/// Continue an FNV-1a 64-bit digest from state `h` — the chaining form
/// incremental hashers (the serving simulator's output checksum) fold
/// over.
pub fn fnv1a64_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a-64 over a word slice as little-endian bytes — the
/// per-sub-tensor checksum rule shared by the packer, the streaming
/// store writer, the v3 TOC table, and the fetcher's verify-on-fetch.
pub fn fnv1a64_words(words: &[u16]) -> u64 {
    let mut h = FNV1A64_OFFSET;
    for &w in words {
        h = fnv1a64_continue(h, &w.to_le_bytes());
    }
    h
}

// ---- byte-level encode/decode helpers -------------------------------

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn usize32(&mut self, v: usize) {
        self.u32(v as u32);
    }
    fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            bail!("container: truncated TOC at byte {}", self.at);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    /// `take` an exact-size array (`try_into` cannot fail on the
    /// `take(N)` slice, but the decoder carries no panic paths at all).
    fn take_arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        self.take(N)?
            .try_into()
            .map_err(|_| err!("container: truncated TOC at byte {}", self.at))
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take_arr()?))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_arr()?))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_arr()?))
    }
    fn usize32(&mut self) -> Result<usize> {
        Ok(self.u32()? as usize)
    }
}

// Codec identifiers on disk are the registry's stable 2-bit tags (the
// v1 scheme byte used the same assignment, so v1 files parse with the
// same table — no per-format match arms).

/// Pack per-sub-tensor 2-bit codec tags, four to a byte, low bits
/// first — the v2 TOC tag table.
fn pack_tags(tags: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; tags.len().div_ceil(4)];
    for (i, &t) in tags.iter().enumerate() {
        debug_assert!(t < 4);
        out[i / 4] |= (t & 0x3) << ((i % 4) * 2);
    }
    out
}

/// Inverse of [`pack_tags`] for `n` sub-tensors.
fn unpack_tags(bytes: &[u8], n: usize) -> Vec<u8> {
    (0..n).map(|i| (bytes.get(i / 4).copied().unwrap_or(0) >> ((i % 4) * 2)) & 0x3).collect()
}

/// Rebuild each record's per-slot codec tags from the linear tag table
/// (records are stored tag-less in the TOC; the block raster walk is
/// the same one the packer assigns records in).
fn fill_record_tags(div: &Division, tags: &[u8], records: &mut [BlockRecord]) {
    let mut bi = 0usize;
    for by in 0..div.n_blocks_y {
        let yr = div.y_segs_of_block(by);
        for bx in 0..div.n_blocks_x {
            let xr = div.x_segs_of_block(bx);
            for icg in 0..div.n_cgroups {
                let rec = &mut records[bi];
                rec.codec_tags.clear();
                for iy in yr.clone() {
                    for ix in xr.clone() {
                        let li = div.linear(SubTensorRef { iy, ix, icg });
                        rec.codec_tags.push(tags[li]);
                    }
                }
                bi += 1;
            }
        }
    }
}

fn encode_division(e: &mut Enc, d: &Division) {
    let (tag, param) = match d.mode {
        DivisionMode::Uniform { edge } => (0u8, edge as u32),
        DivisionMode::GrateTile { n } => (1, n as u32),
        DivisionMode::WholeMap => (2, 0),
        // Edge and anchor both fit comfortably in 16 bits each.
        DivisionMode::Anchored { edge, anchor } => (3, ((edge as u32) << 16) | anchor as u32),
    };
    e.u8(tag);
    e.u32(param);
    e.usize32(d.fm_h);
    e.usize32(d.fm_w);
    e.usize32(d.fm_c);
    e.usize32(d.cd);
    e.usize32(d.n_cgroups);
    for segs in [&d.ys, &d.xs] {
        e.usize32(segs.len());
        for s in segs {
            e.usize32(s.start);
            e.usize32(s.len);
        }
    }
    for blocks in [&d.block_of_y, &d.block_of_x] {
        e.usize32(blocks.len());
        for &b in blocks {
            e.usize32(b);
        }
    }
    e.usize32(d.n_blocks_y);
    e.usize32(d.n_blocks_x);
    e.usize32(d.meta_bits_per_block);
    e.u8(d.compact as u8);
}

fn decode_division(dec: &mut Dec) -> Result<Division> {
    let tag = dec.u8()?;
    let param = dec.u32()? as usize;
    let mode = match tag {
        0 => DivisionMode::Uniform { edge: param },
        1 => DivisionMode::GrateTile { n: param },
        2 => DivisionMode::WholeMap,
        3 => DivisionMode::Anchored { edge: param >> 16, anchor: param & 0xFFFF },
        other => bail!("container: unknown division tag {other}"),
    };
    let fm_h = dec.usize32()?;
    let fm_w = dec.usize32()?;
    let fm_c = dec.usize32()?;
    let cd = dec.usize32()?;
    let n_cgroups = dec.usize32()?;
    // On-disk order matches the encoder's `[y, x]` loops; reading each
    // table directly (rather than pop()-ing a two-element Vec) keeps the
    // decode path free of unwraps.
    fn read_segs(dec: &mut Dec) -> Result<Vec<Seg>> {
        let n = dec.usize32()?;
        let mut segs = Vec::with_capacity(n);
        for _ in 0..n {
            let start = dec.usize32()?;
            let len = dec.usize32()?;
            segs.push(Seg { start, len });
        }
        Ok(segs)
    }
    fn read_index(dec: &mut Dec) -> Result<Vec<usize>> {
        let n = dec.usize32()?;
        let mut b = Vec::with_capacity(n);
        for _ in 0..n {
            b.push(dec.usize32()?);
        }
        Ok(b)
    }
    let ys = read_segs(dec)?;
    let xs = read_segs(dec)?;
    let block_of_y = read_index(dec)?;
    let block_of_x = read_index(dec)?;
    let n_blocks_y = dec.usize32()?;
    let n_blocks_x = dec.usize32()?;
    let meta_bits_per_block = dec.usize32()?;
    let compact = dec.u8()? != 0;
    if ys.len() != block_of_y.len() || xs.len() != block_of_x.len() {
        bail!("container: axis/block table length mismatch");
    }
    Ok(Division {
        mode,
        fm_h,
        fm_w,
        fm_c,
        ys,
        xs,
        cd,
        n_cgroups,
        block_of_y,
        block_of_x,
        n_blocks_y,
        n_blocks_x,
        meta_bits_per_block,
        compact,
    })
}

// ---- the container --------------------------------------------------

/// One tensor's TOC entry: the full layout plus where its payload lives
/// in the file.
#[derive(Debug, Clone)]
pub struct ContainerEntry {
    pub name: String,
    /// Layout with payload-segment-relative addresses; `payload: None`.
    pub packed: PackedFeatureMap,
    /// Absolute file offset of the payload segment (16-byte aligned).
    pub payload_offset: u64,
    pub payload_words: u64,
    pub payload_checksum: u64,
}

impl ContainerEntry {
    pub fn shape(&self) -> (usize, usize, usize) {
        let d = &self.packed.division;
        (d.fm_h, d.fm_w, d.fm_c)
    }
}

/// An opened `.grate` file: eager TOC, on-demand payload.
#[derive(Debug)]
pub struct Container {
    pub path: PathBuf,
    /// On-disk format version the file was written with (1, 2 or 3).
    pub version: u32,
    pub entries: Vec<ContainerEntry>,
}

/// Seeking payload source over one payload segment of the file.
pub struct FilePayload {
    file: File,
    base_bytes: u64,
}

impl PayloadSource for FilePayload {
    fn read_words(&mut self, addr_words: u64, n_words: usize, out: &mut Vec<u16>) {
        // A seek/read failure (file truncated or shrunk after open) must
        // not panic mid-fetch: deliver zeros for the unreadable span and
        // let the integrity layer catch it — a zeroed sub-tensor fails
        // its v3 checksum, and `Container::reader` already rejects
        // segments the TOC says are short. Exactly `n_words` words are
        // always appended (the fetcher's span accounting relies on it).
        let mut buf = vec![0u8; n_words * 2];
        if self.file.seek(SeekFrom::Start(self.base_bytes + addr_words * 2)).is_ok() {
            let mut filled = 0;
            while filled < buf.len() {
                match self.file.read(&mut buf[filled..]) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => filled += n,
                }
            }
        }
        out.extend(buf.chunks_exact(2).map(|b| u16::from_le_bytes([b[0], b[1]])));
    }
}

fn encode_entry(
    e: &mut Enc,
    version: u32,
    name: &str,
    p: &PackedFeatureMap,
    offset: u64,
    checksum: u64,
) {
    let reg = Registry::global();
    e.u16(name.len() as u16);
    e.bytes(name.as_bytes());
    match (version, p.policy) {
        // v1: a bare scheme byte (the registry tag — same assignment).
        (1, CodecPolicy::Fixed(s)) => e.u8(reg.tag_of(s)),
        (1, CodecPolicy::Adaptive) => {
            // lint: allow(panic-in-decoder, write-side dead arm - write_with_version bails on adaptive entries before encoding v1)
            unreachable!("write_with_version rejects adaptive tensors for v1")
        }
        // v2: a policy byte, then the scheme tag for fixed tensors.
        (_, CodecPolicy::Fixed(s)) => {
            e.u8(0);
            e.u8(reg.tag_of(s));
        }
        (_, CodecPolicy::Adaptive) => e.u8(1),
    }
    encode_division(e, &p.division);
    e.usize32(p.sizes_words.len());
    for &s in &p.sizes_words {
        e.u32(s);
    }
    for &s in &p.sizes_bits {
        e.u32(s);
    }
    for &a in &p.addr_words {
        e.u64(a);
    }
    if version >= 2 && p.policy.is_adaptive() {
        // The v2 tag table: 2 bits per sub-tensor, packed 4 per byte.
        e.bytes(&pack_tags(&p.tags));
    }
    if version >= 3 {
        // The v3 integrity table: one presence byte (a map re-exported
        // from a pre-v3 file carries no checksums), then one FNV-1a-64
        // per sub-tensor.
        let present = p.checksums.len() == p.sizes_words.len();
        e.u8(present as u8);
        if present {
            for &c in &p.checksums {
                e.u64(c);
            }
        }
    }
    e.usize32(p.metadata.records.len());
    for r in &p.metadata.records {
        e.u64(r.pointer_words);
        e.u16(r.sizes_words.len() as u16);
        for &s in &r.sizes_words {
            e.u32(s);
        }
    }
    e.usize32(p.metadata.bits_per_record);
    e.u64(p.total_words);
    e.usize32(p.line_words());
    e.u64(offset);
    e.u64(p.payload.as_ref().map(|v| v.len() as u64).unwrap_or(0));
    e.u64(checksum);
}

fn decode_entry(dec: &mut Dec, version: u32) -> Result<ContainerEntry> {
    let reg = Registry::global();
    let name_len = dec.u16()? as usize;
    let name = String::from_utf8(dec.take(name_len)?.to_vec())
        .map_err(|e| err!("container: bad tensor name: {e}"))?;
    let policy = if version == 1 {
        // v1: bare scheme byte — an implicit uniform (fixed) codec.
        CodecPolicy::Fixed(reg.scheme_of_tag(dec.u8()?)?)
    } else {
        match dec.u8()? {
            0 => CodecPolicy::Fixed(reg.scheme_of_tag(dec.u8()?)?),
            1 => CodecPolicy::Adaptive,
            other => bail!("container '{name}': unknown codec policy byte {other}"),
        }
    };
    let division = decode_division(dec)?;
    let n = dec.usize32()?;
    if n != division.n_subtensors() {
        bail!("container '{name}': {n} sizes for {} sub-tensors", division.n_subtensors());
    }
    let mut sizes_words = Vec::with_capacity(n);
    for _ in 0..n {
        sizes_words.push(dec.u32()?);
    }
    let mut sizes_bits = Vec::with_capacity(n);
    for _ in 0..n {
        sizes_bits.push(dec.u32()?);
    }
    let mut addr_words = Vec::with_capacity(n);
    for _ in 0..n {
        addr_words.push(dec.u64()?);
    }
    let tags = if policy.is_adaptive() {
        let tags = unpack_tags(dec.take(n.div_ceil(4))?, n);
        for &t in &tags {
            reg.scheme_of_tag(t)
                .map_err(|e| err!("container '{name}': corrupt tag table: {e}"))?;
        }
        tags
    } else {
        Vec::new()
    };
    let checksums = if version >= 3 {
        match dec.u8()? {
            0 => Vec::new(),
            1 => {
                let mut c = Vec::with_capacity(n);
                for _ in 0..n {
                    c.push(dec.u64()?);
                }
                c
            }
            other => bail!("container '{name}': bad checksum presence byte {other}"),
        }
    } else {
        Vec::new()
    };
    let n_rec = dec.usize32()?;
    if n_rec != division.n_blocks() {
        bail!("container '{name}': {n_rec} records for {} blocks", division.n_blocks());
    }
    let mut records = Vec::with_capacity(n_rec);
    for _ in 0..n_rec {
        let pointer_words = dec.u64()?;
        let k = dec.u16()? as usize;
        let mut sizes = Vec::with_capacity(k);
        for _ in 0..k {
            sizes.push(dec.u32()?);
        }
        records.push(BlockRecord { pointer_words, sizes_words: sizes, codec_tags: Vec::new() });
    }
    if policy.is_adaptive() {
        fill_record_tags(&division, &tags, &mut records);
    }
    let bits_per_record = dec.usize32()?;
    let total_words = dec.u64()?;
    let words_per_line = dec.usize32()?;
    let payload_offset = dec.u64()?;
    let payload_words = dec.u64()?;
    let payload_checksum = dec.u64()?;
    Ok(ContainerEntry {
        name,
        packed: PackedFeatureMap {
            division,
            policy,
            tags,
            sizes_words,
            sizes_bits,
            addr_words,
            metadata: MetadataTable { records, bits_per_record },
            payload: None,
            checksums,
            total_words,
            words_per_line,
        },
        payload_offset,
        payload_words,
        payload_checksum,
    })
}

fn words_to_bytes(words: &[u16]) -> Vec<u8> {
    let mut b = Vec::with_capacity(words.len() * 2);
    for &w in words {
        b.extend_from_slice(&w.to_le_bytes());
    }
    b
}

impl Container {
    /// Write `entries` (payload-carrying packed maps) to `path` in the
    /// current format version.
    pub fn write(path: &Path, entries: &[(String, &PackedFeatureMap)]) -> Result<()> {
        Self::write_with_version(path, entries, VERSION)
    }

    /// Write a container pinned to a specific format version (`1`–`3`).
    /// v1 has no codec-policy byte, so adaptive tensors are rejected;
    /// v2 has no integrity table. This exists so the backward-compat
    /// suite can materialise genuine v1/v2 fixtures.
    pub fn write_with_version(
        path: &Path,
        entries: &[(String, &PackedFeatureMap)],
        version: u32,
    ) -> Result<()> {
        if !(MIN_VERSION..=VERSION).contains(&version) {
            bail!("container write: unsupported version {version}");
        }
        for (name, p) in entries {
            if p.payload.is_none() {
                bail!("container write: tensor '{name}' has no payload");
            }
            if version == 1 && p.policy.is_adaptive() {
                bail!(
                    "container write: tensor '{name}' is adaptive-coded; \
                     v1 containers only hold uniform-codec tensors"
                );
            }
        }
        // Pass 1 with zero offsets fixes the TOC length (offsets are
        // fixed-width), pass 2 fills the real ones.
        let toc_len = {
            let mut e = Enc(Vec::new());
            for (name, p) in entries {
                encode_entry(&mut e, version, name, p, 0, 0);
            }
            e.0.len() as u64
        };
        let mut offset = (HEADER_BYTES + toc_len).div_ceil(16) * 16;
        let mut toc = Enc(Vec::new());
        let mut payloads: Vec<(u64, Vec<u8>)> = Vec::with_capacity(entries.len());
        for (name, p) in entries {
            let words = p.payload.as_ref().ok_or_else(|| {
                err!("container: tensor '{name}' has no payload (pack with with_payload=true)")
            })?;
            let bytes = words_to_bytes(words);
            encode_entry(&mut toc, version, name, p, offset, fnv1a64(&bytes));
            let next = (offset + bytes.len() as u64).div_ceil(16) * 16;
            payloads.push((offset, bytes));
            offset = next;
        }
        debug_assert_eq!(toc.0.len() as u64, toc_len);

        let mut f = File::create(path)
            .with_context(|| format!("creating container {}", path.display()))?;
        let mut header = Enc(Vec::new());
        header.bytes(MAGIC);
        header.u32(version);
        header.u32(entries.len() as u32);
        header.u64(toc_len);
        header.u64(fnv1a64(&toc.0));
        f.write_all(&header.0)?;
        f.write_all(&toc.0)?;
        for (off, bytes) in payloads {
            let pos = f.stream_position()?;
            if pos < off {
                f.write_all(&vec![0u8; (off - pos) as usize])?;
            }
            f.write_all(&bytes)?;
        }
        f.flush()?;
        Ok(())
    }

    /// Open a container, parsing and checksum-verifying the TOC;
    /// payloads stay on disk. Accepts every version back to v1 (which
    /// carries an implicit uniform codec per tensor).
    pub fn open(path: &Path) -> Result<Container> {
        let mut f = File::open(path)
            .with_context(|| format!("opening container {}", path.display()))?;
        let mut header = vec![0u8; HEADER_BYTES as usize];
        f.read_exact(&mut header).context("container header")?;
        let mut dec = Dec { buf: &header, at: 0 };
        if dec.take(4)? != MAGIC {
            bail!("{}: not a .grate container (bad magic)", path.display());
        }
        let version = dec.u32()?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            bail!("{}: unsupported container version {version}", path.display());
        }
        let n_tensors = dec.u32()? as usize;
        let toc_len = dec.u64()? as usize;
        let toc_sum = dec.u64()?;
        // Bound the TOC allocation by the actual file size before
        // trusting the header-declared length — a corrupt or hostile
        // header must produce a typed error, not an OOM attempt.
        let file_len = f
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if toc_len as u64 > file_len.saturating_sub(HEADER_BYTES) {
            bail!(
                "{}: TOC length {toc_len} exceeds file size {file_len} (truncated or corrupt)",
                path.display()
            );
        }
        let mut toc = vec![0u8; toc_len];
        f.read_exact(&mut toc).context("container TOC")?;
        if fnv1a64(&toc) != toc_sum {
            bail!("{}: TOC checksum mismatch (corrupt container)", path.display());
        }
        let mut dec = Dec { buf: &toc, at: 0 };
        // The header's tensor count is *not* covered by the TOC checksum
        // — never pre-reserve from it (a flipped count must end in a
        // decode error below, not a giant allocation here).
        let mut entries = Vec::new();
        for _ in 0..n_tensors {
            entries.push(decode_entry(&mut dec, version)?);
        }
        Ok(Container { path: path.to_path_buf(), version, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&ContainerEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                err!(
                    "container {}: no tensor '{name}' (have: {:?})",
                    self.path.display(),
                    self.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>()
                )
            })
    }

    /// Reusable random-access reader over one tensor: a single opened
    /// file handle serving any number of window fetches. Use this in
    /// hot paths (window-per-tile consumers); [`Container::fetch_window`]
    /// is the one-shot convenience that opens per call.
    pub fn reader(&self, name: &str) -> Result<Fetcher<'_>> {
        let entry = self.entry(name)?;
        let file = File::open(&self.path)
            .with_context(|| format!("reopening {}", self.path.display()))?;
        // Reject truncated payload segments up front, so the seeking
        // source's reads cannot run off the end of the file mid-fetch
        // (the TOC checksum does not cover payload length).
        let need = entry.payload_offset + entry.payload_words * 2;
        let have = file.metadata().map(|m| m.len()).unwrap_or(0);
        if have < need {
            bail!(
                "container {}: payload of '{name}' truncated ({have} < {need} bytes)",
                self.path.display()
            );
        }
        let source = FilePayload { file, base_bytes: entry.payload_offset };
        Ok(Fetcher::with_source(&entry.packed, Box::new(source)))
    }

    /// Random-access window read straight off the file: only the
    /// touched compressed sub-tensors are read and decompressed.
    #[allow(clippy::too_many_arguments)]
    pub fn fetch_window(
        &self,
        name: &str,
        dram: &mut Dram,
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
    ) -> Result<DenseWindow> {
        let mut fetcher = self.reader(name)?;
        Ok(fetcher.fetch_window(dram, y0, y1, x0, x1, c0, c1))
    }

    /// Fetch a whole tensor dense.
    pub fn fetch_dense(&self, name: &str, dram: &mut Dram) -> Result<FeatureMap> {
        let e = self.entry(name)?;
        let (h, w, c) = e.shape();
        let win = self.fetch_window(name, dram, 0, h, 0, w, 0, c)?;
        Ok(FeatureMap::from_vec(h, w, c, win.data))
    }

    /// Load one tensor's payload fully, returning an in-memory packed
    /// map (for inserting into a [`crate::store::TensorStore`]).
    pub fn read_tensor(&self, name: &str) -> Result<PackedFeatureMap> {
        let e = self.entry(name)?;
        let mut f = File::open(&self.path)?;
        f.seek(SeekFrom::Start(e.payload_offset))?;
        let mut bytes = vec![0u8; e.payload_words as usize * 2];
        f.read_exact(&mut bytes)
            .with_context(|| format!("payload of '{name}'"))?;
        if fnv1a64(&bytes) != e.payload_checksum {
            bail!("container tensor '{name}': payload checksum mismatch");
        }
        let words: Vec<u16> =
            bytes.chunks_exact(2).map(|b| u16::from_le_bytes([b[0], b[1]])).collect();
        let mut p = e.packed.clone();
        p.payload = Some(words);
        Ok(p)
    }

    /// Verify every payload checksum (full-file scan).
    pub fn verify(&self) -> Result<()> {
        for e in &self.entries {
            let _ = self.read_tensor(&e.name)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::layout::packer::Packer;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gratetile-{name}-{}", std::process::id()));
        p
    }

    fn packed(mode: DivisionMode, scheme: Scheme, seed: u64) -> (FeatureMap, PackedFeatureMap) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division = Division::build(mode, &layer, &tile, &hw, 24, 24, 16).unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, seed));
        let p = Packer::new(hw, scheme).pack(&fm, &division, true);
        (fm, p)
    }

    #[test]
    fn write_open_fetch_roundtrip() {
        let path = tmp("roundtrip.grate");
        let (fm_a, p_a) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 1);
        let (fm_b, p_b) = packed(DivisionMode::Uniform { edge: 1 }, Scheme::Zrlc, 2);
        Container::write(
            &path,
            &[("a".to_string(), &p_a), ("b".to_string(), &p_b)],
        )
        .unwrap();
        let c = Container::open(&path).unwrap();
        assert_eq!(c.entries.len(), 2);
        c.verify().unwrap();
        // Random-access partial window, off-disk.
        let mut dram = Dram::default();
        let win = c.fetch_window("a", &mut dram, 5, 14, 3, 17, 0, 8).unwrap();
        for y in 5..14 {
            for x in 3..17 {
                for ch in 0..8 {
                    assert_eq!(win.get(y, x, ch), fm_a.get(y, x, ch));
                }
            }
        }
        // Whole-map dense fetch of the compact-packed tensor.
        let got = c.fetch_dense("b", &mut dram).unwrap();
        assert_eq!(got.as_slice(), fm_b.as_slice());
        // In-memory reload matches the original pack bit for bit.
        let re = c.read_tensor("a").unwrap();
        assert_eq!(re.payload, p_a.payload);
        assert_eq!(re.sizes_words, p_a.sizes_words);
        assert_eq!(re.addr_words, p_a.addr_words);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_toc_is_rejected() {
        let path = tmp("corrupt.grate");
        let (_, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 3);
        Container::write(&path, &[("t".to_string(), &p)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[HEADER_BYTES as usize + 4] ^= 0xFF; // flip a TOC byte
        std::fs::write(&path, &bytes).unwrap();
        let e = Container::open(&path).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_payload_fails_verify_but_opens() {
        let path = tmp("corrupt-payload.grate");
        let (_, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 4);
        Container::write(&path, &[("t".to_string(), &p)]).unwrap();
        let c = Container::open(&path).unwrap();
        let off = c.entry("t").unwrap().payload_offset as usize;
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let c = Container::open(&path).unwrap(); // TOC still fine
        assert!(c.verify().is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_payload_rejected_before_fetch() {
        let path = tmp("truncated.grate");
        let (_, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 5);
        Container::write(&path, &[("t".to_string(), &p)]).unwrap();
        let c = Container::open(&path).unwrap();
        let cut = c.entry("t").unwrap().payload_offset as usize + 16;
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let c = Container::open(&path).unwrap(); // TOC intact
        let mut dram = Dram::default();
        let e = c.fetch_window("t", &mut dram, 0, 8, 0, 8, 0, 8).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reader_serves_many_windows_from_one_handle() {
        let path = tmp("reader.grate");
        let (fm, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 6);
        Container::write(&path, &[("t".to_string(), &p)]).unwrap();
        let c = Container::open(&path).unwrap();
        let mut fetcher = c.reader("t").unwrap();
        let mut dram = Dram::default();
        for (y0, y1, x0, x1) in [(0, 9, 0, 9), (7, 17, 7, 17), (15, 24, 15, 24)] {
            let win = fetcher.fetch_window(&mut dram, y0, y1, x0, x1, 0, 16);
            for y in y0..y1 {
                for x in x0..x1 {
                    for ch in 0..16 {
                        assert_eq!(win.get(y, x, ch), fm.get(y, x, ch));
                    }
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    fn packed_policy(
        mode: DivisionMode,
        policy: CodecPolicy,
        seed: u64,
    ) -> (FeatureMap, PackedFeatureMap) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division = Division::build(mode, &layer, &tile, &hw, 24, 24, 16).unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, seed));
        let p = Packer::new(hw, policy).pack(&fm, &division, true);
        (fm, p)
    }

    #[test]
    fn tag_table_packs_and_unpacks() {
        let tags: Vec<u8> = (0..13).map(|i| (i % 4) as u8).collect();
        let bytes = pack_tags(&tags);
        assert_eq!(bytes.len(), 4); // ceil(13/4)
        assert_eq!(unpack_tags(&bytes, 13), tags);
        assert!(pack_tags(&[]).is_empty());
    }

    /// v1 backward compat: a v1-pinned write (no policy byte) reopens
    /// with the implicit uniform codec and serves windows bit-exactly.
    #[test]
    fn v1_container_still_opens_and_serves() {
        let path = tmp("v1-compat.grate");
        let (fm, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Zrlc, 8);
        Container::write_with_version(&path, &[("t".to_string(), &p)], 1).unwrap();
        let c = Container::open(&path).unwrap();
        assert_eq!(c.version, 1);
        c.verify().unwrap();
        let e = c.entry("t").unwrap();
        assert_eq!(e.packed.policy, CodecPolicy::Fixed(Scheme::Zrlc));
        assert!(e.packed.tags.is_empty());
        let mut dram = Dram::default();
        let win = c.fetch_window("t", &mut dram, 2, 20, 3, 21, 0, 16).unwrap();
        for y in 2..20 {
            for x in 3..21 {
                for ch in 0..16 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch));
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    /// v1 cannot hold adaptive tensors — the writer refuses instead of
    /// silently dropping the tag table.
    #[test]
    fn v1_write_rejects_adaptive() {
        let path = tmp("v1-adaptive.grate");
        let (_, p) = packed_policy(DivisionMode::GrateTile { n: 8 }, CodecPolicy::Adaptive, 9);
        let e = Container::write_with_version(&path, &[("t".to_string(), &p)], 1).unwrap_err();
        assert!(e.to_string().contains("adaptive"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    /// v2 adaptive round trip (version-pinned): the packed tag table
    /// survives the TOC, per-record tags are rebuilt, and mixed-codec
    /// windows decode bit-exactly off the file. v2 has no integrity
    /// table, so the reopened map's checksums are empty.
    #[test]
    fn v2_adaptive_roundtrip_with_tag_table() {
        let path = tmp("v2-adaptive.grate");
        let (fm, p) = packed_policy(DivisionMode::GrateTile { n: 8 }, CodecPolicy::Adaptive, 10);
        Container::write_with_version(&path, &[("t".to_string(), &p)], 2).unwrap();
        let c = Container::open(&path).unwrap();
        assert_eq!(c.version, 2);
        c.verify().unwrap();
        let e = c.entry("t").unwrap();
        assert_eq!(e.packed.policy, CodecPolicy::Adaptive);
        assert_eq!(e.packed.tags, p.tags);
        assert!(e.packed.checksums.is_empty());
        assert_eq!(e.packed.metadata.bits_per_record, p.metadata.bits_per_record);
        for (ra, rb) in e.packed.metadata.records.iter().zip(&p.metadata.records) {
            assert_eq!(ra.codec_tags, rb.codec_tags);
        }
        let mut dram = Dram::default();
        let win = c.fetch_window("t", &mut dram, 0, 24, 0, 24, 0, 16).unwrap();
        for y in 0..24 {
            for x in 0..24 {
                for ch in 0..16 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch));
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    /// v3 round trip: the per-sub-tensor integrity table survives the
    /// TOC byte-exactly, for fixed and adaptive tensors alike.
    #[test]
    fn v3_roundtrip_carries_checksum_table() {
        let path = tmp("v3-checksums.grate");
        let (_, p_fixed) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 11);
        let (_, p_auto) =
            packed_policy(DivisionMode::Uniform { edge: 1 }, CodecPolicy::Adaptive, 12);
        assert_eq!(p_fixed.checksums.len(), p_fixed.sizes_words.len());
        Container::write(
            &path,
            &[("f".to_string(), &p_fixed), ("a".to_string(), &p_auto)],
        )
        .unwrap();
        let c = Container::open(&path).unwrap();
        assert_eq!(c.version, 3);
        c.verify().unwrap();
        assert_eq!(c.entry("f").unwrap().packed.checksums, p_fixed.checksums);
        assert_eq!(c.entry("a").unwrap().packed.checksums, p_auto.checksums);
        std::fs::remove_file(&path).ok();
    }

    /// The per-sub-tensor checksum is content-addressed: hashing each
    /// payload slice reproduces the stored table exactly.
    #[test]
    fn checksums_match_payload_slices() {
        let (_, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Zrlc, 13);
        let payload = p.payload.as_ref().unwrap();
        for li in 0..p.sizes_words.len() {
            let a = p.addr_words[li] as usize;
            let s = p.sizes_words[li] as usize;
            assert_eq!(p.checksums[li], fnv1a64_words(&payload[a..a + s]), "sub {li}");
        }
    }

    /// A header whose declared TOC length exceeds the file is a typed
    /// error (no allocation-from-attacker-controlled-length, no panic).
    #[test]
    fn oversized_toc_length_rejected() {
        let path = tmp("bad-toc-len.grate");
        let (_, p) = packed(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 14);
        Container::write(&path, &[("t".to_string(), &p)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // toc_len lives at header bytes [12, 20).
        bytes[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let e = Container::open(&path).unwrap_err();
        assert!(e.to_string().contains("exceeds file size"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("magic.grate");
        std::fs::write(&path, b"NOPE....????????????????????").unwrap();
        let e = Container::open(&path).unwrap_err();
        assert!(e.to_string().contains("magic"), "{e}");
        std::fs::remove_file(&path).ok();
    }
}
