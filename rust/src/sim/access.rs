//! DRAM access-efficiency study: the quantitative form of §III-A's
//! "memory hierarchies favor aligned and coalesced access".
//!
//! The bandwidth simulator counts *bytes*; this study feeds the actual
//! address stream a division mode produces (block pointers + compressed
//! spans, in tile-walk order) into the row-buffer-timed DRAM model and
//! reports row-hit rate and bus efficiency. GrateTile's long aligned
//! sub-tensor reads stream within rows; a fragmented fine division
//! scatters and thrashes.

use crate::compress::CodecPolicy;
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::layout::packer::Packer;
use crate::memsim::timing::{DramTiming, TimedDram};
use crate::sim::walker::TileWalker;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionError, DivisionMode};

/// Access-efficiency result for one layer/mode.
#[derive(Debug, Clone, Copy)]
pub struct AccessStudy {
    pub row_hit_rate: f64,
    pub bus_efficiency: f64,
    pub lines: u64,
    pub cycles: u64,
    pub requests: u64,
}

/// Replay the fetch address stream of a layer under `mode` through the
/// timed DRAM.
pub fn access_study(
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
) -> Result<AccessStudy, DivisionError> {
    let tile = hw.tile_for_layer(layer);
    let division = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c)?;
    let packed = Packer::new(*hw, policy).pack(fm, &division, false);
    let walker = TileWalker::new(*layer, tile);
    let mut dram = TimedDram::new(DramTiming::default());

    for w in walker.iter() {
        for r in division.intersecting(w.y0, w.y1, w.x0, w.x1, w.c0, w.c1) {
            let li = division.linear(r);
            let addr = packed.addr_words[li];
            let words = packed.sizes_words[li].max(1) as u64;
            dram.read(addr, words);
        }
    }
    Ok(AccessStudy {
        row_hit_rate: dram.row_hit_rate(),
        bus_efficiency: dram.efficiency(),
        lines: dram.lines,
        cycles: dram.cycles,
        requests: dram.requests,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::tensor::sparsity::{generate, SparsityParams};

    #[test]
    fn gratetile_streams_better_than_fine_division() {
        let hw = Platform::EyerissLargeTile.hardware();
        let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
        let fm = generate(56, 56, 64, SparsityParams::clustered(0.37, 9));
        let g = access_study(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
            .unwrap();
        let u1 =
            access_study(&hw, &layer, &fm, DivisionMode::Uniform { edge: 1 }, Scheme::Bitmask)
                .unwrap();
        // §III-A quantified: GrateTile coalesces the same traffic into
        // ~50x fewer transactions (whole aligned sub-tensors vs one
        // request per 8-word piece) and wins bus efficiency.
        assert!(
            g.bus_efficiency > u1.bus_efficiency,
            "grate {} vs compact {}",
            g.bus_efficiency,
            u1.bus_efficiency
        );
        assert!(
            u1.requests > 10 * g.requests,
            "compact must issue many more transactions: {} vs {}",
            u1.requests,
            g.requests
        );
    }

    #[test]
    fn efficiency_in_unit_range() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let fm = generate(24, 24, 16, SparsityParams::iid(0.5, 2));
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 4 }] {
            let s = access_study(&hw, &layer, &fm, mode, Scheme::Bitmask).unwrap();
            assert!(s.row_hit_rate >= 0.0 && s.row_hit_rate <= 1.0);
            assert!(s.bus_efficiency > 0.0 && s.bus_efficiency <= 1.0);
            assert!(s.lines > 0);
        }
    }
}
