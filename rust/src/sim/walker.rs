//! Tile walker: the exact fetch pattern of tiled CNN processing.
//!
//! For every output tile `(ty, tx)` and channel group, the accelerator
//! fetches the halo'd input window
//! `[ty·th·s − k·d, (ty·th + th − 1)·s + k·d + 1) × [… same in x …)`,
//! clipped to the feature map (§III-B, Fig. 5). The walker enumerates
//! these windows; the cost model in [`crate::sim::experiment`] prices
//! them.

use crate::config::layer::{ConvLayer, TileShape};

/// One fetched input window (clipped to the map).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub ty: usize,
    pub tx: usize,
    /// Channel-tile index (groups of `tile.tc` input channels).
    pub tcg: usize,
    pub y0: usize,
    pub y1: usize,
    pub x0: usize,
    pub x1: usize,
    pub c0: usize,
    pub c1: usize,
}

impl Window {
    pub fn words(&self) -> u64 {
        ((self.y1 - self.y0) * (self.x1 - self.x0) * (self.c1 - self.c0)) as u64
    }
}

/// Iterates all input windows for a layer/tile pair.
#[derive(Debug, Clone)]
pub struct TileWalker {
    pub layer: ConvLayer,
    pub tile: TileShape,
    pub n_ty: usize,
    pub n_tx: usize,
    pub n_tcg: usize,
}

impl TileWalker {
    pub fn new(layer: ConvLayer, tile: TileShape) -> Self {
        let n_ty = layer.out_h().div_ceil(tile.th);
        let n_tx = layer.out_w().div_ceil(tile.tw);
        let n_tcg = layer.c_in.div_ceil(tile.tc);
        Self { layer, tile, n_ty, n_tx, n_tcg }
    }

    pub fn n_tiles(&self) -> u64 {
        (self.n_ty * self.n_tx * self.n_tcg) as u64
    }

    /// One spatial axis of the §III-B window formula: tile index `ti`
    /// over output-tile length `tlen`, clipped to `[0, limit)`.
    fn axis_span(&self, ti: usize, tlen: usize, limit: usize) -> (usize, usize) {
        let l = &self.layer;
        let halo = l.halo() as i64;
        let lo = (ti * tlen * l.s) as i64 - halo;
        let hi = ((ti * tlen + tlen - 1) * l.s) as i64 + halo + 1;
        (lo.max(0) as usize, hi.min(limit as i64) as usize)
    }

    /// Clipped row range `[y0, y1)` of the window for tile row `ty`.
    /// Depends only on `ty` — the pricer exploits this per-axis
    /// separability to precompute all spans once per walk.
    pub fn y_span(&self, ty: usize) -> (usize, usize) {
        self.axis_span(ty, self.tile.th, self.layer.h)
    }

    /// Clipped column range `[x0, x1)` of the window for tile column `tx`.
    pub fn x_span(&self, tx: usize) -> (usize, usize) {
        self.axis_span(tx, self.tile.tw, self.layer.w)
    }

    /// Channel range `[c0, c1)` of the window for channel tile `tcg`.
    pub fn c_span(&self, tcg: usize) -> (usize, usize) {
        let c0 = tcg * self.tile.tc;
        (c0, (c0 + self.tile.tc).min(self.layer.c_in))
    }

    /// The window for tile `(ty, tx, tcg)`.
    pub fn window(&self, ty: usize, tx: usize, tcg: usize) -> Window {
        let (y0, y1) = self.y_span(ty);
        let (x0, x1) = self.x_span(tx);
        let (c0, c1) = self.c_span(tcg);
        Window { ty, tx, tcg, y0, y1, x0, x1, c0, c1 }
    }

    /// Iterate all windows in raster order.
    pub fn iter(&self) -> impl Iterator<Item = Window> + '_ {
        (0..self.n_ty).flat_map(move |ty| {
            (0..self.n_tx).flat_map(move |tx| {
                (0..self.n_tcg).map(move |tcg| self.window(ty, tx, tcg))
            })
        })
    }

    /// Total words fetched by a dense (uncompressed) fetch of every
    /// window — the paper's baseline denominator. In the channel-planar
    /// layout each pixel's 8-deep channel group is exactly one aligned
    /// line, so the dense fetch has no alignment slack.
    pub fn baseline_words(&self) -> u64 {
        self.iter().map(|w| w.words()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_geometry_interior_and_edges() {
        // Paper Fig. 5: 3x3 conv, 8x8 tile -> 10x10 windows stepping 8.
        let l = ConvLayer::new(1, 1, 64, 64, 8, 8);
        let walker = TileWalker::new(l, TileShape::new(8, 8, 8));
        assert_eq!(walker.n_ty, 8);
        // Tile (0,0): clipped halo on the top/left.
        let w00 = walker.window(0, 0, 0);
        assert_eq!((w00.y0, w00.y1, w00.x0, w00.x1), (0, 9, 0, 9));
        // Interior tile: full 10x10.
        let w11 = walker.window(1, 1, 0);
        assert_eq!((w11.y0, w11.y1, w11.x0, w11.x1), (7, 17, 7, 17));
        assert_eq!(w11.words(), 10 * 10 * 8);
        // Last tile: clipped at the bottom/right.
        let w77 = walker.window(7, 7, 0);
        assert_eq!((w77.y1, w77.x1), (64, 64));
    }

    #[test]
    fn strided_windows() {
        let l = ConvLayer::new(1, 2, 56, 56, 64, 64);
        let walker = TileWalker::new(l, TileShape::new(4, 8, 8));
        // out 28x28, tiles 7x4(x8 groups).
        assert_eq!((walker.n_ty, walker.n_tx, walker.n_tcg), (7, 4, 8));
        let w = walker.window(1, 1, 0);
        // y: [4*2-1, (4+3)*2+1+1) = [7,16); x: [8*2-1, (8+7)*2+2) = [15,32).
        assert_eq!((w.y0, w.y1, w.x0, w.x1), (7, 16, 15, 32));
        assert_eq!(w.y1 - w.y0, 9); // Table I: 9x17 window
        assert_eq!(w.x1 - w.x0, 17);
    }

    #[test]
    fn pointwise_windows_have_no_halo() {
        let l = ConvLayer::new(0, 1, 56, 56, 256, 128);
        let walker = TileWalker::new(l, TileShape::new(8, 16, 8));
        let w = walker.window(1, 1, 3);
        assert_eq!((w.y0, w.y1), (8, 16));
        assert_eq!((w.x0, w.x1), (16, 32));
        assert_eq!((w.c0, w.c1), (24, 32));
    }

    #[test]
    fn ragged_map_is_fully_covered() {
        // 13x13 AlexNet-style map with an 8x16 tile: output pixels all
        // covered exactly once.
        let l = ConvLayer::new(1, 1, 13, 13, 384, 384);
        let walker = TileWalker::new(l, TileShape::new(8, 16, 8));
        assert_eq!((walker.n_ty, walker.n_tx), (2, 1));
        let mut covered = vec![false; 13 * 13];
        for ty in 0..walker.n_ty {
            for tx in 0..walker.n_tx {
                // Output pixels of this tile.
                for oy in ty * 8..((ty + 1) * 8).min(13) {
                    for ox in tx * 16..((tx + 1) * 16).min(13) {
                        assert!(!covered[oy * 13 + ox]);
                        covered[oy * 13 + ox] = true;
                    }
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn baseline_includes_halo_overlap() {
        let l = ConvLayer::new(1, 1, 64, 64, 8, 8);
        let walker = TileWalker::new(l, TileShape::new(8, 8, 8));
        let base = walker.baseline_words();
        // Dense fetch must exceed the raw map size (halo re-fetch).
        assert!(base > (64 * 64 * 8) as u64);
        // And be below the naive (10x10 per tile everywhere) bound.
        assert!(base <= (64 * 10 * 10 * 8) as u64);
    }

    #[test]
    fn dilated_halo() {
        let l = ConvLayer::new(1, 1, 32, 32, 8, 8).dilated(2);
        let walker = TileWalker::new(l, TileShape::new(8, 8, 8));
        let w = walker.window(1, 1, 0);
        // halo = 2: [8-2, 15+2+1) = [6, 18).
        assert_eq!((w.y0, w.y1), (6, 18));
    }
}
