//! The window pricer: O(1)-per-window fetch-cost evaluation via 3D
//! inclusive prefix sums over the sub-tensor grid.
//!
//! The naive §III cost model walks every sub-tensor a window covers —
//! O(tiles × sub-tensors-per-window), worst on the compact Uniform
//! 1×1×8 baseline where a 224×224 VGG window touches hundreds of
//! sub-tensors per channel group. [`LayerPricer`] amortizes that into
//! one O(n_subtensors) pass (the BARISTA-style tiled-cost summary):
//!
//! * **fetched bits** — windows cover an axis-aligned *box* of
//!   sub-tensor indices (the GrateTile grid is rectangular in
//!   (iy, ix, icg) space), so a 3D inclusive prefix sum turns each
//!   window's cost into 8 corner lookups.
//! * **metadata bits** — the touched metadata blocks also form a box in
//!   block space, and `block_of_*` is non-decreasing, so the per-window
//!   distinct-block count is a product of three range widths; summed
//!   over all windows it factorizes per axis into closed form.
//! * **baseline bits** — window word counts are `Δy·Δx·Δc`, which also
//!   factorizes per axis.
//!
//! [`price_naive`] keeps the original per-sub-tensor triple loop as the
//! reference oracle: `rust/tests/property.rs` proves the two agree
//! bit-exactly across division modes, strides, dilation and ragged
//! maps, and `benches/perf_walk.rs` measures the speedup.

use crate::layout::packer::PackedFeatureMap;
use crate::sim::walker::TileWalker;
use crate::tiling::division::Division;

/// Priced totals for one layer walk (all in bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalkCost {
    /// Dense (uncompressed) fetch — the saving denominator.
    pub baseline_bits: u64,
    /// Compressed sub-tensor fetch (line-granular for aligned modes).
    pub fetched_bits: u64,
    /// Block metadata records, once per touched block per tile.
    pub metadata_bits: u64,
}

/// Prefix-summed fetch costs for one packed feature map.
///
/// Built in one pass over the packed sub-tensor grid; prices any walker
/// over the same map in O(tiles).
pub struct LayerPricer<'a> {
    division: &'a Division,
    /// Metadata record width in bits — tag-aware (adaptive maps pay
    /// their 2-bit codec tags per record slot), taken from the packed
    /// map so the closed form and the fetcher charge the same constant.
    record_bits: u64,
    /// `(ny+1) × (nx+1) × (ncg+1)` inclusive prefix sums of
    /// per-sub-tensor fetch bits; entry `(iy, ix, icg)` holds the total
    /// over the box `[0,iy) × [0,ix) × [0,icg)`.
    prefix: Vec<u64>,
    nx1: usize,
    ncg1: usize,
}

impl<'a> LayerPricer<'a> {
    /// One O(n_subtensors) pass over `packed`'s cost grid.
    pub fn new(packed: &'a PackedFeatureMap) -> Self {
        Self::from_grid(&packed.division, packed.record_bits() as u64, &packed.fetch_bits_grid())
    }

    /// Build a pricer from an explicit per-sub-tensor fetch-bits grid
    /// (linear-index order) instead of a packed map. The tuner prices
    /// candidate plans from sizing passes alone — no payload ever
    /// materialises — and its admissible lower bounds are priced from
    /// idealised grids through this same constructor.
    pub fn from_grid(division: &'a Division, record_bits: u64, grid: &[u64]) -> Self {
        let ny = division.ys.len();
        let nx = division.xs.len();
        let ncg = division.n_cgroups;
        debug_assert_eq!(grid.len(), ny * nx * ncg);

        let nx1 = nx + 1;
        let ncg1 = ncg + 1;
        let mut prefix = vec![0u64; (ny + 1) * nx1 * ncg1];
        let at = |iy: usize, ix: usize, icg: usize| (iy * nx1 + ix) * ncg1 + icg;
        for iy in 0..ny {
            for ix in 0..nx {
                for icg in 0..ncg {
                    let cost = grid[(iy * nx + ix) * ncg + icg];
                    // Standard 3D inclusion-exclusion; grouping all
                    // additions first keeps the u64 arithmetic
                    // subtraction-safe (the positive terms dominate).
                    prefix[at(iy + 1, ix + 1, icg + 1)] = (cost
                        + prefix[at(iy, ix + 1, icg + 1)]
                        + prefix[at(iy + 1, ix, icg + 1)]
                        + prefix[at(iy + 1, ix + 1, icg)]
                        + prefix[at(iy, ix, icg)])
                        - prefix[at(iy, ix, icg + 1)]
                        - prefix[at(iy, ix + 1, icg)]
                        - prefix[at(iy + 1, ix, icg)];
                }
            }
        }

        Self { division, record_bits, prefix, nx1, ncg1 }
    }

    /// Sum of fetch bits over sub-tensor index box
    /// `[y0,y1) × [x0,x1) × [c0,c1)` — 8 corner lookups.
    #[inline]
    fn box_bits(&self, y0: usize, y1: usize, x0: usize, x1: usize, c0: usize, c1: usize) -> u64 {
        let p = |iy: usize, ix: usize, icg: usize| self.prefix[(iy * self.nx1 + ix) * self.ncg1 + icg];
        (p(y1, x1, c1) + p(y0, x0, c1) + p(y0, x1, c0) + p(y1, x0, c0))
            - p(y0, x1, c1)
            - p(y1, x0, c1)
            - p(y1, x1, c0)
            - p(y0, x0, c0)
    }

    /// Price every window of `walker` against this map: O(tiles) after
    /// the constructor's single grid pass. Bit-exact with
    /// [`price_naive`] (property-tested).
    pub fn price(&self, walker: &TileWalker) -> WalkCost {
        let div = self.division;

        // Per-axis precomputation: each window's segment-index range,
        // word span and touched-block count depend on one tile
        // coordinate only.
        let mut y_words = 0u64; // Σ_ty Δy
        let mut y_blocks = 0u64; // Σ_ty (#distinct y-blocks)
        let y_ranges: Vec<(usize, usize)> = (0..walker.n_ty)
            .map(|ty| {
                let (y0, y1) = walker.y_span(ty);
                y_words += (y1 - y0) as u64;
                let r = Division::covering(&div.ys, y0, y1);
                debug_assert!(!r.is_empty());
                y_blocks += (div.block_of_y[r.end - 1] - div.block_of_y[r.start] + 1) as u64;
                (r.start, r.end)
            })
            .collect();
        let mut x_words = 0u64;
        let mut x_blocks = 0u64;
        let x_ranges: Vec<(usize, usize)> = (0..walker.n_tx)
            .map(|tx| {
                let (x0, x1) = walker.x_span(tx);
                x_words += (x1 - x0) as u64;
                let r = Division::covering(&div.xs, x0, x1);
                debug_assert!(!r.is_empty());
                x_blocks += (div.block_of_x[r.end - 1] - div.block_of_x[r.start] + 1) as u64;
                (r.start, r.end)
            })
            .collect();
        let mut c_words = 0u64;
        let mut c_groups = 0u64; // Σ_tcg (#channel groups covered)
        let c_ranges: Vec<(usize, usize)> = (0..walker.n_tcg)
            .map(|tcg| {
                let (c0, c1) = walker.c_span(tcg);
                c_words += (c1 - c0) as u64;
                let cg0 = c0 / div.cd;
                let cg1 = c1.div_ceil(div.cd).min(div.n_cgroups);
                c_groups += (cg1 - cg0) as u64;
                (cg0, cg1)
            })
            .collect();

        // Baseline and metadata factorize per axis exactly: every
        // (ty, tx, tcg) combination occurs once, and both per-window
        // quantities are products of per-axis terms.
        let baseline_bits = 16 * y_words * x_words * c_words;
        let metadata_bits = self.record_bits * y_blocks * x_blocks * c_groups;

        // Fetched bits: 8 corner lookups per window.
        let mut fetched_bits = 0u64;
        for &(iy0, iy1) in &y_ranges {
            for &(ix0, ix1) in &x_ranges {
                for &(cg0, cg1) in &c_ranges {
                    fetched_bits += self.box_bits(iy0, iy1, ix0, ix1, cg0, cg1);
                }
            }
        }

        WalkCost { baseline_bits, fetched_bits, metadata_bits }
    }
}

/// Reference oracle: the original per-sub-tensor triple loop with
/// stamp-based block dedup (the seed's `run_layer` inner loop). Kept so
/// property tests can prove the prefix pricer bit-exact, and so
/// `benches/perf_walk.rs` can measure the speedup in the same run.
pub fn price_naive(packed: &PackedFeatureMap, walker: &TileWalker) -> WalkCost {
    let division = &packed.division;
    let record_bits = packed.record_bits() as u64;
    let mut fetched_bits = 0u64;
    let mut metadata_bits = 0u64;
    let mut baseline_bits = 0u64;

    // Per-tile block dedup via a stamp array (no per-tile allocation).
    let mut stamp = vec![0u32; division.n_blocks()];
    let mut tick = 0u32;

    for w in walker.iter() {
        baseline_bits += w.words() * 16;
        tick += 1;
        let yr = Division::covering(&division.ys, w.y0, w.y1);
        let xr = Division::covering(&division.xs, w.x0, w.x1);
        let cg0 = w.c0 / division.cd;
        let cg1 = w.c1.div_ceil(division.cd).min(division.n_cgroups);
        for iy in yr {
            for ix in xr.clone() {
                for icg in cg0..cg1 {
                    let r = crate::tiling::division::SubTensorRef { iy, ix, icg };
                    fetched_bits += packed.fetch_bits(r);
                    let b = division.block_linear(r);
                    if stamp[b] != tick {
                        stamp[b] = tick;
                        metadata_bits += record_bits;
                    }
                }
            }
        }
    }

    WalkCost { baseline_bits, fetched_bits, metadata_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::ConvLayer;
    use crate::layout::packer::Packer;
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tiling::division::DivisionMode;

    fn price_both(layer: ConvLayer, mode: DivisionMode, density: f64) -> (WalkCost, WalkCost) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let tile = hw.tile_for_layer(&layer);
        let division =
            Division::build(mode, &layer, &tile, &hw, layer.h, layer.w, layer.c_in).unwrap();
        let fm = generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(density, 3));
        let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, false);
        let walker = TileWalker::new(layer, tile);
        let pricer = LayerPricer::new(&packed);
        (pricer.price(&walker), price_naive(&packed, &walker))
    }

    #[test]
    fn matches_naive_on_gratetile() {
        let (fast, slow) = price_both(
            ConvLayer::new(1, 1, 56, 56, 64, 64),
            DivisionMode::GrateTile { n: 8 },
            0.37,
        );
        assert_eq!(fast, slow);
    }

    #[test]
    fn matches_naive_on_compact_uniform() {
        let (fast, slow) = price_both(
            ConvLayer::new(1, 1, 40, 40, 16, 16),
            DivisionMode::Uniform { edge: 1 },
            0.5,
        );
        assert_eq!(fast, slow);
    }

    #[test]
    fn matches_naive_on_strided_ragged_map() {
        // 13x13 AlexNet-style ragged geometry with stride 2.
        let (fast, slow) = price_both(
            ConvLayer::new(1, 2, 13, 13, 24, 24),
            DivisionMode::Uniform { edge: 4 },
            0.3,
        );
        assert_eq!(fast, slow);
    }

    #[test]
    fn matches_naive_on_dilated_wholemap() {
        let (fast, slow) = price_both(
            ConvLayer::new(1, 1, 32, 32, 8, 8).dilated(2),
            DivisionMode::WholeMap,
            0.6,
        );
        assert_eq!(fast, slow);
    }

    #[test]
    fn box_bits_full_map_equals_grid_total() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = hw.tile_for_layer(&layer);
        let division =
            Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 24, 24, 16)
                .unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, 9));
        let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, false);
        let pricer = LayerPricer::new(&packed);
        let total: u64 = packed.fetch_bits_grid().iter().sum();
        assert_eq!(
            pricer.box_bits(0, division.ys.len(), 0, division.xs.len(), 0, division.n_cgroups),
            total
        );
        // Empty boxes price to zero.
        assert_eq!(pricer.box_bits(1, 1, 0, 2, 0, 2), 0);
    }
}
