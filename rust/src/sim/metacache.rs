//! Metadata SRAM-cache study (§III-C).
//!
//! The paper stores metadata in DRAM because "the size of metadata
//! would be 72 kB for AlexNet CONV2" with naive pointers, yet notes the
//! latency/bandwidth cost of DRAM-resident metadata. GrateTile's small
//! records make a tiny on-chip metadata cache effective; this study
//! quantifies it: the tile walk's metadata record stream runs through a
//! set-associative SRAM cache, and only misses pay DRAM traffic.
//!
//! The tile *order* matters: spatial-major walks (default) revisit each
//! block row across adjacent tiles (halo) soon — good locality; a
//! channel-major walk (process every channel group of the map before
//! stepping, §IV-B(3)-adjacent) stretches the reuse distance.


use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::memsim::cache::Cache;
use crate::sim::walker::TileWalker;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionError, DivisionMode};

/// Tile iteration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileOrder {
    /// (ty, tx) outer, channel groups inner — the paper's default.
    SpatialMajor,
    /// Channel groups outer, (ty, tx) inner — whole-channel processing.
    ChannelMajor,
}

impl TileOrder {
    /// Stable machine key — the `order=` value in tuned manifests.
    pub fn key(&self) -> &'static str {
        match self {
            TileOrder::SpatialMajor => "spatial",
            TileOrder::ChannelMajor => "channel",
        }
    }

    /// Parse a [`TileOrder::key`]-style name.
    pub fn parse(s: &str) -> Option<TileOrder> {
        match s {
            "spatial" => Some(TileOrder::SpatialMajor),
            "channel" => Some(TileOrder::ChannelMajor),
            _ => None,
        }
    }
}

/// Result of the cache study.
#[derive(Debug, Clone, Copy)]
pub struct MetaCacheStudy {
    pub hit_rate: f64,
    /// Metadata bits that actually reach DRAM (misses only).
    pub dram_bits: u64,
    /// Metadata bits the walk requested (= the no-cache cost).
    pub requested_bits: u64,
}

impl MetaCacheStudy {
    /// Fraction of metadata traffic the cache absorbs.
    pub fn absorbed(&self) -> f64 {
        if self.requested_bits == 0 {
            return 0.0;
        }
        1.0 - self.dram_bits as f64 / self.requested_bits as f64
    }
}

/// Run the study: metadata records of `mode` streamed through a
/// `cache_bytes` SRAM cache in the given tile order.
pub fn metadata_cache_study(
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    cache_bytes: usize,
    order: TileOrder,
) -> Result<MetaCacheStudy, DivisionError> {
    let tile = hw.tile_for_layer(layer);
    let division = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c)?;
    let walker = TileWalker::new(*layer, tile);
    let mut cache = Cache::new(cache_bytes, 4, hw.line_bytes());
    let rec_bytes = (division.meta_bits_per_block as u64).div_ceil(8);

    let mut requested_bits = 0u64;
    let mut dram_bits = 0u64;
    // Record table laid out linearly by block id.
    let mut visit = |ty: usize, tx: usize, tcg: usize| {
        let w = walker.window(ty, tx, tcg);
        // Touched blocks (one record each), deduped within the window.
        let mut last = usize::MAX;
        for r in division.intersecting(w.y0, w.y1, w.x0, w.x1, w.c0, w.c1) {
            let b = division.block_linear(r);
            if b == last {
                continue;
            }
            last = b;
            requested_bits += division.meta_bits_per_block as u64;
            let missed = cache.access(b as u64 * rec_bytes, rec_bytes);
            if missed > 0 {
                dram_bits += division.meta_bits_per_block as u64;
            }
        }
    };

    match order {
        TileOrder::SpatialMajor => {
            for ty in 0..walker.n_ty {
                for tx in 0..walker.n_tx {
                    for tcg in 0..walker.n_tcg {
                        visit(ty, tx, tcg);
                    }
                }
            }
        }
        TileOrder::ChannelMajor => {
            for tcg in 0..walker.n_tcg {
                for ty in 0..walker.n_ty {
                    for tx in 0..walker.n_tx {
                        visit(ty, tx, tcg);
                    }
                }
            }
        }
    }

    Ok(MetaCacheStudy { hit_rate: cache.hit_rate(), dram_bits, requested_bits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn setup() -> (Hardware, ConvLayer, FeatureMap) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
        let fm = generate(56, 56, 64, SparsityParams::clustered(0.4, 6));
        (hw, layer, fm)
    }

    /// A 4 KB cache absorbs most GrateTile metadata traffic (its whole
    /// table for this layer is ~3 KB), while Uniform 1×1×8's 25% index
    /// (~98 KB) thrashes it.
    #[test]
    fn small_cache_absorbs_gratetile_but_not_compact_index() {
        let (hw, layer, fm) = setup();
        let g = metadata_cache_study(
            &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, 4096, TileOrder::SpatialMajor,
        )
        .unwrap();
        let u1 = metadata_cache_study(
            &hw, &layer, &fm, DivisionMode::Uniform { edge: 1 }, 4096, TileOrder::SpatialMajor,
        )
        .unwrap();
        assert!(g.absorbed() > 0.8, "grate absorbed {}", g.absorbed());
        assert!(u1.absorbed() < 0.4, "compact absorbed {}", u1.absorbed());
    }

    #[test]
    fn channel_major_has_worse_locality_under_tiny_cache() {
        let (hw, layer, fm) = setup();
        // Cache smaller than one full metadata sweep.
        let tiny = 512;
        let sm = metadata_cache_study(
            &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, tiny, TileOrder::SpatialMajor,
        )
        .unwrap();
        let cm = metadata_cache_study(
            &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, tiny, TileOrder::ChannelMajor,
        )
        .unwrap();
        assert!(
            sm.hit_rate >= cm.hit_rate,
            "spatial {} vs channel {}",
            sm.hit_rate,
            cm.hit_rate
        );
    }

    #[test]
    fn requested_matches_no_cache_accounting() {
        let (hw, layer, fm) = setup();
        let s = metadata_cache_study(
            &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, 4096, TileOrder::SpatialMajor,
        )
        .unwrap();
        let analytic = crate::sim::experiment::run_layer(
            &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask,
        )
        .unwrap();
        // The walk requests at least the analytic metadata (the analytic
        // path dedups per tile with a stamp; this path dedups only
        // consecutive repeats, so requested >= analytic).
        assert!(s.requested_bits >= analytic.metadata_bits);
        assert!(s.dram_bits <= s.requested_bits);
    }

    #[test]
    fn huge_cache_absorbs_everything_after_warmup() {
        let (hw, layer, fm) = setup();
        let s = metadata_cache_study(
            &hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, 1 << 20, TileOrder::SpatialMajor,
        )
        .unwrap();
        assert!(s.absorbed() > 0.85, "absorbed {}", s.absorbed()); // ~10% cold misses
    }
}
