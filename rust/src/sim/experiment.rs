//! Experiment drivers: price a layer / the full benchmark suite under a
//! division mode and compression scheme (paper §IV).

use super::pricer::{price_naive, LayerPricer, WalkCost};
use super::report::LayerBandwidth;
use super::walker::TileWalker;
use crate::compress::CodecPolicy;
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::config::zoo::BenchLayer;
use crate::layout::packer::Packer;
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionError, DivisionMode};
use crate::util::parallel::par_map;
use crate::util::geomean;

pub use crate::tiling::division::DivisionMode as Mode;

fn bandwidth_report(
    hw: &Hardware,
    fm: &FeatureMap,
    mode: DivisionMode,
    cost: WalkCost,
    n_tiles: u64,
) -> LayerBandwidth {
    LayerBandwidth {
        network: String::new(),
        layer: String::new(),
        mode: mode.name(),
        platform: hw.name.to_string(),
        baseline_bits: cost.baseline_bits,
        fetched_bits: cost.fetched_bits,
        metadata_bits: cost.metadata_bits,
        density: fm.density(),
        n_tiles,
    }
}

/// Price one layer's feature-map traffic under `mode` + `scheme`.
///
/// The §III cost model: every processing tile fetches whole compressed
/// sub-tensors (line-granular) and block metadata records (once per
/// touched block per tile). Evaluated by the prefix-sum
/// [`LayerPricer`] — O(tiles) after packing — and bit-exact with the
/// naive reference walk ([`run_layer_naive`], property-tested).
///
/// Packing goes through the plan/execute engine (`layout::packer`,
/// DESIGN.md §Packing engine): sizes-only packs are one fused stats
/// pass per sub-tensor, parallelised for large maps. Inside a suite
/// sweep the units are already fanned across workers, and the pool
/// marks its worker threads so any nested engine fan-out runs inline
/// (`util::parallel`, no workers² oversubscription); either way
/// results are worker-count invariant.
pub fn run_layer(
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
) -> Result<LayerBandwidth, DivisionError> {
    let tile = hw.tile_for_layer(layer);
    let division = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c)?;
    let packed = Packer::new(*hw, policy).pack(fm, &division, false);
    let walker = TileWalker::new(*layer, tile);
    let cost = LayerPricer::new(&packed).price(&walker);
    Ok(bandwidth_report(hw, fm, mode, cost, walker.n_tiles()))
}

/// Reference oracle: price the layer with the original
/// per-sub-tensor triple loop instead of the prefix-sum pricer.
/// O(tiles × sub-tensors-per-window); kept for the equivalence property
/// tests and the `perf_walk` speedup comparison.
pub fn run_layer_naive(
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
) -> Result<LayerBandwidth, DivisionError> {
    let tile = hw.tile_for_layer(layer);
    let division = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c)?;
    let packed = Packer::new(*hw, policy).pack(fm, &division, false);
    let walker = TileWalker::new(*layer, tile);
    let cost = price_naive(&packed, &walker);
    Ok(bandwidth_report(hw, fm, mode, cost, walker.n_tiles()))
}

/// Run one zoo benchmark layer: synthesises the input feature map at the
/// layer's calibrated density (clustered model; see DESIGN.md §2) and
/// prices it. `fm_cache` lets suite sweeps reuse the synthesis across
/// division modes.
pub fn run_bench_layer(
    hw: &Hardware,
    bench: &BenchLayer,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
    fm: &FeatureMap,
) -> Result<LayerBandwidth, DivisionError> {
    let mut r = run_layer(hw, &bench.layer, fm, mode, policy)?;
    r.network = bench.network.name().to_string();
    r.layer = bench.name.to_string();
    Ok(r)
}

/// Synthesise the input feature map for a zoo layer (deterministic).
pub fn bench_feature_map(bench: &BenchLayer) -> FeatureMap {
    // Seed derived from the layer identity so every experiment sees the
    // same activations.
    let seed = bench
        .name
        .bytes()
        .fold(bench.network.name().bytes().fold(0xF00Du64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64)), |a, b| {
            a.wrapping_mul(131).wrapping_add(b as u64)
        });
    generate(
        bench.layer.h,
        bench.layer.w,
        bench.layer.c_in,
        SparsityParams::clustered(bench.density, seed),
    )
}

/// Suite sweep result: `results[mode][layer]`, `None` where the mode is
/// not applicable (Table III footnote a).
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub platform: String,
    pub policy: CodecPolicy,
    pub modes: Vec<DivisionMode>,
    pub layers: Vec<String>,
    pub results: Vec<Vec<Option<LayerBandwidth>>>,
}

impl SuiteResult {
    /// Geometric-mean saving for a mode across all layers (the paper
    /// geomeans per-layer bandwidth *ratios*). `None` when the mode was
    /// N/A on any layer of the suite.
    pub fn geomean_saving(&self, mode_idx: usize, with_meta: bool) -> Option<f64> {
        let rs = &self.results[mode_idx];
        if rs.iter().any(|r| r.is_none()) {
            return None;
        }
        let ratios: Vec<f64> = rs
            .iter()
            .map(|r| {
                let r = r.as_ref().unwrap();
                if with_meta {
                    1.0 - r.saving_with_meta()
                } else {
                    1.0 - r.saving_without_meta()
                }
            })
            .collect();
        Some(1.0 - geomean(&ratios))
    }

    /// Geomean of the optimal (zero-fraction) saving across layers.
    ///
    /// A layer's density is mode-independent (same synthesized map), so
    /// each layer contributes its density from whichever mode priced it
    /// — never silently dropping layers when some mode rows hold `None`
    /// (Table III footnote a mixes N/A entries into arbitrary rows).
    pub fn geomean_optimal(&self) -> f64 {
        let densities: Vec<f64> = (0..self.layers.len())
            .filter_map(|li| {
                self.results
                    .iter()
                    .find_map(|row| row[li].as_ref())
                    .map(|r| r.density)
            })
            .collect();
        1.0 - geomean(&densities)
    }
}

/// Process-wide cache of the benchmark suite's synthesised feature maps
/// (§Perf: `gratetile all` prices the same 23 maps on two platforms
/// across three figures — synthesise them once, in parallel).
pub fn suite_feature_maps() -> &'static [(BenchLayer, FeatureMap)] {
    use std::sync::OnceLock;
    static FMS: OnceLock<Vec<(BenchLayer, FeatureMap)>> = OnceLock::new();
    FMS.get_or_init(|| {
        let benches = crate::config::zoo::benchmark_suite();
        let fms = par_map(&benches, |_, b| bench_feature_map(b));
        benches.into_iter().zip(fms).collect()
    })
}

/// Fan (platform × mode × layer) pricing units across a scoped worker
/// pool and reassemble per-platform [`SuiteResult`]s. Every unit is an
/// independent `run_bench_layer`, so the work-stealing pool keeps all
/// cores busy even when a 224×224 VGG map sits next to a 13×13 AlexNet
/// one; results are bit-identical to the sequential sweep.
fn price_suites(
    hws: &[Hardware],
    suite: &[(&BenchLayer, &FeatureMap)],
    modes: &[DivisionMode],
    policy: CodecPolicy,
) -> Vec<SuiteResult> {
    let n_layers = suite.len();
    let units: Vec<(usize, usize, usize)> = (0..hws.len())
        .flat_map(|pi| {
            (0..modes.len()).flat_map(move |mi| (0..n_layers).map(move |li| (pi, mi, li)))
        })
        .collect();
    let flat: Vec<Option<LayerBandwidth>> = par_map(&units, |_, &(pi, mi, li)| {
        let (b, fm) = suite[li];
        run_bench_layer(&hws[pi], b, modes[mi], policy, fm).ok()
    });

    let layers: Vec<String> = suite
        .iter()
        .map(|(b, _)| format!("{} {}", b.network.name(), b.name))
        .collect();
    let mut flat = flat.into_iter();
    hws.iter()
        .map(|hw| SuiteResult {
            platform: hw.name.to_string(),
            policy,
            modes: modes.to_vec(),
            layers: layers.clone(),
            results: (0..modes.len())
                .map(|_| (0..n_layers).map(|_| flat.next().unwrap()).collect())
                .collect(),
        })
        .collect()
}

/// Run the full (cached) benchmark suite under every mode on several
/// platforms in one parallel fan-out (Table III / Fig. 8 price both
/// platforms; one pool covers platform × mode × layer).
pub fn run_suites(
    hws: &[Hardware],
    modes: &[DivisionMode],
    policy: impl Into<CodecPolicy>,
) -> Vec<SuiteResult> {
    let suite: Vec<(&BenchLayer, &FeatureMap)> =
        suite_feature_maps().iter().map(|(b, fm)| (b, fm)).collect();
    price_suites(hws, &suite, modes, policy.into())
}

/// Run the full (cached) benchmark suite under every mode.
pub fn run_suite_shared(
    hw: &Hardware,
    modes: &[DivisionMode],
    policy: impl Into<CodecPolicy>,
) -> SuiteResult {
    run_suites(std::slice::from_ref(hw), modes, policy)
        .pop()
        .expect("one platform in, one suite out")
}

/// Run a benchmark suite under every mode (Fig. 8/9, Table III),
/// synthesising the feature maps (in parallel) rather than using the
/// process-wide cache.
pub fn run_suite(
    hw: &Hardware,
    benches: &[BenchLayer],
    modes: &[DivisionMode],
    policy: impl Into<CodecPolicy>,
) -> SuiteResult {
    let fms: Vec<FeatureMap> = par_map(benches, |_, b| bench_feature_map(b));
    let suite: Vec<(&BenchLayer, &FeatureMap)> = benches.iter().zip(&fms).collect();
    price_suites(std::slice::from_ref(hw), &suite, modes, policy.into())
        .pop()
        .expect("one platform in, one suite out")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::zoo::{network_layers, Network};

    fn small_fm(density: f64) -> (ConvLayer, FeatureMap) {
        let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
        let fm = generate(56, 56, 64, SparsityParams::clustered(density, 9));
        (layer, fm)
    }

    #[test]
    fn raw_scheme_fetches_at_least_baseline() {
        // Uncompressed sub-tensors: fetching whole blocks on halo'd
        // windows must cost >= the dense baseline.
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.4);
        let r = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 8 }, Scheme::Raw)
            .unwrap();
        assert!(r.fetched_bits >= r.baseline_bits);
        assert!(r.saving_without_meta() <= 0.0);
    }

    #[test]
    fn gratetile_beats_uniform_at_paper_density() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.37);
        let gr = run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
            .unwrap();
        let u8 = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 8 }, Scheme::Bitmask)
            .unwrap();
        let u2 = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 2 }, Scheme::Bitmask)
            .unwrap();
        assert!(
            gr.saving_with_meta() > u8.saving_with_meta(),
            "grate {} vs uniform8 {}",
            gr.saving_with_meta(),
            u8.saving_with_meta()
        );
        assert!(gr.saving_with_meta() > u2.saving_with_meta());
        // And lands in the paper's ballpark (~0.45-0.62 saving for d=0.37).
        assert!((0.40..0.70).contains(&gr.saving_with_meta()), "{}", gr.saving_with_meta());
    }

    #[test]
    fn saving_bounded_by_optimal() {
        // No scheme can save more than the zero fraction + mask trick:
        // the paper's optimal is the density line; allow the bitmask's
        // all-zero-block advantage a tiny epsilon.
        let hw = Platform::EyerissLargeTile.hardware();
        let (layer, fm) = small_fm(0.5);
        for mode in DivisionMode::table3_modes() {
            if let Ok(r) = run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask) {
                assert!(
                    r.saving_without_meta() <= r.optimal_saving() + 0.02,
                    "{}: {} > optimal {}",
                    mode.name(),
                    r.saving_without_meta(),
                    r.optimal_saving()
                );
            }
        }
    }

    #[test]
    fn compact_1x1_is_upper_bound_without_meta_but_loses_with_meta() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.37);
        let compact =
            run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 1 }, Scheme::Bitmask)
                .unwrap();
        let grate =
            run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
                .unwrap();
        // §IV-B(2): 1x1x8 compact is the no-overhead upper bound...
        assert!(compact.saving_without_meta() >= grate.saving_without_meta());
        // ...but its 25% metadata makes it the worst with overhead.
        assert!(compact.saving_with_meta() < grate.saving_with_meta());
    }

    #[test]
    fn denser_maps_save_less() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm_sparse) = small_fm(0.2);
        let (_, fm_dense) = small_fm(0.8);
        let s = run_layer(&hw, &layer, &fm_sparse, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask).unwrap();
        let d = run_layer(&hw, &layer, &fm_dense, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask).unwrap();
        assert!(s.saving_with_meta() > d.saving_with_meta());
    }

    /// The adaptive policy prices through the same pipeline and never
    /// fetches more payload than any fixed codec (per-sub-tensor min),
    /// while its metadata carries the 2-bit tags on top of the base
    /// record.
    #[test]
    fn adaptive_run_layer_bounds_fixed_codecs() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.37);
        let mode = DivisionMode::GrateTile { n: 8 };
        let auto = run_layer(&hw, &layer, &fm, mode, CodecPolicy::Adaptive).unwrap();
        for scheme in crate::compress::Registry::global().schemes() {
            let fixed = run_layer(&hw, &layer, &fm, mode, scheme).unwrap();
            assert!(
                auto.fetched_bits <= fixed.fetched_bits,
                "auto {} vs {} {}",
                auto.fetched_bits,
                scheme.name(),
                fixed.fetched_bits
            );
            assert!(auto.metadata_bits > fixed.metadata_bits, "tags must be accounted");
            assert_eq!(auto.baseline_bits, fixed.baseline_bits);
        }
    }

    #[test]
    fn suite_runs_and_geomeans() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let benches = network_layers(Network::AlexNet);
        let modes = [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 8 }];
        let suite = run_suite(&hw, &benches, &modes, Scheme::Bitmask);
        let g = suite.geomean_saving(0, true).unwrap();
        let u = suite.geomean_saving(1, true).unwrap();
        assert!(g > u, "grate {g} vs uniform {u}");
        assert!(g > 0.3 && g < 0.8);
        assert!(suite.geomean_optimal() > g - 0.02);
    }

    #[test]
    fn mod16_na_on_small_tile_suite() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let benches = network_layers(Network::Vgg16);
        let modes = [DivisionMode::GrateTile { n: 16 }];
        let suite = run_suite(&hw, &benches, &modes, Scheme::Bitmask);
        assert_eq!(suite.geomean_saving(0, true), None);
    }

    #[test]
    fn pricer_and_naive_walker_agree_bit_exactly() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.37);
        for mode in DivisionMode::table3_modes() {
            let fast = run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask);
            let slow = run_layer_naive(&hw, &layer, &fm, mode, Scheme::Bitmask);
            match (fast, slow) {
                (Ok(f), Ok(s)) => {
                    assert_eq!(f.fetched_bits, s.fetched_bits, "{}", mode.name());
                    assert_eq!(f.metadata_bits, s.metadata_bits, "{}", mode.name());
                    assert_eq!(f.baseline_bits, s.baseline_bits, "{}", mode.name());
                }
                (Err(a), Err(b)) => assert_eq!(a, b),
                (f, s) => panic!("applicability mismatch: {f:?} vs {s:?}"),
            }
        }
    }

    #[test]
    fn parallel_suite_matches_single_threaded() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let benches = network_layers(Network::AlexNet);
        let modes = [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 4 }];
        let par = run_suite(&hw, &benches, &modes, Scheme::Bitmask);
        // Sequential reference, bypassing the pool entirely.
        for (mi, mode) in modes.iter().enumerate() {
            for (li, b) in benches.iter().enumerate() {
                let fm = bench_feature_map(b);
                let seq = run_bench_layer(&hw, b, *mode, Scheme::Bitmask, &fm).ok();
                match (&par.results[mi][li], &seq) {
                    (Some(p), Some(s)) => {
                        assert_eq!(p.fetched_bits, s.fetched_bits, "{} {li}", mode.name());
                        assert_eq!(p.metadata_bits, s.metadata_bits);
                        assert_eq!(p.baseline_bits, s.baseline_bits);
                    }
                    (None, None) => {}
                    (p, s) => panic!("mismatch at {mi},{li}: {p:?} vs {s:?}"),
                }
            }
        }
    }

    #[test]
    fn run_suites_covers_all_platforms() {
        let hws = [
            Platform::NvidiaSmallTile.hardware(),
            Platform::EyerissLargeTile.hardware(),
        ];
        let modes = [DivisionMode::GrateTile { n: 8 }];
        let suites = run_suites(&hws, &modes, Scheme::Bitmask);
        assert_eq!(suites.len(), 2);
        assert_eq!(suites[0].platform, hws[0].name);
        assert_eq!(suites[1].platform, hws[1].name);
        // Both fully populated for mod-8 and distinct (different tiles).
        let a = suites[0].geomean_saving(0, true).unwrap();
        let b = suites[1].geomean_saving(0, true).unwrap();
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(suites[0].results[0][0].as_ref().unwrap().fetched_bits,
                   suites[1].results[0][0].as_ref().unwrap().fetched_bits);
    }

    #[test]
    fn geomean_optimal_survives_mixed_none_rows() {
        // Mode 0 N/A on layer 1, mode 1 N/A on layer 0: every layer's
        // density must still contribute exactly once.
        let lb = |density: f64| LayerBandwidth {
            network: "t".into(),
            layer: "l".into(),
            mode: "m".into(),
            platform: "p".into(),
            baseline_bits: 1000,
            fetched_bits: 500,
            metadata_bits: 10,
            density,
            n_tiles: 1,
        };
        let suite = SuiteResult {
            platform: "p".into(),
            policy: CodecPolicy::Fixed(Scheme::Bitmask),
            modes: vec![DivisionMode::GrateTile { n: 16 }, DivisionMode::GrateTile { n: 8 }],
            layers: vec!["a".into(), "b".into()],
            results: vec![
                vec![Some(lb(0.25)), None],
                vec![None, Some(lb(0.64))],
            ],
        };
        // geomean(0.25, 0.64) = 0.4; the old results[0]-based fallback
        // saw only 0.25.
        assert!((suite.geomean_optimal() - (1.0 - 0.4)).abs() < 1e-12);
    }

    #[test]
    fn metadata_bits_scale_with_division_granularity() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.4);
        let fine = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 2 }, Scheme::Bitmask).unwrap();
        let coarse = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 8 }, Scheme::Bitmask).unwrap();
        assert!(fine.metadata_bits > coarse.metadata_bits);
    }
}
