//! Experiment drivers: price a layer / the full benchmark suite under a
//! division mode and compression scheme (paper §IV).

use super::report::LayerBandwidth;
use super::walker::TileWalker;
use crate::compress::Scheme;
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::config::zoo::BenchLayer;
use crate::layout::packer::Packer;
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionError, DivisionMode};
use crate::util::geomean;

pub use crate::tiling::division::DivisionMode as Mode;

/// Price one layer's feature-map traffic under `mode` + `scheme`.
///
/// Walks every processing tile, fetching whole compressed sub-tensors
/// (line-granular) and block metadata records (once per touched block
/// per tile) — the §III cost model.
pub fn run_layer(
    hw: &Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    scheme: Scheme,
) -> Result<LayerBandwidth, DivisionError> {
    let tile = hw.tile_for_layer(layer);
    let division = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c)?;
    let packed = Packer::new(*hw, scheme).pack(fm, &division, false);
    let walker = TileWalker::new(*layer, tile);

    let mut fetched_bits = 0u64;
    let mut metadata_bits = 0u64;
    let mut baseline_bits = 0u64;

    // Per-tile block dedup via a stamp array (no per-tile allocation).
    let mut stamp = vec![0u32; division.n_blocks()];
    let mut tick = 0u32;

    for w in walker.iter() {
        baseline_bits += w.words() * 16;
        tick += 1;
        let yr = Division::covering(&division.ys, w.y0, w.y1);
        let xr = Division::covering(&division.xs, w.x0, w.x1);
        let cg0 = w.c0 / division.cd;
        let cg1 = w.c1.div_ceil(division.cd).min(division.n_cgroups);
        for iy in yr {
            for ix in xr.clone() {
                for icg in cg0..cg1 {
                    let r = crate::tiling::division::SubTensorRef { iy, ix, icg };
                    fetched_bits += packed.fetch_bits(r);
                    let b = division.block_linear(r);
                    if stamp[b] != tick {
                        stamp[b] = tick;
                        metadata_bits += division.meta_bits_per_block as u64;
                    }
                }
            }
        }
    }

    Ok(LayerBandwidth {
        network: String::new(),
        layer: String::new(),
        mode: mode.name(),
        platform: hw.name.to_string(),
        baseline_bits,
        fetched_bits,
        metadata_bits,
        density: fm.density(),
        n_tiles: walker.n_tiles(),
    })
}

/// Run one zoo benchmark layer: synthesises the input feature map at the
/// layer's calibrated density (clustered model; see DESIGN.md §2) and
/// prices it. `fm_cache` lets suite sweeps reuse the synthesis across
/// division modes.
pub fn run_bench_layer(
    hw: &Hardware,
    bench: &BenchLayer,
    mode: DivisionMode,
    scheme: Scheme,
    fm: &FeatureMap,
) -> Result<LayerBandwidth, DivisionError> {
    let mut r = run_layer(hw, &bench.layer, fm, mode, scheme)?;
    r.network = bench.network.name().to_string();
    r.layer = bench.name.to_string();
    Ok(r)
}

/// Synthesise the input feature map for a zoo layer (deterministic).
pub fn bench_feature_map(bench: &BenchLayer) -> FeatureMap {
    // Seed derived from the layer identity so every experiment sees the
    // same activations.
    let seed = bench
        .name
        .bytes()
        .fold(bench.network.name().bytes().fold(0xF00Du64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64)), |a, b| {
            a.wrapping_mul(131).wrapping_add(b as u64)
        });
    generate(
        bench.layer.h,
        bench.layer.w,
        bench.layer.c_in,
        SparsityParams::clustered(bench.density, seed),
    )
}

/// Suite sweep result: `results[mode][layer]`, `None` where the mode is
/// not applicable (Table III footnote a).
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub platform: String,
    pub scheme: Scheme,
    pub modes: Vec<DivisionMode>,
    pub layers: Vec<String>,
    pub results: Vec<Vec<Option<LayerBandwidth>>>,
}

impl SuiteResult {
    /// Geometric-mean saving for a mode across all layers (the paper
    /// geomeans per-layer bandwidth *ratios*). `None` when the mode was
    /// N/A on any layer of the suite.
    pub fn geomean_saving(&self, mode_idx: usize, with_meta: bool) -> Option<f64> {
        let rs = &self.results[mode_idx];
        if rs.iter().any(|r| r.is_none()) {
            return None;
        }
        let ratios: Vec<f64> = rs
            .iter()
            .map(|r| {
                let r = r.as_ref().unwrap();
                if with_meta {
                    1.0 - r.saving_with_meta()
                } else {
                    1.0 - r.saving_without_meta()
                }
            })
            .collect();
        Some(1.0 - geomean(&ratios))
    }

    /// Geomean of the optimal (zero-fraction) saving across layers.
    pub fn geomean_optimal(&self) -> f64 {
        let ratios: Vec<f64> = self.results[0]
            .iter()
            .flatten()
            .map(|r| r.density)
            .collect();
        if ratios.is_empty() {
            // Fall back to any populated mode row.
            let ratios: Vec<f64> = self
                .results
                .iter()
                .flat_map(|row| row.iter().flatten().map(|r| r.density))
                .take(self.layers.len())
                .collect();
            return 1.0 - geomean(&ratios);
        }
        1.0 - geomean(&ratios)
    }
}

/// Process-wide cache of the benchmark suite's synthesised feature maps
/// (§Perf: `gratetile all` prices the same 23 maps on two platforms
/// across three figures — synthesise them once).
pub fn suite_feature_maps() -> &'static [(BenchLayer, FeatureMap)] {
    use std::sync::OnceLock;
    static FMS: OnceLock<Vec<(BenchLayer, FeatureMap)>> = OnceLock::new();
    FMS.get_or_init(|| {
        crate::config::zoo::benchmark_suite()
            .into_iter()
            .map(|b| {
                let fm = bench_feature_map(&b);
                (b, fm)
            })
            .collect()
    })
}

/// Run the full (cached) benchmark suite under every mode.
pub fn run_suite_shared(
    hw: &Hardware,
    modes: &[DivisionMode],
    scheme: Scheme,
) -> SuiteResult {
    let cached = suite_feature_maps();
    let mut results = Vec::with_capacity(modes.len());
    for &mode in modes {
        let mut row = Vec::with_capacity(cached.len());
        for (b, fm) in cached {
            row.push(run_bench_layer(hw, b, mode, scheme, fm).ok());
        }
        results.push(row);
    }
    SuiteResult {
        platform: hw.name.to_string(),
        scheme,
        modes: modes.to_vec(),
        layers: cached
            .iter()
            .map(|(b, _)| format!("{} {}", b.network.name(), b.name))
            .collect(),
        results,
    }
}

/// Run the full benchmark suite under every mode (Fig. 8/9, Table III).
pub fn run_suite(
    hw: &Hardware,
    benches: &[BenchLayer],
    modes: &[DivisionMode],
    scheme: Scheme,
) -> SuiteResult {
    let fms: Vec<FeatureMap> = benches.iter().map(bench_feature_map).collect();
    let mut results = Vec::with_capacity(modes.len());
    for &mode in modes {
        let mut row = Vec::with_capacity(benches.len());
        for (b, fm) in benches.iter().zip(&fms) {
            row.push(run_bench_layer(hw, b, mode, scheme, fm).ok());
        }
        results.push(row);
    }
    SuiteResult {
        platform: hw.name.to_string(),
        scheme,
        modes: modes.to_vec(),
        layers: benches.iter().map(|b| format!("{} {}", b.network.name(), b.name)).collect(),
        results,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;
    use crate::config::zoo::{network_layers, Network};

    fn small_fm(density: f64) -> (ConvLayer, FeatureMap) {
        let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
        let fm = generate(56, 56, 64, SparsityParams::clustered(density, 9));
        (layer, fm)
    }

    #[test]
    fn raw_scheme_fetches_at_least_baseline() {
        // Uncompressed sub-tensors: fetching whole blocks on halo'd
        // windows must cost >= the dense baseline.
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.4);
        let r = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 8 }, Scheme::Raw)
            .unwrap();
        assert!(r.fetched_bits >= r.baseline_bits);
        assert!(r.saving_without_meta() <= 0.0);
    }

    #[test]
    fn gratetile_beats_uniform_at_paper_density() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.37);
        let gr = run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
            .unwrap();
        let u8 = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 8 }, Scheme::Bitmask)
            .unwrap();
        let u2 = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 2 }, Scheme::Bitmask)
            .unwrap();
        assert!(
            gr.saving_with_meta() > u8.saving_with_meta(),
            "grate {} vs uniform8 {}",
            gr.saving_with_meta(),
            u8.saving_with_meta()
        );
        assert!(gr.saving_with_meta() > u2.saving_with_meta());
        // And lands in the paper's ballpark (~0.45-0.62 saving for d=0.37).
        assert!((0.40..0.70).contains(&gr.saving_with_meta()), "{}", gr.saving_with_meta());
    }

    #[test]
    fn saving_bounded_by_optimal() {
        // No scheme can save more than the zero fraction + mask trick:
        // the paper's optimal is the density line; allow the bitmask's
        // all-zero-block advantage a tiny epsilon.
        let hw = Platform::EyerissLargeTile.hardware();
        let (layer, fm) = small_fm(0.5);
        for mode in DivisionMode::table3_modes() {
            if let Ok(r) = run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask) {
                assert!(
                    r.saving_without_meta() <= r.optimal_saving() + 0.02,
                    "{}: {} > optimal {}",
                    mode.name(),
                    r.saving_without_meta(),
                    r.optimal_saving()
                );
            }
        }
    }

    #[test]
    fn compact_1x1_is_upper_bound_without_meta_but_loses_with_meta() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.37);
        let compact =
            run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 1 }, Scheme::Bitmask)
                .unwrap();
        let grate =
            run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
                .unwrap();
        // §IV-B(2): 1x1x8 compact is the no-overhead upper bound...
        assert!(compact.saving_without_meta() >= grate.saving_without_meta());
        // ...but its 25% metadata makes it the worst with overhead.
        assert!(compact.saving_with_meta() < grate.saving_with_meta());
    }

    #[test]
    fn denser_maps_save_less() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm_sparse) = small_fm(0.2);
        let (_, fm_dense) = small_fm(0.8);
        let s = run_layer(&hw, &layer, &fm_sparse, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask).unwrap();
        let d = run_layer(&hw, &layer, &fm_dense, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask).unwrap();
        assert!(s.saving_with_meta() > d.saving_with_meta());
    }

    #[test]
    fn suite_runs_and_geomeans() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let benches = network_layers(Network::AlexNet);
        let modes = [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 8 }];
        let suite = run_suite(&hw, &benches, &modes, Scheme::Bitmask);
        let g = suite.geomean_saving(0, true).unwrap();
        let u = suite.geomean_saving(1, true).unwrap();
        assert!(g > u, "grate {g} vs uniform {u}");
        assert!(g > 0.3 && g < 0.8);
        assert!(suite.geomean_optimal() > g - 0.02);
    }

    #[test]
    fn mod16_na_on_small_tile_suite() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let benches = network_layers(Network::Vgg16);
        let modes = [DivisionMode::GrateTile { n: 16 }];
        let suite = run_suite(&hw, &benches, &modes, Scheme::Bitmask);
        assert_eq!(suite.geomean_saving(0, true), None);
    }

    #[test]
    fn metadata_bits_scale_with_division_granularity() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (layer, fm) = small_fm(0.4);
        let fine = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 2 }, Scheme::Bitmask).unwrap();
        let coarse = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge: 8 }, Scheme::Bitmask).unwrap();
        assert!(fine.metadata_bits > coarse.metadata_bits);
    }
}
