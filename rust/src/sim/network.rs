//! Whole-network bandwidth simulation.
//!
//! The paper prices representative layers; a deployed system processes
//! whole networks, where every intermediate map is both *written back*
//! compressed (producer side) and *fetched* tiled (consumer side). This
//! module runs a network's full conv stack through the storage model
//! and reports both directions, giving the end-to-end DRAM traffic a
//! GrateTile deployment would see.

use super::experiment::run_layer;
use super::report::LayerBandwidth;
use crate::compress::CodecPolicy;
use crate::config::hardware::Hardware;
use crate::config::zoo::{full_conv_stack, network_layers, Network};
use crate::layout::packer::Packer;
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tiling::division::{Division, DivisionMode};

/// Per-network totals.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    pub network: Network,
    pub mode: String,
    pub per_layer: Vec<LayerBandwidth>,
    /// Compressed payload write-back bits of every intermediate map
    /// (producer side; the baseline writes the dense map once).
    pub writeback_payload_bits: u64,
    /// Producer-side metadata bits (the Fig. 7 index is *written* as
    /// well as read — the overhead the paper bounds at 0.6%).
    pub writeback_meta_bits: u64,
    pub writeback_baseline_bits: u64,
}

impl NetworkReport {
    /// Total producer-side bits (payload + index).
    pub fn writeback_bits(&self) -> u64 {
        self.writeback_payload_bits + self.writeback_meta_bits
    }

    pub fn fetch_saving(&self) -> f64 {
        let fetched: u64 = self
            .per_layer
            .iter()
            .map(|l| l.fetched_bits + l.metadata_bits)
            .sum();
        let base: u64 = self.per_layer.iter().map(|l| l.baseline_bits).sum();
        1.0 - fetched as f64 / base as f64
    }

    pub fn writeback_saving(&self) -> f64 {
        1.0 - self.writeback_bits() as f64 / self.writeback_baseline_bits as f64
    }

    /// Combined read+write saving.
    pub fn total_saving(&self) -> f64 {
        let moved: u64 = self
            .per_layer
            .iter()
            .map(|l| l.fetched_bits + l.metadata_bits)
            .sum::<u64>()
            + self.writeback_bits();
        let base: u64 =
            self.per_layer.iter().map(|l| l.baseline_bits).sum::<u64>()
                + self.writeback_baseline_bits;
        1.0 - moved as f64 / base as f64
    }
}

/// Interpolated activation density for layer `i` of `n` from the
/// network's calibrated bench-layer densities (front-to-back).
pub fn depth_density(net: Network, i: usize, n: usize) -> f64 {
    let bench = network_layers(net);
    let first = bench.first().map(|b| b.density).unwrap_or(0.5);
    let last = bench.last().map(|b| b.density).unwrap_or(0.3);
    if n <= 1 {
        return first;
    }
    let t = i as f64 / (n - 1) as f64;
    first + (last - first) * t
}

/// The analytic producer-side cost of writing `fm` back compressed for
/// its consumer `layer`: `(payload_bits, metadata_bits)` — payload
/// line-padded exactly like storage, metadata one Fig. 7 record per
/// block at the policy's record width (adaptive records carry their
/// 2-bit codec tags). This is the closed form the functional
/// [`crate::store::StoreWriter`] must (and does, asserted in
/// `tests/store_roundtrip.rs`) reproduce bit for bit.
pub fn writeback_cost(
    hw: &Hardware,
    layer: &crate::config::layer::ConvLayer,
    fm: &crate::tensor::FeatureMap,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
) -> Result<(u64, u64), crate::tiling::division::DivisionError> {
    let tile = hw.tile_for_layer(layer);
    let div = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c)?;
    let packed = Packer::new(*hw, policy).pack(fm, &div, false);
    Ok((packed.total_words * 16, packed.meta_total_bits()))
}

/// Simulate a whole network's feature traffic under one division mode.
/// The first layer's input (the image) is dense and skipped, as in the
/// paper's AlexNet treatment.
pub fn run_network_bandwidth(
    hw: &Hardware,
    net: Network,
    mode: DivisionMode,
    policy: impl Into<CodecPolicy>,
    seed: u64,
) -> NetworkReport {
    let policy = policy.into();
    let stack = full_conv_stack(net);
    let n = stack.len();
    let mut per_layer = Vec::new();
    let mut writeback_payload_bits = 0u64;
    let mut writeback_meta_bits = 0u64;
    let mut writeback_baseline_bits = 0u64;

    for (i, layer) in stack.iter().enumerate().skip(1) {
        let density = depth_density(net, i, n);
        let fm = generate(
            layer.h,
            layer.w,
            layer.c_in,
            SparsityParams::clustered(density, seed ^ (i as u64) << 8),
        );
        // Consumer side: tiled fetch of this layer's input.
        if let Ok(mut r) = run_layer(hw, layer, &fm, mode, policy) {
            r.network = net.name().to_string();
            r.layer = format!("conv{i}");
            per_layer.push(r);
        }
        // Producer side: the previous layer wrote this map compressed
        // (payload and index accounted separately).
        if let Ok((payload, meta)) = writeback_cost(hw, layer, &fm, mode, policy) {
            writeback_payload_bits += payload;
            writeback_meta_bits += meta;
            writeback_baseline_bits += (fm.words() * 16) as u64;
        }
    }

    NetworkReport {
        network: net,
        mode: mode.name(),
        per_layer,
        writeback_payload_bits,
        writeback_meta_bits,
        writeback_baseline_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;

    /// Whole-network adaptive traffic: never more payload than the best
    /// fixed codec once both sides carry the tag budget, and strictly
    /// positive index traffic.
    #[test]
    fn adaptive_network_never_loses_to_fixed_payload() {
        let hw = Platform::EyerissLargeTile.hardware();
        let mode = DivisionMode::GrateTile { n: 8 };
        let auto = run_network_bandwidth(&hw, Network::AlexNet, mode, CodecPolicy::Adaptive, 9);
        for scheme in crate::compress::Registry::global().schemes() {
            let fixed = run_network_bandwidth(&hw, Network::AlexNet, mode, scheme, 9);
            assert!(
                auto.writeback_payload_bits <= fixed.writeback_payload_bits,
                "auto payload vs {}",
                scheme.name()
            );
        }
        assert!(auto.writeback_meta_bits > 0);
        assert!(auto.total_saving() > 0.25, "{}", auto.total_saving());
    }

    #[test]
    fn alexnet_network_report() {
        let hw = Platform::EyerissLargeTile.hardware();
        let r = run_network_bandwidth(
            &hw,
            Network::AlexNet,
            DivisionMode::GrateTile { n: 8 },
            Scheme::Bitmask,
            1,
        );
        assert_eq!(r.per_layer.len(), 4); // conv2..conv5
        assert!(r.fetch_saving() > 0.25, "{}", r.fetch_saving());
        assert!(r.writeback_saving() > 0.25, "{}", r.writeback_saving());
        assert!(r.total_saving() > 0.25);
    }

    #[test]
    fn writeback_never_exceeds_dense_plus_meta() {
        let hw = Platform::EyerissLargeTile.hardware();
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 4 }] {
            let r = run_network_bandwidth(&hw, Network::ResNet18, mode, Scheme::Bitmask, 2);
            // Compressed write-back must beat dense write-back at these
            // densities (compression ratio < 1 with small metadata).
            assert!(
                r.writeback_bits() < r.writeback_baseline_bits,
                "{}: {} vs {}",
                r.mode,
                r.writeback_bits(),
                r.writeback_baseline_bits
            );
        }
    }

    /// Producer-side metadata is accounted separately and, for GrateTile
    /// mod 8, stays in the paper's ~0.6% band of the payload it indexes.
    #[test]
    fn writeback_meta_bits_accounted_and_bounded() {
        let hw = Platform::EyerissLargeTile.hardware();
        let r = run_network_bandwidth(
            &hw,
            Network::AlexNet,
            DivisionMode::GrateTile { n: 8 },
            Scheme::Bitmask,
            5,
        );
        assert!(r.writeback_meta_bits > 0);
        assert_eq!(
            r.writeback_bits(),
            r.writeback_payload_bits + r.writeback_meta_bits
        );
        let frac = r.writeback_meta_bits as f64 / r.writeback_baseline_bits as f64;
        assert!(frac < 0.01, "index overhead {frac}");
    }

    #[test]
    fn grate_beats_uniform_at_network_scope() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let g = run_network_bandwidth(
            &hw, Network::Vgg16, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask, 3,
        );
        let u = run_network_bandwidth(
            &hw, Network::Vgg16, DivisionMode::Uniform { edge: 8 }, Scheme::Bitmask, 3,
        );
        assert!(g.total_saving() > u.total_saving());
    }

    #[test]
    fn depth_density_interpolates() {
        let d0 = depth_density(Network::Vgg16, 0, 13);
        let dl = depth_density(Network::Vgg16, 12, 13);
        assert!(d0 > dl, "VGG activations get sparser with depth");
        let mid = depth_density(Network::Vgg16, 6, 13);
        assert!(mid < d0 && mid > dl);
    }
}
