//! The DRAM bandwidth simulator (paper §IV).
//!
//! [`walker`] iterates the exact tile fetch pattern an accelerator
//! produces for a layer — halo'd input windows per output tile, stepping
//! by `s·t` — and prices each window under a division + compression
//! scheme: whole compressed sub-tensors at line granularity, plus block
//! metadata records (Table II widths) once per touched block per tile.
//!
//! [`pricer`] evaluates that cost model in O(tiles) per layer: 3D
//! inclusive prefix sums over the sub-tensor cost grid turn each
//! window's fetch cost into 8 corner lookups, with the naive
//! per-sub-tensor walk kept as a property-tested reference oracle.
//!
//! [`experiment`] wraps the pricer into the paper's experiments: one
//! layer → [`report::LayerBandwidth`]; the benchmark suite → geometric
//! means per division mode (Fig. 8, Fig. 9, Table III), fanned across
//! (platform × mode × layer) worker threads.

pub mod access;
pub mod experiment;
pub mod metacache;
pub mod network;
pub mod pricer;
pub mod report;
pub mod walker;

pub use access::{access_study, AccessStudy};
pub use experiment::{
    run_bench_layer, run_layer, run_layer_naive, run_suite, run_suites, SuiteResult,
};
pub use pricer::{price_naive, LayerPricer, WalkCost};
pub use metacache::{metadata_cache_study, MetaCacheStudy, TileOrder};
pub use network::{run_network_bandwidth, writeback_cost, NetworkReport};
pub use report::LayerBandwidth;
pub use walker::TileWalker;
