//! Bandwidth reports: the quantities behind Fig. 8/9 and Table III.

use crate::config::hardware::BYTES_PER_WORD;

/// Result of simulating one layer under one division mode.
#[derive(Debug, Clone)]
pub struct LayerBandwidth {
    pub network: String,
    pub layer: String,
    pub mode: String,
    pub platform: String,
    /// Dense (uncompressed) fetch in bits — the denominator of every
    /// saving (16 bits per word).
    pub baseline_bits: u64,
    /// Compressed sub-tensor fetch in bits (line-granular for aligned
    /// modes, exact for the compact baseline).
    pub fetched_bits: u64,
    /// Metadata record bits fetched (Table II widths × touches).
    pub metadata_bits: u64,
    /// Nonzero fraction of the input map — the paper's "optimal" line.
    pub density: f64,
    pub n_tiles: u64,
}

impl LayerBandwidth {
    /// Metadata traffic in words (16-bit words).
    pub fn metadata_words(&self) -> u64 {
        self.metadata_bits.div_ceil(16)
    }

    /// Bandwidth saved ignoring metadata (Table III "Without overhead").
    pub fn saving_without_meta(&self) -> f64 {
        1.0 - self.fetched_bits as f64 / self.baseline_bits as f64
    }

    /// Bandwidth saved including metadata (Table III "With overhead").
    pub fn saving_with_meta(&self) -> f64 {
        1.0 - (self.fetched_bits + self.metadata_bits) as f64
            / self.baseline_bits as f64
    }

    /// The paper's optimal reduction: the zero fraction.
    pub fn optimal_saving(&self) -> f64 {
        1.0 - self.density
    }

    /// Total bytes moved (with metadata).
    pub fn bytes_with_meta(&self) -> u64 {
        (self.fetched_bits + self.metadata_bits).div_ceil(8)
    }

    /// Baseline words (16-bit).
    pub fn baseline_words(&self) -> u64 {
        self.baseline_bits / BYTES_PER_WORD as u64 / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lb(baseline_bits: u64, fetched_bits: u64, meta_bits: u64) -> LayerBandwidth {
        LayerBandwidth {
            network: "t".into(),
            layer: "l".into(),
            mode: "m".into(),
            platform: "p".into(),
            baseline_bits,
            fetched_bits,
            metadata_bits: meta_bits,
            density: 0.4,
            n_tiles: 1,
        }
    }

    #[test]
    fn savings_arithmetic() {
        let r = lb(16_000, 7_200, 800);
        assert!((r.saving_without_meta() - 0.55).abs() < 1e-12);
        assert!((r.saving_with_meta() - 0.50).abs() < 1e-12);
        assert!((r.optimal_saving() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn meta_always_hurts() {
        let r = lb(16_000, 7_200, 999);
        assert!(r.saving_with_meta() < r.saving_without_meta());
    }

    #[test]
    fn bytes_and_words_reported() {
        let r = lb(16_000, 6_400, 160);
        assert_eq!(r.bytes_with_meta(), (6_400 + 160) / 8);
        assert_eq!(r.baseline_words(), 1000);
        assert_eq!(r.metadata_words(), 10);
    }
}
