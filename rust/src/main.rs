//! `gratetile` — the leader binary: regenerate every paper table and
//! figure, run ablations, sweeps, and the end-to-end / serving drivers.
//!
//! ```text
//! gratetile table1|table2|table3|fig1|fig8|fig9      # paper artefacts
//! gratetile sweep --density 0.37 --codec bitmask     # one-layer sweep (--codec auto = adaptive)
//! gratetile ablation --codecs|--whole-channel|--sweep|--dilated
//! gratetile e2e [--mode grate8] [--requests 4]       # PJRT end-to-end
//! gratetile serve --workers 4 --requests 32          # serving simulator (--wall for host time)
//! gratetile serve --trace out.json --metrics m.json  # + Perfetto trace / metrics dump
//! gratetile trace --requests 8 --limit 120           # text timeline + counter rollup
//! gratetile servescale                               # serve-scaling study table
//! gratetile chaos                                    # fault-injection chaos study table
//! gratetile store pack|inspect|serve|compare         # .grate containers
//! ```

use gratetile::cli::Cli;
use gratetile::util::error::{Context, Result};
use gratetile::{bail, err, log_error, log_info, log_warn};
use gratetile::compress::{CodecPolicy, Registry};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{
    metrics_of, simulate_traced, LayerRunner, PipelineConfig, Server, ServerConfig, SimServer,
    SimServerConfig, Weights,
};
use gratetile::harness;
use gratetile::memsim::DramTiming;
use gratetile::obs::TraceRecorder;
use gratetile::runtime::{Engine, Manifest};
use gratetile::sim::experiment::run_layer;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::division::DivisionMode;
use gratetile::util::table::Table;
use std::path::Path;

fn main() {
    let cli = Cli::parse(std::env::args().skip(1));
    if let Err(e) = run(&cli) {
        log_error!("{e:#}");
        std::process::exit(1);
    }
}

fn emit(cli: &Cli, name: &str, t: Table) {
    if cli.has_flag("markdown") {
        println!("{}", t.render_markdown());
    } else {
        println!("{}", t.render());
    }
    t.save_csv(name);
}

/// The one division-mode parser: [`DivisionMode::parse`] reads the same
/// keys `DivisionMode::key` renders (and tuned manifests carry),
/// including the tuner's shifted `anchored<edge>@<anchor>` grids.
fn parse_mode(s: &str) -> Result<DivisionMode> {
    DivisionMode::parse(s).map_err(|e| err!("{e}"))
}

/// The one codec-name parser (satisfying ISSUE 5's dedup): the
/// registry resolves names/aliases and `auto`, and lists the valid
/// codecs on failure.
fn parse_policy(s: &str) -> Result<CodecPolicy> {
    Registry::global().parse_policy(s)
}

fn run(cli: &Cli) -> Result<()> {
    // Logging first: `--quiet` wins over `--verbose`; with neither, the
    // GRATETILE_LOG env var (error/warn/info/debug) picks the level.
    gratetile::obs::log::configure(cli.has_flag("verbose"), cli.has_flag("quiet"));
    if let Some(jobs) = cli.opt_parsed::<usize>("jobs") {
        gratetile::util::parallel::set_threads(jobs);
    }
    // `--codec` is canonical; `--scheme` stays as an alias.
    let policy =
        parse_policy(cli.opt("codec").or(cli.opt("scheme")).unwrap_or("bitmask"))?;
    match cli.command.as_str() {
        "table1" => emit(cli, "table1", harness::table1()),
        "table2" => emit(cli, "table2", harness::table2()),
        "table3" => emit(cli, "table3", harness::table3(policy)),
        "fig1" => emit(cli, "fig1", harness::fig1()),
        "fig8" => emit(cli, "fig8", harness::fig8(policy)),
        "fig9" => {
            emit(cli, "fig9a", harness::fig9(Platform::NvidiaSmallTile, policy));
            emit(cli, "fig9b", harness::fig9(Platform::EyerissLargeTile, policy));
        }
        "all" => {
            emit(cli, "fig1", harness::fig1());
            emit(cli, "table1", harness::table1());
            emit(cli, "table2", harness::table2());
            emit(cli, "table3", harness::table3(policy));
            emit(cli, "fig8", harness::fig8(policy));
            emit(cli, "fig9a", harness::fig9(Platform::NvidiaSmallTile, policy));
            emit(cli, "fig9b", harness::fig9(Platform::EyerissLargeTile, policy));
        }
        "ablation" => {
            let all = cli.flags.is_empty();
            if all || cli.has_flag("codecs") {
                emit(cli, "ablation_codecs", harness::ablation_codecs());
            }
            if all || cli.has_flag("whole-channel") {
                emit(cli, "ablation_whole_channel", harness::ablation_whole_channel());
            }
            if all || cli.has_flag("sweep") {
                emit(cli, "ablation_sweep", harness::ablation_sweep());
            }
            if all || cli.has_flag("dilated") {
                emit(cli, "ablation_dilated", harness::ablation_dilated());
            }
        }
        "network" => emit(cli, "network", harness::network_table(policy)),
        "store" => cmd_store(cli, policy)?,
        "access" => emit(cli, "access", harness::access_table()),
        "metacache" => emit(cli, "metacache", harness::metacache_table()),
        "datapath" => emit(cli, "datapath", harness::codec_datapath_table()),
        "roofline" => emit(cli, "roofline", harness::roofline_table(policy)),
        "gemm" => emit(cli, "gemm", harness::gemm_table()),
        "sweep" => cmd_sweep(cli, policy)?,
        "tune" => cmd_tune(cli)?,
        "e2e" => cmd_e2e(cli, policy)?,
        "serve" => cmd_serve(cli, policy)?,
        "trace" => cmd_trace(cli, policy)?,
        "servescale" => emit(cli, "serve_scaling", harness::serve_scaling_table()),
        "chaos" => emit(cli, "chaos", harness::chaos_table()),
        "lint" => cmd_lint(cli)?,
        "" | "help" | "--help" => print_help(),
        other => {
            print_help();
            bail!("unknown subcommand '{other}'");
        }
    }
    Ok(())
}

/// `gratetile lint` — the self-hosted invariant linter over this
/// crate's own sources (`src/` + `tests/`; see `gratetile::analysis`).
/// `--root DIR` overrides crate-root auto-detection, `--deny-warnings`
/// (the CI mode) also fails on stale suppressions, `--report F` writes
/// the rendered report to a file.
fn cmd_lint(cli: &Cli) -> Result<()> {
    let deny = cli.has_flag("deny-warnings");
    let (rendered, ok) =
        gratetile::analysis::run_cli(cli.opt("root"), deny, cli.opt("report"))?;
    print!("{rendered}");
    if !ok {
        bail!("lint failed{}", if deny { " (--deny-warnings)" } else { "" });
    }
    Ok(())
}

/// The auto-tuner study: per-layer exact search over division × codec ×
/// tile order, rendered against the fixed presets. `--out F` also
/// writes the tuned manifest (`tunedv 1` + `tuned` lines) for
/// `store pack --tuned` and manifest-driven serving.
fn cmd_tune(cli: &Cli) -> Result<()> {
    use gratetile::config::zoo::Network;
    let networks: Vec<Network> = match cli.opt("network") {
        Some(name) => vec![match name.to_ascii_lowercase().as_str() {
            "alexnet" => Network::AlexNet,
            "vgg16" => Network::Vgg16,
            "resnet18" => Network::ResNet18,
            "resnet50" => Network::ResNet50,
            "vdsr" => Network::Vdsr,
            other => bail!(
                "unknown network '{other}' (alexnet, vgg16, resnet18, resnet50, vdsr)"
            ),
        }],
        None => harness::TUNE_STUDY_NETWORKS.to_vec(),
    };
    let (t, manifest) = harness::tune_study(&networks);
    emit(cli, "tune", t);
    if let Some(path) = cli.opt("out") {
        std::fs::write(path, manifest.render())
            .with_context(|| format!("writing tuned manifest {path}"))?;
        log_info!("wrote tuned manifest ({} layers) to {path}", manifest.entries.len());
    }
    Ok(())
}

/// One-layer bandwidth sweep across division modes. With `--config
/// <file>` the layers and hardware come from a config file instead.
fn cmd_sweep(cli: &Cli, policy: CodecPolicy) -> Result<()> {
    if let Some(path) = cli.opt("config") {
        return cmd_sweep_config(cli, policy, Path::new(path));
    }
    let density = cli.opt_f64("density", 0.37);
    let h = cli.opt_usize("h", 56);
    let w = cli.opt_usize("w", 56);
    let c = cli.opt_usize("c", 64);
    let k = cli.opt_usize("k", 1);
    let s = cli.opt_usize("s", 1);
    let seed = cli.opt_usize("seed", 42) as u64;
    let layer = ConvLayer::new(k, s, h, w, c, c);
    let fm = generate(h, w, c, SparsityParams::clustered(density, seed));
    let mut t = Table::new(&format!(
        "Sweep — {h}x{w}x{c} k={} s={s} density={density} ({})",
        2 * k + 1,
        policy.name()
    ))
    .header(vec!["Mode", "NVIDIA w/ ovh %", "Eyeriss w/ ovh %"]);
    for mode in DivisionMode::table3_modes() {
        let cell = |p: Platform| {
            run_layer(&p.hardware(), &layer, &fm, mode, policy)
                .map(|r| format!("{:.1}", r.saving_with_meta() * 100.0))
                .unwrap_or("N/A".into())
        };
        t.row(vec![
            mode.name(),
            cell(Platform::NvidiaSmallTile),
            cell(Platform::EyerissLargeTile),
        ]);
    }
    emit(cli, "sweep", t);
    Ok(())
}

/// Config-file-driven sweep (custom hardware + layers).
fn cmd_sweep_config(cli: &Cli, policy: CodecPolicy, path: &Path) -> Result<()> {
    use gratetile::config::FileConfig;
    let cfg = FileConfig::load(path)?;
    let hw = cfg.hardware_or(Platform::EyerissLargeTile);
    let mut t = Table::new(&format!("Config sweep — {} ({})", path.display(), policy.name()))
        .header(vec!["Layer".to_string(), "Density".to_string(), "Mode".to_string(), "Saving w/ ovh %".to_string()]);
    for cl in &cfg.layers {
        let fm = generate(
            cl.layer.h,
            cl.layer.w,
            cl.layer.c_in,
            SparsityParams::clustered(cl.density, 42),
        );
        for mode in DivisionMode::table3_modes() {
            match run_layer(&hw, &cl.layer, &fm, mode, policy) {
                Ok(r) => {
                    t.row(vec![
                        cl.name.clone(),
                        format!("{:.2}", cl.density),
                        mode.name(),
                        format!("{:.1}", r.saving_with_meta() * 100.0),
                    ]);
                }
                Err(_) => {
                    t.row(vec![cl.name.clone(), format!("{:.2}", cl.density), mode.name(), "N/A".into()]);
                }
            }
        }
    }
    emit(cli, "sweep_config", t);
    Ok(())
}

/// End-to-end: PJRT CNN → real activations → GrateTile pipeline.
fn cmd_e2e(cli: &Cli, policy: CodecPolicy) -> Result<()> {
    let mode = parse_mode(cli.opt_or("mode", "grate8"))?;
    let artifacts = Path::new(cli.opt_or("artifacts", "artifacts")).to_path_buf();
    let n_images = cli.opt_usize("requests", 4);

    let manifest = Manifest::load(&artifacts)?;
    let entry = manifest.get("cnn")?;
    let engine = Engine::cpu()?;
    let model = engine.load_entry(entry)?;
    log_info!("PJRT platform: {}; artifact: {}", engine.platform(), entry.file.display());

    let (h, w, c) = (entry.input_dims[0], entry.input_dims[1], entry.input_dims[2]);
    let mut cfg = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    cfg.mode = mode;
    cfg.policy = policy;
    let runner = LayerRunner::new(cfg);

    let mut t = Table::new("E2E — real ReLU activations through the GrateTile store")
        .header(vec!["image", "layer", "density %", "saving w/ ovh %", "pipeline"]);
    for img_i in 0..n_images {
        let image: Vec<f32> = (0..h * w * c)
            .map(|i| {
                let y = (i / (w * c)) as f32 / h as f32;
                let x = ((i / c) % w) as f32 / w as f32;
                let p = img_i as f32;
                (x * y + (7.0 * x + p).sin() * 0.15 + (5.0 * y - p).cos() * 0.1).max(0.0)
            })
            .collect();
        let fms = model.run_cnn(entry, &image)?;
        for (li, fm) in fms.iter().enumerate() {
            // Next-layer geometry: a 3x3 s=1 consumer of this map.
            let layer = ConvLayer::new(1, 1, fm.h, fm.w, fm.c, fm.c);
            let report = run_layer(&cfg.hw, &layer, fm, mode, policy)?;
            // And actually run the tiled pipeline on it.
            let weights = Weights::random(&layer, li as u64);
            let packed = runner.pack(&layer, fm)?;
            let (_out, m) = runner.run_layer(&layer, &weights, &packed)?;
            t.row(vec![
                format!("{img_i}"),
                format!("L{li} {}x{}x{}", fm.h, fm.w, fm.c),
                format!("{:.1}", fm.density() * 100.0),
                format!("{:.1}", report.saving_with_meta() * 100.0),
                m.summary(),
            ]);
        }
    }
    emit(cli, "e2e", t);
    Ok(())
}

/// The tensor-store toolbox: pack feature maps into a `.grate`
/// container, inspect/verify one, serve inference from one, or compare
/// the functional write path against the analytic simulator.
fn cmd_store(cli: &Cli, policy: CodecPolicy) -> Result<()> {
    use gratetile::layout::Packer;
    use gratetile::store::Container;
    use gratetile::tiling::Division;

    let action = cli.positional.first().map(|s| s.as_str()).unwrap_or("");
    match action {
        "pack" => {
            // `--manifest <dir> --name <container>` resolves the output
            // path and codec policy from the manifest's
            // `container <name> <file> [codec=...]` line (explicit
            // `--out` / `--codec` still win) — the deployment manifest
            // and the CLI share one codec surface.
            let mut out = std::path::PathBuf::from(cli.opt_or("out", "store.grate"));
            let mut policy = policy;
            if let Some(dir) = cli.opt("manifest") {
                let m = Manifest::load(Path::new(dir))?;
                let cref = m.container_ref(cli.opt_or("name", "acts"))?;
                if cli.opt("out").is_none() {
                    out = cref.path.clone();
                }
                if cli.opt("codec").is_none() && cli.opt("scheme").is_none() {
                    if let Some(p) = cref.policy {
                        policy = p;
                    }
                }
            }
            let out = out.as_path();
            let h = cli.opt_usize("h", 32);
            let w = cli.opt_usize("w", 32);
            let c = cli.opt_usize("c", 16);
            let count = cli.opt_usize("count", 4);
            let density = cli.opt_f64("density", 0.4);
            let seed = cli.opt_usize("seed", 7) as u64;
            let mut mode = parse_mode(cli.opt_or("mode", "grate8"))?;
            // `--tuned F [--plan NAME]`: take the whole plan (division
            // mode + codec policy) from a `gratetile tune` manifest —
            // explicit `--mode`/`--codec` do not apply once tuned.
            if let Some(tf) = cli.opt("tuned") {
                let text = std::fs::read_to_string(tf)
                    .with_context(|| format!("reading tuned manifest {tf}"))?;
                let tm = gratetile::tune::TunedManifest::parse(&text)?;
                let entry = match cli.opt("plan") {
                    Some(name) => tm.get(name).ok_or_else(|| {
                        err!(
                            "plan '{name}' not in {tf} (have: {:?})",
                            tm.entries.iter().map(|(n, _)| n).collect::<Vec<_>>()
                        )
                    })?,
                    None => tm
                        .entries
                        .first()
                        .map(|(_, e)| e)
                        .ok_or_else(|| err!("{tf}: empty tuned manifest"))?,
                };
                mode = entry.plan.mode;
                policy = entry.plan.policy;
            }
            let hw = Platform::NvidiaSmallTile.hardware();
            // Pack for a 3x3 s=1 consumer of each map.
            let layer = ConvLayer::new(1, 1, h, w, c, c);
            let tile = hw.tile_for_layer(&layer);
            let div = Division::build(mode, &layer, &tile, &hw, h, w, c)
                .map_err(|e| err!("{e}"))?;
            let packer = Packer::new(hw, policy);
            let packs: Vec<(String, _)> = (0..count)
                .map(|i| {
                    let fm =
                        generate(h, w, c, SparsityParams::clustered(density, seed + i as u64));
                    (format!("req{i}"), packer.pack(&fm, &div, true))
                })
                .collect();
            let refs: Vec<(String, &_)> =
                packs.iter().map(|(n, p)| (n.clone(), p)).collect();
            Container::write(out, &refs)?;
            let dense_words = (h * w * c * count) as u64;
            let packed_words: u64 = packs.iter().map(|(_, p)| p.total_words).sum();
            log_info!(
                "packed {count} x {h}x{w}x{c} (d={density}) as {} under {} + {}: {} -> {} words ({:.1}%)",
                out.display(),
                mode.name(),
                policy.name(),
                dense_words,
                packed_words,
                packed_words as f64 / dense_words as f64 * 100.0
            );
            Ok(())
        }
        "inspect" => {
            let path = cli
                .positional
                .get(1)
                .map(|s| Path::new(s.as_str()))
                .ok_or_else(|| err!("usage: store inspect <file.grate>"))?;
            let c = Container::open(path)?;
            c.verify()?;
            let mut t = Table::new(&format!("{} — {} tensors, checksums OK", path.display(), c.entries.len()))
                .header(vec!["Tensor", "Shape", "Mode", "Codec", "Payload words", "Ratio %", "Meta bits"]);
            for e in &c.entries {
                let (h, w, ch) = e.shape();
                t.row(vec![
                    e.name.clone(),
                    format!("{h}x{w}x{ch}"),
                    e.packed.division.mode.name(),
                    e.packed.codec_summary(),
                    e.payload_words.to_string(),
                    format!("{:.1}", e.packed.compression_ratio() * 100.0),
                    e.packed.metadata.total_bits().to_string(),
                ]);
            }
            emit(cli, "store_inspect", t);
            Ok(())
        }
        "serve" => {
            let path = cli
                .positional
                .get(1)
                .map(|s| Path::new(s.as_str()))
                .ok_or_else(|| err!("usage: store serve <file.grate>"))?;
            let workers = cli.opt_usize("workers", 2);
            let c = Container::open(path)?;
            let first = c
                .entries
                .first()
                .ok_or_else(|| err!("{}: empty container", path.display()))?;
            let (h, w, ch) = first.shape();
            drop(c);
            // A small demo net matched to the stored maps' shape.
            let l1 = ConvLayer::new(1, 1, h, w, ch, 16);
            let l2 = ConvLayer::new(1, 2, h, w, 16, 8);
            let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
            let server = Server::new(
                ServerConfig {
                    pipeline: PipelineConfig::new(Platform::NvidiaSmallTile.hardware()),
                    workers,
                    queue_depth: workers * 2,
                },
                layers,
            );
            let report = server.serve_container(path)?;
            println!("{}", report.summary());
            Ok(())
        }
        "compare" => {
            emit(cli, "store_compare", harness::store_compare_table(policy));
            Ok(())
        }
        other => bail!("unknown store action '{other}' (pack/inspect/serve/compare)"),
    }
}

/// The demo network `serve` and `trace` run (3 conv layers).
fn demo_net() -> Vec<(ConvLayer, Weights)> {
    let l1 = ConvLayer::new(1, 1, 32, 32, 8, 16);
    let l2 = ConvLayer::new(1, 2, 32, 32, 16, 16);
    let l3 = ConvLayer::new(1, 1, 16, 16, 16, 8);
    vec![
        (l1, Weights::random(&l1, 1)),
        (l2, Weights::random(&l2, 2)),
        (l3, Weights::random(&l3, 3)),
    ]
}

/// Simulator knobs shared by `serve` and `trace`.
fn sim_config(cli: &Cli, pipeline: PipelineConfig) -> SimServerConfig {
    let workers = cli.opt_usize("workers", 4);
    let mut cfg = SimServerConfig::new(pipeline);
    cfg.workers = workers;
    cfg.queue_depth = cli.opt_usize("queue-depth", workers * 2);
    cfg.batch = cli.opt_usize("batch", 1);
    cfg.timing =
        DramTiming { n_banks: cli.opt_usize("banks", 8), ..DramTiming::default() };
    cfg.pe_lanes = cli.opt_usize("lanes", 32) as u64;
    cfg.arrival_gap = cli.opt_usize("arrival-gap", 0) as u64;
    cfg
}

/// Serving driver. Default (and `--sim`): the deterministic
/// discrete-event simulator — reports in simulated cycles, byte-stable
/// for a given seed regardless of host load or `--jobs`. `--trace F` /
/// `--metrics F` additionally write a Perfetto-loadable Chrome trace
/// and a JSON metrics dump of the simulated run (stdout stays
/// byte-identical either way). `--wall` keeps the original host
/// wall-clock leader/worker topology.
fn cmd_serve(cli: &Cli, policy: CodecPolicy) -> Result<()> {
    let workers = cli.opt_usize("workers", 4);
    let requests = cli.opt_usize("requests", 16);
    let density = cli.opt_f64("density", 0.5);
    let seed = cli.opt_usize("seed", 7) as u64;
    let trace_out = cli.opt("trace");
    let metrics_out = cli.opt("metrics");
    let mut pipeline = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    pipeline.policy = policy;
    if cli.has_flag("wall") {
        if trace_out.is_some() || metrics_out.is_some() {
            log_warn!("--trace/--metrics record the simulated path; ignored under --wall");
        }
        let server = Server::new(
            ServerConfig { pipeline, workers, queue_depth: workers * 2 },
            demo_net(),
        );
        let inputs = server.synthetic_requests(requests, density, seed);
        let report = server.serve(inputs)?;
        println!("{}", report.summary());
        return Ok(());
    }
    let server = SimServer::new(sim_config(cli, pipeline), demo_net());
    let inputs = server.synthetic_requests(requests, density, seed);
    let mut rec = if trace_out.is_some() || metrics_out.is_some() {
        TraceRecorder::enabled()
    } else {
        TraceRecorder::disabled()
    };
    let traces = server.functional_pass(&inputs)?;
    let report = simulate_traced(server.cfg(), &traces, &mut rec);
    print!("{}", report.render());
    if let Some(path) = trace_out {
        std::fs::write(path, rec.to_chrome_json())
            .with_context(|| format!("writing trace {path}"))?;
        log_info!("wrote Perfetto trace to {path}");
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, metrics_of(&report, &traces).to_json())
            .with_context(|| format!("writing metrics {path}"))?;
        log_info!("wrote metrics dump to {path}");
    }
    Ok(())
}

/// Run the serving simulator with tracing on and render the recorded
/// trace in the terminal: summary line, indented per-track timeline,
/// and the counter rollup table — the no-Perfetto view of
/// `serve --trace`. `--out F` also writes the Chrome trace JSON.
fn cmd_trace(cli: &Cli, policy: CodecPolicy) -> Result<()> {
    let requests = cli.opt_usize("requests", 16);
    let density = cli.opt_f64("density", 0.5);
    let seed = cli.opt_usize("seed", 7) as u64;
    let limit = cli.opt_usize("limit", 80);
    let mut pipeline = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    pipeline.policy = policy;
    let server = SimServer::new(sim_config(cli, pipeline), demo_net());
    let inputs = server.synthetic_requests(requests, density, seed);
    let mut rec = TraceRecorder::enabled();
    let report = server.serve_traced(inputs, &mut rec)?;
    println!("{}", report.summary());
    print!("{}", rec.render_text(limit));
    emit(cli, "trace_rollup", rec.rollup_table());
    if let Some(path) = cli.opt("out") {
        std::fs::write(path, rec.to_chrome_json())
            .with_context(|| format!("writing trace {path}"))?;
        log_info!("wrote Perfetto trace to {path}");
    }
    Ok(())
}

fn print_help() {
    println!(
        "gratetile — sparse tensor tiling for CNN processing (paper reproduction)

USAGE: gratetile <command> [options]

Paper artefacts:
  fig1                power breakdown (16x16 systolic, Horowitz energies)
  table1              tile shapes + GrateTile configurations
  table2              metadata overhead per division mode
  table3              bandwidth saved with/without metadata (both platforms)
  fig8                overall geomean bandwidth reduction
  fig9                per-layer breakdown (both platforms)
  all                 everything above

Analysis:
  sweep               one-layer sweep      [--h --w --c --k --s --density --codec]
                      or config-file driven [--config layers.ini]
  ablation            extra studies        [--codecs --whole-channel --sweep --dilated]
  tune                auto-tune division x codec x tile order per zoo layer
                      (exact branch-and-bound over the pricer closed forms;
                      never worse than any preset) [--network N]
                      [--out F: write the tuned manifest (tunedv 1 format)]
  network             whole-network read+write traffic per mode
  store pack          synthesize + pack maps into a .grate container
                      [--out --h --w --c --count --density --mode --codec]
                      [--manifest DIR --name N: take out-path + codec from a
                       manifest 'container N file [codec=...]' line]
                      [--tuned F [--plan NAME]: take mode + codec from a
                       'gratetile tune --out' manifest entry]
  store inspect F     verify checksums, list a container's tensors
  store serve F       serve inference from a container  [--workers]
  store compare       functional vs analytic write-back bits per network
  access              DRAM transaction/row-buffer efficiency study
  metacache           metadata SRAM-cache absorption study
  datapath            codec decode datapath cycle model
  roofline            compute/memory bound + runtime speedup per layer
                      (analytic MACs, labelled 'estimate')
  gemm                GEMM compute-backend study: measured MACs + zero-skip
                      elision per layer x density x skip policy, bit-checked
                      against the direct-conv oracle

End to end:
  e2e                 PJRT CNN -> GrateTile pipeline  [--mode --codec --requests]
  serve               serving driver. Default --sim: deterministic discrete-event
                      simulator in simulated cycles (byte-stable per seed)
                      [--workers --requests --density --seed --queue-depth
                       --batch --banks --lanes --arrival-gap]
                      [--trace F: write Perfetto-loadable Chrome trace JSON]
                      [--metrics F: write JSON metrics dump]; --wall: host
                      wall-clock leader/worker topology
  trace               simulate with tracing on, render the text timeline +
                      counter rollup [serve's sim knobs --limit N (0 = all
                      lines) --out F (also write the Chrome trace JSON)]
  servescale          serve-scaling study: workers x queue x density, simulated
                      (fixed bitmask codec — the golden-filed baseline)
  chaos               chaos study: seeded fault injection x defense policy
                      (checksums/retries/shedding) — goodput, recovery, p99

Tooling:
  lint                self-hosted invariant linter over this crate's sources
                      (nondet-iter, wall-clock, panic-in-decoder, stray-print,
                      env-read; suppress with 'lint: allow(rule, reason)'
                      pragmas or justified lint.allow entries)
                      [--root DIR --deny-warnings --report F]

Common flags: --codec NAME|auto (codec policy: bitmask/zrlc/dictionary/raw, or
auto = cheapest codec per sub-tensor; --scheme is an alias); --markdown (emit
GFM tables); --jobs N (suite worker threads, default: all cores, also via
GRATETILE_THREADS); --verbose/--quiet (stderr log level, also via
GRATETILE_LOG=error|warn|info|debug); all tables also land in results/*.csv"
    );
}
