//! # GrateTile — efficient sparse tensor tiling for CNN processing
//!
//! A full-system reproduction of *GrateTile: Efficient Sparse Tensor
//! Tiling for CNN Processing* (Lin et al., 2020) as a three-layer
//! Rust + JAX + Pallas stack. See `DESIGN.md` for the architecture and
//! `EXPERIMENTS.md` for the paper-vs-measured results.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — GrateTile division ([`tiling`]), compressed
//!   memory layout with Fig. 7 metadata ([`layout`]), the tensor store
//!   with its streaming write path and `.grate` container ([`store`]),
//!   the DRAM bandwidth simulator ([`memsim`], [`sim`]), the accelerator
//!   coordinator ([`coordinator`]), a systolic power model ([`power`]),
//!   deterministic tracing/metrics/logging ([`obs`]), and the
//!   evaluation harness ([`harness`]).
//! * **L2/L1 (build time)** — `python/compile/` lowers a JAX CNN (with a
//!   Pallas conv kernel) to HLO text once; [`runtime`] loads and executes
//!   it via PJRT so the e2e example runs on *real* ReLU sparsity.

// The whole crate is safe Rust; the decoder surfaces additionally deny
// `clippy::unwrap_used` via module-level attributes (see `compress`,
// `store::container`, `layout::fetcher`) and the self-hosted linter in
// [`analysis`] enforces the determinism/panic-safety invariants the
// compiler cannot see.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod cli;
pub mod compress;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod harness;
pub mod layout;
pub mod memsim;
pub mod obs;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod tensor;
pub mod tiling;
pub mod tune;
pub mod util;
