//! Direct convolution compute lane (the PE-array stand-in).
//!
//! The coordinator needs a real compute consumer to prove the fetch →
//! decompress → compute path composes; this is a straightforward direct
//! convolution with ReLU, matching the L1 Pallas kernel's semantics
//! (SAME padding, odd kernels, stride, dilation). It doubles as the
//! reference for pipeline correctness tests.

use crate::config::layer::ConvLayer;
use crate::layout::fetcher::DenseWindow;
use crate::tensor::FeatureMap;
use crate::util::SplitMix64;

/// Layer weights in `[ky][kx][cin][cout]` row-major order.
#[derive(Debug, Clone)]
pub struct Weights {
    pub k: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub data: Vec<f32>,
}

impl Weights {
    /// Deterministic pseudo-random weights (He-ish scale, mixed sign so
    /// ReLU produces realistic sparsity).
    pub fn random(layer: &ConvLayer, seed: u64) -> Weights {
        let ks = layer.kernel_size();
        let n = ks * ks * layer.c_in * layer.c_out;
        let mut rng = SplitMix64::new(seed);
        let scale = (2.0 / (ks * ks * layer.c_in) as f32).sqrt();
        let data = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect();
        Weights { k: layer.k, c_in: layer.c_in, c_out: layer.c_out, data }
    }

    /// Wrap explicit weight data (`[ky][kx][cin][cout]` row-major, the
    /// same layout jax's HWIO uses — cross-language fixtures load
    /// through here).
    pub fn from_vec(layer: &ConvLayer, data: Vec<f32>) -> Weights {
        let ks = layer.kernel_size();
        assert_eq!(
            data.len(),
            ks * ks * layer.c_in * layer.c_out,
            "weight data does not match layer geometry"
        );
        Weights { k: layer.k, c_in: layer.c_in, c_out: layer.c_out, data }
    }

    #[inline]
    pub fn at(&self, ky: usize, kx: usize, cin: usize, cout: usize) -> f32 {
        let ks = 2 * self.k + 1;
        self.data[((ky * ks + kx) * self.c_in + cin) * self.c_out + cout]
    }
}

/// Accumulate the partial convolution of one fetched window into an
/// output-tile accumulator (no ReLU yet — channel groups accumulate).
///
/// `acc` is `(oy1-oy0) × (ox1-ox0) × c_out` row-major; the window holds
/// input channels `[win.c0, win.c1)`.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_tile(
    layer: &ConvLayer,
    weights: &Weights,
    win: &DenseWindow,
    acc: &mut [f32],
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
) {
    let ks = layer.kernel_size();
    let halo = layer.halo() as i64;
    let ow = ox1 - ox0;
    let c_out = layer.c_out;
    debug_assert_eq!(acc.len(), (oy1 - oy0) * ow * c_out);
    for oy in oy0..oy1 {
        for ox in ox0..ox1 {
            let base = ((oy - oy0) * ow + (ox - ox0)) * c_out;
            for ky in 0..ks {
                let iy = (oy * layer.s) as i64 + (ky * layer.d) as i64 - halo;
                if iy < 0 || iy >= layer.h as i64 {
                    continue; // SAME zero padding
                }
                let iy = iy as usize;
                if iy < win.y0 || iy >= win.y1 {
                    continue;
                }
                for kx in 0..ks {
                    let ix = (ox * layer.s) as i64 + (kx * layer.d) as i64 - halo;
                    if ix < 0 || ix >= layer.w as i64 {
                        continue;
                    }
                    let ix = ix as usize;
                    if ix < win.x0 || ix >= win.x1 {
                        continue;
                    }
                    // Hoisted inner product (§Perf): resolve the window
                    // row pointer and the weight tap row once, then run
                    // a slice-level AXPY per nonzero input channel.
                    let wrow = (win.x1 - win.x0) * (win.c1 - win.c0);
                    let wbase =
                        ((iy - win.y0) * (win.x1 - win.x0) + (ix - win.x0)) * (win.c1 - win.c0);
                    let _ = wrow;
                    let tap = ((ky * ks + kx) * weights.c_in) * c_out;
                    for cin in win.c0..win.c1 {
                        let v = win.data[wbase + (cin - win.c0)];
                        if v == 0.0 {
                            continue; // sparse skip (PE gating)
                        }
                        let wslice = &weights.data[tap + cin * c_out..tap + (cin + 1) * c_out];
                        let aslice = &mut acc[base..base + c_out];
                        for (a, &wv) in aslice.iter_mut().zip(wslice) {
                            *a += v * wv;
                        }
                    }
                }
            }
        }
    }
}

/// Reference: full dense conv + ReLU over a feature map (oracle for the
/// tiled pipeline).
pub fn direct_conv_relu(layer: &ConvLayer, weights: &Weights, fm: &FeatureMap) -> FeatureMap {
    assert_eq!((fm.h, fm.w, fm.c), (layer.h, layer.w, layer.c_in));
    let (oh, ow) = (layer.out_h(), layer.out_w());
    let ks = layer.kernel_size();
    let halo = layer.halo() as i64;
    let mut out = vec![0.0f32; oh * ow * layer.c_out];
    for oy in 0..oh {
        for ox in 0..ow {
            let base = (oy * ow + ox) * layer.c_out;
            for ky in 0..ks {
                let iy = (oy * layer.s) as i64 + (ky * layer.d) as i64 - halo;
                if iy < 0 || iy >= layer.h as i64 {
                    continue;
                }
                for kx in 0..ks {
                    let ix = (ox * layer.s) as i64 + (kx * layer.d) as i64 - halo;
                    if ix < 0 || ix >= layer.w as i64 {
                        continue;
                    }
                    for cin in 0..layer.c_in {
                        let v = fm.get(iy as usize, ix as usize, cin);
                        if v == 0.0 {
                            continue;
                        }
                        for cout in 0..layer.c_out {
                            out[base + cout] += v * weights.at(ky, kx, cin, cout);
                        }
                    }
                }
            }
        }
    }
    for v in &mut out {
        *v = v.max(0.0);
    }
    FeatureMap::from_vec(oh, ow, layer.c_out, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::sparsity::{generate, SparsityParams};

    #[test]
    fn identity_kernel_passes_through() {
        // 1x1 conv with identity weights = ReLU(fm).
        let layer = ConvLayer::new(0, 1, 6, 6, 3, 3);
        let mut w = Weights { k: 0, c_in: 3, c_out: 3, data: vec![0.0; 9] };
        for c in 0..3 {
            w.data[c * 3 + c] = 1.0;
        }
        let fm = generate(6, 6, 3, SparsityParams::iid(0.5, 1));
        let out = direct_conv_relu(&layer, &w, &fm);
        for y in 0..6 {
            for x in 0..6 {
                for c in 0..3 {
                    assert_eq!(out.get(y, x, c), fm.get(y, x, c).max(0.0));
                }
            }
        }
    }

    #[test]
    fn averaging_kernel_on_constant_input() {
        // 3x3 all-ones kernel over constant-1 input, 1 channel: interior
        // outputs = 9, corners = 4, edges = 6.
        let layer = ConvLayer::new(1, 1, 5, 5, 1, 1);
        let w = Weights { k: 1, c_in: 1, c_out: 1, data: vec![1.0; 9] };
        let fm = FeatureMap::from_vec(5, 5, 1, vec![1.0; 25]);
        let out = direct_conv_relu(&layer, &w, &fm);
        assert_eq!(out.get(2, 2, 0), 9.0);
        assert_eq!(out.get(0, 0, 0), 4.0);
        assert_eq!(out.get(0, 2, 0), 6.0);
    }

    #[test]
    fn stride_two_downsamples() {
        let layer = ConvLayer::new(1, 2, 8, 8, 2, 4);
        let w = Weights::random(&layer, 3);
        let fm = generate(8, 8, 2, SparsityParams::iid(0.7, 2));
        let out = direct_conv_relu(&layer, &w, &fm);
        assert_eq!((out.h, out.w, out.c), (4, 4, 4));
    }

    #[test]
    fn relu_output_is_nonnegative_and_sparse() {
        let layer = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let w = Weights::random(&layer, 7);
        let fm = generate(16, 16, 8, SparsityParams::iid(0.9, 5));
        let out = direct_conv_relu(&layer, &w, &fm);
        assert!(out.as_slice().iter().all(|&v| v >= 0.0));
        let d = out.density();
        assert!(d > 0.1 && d < 0.9, "density {d}");
    }

    #[test]
    fn accumulate_tile_matches_reference() {
        let layer = ConvLayer::new(1, 1, 12, 12, 4, 4);
        let w = Weights::random(&layer, 11);
        let fm = generate(12, 12, 4, SparsityParams::iid(0.6, 6));
        let oracle = direct_conv_relu(&layer, &w, &fm);
        // Manually assemble the full window and accumulate one tile.
        let win = DenseWindow {
            y0: 0,
            y1: 12,
            x0: 0,
            x1: 12,
            c0: 0,
            c1: 4,
            data: fm.as_slice().to_vec(),
        };
        let (oy0, oy1, ox0, ox1) = (2usize, 8usize, 3usize, 9usize);
        let mut acc = vec![0.0f32; (oy1 - oy0) * (ox1 - ox0) * 4];
        accumulate_tile(&layer, &w, &win, &mut acc, oy0, oy1, ox0, ox1);
        for oy in oy0..oy1 {
            for ox in ox0..ox1 {
                for c in 0..4 {
                    let got = acc[((oy - oy0) * 6 + (ox - ox0)) * 4 + c].max(0.0);
                    let want = oracle.get(oy, ox, c);
                    assert!(
                        (crate::tensor::dense::bf16_quantise(got) - want).abs() < 1e-2,
                        "({oy},{ox},{c}): {got} vs {want}"
                    );
                }
            }
        }
    }
}
