//! Request-serving leader/worker topology over the pipeline.
//!
//! The leader owns a bounded request queue (backpressure) and N worker
//! threads, each running the full multi-layer pipeline on its own core —
//! the process shape of an inference service whose accelerator-side
//! storage is GrateTile. Reports throughput and latency percentiles.

use super::conv::Weights;
use super::pipeline::{LayerRunner, PipelineConfig};
use crate::bail;
use crate::config::layer::ConvLayer;
use crate::memsim::Dram;
use crate::store::Container;
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tensor::FeatureMap;
use crate::util::error::Result;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub pipeline: PipelineConfig,
    pub workers: usize,
    /// Bounded queue depth (requests admitted beyond in-flight).
    pub queue_depth: usize,
}

/// One inference request: an input image (dense) to run through the
/// network.
pub struct Request {
    pub id: u64,
    pub input: FeatureMap,
    pub enqueued: Instant,
}

/// Latency/throughput report.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub completed: u64,
    pub wall: Duration,
    pub latencies: Vec<Duration>,
    pub total_feature_bytes: u64,
}

impl ServerReport {
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Latency samples sorted once; every percentile on the returned
    /// set is an O(1) [`super::metrics::percentile_index`] lookup.
    pub fn sorted_latencies(&self) -> super::metrics::SortedSamples<Duration> {
        super::metrics::SortedSamples::from_unsorted(self.latencies.clone())
    }

    /// Nearest-rank latency percentile. `p` is clamped to `[0, 1]`
    /// (NaN selects the minimum), so callers can never panic the index
    /// computation with an out-of-domain fraction. Loops over several
    /// percentiles should sort once via [`Self::sorted_latencies`].
    pub fn percentile(&self, p: f64) -> Duration {
        self.sorted_latencies().at_or(p, Duration::ZERO)
    }

    pub fn summary(&self) -> String {
        let lat = self.sorted_latencies();
        format!(
            "{} requests in {:.2}s -> {:.1} req/s; p50={:.1}ms p95={:.1}ms p99={:.1}ms; feature traffic {} KB",
            self.completed,
            self.wall.as_secs_f64(),
            self.throughput_rps(),
            lat.at_or(0.50, Duration::ZERO).as_secs_f64() * 1e3,
            lat.at_or(0.95, Duration::ZERO).as_secs_f64() * 1e3,
            lat.at_or(0.99, Duration::ZERO).as_secs_f64() * 1e3,
            self.total_feature_bytes / 1024,
        )
    }
}

/// The serving leader.
pub struct Server {
    cfg: ServerConfig,
    layers: Arc<Vec<(ConvLayer, Weights)>>,
}

impl Server {
    pub fn new(cfg: ServerConfig, layers: Vec<(ConvLayer, Weights)>) -> Self {
        Self { cfg, layers: Arc::new(layers) }
    }

    /// Shape expected of request inputs.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        let l = &self.layers[0].0;
        (l.h, l.w, l.c_in)
    }

    /// Generate a synthetic request batch (deterministic).
    pub fn synthetic_requests(&self, n: usize, density: f64, seed: u64) -> Vec<FeatureMap> {
        let (h, w, c) = self.input_shape();
        (0..n)
            .map(|i| generate(h, w, c, SparsityParams::clustered(density, seed + i as u64)))
            .collect()
    }

    /// Serve inference from a `.grate` container: every tensor in the
    /// file becomes one request, fetched dense through the container's
    /// random-access read path, then run through the network with
    /// store-resident intermediates.
    pub fn serve_container(&self, path: &Path) -> Result<ServerReport> {
        let c = Container::open(path)?;
        if c.entries.is_empty() {
            bail!("container {} holds no tensors", path.display());
        }
        let want = self.input_shape();
        let mut inputs = Vec::with_capacity(c.entries.len());
        let mut dram = Dram::default();
        for e in &c.entries {
            if e.shape() != want {
                bail!(
                    "container tensor '{}' is {:?}, the network expects {:?}",
                    e.name,
                    e.shape(),
                    want
                );
            }
            inputs.push(c.fetch_dense(&e.name, &mut dram)?);
        }
        self.serve(inputs)
    }

    /// Serve a fixed batch of requests to completion.
    pub fn serve(&self, inputs: Vec<FeatureMap>) -> Result<ServerReport> {
        let n = inputs.len() as u64;
        let start = Instant::now();
        let (tx, rx) = sync_channel::<Request>(self.cfg.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let latencies = Arc::new(Mutex::new(Vec::<Duration>::new()));
        let feature_bytes = Arc::new(AtomicU64::new(0));

        std::thread::scope(|scope| -> Result<()> {
            // Workers.
            for _ in 0..self.cfg.workers.max(1) {
                let rx = Arc::clone(&rx);
                let layers = Arc::clone(&self.layers);
                let latencies = Arc::clone(&latencies);
                let feature_bytes = Arc::clone(&feature_bytes);
                let cfg = self.cfg;
                scope.spawn(move || {
                    let runner = LayerRunner::new(cfg.pipeline);
                    loop {
                        let req = {
                            let guard = rx.lock().unwrap();
                            match guard.recv() {
                                Ok(r) => r,
                                Err(_) => break, // queue closed
                            }
                        };
                        if let Ok((_out, per_layer)) =
                            runner.run_network(&layers, req.input)
                        {
                            let bytes: u64 =
                                per_layer.iter().map(|m| m.feature_bytes()).sum();
                            feature_bytes.fetch_add(bytes, Ordering::Relaxed);
                            latencies.lock().unwrap().push(req.enqueued.elapsed());
                        }
                    }
                });
            }
            // Leader: admit requests (blocks on backpressure).
            for (i, input) in inputs.into_iter().enumerate() {
                tx.send(Request { id: i as u64, input, enqueued: Instant::now() })
                    .expect("workers alive");
            }
            drop(tx);
            Ok(())
        })?;

        let latencies = Arc::try_unwrap(latencies).unwrap().into_inner().unwrap();
        Ok(ServerReport {
            completed: latencies.len() as u64,
            wall: start.elapsed(),
            latencies,
            total_feature_bytes: feature_bytes.load(Ordering::Relaxed),
        })
        .and_then(|r| {
            if r.completed == n {
                Ok(r)
            } else {
                crate::bail!("{} of {n} requests completed", r.completed)
            }
        })
    }
}

/// Helper for recv in workers.
#[allow(dead_code)]
fn recv_one(rx: &Mutex<Receiver<Request>>) -> Option<Request> {
    rx.lock().unwrap().recv().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;

    fn tiny_net() -> Vec<(ConvLayer, Weights)> {
        let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let l2 = ConvLayer::new(1, 2, 16, 16, 8, 8);
        vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))]
    }

    fn server(workers: usize) -> Server {
        let cfg = ServerConfig {
            pipeline: PipelineConfig::new(Platform::NvidiaSmallTile.hardware()),
            workers,
            queue_depth: 4,
        };
        Server::new(cfg, tiny_net())
    }

    #[test]
    fn serves_all_requests() {
        let s = server(2);
        let reqs = s.synthetic_requests(8, 0.5, 7);
        let report = s.serve(reqs).unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.total_feature_bytes > 0);
        assert!(report.percentile(0.99) >= report.percentile(0.50));
    }

    #[test]
    fn single_worker_also_completes() {
        let s = server(1);
        let reqs = s.synthetic_requests(3, 0.5, 9);
        let report = s.serve(reqs).unwrap();
        assert_eq!(report.completed, 3);
    }

    /// End-to-end container serving: pack request maps into a `.grate`
    /// file, serve inference from it, and check against direct serving
    /// of the same inputs.
    #[test]
    fn serves_inference_from_container_file() {
        use crate::layout::packer::Packer;
        use crate::tiling::division::{Division, DivisionMode};
        let s = server(2);
        let inputs = s.synthetic_requests(3, 0.5, 21);
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = tiny_net()[0].0;
        let tile = hw.tile_for_layer(&layer);
        let div =
            Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 16, 16, 8)
                .unwrap();
        let packer = Packer::new(hw, crate::compress::Scheme::Bitmask);
        let entries: Vec<(String, _)> = inputs
            .iter()
            .enumerate()
            .map(|(i, fm)| (format!("req{i}"), packer.pack(fm, &div, true)))
            .collect();
        let refs: Vec<(String, &_)> =
            entries.iter().map(|(n, p)| (n.clone(), p)).collect();
        let mut path = std::env::temp_dir();
        path.push(format!("gratetile-serve-{}.grate", std::process::id()));
        Container::write(&path, &refs).unwrap();

        let report = s.serve_container(&path).unwrap();
        assert_eq!(report.completed, 3);
        assert!(report.total_feature_bytes > 0);
        std::fs::remove_file(&path).ok();
    }

    /// The percentile bugfix: out-of-domain `p` (negative, > 1, NaN,
    /// infinite) must clamp instead of indexing out of bounds, and the
    /// empty / single-sample paths stay well defined.
    #[test]
    fn percentile_clamps_out_of_domain_p() {
        let empty = ServerReport {
            completed: 0,
            wall: Duration::ZERO,
            latencies: Vec::new(),
            total_feature_bytes: 0,
        };
        for p in [-1.0, 0.0, 0.5, 1.0, 2.0, f64::NAN, f64::INFINITY] {
            assert_eq!(empty.percentile(p), Duration::ZERO);
        }

        let one = ServerReport {
            completed: 1,
            wall: Duration::from_millis(5),
            latencies: vec![Duration::from_millis(3)],
            total_feature_bytes: 1,
        };
        for p in [-1.0, 0.0, 0.5, 1.0, 7.5, f64::NAN, f64::NEG_INFINITY] {
            assert_eq!(one.percentile(p), Duration::from_millis(3), "p={p}");
        }

        let many = ServerReport {
            completed: 3,
            wall: Duration::from_millis(9),
            latencies: vec![
                Duration::from_millis(9),
                Duration::from_millis(1),
                Duration::from_millis(5),
            ],
            total_feature_bytes: 1,
        };
        assert_eq!(many.percentile(-3.0), Duration::from_millis(1));
        assert_eq!(many.percentile(0.5), Duration::from_millis(5));
        assert_eq!(many.percentile(42.0), Duration::from_millis(9));
        assert_eq!(many.percentile(f64::NAN), Duration::from_millis(1));
    }

    #[test]
    fn more_workers_not_slower_per_request_batch() {
        // Smoke: 4 workers on 8 requests completes; wall-time comparison
        // is flaky on CI boxes, so only assert completion + sane stats.
        let s = server(4);
        let reqs = s.synthetic_requests(8, 0.4, 11);
        let report = s.serve(reqs).unwrap();
        assert_eq!(report.completed, 8);
        assert_eq!(report.latencies.len(), 8);
    }
}
