//! Pipeline metrics: traffic, timing, overlap.

use crate::memsim::{Dram, Stream};
use std::time::Duration;

/// Metrics for one layer (or whole-network) pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineMetrics {
    pub tiles: u64,
    /// Wall time of the whole run.
    pub wall: Duration,
    /// Time the fetch lane spent fetching/decompressing.
    pub fetch_busy: Duration,
    /// Time the compute lane spent convolving.
    pub compute_busy: Duration,
    /// DRAM traffic (feature + metadata streams).
    pub feature_lines: u64,
    pub metadata_words: u64,
    pub output_words: u64,
}

impl PipelineMetrics {
    pub fn absorb_dram(&mut self, dram: &Dram) {
        self.feature_lines += dram.lines_of(Stream::FeatureRead);
        self.metadata_words += dram.words_of(Stream::MetadataRead);
        self.output_words += dram.words_of(Stream::OutputWrite);
    }

    pub fn merge(&mut self, o: &PipelineMetrics) {
        self.tiles += o.tiles;
        self.wall += o.wall;
        self.fetch_busy += o.fetch_busy;
        self.compute_busy += o.compute_busy;
        self.feature_lines += o.feature_lines;
        self.metadata_words += o.metadata_words;
        self.output_words += o.output_words;
    }

    pub fn tiles_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.tiles as f64 / self.wall.as_secs_f64()
    }

    /// Overlap efficiency: with perfect double buffering the wall time
    /// approaches max(fetch, compute) rather than their sum.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.fetch_busy.as_secs_f64() + self.compute_busy.as_secs_f64();
        if serial == 0.0 {
            return 1.0;
        }
        let ideal = self.fetch_busy.as_secs_f64().max(self.compute_busy.as_secs_f64());
        // 1.0 = perfectly overlapped, 0.0 = fully serialised.
        let wall = self.wall.as_secs_f64().max(ideal);
        ((serial - wall) / (serial - ideal).max(1e-12)).clamp(0.0, 1.0)
    }

    pub fn feature_bytes(&self) -> u64 {
        self.feature_lines * 16
    }

    pub fn summary(&self) -> String {
        format!(
            "tiles={} wall={:.1}ms fetch={:.1}ms compute={:.1}ms overlap={:.0}% feature={}KB meta={}KB out={}KB ({:.0} tiles/s)",
            self.tiles,
            self.wall.as_secs_f64() * 1e3,
            self.fetch_busy.as_secs_f64() * 1e3,
            self.compute_busy.as_secs_f64() * 1e3,
            self.overlap_efficiency() * 100.0,
            self.feature_bytes() / 1024,
            self.metadata_words * 2 / 1024,
            self.output_words * 2 / 1024,
            self.tiles_per_sec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_efficiency_bounds() {
        let mut m = PipelineMetrics {
            fetch_busy: Duration::from_millis(10),
            compute_busy: Duration::from_millis(10),
            ..Default::default()
        };
        // Fully serialised: wall = sum.
        m.wall = Duration::from_millis(20);
        assert!(m.overlap_efficiency() < 0.05);
        // Fully overlapped: wall = max.
        m.wall = Duration::from_millis(10);
        assert!(m.overlap_efficiency() > 0.95);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PipelineMetrics { tiles: 2, ..Default::default() };
        let b = PipelineMetrics { tiles: 3, feature_lines: 10, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.tiles, 5);
        assert_eq!(a.feature_bytes(), 160);
    }

    #[test]
    fn dram_absorption() {
        let mut d = Dram::default();
        d.access(Stream::FeatureRead, 0, 64);
        d.account_bits(Stream::MetadataRead, 96);
        let mut m = PipelineMetrics::default();
        m.absorb_dram(&d);
        assert_eq!(m.feature_lines, 8);
        assert_eq!(m.metadata_words, 6);
    }
}
