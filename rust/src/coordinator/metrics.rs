//! Pipeline metrics: traffic, timing, overlap, measured compute.

use crate::compute::GemmStats;
use crate::layout::FetchCounters;
use crate::memsim::{Dram, Stream};
use std::time::Duration;

/// Metrics for one layer (or whole-network) pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineMetrics {
    pub tiles: u64,
    /// Wall time of the whole run.
    pub wall: Duration,
    /// Time the fetch lane spent fetching/decompressing.
    pub fetch_busy: Duration,
    /// Time the compute lane spent convolving.
    pub compute_busy: Duration,
    /// DRAM traffic (feature + metadata streams).
    pub feature_lines: u64,
    pub metadata_words: u64,
    pub output_words: u64,
    /// Producer-side index traffic (Fig. 7 records written back).
    pub metadata_write_words: u64,
    /// Exact streamed write-back bits (payload, line-padded) — equals
    /// the analytic `total_words × 16` of the stored map; 0 on the
    /// dense (non-store) path.
    pub writeback_payload_bits: u64,
    /// Exact streamed metadata bits (`n_blocks × bits_per_record`).
    pub writeback_meta_bits: u64,
    /// Dense staging high-water mark of the streaming writer, in words.
    pub peak_staged_words: u64,
    /// Timed-DRAM replay of the layer's real addresses (store path).
    pub row_hits: u64,
    pub row_misses: u64,
    pub dram_cycles: u64,
    /// Read-side datapath counters from the fetch lane (decode cache
    /// hits, words emitted by the span decoder, metadata-only skips).
    pub cache_hits: u64,
    pub decoded_words: u64,
    pub skipped_subtensors: u64,
    pub skipped_spans: u64,
    /// Integrity-layer counters from the fetch lane (zero unless
    /// verify-on-fetch ran; see [`crate::layout::IntegrityPolicy`]).
    pub verified_reads: u64,
    pub checksum_mismatches: u64,
    pub retried_reads: u64,
    pub recovered_reads: u64,
    /// Sub-tensors that exhausted their retry budget and were served as
    /// all-zero substitutes (one count per degraded *touch*).
    pub degraded_subtensors: u64,
    /// Simulated cycles of exponential backoff spent on retries; the
    /// serving simulator adds these to the layer's timing.
    pub retry_backoff_cycles: u64,
    /// Compressed payload bits of the layer's *input* map, split by
    /// codec tag (registry order: bitmask, zrlc, dictionary, raw).
    pub packed_bits_by_codec: [u64; 4],
    /// Measured kernel work from the GEMM compute backend (`macs` =
    /// executed, `dense_macs` = dense-equivalent on the same in-bounds
    /// taps). Zero when no compute backend ran — consumers fall back to
    /// the analytic `ConvLayer::macs()` *estimate* and must label it so.
    pub gemm: GemmStats,
}

impl PipelineMetrics {
    pub fn absorb_dram(&mut self, dram: &Dram) {
        self.feature_lines += dram.lines_of(Stream::FeatureRead);
        self.metadata_words += dram.words_of(Stream::MetadataRead);
        self.output_words += dram.words_of(Stream::OutputWrite);
        self.metadata_write_words += dram.words_of(Stream::MetadataWrite);
    }

    /// Fold the fetch lane's datapath counters into the layer metrics.
    pub fn absorb_fetch_counters(&mut self, c: &FetchCounters) {
        self.cache_hits += c.cache_hits;
        self.decoded_words += c.decoded_words;
        self.skipped_subtensors += c.skipped_subtensors;
        self.skipped_spans += c.skipped_spans;
        self.verified_reads += c.verified_reads;
        self.checksum_mismatches += c.checksum_mismatches;
        self.retried_reads += c.retried_reads;
        self.recovered_reads += c.recovered_reads;
        self.degraded_subtensors += c.degraded_subtensors;
        self.retry_backoff_cycles += c.retry_backoff_cycles;
    }

    pub fn merge(&mut self, o: &PipelineMetrics) {
        self.tiles += o.tiles;
        self.wall += o.wall;
        self.fetch_busy += o.fetch_busy;
        self.compute_busy += o.compute_busy;
        self.feature_lines += o.feature_lines;
        self.metadata_words += o.metadata_words;
        self.output_words += o.output_words;
        self.metadata_write_words += o.metadata_write_words;
        self.writeback_payload_bits += o.writeback_payload_bits;
        self.writeback_meta_bits += o.writeback_meta_bits;
        self.peak_staged_words = self.peak_staged_words.max(o.peak_staged_words);
        self.row_hits += o.row_hits;
        self.row_misses += o.row_misses;
        self.dram_cycles += o.dram_cycles;
        self.cache_hits += o.cache_hits;
        self.decoded_words += o.decoded_words;
        self.skipped_subtensors += o.skipped_subtensors;
        self.skipped_spans += o.skipped_spans;
        self.verified_reads += o.verified_reads;
        self.checksum_mismatches += o.checksum_mismatches;
        self.retried_reads += o.retried_reads;
        self.recovered_reads += o.recovered_reads;
        self.degraded_subtensors += o.degraded_subtensors;
        self.retry_backoff_cycles += o.retry_backoff_cycles;
        for (a, b) in self.packed_bits_by_codec.iter_mut().zip(o.packed_bits_by_codec) {
            *a += b;
        }
        self.gemm.merge(&o.gemm);
    }

    /// Measured MACs when a compute backend ran, else `None` (caller
    /// falls back to the analytic estimate — and labels it).
    pub fn measured_macs(&self) -> Option<u64> {
        (self.gemm.dense_macs > 0).then_some(self.gemm.macs)
    }

    /// Total producer-side bits (payload + index) of the streamed write.
    pub fn writeback_bits(&self) -> u64 {
        self.writeback_payload_bits + self.writeback_meta_bits
    }

    /// Row-buffer hit rate of the timed replay (0 when not replayed).
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    pub fn tiles_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.tiles as f64 / self.wall.as_secs_f64()
    }

    /// Overlap efficiency: with perfect double buffering the wall time
    /// approaches max(fetch, compute) rather than their sum.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.fetch_busy.as_secs_f64() + self.compute_busy.as_secs_f64();
        if serial == 0.0 {
            return 1.0;
        }
        let ideal = self.fetch_busy.as_secs_f64().max(self.compute_busy.as_secs_f64());
        // 1.0 = perfectly overlapped, 0.0 = fully serialised.
        let wall = self.wall.as_secs_f64().max(ideal);
        ((serial - wall) / (serial - ideal).max(1e-12)).clamp(0.0, 1.0)
    }

    pub fn feature_bytes(&self) -> u64 {
        self.feature_lines * 16
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "tiles={} wall={:.1}ms fetch={:.1}ms compute={:.1}ms overlap={:.0}% feature={}KB meta={}KB out={}KB ({:.0} tiles/s)",
            self.tiles,
            self.wall.as_secs_f64() * 1e3,
            self.fetch_busy.as_secs_f64() * 1e3,
            self.compute_busy.as_secs_f64() * 1e3,
            self.overlap_efficiency() * 100.0,
            self.feature_bytes() / 1024,
            self.metadata_words * 2 / 1024,
            self.output_words * 2 / 1024,
            self.tiles_per_sec(),
        );
        if self.row_hits + self.row_misses > 0 {
            s.push_str(&format!(" rowhit={:.0}%", self.row_hit_rate() * 100.0));
        }
        s
    }
}

// The percentile machinery moved to [`crate::obs::metrics`] (the
// unified metrics layer); this re-export keeps the historical path —
// and with it the nearest-rank semantics the goldens pin — intact.
pub use crate::obs::metrics::{percentile_index, SortedSamples};

/// Per-layer observable counters computed by the **functional** pass
/// and carried alongside each [`crate::coordinator::simserver::LayerWork`],
/// so the single-threaded timing pass can emit them as trace counter
/// events at exact simulated cycles — `--jobs`-invariant by
/// construction (host parallelism never touches emission order).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerObs {
    /// Executed MACs (measured when a compute backend ran, else the
    /// analytic estimate — same fallback as the serving report).
    pub macs: u64,
    /// Input payload bits by codec tag (registry order).
    pub packed_bits_by_codec: [u64; 4],
    pub cache_hits: u64,
    pub decoded_words: u64,
    pub skipped_subtensors: u64,
    pub skipped_spans: u64,
    pub skipped_rows: u64,
    pub skipped_values: u64,
    /// Integrity-layer counters (zero unless verify-on-fetch ran).
    pub verified_reads: u64,
    pub checksum_mismatches: u64,
    pub retried_reads: u64,
    pub recovered_reads: u64,
    pub degraded_subtensors: u64,
    /// Simulated retry-backoff cycles the timing pass must add to the
    /// layer's service time.
    pub retry_backoff_cycles: u64,
}

impl LayerObs {
    /// Project the observable subset out of a layer's pipeline metrics.
    pub fn from_metrics(m: &PipelineMetrics) -> Self {
        LayerObs {
            macs: m.gemm.macs,
            packed_bits_by_codec: m.packed_bits_by_codec,
            cache_hits: m.cache_hits,
            decoded_words: m.decoded_words,
            skipped_subtensors: m.skipped_subtensors,
            skipped_spans: m.skipped_spans,
            skipped_rows: m.gemm.skipped_rows,
            skipped_values: m.gemm.skipped_values,
            verified_reads: m.verified_reads,
            checksum_mismatches: m.checksum_mismatches,
            retried_reads: m.retried_reads,
            recovered_reads: m.recovered_reads,
            degraded_subtensors: m.degraded_subtensors,
            retry_backoff_cycles: m.retry_backoff_cycles,
        }
    }

    pub fn merge(&mut self, o: &LayerObs) {
        self.macs += o.macs;
        for (a, b) in self.packed_bits_by_codec.iter_mut().zip(o.packed_bits_by_codec) {
            *a += b;
        }
        self.cache_hits += o.cache_hits;
        self.decoded_words += o.decoded_words;
        self.skipped_subtensors += o.skipped_subtensors;
        self.skipped_spans += o.skipped_spans;
        self.skipped_rows += o.skipped_rows;
        self.skipped_values += o.skipped_values;
        self.verified_reads += o.verified_reads;
        self.checksum_mismatches += o.checksum_mismatches;
        self.retried_reads += o.retried_reads;
        self.recovered_reads += o.recovered_reads;
        self.degraded_subtensors += o.degraded_subtensors;
        self.retry_backoff_cycles += o.retry_backoff_cycles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_index_reexport_keeps_semantics() {
        // The implementation lives in obs::metrics now; the historical
        // path must keep the exact nearest-rank clamping semantics.
        assert_eq!(percentile_index(0, 0.5), 0);
        assert_eq!(percentile_index(5, f64::NAN), 0);
        assert_eq!(percentile_index(5, 0.5), 2);
        assert_eq!(percentile_index(5, 17.0), 4);
    }

    #[test]
    fn layer_obs_projects_and_merges() {
        let m = PipelineMetrics {
            cache_hits: 3,
            decoded_words: 40,
            skipped_subtensors: 2,
            skipped_spans: 5,
            packed_bits_by_codec: [10, 20, 0, 0],
            gemm: GemmStats { macs: 100, dense_macs: 400, skipped_rows: 7, skipped_values: 9 },
            ..Default::default()
        };
        let mut o = LayerObs::from_metrics(&m);
        assert_eq!(o.macs, 100);
        assert_eq!(o.skipped_rows, 7);
        assert_eq!(o.packed_bits_by_codec, [10, 20, 0, 0]);
        let snapshot = o;
        o.merge(&snapshot);
        assert_eq!(o.macs, 200);
        assert_eq!(o.packed_bits_by_codec, [20, 40, 0, 0]);
        assert_eq!(o.skipped_values, 18);
    }

    #[test]
    fn overlap_efficiency_bounds() {
        let mut m = PipelineMetrics {
            fetch_busy: Duration::from_millis(10),
            compute_busy: Duration::from_millis(10),
            ..Default::default()
        };
        // Fully serialised: wall = sum.
        m.wall = Duration::from_millis(20);
        assert!(m.overlap_efficiency() < 0.05);
        // Fully overlapped: wall = max.
        m.wall = Duration::from_millis(10);
        assert!(m.overlap_efficiency() > 0.95);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PipelineMetrics { tiles: 2, ..Default::default() };
        let b = PipelineMetrics { tiles: 3, feature_lines: 10, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.tiles, 5);
        assert_eq!(a.feature_bytes(), 160);
    }

    #[test]
    fn dram_absorption() {
        let mut d = Dram::default();
        d.access(Stream::FeatureRead, 0, 64);
        d.account_bits(Stream::MetadataRead, 96);
        let mut m = PipelineMetrics::default();
        m.absorb_dram(&d);
        assert_eq!(m.feature_lines, 8);
        assert_eq!(m.metadata_words, 6);
    }
}
