//! Double-buffered tiled execution over GrateTile-packed feature maps.
//!
//! Topology per layer (paper Fig. 2c):
//!
//! ```text
//!   [prefetch thread]        bounded channel          [compute lane]
//!   metadata lookup  ──►  (depth = double buffer)  ──►  GEMM kernel
//!   fetch sub-tensors      (window + row index)         zero-skip
//!   decompress + occupancy                              ReLU + store
//! ```
//!
//! The prefetch thread walks the same tile schedule as the bandwidth
//! simulator, so the DRAM traffic it accounts matches `sim`'s analytic
//! numbers; the compute lane runs the real tiled GEMM backend
//! ([`crate::compute`]) over the fetched windows — bit-identical to the
//! direct-conv oracle — and ships **measured** MAC counts out in
//! [`PipelineMetrics::gemm`]. Under the `ZeroSkip` policy the fetch
//! lane also ships its per-row occupancy index so proven-zero im2col
//! rows never reach the kernel.

use super::conv::Weights;
use super::metrics::PipelineMetrics;
use crate::bail;
use crate::compress::CodecPolicy;
use crate::compute::{gemm_tile, GemmStats, PackedWeights, SkipPolicy};
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::fault::{FaultPlan, FaultySource};
use crate::layout::fetcher::{DenseWindow, FetchCounters, Fetcher, IntegrityPolicy, PayloadSource};
use crate::layout::packer::{PackedFeatureMap, Packer};
use crate::memsim::{Access, Dram, DramTiming, Stream, TimedDram};
use crate::sim::walker::TileWalker;
use crate::store::{StoreWriter, TensorStore};
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionMode};
use crate::tune::LayerPlan;
use crate::util::error::{Context, Result};
use std::sync::mpsc::{channel, sync_channel};
use std::time::{Duration, Instant};

/// Decoded-sub-tensor LRU capacity for the prefetch lane's fetcher:
/// big enough for the halo sub-tensors two adjacent tile windows share,
/// small enough to stay within an on-chip-buffer-ish footprint. Purely
/// a software-speed knob — traffic accounting is cache-invariant
/// (property-tested in `layout::fetcher`).
const DECODE_CACHE_SUBTENSORS: usize = 32;

/// Pipeline configuration.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub hw: Hardware,
    pub mode: DivisionMode,
    /// Codec policy for every packed/streamed map (fixed codec or
    /// per-sub-tensor adaptive selection).
    pub policy: CodecPolicy,
    /// Prefetch queue depth; 2 = classic double buffering.
    pub prefetch_depth: usize,
    /// Kernel sparsity policy (see [`SkipPolicy`]); every tier is
    /// bit-identical in output, they differ only in executed MACs.
    pub skip: SkipPolicy,
    /// Verify-on-fetch policy: when set, every payload read is hashed
    /// against the map's per-sub-tensor checksum table (`.grate` v3),
    /// with bounded retry / quarantine / zero-substitution on mismatch.
    /// `None` = trust payload reads (the historical behaviour).
    pub integrity: Option<IntegrityPolicy>,
    /// Deterministic fault injection at the payload-read boundary of
    /// store-backed runs (`None` = clean reads). Timing-class faults in
    /// the plan are consulted by the serving simulator, not here.
    pub fault: Option<FaultPlan>,
    /// Stable identifier mixed into payload-fault decisions; the
    /// serving simulator sets it per request so concurrent requests
    /// draw independent — yet reproducible — fault streams.
    pub fault_salt: u64,
}

impl PipelineConfig {
    pub fn new(hw: Hardware) -> Self {
        Self {
            hw,
            mode: DivisionMode::GrateTile { n: 8 },
            policy: CodecPolicy::Fixed(crate::compress::Scheme::Bitmask),
            prefetch_depth: 2,
            skip: SkipPolicy::ZeroSkip,
            integrity: None,
            fault: None,
            fault_salt: 0,
        }
    }
}

/// One layer's DRAM trace from the functional pass, at real store
/// addresses: the prefetch lane's reads followed by the streaming
/// writer's payload/metadata writes. This is the interface between the
/// functional pass and any timing pass — the wall-clock replay in
/// [`LayerRunner::run_layer_store`] and the discrete-event serving
/// simulator ([`crate::coordinator::simserver`]) both consume it.
#[derive(Debug, Clone, Default)]
pub struct LayerTrace {
    /// Prefetch-lane accesses (feature + metadata reads), in tile
    /// schedule order — deterministic for a given packed input.
    pub fetch: Vec<Access>,
    /// Writer accesses (payload commits + index records), in block
    /// completion order.
    pub write: Vec<Access>,
}

impl LayerTrace {
    /// All accesses in replay order (reads, then write-back).
    pub fn iter(&self) -> impl Iterator<Item = &Access> {
        self.fetch.iter().chain(self.write.iter())
    }

    /// Total words moved by the trace.
    pub fn words(&self) -> u64 {
        self.iter().map(|a| a.words).sum()
    }
}

/// Executes layers tile-by-tile.
pub struct LayerRunner {
    pub cfg: PipelineConfig,
    /// Per-layer tuned plans: entry `i` governs layer `i`'s *input* map
    /// (its division mode and codec policy). Empty = every map uses the
    /// global `cfg.mode`/`cfg.policy`, the historical behaviour.
    plans: Vec<LayerPlan>,
}

impl LayerRunner {
    pub fn new(cfg: PipelineConfig) -> Self {
        Self { cfg, plans: Vec::new() }
    }

    /// Attach per-layer tuned plans (from a tuned manifest; see
    /// [`crate::tune`]). Positional: plan `i` applies to layer `i`'s
    /// input map. Layers beyond the list fall back to the global config.
    pub fn with_plans(mut self, plans: Vec<LayerPlan>) -> Self {
        self.plans = plans;
        self
    }

    /// The plan for layer `i`'s input map: tuned if provided, otherwise
    /// the global config as a plan.
    pub fn plan_for(&self, i: usize) -> LayerPlan {
        self.plans.get(i).copied().unwrap_or(LayerPlan {
            mode: self.cfg.mode,
            policy: self.cfg.policy,
            order: crate::sim::metacache::TileOrder::SpatialMajor,
        })
    }

    /// Pack a dense feature map for this pipeline's storage scheme
    /// (layer 0's input plan when tuned plans are attached).
    pub fn pack(&self, layer: &ConvLayer, fm: &FeatureMap) -> Result<PackedFeatureMap> {
        let p = self.plan_for(0);
        self.pack_with(layer, fm, p.mode, p.policy)
    }

    /// Pack under an explicit `(mode, policy)` — the per-layer seam the
    /// tuned path and `store pack --tuned` drive directly.
    pub fn pack_with(
        &self,
        layer: &ConvLayer,
        fm: &FeatureMap,
        mode: DivisionMode,
        policy: CodecPolicy,
    ) -> Result<PackedFeatureMap> {
        let tile = self.cfg.hw.tile_for_layer(layer);
        let division = Division::build(mode, layer, &tile, &self.cfg.hw, fm.h, fm.w, fm.c)
            .context("building division")?;
        Ok(Packer::new(self.cfg.hw, policy).pack(fm, &division, true))
    }

    /// Run one layer over a packed input; returns the ReLU'd output map
    /// and pipeline metrics.
    pub fn run_layer(
        &self,
        layer: &ConvLayer,
        weights: &Weights,
        packed: &PackedFeatureMap,
    ) -> Result<(FeatureMap, PipelineMetrics)> {
        let tile = self.cfg.hw.tile_for_layer(layer);
        let walker = TileWalker::new(*layer, tile);
        let (out_h, out_w) = (layer.out_h(), layer.out_w());
        let mut out = FeatureMap::zeros(out_h, out_w, layer.c_out);
        let mut metrics = PipelineMetrics::default();
        let wall_start = Instant::now();

        let depth = self.cfg.prefetch_depth.max(1);
        let track = self.cfg.skip == SkipPolicy::ZeroSkip;
        // Windows travel with their row-occupancy index (empty when the
        // policy does not consume it).
        let (tx, rx) = sync_channel::<(DenseWindow, Vec<bool>)>(depth);
        // Return lane: spent window buffers flow back to the fetcher's
        // pool, so the steady-state pipeline allocates nothing per tile.
        let (back_tx, back_rx) = channel::<DenseWindow>();
        let pw = PackedWeights::prepare(layer, weights);
        let mut gemm = GemmStats::default();

        let (fetch_busy, fetch_dram, fetch_counters) = std::thread::scope(
            |scope| -> Result<(Duration, Dram, FetchCounters)> {
                // ---- prefetch lane ----
                let walker_f = walker.clone();
                let integrity = self.cfg.integrity;
                let fetch_handle = scope.spawn(move || {
                    let mut fetcher = Fetcher::new(packed)
                        .with_cache(DECODE_CACHE_SUBTENSORS)
                        .with_occupancy(track);
                    if let Some(pol) = integrity {
                        fetcher = fetcher.with_integrity(pol);
                    }
                    let mut dram = Dram::default();
                    let mut busy = Duration::ZERO;
                    for w in walker_f.iter() {
                        while let Ok(spent) = back_rx.try_recv() {
                            fetcher.recycle(spent);
                        }
                        let t0 = Instant::now();
                        let win = fetcher.fetch_window(
                            &mut dram, w.y0, w.y1, w.x0, w.x1, w.c0, w.c1,
                        );
                        let occ = fetcher.row_occupancy().to_vec();
                        busy += t0.elapsed();
                        // Backpressure: blocks when `depth` windows are
                        // already staged.
                        if tx.send((win, occ)).is_err() {
                            break; // compute lane bailed
                        }
                    }
                    (busy, dram, fetcher.counters())
                });

                // ---- compute lane (this thread) ----
                let mut acc: Vec<f32> = Vec::new();
                for ty in 0..walker.n_ty {
                    let oy0 = ty * tile.th;
                    let oy1 = (oy0 + tile.th).min(out_h);
                    for tx_i in 0..walker.n_tx {
                        let ox0 = tx_i * tile.tw;
                        let ox1 = (ox0 + tile.tw).min(out_w);
                        acc.clear();
                        acc.resize((oy1 - oy0) * (ox1 - ox0) * layer.c_out, 0.0);
                        for _tcg in 0..walker.n_tcg {
                            let (win, occ) = rx.recv().context("prefetch lane died")?;
                            let t0 = Instant::now();
                            let row_occ = track.then_some(&occ[..]);
                            gemm_tile(
                                layer, &pw, &win, row_occ, self.cfg.skip, &mut acc,
                                oy0, oy1, ox0, ox1, &mut gemm,
                            );
                            metrics.compute_busy += t0.elapsed();
                            let _ = back_tx.send(win); // best-effort recycle
                        }
                        // ReLU + writeback.
                        let t0 = Instant::now();
                        for v in &mut acc {
                            *v = v.max(0.0);
                        }
                        out.write_block(oy0, ox0, 0, oy1 - oy0, ox1 - ox0, layer.c_out, &acc);
                        metrics.compute_busy += t0.elapsed();
                        metrics.tiles += 1;
                    }
                }
                drop(rx);
                let lane = fetch_handle.join().expect("prefetch lane panicked");
                Ok(lane)
            },
        )?;

        metrics.fetch_busy = fetch_busy;
        metrics.gemm = gemm;
        metrics.absorb_dram(&fetch_dram);
        metrics.absorb_fetch_counters(&fetch_counters);
        metrics.packed_bits_by_codec = packed.payload_bits_by_tag();
        let mut out_dram = Dram::default();
        out_dram.access(Stream::OutputWrite, 0, out.words() as u64);
        metrics.absorb_dram(&out_dram);
        metrics.wall = wall_start.elapsed();
        Ok((out, metrics))
    }

    /// Division the *output* of a layer is stored under: built for its
    /// consumer (the next layer), or for a pointwise identity view when
    /// the stack ends. Falls back to a uniform grid if the configured
    /// GrateTile modulus does not exist for the consumer's tile
    /// (Table III footnote a) — the store must always be writable.
    pub fn output_division(
        &self,
        consumer: Option<&ConvLayer>,
        h: usize,
        w: usize,
        c: usize,
    ) -> Result<Division> {
        self.output_division_with(self.cfg.mode, consumer, h, w, c)
    }

    /// [`LayerRunner::output_division`] under an explicit mode — the
    /// per-layer seam the tuned network path drives.
    pub fn output_division_with(
        &self,
        mode: DivisionMode,
        consumer: Option<&ConvLayer>,
        h: usize,
        w: usize,
        c: usize,
    ) -> Result<Division> {
        let fallback = ConvLayer::new(0, 1, h, w, c, c);
        let consumer = consumer.copied().unwrap_or(fallback);
        let tile = self.cfg.hw.tile_for_layer(&consumer);
        match Division::build(mode, &consumer, &tile, &self.cfg.hw, h, w, c) {
            Ok(d) => Ok(d),
            Err(_) => {
                Division::build(
                    DivisionMode::Uniform { edge: 8 },
                    &consumer,
                    &tile,
                    &self.cfg.hw,
                    h,
                    w,
                    c,
                )
                .context("building fallback output division")
            }
        }
    }

    /// Run one layer store-to-store: the input is fetched from
    /// `store[input]` through the store-backed [`Fetcher`] (prefetch
    /// lane, real DRAM addresses), the output is streamed compressed
    /// into `store[output]` under `out_division` by a [`StoreWriter`] —
    /// no dense intermediate map materialises. The layer's reads and
    /// writes are replayed through the [`TimedDram`] row-buffer model at
    /// their real store addresses.
    pub fn run_layer_store(
        &self,
        store: &mut TensorStore,
        input: &str,
        output: &str,
        layer: &ConvLayer,
        weights: &Weights,
        out_division: Division,
    ) -> Result<PipelineMetrics> {
        let (mut metrics, trace) =
            self.run_layer_store_traced(store, input, output, layer, weights, out_division)?;
        Self::replay_timed(&mut metrics, &trace);
        Ok(metrics)
    }

    /// Post-hoc solo replay of a layer's trace through the row-buffer
    /// model (uncontended; the serving simulator replays the same traces
    /// through a *shared* [`crate::memsim::SharedDram`] instead).
    fn replay_timed(metrics: &mut PipelineMetrics, trace: &LayerTrace) {
        let mut timed = TimedDram::new(DramTiming::default());
        for a in trace.iter() {
            timed.read(a.addr_words, a.words);
        }
        metrics.row_hits = timed.row_hits;
        metrics.row_misses = timed.row_misses;
        metrics.dram_cycles = timed.cycles;
    }

    /// The functional pass of [`LayerRunner::run_layer_store`], decoupled
    /// from any timing model: runs the layer store-to-store and returns
    /// the metrics plus the layer's [`LayerTrace`] at real store
    /// addresses. The trace depends only on the packed input, the tile
    /// schedule and the arena layout — never on host load or worker
    /// scheduling — so timing passes over it are deterministic.
    pub fn run_layer_store_traced(
        &self,
        store: &mut TensorStore,
        input: &str,
        output: &str,
        layer: &ConvLayer,
        weights: &Weights,
        out_division: Division,
    ) -> Result<(PipelineMetrics, LayerTrace)> {
        self.run_layer_store_traced_policy(
            store,
            input,
            output,
            layer,
            weights,
            out_division,
            self.cfg.policy,
        )
    }

    /// [`LayerRunner::run_layer_store_traced`] with an explicit codec
    /// policy for the *output* map — the per-layer seam the tuned
    /// network path drives (the output of layer `i` is the input of
    /// layer `i+1`, so it is written under layer `i+1`'s plan).
    #[allow(clippy::too_many_arguments)]
    pub fn run_layer_store_traced_policy(
        &self,
        store: &mut TensorStore,
        input: &str,
        output: &str,
        layer: &ConvLayer,
        weights: &Weights,
        out_division: Division,
        out_policy: CodecPolicy,
    ) -> Result<(PipelineMetrics, LayerTrace)> {
        let tile = self.cfg.hw.tile_for_layer(layer);
        let walker = TileWalker::new(*layer, tile);
        let (out_h, out_w) = (layer.out_h(), layer.out_w());
        let mut metrics = PipelineMetrics::default();
        let wall_start = Instant::now();

        let (snap_packed, snap_payload) = store.snapshot(input)?;
        {
            let d = &snap_packed.division;
            if (d.fm_h, d.fm_w, d.fm_c) != (layer.h, layer.w, layer.c_in) {
                bail!(
                    "store tensor '{input}' is {}x{}x{}, layer expects {}x{}x{}",
                    d.fm_h, d.fm_w, d.fm_c, layer.h, layer.w, layer.c_in
                );
            }
        }
        // Computed here: `snap_packed` moves into the prefetch lane.
        let input_bits_by_codec = snap_packed.payload_bits_by_tag();
        let mut writer = StoreWriter::new(store, output, out_division, out_policy);

        let depth = self.cfg.prefetch_depth.max(1);
        let track = self.cfg.skip == SkipPolicy::ZeroSkip;
        let (tx, rx) = sync_channel::<(DenseWindow, Vec<bool>)>(depth);
        let (back_tx, back_rx) = channel::<DenseWindow>();
        let pw = PackedWeights::prepare(layer, weights);
        let mut gemm = GemmStats::default();

        let (fetch_busy, fetch_dram, fetch_counters) = std::thread::scope(
            |scope| -> Result<(Duration, Dram, FetchCounters)> {
                // ---- prefetch lane: reads the store snapshot ----
                let walker_f = walker.clone();
                let integrity = self.cfg.integrity;
                let fault = self.cfg.fault;
                let fault_salt = self.cfg.fault_salt;
                let fetch_handle = scope.spawn(move || {
                    let packed = snap_packed;
                    // The fault boundary: payload reads from the store
                    // snapshot pass through the plan's corruption
                    // decorator before the fetcher (and its verify-on-
                    // fetch layer) ever sees them.
                    let source: Box<dyn PayloadSource> = match fault {
                        Some(plan) if plan.payload_faults_active() => {
                            Box::new(FaultySource::new(snap_payload, plan, fault_salt))
                        }
                        _ => Box::new(snap_payload),
                    };
                    let mut fetcher = Fetcher::with_source(&packed, source)
                        .with_cache(DECODE_CACHE_SUBTENSORS)
                        .with_occupancy(track);
                    if let Some(pol) = integrity {
                        fetcher = fetcher.with_integrity(pol);
                    }
                    let mut dram = Dram::default().with_trace();
                    let mut busy = Duration::ZERO;
                    for w in walker_f.iter() {
                        while let Ok(spent) = back_rx.try_recv() {
                            fetcher.recycle(spent);
                        }
                        let t0 = Instant::now();
                        let win = fetcher.fetch_window(
                            &mut dram, w.y0, w.y1, w.x0, w.x1, w.c0, w.c1,
                        );
                        let occ = fetcher.row_occupancy().to_vec();
                        busy += t0.elapsed();
                        if tx.send((win, occ)).is_err() {
                            break;
                        }
                    }
                    (busy, dram, fetcher.counters())
                });

                // ---- compute lane: convolve, ReLU, stream to store ----
                let mut acc: Vec<f32> = Vec::new();
                for ty in 0..walker.n_ty {
                    let oy0 = ty * tile.th;
                    let oy1 = (oy0 + tile.th).min(out_h);
                    for tx_i in 0..walker.n_tx {
                        let ox0 = tx_i * tile.tw;
                        let ox1 = (ox0 + tile.tw).min(out_w);
                        acc.clear();
                        acc.resize((oy1 - oy0) * (ox1 - ox0) * layer.c_out, 0.0);
                        for _tcg in 0..walker.n_tcg {
                            let (win, occ) = rx.recv().context("prefetch lane died")?;
                            let t0 = Instant::now();
                            let row_occ = track.then_some(&occ[..]);
                            gemm_tile(
                                layer, &pw, &win, row_occ, self.cfg.skip, &mut acc,
                                oy0, oy1, ox0, ox1, &mut gemm,
                            );
                            metrics.compute_busy += t0.elapsed();
                            let _ = back_tx.send(win); // best-effort recycle
                        }
                        let t0 = Instant::now();
                        for v in &mut acc {
                            *v = v.max(0.0);
                        }
                        writer.write_tile(oy0, oy1, ox0, ox1, 0, layer.c_out, &acc);
                        metrics.compute_busy += t0.elapsed();
                        metrics.tiles += 1;
                    }
                }
                drop(rx);
                let lane = fetch_handle.join().expect("prefetch lane panicked");
                Ok(lane)
            },
        )?;

        let report = writer.finish()?;
        // Wall clock covers the pipeline itself; post-hoc timing
        // replays over the returned trace (replay_timed, the serving
        // simulator) must not skew tiles_per_sec / overlap_efficiency.
        metrics.wall = wall_start.elapsed();
        metrics.fetch_busy = fetch_busy;
        metrics.gemm = gemm;
        metrics.absorb_dram(&fetch_dram);
        metrics.absorb_fetch_counters(&fetch_counters);
        metrics.packed_bits_by_codec = input_bits_by_codec;
        metrics.absorb_dram(&report.dram);
        metrics.writeback_payload_bits = report.payload_bits;
        metrics.writeback_meta_bits = report.metadata_bits;
        metrics.peak_staged_words = report.peak_staged_words as u64;

        // Both lanes' accesses at their real store addresses — the store
        // makes these genuine, scattered, arena-assigned addresses
        // rather than every map starting at 0.
        let trace = LayerTrace {
            fetch: fetch_dram.trace().map(<[Access]>::to_vec).unwrap_or_default(),
            write: report.dram.trace().map(<[Access]>::to_vec).unwrap_or_default(),
        };
        Ok((metrics, trace))
    }

    /// Run a whole stack store-resident: the dense input image is packed
    /// once into `store`, then every layer reads its input from the
    /// store and streams its output back compressed — the packed output
    /// of layer N *is* the packed input of layer N+1, and no dense
    /// intermediate map ever materialises. Consumed inputs are freed,
    /// exercising the arena's reuse path. Tensors are named
    /// `<prefix>0..=<prefix>N`; the final activation stays resident.
    pub fn run_network_in_store(
        &self,
        store: &mut TensorStore,
        layers: &[(ConvLayer, Weights)],
        input: FeatureMap,
        prefix: &str,
    ) -> Result<Vec<PipelineMetrics>> {
        Ok(self
            .run_network_in_store_traced(store, layers, input, prefix)?
            .into_iter()
            .map(|(mut m, trace)| {
                Self::replay_timed(&mut m, &trace);
                m
            })
            .collect())
    }

    /// [`LayerRunner::run_network_in_store`] without the solo timed
    /// replay: returns each layer's metrics *and* its trace, so a caller
    /// (the serving simulator) can replay the whole request under shared
    /// contention instead.
    pub fn run_network_in_store_traced(
        &self,
        store: &mut TensorStore,
        layers: &[(ConvLayer, Weights)],
        input: FeatureMap,
        prefix: &str,
    ) -> Result<Vec<(PipelineMetrics, LayerTrace)>> {
        if layers.is_empty() {
            bail!("run_network_in_store: empty layer stack");
        }
        let packed = self.pack(&layers[0].0, &input).context("packing network input")?;
        store.insert_packed(&format!("{prefix}0"), &packed)?;
        let mut per_layer = Vec::with_capacity(layers.len());
        for (i, (layer, weights)) in layers.iter().enumerate() {
            let next = layers.get(i + 1).map(|(l, _)| l);
            // Layer i's output is layer i+1's input: store it under the
            // consumer's plan. Past the last tuned entry this is the
            // global config, preserving the untuned behaviour.
            let out_plan = self.plan_for(i + 1);
            let div = self.output_division_with(
                out_plan.mode,
                next,
                layer.out_h(),
                layer.out_w(),
                layer.c_out,
            )?;
            let in_name = format!("{prefix}{i}");
            let out_name = format!("{prefix}{}", i + 1);
            let m = self.run_layer_store_traced_policy(
                store, &in_name, &out_name, layer, weights, div, out_plan.policy,
            )?;
            per_layer.push(m);
            store.remove(&in_name)?;
        }
        Ok(per_layer)
    }

    /// Run a whole stack through a fresh [`TensorStore`] and fetch the
    /// final activation dense. Every intermediate map lives only as
    /// compressed store-resident storage.
    pub fn run_network(
        &self,
        layers: &[(ConvLayer, Weights)],
        input: FeatureMap,
    ) -> Result<(FeatureMap, Vec<PipelineMetrics>)> {
        let mut store = TensorStore::new();
        let per_layer = self.run_network_in_store(&mut store, layers, input, "act")?;
        let mut dram = Dram::default();
        let out = store.fetch_dense(&format!("act{}", layers.len()), &mut dram)?;
        Ok((out, per_layer))
    }

    /// Run a whole stack through a fresh store and return the dense
    /// output, the per-layer metrics AND the per-layer traces. A fresh
    /// store means the arena assigns the same addresses for the same
    /// request every time — the traces (and anything priced from them)
    /// are bit-deterministic regardless of how many requests run
    /// concurrently.
    pub fn run_network_traced(
        &self,
        layers: &[(ConvLayer, Weights)],
        input: FeatureMap,
    ) -> Result<(FeatureMap, Vec<PipelineMetrics>, Vec<LayerTrace>)> {
        let mut store = TensorStore::new();
        let pairs = self.run_network_in_store_traced(&mut store, layers, input, "act")?;
        let mut dram = Dram::default();
        let out = store.fetch_dense(&format!("act{}", layers.len()), &mut dram)?;
        let (metrics, traces) = pairs.into_iter().unzip();
        Ok((out, metrics, traces))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;
    use crate::coordinator::conv::direct_conv_relu;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn cfg() -> PipelineConfig {
        PipelineConfig::new(Platform::NvidiaSmallTile.hardware())
    }

    fn assert_fm_close(a: &FeatureMap, b: &FeatureMap, tol: f32) {
        assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c));
        for (i, (&x, &y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            assert!(
                (x - y).abs() / scale <= tol,
                "idx {i}: {x} vs {y}"
            );
        }
    }

    /// THE end-to-end correctness invariant: the tiled, compressed,
    /// double-buffered pipeline computes the same layer output as a
    /// dense reference convolution.
    #[test]
    fn pipeline_matches_dense_reference() {
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 8);
        let w = Weights::random(&layer, 42);
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.5, 9));
        let runner = LayerRunner::new(cfg());
        let packed = runner.pack(&layer, &fm).unwrap();
        let (out, m) = runner.run_layer(&layer, &w, &packed).unwrap();
        let oracle = direct_conv_relu(&layer, &w, &fm);
        assert_fm_close(&out, &oracle, 0.02);
        assert!(m.tiles > 0);
        assert!(m.feature_lines > 0);
        assert!(m.metadata_words > 0);
        // The compute lane reports measured kernel work.
        assert!(m.gemm.dense_macs > 0);
        assert!(m.measured_macs().unwrap() < m.gemm.dense_macs, "50% map must skip");
        // The fetch lane ships its datapath counters up into metrics,
        // and the input's payload bits land under its codec tag.
        assert!(m.decoded_words > 0, "fetch counters absorbed");
        let bits: u64 = m.packed_bits_by_codec.iter().sum();
        assert!(bits > 0, "input payload bits attributed to a codec tag");
    }

    /// Every kernel skip policy yields the same pipeline output; the
    /// measured MAC ladder is monotone (ZeroSkip ≤ ValueSkip < Dense on
    /// a sparse map) and the dense-equivalent count is policy-invariant.
    #[test]
    fn skip_policies_agree_and_report_measured_macs() {
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 8);
        let w = Weights::random(&layer, 11);
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.25, 14));
        let mut outs = Vec::new();
        let mut stats = Vec::new();
        for skip in crate::compute::SkipPolicy::all() {
            let mut c = cfg();
            c.skip = skip;
            let runner = LayerRunner::new(c);
            let packed = runner.pack(&layer, &fm).unwrap();
            let (out, m) = runner.run_layer(&layer, &w, &packed).unwrap();
            outs.push(out);
            stats.push(m.gemm);
        }
        assert_eq!(outs[0].as_slice(), outs[1].as_slice());
        assert_eq!(outs[0].as_slice(), outs[2].as_slice());
        let (dense, vskip, zskip) = (stats[0], stats[1], stats[2]);
        assert_eq!(dense.macs, dense.dense_macs);
        assert!(vskip.macs < dense.macs);
        assert!(zskip.macs <= vskip.macs);
        assert_eq!(vskip.dense_macs, dense.dense_macs);
        assert_eq!(zskip.dense_macs, dense.dense_macs);
    }

    #[test]
    fn pipeline_strided_and_pointwise() {
        for layer in [
            ConvLayer::new(1, 2, 24, 24, 16, 8),
            ConvLayer::new(0, 1, 16, 16, 16, 16),
            ConvLayer::new(2, 1, 20, 20, 8, 8),
        ] {
            let w = Weights::random(&layer, 5);
            let fm = generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(0.4, 3));
            let runner = LayerRunner::new(cfg());
            let packed = runner.pack(&layer, &fm).unwrap();
            let (out, _) = runner.run_layer(&layer, &w, &packed).unwrap();
            let oracle = direct_conv_relu(&layer, &w, &fm);
            assert_fm_close(&out, &oracle, 0.02);
        }
    }

    #[test]
    fn multi_layer_network_chains() {
        let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let l2 = ConvLayer::new(1, 2, 16, 16, 8, 16);
        let l3 = ConvLayer::new(0, 1, 8, 8, 16, 8);
        let layers = vec![
            (l1, Weights::random(&l1, 1)),
            (l2, Weights::random(&l2, 2)),
            (l3, Weights::random(&l3, 3)),
        ];
        let input = generate(16, 16, 8, SparsityParams::iid(0.8, 4));
        let runner = LayerRunner::new(cfg());
        let (out, per_layer) = runner.run_network(&layers, input.clone()).unwrap();
        assert_eq!((out.h, out.w, out.c), (8, 8, 8));
        assert_eq!(per_layer.len(), 3);
        // Oracle chain.
        let mut fm = input;
        for (l, w) in &layers {
            fm = direct_conv_relu(l, w, &fm);
        }
        assert_fm_close(&out, &fm, 0.05);
    }

    #[test]
    fn uniform_mode_also_correct() {
        let layer = ConvLayer::new(1, 1, 20, 20, 8, 8);
        let w = Weights::random(&layer, 13);
        let fm = generate(20, 20, 8, SparsityParams::clustered(0.4, 17));
        for mode in [DivisionMode::Uniform { edge: 4 }, DivisionMode::Uniform { edge: 1 }] {
            let mut c = cfg();
            c.mode = mode;
            let runner = LayerRunner::new(c);
            let packed = runner.pack(&layer, &fm).unwrap();
            let (out, _) = runner.run_layer(&layer, &w, &packed).unwrap();
            assert_fm_close(&out, &direct_conv_relu(&layer, &w, &fm), 0.02);
        }
    }

    /// Store-resident chaining: intermediates are freed as consumed,
    /// write-back traffic is accounted exactly, staging never holds the
    /// whole map, and the timed replay sees real addresses.
    #[test]
    fn store_chain_frees_intermediates_and_accounts_writeback() {
        let l1 = ConvLayer::new(1, 1, 40, 40, 16, 16);
        let l2 = ConvLayer::new(1, 1, 40, 40, 16, 8);
        let layers =
            vec![(l1, Weights::random(&l1, 4)), (l2, Weights::random(&l2, 5))];
        let input = generate(40, 40, 16, SparsityParams::clustered(0.5, 6));
        let runner = LayerRunner::new(cfg());
        let mut store = crate::store::TensorStore::new();
        let per_layer =
            runner.run_network_in_store(&mut store, &layers, input, "act").unwrap();
        assert_eq!(per_layer.len(), 2);
        // Only the final activation remains resident.
        assert_eq!(store.names(), vec!["act2".to_string()]);
        store.arena().check().unwrap();
        for m in &per_layer {
            assert!(m.writeback_payload_bits > 0);
            assert!(m.writeback_meta_bits > 0);
            assert!(m.metadata_write_words > 0, "producer-side index traffic accounted");
            assert!(m.row_hits + m.row_misses > 0, "timed replay ran");
            assert!(m.decoded_words > 0, "store path also ships fetch counters");
            assert!(m.packed_bits_by_codec.iter().sum::<u64>() > 0);
            // The streaming writer's staging stays well under the dense
            // intermediate it replaces (40x40x16 = 25600 words).
            assert!(
                (m.peak_staged_words as usize) < 40 * 40 * 16,
                "staging {} should not reach the dense map",
                m.peak_staged_words
            );
        }
    }

    /// `run_network` (store-backed) still matches the dense oracle and
    /// a store-resident intermediate fetched back equals what the dense
    /// path would have produced (bf16).
    #[test]
    fn store_chain_matches_dense_oracle() {
        let l1 = ConvLayer::new(1, 1, 24, 24, 8, 8);
        let l2 = ConvLayer::new(0, 1, 24, 24, 8, 8);
        let layers =
            vec![(l1, Weights::random(&l1, 7)), (l2, Weights::random(&l2, 8))];
        let input = generate(24, 24, 8, SparsityParams::clustered(0.5, 9));
        let runner = LayerRunner::new(cfg());
        let (out, _) = runner.run_network(&layers, input.clone()).unwrap();
        let mut fm = input;
        for (l, w) in &layers {
            fm = direct_conv_relu(l, w, &fm);
        }
        assert_fm_close(&out, &fm, 0.05);
    }

    /// The functional/timing decoupling: traces are exposed, non-empty,
    /// and bit-identical across repeated functional passes of the same
    /// request (fresh store ⇒ same arena addresses every time).
    #[test]
    fn traced_run_is_deterministic_and_matches_store_path() {
        let l1 = ConvLayer::new(1, 1, 24, 24, 8, 8);
        let layers = vec![(l1, Weights::random(&l1, 3))];
        let input = generate(24, 24, 8, SparsityParams::clustered(0.5, 4));
        let runner = LayerRunner::new(cfg());
        let (out_a, metrics, traces) =
            runner.run_network_traced(&layers, input.clone()).unwrap();
        assert_eq!(traces.len(), 1);
        assert!(!traces[0].fetch.is_empty(), "prefetch lane must trace");
        assert!(!traces[0].write.is_empty(), "writer must trace");
        assert!(traces[0].words() > 0);
        // The traced variant skips the solo replay; metrics still carry
        // the functional traffic.
        assert!(metrics[0].feature_lines > 0);
        let (out_b, _, traces2) = runner.run_network_traced(&layers, input).unwrap();
        assert_eq!(traces[0].fetch, traces2[0].fetch);
        assert_eq!(traces[0].write, traces2[0].write);
        assert_eq!(out_a.as_slice(), out_b.as_slice());
    }

    /// Per-layer tuned plans change only *how* maps are stored, never
    /// what the network computes: a mixed-plan run (different division
    /// mode and codec per layer) matches the untuned run bit-for-bit.
    #[test]
    fn tuned_plans_preserve_network_output() {
        use crate::compress::Scheme;
        use crate::sim::metacache::TileOrder;
        let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let l2 = ConvLayer::new(1, 2, 16, 16, 8, 16);
        let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
        let input = generate(16, 16, 8, SparsityParams::clustered(0.5, 7));
        let base = LayerRunner::new(cfg());
        let (out_a, _) = base.run_network(&layers, input.clone()).unwrap();
        let plans = vec![
            LayerPlan {
                mode: DivisionMode::Uniform { edge: 4 },
                policy: CodecPolicy::Adaptive,
                order: TileOrder::SpatialMajor,
            },
            LayerPlan {
                mode: DivisionMode::Anchored { edge: 8, anchor: 1 },
                policy: CodecPolicy::Fixed(Scheme::Zrlc),
                order: TileOrder::ChannelMajor,
            },
        ];
        let tuned = LayerRunner::new(cfg()).with_plans(plans);
        let (out_b, metrics) = tuned.run_network(&layers, input).unwrap();
        assert_eq!(out_a.as_slice(), out_b.as_slice());
        assert_eq!(metrics.len(), 2);
    }

    #[test]
    fn gratetile_moves_fewer_feature_bytes_than_uniform8() {
        let layer = ConvLayer::new(1, 1, 56, 56, 32, 8);
        let w = Weights::random(&layer, 21);
        let fm = generate(56, 56, 32, SparsityParams::clustered(0.35, 23));
        let run = |mode| {
            let mut c = cfg();
            c.mode = mode;
            let runner = LayerRunner::new(c);
            let packed = runner.pack(&layer, &fm).unwrap();
            let (_, m) = runner.run_layer(&layer, &w, &packed).unwrap();
            m.feature_bytes()
        };
        let grate = run(DivisionMode::GrateTile { n: 8 });
        let uni = run(DivisionMode::Uniform { edge: 8 });
        assert!(grate < uni, "grate {grate} vs uniform {uni}");
    }
}
