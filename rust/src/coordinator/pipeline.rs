//! Double-buffered tiled execution over GrateTile-packed feature maps.
//!
//! Topology per layer (paper Fig. 2c):
//!
//! ```text
//!   [prefetch thread]        bounded channel          [compute lane]
//!   metadata lookup  ──►  (depth = double buffer)  ──►  direct conv
//!   fetch sub-tensors                                    accumulate
//!   decompress                                           ReLU + store
//! ```
//!
//! The prefetch thread walks the same tile schedule as the bandwidth
//! simulator, so the DRAM traffic it accounts matches `sim`'s analytic
//! numbers; the compute lane proves the fetched data is *correct* by
//! actually convolving it.

use super::conv::{accumulate_tile, Weights};
use super::metrics::PipelineMetrics;
use crate::compress::Scheme;
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::layout::fetcher::{DenseWindow, Fetcher};
use crate::layout::packer::{PackedFeatureMap, Packer};
use crate::memsim::{Dram, Stream};
use crate::sim::walker::TileWalker;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionMode};
use crate::util::error::{Context, Result};
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

/// Pipeline configuration.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub hw: Hardware,
    pub mode: DivisionMode,
    pub scheme: Scheme,
    /// Prefetch queue depth; 2 = classic double buffering.
    pub prefetch_depth: usize,
}

impl PipelineConfig {
    pub fn new(hw: Hardware) -> Self {
        Self { hw, mode: DivisionMode::GrateTile { n: 8 }, scheme: Scheme::Bitmask, prefetch_depth: 2 }
    }
}

/// Executes layers tile-by-tile.
pub struct LayerRunner {
    pub cfg: PipelineConfig,
}

impl LayerRunner {
    pub fn new(cfg: PipelineConfig) -> Self {
        Self { cfg }
    }

    /// Pack a dense feature map for this pipeline's storage scheme.
    pub fn pack(&self, layer: &ConvLayer, fm: &FeatureMap) -> Result<PackedFeatureMap> {
        let tile = self.cfg.hw.tile_for_layer(layer);
        let division =
            Division::build(self.cfg.mode, layer, &tile, &self.cfg.hw, fm.h, fm.w, fm.c)
                .context("building division")?;
        Ok(Packer::new(self.cfg.hw, self.cfg.scheme).pack(fm, &division, true))
    }

    /// Run one layer over a packed input; returns the ReLU'd output map
    /// and pipeline metrics.
    pub fn run_layer(
        &self,
        layer: &ConvLayer,
        weights: &Weights,
        packed: &PackedFeatureMap,
    ) -> Result<(FeatureMap, PipelineMetrics)> {
        let tile = self.cfg.hw.tile_for_layer(layer);
        let walker = TileWalker::new(*layer, tile);
        let (out_h, out_w) = (layer.out_h(), layer.out_w());
        let mut out = FeatureMap::zeros(out_h, out_w, layer.c_out);
        let mut metrics = PipelineMetrics::default();
        let wall_start = Instant::now();

        let depth = self.cfg.prefetch_depth.max(1);
        let (tx, rx) = sync_channel::<DenseWindow>(depth);

        let (fetch_busy, fetch_dram) = std::thread::scope(
            |scope| -> Result<(Duration, Dram)> {
                // ---- prefetch lane ----
                let walker_f = walker.clone();
                let fetch_handle = scope.spawn(move || {
                    let mut fetcher = Fetcher::new(packed);
                    let mut dram = Dram::default();
                    let mut busy = Duration::ZERO;
                    for w in walker_f.iter() {
                        let t0 = Instant::now();
                        let win = fetcher.fetch_window(
                            &mut dram, w.y0, w.y1, w.x0, w.x1, w.c0, w.c1,
                        );
                        busy += t0.elapsed();
                        // Backpressure: blocks when `depth` windows are
                        // already staged.
                        if tx.send(win).is_err() {
                            break; // compute lane bailed
                        }
                    }
                    (busy, dram)
                });

                // ---- compute lane (this thread) ----
                let mut acc: Vec<f32> = Vec::new();
                for ty in 0..walker.n_ty {
                    let oy0 = ty * tile.th;
                    let oy1 = (oy0 + tile.th).min(out_h);
                    for tx_i in 0..walker.n_tx {
                        let ox0 = tx_i * tile.tw;
                        let ox1 = (ox0 + tile.tw).min(out_w);
                        acc.clear();
                        acc.resize((oy1 - oy0) * (ox1 - ox0) * layer.c_out, 0.0);
                        for _tcg in 0..walker.n_tcg {
                            let win = rx.recv().context("prefetch lane died")?;
                            let t0 = Instant::now();
                            accumulate_tile(layer, weights, &win, &mut acc, oy0, oy1, ox0, ox1);
                            metrics.compute_busy += t0.elapsed();
                        }
                        // ReLU + writeback.
                        let t0 = Instant::now();
                        for v in &mut acc {
                            *v = v.max(0.0);
                        }
                        out.write_block(oy0, ox0, 0, oy1 - oy0, ox1 - ox0, layer.c_out, &acc);
                        metrics.compute_busy += t0.elapsed();
                        metrics.tiles += 1;
                    }
                }
                drop(rx);
                let (busy, dram) = fetch_handle.join().expect("prefetch lane panicked");
                Ok((busy, dram))
            },
        )?;

        metrics.fetch_busy = fetch_busy;
        metrics.absorb_dram(&fetch_dram);
        let mut out_dram = Dram::default();
        out_dram.access(Stream::OutputWrite, 0, out.words() as u64);
        metrics.absorb_dram(&out_dram);
        metrics.wall = wall_start.elapsed();
        Ok((out, metrics))
    }

    /// Run a whole stack: pack the input once, then per layer
    /// fetch→compute→ReLU→re-pack, keeping every intermediate map in
    /// compressed storage. Returns the final map plus per-layer metrics.
    pub fn run_network(
        &self,
        layers: &[(ConvLayer, Weights)],
        input: FeatureMap,
    ) -> Result<(FeatureMap, Vec<PipelineMetrics>)> {
        let mut fm = input;
        let mut per_layer = Vec::with_capacity(layers.len());
        for (layer, weights) in layers {
            let packed = self.pack(layer, &fm).context("packing layer input")?;
            let (out, m) = self.run_layer(layer, weights, &packed)?;
            per_layer.push(m);
            fm = out;
        }
        Ok((fm, per_layer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;
    use crate::coordinator::conv::direct_conv_relu;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn cfg() -> PipelineConfig {
        PipelineConfig::new(Platform::NvidiaSmallTile.hardware())
    }

    fn assert_fm_close(a: &FeatureMap, b: &FeatureMap, tol: f32) {
        assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c));
        for (i, (&x, &y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
            let scale = x.abs().max(y.abs()).max(1.0);
            assert!(
                (x - y).abs() / scale <= tol,
                "idx {i}: {x} vs {y}"
            );
        }
    }

    /// THE end-to-end correctness invariant: the tiled, compressed,
    /// double-buffered pipeline computes the same layer output as a
    /// dense reference convolution.
    #[test]
    fn pipeline_matches_dense_reference() {
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 8);
        let w = Weights::random(&layer, 42);
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.5, 9));
        let runner = LayerRunner::new(cfg());
        let packed = runner.pack(&layer, &fm).unwrap();
        let (out, m) = runner.run_layer(&layer, &w, &packed).unwrap();
        let oracle = direct_conv_relu(&layer, &w, &fm);
        assert_fm_close(&out, &oracle, 0.02);
        assert!(m.tiles > 0);
        assert!(m.feature_lines > 0);
        assert!(m.metadata_words > 0);
    }

    #[test]
    fn pipeline_strided_and_pointwise() {
        for layer in [
            ConvLayer::new(1, 2, 24, 24, 16, 8),
            ConvLayer::new(0, 1, 16, 16, 16, 16),
            ConvLayer::new(2, 1, 20, 20, 8, 8),
        ] {
            let w = Weights::random(&layer, 5);
            let fm = generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(0.4, 3));
            let runner = LayerRunner::new(cfg());
            let packed = runner.pack(&layer, &fm).unwrap();
            let (out, _) = runner.run_layer(&layer, &w, &packed).unwrap();
            let oracle = direct_conv_relu(&layer, &w, &fm);
            assert_fm_close(&out, &oracle, 0.02);
        }
    }

    #[test]
    fn multi_layer_network_chains() {
        let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let l2 = ConvLayer::new(1, 2, 16, 16, 8, 16);
        let l3 = ConvLayer::new(0, 1, 8, 8, 16, 8);
        let layers = vec![
            (l1, Weights::random(&l1, 1)),
            (l2, Weights::random(&l2, 2)),
            (l3, Weights::random(&l3, 3)),
        ];
        let input = generate(16, 16, 8, SparsityParams::iid(0.8, 4));
        let runner = LayerRunner::new(cfg());
        let (out, per_layer) = runner.run_network(&layers, input.clone()).unwrap();
        assert_eq!((out.h, out.w, out.c), (8, 8, 8));
        assert_eq!(per_layer.len(), 3);
        // Oracle chain.
        let mut fm = input;
        for (l, w) in &layers {
            fm = direct_conv_relu(l, w, &fm);
        }
        assert_fm_close(&out, &fm, 0.05);
    }

    #[test]
    fn uniform_mode_also_correct() {
        let layer = ConvLayer::new(1, 1, 20, 20, 8, 8);
        let w = Weights::random(&layer, 13);
        let fm = generate(20, 20, 8, SparsityParams::clustered(0.4, 17));
        for mode in [DivisionMode::Uniform { edge: 4 }, DivisionMode::Uniform { edge: 1 }] {
            let mut c = cfg();
            c.mode = mode;
            let runner = LayerRunner::new(c);
            let packed = runner.pack(&layer, &fm).unwrap();
            let (out, _) = runner.run_layer(&layer, &w, &packed).unwrap();
            assert_fm_close(&out, &direct_conv_relu(&layer, &w, &fm), 0.02);
        }
    }

    #[test]
    fn gratetile_moves_fewer_feature_bytes_than_uniform8() {
        let layer = ConvLayer::new(1, 1, 56, 56, 32, 8);
        let w = Weights::random(&layer, 21);
        let fm = generate(56, 56, 32, SparsityParams::clustered(0.35, 23));
        let run = |mode| {
            let mut c = cfg();
            c.mode = mode;
            let runner = LayerRunner::new(c);
            let packed = runner.pack(&layer, &fm).unwrap();
            let (_, m) = runner.run_layer(&layer, &w, &packed).unwrap();
            m.feature_bytes()
        };
        let grate = run(DivisionMode::GrateTile { n: 8 });
        let uni = run(DivisionMode::Uniform { edge: 8 });
        assert!(grate < uni, "grate {grate} vs uniform {uni}");
    }
}
