//! Discrete-event, virtual-clock serving simulator.
//!
//! [`super::server::Server`] measures host wall-clock time, so its
//! throughput/latency numbers depend on the machine, the load and the
//! thread schedule — useless for regression tests or cross-PR
//! comparison. This module replaces *time measurement* with *time
//! simulation*, the way BARISTA simulates concurrent sparse-tensor
//! traffic cycle-by-cycle and GrateTile §V prices layers on a DRAM
//! simulation:
//!
//! 1. **Functional pass** (host-parallel, order-preserving): every
//!    request runs the real store-resident pipeline
//!    ([`LayerRunner::run_network_traced`]) against a fresh
//!    [`crate::store::TensorStore`], producing its dense output, a
//!    checksum, and per-layer [`LayerTrace`]s at real arena addresses.
//!    Traces depend only on the data, so this pass can fan across any
//!    number of host threads and still produce identical bytes.
//! 2. **Timing pass** (single-threaded, deterministic): a discrete-event
//!    loop replays those traces through one **shared, bank-contended**
//!    [`SharedDram`]. N simulated accelerator workers pull batches from
//!    a bounded admission queue (priority classes first, FIFO within a
//!    class); same-cycle grants go round-robin across workers. Each
//!    layer advances a worker's clock by
//!    `max(batched compute, contended DRAM stream)` — the
//!    double-buffered overlap the pipeline implements functionally.
//!
//! The resulting [`SimServerReport`] is in *simulated cycles* and its
//! [`SimServerReport::render`] output is byte-identical for a given
//! request set regardless of host load or `--jobs` — asserted by
//! `tests/golden.rs` and covered by a golden fixture.

use super::conv::Weights;
use super::metrics::{LayerObs, SortedSamples};
use super::pipeline::{LayerRunner, LayerTrace, PipelineConfig};
use crate::compress::Registry;
use crate::fault::FaultPlan;
use crate::config::layer::ConvLayer;
use crate::memsim::{DramTiming, SharedDram};
use crate::obs::trace::{Track, TraceRecorder, ADMISSION_PID, COUNTER_PID, DRAM_PID, WORKER_PID};
use crate::obs::MetricsRegistry;
use crate::store::container::{fnv1a64_continue, FNV1A64_OFFSET};
use crate::tensor::sparsity::{generate, SparsityParams};
use crate::tensor::FeatureMap;
use crate::util::error::Result;
use crate::util::parallel::par_map;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::fmt::Write as _;

/// Request priority class: interactive requests pre-empt batch-class
/// requests at every queue pop (FIFO within a class — no starvation
/// model beyond class order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Interactive,
    Batch,
}

impl Priority {
    pub fn name(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// First-class per-request serving outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RequestOutcome {
    /// Served with a bit-exact output.
    Completed,
    /// Served, but at least one quarantined sub-tensor was substituted
    /// with zeros along the way (graceful degradation — the client got
    /// an answer, flagged imperfect).
    Degraded,
    /// Deadline missed after exhausting the serving retry budget.
    TimedOut,
    /// Dropped at admission under overload (Batch class sheds first;
    /// Interactive is never shed).
    Shed,
    /// Bounded waiting-room overflow at admission.
    Rejected,
}

impl RequestOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::Degraded => "degraded",
            RequestOutcome::TimedOut => "timed_out",
            RequestOutcome::Shed => "shed",
            RequestOutcome::Rejected => "rejected",
        }
    }

    /// Whether the request actually ran on a worker (and therefore has
    /// meaningful queue/latency samples).
    pub fn served(&self) -> bool {
        matches!(
            self,
            RequestOutcome::Completed | RequestOutcome::Degraded | RequestOutcome::TimedOut
        )
    }
}

/// Serving-robustness knobs. All off under [`Default`] — the
/// historical always-serve behaviour — so existing configurations are
/// unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServingPolicy {
    /// Per-request deadline in cycles from (effective) arrival;
    /// 0 disables deadlines.
    pub deadline_cycles: u64,
    /// Re-serve attempts granted after a deadline miss before the
    /// request is counted [`RequestOutcome::TimedOut`].
    pub retry_budget: u32,
    /// Under overload (admission queue plus waiting room at the
    /// admission-queue capacity), shed arriving Batch-class requests
    /// instead of queueing them. Interactive arrivals are never shed.
    pub shed_batch_on_overload: bool,
    /// Bound on the pre-admission waiting room (0 = unbounded). An
    /// arrival beyond it is rejected — counted, never silently dropped.
    pub waiting_depth: usize,
}

/// One inference request for the simulator.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub id: u64,
    pub priority: Priority,
    /// Simulated cycle the request arrives at the admission queue.
    pub arrival_cycle: u64,
    pub input: FeatureMap,
}

/// Simulator configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimServerConfig {
    pub pipeline: PipelineConfig,
    /// Simulated accelerator workers.
    pub workers: usize,
    /// Bounded admission queue depth (requests admitted beyond
    /// in-flight ones; arrivals beyond it wait unadmitted).
    pub queue_depth: usize,
    /// Max requests a worker pulls per grant (batching amortises layer
    /// scheduling; batched requests share one completion cycle).
    pub batch: usize,
    /// Shared-DRAM geometry/timing (banks, row buffers, latencies).
    pub timing: DramTiming,
    /// MAC lanes of one worker's PE array: a layer's compute time is
    /// `ceil(macs / pe_lanes)` cycles.
    pub pe_lanes: u64,
    /// Cycles between successive request arrivals (0 = closed batch,
    /// everything arrives at cycle 0).
    pub arrival_gap: u64,
    /// Deadlines, retry budgets, overload shedding and waiting-room
    /// bounds (all off by default).
    pub serving: ServingPolicy,
}

impl SimServerConfig {
    pub fn new(pipeline: PipelineConfig) -> Self {
        Self {
            pipeline,
            workers: 2,
            queue_depth: 8,
            batch: 1,
            timing: DramTiming::default(),
            pe_lanes: 32,
            arrival_gap: 0,
            serving: ServingPolicy::default(),
        }
    }
}

/// One layer's simulated work: its DRAM trace plus its raw MAC count.
/// Compute *cycles* are derived inside the timing pass from the
/// simulate-time `pe_lanes`, so re-simulating the same traces under a
/// different PE width is honest without a new functional pass.
#[derive(Debug, Clone)]
pub struct LayerWork {
    /// MACs this layer charges the PE array. **Measured** from the GEMM
    /// kernel when the pipeline's compute backend ran (the normal case);
    /// the analytic `ConvLayer::macs()` estimate only as fallback —
    /// [`Self::measured`] says which.
    pub macs: u64,
    /// `true` when `macs` came from kernel counters, `false` when it is
    /// the analytic estimate.
    pub measured: bool,
    pub trace: LayerTrace,
    /// Observable per-layer counters (packed bits by codec, cache hits,
    /// skip counts…) computed by the functional pass and emitted as
    /// trace counter events by the timing pass.
    pub obs: LayerObs,
}

impl LayerWork {
    /// Compute cycles on a `pe_lanes`-wide MAC array.
    pub fn compute_cycles(&self, pe_lanes: u64) -> u64 {
        self.macs.div_ceil(pe_lanes.max(1))
    }
}

/// Everything the timing pass needs to know about one request — the
/// functional pass's deterministic digest.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub id: u64,
    pub priority: Priority,
    pub arrival_cycle: u64,
    pub feature_bytes: u64,
    /// FNV-1a over the request's dense output bits.
    pub output_checksum: u64,
    pub layers: Vec<LayerWork>,
}

impl RequestTrace {
    /// Total MACs this request charges across its layers.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// `true` iff every layer's MAC count was kernel-measured.
    pub fn macs_measured(&self) -> bool {
        !self.layers.is_empty() && self.layers.iter().all(|l| l.measured)
    }

    /// Zero-substituted sub-tensor touches across the request's layers
    /// (from the functional pass's integrity layer).
    pub fn degraded_subtensors(&self) -> u64 {
        self.layers.iter().map(|l| l.obs.degraded_subtensors).sum()
    }

    /// Checksum mismatches the integrity layer detected across layers.
    pub fn checksum_mismatches(&self) -> u64 {
        self.layers.iter().map(|l| l.obs.checksum_mismatches).sum()
    }

    /// True when any sub-tensor fetch fell back to the zero substitute
    /// — the request's output is flagged, not bit-exact.
    pub fn degraded(&self) -> bool {
        self.degraded_subtensors() > 0
    }

    /// True when corruption was detected but every read healed on
    /// retry: the output is still bit-exact ("silently correct").
    pub fn recovered(&self) -> bool {
        !self.degraded() && self.checksum_mismatches() > 0
    }
}

/// Per-request outcome, in request-id order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestStat {
    pub id: u64,
    pub priority: Priority,
    /// How the request left the system.
    pub outcome: RequestOutcome,
    /// Serve attempts consumed (1 for a first-try completion; 0 for
    /// requests shed/rejected at admission).
    pub attempts: u32,
    /// Cycles from (effective) arrival to the final worker grant.
    pub queue_cycles: u64,
    /// Cycles from (effective) arrival to completion.
    pub latency_cycles: u64,
    /// MACs the request charged the PE array (kernel-measured when the
    /// compute backend ran — see [`RequestTrace::macs_measured`]).
    pub macs: u64,
}

/// The simulated serving report — every field in simulated cycles or
/// exact counts, so [`SimServerReport::render`] is byte-stable for a
/// given request set on any host.
#[derive(Debug, Clone)]
pub struct SimServerReport {
    pub workers: usize,
    pub queue_depth: usize,
    pub batch: usize,
    pub n_banks: usize,
    pub pe_lanes: u64,
    /// Requests served to completion (bit-exact **or** degraded).
    pub completed: u64,
    /// Requests offered to admission; conservation
    /// `admitted + rejected + shed == offered` is asserted by the
    /// timing pass (see [`Self::conservation_holds`]).
    pub offered: u64,
    /// Requests that reached a worker (`completed + timed_out`).
    pub admitted: u64,
    /// Bounded waiting-room overflows at admission.
    pub rejected: u64,
    /// Batch-class requests dropped by overload shedding.
    pub shed: u64,
    /// Requests that missed their deadline after every retry.
    pub timed_out: u64,
    /// Deadline-miss re-serves granted by the retry budget.
    pub serving_retries: u64,
    /// Served requests flagged degraded (zero-substituted sub-tensors).
    pub degraded_requests: u64,
    /// Served requests whose detected corruption fully healed on
    /// re-read — output still bit-exact.
    pub recovered_requests: u64,
    /// Integrity-layer read counters, summed over the functional pass
    /// (per unique request, independent of serving retries).
    pub verified_reads: u64,
    pub checksum_mismatches: u64,
    pub retried_reads: u64,
    pub recovered_reads: u64,
    pub degraded_subtensors: u64,
    pub makespan_cycles: u64,
    pub requests: Vec<RequestStat>,
    /// MACs across all requests, and whether every count was
    /// kernel-measured (vs the analytic estimate).
    pub total_macs: u64,
    pub macs_measured: bool,
    pub total_feature_bytes: u64,
    pub output_checksum: u64,
    pub dram_lines: u64,
    pub dram_requests: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub transfer_cycles: u64,
    pub bank_busy_cycles: Vec<u64>,
}

impl SimServerReport {
    /// Requests completed per million simulated cycles.
    pub fn throughput_rpmc(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 * 1e6 / self.makespan_cycles as f64
    }

    /// End-to-end latency samples over **served** requests (shed and
    /// rejected arrivals never ran, so they contribute no sample),
    /// sorted **once** — every percentile on the returned set is an
    /// O(1) lookup. [`Self::render`] and [`Self::summary`] go through
    /// this instead of re-sorting per percentile call.
    pub fn latency_samples(&self) -> SortedSamples<u64> {
        SortedSamples::from_unsorted(
            self.requests
                .iter()
                .filter(|r| r.outcome.served())
                .map(|r| r.latency_cycles)
                .collect(),
        )
    }

    /// Queue-wait samples, sorted once (see [`Self::latency_samples`]).
    pub fn queue_samples(&self) -> SortedSamples<u64> {
        SortedSamples::from_unsorted(
            self.requests
                .iter()
                .filter(|r| r.outcome.served())
                .map(|r| r.queue_cycles)
                .collect(),
        )
    }

    /// Admission conservation: every offered request is exactly one of
    /// admitted, rejected or shed, and every admitted request either
    /// completed or timed out.
    pub fn conservation_holds(&self) -> bool {
        self.admitted + self.rejected + self.shed == self.offered
            && self.completed + self.timed_out == self.admitted
    }

    /// Completed-and-bit-exact requests per million simulated cycles —
    /// degraded and timed-out requests do not count as goodput.
    pub fn goodput_rpmc(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        (self.completed - self.degraded_requests) as f64 * 1e6 / self.makespan_cycles as f64
    }

    /// End-to-end latency percentile in cycles; `p` is clamped to
    /// `[0, 1]` (NaN → minimum), so no input can panic the index math.
    pub fn latency_percentile(&self, p: f64) -> u64 {
        self.latency_samples().at_or(p, 0)
    }

    /// Queue-wait percentile in cycles (same clamping).
    pub fn queue_percentile(&self, p: f64) -> u64 {
        self.queue_samples().at_or(p, 0)
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// One-line digest.
    pub fn summary(&self) -> String {
        let lat = self.latency_samples();
        format!(
            "{} requests in {} simulated cycles -> {:.3} req/Mcycle; p50={} p99={} cycles; row-hit {:.1}%",
            self.completed,
            self.makespan_cycles,
            self.throughput_rpmc(),
            lat.at_or(0.50, 0),
            lat.at_or(0.99, 0),
            self.row_hit_rate() * 100.0,
        )
    }

    /// Full byte-stable report: the golden-fixture / determinism-test
    /// surface. Every line derives from simulated state only.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("sim-serve report (simulated cycles; host-independent)\n");
        let _ = writeln!(
            s,
            "config workers={} queue_depth={} batch={} banks={} pe_lanes={}",
            self.workers, self.queue_depth, self.batch, self.n_banks, self.pe_lanes
        );
        let _ = writeln!(
            s,
            "completed={} makespan_cycles={} throughput_rpMcycle={:.3}",
            self.completed,
            self.makespan_cycles,
            self.throughput_rpmc()
        );
        let _ = writeln!(
            s,
            "outcomes offered={} admitted={} degraded={} timed_out={} shed={} rejected={} retries={}",
            self.offered,
            self.admitted,
            self.degraded_requests,
            self.timed_out,
            self.shed,
            self.rejected,
            self.serving_retries
        );
        if self.verified_reads > 0 || self.checksum_mismatches > 0 {
            let _ = writeln!(
                s,
                "integrity verified={} mismatches={} retried={} recovered={} degraded_subtensors={} recovered_requests={}",
                self.verified_reads,
                self.checksum_mismatches,
                self.retried_reads,
                self.recovered_reads,
                self.degraded_subtensors,
                self.recovered_requests
            );
        }
        // Each sample set is sorted exactly once for all percentiles.
        let lat = self.latency_samples();
        let queue = self.queue_samples();
        let _ = writeln!(
            s,
            "latency_cycles p50={} p95={} p99={} max={}",
            lat.at_or(0.50, 0),
            lat.at_or(0.95, 0),
            lat.at_or(0.99, 0),
            lat.at_or(1.0, 0),
        );
        let _ = writeln!(
            s,
            "queue_cycles p50={} max={}",
            queue.at_or(0.50, 0),
            queue.at_or(1.0, 0),
        );
        let _ = writeln!(
            s,
            "dram lines={} requests={} row_hits={} row_misses={} transfer_cycles={}",
            self.dram_lines, self.dram_requests, self.row_hits, self.row_misses,
            self.transfer_cycles
        );
        let _ = writeln!(s, "bank_busy_cycles {:?}", self.bank_busy_cycles);
        let _ = writeln!(
            s,
            "macs={} source={}",
            self.total_macs,
            if self.macs_measured { "measured-kernel" } else { "analytic-estimate" }
        );
        let _ = writeln!(
            s,
            "feature_bytes={} output_checksum={:016x}",
            self.total_feature_bytes, self.output_checksum
        );
        for r in &self.requests {
            let _ = writeln!(
                s,
                "request id={} priority={} outcome={} attempts={} queue={} latency={} macs={}",
                r.id,
                r.priority.name(),
                r.outcome.name(),
                r.attempts,
                r.queue_cycles,
                r.latency_cycles,
                r.macs
            );
        }
        s
    }
}

/// The serving simulator: a request set served by `cfg.workers`
/// simulated accelerators over one shared DRAM.
pub struct SimServer {
    cfg: SimServerConfig,
    layers: Vec<(ConvLayer, Weights)>,
    /// Per-layer tuned plans applied to every request's pipeline
    /// (positional, layer `i`'s input map; empty = untuned).
    plans: Vec<crate::tune::LayerPlan>,
}

impl SimServer {
    pub fn new(cfg: SimServerConfig, layers: Vec<(ConvLayer, Weights)>) -> Self {
        Self { cfg, layers, plans: Vec::new() }
    }

    /// Serve under per-layer tuned plans (from a tuned manifest): every
    /// request's store-resident pipeline packs and writes each layer's
    /// map under its tuned `(division, codec)` instead of the global
    /// config.
    pub fn with_plans(mut self, plans: Vec<crate::tune::LayerPlan>) -> Self {
        self.plans = plans;
        self
    }

    pub fn cfg(&self) -> &SimServerConfig {
        &self.cfg
    }

    /// Shape expected of request inputs.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        let l = &self.layers[0].0;
        (l.h, l.w, l.c_in)
    }

    /// Deterministic synthetic request batch: clustered-sparsity inputs
    /// seeded per request, arrivals spaced `arrival_gap` cycles, every
    /// fourth request in the batch-priority class.
    pub fn synthetic_requests(&self, n: usize, density: f64, seed: u64) -> Vec<SimRequest> {
        let (h, w, c) = self.input_shape();
        (0..n)
            .map(|i| SimRequest {
                id: i as u64,
                priority: if i % 4 == 3 { Priority::Batch } else { Priority::Interactive },
                arrival_cycle: i as u64 * self.cfg.arrival_gap,
                input: generate(h, w, c, SparsityParams::clustered(density, seed + i as u64)),
            })
            .collect()
    }

    /// The functional pass: every request through the real
    /// store-resident pipeline, fanned across host workers
    /// (`--jobs`-controlled) with order-preserving results. Each request
    /// gets a fresh [`crate::store::TensorStore`], so its traces — and
    /// therefore everything the timing pass derives — are identical for
    /// any worker count; concurrent readers inside a request share the
    /// store via owned snapshots.
    pub fn functional_pass(&self, requests: &[SimRequest]) -> Result<Vec<RequestTrace>> {
        par_map(requests, |_, req| -> Result<RequestTrace> {
            // Per-request fault salt: concurrent requests draw
            // independent fault streams, yet request k sees the same
            // faults on every run and every `--jobs` (the salt is its
            // id, not anything scheduling-dependent).
            let mut pipeline = self.cfg.pipeline;
            pipeline.fault_salt = req.id;
            let runner = LayerRunner::new(pipeline).with_plans(self.plans.clone());
            let (out, per_layer, traces) =
                runner.run_network_traced(&self.layers, req.input.clone())?;
            // Prefer the GEMM kernel's measured MAC count over the
            // analytic estimate — no double counting: exactly one of
            // the two prices the layer, and `measured` records which.
            let layers: Vec<LayerWork> = self
                .layers
                .iter()
                .zip(per_layer.iter())
                .zip(traces)
                .map(|(((layer, _), m), trace)| {
                    let obs = LayerObs::from_metrics(m);
                    match m.measured_macs() {
                        Some(macs) => LayerWork { macs, measured: true, trace, obs },
                        None => LayerWork { macs: layer.macs(), measured: false, trace, obs },
                    }
                })
                .collect();
            let feature_bytes = per_layer.iter().map(|m| m.feature_bytes()).sum();
            let mut ck = FNV1A64_OFFSET;
            for &v in out.as_slice() {
                ck = fnv1a64_continue(ck, &v.to_bits().to_le_bytes());
            }
            Ok(RequestTrace {
                id: req.id,
                priority: req.priority,
                arrival_cycle: req.arrival_cycle,
                feature_bytes,
                output_checksum: ck,
                layers,
            })
        })
        .into_iter()
        .collect()
    }

    /// Functional pass + timing pass.
    pub fn serve(&self, requests: Vec<SimRequest>) -> Result<SimServerReport> {
        self.serve_traced(requests, &mut TraceRecorder::disabled())
    }

    /// [`Self::serve`] with a trace recorder: when `rec` is enabled the
    /// timing pass emits per-worker request/layer spans, per-bank DRAM
    /// occupancy, admission waits, and cumulative counter events — all
    /// in simulated cycles, byte-stable across `--jobs`.
    pub fn serve_traced(
        &self,
        requests: Vec<SimRequest>,
        rec: &mut TraceRecorder,
    ) -> Result<SimServerReport> {
        let traces = self.functional_pass(&requests)?;
        Ok(simulate_traced(&self.cfg, &traces, rec))
    }
}

/// Event kinds of the timing loop. The heap key is `(cycle, seq, kind)`
/// with a unique monotone `seq`, so pop order is total and
/// deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    Arrive(usize),
    WorkerFree(usize),
}

/// Grant the next idle worker in round-robin order starting after the
/// last grant (the arbiter that keeps same-cycle grants fair and
/// deterministic).
fn grant_rr(idle: &[bool], rr: &mut usize) -> Option<usize> {
    let n = idle.len();
    for k in 0..n {
        let w = (*rr + k) % n;
        if idle[w] {
            *rr = (w + 1) % n;
            return Some(w);
        }
    }
    None
}

/// Advance one worker through a batch starting at `start`: per layer,
/// every batched request's trace streams through the shared DRAM
/// (bank-contended completion times) while the batch's compute
/// accumulates on the worker; the layer ends when both streams drain
/// (double-buffered overlap).
///
/// With an enabled recorder it also emits, on `worker_track`, one
/// `L{li}` span per layer with `dram`/`compute` child spans (children
/// share the layer's start, so nesting holds by construction), and
/// buffers a `(finish, request, layer)` mark per batched layer into
/// `layer_marks` — counter events are emitted later in global
/// timestamp order, because batches complete ahead of the event
/// loop's clock.
#[allow(clippy::too_many_arguments)] // one call site; a params struct would only obscure it
fn run_batch(
    dram: &mut SharedDram,
    start: u64,
    batch: &[usize],
    traces: &[RequestTrace],
    pe_lanes: u64,
    fault: &FaultPlan,
    rec: &mut TraceRecorder,
    worker_track: Track,
    layer_marks: &mut Vec<(u64, usize, usize)>,
) -> u64 {
    let n_layers = batch.iter().map(|&i| traces[i].layers.len()).max().unwrap_or(0);
    let mut t = start;
    for li in 0..n_layers {
        let t0 = t;
        let mut dram_done = t;
        let mut compute = 0u64;
        for &ri in batch {
            let Some(lw) = traces[ri].layers.get(li) else { continue };
            let mut cursor = t;
            for a in lw.trace.iter() {
                cursor = dram.service(cursor, a.addr_words, a.words);
            }
            // Injected bank spikes and integrity retry backoff extend
            // this request's fetch stream only — shared bank state is
            // untouched, so the per-bank busy totals still reconcile
            // exactly with `transfer_cycles`.
            cursor += fault.bank_spike(traces[ri].id, li as u64);
            cursor += lw.obs.retry_backoff_cycles;
            dram_done = dram_done.max(cursor);
            compute += lw.compute_cycles(pe_lanes);
            compute += fault.worker_stall(traces[ri].id, li as u64);
        }
        t = (t + compute).max(dram_done);
        if rec.is_enabled() {
            rec.span(worker_track, &format!("L{li}"), t0, t);
            if dram_done > t0 {
                rec.span(worker_track, "dram", t0, dram_done);
            }
            if compute > 0 {
                rec.span(worker_track, "compute", t0, t0 + compute);
            }
            for &ri in batch {
                if traces[ri].layers.get(li).is_some() {
                    layer_marks.push((t, ri, li));
                }
            }
        }
    }
    t
}

/// Display name of codec tag `tag` (registry order), for counter
/// series and metrics keys.
fn codec_name(tag: usize) -> &'static str {
    Registry::global().entries().get(tag).map_or("unknown", |e| e.name)
}

/// The timing pass: replay `traces` under `cfg` and return the report.
/// Pure and single-threaded — re-simulating the same traces under many
/// configurations (the serve-scaling study, the bench's bank sweep) is
/// cheap and needs no new functional pass.
pub fn simulate(cfg: &SimServerConfig, traces: &[RequestTrace]) -> SimServerReport {
    simulate_traced(cfg, traces, &mut TraceRecorder::disabled())
}

/// [`simulate`] with a trace recorder. When `rec` is enabled, the pass
/// additionally records — all keyed on simulated cycles:
///
/// - per-worker tracks: one `req <ids>` span per grant, with per-layer
///   `L{li}` / `dram` / `compute` child spans;
/// - per-bank DRAM tracks: coalesced `busy` occupancy spans whose
///   per-bank totals reconcile **exactly** with
///   [`SimServerReport::bank_busy_cycles`];
/// - per-request admission tracks: a `wait` span from arrival to grant
///   (only when the wait is non-zero);
/// - cumulative counter events (`macs`, cache hits, skip counts,
///   packed bits per codec) stamped at each layer-completion cycle.
///
/// Emission happens entirely in this single-threaded pass from data the
/// functional pass attached to the traces, so the recorded trace is
/// `--jobs`-invariant by construction.
pub fn simulate_traced(
    cfg: &SimServerConfig,
    traces: &[RequestTrace],
    rec: &mut TraceRecorder,
) -> SimServerReport {
    let workers = cfg.workers.max(1);
    let queue_depth = cfg.queue_depth.max(1);
    let batch_max = cfg.batch.max(1);
    let n = traces.len();
    let mut dram = if rec.is_enabled() {
        SharedDram::new(cfg.timing).with_busy_trace()
    } else {
        SharedDram::new(cfg.timing)
    };

    // Register every process/track up front so export order never
    // depends on which worker or bank happens to run first.
    let mut worker_tracks: Vec<Track> = Vec::new();
    if rec.is_enabled() {
        rec.process(WORKER_PID, "workers");
        for w in 0..workers {
            worker_tracks.push(rec.track(WORKER_PID, w as u64, &format!("worker {w}")));
        }
        rec.process(DRAM_PID, "dram banks");
        for b in 0..dram.timing().n_banks {
            rec.track(DRAM_PID, b as u64, &format!("bank {b}"));
        }
        rec.process(ADMISSION_PID, "admission");
        for t in traces {
            rec.track(ADMISSION_PID, t.id, &format!("req {}", t.id));
        }
        rec.process(COUNTER_PID, "counters");
    }
    // (completion cycle, request index, layer index) of every simulated
    // layer — buffered because batches complete ahead of `now`, then
    // sorted so counter events are emitted in timestamp order.
    let mut layer_marks: Vec<(u64, usize, usize)> = Vec::new();

    let fault = cfg.pipeline.fault.unwrap_or_default();
    let pol = cfg.serving;
    // Effective arrivals after injected burst collapse: a burst-flagged
    // request arrives together with its predecessor (chained, so a run
    // of flagged requests lands as one burst). Queue waits and
    // latencies are measured from these effective arrivals.
    let mut arrivals: Vec<u64> = traces.iter().map(|t| t.arrival_cycle).collect();
    if fault.arrival_burst_rate > 0.0 {
        for i in 1..n {
            if fault.arrival_burst(traces[i].id) {
                arrivals[i] = arrivals[i - 1];
            }
        }
    }

    let mut heap: BinaryHeap<Reverse<(u64, u64, EventKind)>> = BinaryHeap::new();
    let mut seq = 0u64;
    for i in 0..n {
        heap.push(Reverse((arrivals[i], seq, EventKind::Arrive(i))));
        seq += 1;
    }
    // Arrived but not admitted (admission-queue overflow), FIFO.
    let mut waiting: VecDeque<usize> = VecDeque::new();
    // The bounded admission queue.
    let mut admitted: Vec<usize> = Vec::new();
    let mut idle = vec![true; workers];
    let mut rr = 0usize;
    let mut stats: Vec<Option<RequestStat>> = vec![None; n];
    let mut outcomes: Vec<Option<RequestOutcome>> = vec![None; n];
    let mut attempts = vec![0u32; n];
    let (mut shed, mut rejected, mut timed_out, mut serving_retries) = (0u64, 0u64, 0u64, 0u64);
    let mut makespan = 0u64;

    while let Some(Reverse((now, _, kind))) = heap.pop() {
        // Drain every event of this cycle before making scheduling
        // decisions: simultaneous arrivals must all be visible to the
        // batching/priority pop, and simultaneous worker-frees to the
        // round-robin arbiter.
        let mut pending = vec![kind];
        while let Some(&Reverse((c, _, _))) = heap.peek() {
            if c != now {
                break;
            }
            pending.push(heap.pop().expect("peeked event").0 .2);
        }
        for kind in pending {
            match kind {
                EventKind::Arrive(i) => {
                    // Admission control. Retries (attempts > 0) bypass
                    // it: the request is already accepted work.
                    if attempts[i] == 0
                        && pol.shed_batch_on_overload
                        && traces[i].priority == Priority::Batch
                        && admitted.len() + waiting.len() >= queue_depth
                    {
                        outcomes[i] = Some(RequestOutcome::Shed);
                        shed += 1;
                        if rec.is_enabled() {
                            let at = rec
                                .track(ADMISSION_PID, traces[i].id, &format!("req {}", traces[i].id));
                            rec.span(at, "shed", now, now + 1);
                        }
                    } else if attempts[i] == 0
                        && pol.waiting_depth > 0
                        && waiting.len() >= pol.waiting_depth
                    {
                        outcomes[i] = Some(RequestOutcome::Rejected);
                        rejected += 1;
                        if rec.is_enabled() {
                            let at = rec
                                .track(ADMISSION_PID, traces[i].id, &format!("req {}", traces[i].id));
                            rec.span(at, "rejected", now, now + 1);
                        }
                    } else {
                        waiting.push_back(i);
                    }
                }
                EventKind::WorkerFree(w) => idle[w] = true,
            }
        }
        let refill = |admitted: &mut Vec<usize>, waiting: &mut VecDeque<usize>| {
            while admitted.len() < queue_depth {
                match waiting.pop_front() {
                    Some(i) => admitted.push(i),
                    None => break,
                }
            }
        };
        refill(&mut admitted, &mut waiting);
        while !admitted.is_empty() {
            let Some(w) = grant_rr(&idle, &mut rr) else { break };
            // Queue pop order: priority class first, FIFO (arrival, id)
            // within a class; a batch groups the head with same-class
            // followers up to the batch cap.
            admitted.sort_by_key(|&i| (traces[i].priority, arrivals[i], traces[i].id));
            let class = traces[admitted[0]].priority;
            let take = admitted
                .iter()
                .take(batch_max)
                .take_while(|&&i| traces[i].priority == class)
                .count();
            let batch: Vec<usize> = admitted.drain(..take).collect();
            idle[w] = false;
            // Grant freed admission slots: backpressure releases now.
            refill(&mut admitted, &mut waiting);
            let wt = worker_tracks.get(w).copied().unwrap_or(Track { pid: WORKER_PID, tid: 0 });
            let finish = run_batch(
                &mut dram, now, &batch, traces, cfg.pe_lanes, &fault, rec, wt, &mut layer_marks,
            );
            if rec.is_enabled() {
                let ids: Vec<String> = batch.iter().map(|&i| traces[i].id.to_string()).collect();
                rec.span(wt, &format!("req {}", ids.join("+")), now, finish);
                for &i in &batch {
                    let t = &traces[i];
                    if now > arrivals[i] {
                        let at = rec.track(ADMISSION_PID, t.id, &format!("req {}", t.id));
                        rec.span(at, "wait", arrivals[i], now);
                    }
                }
            }
            for &i in &batch {
                let t = &traces[i];
                let deadline_ok =
                    pol.deadline_cycles == 0 || finish <= arrivals[i] + pol.deadline_cycles;
                if !deadline_ok && attempts[i] < pol.retry_budget {
                    // Deadline missed with budget left: the attempt's
                    // work is wasted and the request re-enters
                    // admission at this worker's finish cycle.
                    attempts[i] += 1;
                    serving_retries += 1;
                    heap.push(Reverse((finish, seq, EventKind::Arrive(i))));
                    seq += 1;
                    continue;
                }
                let outcome = if !deadline_ok {
                    timed_out += 1;
                    RequestOutcome::TimedOut
                } else if t.degraded() {
                    RequestOutcome::Degraded
                } else {
                    RequestOutcome::Completed
                };
                outcomes[i] = Some(outcome);
                stats[i] = Some(RequestStat {
                    id: t.id,
                    priority: t.priority,
                    outcome,
                    attempts: attempts[i] + 1,
                    queue_cycles: now - arrivals[i],
                    latency_cycles: finish - arrivals[i],
                    macs: t.macs(),
                });
            }
            makespan = makespan.max(finish);
            heap.push(Reverse((finish, seq, EventKind::WorkerFree(w))));
            seq += 1;
        }
    }

    if rec.is_enabled() {
        // Counter events: cumulative totals stamped at each layer's
        // completion cycle, in global timestamp order (batches complete
        // ahead of the event loop's clock, hence the sort).
        layer_marks.sort_unstable();
        let mut cum = LayerObs::default();
        for (ts, ri, li) in layer_marks {
            cum.merge(&traces[ri].layers[li].obs);
            rec.counter("macs", ts, cum.macs);
            rec.counter("cache_hits", ts, cum.cache_hits);
            rec.counter("decoded_words", ts, cum.decoded_words);
            rec.counter("skipped_subtensors", ts, cum.skipped_subtensors);
            rec.counter("skipped_spans", ts, cum.skipped_spans);
            rec.counter("skipped_rows", ts, cum.skipped_rows);
            rec.counter("skipped_values", ts, cum.skipped_values);
            // Integrity/fault series only exist when something was
            // detected — fault-free traces stay byte-identical lean.
            if cum.checksum_mismatches > 0 {
                rec.counter("checksum_mismatches", ts, cum.checksum_mismatches);
                rec.counter("retried_reads", ts, cum.retried_reads);
                rec.counter("recovered_reads", ts, cum.recovered_reads);
            }
            if cum.degraded_subtensors > 0 {
                rec.counter("degraded_subtensors", ts, cum.degraded_subtensors);
            }
            for (tag, &bits) in cum.packed_bits_by_codec.iter().enumerate() {
                if bits > 0 {
                    rec.counter(&format!("packed_bits_{}", codec_name(tag)), ts, bits);
                }
            }
        }
        // Per-bank DRAM occupancy: coalesced busy intervals whose sums
        // reconcile exactly with `bank_busy_cycles` (tests/obs.rs).
        if let Some(spans) = dram.busy_spans() {
            for s in spans {
                let track = Track { pid: DRAM_PID, tid: s.bank as u64 };
                rec.span(track, "busy", s.start, s.end);
            }
        }
    }

    // Every request resolves to exactly one outcome: served requests
    // carry full stats, shed/rejected ones a zero-latency stub (they
    // never ran — the sample filters skip them).
    let requests: Vec<RequestStat> = traces
        .iter()
        .enumerate()
        .filter_map(|(i, t)| {
            stats[i].clone().or_else(|| {
                outcomes[i].map(|o| RequestStat {
                    id: t.id,
                    priority: t.priority,
                    outcome: o,
                    attempts: attempts[i],
                    queue_cycles: 0,
                    latency_cycles: 0,
                    macs: 0,
                })
            })
        })
        .collect();
    let completed = requests
        .iter()
        .filter(|r| matches!(r.outcome, RequestOutcome::Completed | RequestOutcome::Degraded))
        .count() as u64;
    let degraded_requests =
        requests.iter().filter(|r| r.outcome == RequestOutcome::Degraded).count() as u64;
    let recovered_requests = requests
        .iter()
        .zip(traces)
        .filter(|(r, t)| r.outcome == RequestOutcome::Completed && t.recovered())
        .count() as u64;
    let mut iobs = LayerObs::default();
    for t in traces {
        for l in &t.layers {
            iobs.merge(&l.obs);
        }
    }
    let offered = n as u64;
    let admitted = offered - shed - rejected;
    // The admission conservation invariant the report advertises.
    assert_eq!(admitted + rejected + shed, offered, "admission conservation");
    assert_eq!(completed + timed_out, admitted, "service conservation");
    let total_macs = traces.iter().map(|t| t.macs()).sum();
    let macs_measured = !traces.is_empty() && traces.iter().all(|t| t.macs_measured());
    let total_feature_bytes = traces.iter().map(|t| t.feature_bytes).sum();
    let mut ck = FNV1A64_OFFSET;
    for t in traces {
        ck = fnv1a64_continue(ck, &t.id.to_le_bytes());
        ck = fnv1a64_continue(ck, &t.output_checksum.to_le_bytes());
    }
    SimServerReport {
        workers,
        queue_depth,
        batch: batch_max,
        n_banks: dram.timing().n_banks,
        pe_lanes: cfg.pe_lanes,
        completed,
        offered,
        admitted,
        rejected,
        shed,
        timed_out,
        serving_retries,
        degraded_requests,
        recovered_requests,
        verified_reads: iobs.verified_reads,
        checksum_mismatches: iobs.checksum_mismatches,
        retried_reads: iobs.retried_reads,
        recovered_reads: iobs.recovered_reads,
        degraded_subtensors: iobs.degraded_subtensors,
        makespan_cycles: makespan,
        requests,
        total_macs,
        macs_measured,
        total_feature_bytes,
        output_checksum: ck,
        dram_lines: dram.lines,
        dram_requests: dram.requests,
        row_hits: dram.row_hits,
        row_misses: dram.row_misses,
        transfer_cycles: dram.transfer_cycles,
        bank_busy_cycles: dram.bank_busy_cycles().to_vec(),
    }
}

/// Project a serving run into the unified metrics registry: report
/// aggregates as counters/gauges, per-request latency and queue waits
/// as log-bucketed histograms, and the functional pass's per-layer
/// observables (cache hits, skips, packed bits per codec) summed
/// across `traces`. Deterministic — [`MetricsRegistry::to_json`] of
/// the result is byte-stable across hosts and `--jobs`.
pub fn metrics_of(report: &SimServerReport, traces: &[RequestTrace]) -> MetricsRegistry {
    let mut m = MetricsRegistry::new();
    m.counter_add("completed", report.completed);
    m.counter_add("offered", report.offered);
    m.counter_add("admitted", report.admitted);
    m.counter_add("rejected", report.rejected);
    m.counter_add("shed", report.shed);
    m.counter_add("timed_out", report.timed_out);
    m.counter_add("serving_retries", report.serving_retries);
    m.counter_add("degraded_requests", report.degraded_requests);
    m.counter_add("recovered_requests", report.recovered_requests);
    m.counter_add("verified_reads", report.verified_reads);
    m.counter_add("checksum_mismatches", report.checksum_mismatches);
    m.counter_add("retried_reads", report.retried_reads);
    m.counter_add("recovered_reads", report.recovered_reads);
    m.counter_add("degraded_subtensors", report.degraded_subtensors);
    m.counter_add("makespan_cycles", report.makespan_cycles);
    m.counter_add("total_macs", report.total_macs);
    m.counter_add("feature_bytes", report.total_feature_bytes);
    m.counter_add("dram_lines", report.dram_lines);
    m.counter_add("dram_requests", report.dram_requests);
    m.counter_add("row_hits", report.row_hits);
    m.counter_add("row_misses", report.row_misses);
    m.counter_add("transfer_cycles", report.transfer_cycles);
    let mut obs = LayerObs::default();
    for t in traces {
        for l in &t.layers {
            obs.merge(&l.obs);
        }
    }
    m.counter_add("cache_hits", obs.cache_hits);
    m.counter_add("decoded_words", obs.decoded_words);
    m.counter_add("skipped_subtensors", obs.skipped_subtensors);
    m.counter_add("skipped_spans", obs.skipped_spans);
    m.counter_add("skipped_rows", obs.skipped_rows);
    m.counter_add("skipped_values", obs.skipped_values);
    for (tag, &bits) in obs.packed_bits_by_codec.iter().enumerate() {
        if bits > 0 {
            m.counter_add(&format!("packed_bits_{}", codec_name(tag)), bits);
        }
    }
    m.gauge_set("throughput_rpMcycle", report.throughput_rpmc());
    m.gauge_set("row_hit_rate", report.row_hit_rate());
    for r in &report.requests {
        m.observe("latency_cycles", r.latency_cycles);
        m.observe("queue_cycles", r.queue_cycles);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;

    fn tiny_net() -> Vec<(ConvLayer, Weights)> {
        let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let l2 = ConvLayer::new(1, 2, 16, 16, 8, 8);
        vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))]
    }

    fn sim_cfg() -> SimServerConfig {
        SimServerConfig::new(PipelineConfig::new(Platform::NvidiaSmallTile.hardware()))
    }

    #[test]
    fn serves_all_requests_and_report_is_reproducible() {
        let server = SimServer::new(sim_cfg(), tiny_net());
        let r1 = server.serve(server.synthetic_requests(6, 0.5, 7)).unwrap();
        assert_eq!(r1.completed, 6);
        assert!(r1.makespan_cycles > 0);
        assert!(r1.total_feature_bytes > 0);
        assert!(r1.throughput_rpmc() > 0.0);
        let r2 = server.serve(server.synthetic_requests(6, 0.5, 7)).unwrap();
        assert_eq!(r1.render(), r2.render(), "same seed ⇒ same bytes");
        let r3 = server.serve(server.synthetic_requests(6, 0.5, 8)).unwrap();
        assert_ne!(r1.output_checksum, r3.output_checksum, "seed must matter");
    }

    #[test]
    fn two_workers_beat_one_on_compute_heavy_batches() {
        let mut cfg = sim_cfg();
        cfg.pe_lanes = 4; // compute-dominant
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(8, 0.5, 3)).unwrap();
        let mut one = cfg;
        one.workers = 1;
        let m1 = simulate(&one, &traces).makespan_cycles;
        let rep2 = simulate(&cfg, &traces);
        assert!(
            rep2.makespan_cycles < m1,
            "2 workers {} vs 1 worker {m1}",
            rep2.makespan_cycles
        );
        // Bank occupancy conservation surfaces in the report.
        assert_eq!(rep2.bank_busy_cycles.iter().sum::<u64>(), rep2.transfer_cycles);
        assert_eq!(rep2.row_hits + rep2.row_misses, rep2.dram_lines);
    }

    #[test]
    fn fewer_banks_never_faster_when_dram_bound() {
        let mut cfg = sim_cfg();
        cfg.pe_lanes = 1 << 30; // compute ≈ 1 cycle/layer: DRAM-bound
        cfg.workers = 2;
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(6, 0.5, 5)).unwrap();
        let mut one_bank = cfg;
        one_bank.timing.n_banks = 1;
        let m1 = simulate(&one_bank, &traces).makespan_cycles;
        let m8 = simulate(&cfg, &traces).makespan_cycles;
        assert!(m1 >= m8, "1 bank {m1} vs 8 banks {m8}");
    }

    #[test]
    fn priority_classes_order_the_queue() {
        // Single worker, all arrivals at cycle 0, everything admitted:
        // every interactive request must complete before any batch-class
        // request does.
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        cfg.queue_depth = 16;
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(8, 0.5, 9)).unwrap();
        let rep = simulate(&cfg, &traces);
        let max_interactive = rep
            .requests
            .iter()
            .filter(|r| r.priority == Priority::Interactive)
            .map(|r| r.latency_cycles)
            .max()
            .unwrap();
        let min_batch = rep
            .requests
            .iter()
            .filter(|r| r.priority == Priority::Batch)
            .map(|r| r.latency_cycles)
            .min()
            .unwrap();
        assert!(max_interactive <= min_batch, "{max_interactive} vs {min_batch}");
    }

    #[test]
    fn batching_shares_one_completion_cycle() {
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        cfg.batch = 4;
        // ids 0..3 with id%4==3 in the batch class ⇒ use 3 requests so
        // all share one class and one grant.
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(3, 0.5, 11)).unwrap();
        let rep = simulate(&cfg, &traces);
        assert_eq!(rep.completed, 3);
        let l0 = rep.requests[0].latency_cycles;
        assert!(rep.requests.iter().all(|r| r.latency_cycles == l0));
        assert_eq!(rep.makespan_cycles, l0);
    }

    /// Traces carry raw MACs, so `simulate` honours a *different*
    /// `pe_lanes` than the functional pass ran with — config re-sweeps
    /// are honest without re-running the pipeline.
    #[test]
    fn pe_lanes_resweep_is_honest_without_new_functional_pass() {
        let cfg = sim_cfg();
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(2, 0.5, 17)).unwrap();
        let mut narrow = cfg;
        narrow.pe_lanes = 1; // compute-dominated
        let mut wide = cfg;
        wide.pe_lanes = 1 << 20; // compute ≈ 1 cycle
        let slow = simulate(&narrow, &traces).makespan_cycles;
        let fast = simulate(&wide, &traces).makespan_cycles;
        assert!(fast < slow, "wider PE array must simulate faster: {fast} vs {slow}");
    }

    #[test]
    fn report_percentiles_clamp_and_handle_empty_and_single() {
        let empty = simulate(&sim_cfg(), &[]);
        assert_eq!(empty.completed, 0);
        assert_eq!(empty.makespan_cycles, 0);
        for p in [-1.0, 0.5, 2.0, f64::NAN] {
            assert_eq!(empty.latency_percentile(p), 0);
        }
        assert!(empty.render().contains("completed=0"));

        let server = SimServer::new(sim_cfg(), tiny_net());
        let rep = server.serve(server.synthetic_requests(1, 0.5, 13)).unwrap();
        let only = rep.requests[0].latency_cycles;
        assert!(only > 0);
        for p in [-1.0, 0.0, 0.5, 1.0, 2.0, f64::NAN] {
            assert_eq!(rep.latency_percentile(p), only, "p={p}");
        }
    }

    /// The functional pass prices layers with kernel-measured MACs —
    /// on a 50%-dense input that must be strictly less than the analytic
    /// estimate, and the report says which source it used.
    #[test]
    fn traces_carry_measured_macs_and_report_labels_source() {
        let net = tiny_net();
        let analytic: u64 = net.iter().map(|(l, _)| l.macs()).sum();
        let server = SimServer::new(sim_cfg(), net);
        let traces =
            server.functional_pass(&server.synthetic_requests(2, 0.5, 21)).unwrap();
        for t in &traces {
            assert!(t.macs_measured(), "pipeline always runs the GEMM backend");
            assert!(t.macs() > 0);
            assert!(t.macs() < analytic, "{} vs analytic {analytic}", t.macs());
        }
        let rep = simulate(&sim_cfg(), &traces);
        assert!(rep.macs_measured);
        assert_eq!(rep.total_macs, traces.iter().map(|t| t.macs()).sum::<u64>());
        assert!(rep.render().contains("source=measured-kernel"));
        for r in &rep.requests {
            assert!(r.macs > 0);
        }
    }

    #[test]
    fn metrics_adapter_reflects_report_and_traces() {
        let server = SimServer::new(sim_cfg(), tiny_net());
        let traces = server.functional_pass(&server.synthetic_requests(4, 0.5, 7)).unwrap();
        let rep = simulate(&sim_cfg(), &traces);
        let m = metrics_of(&rep, &traces);
        assert_eq!(m.counter("completed"), Some(rep.completed));
        assert_eq!(m.counter("total_macs"), Some(rep.total_macs));
        assert_eq!(m.counter("transfer_cycles"), Some(rep.transfer_cycles));
        let lat = m.histogram("latency_cycles").expect("latency histogram");
        assert_eq!(lat.count() as usize, rep.requests.len());
        // The histogram quantile bounds the exact sorted percentile.
        let exact = rep.latency_percentile(0.5);
        let qh = lat.quantile(0.5);
        assert!(qh <= exact && exact <= qh + (qh >> 3), "{qh} vs {exact}");
        // Functional-pass observables made it through the traces.
        assert!(m.counter("decoded_words").unwrap_or(0) > 0);
        assert!(m.counter("macs").is_none(), "per-layer macs only exist as trace counters");
        let packed: u64 = (0..4)
            .filter_map(|tag| m.counter(&format!("packed_bits_{}", codec_name(tag))))
            .sum();
        assert!(packed > 0, "some codec packed bits must be accounted");
        // JSON dump is deterministic for the same inputs.
        assert_eq!(m.to_json(), metrics_of(&rep, &traces).to_json());
    }

    #[test]
    fn arrival_gap_reduces_queueing() {
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(4, 0.5, 15)).unwrap();
        let closed = simulate(&cfg, &traces);
        // Space the same requests far apart: queue waits collapse.
        let mut spaced = traces.clone();
        let gap = closed.makespan_cycles + 1;
        for (i, t) in spaced.iter_mut().enumerate() {
            t.arrival_cycle = i as u64 * gap;
        }
        let open = simulate(&cfg, &spaced);
        assert_eq!(open.queue_percentile(1.0), 0, "no contention ⇒ no waiting");
        assert!(open.queue_percentile(1.0) <= closed.queue_percentile(1.0));
    }

    #[test]
    fn shedding_drops_batch_first_and_conserves_offered() {
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        cfg.queue_depth = 1;
        cfg.serving.shed_batch_on_overload = true;
        let server = SimServer::new(cfg, tiny_net());
        // 8 simultaneous arrivals; ids 3 and 7 are Batch class.
        let traces =
            server.functional_pass(&server.synthetic_requests(8, 0.5, 19)).unwrap();
        let rep = simulate(&cfg, &traces);
        assert!(rep.conservation_holds());
        assert_eq!(rep.offered, 8);
        assert_eq!(rep.shed, 2, "both batch-class arrivals shed under overload");
        assert_eq!(rep.completed, 6);
        assert_eq!(rep.timed_out, 0);
        for r in &rep.requests {
            if r.outcome == RequestOutcome::Shed {
                assert_eq!(r.priority, Priority::Batch, "interactive is never shed");
                assert_eq!(r.latency_cycles, 0);
                assert_eq!(r.attempts, 0);
            }
        }
        assert!(rep.render().contains("shed=2"));
    }

    #[test]
    fn bounded_waiting_room_rejects_overflow_and_conserves() {
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        cfg.queue_depth = 1;
        cfg.serving.waiting_depth = 2;
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(6, 0.5, 23)).unwrap();
        let rep = simulate(&cfg, &traces);
        assert!(rep.conservation_holds());
        assert_eq!(rep.offered, 6);
        assert_eq!(rep.rejected, 4, "waiting room of 2 rejects the later arrivals");
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.admitted + rep.rejected + rep.shed, rep.offered);
        // Rejected requests contribute no latency sample.
        assert_eq!(rep.latency_samples().len(), 2);
    }

    #[test]
    fn deadlines_and_retry_budgets_produce_timeouts() {
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        cfg.serving.deadline_cycles = 1; // unmeetable
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(3, 0.5, 27)).unwrap();
        let rep = simulate(&cfg, &traces);
        assert!(rep.conservation_holds());
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.timed_out, 3);
        assert_eq!(rep.serving_retries, 0);
        assert!(rep.requests.iter().all(|r| r.outcome == RequestOutcome::TimedOut));
        assert!(rep.latency_percentile(1.0) > 0, "timed-out requests still ran");

        // A retry budget re-serves each request before giving up.
        let mut retry_cfg = cfg;
        retry_cfg.serving.retry_budget = 2;
        let rep2 = simulate(&retry_cfg, &traces);
        assert!(rep2.conservation_holds());
        assert_eq!(rep2.timed_out, 3);
        assert_eq!(rep2.serving_retries, 6, "every request spends its whole budget");
        assert!(rep2.requests.iter().all(|r| r.attempts == 3));
        assert!(rep2.makespan_cycles > rep.makespan_cycles, "retries burn simulated time");

        // A generous deadline completes everything first try.
        let mut loose = cfg;
        loose.serving.deadline_cycles = u64::MAX / 2;
        let rep3 = simulate(&loose, &traces);
        assert_eq!(rep3.completed, 3);
        assert_eq!(rep3.timed_out, 0);
    }

    #[test]
    fn arrival_bursts_collapse_gaps_and_stay_deterministic() {
        let mut cfg = sim_cfg();
        cfg.workers = 1;
        cfg.arrival_gap = 1_000_000; // spaced: no queueing at all
        let server = SimServer::new(cfg, tiny_net());
        let traces =
            server.functional_pass(&server.synthetic_requests(4, 0.5, 31)).unwrap();
        let calm = simulate(&cfg, &traces);
        assert_eq!(calm.queue_percentile(1.0), 0, "spaced arrivals never wait");
        let mut bursty = cfg;
        bursty.pipeline.fault =
            Some(FaultPlan { arrival_burst_rate: 1.0, ..FaultPlan::default() });
        let b1 = simulate(&bursty, &traces);
        assert!(b1.queue_percentile(1.0) > 0, "burst collapse forces queueing");
        assert_eq!(b1.completed, 4);
        assert!(b1.conservation_holds());
        let b2 = simulate(&bursty, &traces);
        assert_eq!(b1.render(), b2.render(), "fault injection is deterministic");
    }

    /// THE recovery-soundness criterion: with checksums + retries on
    /// and only transient corruption, zero requests degrade and the
    /// serving output checksum is bit-identical to the fault-free run
    /// at the same seed; persistent corruption degrades gracefully and
    /// is counted exactly.
    #[test]
    fn corruption_recovers_transparently_or_degrades_gracefully() {
        let clean_cfg = sim_cfg();
        let server = SimServer::new(clean_cfg, tiny_net());
        let requests = server.synthetic_requests(4, 0.5, 33);
        let clean = simulate(&clean_cfg, &server.functional_pass(&requests).unwrap());

        // Transient-only corruption, defended: detected, healed,
        // bit-exact — silently correct.
        let mut defended = clean_cfg;
        defended.pipeline.integrity = Some(crate::layout::IntegrityPolicy::default());
        defended.pipeline.fault = Some(FaultPlan {
            seed: 17,
            payload_flip_rate: 0.4,
            persistent_fraction: 0.0,
            ..FaultPlan::default()
        });
        let dserver = SimServer::new(defended, tiny_net());
        let rep = simulate(&defended, &dserver.functional_pass(&requests).unwrap());
        assert!(rep.checksum_mismatches > 0, "rate 0.4 must corrupt something");
        assert!(rep.recovered_reads > 0);
        assert_eq!(rep.degraded_subtensors, 0, "transient faults always heal");
        assert_eq!(rep.degraded_requests, 0);
        assert!(rep.recovered_requests > 0);
        assert_eq!(
            rep.output_checksum, clean.output_checksum,
            "zero degraded ⇒ serving output bit-identical to the fault-free run"
        );

        // Persistent corruption exhausts the read-retry budget:
        // requests complete flagged degraded, with exact counters.
        let mut lossy = defended;
        lossy.pipeline.fault = Some(FaultPlan {
            seed: 17,
            payload_flip_rate: 0.4,
            persistent_fraction: 1.0,
            ..FaultPlan::default()
        });
        let lserver = SimServer::new(lossy, tiny_net());
        let lrep = simulate(&lossy, &lserver.functional_pass(&requests).unwrap());
        assert!(lrep.degraded_subtensors > 0);
        assert!(lrep.degraded_requests > 0);
        assert_eq!(lrep.completed, 4, "degraded requests still complete");
        assert!(lrep.conservation_holds());
        assert_ne!(lrep.output_checksum, clean.output_checksum);
        assert!(lrep.requests.iter().any(|r| r.outcome == RequestOutcome::Degraded));
    }
}
