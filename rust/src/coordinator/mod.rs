//! The accelerator coordinator: GrateTile's runtime integration point
//! (paper §I "fetch and decompress sub-tensors on-the-fly in a tiled
//! processing manner", §III-A).
//!
//! [`pipeline`] executes CNN layers tile-by-tile over GrateTile-packed
//! feature maps with a *double-buffered prefetch thread*: while the
//! compute lane convolves tile `i`, the fetch lane is already reading
//! and decompressing the sub-tensors of tile `i+1` — the overlap a real
//! memory controller provides. Multi-layer runs are store-resident
//! ([`crate::store::TensorStore`]): each layer's output streams
//! compressed into the store tile-by-tile and becomes the next layer's
//! packed input, so no dense intermediate map ever materialises and the
//! DRAM timing model sees real arena-assigned addresses.
//!
//! [`server`] wraps the pipeline in a request-serving leader/worker
//! topology (bounded queue, N worker threads, latency percentiles) for
//! the `serve` example — host wall-clock, nondeterministic timings.
//!
//! [`simserver`] is the deterministic counterpart: a discrete-event,
//! virtual-clock serving simulator that replays the functional pass's
//! per-layer traces through one shared, bank-contended DRAM and reports
//! in *simulated cycles* — byte-stable for a given seed regardless of
//! host load or `--jobs` (the golden-fixture serving surface).

pub mod conv;
pub mod metrics;
pub mod pipeline;
pub mod server;
pub mod simserver;

pub use conv::{direct_conv_relu, Weights};
pub use metrics::{LayerObs, PipelineMetrics};
pub use pipeline::{LayerRunner, LayerTrace, PipelineConfig};
pub use server::{Server, ServerConfig, ServerReport};
pub use simserver::{
    metrics_of, simulate, simulate_traced, Priority, RequestOutcome, ServingPolicy, SimRequest,
    SimServer, SimServerConfig, SimServerReport,
};
