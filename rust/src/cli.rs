//! Dependency-free CLI argument parsing (no `clap` in the offline
//! build environment).

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and `--key value`
/// / `--flag` options. Options live in a `BTreeMap` so any future
/// iteration (help text, option echoing) is deterministic.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Cli {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        if let Some(cmd) = it.next() {
            cli.command = cmd;
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare flag.
                if let Some((k, v)) = key.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    cli.options.insert(key.to_string(), v);
                } else {
                    cli.flags.push(key.to_string());
                }
            } else {
                cli.positional.push(arg);
            }
        }
        cli
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Typed option without a default: `None` when absent or unparsable
    /// (e.g. `--jobs 8` for the parallel suite engine).
    pub fn opt_parsed<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.opt(key).and_then(|v| v.parse().ok())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let c = parse("table3 extra --scheme bitmask --seed=42 --markdown");
        assert_eq!(c.command, "table3");
        assert_eq!(c.opt("scheme"), Some("bitmask"));
        assert_eq!(c.opt("seed"), Some("42"));
        assert!(c.has_flag("markdown"));
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn greedy_value_binding() {
        // A bare token after `--key` binds as its value (clap-style).
        let c = parse("cmd --markdown extra");
        assert_eq!(c.opt("markdown"), Some("extra"));
        assert!(c.positional.is_empty());
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let c = parse("sweep --n 16 --density 0.4");
        assert_eq!(c.opt_usize("n", 8), 16);
        assert_eq!(c.opt_f64("density", 0.3), 0.4);
        assert_eq!(c.opt_usize("missing", 7), 7);
        assert_eq!(c.opt_or("scheme", "bitmask"), "bitmask");
    }

    #[test]
    fn opt_parsed_typed_access() {
        let c = parse("table3 --jobs 8 --density 0.4 --bad x");
        assert_eq!(c.opt_parsed::<usize>("jobs"), Some(8));
        assert_eq!(c.opt_parsed::<f64>("density"), Some(0.4));
        assert_eq!(c.opt_parsed::<usize>("bad"), None); // unparsable
        assert_eq!(c.opt_parsed::<usize>("missing"), None);
    }

    #[test]
    fn empty_args() {
        let c = Cli::parse(std::iter::empty());
        assert_eq!(c.command, "");
    }

    #[test]
    fn flag_before_value_option() {
        let c = parse("x --verbose --k 3");
        assert!(c.has_flag("verbose"));
        assert_eq!(c.opt("k"), Some("3"));
    }
}
