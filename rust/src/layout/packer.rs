//! The packer: compress every sub-tensor of a division and assign
//! storage addresses (paper §III-C).
//!
//! Aligned modes start every sub-tensor on a cache-line boundary (the
//! paper's "GrateTile only stores these subtensors in aligned
//! addresses"); the Uniform 1×1×8 baseline packs word-compactly (Table
//! II footnote a). Blocks are laid out in raster order — (block_y,
//! block_x, channel-group) — with the block pointer addressing the first
//! sub-tensor, exactly the two-step access structure of Fig. 7b.
//!
//! ## The plan/execute engine (§Perf, DESIGN.md §Packing engine)
//!
//! [`Packer::pack`] is a two-phase engine:
//!
//! * **Plan** — one fused stats pass per sub-tensor (streamed straight
//!   off the feature map, no block gather) feeds every codec's exact
//!   closed-form size ([`Compressor::sizes_from_stats`]); a serial
//!   O(sub-tensors) prefix walk then assigns every final address and
//!   emits the Fig. 7 records. No compression has happened yet, and no
//!   block has been scanned more than once.
//! * **Execute** (`with_payload` only) — the payload buffer is
//!   preallocated at its exact final size and split into disjoint
//!   per-block slices; sub-tensors compress **in parallel**
//!   ([`crate::util::parallel::par_for_each_init`]) directly into their
//!   planned slices. Output is bit-identical for every worker count,
//!   and identical to the seed packer.
//!
//! [`Packer::pack_reference`] keeps the seed's serial
//! gather → size → compress → cursor walk as the property-tested oracle
//! (`tests/property.rs::prop_engine_matches_seed_packer`,
//! `benches/perf_pack.rs` asserts both bit-exactness and the speedup).

use super::metadata::{record_bits_for, BlockRecord, MetadataTable};
use crate::compress::{CodecPolicy, Compressor, DistinctTracker, Registry, Scheme, StatsAcc};
use crate::config::hardware::Hardware;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, SubTensorRef};
use crate::util::parallel::{par_for_each_init, par_map_init};
use crate::util::round_up;

/// Below this many feature-map elements the engine stays on one thread:
/// the map packs in well under a millisecond and worker spawn would
/// dominate (suite sweeps also already parallelise across layers).
const PAR_MIN_ELEMS: usize = 1 << 16;

/// A fully packed feature map: per-sub-tensor compressed sizes and
/// addresses, block metadata, and (optionally) the compressed payload.
#[derive(Debug, Clone)]
pub struct PackedFeatureMap {
    pub division: Division,
    /// Codec policy the map was packed under.
    pub policy: CodecPolicy,
    /// Per-sub-tensor codec tags (registry ids), indexed by
    /// `division.linear(ref)`. Empty under `Fixed` (uniform codec);
    /// in adaptive mode the same tags also sit in every
    /// [`BlockRecord::codec_tags`] slot (the Fig. 7 on-format home).
    pub tags: Vec<u8>,
    /// Compressed size in words, indexed by `division.linear(ref)`.
    pub sizes_words: Vec<u32>,
    /// Idealised compressed size in bits (no word padding), same
    /// indexing; what the compact baseline pays (§IV-B(2)).
    pub sizes_bits: Vec<u32>,
    /// Start word address, same indexing.
    pub addr_words: Vec<u64>,
    /// Block metadata table (Fig. 7).
    pub metadata: MetadataTable,
    /// Compressed payload words, addressed by `addr_words` (present only
    /// when packed with `with_payload`).
    pub payload: Option<Vec<u16>>,
    /// Per-sub-tensor integrity checksums (FNV-1a-64 over the compressed
    /// words as little-endian bytes), same indexing as `sizes_words`.
    /// Content-addressed — independent of `addr_words` — so rebasing a
    /// sub-tensor (store import/export, segment sources) carries its
    /// checksum unchanged. Populated only when the payload was
    /// materialised; empty for sizes-only packs and for maps decoded
    /// from pre-v3 containers (the fetcher then skips verification).
    pub checksums: Vec<u64>,
    /// Total storage footprint in words (end of the last sub-tensor,
    /// line-rounded for aligned modes).
    pub total_words: u64,
    /// Line geometry the addresses were assigned under (crate-visible so
    /// the store's streaming writer and the container reader can
    /// assemble layouts without re-packing).
    pub(crate) words_per_line: usize,
}

impl PackedFeatureMap {
    /// Fetch cost by linear sub-tensor index — the single encoding of
    /// the compact-vs-line-rounded cost rule ([`Self::fetch_bits`] and
    /// [`Self::fetch_bits_grid`] both go through here).
    #[inline]
    fn fetch_bits_at(&self, li: usize) -> u64 {
        if self.division.compact {
            self.sizes_bits[li] as u64
        } else {
            let words = self.sizes_words[li] as usize;
            (round_up(words, self.words_per_line) * 16) as u64
        }
    }

    /// Fetch cost of one sub-tensor in *bits*: aligned sub-tensors move
    /// whole cache lines; compact ones (Uniform 1×1×8) move the exact
    /// compressed bits — the idealised upper bound of §IV-B(2).
    pub fn fetch_bits(&self, r: SubTensorRef) -> u64 {
        self.fetch_bits_at(self.division.linear(r))
    }

    /// Fetch cost in words (line-rounded for aligned modes).
    pub fn fetch_words(&self, r: SubTensorRef) -> u64 {
        self.fetch_bits(r).div_ceil(16)
    }

    /// Per-sub-tensor fetch costs in bits, indexed by
    /// [`Division::linear`] — the pricer's input grid, available without
    /// materializing any payload. Entry `i` equals `fetch_bits` of the
    /// sub-tensor with linear index `i`.
    pub fn fetch_bits_grid(&self) -> Vec<u64> {
        (0..self.division.n_subtensors())
            .map(|li| self.fetch_bits_at(li))
            .collect()
    }

    /// Compressed size in words of one sub-tensor.
    pub fn size_words(&self, r: SubTensorRef) -> u32 {
        self.sizes_words[self.division.linear(r)]
    }

    /// Storage footprint in cache lines.
    pub fn total_lines(&self) -> u64 {
        (self.total_words as usize).div_ceil(self.words_per_line) as u64
    }

    /// Line geometry the map was packed under.
    pub fn line_words(&self) -> usize {
        self.words_per_line
    }

    /// Compression ratio vs. the dense map (< 1 is smaller).
    pub fn compression_ratio(&self) -> f64 {
        let dense = (self.division.fm_h * self.division.fm_w * self.division.fm_c) as f64;
        self.total_words as f64 / dense
    }

    /// Codec of one sub-tensor by linear index (the map's uniform codec
    /// under `Fixed`, the stored 2-bit tag under `Adaptive`).
    pub fn scheme_of(&self, li: usize) -> Scheme {
        match self.policy {
            CodecPolicy::Fixed(s) => s,
            CodecPolicy::Adaptive => Registry::global().entries()[self.tags[li] as usize].scheme,
        }
    }

    /// The registry compressor for one sub-tensor.
    pub fn compressor_of(&self, li: usize) -> &'static dyn Compressor {
        match self.policy {
            CodecPolicy::Fixed(s) => Registry::global().compressor(s),
            CodecPolicy::Adaptive => Registry::global().compressor_of_tag(self.tags[li]),
        }
    }

    /// Metadata record width in bits, codec tags included — what one
    /// touched block costs to read or write (`metadata.bits_per_record`,
    /// which the packer/writer set via
    /// [`super::metadata::record_bits_for`]).
    pub fn record_bits(&self) -> usize {
        self.metadata.bits_per_record
    }

    /// Total metadata bits of the map (records × tag-aware width) — the
    /// producer-side index cost the analytic model and the streaming
    /// writer both charge.
    pub fn meta_total_bits(&self) -> u64 {
        self.metadata.total_bits()
    }

    /// Stored payload bits attributed to each codec tag, in registry
    /// order (bitmask, zrlc, dictionary, raw). Compact maps pay exact
    /// compressed bits, aligned modes pay whole stored words — the same
    /// storage-cost rule as [`Self::total_words`], split by the codec
    /// that produced each sub-tensor. This is the per-codec breakdown
    /// the observability layer emits as `packed_bits_<codec>` counters.
    pub fn payload_bits_by_tag(&self) -> [u64; 4] {
        let fixed_tag = match self.policy {
            CodecPolicy::Fixed(s) => Some(Registry::global().tag_of(s)),
            CodecPolicy::Adaptive => None,
        };
        let mut out = [0u64; 4];
        for li in 0..self.division.n_subtensors() {
            let bits = if self.division.compact {
                self.sizes_bits[li] as u64
            } else {
                self.sizes_words[li] as u64 * 16
            };
            let tag = fixed_tag.unwrap_or_else(|| self.tags[li]);
            out[(tag as usize) & 3] += bits;
        }
        out
    }

    /// Human-readable codec description: the codec name for fixed maps,
    /// `auto(name:count,...)` with the per-codec sub-tensor histogram
    /// for adaptive ones.
    pub fn codec_summary(&self) -> String {
        match self.policy {
            CodecPolicy::Fixed(s) => s.name().to_string(),
            CodecPolicy::Adaptive => {
                let reg = Registry::global();
                let mut counts = vec![0usize; reg.entries().len()];
                for &t in &self.tags {
                    counts[t as usize] += 1;
                }
                let parts: Vec<String> = reg
                    .entries()
                    .iter()
                    .zip(&counts)
                    .filter(|(_, &c)| c > 0)
                    .map(|(e, c)| format!("{}:{c}", e.name))
                    .collect();
                format!("auto({})", parts.join(","))
            }
        }
    }
}

/// Sub-tensor geometry by linear index: `(y seg, x seg, c0, depth)`.
#[inline]
fn geom(
    division: &Division,
    li: usize,
) -> (crate::tiling::division::Seg, crate::tiling::division::Seg, usize, usize) {
    let r = division.subtensor_coords(li);
    (
        division.ys[r.iy],
        division.xs[r.ix],
        r.icg * division.cd,
        division.cg_depth(r.icg),
    )
}

/// Per-worker scratch for the plan phase: the distinct-value tracker
/// (dictionary codec only), a gather buffer for the stats-less
/// fallback, and the per-codec size buffer adaptive selection reuses
/// across sub-tensors.
struct PlanScratch {
    tracker: Option<DistinctTracker>,
    block: Vec<f32>,
    sizes: Vec<(usize, usize)>,
}

/// Plan-phase output: exact per-sub-tensor sizes, plus the winning
/// codec tag per sub-tensor in adaptive mode (empty otherwise).
struct SizePlan {
    words: Vec<u32>,
    bits: Vec<u32>,
    tags: Vec<u8>,
}

/// One metadata block's payload extent and its sub-tensors
/// `(linear index, absolute word address)` in raster order — the unit
/// of the parallel execute phase.
struct BlockSpan {
    start: u64,
    end: u64,
    subs: Vec<(usize, u64)>,
}

/// Address-assignment output: the full layout, ready for execution.
struct AddressPlan {
    addr_words: Vec<u64>,
    records: Vec<BlockRecord>,
    spans: Vec<BlockSpan>,
    /// Line-rounded storage footprint (aligned modes).
    total_words: u64,
    /// End of the last written word — the *unpadded* cursor. The seed
    /// packer's payload vec ends exactly here (its `resize` only ever
    /// reaches the last write), so the engine's payload must too for
    /// byte-equality; `total_words` only rounds the *accounted*
    /// footprint up to a whole line.
    payload_words: u64,
}

/// Packs feature maps under a division + codec policy.
pub struct Packer {
    pub hw: Hardware,
    pub policy: CodecPolicy,
}

impl Packer {
    pub fn new(hw: Hardware, policy: impl Into<CodecPolicy>) -> Self {
        Self { hw, policy: policy.into() }
    }

    /// Pack `fm` under `division` with the plan/execute engine.
    /// `with_payload` materialises the compressed byte stream (needed by
    /// the fetch/decompress path; the bandwidth simulator only needs
    /// sizes). Bit-exact with [`Packer::pack_reference`] and
    /// deterministic for every worker count. Under
    /// [`CodecPolicy::Adaptive`] the plan pass sizes every registered
    /// codec from the same fused stats and keeps the per-sub-tensor
    /// winner — selection is free on top of the existing scan.
    pub fn pack(
        &self,
        fm: &FeatureMap,
        division: &Division,
        with_payload: bool,
    ) -> PackedFeatureMap {
        assert_eq!(
            (fm.h, fm.w, fm.c),
            (division.fm_h, division.fm_w, division.fm_c),
            "division was built for a different map shape"
        );
        let parallel = fm.words() >= PAR_MIN_ELEMS;
        let plan = plan_sizes(fm, division, self.policy, parallel);
        let wpl = self.hw.words_per_line;
        let layout = assign_addresses(division, &plan.words, &plan.tags, wpl, with_payload);
        let payload = with_payload.then(|| {
            execute_payload(fm, division, self.policy, &plan, &layout, parallel)
        });
        let checksums = match &payload {
            Some(p) => payload_checksums(p, &layout.addr_words, &plan.words),
            None => Vec::new(),
        };
        PackedFeatureMap {
            division: division.clone(),
            policy: self.policy,
            tags: plan.tags,
            sizes_words: plan.words,
            sizes_bits: plan.bits,
            addr_words: layout.addr_words,
            metadata: MetadataTable {
                records: layout.records,
                bits_per_record: record_bits_for(division, self.policy),
            },
            payload,
            checksums,
            total_words: layout.total_words,
            words_per_line: wpl,
        }
    }

    /// The seed packer, kept verbatim as the engine's oracle: serial
    /// raster walk, per-block gather, per-codec sizing scans, growing
    /// cursor. Property tests and `benches/perf_pack.rs` hold
    /// [`Packer::pack`] bit-exact to (and faster than) this. In
    /// adaptive mode the oracle selects from the *real* codecs'
    /// `compressed_sizes` — an independent path from the engine's
    /// stats-derived sizing, so the property tests also pin the two
    /// sizing substrates against each other.
    pub fn pack_reference(
        &self,
        fm: &FeatureMap,
        division: &Division,
        with_payload: bool,
    ) -> PackedFeatureMap {
        assert_eq!(
            (fm.h, fm.w, fm.c),
            (division.fm_h, division.fm_w, division.fm_c),
            "division was built for a different map shape"
        );
        let reg = Registry::global();
        let adaptive = self.policy.is_adaptive();
        let fixed_codec = match self.policy {
            CodecPolicy::Fixed(s) => Some(reg.compressor(s)),
            CodecPolicy::Adaptive => None,
        };
        let n = division.n_subtensors();
        let mut sizes_words = vec![0u32; n];
        let mut sizes_bits = vec![0u32; n];
        let mut tags: Vec<u8> = if adaptive { vec![0; n] } else { Vec::new() };
        let mut addr_words = vec![0u64; n];
        let mut payload: Option<Vec<u16>> = if with_payload { Some(Vec::new()) } else { None };
        let mut records: Vec<BlockRecord> = Vec::with_capacity(division.n_blocks());

        let wpl = self.hw.words_per_line;
        let mut cursor: u64 = 0;
        let mut block = Vec::with_capacity(64);
        let mut sizes_scratch: Vec<(usize, usize)> = Vec::new();

        // Raster order over metadata blocks; sub-tensors inside a block
        // in (y, x) raster order — the Fig. 7b layout.
        for by in 0..division.n_blocks_y {
            let yr = division.y_segs_of_block(by);
            for bx in 0..division.n_blocks_x {
                let xr = division.x_segs_of_block(bx);
                for icg in 0..division.n_cgroups {
                    // Block start: line-aligned pointer (Fig. 7).
                    if !division.compact {
                        cursor = round_up(cursor as usize, wpl) as u64;
                    }
                    let pointer_words = cursor;
                    let mut rec_sizes = Vec::with_capacity(yr.len() * xr.len());
                    let mut rec_tags = Vec::with_capacity(if adaptive {
                        yr.len() * xr.len()
                    } else {
                        0
                    });
                    for iy in yr.clone() {
                        for ix in xr.clone() {
                            let r = SubTensorRef { iy, ix, icg };
                            let sy = division.ys[iy];
                            let sx = division.xs[ix];
                            let cd = division.cg_depth(icg);
                            fm.extract_block_into(
                                sy.start,
                                sx.start,
                                icg * division.cd,
                                sy.len,
                                sx.len,
                                cd,
                                &mut block,
                            );
                            let li = division.linear(r);
                            let codec: &dyn Compressor = match fixed_codec {
                                Some(c) => c,
                                None => {
                                    // Oracle selection: every registered
                                    // codec's real sizes, then the shared
                                    // deterministic min rule.
                                    sizes_scratch.clear();
                                    sizes_scratch.extend(
                                        reg.entries()
                                            .iter()
                                            .map(|e| e.codec.compressed_sizes(&block)),
                                    );
                                    let tag = reg.select(&sizes_scratch, division.compact);
                                    tags[li] = tag;
                                    rec_tags.push(tag);
                                    reg.compressor_of_tag(tag)
                                }
                            };
                            sizes_bits[li] = codec.compressed_bits(&block) as u32;
                            if let Some(p) = &mut payload {
                                let comp = codec.compress(&block);
                                sizes_words[li] = comp.words.len() as u32;
                                if !division.compact {
                                    cursor = round_up(cursor as usize, wpl) as u64;
                                }
                                addr_words[li] = cursor;
                                // Materialise at the assigned address.
                                let end = cursor as usize + comp.words.len();
                                if p.len() < end {
                                    p.resize(end, 0);
                                }
                                p[cursor as usize..end].copy_from_slice(&comp.words);
                                cursor += comp.words.len() as u64;
                            } else {
                                let size = codec.compressed_words(&block) as u32;
                                sizes_words[li] = size;
                                if !division.compact {
                                    cursor = round_up(cursor as usize, wpl) as u64;
                                }
                                addr_words[li] = cursor;
                                cursor += size as u64;
                            }
                            rec_sizes.push(sizes_words[li]);
                        }
                    }
                    records.push(BlockRecord {
                        pointer_words,
                        sizes_words: rec_sizes,
                        codec_tags: rec_tags,
                    });
                }
            }
        }

        let total_words = if division.compact { cursor } else { round_up(cursor as usize, wpl) as u64 };
        let checksums = match &payload {
            Some(p) => payload_checksums(p, &addr_words, &sizes_words),
            None => Vec::new(),
        };
        PackedFeatureMap {
            division: division.clone(),
            policy: self.policy,
            tags,
            sizes_words,
            sizes_bits,
            addr_words,
            metadata: MetadataTable {
                records,
                bits_per_record: record_bits_for(division, self.policy),
            },
            payload,
            checksums,
            total_words,
            words_per_line: wpl,
        }
    }
}

/// Per-sub-tensor FNV-1a-64 checksums over the packed payload slices —
/// the integrity table `.grate` v3 stores and the fetcher verifies on
/// every payload read. A serial O(payload) post-pass (one hash per
/// stored word, no re-compression), so it rides the pack for free at
/// table precision.
fn payload_checksums(payload: &[u16], addr_words: &[u64], sizes_words: &[u32]) -> Vec<u64> {
    addr_words
        .iter()
        .zip(sizes_words)
        .map(|(&a, &s)| {
            crate::store::container::fnv1a64_words(&payload[a as usize..a as usize + s as usize])
        })
        .collect()
}

/// Plan phase: exact `(words, bits)` for every sub-tensor from one fused
/// stats pass each, streamed row-by-row straight off the feature map —
/// no gather, no per-codec re-scan. Under [`CodecPolicy::Adaptive`] the
/// same single pass tracks the union of every registered codec's stats
/// needs (`Registry::max_stats_dict_cap`), every codec's closed-form
/// size is evaluated from it, and the winner's tag is kept — selection
/// costs four formula evaluations per sub-tensor, not extra scans.
fn plan_sizes(
    fm: &FeatureMap,
    division: &Division,
    policy: CodecPolicy,
    parallel: bool,
) -> SizePlan {
    let reg = Registry::global();
    let n = division.n_subtensors();
    let (dict_cap, fixed_codec) = match policy {
        CodecPolicy::Fixed(s) => {
            let codec = reg.compressor(s);
            (codec.stats_dict_cap(), Some(codec))
        }
        CodecPolicy::Adaptive => (reg.max_stats_dict_cap(), None),
    };
    let data = fm.as_slice();

    let size_one = |st: &mut PlanScratch, li: usize| -> (u32, u32, u8) {
        let (sy, sx, c0, cdep) = geom(division, li);
        let mut acc = StatsAcc::new(dict_cap, st.tracker.as_mut());
        for y in sy.start..sy.end() {
            let row = y * fm.w;
            for x in sx.start..sx.end() {
                let px = (row + x) * fm.c + c0;
                acc.feed(&data[px..px + cdep]);
            }
        }
        let stats = acc.finish();
        match fixed_codec {
            Some(codec) => match codec.sizes_from_stats(&stats) {
                Some((w, b)) => (w as u32, b as u32, 0),
                None => {
                    // Stats-blind codec: gather once, size in one scan.
                    fm.extract_block_into(
                        sy.start, sx.start, c0, sy.len, sx.len, cdep, &mut st.block,
                    );
                    let (w, b) = codec.compressed_sizes(&st.block);
                    (w as u32, b as u32, 0)
                }
            },
            None => {
                // Adaptive: size every codec from the shared stats via
                // the registry's one sizing substrate (a stats-blind
                // codec would need the gathered block), then the
                // deterministic min.
                let block = if reg.any_stats_blind(&stats) {
                    fm.extract_block_into(
                        sy.start, sx.start, c0, sy.len, sx.len, cdep, &mut st.block,
                    );
                    Some(st.block.as_slice())
                } else {
                    None
                };
                reg.sizes_from(&stats, block, &mut st.sizes);
                let tag = reg.select(&st.sizes, division.compact);
                let (w, b) = st.sizes[tag as usize];
                (w as u32, b as u32, tag)
            }
        }
    };
    let init = || PlanScratch {
        tracker: (dict_cap > 0).then(DistinctTracker::new),
        block: Vec::new(),
        sizes: Vec::new(),
    };

    let sizes: Vec<(u32, u32, u8)> = if parallel && n > 1 {
        let idxs: Vec<usize> = (0..n).collect();
        par_map_init(&idxs, init, |st, _, &li| size_one(st, li))
    } else {
        let mut st = init();
        (0..n).map(|li| size_one(&mut st, li)).collect()
    };
    SizePlan {
        words: sizes.iter().map(|s| s.0).collect(),
        bits: sizes.iter().map(|s| s.1).collect(),
        tags: if policy.is_adaptive() { sizes.iter().map(|s| s.2).collect() } else { Vec::new() },
    }
}

/// Exact `(words, bits)` of **every registered codec** for every
/// sub-tensor of `division`, flattened `[li × n_codecs + tag]` — the
/// auto-tuner's sizing substrate. One fused stats pass per sub-tensor
/// (the same scan [`plan_sizes`] does under `Adaptive`) prices all
/// codecs at once, so a plan search over codec policies costs one pass
/// over the map per division candidate, never a re-pack. Results are
/// position-indexed and computed with the deterministic-order parallel
/// map, hence byte-stable for any `--jobs`.
pub struct AllCodecSizes {
    pub n_codecs: usize,
    sizes: Vec<(u32, u32)>,
}

impl AllCodecSizes {
    /// `(words, bits)` of sub-tensor `li` under codec tag `tag`.
    #[inline]
    pub fn at(&self, li: usize, tag: usize) -> (u32, u32) {
        self.sizes[li * self.n_codecs + tag]
    }

    /// Number of sub-tensors covered.
    pub fn n_subtensors(&self) -> usize {
        self.sizes.len() / self.n_codecs
    }
}

/// Size every registered codec on every sub-tensor of `division` in one
/// stats pass each. See [`AllCodecSizes`].
pub fn size_all_codecs(fm: &FeatureMap, division: &Division) -> AllCodecSizes {
    let reg = Registry::global();
    let n = division.n_subtensors();
    let n_codecs = reg.entries().len();
    let dict_cap = reg.max_stats_dict_cap();
    let data = fm.as_slice();

    let size_one = |st: &mut PlanScratch, li: usize| -> Vec<(u32, u32)> {
        let (sy, sx, c0, cdep) = geom(division, li);
        let mut acc = StatsAcc::new(dict_cap, st.tracker.as_mut());
        for y in sy.start..sy.end() {
            let row = y * fm.w;
            for x in sx.start..sx.end() {
                let px = (row + x) * fm.c + c0;
                acc.feed(&data[px..px + cdep]);
            }
        }
        let stats = acc.finish();
        let block = if reg.any_stats_blind(&stats) {
            fm.extract_block_into(sy.start, sx.start, c0, sy.len, sx.len, cdep, &mut st.block);
            Some(st.block.as_slice())
        } else {
            None
        };
        reg.sizes_from(&stats, block, &mut st.sizes);
        st.sizes.iter().map(|&(w, b)| (w as u32, b as u32)).collect()
    };
    let init = || PlanScratch {
        tracker: (dict_cap > 0).then(DistinctTracker::new),
        block: Vec::new(),
        sizes: Vec::new(),
    };

    let per_li: Vec<Vec<(u32, u32)>> = if fm.words() >= PAR_MIN_ELEMS && n > 1 {
        let idxs: Vec<usize> = (0..n).collect();
        par_map_init(&idxs, init, |st, _, &li| size_one(st, li))
    } else {
        let mut st = init();
        (0..n).map(|li| size_one(&mut st, li)).collect()
    };
    AllCodecSizes { n_codecs, sizes: per_li.into_iter().flatten().collect() }
}

/// Serial prefix walk over the block raster: with every size known, all
/// final addresses, records and the total footprint follow in O(n)
/// arithmetic — the seed's cursor discipline without any compression or
/// `resize` churn on the walk.
fn assign_addresses(
    division: &Division,
    sizes_words: &[u32],
    tags: &[u8],
    wpl: usize,
    want_spans: bool,
) -> AddressPlan {
    let n = division.n_subtensors();
    let mut addr_words = vec![0u64; n];
    let mut records: Vec<BlockRecord> = Vec::with_capacity(division.n_blocks());
    let mut spans: Vec<BlockSpan> =
        Vec::with_capacity(if want_spans { division.n_blocks() } else { 0 });
    let mut cursor: u64 = 0;

    for by in 0..division.n_blocks_y {
        let yr = division.y_segs_of_block(by);
        for bx in 0..division.n_blocks_x {
            let xr = division.x_segs_of_block(bx);
            for icg in 0..division.n_cgroups {
                if !division.compact {
                    cursor = round_up(cursor as usize, wpl) as u64;
                }
                let pointer_words = cursor;
                let mut rec_sizes = Vec::with_capacity(yr.len() * xr.len());
                let mut rec_tags =
                    Vec::with_capacity(if tags.is_empty() { 0 } else { yr.len() * xr.len() });
                let mut subs = Vec::with_capacity(if want_spans { yr.len() * xr.len() } else { 0 });
                for iy in yr.clone() {
                    for ix in xr.clone() {
                        let li = division.linear(SubTensorRef { iy, ix, icg });
                        if !division.compact {
                            cursor = round_up(cursor as usize, wpl) as u64;
                        }
                        addr_words[li] = cursor;
                        if want_spans {
                            subs.push((li, cursor));
                        }
                        cursor += sizes_words[li] as u64;
                        rec_sizes.push(sizes_words[li]);
                        if !tags.is_empty() {
                            rec_tags.push(tags[li]);
                        }
                    }
                }
                records.push(BlockRecord {
                    pointer_words,
                    sizes_words: rec_sizes,
                    codec_tags: rec_tags,
                });
                if want_spans {
                    spans.push(BlockSpan { start: pointer_words, end: cursor, subs });
                }
            }
        }
    }

    let total_words =
        if division.compact { cursor } else { round_up(cursor as usize, wpl) as u64 };
    AddressPlan { addr_words, records, spans, total_words, payload_words: cursor }
}

/// Execute phase: compress every sub-tensor into its planned slice. The
/// payload is preallocated at its exact final size and split into
/// disjoint per-block `&mut` chunks, so blocks materialise in parallel
/// with no synchronisation and bit-identical output for any worker
/// count. Alignment gaps stay zero, exactly like the reference packer's
/// `resize` fill.
fn execute_payload(
    fm: &FeatureMap,
    division: &Division,
    policy: CodecPolicy,
    plan: &SizePlan,
    layout: &AddressPlan,
    parallel: bool,
) -> Vec<u16> {
    let reg = Registry::global();
    let sizes_words = &plan.words;
    let codec_of = |li: usize| -> &'static dyn Compressor {
        match policy {
            CodecPolicy::Fixed(s) => reg.compressor(s),
            CodecPolicy::Adaptive => reg.compressor_of_tag(plan.tags[li]),
        }
    };
    struct BlockTask<'p, 's> {
        base: u64,
        out: &'p mut [u16],
        subs: &'s [(usize, u64)],
    }

    // Sized to the last written word (NOT the line-rounded total): the
    // reference packer's payload ends exactly at its final write, and
    // byte-equality with it is asserted.
    let mut payload = vec![0u16; layout.payload_words as usize];
    let mut tasks: Vec<BlockTask> = Vec::with_capacity(layout.spans.len());
    let mut rest = payload.as_mut_slice();
    let mut consumed = 0u64;
    for span in &layout.spans {
        let tail = std::mem::take(&mut rest);
        // Alignment gap between blocks stays zeroed.
        let (_gap, tail) = tail.split_at_mut((span.start - consumed) as usize);
        let (chunk, tail) = tail.split_at_mut((span.end - span.start) as usize);
        tasks.push(BlockTask { base: span.start, out: chunk, subs: &span.subs });
        rest = tail;
        consumed = span.end;
    }

    let work = |scratch: &mut Vec<f32>, task: &mut BlockTask| {
        for &(li, addr) in task.subs {
            let (sy, sx, c0, cdep) = geom(division, li);
            fm.extract_block_into(sy.start, sx.start, c0, sy.len, sx.len, cdep, scratch);
            let codec = codec_of(li);
            let comp = codec.compress(scratch);
            assert_eq!(
                comp.words.len() as u32,
                sizes_words[li],
                "planner sized sub-tensor {li} wrong (scheme {:?})",
                codec.scheme()
            );
            let off = (addr - task.base) as usize;
            task.out[off..off + comp.words.len()].copy_from_slice(&comp.words);
        }
    };

    if parallel && tasks.len() > 1 {
        par_for_each_init(&mut tasks, Vec::<f32>::new, |scratch, _, t| work(scratch, t));
    } else {
        let mut scratch = Vec::new();
        for t in &mut tasks {
            work(&mut scratch, t);
        }
    }
    drop(tasks);
    payload
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tiling::division::DivisionMode;

    fn setup(mode: DivisionMode, density: f64) -> (FeatureMap, Division, Packer) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division =
            Division::build(mode, &layer, &tile, &hw, 24, 24, 16).unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(density, 11));
        (fm, division, Packer::new(hw, Scheme::Bitmask))
    }

    #[test]
    fn sizes_cover_all_subtensors() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        assert_eq!(packed.sizes_words.len(), div.n_subtensors());
        assert!(packed.sizes_words.iter().all(|&s| s > 0)); // bitmask >= mask words
        assert_eq!(packed.metadata.records.len(), div.n_blocks());
    }

    #[test]
    fn aligned_addresses_are_line_multiples() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        for &a in &packed.addr_words {
            assert_eq!(a % 8, 0, "sub-tensor at {a} not line-aligned");
        }
    }

    #[test]
    fn compact_mode_packs_without_alignment() {
        let (fm, div, packer) = setup(DivisionMode::Uniform { edge: 1 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        // Compact total == sum of sizes exactly (no padding).
        let sum: u64 = packed.sizes_words.iter().map(|&s| s as u64).sum();
        assert_eq!(packed.total_words, sum);
    }

    #[test]
    fn aligned_total_at_least_sum_of_sizes() {
        let (fm, div, packer) = setup(DivisionMode::Uniform { edge: 4 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        let sum: u64 = packed.sizes_words.iter().map(|&s| s as u64).sum();
        assert!(packed.total_words >= sum);
        assert_eq!(packed.total_words % 8, 0);
    }

    #[test]
    fn payload_and_size_only_modes_agree() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.35);
        let a = packer.pack(&fm, &div, false);
        let b = packer.pack(&fm, &div, true);
        assert_eq!(a.sizes_words, b.sizes_words);
        assert_eq!(a.addr_words, b.addr_words);
        assert_eq!(a.total_words, b.total_words);
        assert!(b.payload.is_some());
    }

    /// The engine's defining invariant at unit scale: identical output
    /// to the seed oracle for every mode × policy (all fixed codecs AND
    /// adaptive), payload and codec tags included.
    #[test]
    fn engine_matches_reference_packer() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let mut policies: Vec<CodecPolicy> =
            Registry::global().schemes().into_iter().map(CodecPolicy::Fixed).collect();
        policies.push(CodecPolicy::Adaptive);
        for mode in [
            DivisionMode::GrateTile { n: 8 },
            DivisionMode::Uniform { edge: 4 },
            DivisionMode::Uniform { edge: 1 },
            DivisionMode::WholeMap,
        ] {
            for policy in &policies {
                let (fm, div, _) = setup(mode, 0.4);
                let packer = Packer::new(hw, *policy);
                let a = packer.pack_reference(&fm, &div, true);
                let b = packer.pack(&fm, &div, true);
                let tag = format!("{mode:?} {policy:?}");
                assert_eq!(a.sizes_words, b.sizes_words, "{tag} sizes_words");
                assert_eq!(a.sizes_bits, b.sizes_bits, "{tag} sizes_bits");
                assert_eq!(a.tags, b.tags, "{tag} codec tags");
                assert_eq!(a.addr_words, b.addr_words, "{tag} addr_words");
                assert_eq!(a.total_words, b.total_words, "{tag} total_words");
                assert_eq!(a.payload, b.payload, "{tag} payload");
                assert_eq!(a.checksums, b.checksums, "{tag} checksums");
                assert_eq!(a.checksums.len(), div.n_subtensors(), "{tag} checksum count");
                assert_eq!(
                    a.metadata.records.len(),
                    b.metadata.records.len(),
                    "{tag} record count"
                );
                for (ra, rb) in a.metadata.records.iter().zip(&b.metadata.records) {
                    assert_eq!(ra.pointer_words, rb.pointer_words, "{tag} pointer");
                    assert_eq!(ra.sizes_words, rb.sizes_words, "{tag} record sizes");
                    assert_eq!(ra.codec_tags, rb.codec_tags, "{tag} record tags");
                }
            }
        }
    }

    /// Adaptive selection is per-sub-tensor optimal: every sub-tensor's
    /// packed size equals the minimum over all fixed codecs' sizes for
    /// that sub-tensor, so the adaptive payload never exceeds any fixed
    /// codec's.
    #[test]
    fn adaptive_is_per_subtensor_min() {
        let hw = Platform::NvidiaSmallTile.hardware();
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 1 }] {
            let (fm, div, _) = setup(mode, 0.4);
            let auto = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &div, false);
            let fixed: Vec<PackedFeatureMap> = Registry::global()
                .schemes()
                .into_iter()
                .map(|s| Packer::new(hw, s).pack(&fm, &div, false))
                .collect();
            for li in 0..div.n_subtensors() {
                let min_words = fixed.iter().map(|p| p.sizes_words[li]).min().unwrap();
                let min_bits = fixed.iter().map(|p| p.sizes_bits[li]).min().unwrap();
                if div.compact {
                    assert_eq!(auto.sizes_bits[li], min_bits, "sub {li} bits");
                } else {
                    assert_eq!(auto.sizes_words[li], min_words, "sub {li} words");
                }
            }
            for p in &fixed {
                assert!(auto.total_words <= p.total_words, "{mode:?} vs {:?}", p.policy);
            }
        }
    }

    /// Adaptive metadata records carry one tag per slot and the record
    /// width accounts TAG_BITS per slot on top of the Fig. 7 base.
    #[test]
    fn adaptive_records_carry_tags_and_widen() {
        use crate::compress::TAG_BITS;
        let (fm, div, _) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let hw = Platform::NvidiaSmallTile.hardware();
        let auto = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &div, false);
        let fixed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &div, false);
        assert_eq!(
            auto.record_bits(),
            fixed.record_bits() + TAG_BITS * div.record_slots()
        );
        assert_eq!(auto.tags.len(), div.n_subtensors());
        for rec in &auto.metadata.records {
            assert_eq!(rec.codec_tags.len(), rec.sizes_words.len());
        }
        // Fixed maps carry no tags at all.
        assert!(fixed.tags.is_empty());
        assert!(fixed.metadata.records.iter().all(|r| r.codec_tags.is_empty()));
    }

    /// Per-codec bit attribution: a fixed map charges every stored bit
    /// to its single codec's tag; an adaptive map's per-tag bits sum to
    /// the same storage-rule total and land only on selected tags.
    #[test]
    fn payload_bits_by_tag_accounts_all_storage() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let fixed = packer.pack(&fm, &div, false);
        let by_tag = fixed.payload_bits_by_tag();
        let tag = Registry::global().tag_of(Scheme::Bitmask) as usize;
        let stored: u64 = fixed.sizes_words.iter().map(|&s| s as u64 * 16).sum();
        assert_eq!(by_tag[tag], stored);
        assert_eq!(by_tag.iter().sum::<u64>(), stored, "only the fixed tag is charged");

        let auto = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &div, false);
        let auto_by_tag = auto.payload_bits_by_tag();
        let auto_stored: u64 = auto.sizes_words.iter().map(|&s| s as u64 * 16).sum();
        assert_eq!(auto_by_tag.iter().sum::<u64>(), auto_stored);

        // Compact maps charge exact bits, not padded words.
        let (fm_c, div_c, packer_c) = setup(DivisionMode::Uniform { edge: 1 }, 0.4);
        let compact = packer_c.pack(&fm_c, &div_c, false);
        let exact: u64 = compact.sizes_bits.iter().map(|&b| b as u64).sum();
        assert_eq!(compact.payload_bits_by_tag().iter().sum::<u64>(), exact);
    }

    /// The tuner's sizing substrate agrees exactly with what a real pack
    /// under each fixed codec produces — per sub-tensor, words and bits.
    #[test]
    fn size_all_codecs_matches_fixed_packs() {
        let hw = Platform::NvidiaSmallTile.hardware();
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 1 }] {
            let (fm, div, _) = setup(mode, 0.4);
            let all = size_all_codecs(&fm, &div);
            assert_eq!(all.n_subtensors(), div.n_subtensors());
            for (tag, entry) in Registry::global().entries().iter().enumerate() {
                let packed = Packer::new(hw, entry.scheme).pack(&fm, &div, false);
                for li in 0..div.n_subtensors() {
                    let (w, b) = all.at(li, tag);
                    assert_eq!(w, packed.sizes_words[li], "{mode:?} {} sub {li}", entry.name);
                    assert_eq!(b, packed.sizes_bits[li], "{mode:?} {} sub {li}", entry.name);
                }
            }
        }
    }

    #[test]
    fn sparser_maps_pack_smaller() {
        let (fm_d, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.8);
        let (fm_s, _, _) = setup(DivisionMode::GrateTile { n: 8 }, 0.2);
        let dense = packer.pack(&fm_d, &div, false);
        let sparse = packer.pack(&fm_s, &div, false);
        assert!(sparse.total_words < dense.total_words);
        assert!(sparse.compression_ratio() < 0.5);
    }

    #[test]
    fn block_records_match_subtensor_sizes() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        // Sum of record sizes == sum of sub-tensor sizes.
        let rec_sum: u64 = packed
            .metadata
            .records
            .iter()
            .flat_map(|r| r.sizes_words.iter())
            .map(|&s| s as u64)
            .sum();
        let sz_sum: u64 = packed.sizes_words.iter().map(|&s| s as u64).sum();
        assert_eq!(rec_sum, sz_sum);
        // Interior GrateTile blocks carry exactly 4 spatial sub-tensors.
        let max_per_block = packed
            .metadata
            .records
            .iter()
            .map(|r| r.sizes_words.len())
            .max()
            .unwrap();
        assert_eq!(max_per_block, 4);
    }

    #[test]
    fn fetch_bits_grid_matches_pointwise_lookup() {
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 1 }] {
            let (fm, div, packer) = setup(mode, 0.4);
            let packed = packer.pack(&fm, &div, false);
            let grid = packed.fetch_bits_grid();
            assert_eq!(grid.len(), div.n_subtensors());
            for iy in 0..div.ys.len() {
                for ix in 0..div.xs.len() {
                    for icg in 0..div.n_cgroups {
                        let r = SubTensorRef { iy, ix, icg };
                        assert_eq!(grid[div.linear(r)], packed.fetch_bits(r));
                    }
                }
            }
        }
    }

    #[test]
    fn fetch_words_line_rounds_only_when_aligned() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        let r = SubTensorRef { iy: 1, ix: 1, icg: 0 };
        let sz = packed.size_words(r) as u64;
        assert_eq!(packed.fetch_words(r), sz.div_ceil(8) * 8);

        let (fm2, div2, packer2) = setup(DivisionMode::Uniform { edge: 1 }, 0.4);
        let packed2 = packer2.pack(&fm2, &div2, false);
        let r2 = SubTensorRef { iy: 0, ix: 0, icg: 0 };
        assert_eq!(packed2.fetch_words(r2), packed2.size_words(r2) as u64);
    }
}
