//! The packer: compress every sub-tensor of a division and assign
//! storage addresses (paper §III-C).
//!
//! Aligned modes start every sub-tensor on a cache-line boundary (the
//! paper's "GrateTile only stores these subtensors in aligned
//! addresses"); the Uniform 1×1×8 baseline packs word-compactly (Table
//! II footnote a). Blocks are laid out in raster order — (block_y,
//! block_x, channel-group) — with the block pointer addressing the first
//! sub-tensor, exactly the two-step access structure of Fig. 7b.

use super::metadata::{BlockRecord, MetadataTable};
use crate::compress::Scheme;
use crate::config::hardware::Hardware;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, SubTensorRef};
use crate::util::round_up;

/// A fully packed feature map: per-sub-tensor compressed sizes and
/// addresses, block metadata, and (optionally) the compressed payload.
#[derive(Debug, Clone)]
pub struct PackedFeatureMap {
    pub division: Division,
    pub scheme: Scheme,
    /// Compressed size in words, indexed by `division.linear(ref)`.
    pub sizes_words: Vec<u32>,
    /// Idealised compressed size in bits (no word padding), same
    /// indexing; what the compact baseline pays (§IV-B(2)).
    pub sizes_bits: Vec<u32>,
    /// Start word address, same indexing.
    pub addr_words: Vec<u64>,
    /// Block metadata table (Fig. 7).
    pub metadata: MetadataTable,
    /// Compressed payload words, addressed by `addr_words` (present only
    /// when packed with `with_payload`).
    pub payload: Option<Vec<u16>>,
    /// Total storage footprint in words (end of the last sub-tensor,
    /// line-rounded for aligned modes).
    pub total_words: u64,
    /// Line geometry the addresses were assigned under (crate-visible so
    /// the store's streaming writer and the container reader can
    /// assemble layouts without re-packing).
    pub(crate) words_per_line: usize,
}

impl PackedFeatureMap {
    /// Fetch cost by linear sub-tensor index — the single encoding of
    /// the compact-vs-line-rounded cost rule ([`Self::fetch_bits`] and
    /// [`Self::fetch_bits_grid`] both go through here).
    #[inline]
    fn fetch_bits_at(&self, li: usize) -> u64 {
        if self.division.compact {
            self.sizes_bits[li] as u64
        } else {
            let words = self.sizes_words[li] as usize;
            (round_up(words, self.words_per_line) * 16) as u64
        }
    }

    /// Fetch cost of one sub-tensor in *bits*: aligned sub-tensors move
    /// whole cache lines; compact ones (Uniform 1×1×8) move the exact
    /// compressed bits — the idealised upper bound of §IV-B(2).
    pub fn fetch_bits(&self, r: SubTensorRef) -> u64 {
        self.fetch_bits_at(self.division.linear(r))
    }

    /// Fetch cost in words (line-rounded for aligned modes).
    pub fn fetch_words(&self, r: SubTensorRef) -> u64 {
        self.fetch_bits(r).div_ceil(16)
    }

    /// Per-sub-tensor fetch costs in bits, indexed by
    /// [`Division::linear`] — the pricer's input grid, available without
    /// materializing any payload. Entry `i` equals `fetch_bits` of the
    /// sub-tensor with linear index `i`.
    pub fn fetch_bits_grid(&self) -> Vec<u64> {
        (0..self.division.n_subtensors())
            .map(|li| self.fetch_bits_at(li))
            .collect()
    }

    /// Compressed size in words of one sub-tensor.
    pub fn size_words(&self, r: SubTensorRef) -> u32 {
        self.sizes_words[self.division.linear(r)]
    }

    /// Storage footprint in cache lines.
    pub fn total_lines(&self) -> u64 {
        (self.total_words as usize).div_ceil(self.words_per_line) as u64
    }

    /// Line geometry the map was packed under.
    pub fn line_words(&self) -> usize {
        self.words_per_line
    }

    /// Compression ratio vs. the dense map (< 1 is smaller).
    pub fn compression_ratio(&self) -> f64 {
        let dense = (self.division.fm_h * self.division.fm_w * self.division.fm_c) as f64;
        self.total_words as f64 / dense
    }
}

/// Packs feature maps under a division + compression scheme.
pub struct Packer {
    pub hw: Hardware,
    pub scheme: Scheme,
}

impl Packer {
    pub fn new(hw: Hardware, scheme: Scheme) -> Self {
        Self { hw, scheme }
    }

    /// Pack `fm` under `division`. `with_payload` materialises the
    /// compressed byte stream (needed by the fetch/decompress path; the
    /// bandwidth simulator only needs sizes).
    pub fn pack(
        &self,
        fm: &FeatureMap,
        division: &Division,
        with_payload: bool,
    ) -> PackedFeatureMap {
        assert_eq!(
            (fm.h, fm.w, fm.c),
            (division.fm_h, division.fm_w, division.fm_c),
            "division was built for a different map shape"
        );
        // Perf fast path (§Perf, EXPERIMENTS.md): bitmask sizes depend
        // only on per-sub-tensor nonzero counts, which one linear pass
        // over the map computes without any block extraction.
        if self.scheme == Scheme::Bitmask && !with_payload {
            return self.pack_bitmask_sizes(fm, division);
        }
        let codec = self.scheme.build();
        let n = division.n_subtensors();
        let mut sizes_words = vec![0u32; n];
        let mut sizes_bits = vec![0u32; n];
        let mut addr_words = vec![0u64; n];
        let mut payload: Option<Vec<u16>> = if with_payload { Some(Vec::new()) } else { None };
        let mut records: Vec<BlockRecord> = Vec::with_capacity(division.n_blocks());

        let wpl = self.hw.words_per_line;
        let mut cursor: u64 = 0;
        let mut block = Vec::with_capacity(64);

        // Raster order over metadata blocks; sub-tensors inside a block
        // in (y, x) raster order — the Fig. 7b layout.
        let seg_range = |block_of: &[usize], bid: usize| -> std::ops::Range<usize> {
            let first = block_of.partition_point(|&b| b < bid);
            let last = block_of.partition_point(|&b| b <= bid);
            first..last
        };

        for by in 0..division.n_blocks_y {
            let yr = seg_range(&division.block_of_y, by);
            for bx in 0..division.n_blocks_x {
                let xr = seg_range(&division.block_of_x, bx);
                for icg in 0..division.n_cgroups {
                    // Block start: line-aligned pointer (Fig. 7).
                    if !division.compact {
                        cursor = round_up(cursor as usize, wpl) as u64;
                    }
                    let pointer_words = cursor;
                    let mut rec_sizes = Vec::with_capacity(yr.len() * xr.len());
                    for iy in yr.clone() {
                        for ix in xr.clone() {
                            let r = SubTensorRef { iy, ix, icg };
                            let sy = division.ys[iy];
                            let sx = division.xs[ix];
                            let cd = division.cg_depth(icg);
                            fm.extract_block_into(
                                sy.start,
                                sx.start,
                                icg * division.cd,
                                sy.len,
                                sx.len,
                                cd,
                                &mut block,
                            );
                            let li = division.linear(r);
                            sizes_bits[li] = codec.compressed_bits(&block) as u32;
                            if let Some(p) = &mut payload {
                                let comp = codec.compress(&block);
                                sizes_words[li] = comp.words.len() as u32;
                                if !division.compact {
                                    cursor = round_up(cursor as usize, wpl) as u64;
                                }
                                addr_words[li] = cursor;
                                // Materialise at the assigned address.
                                let end = cursor as usize + comp.words.len();
                                if p.len() < end {
                                    p.resize(end, 0);
                                }
                                p[cursor as usize..end].copy_from_slice(&comp.words);
                                cursor += comp.words.len() as u64;
                            } else {
                                let size = codec.compressed_words(&block) as u32;
                                sizes_words[li] = size;
                                if !division.compact {
                                    cursor = round_up(cursor as usize, wpl) as u64;
                                }
                                addr_words[li] = cursor;
                                cursor += size as u64;
                            }
                            rec_sizes.push(sizes_words[li]);
                        }
                    }
                    records.push(BlockRecord { pointer_words, sizes_words: rec_sizes });
                }
            }
        }

        let total_words = if division.compact { cursor } else { round_up(cursor as usize, wpl) as u64 };
        PackedFeatureMap {
            division: division.clone(),
            scheme: self.scheme,
            sizes_words,
            sizes_bits,
            addr_words,
            metadata: MetadataTable {
                records,
                bits_per_record: division.meta_bits_per_block,
            },
            payload,
            total_words,
            words_per_line: wpl,
        }
    }
}

impl Packer {
    /// Sizes-only bitmask packing in two allocation-light passes:
    /// (1) one sweep over the map accumulating nonzeros per sub-tensor
    /// via per-coordinate segment lookup tables, (2) the usual
    /// block-raster address assignment reading those counts.
    fn pack_bitmask_sizes(&self, fm: &FeatureMap, division: &Division) -> PackedFeatureMap {
        let n = division.n_subtensors();
        let mut nnz = vec![0u32; n];

        // Coordinate -> segment index lookups.
        let mut seg_of_y = vec![0u32; fm.h];
        for (iy, s) in division.ys.iter().enumerate() {
            for y in s.start..s.end() {
                seg_of_y[y] = iy as u32;
            }
        }
        let mut seg_of_x = vec![0u32; fm.w];
        for (ix, s) in division.xs.iter().enumerate() {
            for x in s.start..s.end() {
                seg_of_x[x] = ix as u32;
            }
        }

        // Pass 1: count nonzeros per (iy, ix, icg).
        let data = fm.as_slice();
        let nxs = division.xs.len();
        let ncg = division.n_cgroups;
        let cd = division.cd;
        for y in 0..fm.h {
            let iy = seg_of_y[y] as usize;
            let row_base = y * fm.w;
            for x in 0..fm.w {
                let ix = seg_of_x[x] as usize;
                let px = (row_base + x) * fm.c;
                let sub_base = (iy * nxs + ix) * ncg;
                for icg in 0..ncg {
                    let c0 = icg * cd;
                    let c1 = (c0 + cd).min(fm.c);
                    let mut cnt = 0u32;
                    for &v in &data[px + c0..px + c1] {
                        cnt += (v != 0.0) as u32;
                    }
                    nnz[sub_base + icg] += cnt;
                }
            }
        }

        // Pass 2: sizes + block-raster addresses + records.
        let mut sizes_words = vec![0u32; n];
        let mut sizes_bits = vec![0u32; n];
        let mut addr_words = vec![0u64; n];
        let mut records: Vec<BlockRecord> = Vec::with_capacity(division.n_blocks());
        let wpl = self.hw.words_per_line;
        let mut cursor: u64 = 0;
        let seg_range = |block_of: &[usize], bid: usize| -> std::ops::Range<usize> {
            let first = block_of.partition_point(|&b| b < bid);
            let last = block_of.partition_point(|&b| b <= bid);
            first..last
        };
        for by in 0..division.n_blocks_y {
            let yr = seg_range(&division.block_of_y, by);
            for bx in 0..division.n_blocks_x {
                let xr = seg_range(&division.block_of_x, bx);
                for icg in 0..ncg {
                    if !division.compact {
                        cursor = crate::util::round_up(cursor as usize, wpl) as u64;
                    }
                    let pointer_words = cursor;
                    let mut rec_sizes = Vec::with_capacity(yr.len() * xr.len());
                    for iy in yr.clone() {
                        for ix in xr.clone() {
                            let r = SubTensorRef { iy, ix, icg };
                            let li = division.linear(r);
                            let elems = division.subtensor_words(r);
                            let z = nnz[li];
                            sizes_words[li] = elems.div_ceil(16) as u32 + z;
                            sizes_bits[li] = elems as u32 + z * 16;
                            if !division.compact {
                                cursor = crate::util::round_up(cursor as usize, wpl) as u64;
                            }
                            addr_words[li] = cursor;
                            cursor += sizes_words[li] as u64;
                            rec_sizes.push(sizes_words[li]);
                        }
                    }
                    records.push(BlockRecord { pointer_words, sizes_words: rec_sizes });
                }
            }
        }
        let total_words = if division.compact {
            cursor
        } else {
            crate::util::round_up(cursor as usize, wpl) as u64
        };
        PackedFeatureMap {
            division: division.clone(),
            scheme: self.scheme,
            sizes_words,
            sizes_bits,
            addr_words,
            metadata: MetadataTable { records, bits_per_record: division.meta_bits_per_block },
            payload: None,
            total_words,
            words_per_line: wpl,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tiling::division::DivisionMode;

    fn setup(mode: DivisionMode, density: f64) -> (FeatureMap, Division, Packer) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division =
            Division::build(mode, &layer, &tile, &hw, 24, 24, 16).unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(density, 11));
        (fm, division, Packer::new(hw, Scheme::Bitmask))
    }

    #[test]
    fn sizes_cover_all_subtensors() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        assert_eq!(packed.sizes_words.len(), div.n_subtensors());
        assert!(packed.sizes_words.iter().all(|&s| s > 0)); // bitmask >= mask words
        assert_eq!(packed.metadata.records.len(), div.n_blocks());
    }

    #[test]
    fn aligned_addresses_are_line_multiples() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        for &a in &packed.addr_words {
            assert_eq!(a % 8, 0, "sub-tensor at {a} not line-aligned");
        }
    }

    #[test]
    fn compact_mode_packs_without_alignment() {
        let (fm, div, packer) = setup(DivisionMode::Uniform { edge: 1 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        // Compact total == sum of sizes exactly (no padding).
        let sum: u64 = packed.sizes_words.iter().map(|&s| s as u64).sum();
        assert_eq!(packed.total_words, sum);
    }

    #[test]
    fn aligned_total_at_least_sum_of_sizes() {
        let (fm, div, packer) = setup(DivisionMode::Uniform { edge: 4 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        let sum: u64 = packed.sizes_words.iter().map(|&s| s as u64).sum();
        assert!(packed.total_words >= sum);
        assert_eq!(packed.total_words % 8, 0);
    }

    #[test]
    fn payload_and_size_only_modes_agree() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.35);
        let a = packer.pack(&fm, &div, false);
        let b = packer.pack(&fm, &div, true);
        assert_eq!(a.sizes_words, b.sizes_words);
        assert_eq!(a.addr_words, b.addr_words);
        assert_eq!(a.total_words, b.total_words);
        assert!(b.payload.is_some());
    }

    #[test]
    fn sparser_maps_pack_smaller() {
        let (fm_d, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.8);
        let (fm_s, _, _) = setup(DivisionMode::GrateTile { n: 8 }, 0.2);
        let dense = packer.pack(&fm_d, &div, false);
        let sparse = packer.pack(&fm_s, &div, false);
        assert!(sparse.total_words < dense.total_words);
        assert!(sparse.compression_ratio() < 0.5);
    }

    #[test]
    fn block_records_match_subtensor_sizes() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        // Sum of record sizes == sum of sub-tensor sizes.
        let rec_sum: u64 = packed
            .metadata
            .records
            .iter()
            .flat_map(|r| r.sizes_words.iter())
            .map(|&s| s as u64)
            .sum();
        let sz_sum: u64 = packed.sizes_words.iter().map(|&s| s as u64).sum();
        assert_eq!(rec_sum, sz_sum);
        // Interior GrateTile blocks carry exactly 4 spatial sub-tensors.
        let max_per_block = packed
            .metadata
            .records
            .iter()
            .map(|r| r.sizes_words.len())
            .max()
            .unwrap();
        assert_eq!(max_per_block, 4);
    }

    #[test]
    fn fetch_bits_grid_matches_pointwise_lookup() {
        for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 1 }] {
            let (fm, div, packer) = setup(mode, 0.4);
            let packed = packer.pack(&fm, &div, false);
            let grid = packed.fetch_bits_grid();
            assert_eq!(grid.len(), div.n_subtensors());
            for iy in 0..div.ys.len() {
                for ix in 0..div.xs.len() {
                    for icg in 0..div.n_cgroups {
                        let r = SubTensorRef { iy, ix, icg };
                        assert_eq!(grid[div.linear(r)], packed.fetch_bits(r));
                    }
                }
            }
        }
    }

    #[test]
    fn fetch_words_line_rounds_only_when_aligned() {
        let (fm, div, packer) = setup(DivisionMode::GrateTile { n: 8 }, 0.4);
        let packed = packer.pack(&fm, &div, false);
        let r = SubTensorRef { iy: 1, ix: 1, icg: 0 };
        let sz = packed.size_words(r) as u64;
        assert_eq!(packed.fetch_words(r), sz.div_ceil(8) * 8);

        let (fm2, div2, packer2) = setup(DivisionMode::Uniform { edge: 1 }, 0.4);
        let packed2 = packer2.pack(&fm2, &div2, false);
        let r2 = SubTensorRef { iy: 0, ix: 0, icg: 0 };
        assert_eq!(packed2.fetch_words(r2), packed2.size_words(r2) as u64);
    }
}
