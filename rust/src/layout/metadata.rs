//! The Fig. 7 metadata structure and Table II accounting.
//!
//! Uniform division (Fig. 7a): one pointer per sub-tensor. GrateTile
//! (Fig. 7b): one pointer per mod-N block plus the compressed sizes (in
//! cache lines) of the up-to-four uneven sub-tensors inside the block;
//! access is the paper's two-step procedure — locate the block start
//! from the pointer, then add size prefixes for the actual offset.

use crate::config::hardware::Hardware;
use crate::tiling::division::{Division, DivisionMode};
use crate::util::ceil_div;

/// Bits needed to represent a compressed size of up to `max_lines`
/// cache lines (values 0..=max_lines inclusive).
pub fn size_bits_for_lines(max_lines: usize) -> usize {
    (usize::BITS - max_lines.leading_zeros()) as usize
}

/// Size-field bits for one GrateTile block given its period segment
/// lengths (paper §III-C): the four sub-tensors of an `a/b` split of an
/// N-period block of depth 8 have `a·a·8`, `a·b·8`, `b·a·8`, `b·b·8`
/// words; each field must hold its line count.
pub fn size_field_bits_for(seg_a: usize, seg_b: usize, depth: usize, words_per_line: usize) -> usize {
    let shapes = [(seg_a, seg_a), (seg_a, seg_b), (seg_b, seg_a), (seg_b, seg_b)];
    shapes
        .iter()
        .map(|&(h, w)| size_bits_for_lines(ceil_div(h * w * depth, words_per_line)))
        .sum()
}

/// Metadata bits per KB (512 16-bit words) of feature map for a division
/// mode — the Table II quantity.
pub fn metadata_bits_per_kb(mode: DivisionMode, hw: &Hardware) -> f64 {
    let record = |bits: usize, words_per_record: usize| -> f64 {
        bits as f64 * (512.0 / words_per_record as f64)
    };
    match mode {
        // GrateTile: 48 bits per N×N×8 block.
        DivisionMode::GrateTile { n } => {
            record(hw.pointer_bits + hw.size_field_bits, n * n * 8)
        }
        // Uniform edge≥2: 28-bit pointer per edge×edge×8 block;
        // edge==1: compact 32-bit address per 1×1×8 sub-tensor.
        DivisionMode::Uniform { edge } => {
            if edge == 1 {
                record(32, 8)
            } else {
                record(hw.pointer_bits, edge * edge * 8)
            }
        }
        DivisionMode::WholeMap => 0.0,
        // Anchored: same economics as aligned Uniform (one pointer per
        // edge×edge×8 block); only the cut positions differ.
        DivisionMode::Anchored { edge, .. } => record(hw.pointer_bits, edge * edge * 8),
    }
}

/// Metadata overhead as a fraction of feature-map size (Table II's
/// "Percentage" column): bits per KB over 8192 bits per KB.
pub fn metadata_overhead_fraction(mode: DivisionMode, hw: &Hardware) -> f64 {
    metadata_bits_per_kb(mode, hw) / (512.0 * 16.0)
}

/// Concrete per-block records for a packed map (used by the fetcher).
#[derive(Debug, Clone)]
pub struct BlockRecord {
    /// Word address of the block's first sub-tensor (line-aligned).
    pub pointer_words: u64,
    /// Compressed sizes (words) of the block's sub-tensors in raster
    /// order (y-major, then x, for the block's segment ranges).
    pub sizes_words: Vec<u32>,
    /// Per-sub-tensor codec tags (registry ids), parallel to
    /// `sizes_words` — present only under
    /// [`crate::compress::CodecPolicy::Adaptive`] (empty = the map's
    /// uniform codec applies).
    pub codec_tags: Vec<u8>,
}

/// Record width in bits for a division under a codec policy: the Fig. 7
/// base record plus, in adaptive mode, one
/// [`crate::compress::TAG_BITS`]-bit codec tag per record slot (the
/// record format is fixed-width, so every record pays the division's
/// maximum slot count, exactly like the base size fields). This is the
/// single constant the packer, store writer, fetcher and pricer all
/// account metadata traffic with.
pub fn record_bits_for(division: &Division, policy: crate::compress::CodecPolicy) -> usize {
    division.meta_bits_per_block
        + if policy.is_adaptive() {
            crate::compress::TAG_BITS * division.record_slots()
        } else {
            0
        }
}

/// The metadata table: one record per (block_y, block_x, cgroup).
#[derive(Debug, Clone)]
pub struct MetadataTable {
    pub records: Vec<BlockRecord>,
    pub bits_per_record: usize,
}

impl MetadataTable {
    pub fn total_bits(&self) -> u64 {
        self.records.len() as u64 * self.bits_per_record as u64
    }

    pub fn record(&self, division: &Division, block_linear: usize) -> &BlockRecord {
        debug_assert!(block_linear < division.n_blocks());
        &self.records[block_linear]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;

    #[test]
    fn size_bits_match_paper_examples() {
        // §III-C: G={1,7}: sub-tensors 2x2/2x6/6x2/6x6 ×8ch at 16-byte
        // lines -> 64, 192, 192, 576 bytes -> 3+4+4+6 = 17 bits.
        assert_eq!(size_field_bits_for(2, 6, 8, 8), 17);
        // G={2,6} (kernels 5 and 9): 4x4 splits -> 5+5+5+5 = 20 bits.
        assert_eq!(size_field_bits_for(4, 4, 8, 8), 20);
    }

    #[test]
    fn size_bits_for_lines_basics() {
        assert_eq!(size_bits_for_lines(4), 3); // 0..=4 needs 3 bits
        assert_eq!(size_bits_for_lines(12), 4);
        assert_eq!(size_bits_for_lines(36), 6);
        assert_eq!(size_bits_for_lines(16), 5);
    }

    /// Table II, all six rows.
    #[test]
    fn table2_bits_per_kb() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let cases = [
            (DivisionMode::GrateTile { n: 4 }, 192.0),
            (DivisionMode::GrateTile { n: 8 }, 48.0),
            (DivisionMode::GrateTile { n: 16 }, 12.0),
            (DivisionMode::Uniform { edge: 8 }, 28.0),
            (DivisionMode::Uniform { edge: 4 }, 112.0),
            (DivisionMode::Uniform { edge: 2 }, 448.0),
            (DivisionMode::Uniform { edge: 1 }, 2048.0),
        ];
        for (mode, expect) in cases {
            let got = metadata_bits_per_kb(mode, &hw);
            assert!((got - expect).abs() < 1e-9, "{}: {got} != {expect}", mode.name());
        }
    }

    /// Table II percentage column.
    #[test]
    fn table2_percentages() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let pct = |m| metadata_overhead_fraction(m, &hw) * 100.0;
        assert!((pct(DivisionMode::GrateTile { n: 8 }) - 0.59).abs() < 0.01);
        assert!((pct(DivisionMode::GrateTile { n: 4 }) - 2.34).abs() < 0.03);
        assert!((pct(DivisionMode::GrateTile { n: 16 }) - 0.15).abs() < 0.01);
        assert!((pct(DivisionMode::Uniform { edge: 8 }) - 0.34).abs() < 0.01);
        assert!((pct(DivisionMode::Uniform { edge: 4 }) - 1.37).abs() < 0.01);
        assert!((pct(DivisionMode::Uniform { edge: 2 }) - 5.47).abs() < 0.01);
        assert!((pct(DivisionMode::Uniform { edge: 1 }) - 25.0).abs() < 0.01);
    }

    /// §III-C example: AlexNet CONV2-sized metadata with 32-bit pointers
    /// per 8-word sub-tensor would be ~72 kB — too big for SRAM, hence
    /// the DRAM-resident design.
    #[test]
    fn alexnet_conv2_naive_metadata_is_sram_hostile() {
        // 27*27*96 words fm, 8-word sub-tensors, 32-bit pointers.
        let words = 27 * 27 * 96u64;
        let pointer_bytes = (words / 8) * 4;
        assert!(pointer_bytes > 32 * 1024, "{pointer_bytes} bytes");
    }
}
