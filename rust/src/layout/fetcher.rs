//! The runtime access path: fetch compressed sub-tensors and assemble a
//! dense tile on-the-fly (paper Fig. 2c, §III-A).
//!
//! This is what the memory controller of a GrateTile-enabled accelerator
//! does per processing tile: (1) read the block metadata records the
//! window touches, (2) two-step address computation (block pointer +
//! size prefix), (3) fetch whole compressed sub-tensors, (4) decompress
//! into the tile's dense working buffer. All DRAM traffic is accounted
//! against a [`Dram`] so the coordinator's end-to-end numbers match the
//! analytic simulator.
//!
//! ## Window-decode fast path (§Perf)
//!
//! Two software optimisations keep the simulator's wall-clock off the
//! decode floor **without touching the modeled traffic** (DRAM
//! accounting is identical with or without them — property-tested):
//!
//! * **Popcount row-skipping** — a window that covers a sub-tensor only
//!   partially (uniform divisions split windows, Fig. 3a) decodes just
//!   the covered rows via [`crate::compress::Compressor::decompress_span`]: the bitmask
//!   codec skips to any element in O(mask words) by popcounting the
//!   mask prefix. [`Fetcher::decoded_words`] exposes the saving.
//! * **Decoded-sub-tensor LRU** ([`Fetcher::with_cache`]) — tiled
//!   convolution re-touches the same halo sub-tensors from adjacent
//!   windows; a small LRU returns the previous decode instead of
//!   re-running the codec.
//!
//! The two are *alternative* policies for a partially covered
//! sub-tensor: with the LRU on (the pipeline's prefetch lanes, where
//!   halo re-touches are guaranteed by the tile schedule) a partial miss
//! decodes fully so neighbours can hit; with it off (the default
//! `Fetcher::new`/`with_source` used by container serving and store
//! reads, where windows are arbitrary) partial coverage takes the
//! row-skip path.
//!
//! Window buffers come from an internal pool refilled by
//! [`Fetcher::recycle`], so a steady-state pipeline allocates nothing
//! per window.

// Decoder surface: unwrap() is a denied panic path in production
// code (tests may unwrap freely).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::packer::PackedFeatureMap;
use crate::compress::CompressedBlock;
use crate::memsim::{Dram, Stream};
use crate::tiling::division::{Division, Seg, SubTensorRef};

/// Dense window assembled by a fetch: `[y0,y1) × [x0,x1) × [c0,c1)` in
/// row-major (y, x, c) order.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseWindow {
    pub y0: usize,
    pub y1: usize,
    pub x0: usize,
    pub x1: usize,
    pub c0: usize,
    pub c1: usize,
    pub data: Vec<f32>,
}

impl DenseWindow {
    pub fn get(&self, y: usize, x: usize, ch: usize) -> f32 {
        debug_assert!(y >= self.y0 && y < self.y1);
        debug_assert!(x >= self.x0 && x < self.x1);
        debug_assert!(ch >= self.c0 && ch < self.c1);
        let w = self.x1 - self.x0;
        let c = self.c1 - self.c0;
        self.data[((y - self.y0) * w + (x - self.x0)) * c + (ch - self.c0)]
    }
}

/// Where a fetch reads its compressed words from. The layout
/// (`PackedFeatureMap`) describes *where* each sub-tensor lives; the
/// payload source is *what* is stored there — an in-memory pack, a
/// snapshot of the store's simulated DRAM, or a `.grate` container
/// segment on disk. Addresses are 16-bit-word addresses in whatever
/// space the layout's `addr_words` were assigned in.
pub trait PayloadSource: Send {
    /// Append `n_words` payload words starting at `addr_words` to `out`.
    fn read_words(&mut self, addr_words: u64, n_words: usize, out: &mut Vec<u16>);
}

/// Contiguous in-memory payload (a `Packer`-materialised map, address 0
/// = first payload word).
pub struct SlicePayload<'a>(pub &'a [u16]);

impl PayloadSource for SlicePayload<'_> {
    fn read_words(&mut self, addr_words: u64, n_words: usize, out: &mut Vec<u16>) {
        let a = addr_words as usize;
        out.extend_from_slice(&self.0[a..a + n_words]);
    }
}

/// Scattered extents of a larger address space (a tensor-store
/// snapshot): `(base_addr, words)` sorted by base. A sub-tensor read
/// never crosses an extent, because every extent holds whole metadata
/// blocks.
pub struct SegmentPayload {
    pub segs: Vec<(u64, Vec<u16>)>,
}

impl PayloadSource for SegmentPayload {
    fn read_words(&mut self, addr_words: u64, n_words: usize, out: &mut Vec<u16>) {
        let i = self.segs.partition_point(|s| s.0 <= addr_words);
        assert!(i > 0, "address {addr_words} below every payload segment");
        let (base, words) = &self.segs[i - 1];
        let off = (addr_words - base) as usize;
        out.extend_from_slice(&words[off..off + n_words]);
    }
}

/// Verify-on-fetch configuration ([`Fetcher::with_integrity`]): every
/// payload read is hashed against the map's per-sub-tensor checksum
/// table (`.grate` v3). On a mismatch the sub-tensor is re-read from
/// the source up to `retry_budget` times with exponential backoff in
/// *simulated* cycles; if every attempt fails the sub-tensor is
/// quarantined and an all-zero substitute is served (the request
/// completes, flagged degraded, instead of failing the whole layer —
/// the graceful-degradation story GrateTile's independently
/// checksummable sub-tensors make cheap, paper §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntegrityPolicy {
    /// Re-reads attempted per corrupt read before degrading to the
    /// all-zero substitute. 0 disables recovery (detect-only).
    pub retry_budget: u32,
    /// Simulated-cycle cost of the first re-read; doubles on each
    /// further attempt. Accumulated in
    /// [`FetchCounters::retry_backoff_cycles`] and charged to the
    /// layer's simulated time by the serving timing pass.
    pub backoff_cycles: u64,
}

impl Default for IntegrityPolicy {
    fn default() -> Self {
        Self { retry_budget: 3, backoff_cycles: 64 }
    }
}

/// LRU of decoded sub-tensors, keyed by linear sub-tensor index. Small
/// (a few dozen entries), so a stamped linear scan beats any map.
/// Evicted entries donate their buffers to the replacement, so the
/// steady state allocates nothing.
struct DecodedCache {
    cap: usize,
    tick: u64,
    entries: Vec<(usize, u64, Vec<f32>)>,
}

impl DecodedCache {
    fn new(cap: usize) -> Self {
        Self { cap, tick: 0, entries: Vec::with_capacity(cap) }
    }

    fn get(&mut self, li: usize) -> Option<&[f32]> {
        self.tick += 1;
        let now = self.tick;
        self.entries.iter_mut().find(|e| e.0 == li).map(|e| {
            e.1 = now;
            e.2.as_slice()
        })
    }

    fn insert(&mut self, li: usize, data: &[f32]) {
        self.tick += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == li) {
            e.1 = self.tick;
            e.2.clear();
            e.2.extend_from_slice(data);
            return;
        }
        // Evict the least-recently-stamped entry once full and recycle
        // its buffer (a cap of 0 degrades to cap 1 rather than panicking).
        let lru = if self.entries.len() >= self.cap {
            self.entries.iter().enumerate().min_by_key(|(_, e)| e.1).map(|(i, _)| i)
        } else {
            None
        };
        let mut buf = match lru {
            Some(i) => self.entries.swap_remove(i).2,
            None => Vec::new(),
        };
        buf.clear();
        buf.extend_from_slice(data);
        self.entries.push((li, self.tick, buf));
    }
}

/// Fetches windows from a packed feature map. The codec of each
/// sub-tensor comes from the map's [`crate::compress::CodecPolicy`] —
/// a mixed-codec (adaptive) map decodes each sub-tensor with the codec
/// its 2-bit record tag names, via the shared
/// [`crate::compress::Registry`] (no per-fetch allocation).
pub struct Fetcher<'a> {
    packed: &'a PackedFeatureMap,
    scratch: Vec<f32>,
    comp_words: Vec<u16>,
    source: Box<dyn PayloadSource + 'a>,
    cache: Option<DecodedCache>,
    pool: Vec<Vec<f32>>,
    decoded_words: u64,
    zero_skip: bool,
    skipped_subtensors: u64,
    skipped_spans: u64,
    cache_hits: u64,
    track_occupancy: bool,
    occ_rows: Vec<bool>,
    /// Verify-on-fetch policy (None = trust every read, the pre-v3
    /// behaviour). Verification also needs a non-empty checksum table
    /// on the map; pre-v3 maps fetch unverified either way.
    integrity: Option<IntegrityPolicy>,
    /// Sub-tensors that exhausted their retry budget: later touches
    /// skip the (deterministically futile) re-reads and go straight to
    /// the zero substitute.
    quarantined: Vec<bool>,
    verified_reads: u64,
    checksum_mismatches: u64,
    retried_reads: u64,
    recovered_reads: u64,
    degraded_subtensors: u64,
    retry_backoff_cycles: u64,
}

/// Snapshot of a fetcher's datapath counters, absorbed into
/// [`crate::coordinator::PipelineMetrics`] (and from there the
/// observability layer) when a pipeline lane retires its fetcher.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FetchCounters {
    pub decoded_words: u64,
    pub cache_hits: u64,
    pub skipped_subtensors: u64,
    pub skipped_spans: u64,
    /// Payload reads hashed against the v3 checksum table.
    pub verified_reads: u64,
    /// Reads whose hash disagreed with the table (initial + retry
    /// attempts both count — a retry storm shows up here).
    pub checksum_mismatches: u64,
    /// Re-reads issued by the bounded retry loop.
    pub retried_reads: u64,
    /// Corrupt reads a re-read recovered bit-exactly (the request stays
    /// silently correct).
    pub recovered_reads: u64,
    /// Zero-substitution events: a fetch served the all-zero substitute
    /// because retries were exhausted (or the sub-tensor was already
    /// quarantined). Any nonzero value flags the consuming request
    /// `degraded`.
    pub degraded_subtensors: u64,
    /// Simulated-cycle cost of retry backoff, charged to the layer's
    /// time by the serving timing pass.
    pub retry_backoff_cycles: u64,
}

/// Recycled window buffers kept at most (beyond this they drop).
const POOL_CAP: usize = 8;

impl<'a> Fetcher<'a> {
    pub fn new(packed: &'a PackedFeatureMap) -> Self {
        assert!(
            packed.payload.is_some(),
            "fetcher requires a payload-packed map (pack with with_payload=true)"
        );
        #[allow(clippy::unwrap_used)] // guarded by the assert directly above
        // lint: allow(panic-in-decoder, constructor contract - the assert above rejects payload-less maps before this unwrap)
        let payload = packed.payload.as_ref().unwrap().as_slice();
        Self::with_source(packed, Box::new(SlicePayload(payload)))
    }

    /// Read through an explicit payload source (store snapshot, `.grate`
    /// container segment, ...); `packed.addr_words` must be addresses in
    /// the source's space.
    pub fn with_source(
        packed: &'a PackedFeatureMap,
        source: Box<dyn PayloadSource + 'a>,
    ) -> Self {
        Self {
            packed,
            scratch: Vec::new(),
            comp_words: Vec::new(),
            source,
            cache: None,
            pool: Vec::new(),
            decoded_words: 0,
            zero_skip: true,
            skipped_subtensors: 0,
            skipped_spans: 0,
            cache_hits: 0,
            track_occupancy: false,
            occ_rows: Vec::new(),
            integrity: None,
            quarantined: Vec::new(),
            verified_reads: 0,
            checksum_mismatches: 0,
            retried_reads: 0,
            recovered_reads: 0,
            degraded_subtensors: 0,
            retry_backoff_cycles: 0,
        }
    }

    /// Enable the decoded-sub-tensor LRU (`capacity` sub-tensors;
    /// 0 disables). Purely a software-speed knob: window contents and
    /// DRAM accounting are bit-identical with the cache on or off.
    pub fn with_cache(mut self, capacity: usize) -> Self {
        self.cache = (capacity > 0).then(|| DecodedCache::new(capacity));
        self
    }

    /// Toggle the zero-skip decode bypass (on by default). Purely a
    /// software-speed knob like the LRU: window contents and DRAM
    /// accounting are bit-identical with it on or off — the occupancy
    /// query reads only the codec's index metadata, and the window
    /// buffer is pre-zeroed, so an all-zero sub-tensor's decode + copy
    /// are pure no-ops.
    pub fn with_zero_skip(mut self, enabled: bool) -> Self {
        self.zero_skip = enabled;
        self
    }

    /// Enable verify-on-fetch under `policy` (off by default). Needs a
    /// map with a populated checksum table (v3 containers, any freshly
    /// packed/streamed map); on a pre-v3 map this is a no-op and every
    /// read stays unverified. In the fault-free case the only cost is
    /// one FNV-1a pass over each compressed read — gated < 3% end to
    /// end by `benches/perf_chaos.rs`.
    pub fn with_integrity(mut self, policy: IntegrityPolicy) -> Self {
        self.quarantined = vec![false; self.packed.division.n_subtensors()];
        self.integrity = Some(policy);
        self
    }

    /// Track per-window-row occupancy during fetches (off by default).
    /// When on, [`Fetcher::row_occupancy`] reports, for each row of the
    /// most recent window, whether it *may* contain nonzeros: `false`
    /// entries are **proven** all-zero from the codecs' metadata-only
    /// occupancy index (no value decode), `true` is the conservative
    /// answer everywhere else (LRU hits, full decodes, codecs without
    /// an index). The GEMM backend's `ZeroSkip` policy consumes this to
    /// drop whole im2col row spans before they reach the kernel.
    pub fn with_occupancy(mut self, enabled: bool) -> Self {
        self.track_occupancy = enabled;
        self
    }

    /// Row-occupancy index of the most recent [`Fetcher::fetch_window`]
    /// (window-relative: entry `i` covers map row `y0 + i`). Empty
    /// unless tracking was enabled via [`Fetcher::with_occupancy`].
    /// `false` = the row is certainly all zero across the whole fetched
    /// window; `true` = it may contain nonzeros.
    pub fn row_occupancy(&self) -> &[bool] {
        &self.occ_rows
    }

    /// Dense elements materialised by decompression so far — the
    /// partial-window fast path's saving shows up here (a full decode
    /// of a sub-tensor costs its whole element count; a row-skipped one
    /// only the covered elements). LRU hits decode nothing.
    pub fn decoded_words(&self) -> u64 {
        self.decoded_words
    }

    /// Sub-tensors whose decode was bypassed entirely because the
    /// metadata-only occupancy query answered "all zero".
    pub fn skipped_subtensors(&self) -> u64 {
        self.skipped_subtensors
    }

    /// Partial-window row spans bypassed because their occupancy count
    /// was zero (the window row stayed at its pre-zeroed contents).
    pub fn skipped_spans(&self) -> u64 {
        self.skipped_spans
    }

    /// Decoded-sub-tensor LRU hits (0 when the cache is disabled).
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    /// All datapath counters at once — what the pipeline absorbs into
    /// its metrics when the fetch lane retires.
    pub fn counters(&self) -> FetchCounters {
        FetchCounters {
            decoded_words: self.decoded_words,
            cache_hits: self.cache_hits,
            skipped_subtensors: self.skipped_subtensors,
            skipped_spans: self.skipped_spans,
            verified_reads: self.verified_reads,
            checksum_mismatches: self.checksum_mismatches,
            retried_reads: self.retried_reads,
            recovered_reads: self.recovered_reads,
            degraded_subtensors: self.degraded_subtensors,
            retry_backoff_cycles: self.retry_backoff_cycles,
        }
    }

    /// Zero-substitution events so far (see
    /// [`FetchCounters::degraded_subtensors`]).
    pub fn degraded_subtensors(&self) -> u64 {
        self.degraded_subtensors
    }

    /// Return a spent window's buffer to the fetch pool (the pipeline's
    /// compute lane hands windows back so steady-state fetching
    /// allocates nothing).
    pub fn recycle(&mut self, win: DenseWindow) {
        if self.pool.len() < POOL_CAP {
            self.pool.push(win.data);
        }
    }

    /// Fetch a clipped window, decompressing every intersecting
    /// sub-tensor; traffic is accounted on `dram`. Elements of fetched
    /// sub-tensors that fall outside the requested window are *moved*
    /// (the over-fetch the paper's division scheme is designed to
    /// avoid) but no longer necessarily *decoded* — see the module
    /// docs' fast path.
    pub fn fetch_window(
        &mut self,
        dram: &mut Dram,
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
    ) -> DenseWindow {
        let div = &self.packed.division;
        assert!(y1 <= div.fm_h && x1 <= div.fm_w && c1 <= div.fm_c);
        let (wh, ww, wc) = (y1 - y0, x1 - x0, c1 - c0);
        let mut out = self.pool.pop().unwrap_or_default();
        out.clear();
        out.resize(wh * ww * wc, 0.0);
        if self.track_occupancy {
            // Rows start "proven zero" and are promoted to maybe-nonzero
            // by every fetch path that lands data (or can't rule it out).
            self.occ_rows.clear();
            self.occ_rows.resize(wh, false);
        }

        // Metadata reads: one record per touched block, once per fetch.
        // The touched blocks form an axis-aligned box (block ids are
        // non-decreasing along each axis), so walk the block ranges
        // directly instead of deduplicating per sub-tensor (the old
        // `touched_blocks.contains` scan was O(touched²)). The record
        // width is policy-aware: adaptive maps pay the 2-bit codec tags
        // as part of each record read.
        let record_bits = self.packed.record_bits() as u64;
        let yr = Division::covering(&div.ys, y0, y1);
        let xr = Division::covering(&div.xs, x0, x1);
        let cg0 = c0 / div.cd;
        let cg1 = c1.div_ceil(div.cd).min(div.n_cgroups);
        if !yr.is_empty() && !xr.is_empty() && cg0 < cg1 {
            let n_by = div.block_of_y[yr.end - 1] - div.block_of_y[yr.start] + 1;
            let n_bx = div.block_of_x[xr.end - 1] - div.block_of_x[xr.start] + 1;
            for _ in 0..n_by * n_bx * (cg1 - cg0) {
                dram.account_bits(Stream::MetadataRead, record_bits);
            }
        }

        for r in div.intersecting(y0, y1, x0, x1, c0, c1) {
            self.fetch_subtensor(dram, r, &mut out, y0, y1, x0, x1, c0, c1);
        }
        DenseWindow { y0, y1, x0, x1, c0, c1, data: out }
    }

    #[allow(clippy::too_many_arguments)]
    fn fetch_subtensor(
        &mut self,
        dram: &mut Dram,
        r: SubTensorRef,
        out: &mut [f32],
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
    ) {
        let div: &Division = &self.packed.division;
        let li = div.linear(r);
        let codec = self.packed.compressor_of(li);
        let addr = self.packed.addr_words[li];
        let size = self.packed.sizes_words[li] as u64;
        // The whole compressed sub-tensor moves (not randomly accessible
        // inside); line accounting via the span. This is the *hardware*
        // traffic model and is deliberately independent of the software
        // decode strategy below — an LRU hit or a row-skipped decode
        // moves exactly the same modeled lines.
        dram.access(Stream::FeatureRead, addr, size.max(if div.compact { 0 } else { 1 }));

        let sy = div.ys[r.iy];
        let sx = div.xs[r.ix];
        let scg0 = r.icg * div.cd;
        let cd = div.cg_depth(r.icg);
        let n = sy.len * sx.len * cd;

        // Window ∩ sub-tensor box.
        let iy0 = sy.start.max(y0);
        let iy1 = sy.end().min(y1);
        let ix0 = sx.start.max(x0);
        let ix1 = sx.end().min(x1);
        let ic0 = scg0.max(c0);
        let ic1 = (scg0 + cd).min(c1);
        let clip = (iy0, iy1, ix0, ix1, ic0, ic1);
        let full = iy0 == sy.start
            && iy1 == sy.end()
            && ix0 == sx.start
            && ix1 == sx.end()
            && ic0 == scg0
            && ic1 == scg0 + cd;

        // LRU hit: adjacent windows re-touching a halo sub-tensor copy
        // the previous decode instead of re-running the codec.
        if let Some(cache) = self.cache.as_mut() {
            if let Some(data) = cache.get(li) {
                self.cache_hits += 1;
                let win = (y0, x0, c0, x1 - x0, c1 - c0);
                copy_intersection(data, out, sy, sx, scg0, cd, clip, win);
                if self.track_occupancy {
                    // Conservative: a cached decode may hold nonzeros.
                    for y in iy0..iy1 {
                        self.occ_rows[y - y0] = true;
                    }
                }
                return;
            }
        }

        self.comp_words.clear();
        self.source.read_words(addr, size as usize, &mut self.comp_words);
        let mut comp = CompressedBlock {
            n_elems: n,
            words: std::mem::take(&mut self.comp_words),
        };

        // Integrity layer: hash the read against the v3 checksum table.
        // A mismatch triggers bounded re-reads — each a real modeled
        // DRAM access plus exponential backoff in simulated cycles; an
        // unrecoverable sub-tensor is quarantined and served all-zero.
        // The window is pre-zeroed and the access above already moved
        // the modeled lines, so the degraded early return keeps window
        // shape and traffic accounting intact.
        if let Some(pol) = self.integrity {
            if let Some(&want) = self.packed.checksums.get(li) {
                self.verified_reads += 1;
                if crate::store::container::fnv1a64_words(&comp.words) != want {
                    self.checksum_mismatches += 1;
                    let mut recovered = false;
                    if !self.quarantined[li] {
                        let mut backoff = pol.backoff_cycles;
                        for _ in 0..pol.retry_budget {
                            self.retried_reads += 1;
                            self.retry_backoff_cycles += backoff;
                            backoff = backoff.saturating_mul(2);
                            comp.words.clear();
                            self.source.read_words(addr, size as usize, &mut comp.words);
                            dram.access(
                                Stream::FeatureRead,
                                addr,
                                size.max(if div.compact { 0 } else { 1 }),
                            );
                            if crate::store::container::fnv1a64_words(&comp.words) == want {
                                recovered = true;
                                self.recovered_reads += 1;
                                break;
                            }
                            self.checksum_mismatches += 1;
                        }
                    }
                    if !recovered {
                        self.quarantined[li] = true;
                        self.degraded_subtensors += 1;
                        self.comp_words = comp.words;
                        return;
                    }
                }
            }
        }

        // Zero-skip: the metadata-only occupancy query (for bitmask, an
        // O(1) payload-length test — no value decode) lets an all-zero
        // sub-tensor bypass decode and copy entirely. The window buffer
        // is pre-zeroed and the modeled DRAM access above has already
        // been issued, so this is invisible to both window contents and
        // traffic accounting.
        if self.zero_skip && codec.is_all_zero(&comp) == Some(true) {
            self.skipped_subtensors += 1;
            self.comp_words = comp.words;
            return;
        }

        // Partial-window fast path: decode only the covered rows.
        // (With the LRU on, a partially covered sub-tensor is decoded
        // fully instead so the halo neighbours can hit the cache.)
        if !full && self.cache.is_none() {
            let run = ic1 - ic0;
            let (ww, wc) = (x1 - x0, c1 - c0);
            // Decode-fusion seam: when consecutive x cells are adjacent
            // in both the compressed stream (full sub-tensor channel
            // depth) and the window buffer (window depth == run, same
            // channel origin), each covered row is ONE contiguous span —
            // decoded word-at-a-time straight into the window buffer,
            // no scratch staging. All-zero rows skip the decode via the
            // occupancy index and leave the pre-zeroed row untouched.
            if run == cd && run == wc && ic0 == c0 {
                let rowlen = (ix1 - ix0) * cd;
                let mut fast = true;
                for y in iy0..iy1 {
                    let start = ((y - sy.start) * sx.len + (ix0 - sx.start)) * cd;
                    if self.zero_skip
                        && codec.span_nonzeros(&comp, start, rowlen) == Some(0)
                    {
                        self.skipped_spans += 1;
                        continue;
                    }
                    let dst = ((y - y0) * ww + (ix0 - x0)) * wc;
                    if !codec.decompress_span(&comp, start, &mut out[dst..dst + rowlen]) {
                        // Codec cannot random-access its stream (first
                        // call, nothing decoded yet) — full decode below.
                        fast = false;
                        break;
                    }
                    self.decoded_words += rowlen as u64;
                    if self.track_occupancy {
                        self.occ_rows[y - y0] = true;
                    }
                }
                if fast {
                    self.comp_words = comp.words;
                    return;
                }
            } else {
                self.scratch.clear();
                self.scratch.resize(run, 0.0);
                let mut fast = true;
                'rows: for y in iy0..iy1 {
                    for x in ix0..ix1 {
                        let start =
                            ((y - sy.start) * sx.len + (x - sx.start)) * cd + (ic0 - scg0);
                        if self.zero_skip
                            && codec.span_nonzeros(&comp, start, run) == Some(0)
                        {
                            self.skipped_spans += 1;
                            continue;
                        }
                        if !codec.decompress_span(&comp, start, &mut self.scratch[..run]) {
                            fast = false;
                            break 'rows;
                        }
                        self.decoded_words += run as u64;
                        if self.track_occupancy {
                            self.occ_rows[y - y0] = true;
                        }
                        let dst = ((y - y0) * ww + (x - x0)) * wc + (ic0 - c0);
                        out[dst..dst + run].copy_from_slice(&self.scratch[..run]);
                    }
                }
                if fast {
                    self.comp_words = comp.words;
                    return;
                }
            }
        }

        self.scratch.clear();
        self.scratch.resize(n, 0.0);
        codec.decompress(&comp, &mut self.scratch);
        self.decoded_words += n as u64;
        copy_intersection(
            &self.scratch,
            out,
            sy,
            sx,
            scg0,
            cd,
            clip,
            (y0, x0, c0, x1 - x0, c1 - c0),
        );
        if let Some(cache) = self.cache.as_mut() {
            cache.insert(li, &self.scratch);
        }
        if self.track_occupancy {
            // Full decodes cover most interior sub-tensors, so refine
            // per row from the occupancy index (metadata-only popcount)
            // rather than conservatively marking everything; a codec
            // without an index answers `None` and the row stays the
            // conservative `true`.
            let run = ic1 - ic0;
            for y in iy0..iy1 {
                if self.occ_rows[y - y0] {
                    continue;
                }
                let zero = if run == cd {
                    let start = ((y - sy.start) * sx.len + (ix0 - sx.start)) * cd;
                    codec.span_nonzeros(&comp, start, (ix1 - ix0) * cd) == Some(0)
                } else {
                    (ix0..ix1).all(|x| {
                        let start =
                            ((y - sy.start) * sx.len + (x - sx.start)) * cd + (ic0 - scg0);
                        codec.span_nonzeros(&comp, start, run) == Some(0)
                    })
                };
                if !zero {
                    self.occ_rows[y - y0] = true;
                }
            }
        }
        self.comp_words = comp.words;
    }
}

/// Copy a decoded sub-tensor's intersection with the window into the
/// window buffer (`win` = `(y0, x0, c0, window width, window depth)`).
#[allow(clippy::too_many_arguments)]
fn copy_intersection(
    src: &[f32],
    out: &mut [f32],
    sy: Seg,
    sx: Seg,
    scg0: usize,
    cd: usize,
    clip: (usize, usize, usize, usize, usize, usize),
    win: (usize, usize, usize, usize, usize),
) {
    let (iy0, iy1, ix0, ix1, ic0, ic1) = clip;
    let (y0, x0, c0, ww, wc) = win;
    let run = ic1 - ic0;
    for y in iy0..iy1 {
        for x in ix0..ix1 {
            let s = ((y - sy.start) * sx.len + (x - sx.start)) * cd + (ic0 - scg0);
            let d = ((y - y0) * ww + (x - x0)) * wc + (ic0 - c0);
            out[d..d + run].copy_from_slice(&src[s..s + run]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::layout::packer::Packer;
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tensor::FeatureMap;
    use crate::tiling::division::DivisionMode;

    fn packed_map(
        mode: DivisionMode,
        scheme: Scheme,
    ) -> (FeatureMap, PackedFeatureMap) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division = crate::tiling::Division::build(mode, &layer, &tile, &hw, 24, 24, 16)
            .unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, 21));
        let packed = Packer::new(hw, scheme).pack(&fm, &division, true);
        (fm, packed)
    }

    fn check_window(
        fm: &FeatureMap,
        packed: &PackedFeatureMap,
        (y0, y1, x0, x1, c0, c1): (usize, usize, usize, usize, usize, usize),
    ) {
        let mut dram = Dram::default();
        let mut fetcher = Fetcher::new(packed);
        let win = fetcher.fetch_window(&mut dram, y0, y1, x0, x1, c0, c1);
        for y in y0..y1 {
            for x in x0..x1 {
                for ch in c0..c1 {
                    assert_eq!(
                        win.get(y, x, ch),
                        fm.get(y, x, ch),
                        "mismatch at ({y},{x},{ch})"
                    );
                }
            }
        }
        assert!(dram.lines_of(Stream::FeatureRead) > 0);
    }

    #[test]
    fn full_map_roundtrip_all_schemes() {
        for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
            let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, scheme);
            check_window(&fm, &packed, (0, 24, 0, 24, 0, 16));
        }
    }

    #[test]
    fn partial_windows_roundtrip() {
        let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        for w in [
            (0usize, 10usize, 0usize, 10usize, 0usize, 8usize),
            (7, 17, 7, 17, 0, 16),
            (15, 24, 15, 24, 8, 16),
            (1, 2, 1, 2, 0, 8),
        ] {
            check_window(&fm, &packed, w);
        }
    }

    /// Partial windows over a *splitting* division exercise the
    /// row-skipped span decode for every codec that supports it, and
    /// the full-decode fallback for the rest.
    #[test]
    fn partial_windows_roundtrip_all_schemes_uniform() {
        for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
            let (fm, packed) = packed_map(DivisionMode::Uniform { edge: 8 }, scheme);
            for w in [
                (0usize, 10usize, 0usize, 10usize, 0usize, 8usize),
                (3, 19, 5, 21, 2, 14),
                (9, 10, 9, 10, 0, 16),
            ] {
                check_window(&fm, &packed, w);
            }
        }
    }

    #[test]
    fn uniform_divisions_also_roundtrip() {
        for edge in [1usize, 2, 4, 8] {
            let (fm, packed) = packed_map(DivisionMode::Uniform { edge }, Scheme::Bitmask);
            check_window(&fm, &packed, (3, 19, 5, 21, 0, 16));
        }
    }

    #[test]
    fn metadata_traffic_counted_once_per_block() {
        let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let mut dram = Dram::default();
        let mut fetcher = Fetcher::new(&packed);
        // Window [7,17)x[7,17)x[0,8): 9 sub-tensors across 4 blocks.
        let _ = fetcher.fetch_window(&mut dram, 7, 17, 7, 17, 0, 8);
        // 4 blocks x 48 bits -> 192 bits -> 12 words.
        assert_eq!(dram.words_of(Stream::MetadataRead), 12);
    }

    #[test]
    fn larger_window_fetches_more() {
        let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let mut fetcher = Fetcher::new(&packed);
        let mut d1 = Dram::default();
        let _ = fetcher.fetch_window(&mut d1, 0, 9, 0, 9, 0, 8);
        let mut d2 = Dram::default();
        let _ = fetcher.fetch_window(&mut d2, 0, 17, 0, 17, 0, 16);
        assert!(
            d2.lines_of(Stream::FeatureRead) > d1.lines_of(Stream::FeatureRead)
        );
    }

    /// The partial-window fast path decodes strictly fewer elements
    /// than a whole-sub-tensor decode would, on a window that splits
    /// sub-tensors (uniform grids do; Fig. 3a).
    #[test]
    fn partial_window_decodes_fewer_words() {
        let (fm, packed) = packed_map(DivisionMode::Uniform { edge: 8 }, Scheme::Bitmask);
        let (y0, y1, x0, x1, c0, c1) = (0usize, 10usize, 0usize, 10usize, 0usize, 8usize);
        let touched: u64 = packed
            .division
            .intersecting(y0, y1, x0, x1, c0, c1)
            .iter()
            .map(|&r| packed.division.subtensor_words(r) as u64)
            .sum();
        let mut dram = Dram::default();
        // Zero-skip off: this test pins the *row-clipping* saving alone
        // (with it on, all-zero rows would additionally skip decode and
        // the lower bound below would not hold).
        let mut fetcher = Fetcher::new(&packed).with_zero_skip(false);
        let win = fetcher.fetch_window(&mut dram, y0, y1, x0, x1, c0, c1);
        assert!(
            fetcher.decoded_words() < touched,
            "row-skip decoded {} of {touched} touched words",
            fetcher.decoded_words()
        );
        // And at least the window itself was materialised, correctly.
        assert!(fetcher.decoded_words() >= ((y1 - y0) * (x1 - x0) * (c1 - c0)) as u64);
        for y in y0..y1 {
            for x in x0..x1 {
                for ch in c0..c1 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch));
                }
            }
        }
    }

    /// LRU on vs off: identical window data AND identical DRAM
    /// accounting (the cache is a software-speed knob, not a traffic
    /// model change); overlapping windows hit the cache.
    #[test]
    fn lru_cache_is_traffic_invariant() {
        for scheme in [Scheme::Bitmask, Scheme::Zrlc] {
            let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, scheme);
            let windows = [
                (0usize, 10usize, 0usize, 10usize, 0usize, 16usize),
                (7, 17, 0, 10, 0, 16), // shares the halo row with the first
                (7, 17, 7, 17, 0, 16),
                (0, 24, 0, 24, 0, 16),
            ];
            let mut plain = Fetcher::new(&packed);
            // Capacity holds the windows' whole working set, so the
            // halo-overlap hits are deterministic.
            let mut cached = Fetcher::new(&packed).with_cache(64);
            let mut d_plain = Dram::default();
            let mut d_cached = Dram::default();
            for &(y0, y1, x0, x1, c0, c1) in &windows {
                let a = plain.fetch_window(&mut d_plain, y0, y1, x0, x1, c0, c1);
                let b = cached.fetch_window(&mut d_cached, y0, y1, x0, x1, c0, c1);
                assert_eq!(a, b, "{scheme:?} window ({y0},{y1},{x0},{x1})");
            }
            assert_eq!(
                d_plain.words_of(Stream::FeatureRead),
                d_cached.words_of(Stream::FeatureRead),
                "{scheme:?} feature traffic"
            );
            assert_eq!(
                d_plain.words_of(Stream::MetadataRead),
                d_cached.words_of(Stream::MetadataRead),
                "{scheme:?} metadata traffic"
            );
            // The overlapping windows actually hit: fewer decoded words,
            // and the hit counter says so while the uncached fetcher's
            // stays at zero.
            assert!(
                cached.decoded_words() < plain.decoded_words(),
                "{scheme:?} cache never hit ({} vs {})",
                cached.decoded_words(),
                plain.decoded_words()
            );
            assert!(cached.cache_hits() > 0, "{scheme:?} hit counter");
            assert_eq!(plain.cache_hits(), 0);
            let c = cached.counters();
            assert_eq!(c.cache_hits, cached.cache_hits());
            assert_eq!(c.decoded_words, cached.decoded_words());
        }
    }

    /// Zero-skip on vs off: bit-identical window data, bit-identical
    /// DRAM accounting, and on a clustered-sparse map the skip counters
    /// actually fire (all-zero sub-tensors exist at 40% clustered
    /// density) while decoding strictly fewer words.
    #[test]
    fn zero_skip_is_traffic_invariant_and_fires() {
        for scheme in [Scheme::Bitmask, Scheme::Zrlc] {
            let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, scheme);
            let windows = [
                (0usize, 10usize, 0usize, 10usize, 0usize, 16usize),
                (7, 17, 7, 17, 0, 16),
                (0, 24, 0, 24, 0, 16),
                (3, 19, 5, 21, 2, 14),
            ];
            let mut skip = Fetcher::new(&packed);
            let mut noskip = Fetcher::new(&packed).with_zero_skip(false);
            let mut d_skip = Dram::default();
            let mut d_noskip = Dram::default();
            for &(y0, y1, x0, x1, c0, c1) in &windows {
                let a = skip.fetch_window(&mut d_skip, y0, y1, x0, x1, c0, c1);
                let b = noskip.fetch_window(&mut d_noskip, y0, y1, x0, x1, c0, c1);
                assert_eq!(a, b, "{scheme:?} window ({y0},{y1},{x0},{x1})");
            }
            for stream in [Stream::FeatureRead, Stream::MetadataRead] {
                assert_eq!(
                    d_skip.words_of(stream),
                    d_noskip.words_of(stream),
                    "{scheme:?} {stream:?} traffic"
                );
            }
            assert_eq!(noskip.skipped_subtensors() + noskip.skipped_spans(), 0);
            if scheme == Scheme::Bitmask {
                assert!(
                    skip.skipped_subtensors() + skip.skipped_spans() > 0,
                    "nothing skipped on a clustered 40% map"
                );
                assert!(
                    skip.decoded_words() < noskip.decoded_words(),
                    "skip decoded {} vs {}",
                    skip.decoded_words(),
                    noskip.decoded_words()
                );
            } else {
                // No occupancy index -> conservative: nothing skipped.
                assert_eq!(skip.skipped_subtensors(), 0);
                assert_eq!(skip.decoded_words(), noskip.decoded_words());
            }
        }
    }

    /// The row-occupancy index is sound (`false` ⇒ the window row is
    /// truly all zero) and, with an indexed codec over a map with
    /// planted zero rows, actually proves those rows zero.
    #[test]
    fn row_occupancy_is_sound_and_fires() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division = crate::tiling::Division::build(
            DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 24, 24, 16)
            .unwrap();
        let mut fm = generate(24, 24, 16, SparsityParams::clustered(0.4, 33));
        for y in 10..14 {
            for x in 0..24 {
                for ch in 0..16 {
                    fm.set(y, x, ch, 0.0);
                }
            }
        }
        for scheme in [Scheme::Bitmask, Scheme::Zrlc] {
            let packed = Packer::new(hw, scheme).pack(&fm, &division, true);
            let mut fetcher = Fetcher::new(&packed).with_occupancy(true);
            let mut dram = Dram::default();
            for (y0, y1) in [(0usize, 24usize), (6, 18), (11, 13)] {
                let win = fetcher.fetch_window(&mut dram, y0, y1, 0, 24, 0, 16);
                let occ = fetcher.row_occupancy().to_vec();
                assert_eq!(occ.len(), y1 - y0, "{scheme:?}");
                for (i, &maybe) in occ.iter().enumerate() {
                    if !maybe {
                        for x in 0..24 {
                            for ch in 0..16 {
                                assert_eq!(
                                    win.get(y0 + i, x, ch),
                                    0.0,
                                    "{scheme:?}: row {} marked zero but isn't",
                                    y0 + i
                                );
                            }
                        }
                    }
                }
                if scheme == Scheme::Bitmask {
                    // The planted zero band is provable from the mask.
                    for y in 10..14 {
                        if y >= y0 && y < y1 {
                            assert!(!occ[y - y0], "row {y} not proven zero");
                        }
                    }
                }
                fetcher.recycle(win);
            }
        }
    }

    /// Recycled window buffers are reused without leaking stale data.
    #[test]
    fn recycle_reuses_buffers_cleanly() {
        let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let mut fetcher = Fetcher::new(&packed);
        let mut dram = Dram::default();
        let big = fetcher.fetch_window(&mut dram, 0, 24, 0, 24, 0, 16);
        fetcher.recycle(big);
        let small = fetcher.fetch_window(&mut dram, 1, 2, 1, 2, 0, 8);
        assert_eq!(small.data.len(), 8);
        for ch in 0..8 {
            assert_eq!(small.get(1, 1, ch), fm.get(1, 1, ch));
        }
    }

    /// Reading through a scattered-segment source is identical to the
    /// contiguous in-memory path.
    #[test]
    fn segment_source_matches_slice_source() {
        let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Zrlc);
        let payload = packed.payload.as_ref().unwrap();
        // One segment per metadata block (extents hold whole blocks),
        // rebased to a scattered address space.
        let rebase = 1024u64;
        let mut ptrs: Vec<u64> =
            packed.metadata.records.iter().map(|r| r.pointer_words).collect();
        ptrs.push(payload.len() as u64);
        let segs: Vec<(u64, Vec<u16>)> = ptrs
            .windows(2)
            .map(|w| (rebase + w[0], payload[w[0] as usize..w[1] as usize].to_vec()))
            .collect();
        let mut rebased = packed.clone();
        rebased.payload = None;
        for a in &mut rebased.addr_words {
            *a += rebase;
        }
        let mut fetcher =
            Fetcher::with_source(&rebased, Box::new(SegmentPayload { segs }));
        let mut dram = Dram::default();
        let win = fetcher.fetch_window(&mut dram, 3, 20, 1, 17, 0, 16);
        for y in 3..20 {
            for x in 1..17 {
                for ch in 0..16 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch));
                }
            }
        }
    }

    /// Test source: corrupts the first `transient` reads of every
    /// address (a retry then reads clean) and every read of the
    /// `persistent` addresses.
    struct FlakySource<'a> {
        inner: SlicePayload<'a>,
        transient: u32,
        persistent: Vec<u64>,
        seen: std::collections::BTreeMap<u64, u32>,
    }

    impl PayloadSource for FlakySource<'_> {
        fn read_words(&mut self, addr: u64, n: usize, out: &mut Vec<u16>) {
            let at = out.len();
            self.inner.read_words(addr, n, out);
            let attempt = self.seen.entry(addr).or_insert(0);
            let corrupt = self.persistent.contains(&addr) || *attempt < self.transient;
            *attempt += 1;
            if corrupt && n > 0 {
                out[at] ^= 0x5a5a;
            }
        }
    }

    /// Transient corruption (clean on re-read) is detected and healed by
    /// the bounded retry: windows stay bit-exact, nothing degrades.
    #[test]
    fn integrity_recovers_transient_corruption() {
        let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let payload = packed.payload.as_ref().unwrap();
        let source = FlakySource {
            inner: SlicePayload(payload),
            transient: 1,
            persistent: Vec::new(),
            seen: Default::default(),
        };
        let mut fetcher = Fetcher::with_source(&packed, Box::new(source))
            .with_integrity(IntegrityPolicy::default());
        let mut dram = Dram::default();
        let win = fetcher.fetch_window(&mut dram, 0, 24, 0, 24, 0, 16);
        for y in 0..24 {
            for x in 0..24 {
                for ch in 0..16 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch), "({y},{x},{ch})");
                }
            }
        }
        let c = fetcher.counters();
        assert!(c.verified_reads > 0);
        assert!(c.checksum_mismatches > 0, "corruption went undetected");
        assert!(c.recovered_reads > 0, "nothing recovered");
        assert!(c.retry_backoff_cycles > 0, "recovery charged no simulated time");
        assert_eq!(c.degraded_subtensors, 0, "transient faults must heal");
    }

    /// Persistent corruption of one sub-tensor exhausts the retry
    /// budget, quarantines it, and serves an all-zero substitute — the
    /// rest of the window stays bit-exact, and a re-touch goes straight
    /// to the substitute without futile re-reads.
    #[test]
    fn integrity_degrades_persistent_corruption_to_zeros() {
        let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let payload = packed.payload.as_ref().unwrap();
        // Pick a sub-tensor that actually holds nonzeros so the zero
        // substitution is observable.
        let div = &packed.division;
        let li_bad = (0..div.n_subtensors())
            .find(|&li| {
                let r = div.subtensor_coords(li);
                let (sy, sx) = (div.ys[r.iy], div.xs[r.ix]);
                let (cg0, cd) = (r.icg * div.cd, div.cg_depth(r.icg));
                packed.sizes_words[li] > 0
                    && (sy.start..sy.end()).any(|y| {
                        (sx.start..sx.end()).any(|x| {
                            (cg0..cg0 + cd).any(|ch| fm.get(y, x, ch) != 0.0)
                        })
                    })
            })
            .expect("a nonzero sub-tensor exists at 40% density");
        let r_bad = div.subtensor_coords(li_bad);
        let (sy, sx) = (div.ys[r_bad.iy], div.xs[r_bad.ix]);
        let (cg0, cd) = (r_bad.icg * div.cd, div.cg_depth(r_bad.icg));
        let source = FlakySource {
            inner: SlicePayload(payload),
            transient: 0,
            persistent: vec![packed.addr_words[li_bad]],
            seen: Default::default(),
        };
        let policy = IntegrityPolicy { retry_budget: 2, backoff_cycles: 16 };
        let mut fetcher =
            Fetcher::with_source(&packed, Box::new(source)).with_integrity(policy);
        let mut dram = Dram::default();
        let win = fetcher.fetch_window(&mut dram, 0, 24, 0, 24, 0, 16);
        for y in 0..24 {
            for x in 0..24 {
                for ch in 0..16 {
                    let inside = y >= sy.start
                        && y < sy.end()
                        && x >= sx.start
                        && x < sx.end()
                        && ch >= cg0
                        && ch < cg0 + cd;
                    let want = if inside { 0.0 } else { fm.get(y, x, ch) };
                    assert_eq!(win.get(y, x, ch), want, "({y},{x},{ch})");
                }
            }
        }
        let c1 = fetcher.counters();
        assert_eq!(c1.degraded_subtensors, 1);
        assert_eq!(c1.retried_reads, policy.retry_budget as u64);
        assert_eq!(c1.recovered_reads, 0);
        // Quarantine: the re-touch degrades again but never re-reads.
        let _ = fetcher.fetch_window(&mut dram, sy.start, sy.end(), sx.start, sx.end(), cg0, cg0 + cd);
        let c2 = fetcher.counters();
        assert_eq!(c2.degraded_subtensors, 2);
        assert_eq!(c2.retried_reads, c1.retried_reads, "quarantined sub-tensor was re-read");
    }

    /// Without a checksum table (pre-v3 map) verify-on-fetch is a no-op.
    #[test]
    fn integrity_noop_without_checksum_table() {
        let (fm, mut packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        packed.checksums.clear();
        let mut fetcher = Fetcher::new(&packed).with_integrity(IntegrityPolicy::default());
        let mut dram = Dram::default();
        let win = fetcher.fetch_window(&mut dram, 0, 24, 0, 24, 0, 16);
        for y in 0..24 {
            for x in 0..24 {
                for ch in 0..16 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch));
                }
            }
        }
        assert_eq!(fetcher.counters().verified_reads, 0);
    }

    /// Fault-free verify-on-fetch changes nothing observable: windows,
    /// DRAM accounting, and every non-integrity counter are identical,
    /// and every read hashes clean.
    #[test]
    fn integrity_is_invariant_when_fault_free() {
        let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let windows = [
            (0usize, 10usize, 0usize, 10usize, 0usize, 16usize),
            (7, 17, 7, 17, 0, 16),
            (0, 24, 0, 24, 0, 16),
        ];
        let mut plain = Fetcher::new(&packed);
        let mut verified = Fetcher::new(&packed).with_integrity(IntegrityPolicy::default());
        let mut d_plain = Dram::default();
        let mut d_verified = Dram::default();
        for &(y0, y1, x0, x1, c0, c1) in &windows {
            let a = plain.fetch_window(&mut d_plain, y0, y1, x0, x1, c0, c1);
            let b = verified.fetch_window(&mut d_verified, y0, y1, x0, x1, c0, c1);
            assert_eq!(a, b, "window ({y0},{y1},{x0},{x1})");
        }
        for stream in [Stream::FeatureRead, Stream::MetadataRead] {
            assert_eq!(d_plain.words_of(stream), d_verified.words_of(stream), "{stream:?}");
        }
        let c = verified.counters();
        assert!(c.verified_reads > 0);
        assert_eq!(c.checksum_mismatches, 0);
        assert_eq!(c.retried_reads, 0);
        assert_eq!(c.degraded_subtensors, 0);
        assert_eq!(c.retry_backoff_cycles, 0);
        assert_eq!(c.decoded_words, plain.counters().decoded_words);
    }

    #[test]
    #[should_panic(expected = "payload")]
    fn fetcher_requires_payload() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let tile = TileShape::new(8, 8, 8);
        let division = crate::tiling::Division::build(
            DivisionMode::Uniform { edge: 8 }, &layer, &tile, &hw, 16, 16, 8)
            .unwrap();
        let fm = FeatureMap::zeros(16, 16, 8);
        let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, false);
        let _ = Fetcher::new(&packed);
    }
}
