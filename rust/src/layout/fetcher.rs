//! The runtime access path: fetch compressed sub-tensors and assemble a
//! dense tile on-the-fly (paper Fig. 2c, §III-A).
//!
//! This is what the memory controller of a GrateTile-enabled accelerator
//! does per processing tile: (1) read the block metadata records the
//! window touches, (2) two-step address computation (block pointer +
//! size prefix), (3) fetch whole compressed sub-tensors, (4) decompress
//! into the tile's dense working buffer. All DRAM traffic is accounted
//! against a [`Dram`] so the coordinator's end-to-end numbers match the
//! analytic simulator.

use super::packer::PackedFeatureMap;
use crate::compress::{CompressedBlock, Compressor};
use crate::memsim::{Dram, Stream};
use crate::tiling::division::{Division, SubTensorRef};

/// Dense window assembled by a fetch: `[y0,y1) × [x0,x1) × [c0,c1)` in
/// row-major (y, x, c) order.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseWindow {
    pub y0: usize,
    pub y1: usize,
    pub x0: usize,
    pub x1: usize,
    pub c0: usize,
    pub c1: usize,
    pub data: Vec<f32>,
}

impl DenseWindow {
    pub fn get(&self, y: usize, x: usize, ch: usize) -> f32 {
        debug_assert!(y >= self.y0 && y < self.y1);
        debug_assert!(x >= self.x0 && x < self.x1);
        debug_assert!(ch >= self.c0 && ch < self.c1);
        let w = self.x1 - self.x0;
        let c = self.c1 - self.c0;
        self.data[((y - self.y0) * w + (x - self.x0)) * c + (ch - self.c0)]
    }
}

/// Fetches windows from a packed feature map.
pub struct Fetcher<'a> {
    packed: &'a PackedFeatureMap,
    codec: Box<dyn Compressor>,
    scratch: Vec<f32>,
}

impl<'a> Fetcher<'a> {
    pub fn new(packed: &'a PackedFeatureMap) -> Self {
        assert!(
            packed.payload.is_some(),
            "fetcher requires a payload-packed map (pack with with_payload=true)"
        );
        Self { packed, codec: packed.scheme.build(), scratch: Vec::new() }
    }

    /// Fetch a clipped window, decompressing every intersecting
    /// sub-tensor; traffic is accounted on `dram`. Elements of fetched
    /// sub-tensors that fall outside the requested window are decoded
    /// but not copied — exactly the over-fetch the paper's division
    /// scheme is designed to avoid.
    pub fn fetch_window(
        &mut self,
        dram: &mut Dram,
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
    ) -> DenseWindow {
        let div = &self.packed.division;
        assert!(y1 <= div.fm_h && x1 <= div.fm_w && c1 <= div.fm_c);
        let (wh, ww, wc) = (y1 - y0, x1 - x0, c1 - c0);
        let mut out = vec![0.0f32; wh * ww * wc];
        let payload = self.packed.payload.as_ref().unwrap();

        // Metadata reads: one record per touched block, once per fetch.
        let mut touched_blocks: Vec<usize> = Vec::new();
        let subs = div.intersecting(y0, y1, x0, x1, c0, c1);
        for &r in &subs {
            let b = div.block_linear(r);
            if !touched_blocks.contains(&b) {
                touched_blocks.push(b);
                dram.account_bits(Stream::MetadataRead, div.meta_bits_per_block as u64);
            }
        }

        for r in subs {
            self.fetch_subtensor(dram, payload, r, &mut out, y0, y1, x0, x1, c0, c1);
        }
        DenseWindow { y0, y1, x0, x1, c0, c1, data: out }
    }

    #[allow(clippy::too_many_arguments)]
    fn fetch_subtensor(
        &mut self,
        dram: &mut Dram,
        payload: &[u16],
        r: SubTensorRef,
        out: &mut [f32],
        y0: usize,
        y1: usize,
        x0: usize,
        x1: usize,
        c0: usize,
        c1: usize,
    ) {
        let div: &Division = &self.packed.division;
        let li = div.linear(r);
        let addr = self.packed.addr_words[li];
        let size = self.packed.sizes_words[li] as u64;
        // The whole compressed sub-tensor moves (not randomly accessible
        // inside); line accounting via the span.
        dram.access(Stream::FeatureRead, addr, size.max(if div.compact { 0 } else { 1 }));

        let sy = div.ys[r.iy];
        let sx = div.xs[r.ix];
        let scg0 = r.icg * div.cd;
        let cd = div.cg_depth(r.icg);
        let n = sy.len * sx.len * cd;
        self.scratch.clear();
        self.scratch.resize(n, 0.0);
        let comp = CompressedBlock {
            n_elems: n,
            words: payload[addr as usize..(addr + size) as usize].to_vec(),
        };
        self.codec.decompress(&comp, &mut self.scratch);

        // Copy the intersection into the window buffer.
        let iy0 = sy.start.max(y0);
        let iy1 = sy.end().min(y1);
        let ix0 = sx.start.max(x0);
        let ix1 = sx.end().min(x1);
        let ic0 = scg0.max(c0);
        let ic1 = (scg0 + cd).min(c1);
        let (ww, wc) = (x1 - x0, c1 - c0);
        for y in iy0..iy1 {
            for x in ix0..ix1 {
                for ch in ic0..ic1 {
                    let src = ((y - sy.start) * sx.len + (x - sx.start)) * cd + (ch - scg0);
                    let dst = ((y - y0) * ww + (x - x0)) * wc + (ch - c0);
                    out[dst] = self.scratch[src];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::{ConvLayer, TileShape};
    use crate::layout::packer::Packer;
    use crate::tensor::sparsity::{generate, SparsityParams};
    use crate::tensor::FeatureMap;
    use crate::tiling::division::DivisionMode;

    fn packed_map(
        mode: DivisionMode,
        scheme: Scheme,
    ) -> (FeatureMap, PackedFeatureMap) {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let tile = TileShape::new(8, 8, 8);
        let division = crate::tiling::Division::build(mode, &layer, &tile, &hw, 24, 24, 16)
            .unwrap();
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, 21));
        let packed = Packer::new(hw, scheme).pack(&fm, &division, true);
        (fm, packed)
    }

    fn check_window(
        fm: &FeatureMap,
        packed: &PackedFeatureMap,
        (y0, y1, x0, x1, c0, c1): (usize, usize, usize, usize, usize, usize),
    ) {
        let mut dram = Dram::default();
        let mut fetcher = Fetcher::new(packed);
        let win = fetcher.fetch_window(&mut dram, y0, y1, x0, x1, c0, c1);
        for y in y0..y1 {
            for x in x0..x1 {
                for ch in c0..c1 {
                    assert_eq!(
                        win.get(y, x, ch),
                        fm.get(y, x, ch),
                        "mismatch at ({y},{x},{ch})"
                    );
                }
            }
        }
        assert!(dram.lines_of(Stream::FeatureRead) > 0);
    }

    #[test]
    fn full_map_roundtrip_all_schemes() {
        for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
            let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, scheme);
            check_window(&fm, &packed, (0, 24, 0, 24, 0, 16));
        }
    }

    #[test]
    fn partial_windows_roundtrip() {
        let (fm, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        for w in [
            (0usize, 10usize, 0usize, 10usize, 0usize, 8usize),
            (7, 17, 7, 17, 0, 16),
            (15, 24, 15, 24, 8, 16),
            (1, 2, 1, 2, 0, 8),
        ] {
            check_window(&fm, &packed, w);
        }
    }

    #[test]
    fn uniform_divisions_also_roundtrip() {
        for edge in [1usize, 2, 4, 8] {
            let (fm, packed) = packed_map(DivisionMode::Uniform { edge }, Scheme::Bitmask);
            check_window(&fm, &packed, (3, 19, 5, 21, 0, 16));
        }
    }

    #[test]
    fn metadata_traffic_counted_once_per_block() {
        let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let mut dram = Dram::default();
        let mut fetcher = Fetcher::new(&packed);
        // Window [7,17)x[7,17)x[0,8): 9 sub-tensors across 4 blocks.
        let _ = fetcher.fetch_window(&mut dram, 7, 17, 7, 17, 0, 8);
        // 4 blocks x 48 bits -> 192 bits -> 12 words.
        assert_eq!(dram.words_of(Stream::MetadataRead), 12);
    }

    #[test]
    fn larger_window_fetches_more() {
        let (_, packed) = packed_map(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask);
        let mut fetcher = Fetcher::new(&packed);
        let mut d1 = Dram::default();
        let _ = fetcher.fetch_window(&mut d1, 0, 9, 0, 9, 0, 8);
        let mut d2 = Dram::default();
        let _ = fetcher.fetch_window(&mut d2, 0, 17, 0, 17, 0, 16);
        assert!(
            d2.lines_of(Stream::FeatureRead) > d1.lines_of(Stream::FeatureRead)
        );
    }

    #[test]
    #[should_panic(expected = "payload")]
    fn fetcher_requires_payload() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let tile = TileShape::new(8, 8, 8);
        let division = crate::tiling::Division::build(
            DivisionMode::Uniform { edge: 8 }, &layer, &tile, &hw, 16, 16, 8)
            .unwrap();
        let fm = FeatureMap::zeros(16, 16, 8);
        let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, false);
        let _ = Fetcher::new(&packed);
    }
}
