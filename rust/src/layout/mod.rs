//! Compressed memory layout for divided feature maps (paper §III-C,
//! Fig. 7).
//!
//! * [`packer::Packer`] compresses every sub-tensor of a [`crate::tiling::Division`]
//!   and assigns cache-line-aligned addresses (word-compact for the
//!   Uniform 1×1×8 baseline), producing a [`packer::PackedFeatureMap`].
//! * [`metadata`] models the Fig. 7 metadata structure — one pointer per
//!   block plus the compressed sizes of the block's sub-tensors — and
//!   reproduces the Table II bits-per-KB accounting.
//! * [`fetcher::Fetcher`] is the runtime access path: two-step metadata
//!   lookup (pointer, then size offsets), whole-sub-tensor fetches,
//!   on-the-fly decompression into a dense tile buffer.

pub mod fetcher;
pub mod metadata;
pub mod packer;

pub use fetcher::{
    FetchCounters, Fetcher, IntegrityPolicy, PayloadSource, SegmentPayload, SlicePayload,
};
pub use metadata::{metadata_bits_per_kb, size_field_bits_for};
pub use packer::{size_all_codecs, AllCodecSizes, PackedFeatureMap, Packer};
