//! The tiled GEMM kernel: per-tile im2col-free convolution over a
//! fetched [`DenseWindow`], with zero-skip at two levels.
//!
//! ## Loop order = oracle order (bit-identity)
//!
//! For a fixed output `(oy, ox, cout)` the oracle
//! (`coordinator::conv::direct_conv_relu`) accumulates taps in
//! `(ky asc, kx asc, cin asc)` order, skipping `v == 0` inputs. The
//! kernel's `(oy, ky, kx, ox, cin)` loop nest visits exactly the same
//! taps per output in exactly the same order — only the `ox` hoisting
//! differs, which never reorders the terms *of one output*. With the
//! `ValueSkip`/`ZeroSkip` policies the executed term set is also
//! identical (index-driven skips remove only `v == 0.0` terms, and
//! `x + 0.0` is not even executed by the oracle), so the f32
//! accumulators match the oracle **bit for bit**.
//!
//! ## Blocking
//!
//! Two levels: the walker's processing tile bounds the working set
//! (window + accumulator stay cache-resident), and the inner AXPY
//! streams one contiguous `c_out`-wide packed-weight row against one
//! accumulator row — the microkernel shape auto-vectorises and is the
//! unit the zero-skip gates elide.

use super::weights::PackedWeights;
use crate::config::layer::ConvLayer;
use crate::layout::fetcher::DenseWindow;

/// Sparsity policy of the GEMM kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkipPolicy {
    /// No sparsity exploitation: every in-bounds tap runs its full
    /// `c_in × c_out` multiply-accumulate block. The honest dense
    /// baseline the §Perf speedup gate measures against.
    Dense,
    /// Gate `v == 0.0` inputs at the innermost loop (PE-level clock
    /// gating) — exactly the oracle's executed term set.
    ValueSkip,
    /// `ValueSkip` plus index-driven skips: whole im2col row spans
    /// proven zero by the fetcher's occupancy index (and, upstream,
    /// all-zero sub-tensors proven by the codec metadata) never reach
    /// the kernel at all.
    ZeroSkip,
}

impl SkipPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SkipPolicy::Dense => "dense",
            SkipPolicy::ValueSkip => "valueskip",
            SkipPolicy::ZeroSkip => "zeroskip",
        }
    }

    pub fn parse(s: &str) -> Option<SkipPolicy> {
        match s {
            "dense" => Some(SkipPolicy::Dense),
            "valueskip" => Some(SkipPolicy::ValueSkip),
            "zeroskip" => Some(SkipPolicy::ZeroSkip),
            _ => None,
        }
    }

    pub fn all() -> [SkipPolicy; 3] {
        [SkipPolicy::Dense, SkipPolicy::ValueSkip, SkipPolicy::ZeroSkip]
    }
}

/// Measured kernel work. `macs` is what the kernel actually executed;
/// `dense_macs` is what an always-dense kernel would have executed on
/// the same in-bounds taps (SAME-padding clips excluded from both) —
/// the pair replaces the analytic `ConvLayer::macs()` estimate in
/// reports once a compute backend has run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GemmStats {
    /// Multiply-accumulates executed.
    pub macs: u64,
    /// MACs a dense kernel would execute on the same in-bounds taps.
    pub dense_macs: u64,
    /// `(oy, ky)` input-row spans elided via the occupancy index.
    pub skipped_rows: u64,
    /// Input values elided by the `v == 0.0` gate.
    pub skipped_values: u64,
}

impl GemmStats {
    pub fn merge(&mut self, other: &GemmStats) {
        self.macs += other.macs;
        self.dense_macs += other.dense_macs;
        self.skipped_rows += other.skipped_rows;
        self.skipped_values += other.skipped_values;
    }

    /// Fraction of dense MACs eliminated by skipping (0 when nothing
    /// was measured).
    pub fn mac_reduction(&self) -> f64 {
        if self.dense_macs == 0 {
            0.0
        } else {
            1.0 - self.macs as f64 / self.dense_macs as f64
        }
    }
}

/// Accumulate the convolution contributions of `win` into the output
/// tile `[oy0,oy1) × [ox0,ox1)` (`acc` is `(oy1-oy0) × (ox1-ox0) ×
/// c_out`, row-major). `row_occ` is the fetcher's window-relative
/// row-occupancy index (entry `i` = window row `win.y0 + i`); `None`
/// disables row skips regardless of policy.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tile(
    layer: &ConvLayer,
    pw: &PackedWeights,
    win: &DenseWindow,
    row_occ: Option<&[bool]>,
    policy: SkipPolicy,
    acc: &mut [f32],
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
    stats: &mut GemmStats,
) {
    let ks = layer.kernel_size();
    let halo = layer.halo() as i64;
    let ow = ox1 - ox0;
    let c_out = layer.c_out;
    debug_assert_eq!(acc.len(), (oy1 - oy0) * ow * c_out);
    debug_assert_eq!(pw.c_out, c_out);
    let ww = win.x1 - win.x0;
    let wc = win.c1 - win.c0;
    // Resolve an input column for (ox, kx): in-bounds in both the map
    // and the fetched window, or None (SAME-padding clip / halo clip).
    let col = |ox: usize, kx: usize| -> Option<usize> {
        let ix = (ox * layer.s + kx * layer.d) as i64 - halo;
        if ix < 0 || ix >= layer.w as i64 {
            return None;
        }
        let ix = ix as usize;
        (ix >= win.x0 && ix < win.x1).then_some(ix)
    };
    for oy in oy0..oy1 {
        let arow = (oy - oy0) * ow * c_out;
        for ky in 0..ks {
            let iy = (oy * layer.s + ky * layer.d) as i64 - halo;
            if iy < 0 || iy >= layer.h as i64 {
                continue;
            }
            let iy = iy as usize;
            if iy < win.y0 || iy >= win.y1 {
                continue;
            }
            // Index-driven row skip: the whole (oy, ky) input row was
            // proven zero by the fetch-side occupancy index — elide it
            // before touching a single value. Skipped work still counts
            // toward the dense-equivalent total.
            if policy == SkipPolicy::ZeroSkip {
                if let Some(occ) = row_occ {
                    if !occ[iy - win.y0] {
                        stats.skipped_rows += 1;
                        for kx in 0..ks {
                            for ox in ox0..ox1 {
                                if col(ox, kx).is_some() {
                                    stats.dense_macs += (wc * c_out) as u64;
                                }
                            }
                        }
                        continue;
                    }
                }
            }
            let wrow = (iy - win.y0) * ww;
            for kx in 0..ks {
                let tap = pw.tap(ky, kx);
                for ox in ox0..ox1 {
                    let Some(ix) = col(ox, kx) else { continue };
                    let wbase = (wrow + (ix - win.x0)) * wc;
                    let base = arow + (ox - ox0) * c_out;
                    stats.dense_macs += (wc * c_out) as u64;
                    for ci in 0..wc {
                        let v = win.data[wbase + ci];
                        if v == 0.0 && policy != SkipPolicy::Dense {
                            stats.skipped_values += 1;
                            continue;
                        }
                        stats.macs += c_out as u64;
                        let cin = win.c0 + ci;
                        let wslice = &tap[cin * c_out..(cin + 1) * c_out];
                        let aslice = &mut acc[base..base + c_out];
                        for (a, &w) in aslice.iter_mut().zip(wslice) {
                            *a += v * w;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::conv::Weights;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn whole_map_window(fm: &crate::tensor::FeatureMap) -> DenseWindow {
        DenseWindow {
            y0: 0,
            y1: fm.h,
            x0: 0,
            x1: fm.w,
            c0: 0,
            c1: fm.c,
            data: fm.as_slice().to_vec(),
        }
    }

    fn run_policy(
        layer: &ConvLayer,
        pw: &PackedWeights,
        win: &DenseWindow,
        occ: Option<&[bool]>,
        policy: SkipPolicy,
    ) -> (Vec<f32>, GemmStats) {
        let (oh, ow) = (layer.out_h(), layer.out_w());
        let mut acc = vec![0.0f32; oh * ow * layer.c_out];
        let mut stats = GemmStats::default();
        gemm_tile(layer, pw, win, occ, policy, &mut acc, 0, oh, 0, ow, &mut stats);
        (acc, stats)
    }

    /// All three policies produce bit-identical accumulators on the
    /// same window (±0.0 terms never change an f32 sum at these
    /// magnitudes is NOT assumed — the skipped terms are exact zeros
    /// that the oracle also skips, so Dense is the only policy that
    /// executes them, and adding literal `v == 0.0` here still yields
    /// identical bits because `a + 0.0 * w == a` for finite `a`).
    #[test]
    fn policies_agree_bitwise() {
        let layer = ConvLayer::new(1, 1, 12, 12, 8, 6);
        let mut fm = generate(12, 12, 8, SparsityParams::clustered(0.3, 7));
        // Plant a guaranteed all-zero row band so the row-skip path
        // deterministically fires.
        for y in 4..6 {
            for x in 0..12 {
                for c in 0..8 {
                    fm.set(y, x, c, 0.0);
                }
            }
        }
        let w = Weights::random(&layer, 5);
        let pw = PackedWeights::prepare(&layer, &w);
        let win = whole_map_window(&fm);
        // True per-row occupancy computed from the window itself.
        let occ: Vec<bool> = (0..fm.h)
            .map(|y| (0..fm.w).any(|x| (0..fm.c).any(|c| fm.get(y, x, c) != 0.0)))
            .collect();
        let (dense, sd) = run_policy(&layer, &pw, &win, None, SkipPolicy::Dense);
        let (vskip, sv) = run_policy(&layer, &pw, &win, None, SkipPolicy::ValueSkip);
        let (zskip, sz) = run_policy(&layer, &pw, &win, Some(&occ), SkipPolicy::ZeroSkip);
        assert_eq!(dense, vskip);
        assert_eq!(dense, zskip);
        // Work accounting: dense executes everything, skips save MACs.
        assert_eq!(sd.macs, sd.dense_macs);
        assert!(sv.macs < sd.macs);
        assert_eq!(sv.dense_macs, sd.dense_macs);
        assert!(sz.macs <= sv.macs);
        assert_eq!(sz.dense_macs, sd.dense_macs);
        assert!(sz.skipped_rows > 0, "planted zero rows must be skipped");
        assert!(sv.skipped_values > 0);
        assert!(sz.mac_reduction() > 0.1);
    }

    /// A conservative (all-true) occupancy index degrades ZeroSkip to
    /// ValueSkip — same result, same MACs, no row skips.
    #[test]
    fn conservative_occupancy_is_safe() {
        let layer = ConvLayer::new(2, 1, 10, 10, 4, 4).dilated(2);
        let fm = generate(10, 10, 4, SparsityParams::iid(0.2, 3));
        let w = Weights::random(&layer, 9);
        let pw = PackedWeights::prepare(&layer, &w);
        let win = whole_map_window(&fm);
        let occ = vec![true; fm.h];
        let (v, sv) = run_policy(&layer, &pw, &win, None, SkipPolicy::ValueSkip);
        let (z, sz) = run_policy(&layer, &pw, &win, Some(&occ), SkipPolicy::ZeroSkip);
        assert_eq!(v, z);
        assert_eq!(sv.macs, sz.macs);
        assert_eq!(sz.skipped_rows, 0);
    }

    #[test]
    fn skip_policy_names_roundtrip() {
        for p in SkipPolicy::all() {
            assert_eq!(SkipPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(SkipPolicy::parse("nope"), None);
    }
}
