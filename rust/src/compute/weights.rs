//! Packed weight layout for the tiled GEMM backend.
//!
//! The kernel consumes weights as per-tap `[c_in][c_out]` panels: for a
//! fixed kernel tap `(ky, kx)` the panel is one contiguous slice whose
//! rows (one per input channel) are the `c_out`-wide AXPY operands of
//! the inner loop. [`PackedWeights::prepare`] freezes a layer's weights
//! into this layout **once per layer** — the hot loop then slices
//! panels with two multiplies instead of re-deriving the 4-D index per
//! multiply-accumulate, and the panel rows are the exact cache lines
//! the microkernel streams.

use crate::config::layer::ConvLayer;
use crate::coordinator::conv::Weights;

/// Layer weights packed for the GEMM kernel: tap-major contiguous
/// `[c_in][c_out]` panels.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    pub ks: usize,
    pub c_in: usize,
    pub c_out: usize,
    data: Vec<f32>,
}

impl PackedWeights {
    /// Pack `weights` for `layer`. The source `[ky][kx][cin][cout]`
    /// row-major order already has contiguous tap panels, so packing is
    /// one validated copy; the value of this type is the *contract* (the
    /// kernel can slice panels blindly) plus the single point where a
    /// future layout change (padding, blocking, transposition) happens.
    pub fn prepare(layer: &ConvLayer, weights: &Weights) -> PackedWeights {
        let ks = layer.kernel_size();
        assert_eq!(
            (weights.k, weights.c_in, weights.c_out),
            (layer.k, layer.c_in, layer.c_out),
            "weights do not match layer geometry"
        );
        assert_eq!(weights.data.len(), ks * ks * layer.c_in * layer.c_out);
        PackedWeights {
            ks,
            c_in: layer.c_in,
            c_out: layer.c_out,
            data: weights.data.clone(),
        }
    }

    /// The `[c_in][c_out]` panel of tap `(ky, kx)`.
    #[inline]
    pub fn tap(&self, ky: usize, kx: usize) -> &[f32] {
        let panel = self.c_in * self.c_out;
        let p = (ky * self.ks + kx) * panel;
        &self.data[p..p + panel]
    }

    /// The `c_out`-wide AXPY row of input channel `cin` at tap
    /// `(ky, kx)`.
    #[inline]
    pub fn row(&self, ky: usize, kx: usize, cin: usize) -> &[f32] {
        let tap = self.tap(ky, kx);
        &tap[cin * self.c_out..(cin + 1) * self.c_out]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panels_match_weight_accessor() {
        let layer = ConvLayer::new(1, 1, 8, 8, 4, 6);
        let w = Weights::random(&layer, 3);
        let pw = PackedWeights::prepare(&layer, &w);
        for ky in 0..3 {
            for kx in 0..3 {
                for cin in 0..4 {
                    for cout in 0..6 {
                        assert_eq!(
                            pw.row(ky, kx, cin)[cout],
                            w.at(ky, kx, cin, cout),
                            "({ky},{kx},{cin},{cout})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "geometry")]
    fn geometry_mismatch_rejected() {
        let layer = ConvLayer::new(1, 1, 8, 8, 4, 6);
        let other = ConvLayer::new(1, 1, 8, 8, 8, 6);
        let w = Weights::random(&other, 1);
        let _ = PackedWeights::prepare(&layer, &w);
    }
}
