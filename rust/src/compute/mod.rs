//! The compute backend: tiled sparse GEMM over fetched windows.
//!
//! This is the "real compute path" — where the simulator previously
//! priced DRAM traffic and *estimated* MACs analytically, this module
//! executes the convolution the accelerator would run, consuming the
//! fetcher's decoded windows tile by tile and reporting **measured**
//! MAC counts to the roofline/power/serving reports.
//!
//! The backend replaces the naive `coordinator::conv::direct_conv_relu`
//! on the hot path; the direct conv survives as the property-tested
//! numerics oracle (the GEMM output is bit-identical f32, see
//! [`kernel`]).
//!
//! Structure:
//! * [`weights::PackedWeights`] — per-layer packed weight panels,
//!   prepared once;
//! * [`kernel::gemm_tile`] — the blocked kernel with the
//!   [`kernel::SkipPolicy`] zero-skip ladder;
//! * [`GemmBackend`] — the driver: division → pack → walk tiles →
//!   fetch windows (with the occupancy index when zero-skipping) →
//!   kernel → ReLU → output map. DRAM traffic accounting is identical
//!   to a plain fetch pass of the same windows (property-tested): the
//!   backend only *consumes* windows, it never changes what moves.

pub mod kernel;
pub mod weights;

pub use kernel::{gemm_tile, GemmStats, SkipPolicy};
pub use weights::PackedWeights;

use crate::config::hardware::Hardware;
use crate::compress::CodecPolicy;
use crate::coordinator::conv::Weights;
use crate::layout::fetcher::Fetcher;
use crate::layout::packer::Packer;
use crate::memsim::Dram;
use crate::sim::walker::TileWalker;
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionError, DivisionMode};

/// Everything one backend run produced: the output map, measured kernel
/// work, and the fetch-side accounting (DRAM traffic + decode/skip
/// counters) for invariance checks and study tables.
#[derive(Debug)]
pub struct GemmRun {
    pub out: FeatureMap,
    pub stats: GemmStats,
    /// Fetch-side DRAM accounting of the run (feature + metadata reads).
    pub dram: Dram,
    /// Dense elements actually decompressed by the fetch side.
    pub decoded_words: u64,
    /// All-zero sub-tensors whose decode was bypassed.
    pub skipped_subtensors: u64,
    /// All-zero row spans whose decode was bypassed.
    pub skipped_spans: u64,
}

/// The tiled GEMM convolution backend.
#[derive(Debug, Clone, Copy)]
pub struct GemmBackend {
    pub hw: Hardware,
    pub mode: DivisionMode,
    pub policy: CodecPolicy,
    pub skip: SkipPolicy,
}

impl GemmBackend {
    pub fn new(hw: Hardware) -> Self {
        Self {
            hw,
            mode: DivisionMode::GrateTile { n: 8 },
            policy: CodecPolicy::Fixed(crate::compress::Scheme::Bitmask),
            skip: SkipPolicy::ZeroSkip,
        }
    }

    pub fn with_mode(mut self, mode: DivisionMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_policy(mut self, policy: impl Into<CodecPolicy>) -> Self {
        self.policy = policy.into();
        self
    }

    pub fn with_skip(mut self, skip: SkipPolicy) -> Self {
        self.skip = skip;
        self
    }

    /// Run `layer` over `fm`: pack the input with this backend's
    /// division/codec, then walk the layer's processing tiles fetching
    /// ONE full-channel window per spatial tile and accumulating it
    /// with [`gemm_tile`] — per output, taps arrive in the oracle's
    /// `(ky, kx, cin)` order, so the result is bit-identical f32 to
    /// `direct_conv_relu` under every skip policy.
    pub fn conv_relu(
        &self,
        layer: &crate::config::layer::ConvLayer,
        weights: &Weights,
        fm: &FeatureMap,
    ) -> Result<GemmRun, DivisionError> {
        let tile = self.hw.tile_for_layer(layer);
        let division =
            Division::build(self.mode, layer, &tile, &self.hw, fm.h, fm.w, fm.c)?;
        let packed = Packer::new(self.hw, self.policy).pack(fm, &division, true);
        let pw = PackedWeights::prepare(layer, weights);
        let walker = TileWalker::new(*layer, tile);
        let (oh, ow) = (layer.out_h(), layer.out_w());
        let mut out = vec![0.0f32; oh * ow * layer.c_out];
        let mut dram = Dram::default();
        let zero_skip = self.skip == SkipPolicy::ZeroSkip;
        let mut fetcher = Fetcher::new(&packed).with_occupancy(zero_skip);
        let mut stats = GemmStats::default();
        let mut acc: Vec<f32> = Vec::new();
        let mut occ: Vec<bool> = Vec::new();
        for ty in 0..walker.n_ty {
            let (y0, y1) = walker.y_span(ty);
            let oy0 = ty * tile.th;
            let oy1 = (oy0 + tile.th).min(oh);
            for tx in 0..walker.n_tx {
                let (x0, x1) = walker.x_span(tx);
                let ox0 = tx * tile.tw;
                let ox1 = (ox0 + tile.tw).min(ow);
                let win = fetcher.fetch_window(&mut dram, y0, y1, x0, x1, 0, layer.c_in);
                let row_occ = if zero_skip {
                    occ.clear();
                    occ.extend_from_slice(fetcher.row_occupancy());
                    Some(&occ[..])
                } else {
                    None
                };
                acc.clear();
                acc.resize((oy1 - oy0) * (ox1 - ox0) * layer.c_out, 0.0);
                gemm_tile(
                    layer, &pw, &win, row_occ, self.skip, &mut acc, oy0, oy1, ox0, ox1,
                    &mut stats,
                );
                for v in &mut acc {
                    *v = v.max(0.0);
                }
                let (bw, c) = (ox1 - ox0, layer.c_out);
                for (i, oy) in (oy0..oy1).enumerate() {
                    let dst = (oy * ow + ox0) * c;
                    out[dst..dst + bw * c].copy_from_slice(&acc[i * bw * c..(i + 1) * bw * c]);
                }
                fetcher.recycle(win);
            }
        }
        Ok(GemmRun {
            out: FeatureMap::from_vec(oh, ow, layer.c_out, out),
            stats,
            decoded_words: fetcher.decoded_words(),
            skipped_subtensors: fetcher.skipped_subtensors(),
            skipped_spans: fetcher.skipped_spans(),
            dram,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;
    use crate::config::hardware::Platform;
    use crate::config::layer::ConvLayer;
    use crate::coordinator::conv::direct_conv_relu;
    use crate::memsim::Stream;
    use crate::tensor::sparsity::{generate, SparsityParams};

    /// The backend matches the direct-conv oracle bit for bit, for
    /// every skip policy and a mixed-codec (adaptive) pack.
    #[test]
    fn matches_oracle_bitwise_all_policies() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 20, 20, 16, 8);
        let fm = generate(20, 20, 16, SparsityParams::clustered(0.35, 17));
        let w = Weights::random(&layer, 4);
        let oracle = direct_conv_relu(&layer, &w, &fm);
        for policy in [CodecPolicy::Fixed(Scheme::Bitmask), CodecPolicy::Adaptive] {
            for skip in SkipPolicy::all() {
                let run = GemmBackend::new(hw)
                    .with_policy(policy)
                    .with_skip(skip)
                    .conv_relu(&layer, &w, &fm)
                    .unwrap();
                assert_eq!(
                    run.out.as_slice(),
                    oracle.as_slice(),
                    "{policy:?}/{}",
                    skip.name()
                );
                assert!(run.stats.dense_macs > 0);
            }
        }
    }

    /// The skip ladder is monotone in measured MACs, and the zero-skip
    /// tier leaves DRAM traffic untouched.
    #[test]
    fn skip_ladder_monotone_and_traffic_invariant() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
        let fm = generate(24, 24, 16, SparsityParams::clustered(0.2, 9));
        let w = Weights::random(&layer, 6);
        let be = GemmBackend::new(hw);
        let dense = be.with_skip(SkipPolicy::Dense).conv_relu(&layer, &w, &fm).unwrap();
        let vskip = be.with_skip(SkipPolicy::ValueSkip).conv_relu(&layer, &w, &fm).unwrap();
        let zskip = be.with_skip(SkipPolicy::ZeroSkip).conv_relu(&layer, &w, &fm).unwrap();
        assert_eq!(dense.stats.macs, dense.stats.dense_macs);
        assert!(vskip.stats.macs < dense.stats.macs);
        assert!(zskip.stats.macs <= vskip.stats.macs);
        assert_eq!(dense.stats.dense_macs, vskip.stats.dense_macs);
        assert_eq!(dense.stats.dense_macs, zskip.stats.dense_macs);
        for stream in [Stream::FeatureRead, Stream::MetadataRead] {
            assert_eq!(
                dense.dram.words_of(stream),
                zskip.dram.words_of(stream),
                "{stream:?}"
            );
        }
        // The zero-skip run decodes less and proves it via counters.
        assert!(zskip.decoded_words <= dense.decoded_words);
        assert!(zskip.skipped_subtensors + zskip.skipped_spans > 0);
    }
}
