//! Identity "codec": the uncompressed baseline.

use super::stats::BlockStats;
use super::{CodecCost, CompressedBlock, Compressor, Scheme};
use crate::tensor::dense::{bf16_bits, bf16_from_bits};

/// Stores blocks verbatim (1 word per element).
#[derive(Debug, Clone, Copy, Default)]
pub struct RawDense;

impl Compressor for RawDense {
    fn scheme(&self) -> Scheme {
        Scheme::Raw
    }

    fn compress(&self, block: &[f32]) -> CompressedBlock {
        CompressedBlock {
            n_elems: block.len(),
            words: block.iter().map(|&v| bf16_bits(v)).collect(),
        }
    }

    fn decompress(&self, comp: &CompressedBlock, out: &mut [f32]) {
        assert_eq!(out.len(), comp.n_elems);
        if comp.words.len() < comp.n_elems {
            // Truncated payload: the missing tail decodes as zeros
            // (never panic — the integrity layer above flags it).
            out.fill(0.0);
        }
        for (o, &w) in out.iter_mut().zip(&comp.words) {
            *o = bf16_from_bits(w);
        }
    }

    fn compressed_words(&self, block: &[f32]) -> usize {
        block.len()
    }

    fn compressed_sizes(&self, block: &[f32]) -> (usize, usize) {
        (block.len(), block.len() * 16)
    }

    fn compress_with_bits(&self, block: &[f32]) -> (CompressedBlock, usize) {
        (self.compress(block), block.len() * 16)
    }

    fn sizes_from_stats(&self, s: &BlockStats) -> Option<(usize, usize)> {
        Some((s.n_elems, s.n_elems * 16))
    }

    fn decompress_span(&self, comp: &CompressedBlock, start: usize, out: &mut [f32]) -> bool {
        debug_assert!(start + out.len() <= comp.n_elems);
        let avail = comp.words.get(start..).unwrap_or(&[]);
        if avail.len() < out.len() {
            out.fill(0.0);
        }
        for (o, &w) in out.iter_mut().zip(avail) {
            *o = bf16_from_bits(w);
        }
        true
    }

    fn cost(&self) -> CodecCost {
        CodecCost { gates_per_lane: 0, enc_cycles_per_word: 0.0, dec_cycles_per_word: 0.0, serial: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip() {
        let blk = vec![0.0f32, 1.5, -2.0, 0.0];
        let c = RawDense.compress(&blk);
        assert_eq!(c.compressed_words(), 4);
        let mut out = vec![9.0; 4];
        RawDense.decompress(&c, &mut out);
        assert_eq!(out, blk);
    }
}
