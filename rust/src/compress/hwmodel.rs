//! Cycle-level model of the hardware (de)compressor datapath (§V).
//!
//! The paper closes with a SystemVerilog area/throughput study of
//! GrateTile codecs vs ZRLC / bitmask / dictionary decoders, claiming
//! "better scalability and less serialization". This module makes that
//! comparison *runnable*: a cycle-driven simulation of a decompressor
//! fed by DRAM bursts through a finite FIFO, with per-codec lane
//! semantics:
//!
//! * **Bitmask**: `lanes` words/cycle — each lane pops one mask bit and
//!   either emits a zero or consumes the next value word (prefix-sum
//!   scatter is combinational across lanes).
//! * **ZRLC**: the run chain serialises token decode: at most 2 tokens
//!   per cycle regardless of lane count (the §V "serialization" point).
//! * **Dictionary**: `lanes` index lookups/cycle after a dictionary
//!   load of `dict_len / lanes` cycles per block.
//!
//! The input FIFO refills at the DRAM burst rate; the model reports
//! decode cycles, stall cycles and steady-state words/cycle, so the
//! ablation can show where the memory side, not the codec, limits.

use super::{CompressedBlock, Scheme};
use crate::util::ceil_div;

/// Decompressor configuration.
#[derive(Debug, Clone, Copy)]
pub struct DecoderConfig {
    /// Parallel output lanes.
    pub lanes: usize,
    /// Input FIFO capacity in words.
    pub fifo_words: usize,
    /// DRAM delivery rate into the FIFO, words per cycle.
    pub fill_rate: f64,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        Self { lanes: 8, fifo_words: 64, fill_rate: 8.0 }
    }
}

/// Result of decoding one block stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeStats {
    pub cycles: u64,
    pub stall_cycles: u64,
    pub words_out: u64,
    pub words_in: u64,
}

impl DecodeStats {
    /// Output throughput in words per cycle.
    pub fn words_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.words_out as f64 / self.cycles as f64
    }

    pub fn utilisation(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        1.0 - self.stall_cycles as f64 / self.cycles as f64
    }
}

/// Output words produced per decode cycle for a codec, given the block's
/// stream statistics. Parallel codecs emit `lanes` words; ZRLC's serial
/// run chain decodes at most 2 tokens/cycle, each covering its zero run
/// plus one value (density-dependent coverage).
fn out_per_cycle(scheme: Scheme, cfg: &DecoderConfig, comp: &CompressedBlock) -> u64 {
    match scheme {
        Scheme::Bitmask | Scheme::Raw | Scheme::Dictionary => cfg.lanes as u64,
        Scheme::Zrlc => {
            // tokens = 21-bit units in the stream; average coverage =
            // outputs per token (>= 1).
            let tokens = ((comp.words.len() * 16) / 21).max(1) as u64;
            let cover = (comp.n_elems as u64).div_ceil(tokens).max(1);
            (2 * cover).max(1)
        }
    }
}

/// Simulate decoding one compressed block into `n_elems` words.
pub fn decode_block(scheme: Scheme, cfg: &DecoderConfig, comp: &CompressedBlock) -> DecodeStats {
    let words_in_total = comp.words.len() as u64;
    let words_out_total = comp.n_elems as u64;

    let mut fifo = 0.0f64; // words currently buffered
    let mut delivered = 0.0f64; // words fetched from DRAM so far
    let mut out = 0u64;
    let mut cycles = 0u64;
    let mut stalls = 0u64;
    // Dictionary: pay the table-load latency up front (unless the block
    // fell back to raw — header == u16::MAX marker).
    if scheme == Scheme::Dictionary {
        if let Some(&header) = comp.words.first() {
            if header != u16::MAX {
                let dict_len = header as usize;
                cycles += ceil_div(dict_len.max(1), cfg.lanes) as u64;
                delivered += (1 + dict_len) as f64;
            }
        }
    }

    // Input-per-output ratio over the *streamed* portion (the table, if
    // any, was pre-delivered above).
    let in_per_out = if words_out_total == 0 {
        0.0
    } else {
        (words_in_total as f64 - delivered) / words_out_total as f64
    };

    let step = out_per_cycle(scheme, cfg, comp);
    while out < words_out_total {
        cycles += 1;
        // DRAM refills the FIFO.
        let room = cfg.fifo_words as f64 - fifo;
        let refill = cfg
            .fill_rate
            .min(room)
            .min((words_in_total as f64 - delivered).max(0.0));
        fifo += refill;
        delivered += refill;

        let out_step = step.min(words_out_total - out);
        let need_in = out_step as f64 * in_per_out;
        if fifo + 1e-9 >= need_in {
            fifo -= need_in;
            out += out_step;
        } else {
            stalls += 1; // starved by the memory side
        }
        if cycles > 16 * words_out_total + 1024 {
            break; // safety: should never trip
        }
    }

    DecodeStats {
        cycles,
        stall_cycles: stalls,
        words_out: out,
        words_in: words_in_total,
    }
}

/// Decode a whole packed stream of blocks back-to-back.
pub fn decode_stream(
    scheme: Scheme,
    cfg: &DecoderConfig,
    blocks: &[CompressedBlock],
) -> DecodeStats {
    let mut total = DecodeStats { cycles: 0, stall_cycles: 0, words_out: 0, words_in: 0 };
    for b in blocks {
        let s = decode_block(scheme, cfg, b);
        total.cycles += s.cycles;
        total.stall_cycles += s.stall_cycles;
        total.words_out += s.words_out;
        total.words_in += s.words_in;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Scheme};
    use crate::util::SplitMix64;

    fn block(density: f64, scheme: Scheme, len: usize) -> CompressedBlock {
        let mut rng = SplitMix64::new(11);
        let data: Vec<f32> = (0..len)
            .map(|_| if rng.chance(density) { rng.next_f32() + 0.01 } else { 0.0 })
            .collect();
        scheme.build().compress(&data)
    }

    #[test]
    fn bitmask_scales_with_lanes() {
        let b = block(0.4, Scheme::Bitmask, 512);
        let t4 = decode_block(Scheme::Bitmask, &DecoderConfig { lanes: 4, ..Default::default() }, &b);
        let t16 =
            decode_block(Scheme::Bitmask, &DecoderConfig { lanes: 16, fill_rate: 16.0, fifo_words: 128 }, &b);
        assert!(
            t16.words_per_cycle() > 2.5 * t4.words_per_cycle(),
            "16 lanes {} vs 4 lanes {}",
            t16.words_per_cycle(),
            t4.words_per_cycle()
        );
    }

    #[test]
    fn zrlc_does_not_scale_with_lanes() {
        let b = block(0.4, Scheme::Zrlc, 512);
        let t4 = decode_block(Scheme::Zrlc, &DecoderConfig { lanes: 4, ..Default::default() }, &b);
        let t16 =
            decode_block(Scheme::Zrlc, &DecoderConfig { lanes: 16, fill_rate: 16.0, fifo_words: 128 }, &b);
        let ratio = t16.words_per_cycle() / t4.words_per_cycle();
        assert!(ratio < 1.3, "serial decode should not scale: {ratio}");
    }

    #[test]
    fn starved_fifo_stalls() {
        // Dense bitmask block at a trickle fill rate: decode outpaces
        // memory and stalls.
        let b = block(1.0, Scheme::Bitmask, 512);
        let s = decode_block(
            Scheme::Bitmask,
            &DecoderConfig { lanes: 16, fifo_words: 32, fill_rate: 1.0 },
            &b,
        );
        assert!(s.stall_cycles > 0);
        assert!(s.utilisation() < 0.5);
        assert_eq!(s.words_out, 512);
    }

    #[test]
    fn sparse_blocks_decode_faster_per_output() {
        // Same output size, less input: sparse decodes at least as fast.
        let dense = block(0.9, Scheme::Bitmask, 512);
        let sparse = block(0.1, Scheme::Bitmask, 512);
        let cfg = DecoderConfig { lanes: 8, fifo_words: 32, fill_rate: 4.0 };
        let td = decode_block(Scheme::Bitmask, &cfg, &dense);
        let ts = decode_block(Scheme::Bitmask, &cfg, &sparse);
        assert!(ts.cycles <= td.cycles, "sparse {} vs dense {}", ts.cycles, td.cycles);
    }

    #[test]
    fn dictionary_pays_table_load() {
        let b = block(0.5, Scheme::Dictionary, 256);
        let cfg = DecoderConfig::default();
        let s = decode_block(Scheme::Dictionary, &cfg, &b);
        // Lower bound: output cycles + at least one table-load cycle.
        assert!(s.cycles > (256 / cfg.lanes) as u64);
        assert_eq!(s.words_out, 256);
    }

    #[test]
    fn stream_accumulates() {
        let blocks: Vec<_> = (0..4).map(|_| block(0.4, Scheme::Bitmask, 512)).collect();
        let s = decode_stream(Scheme::Bitmask, &DecoderConfig::default(), &blocks);
        assert_eq!(s.words_out, 4 * 512);
        assert!(s.cycles >= 4 * (512 / 8) as u64);
    }

    #[test]
    fn paper_claim_bitmask_beats_zrlc_and_gap_widens_with_lanes() {
        // §V: "better scalability and less serialization" — bitmask wins
        // at 8 lanes and the gap widens at 16 (ZRLC stays token-bound).
        let bb = block(0.4, Scheme::Bitmask, 512);
        let bz = block(0.4, Scheme::Zrlc, 512);
        let at = |lanes: usize| {
            let cfg = DecoderConfig { lanes, fifo_words: 16 * lanes, fill_rate: 2.0 * lanes as f64 };
            (
                decode_block(Scheme::Bitmask, &cfg, &bb).words_per_cycle(),
                decode_block(Scheme::Zrlc, &cfg, &bz).words_per_cycle(),
            )
        };
        let (b8, z8) = at(8);
        let (b16, z16) = at(16);
        assert!(b8 > z8, "8 lanes: bitmask {b8} vs zrlc {z8}");
        assert!(b16 / z16 > b8 / z8 * 1.5, "gap must widen: {b16}/{z16} vs {b8}/{z8}");
    }
}
