//! The codec registry and per-sub-tensor codec policy.
//!
//! GrateTile stores sub-tensors "in a compressed yet randomly accessible
//! format" — nothing in that contract requires every sub-tensor of a
//! layer to use the *same* codec. This module is the one place the crate
//! knows which codecs exist:
//!
//! * [`Registry`] maps codec **name ⇄ on-format tag ⇄ compressor**. The
//!   tag is the stable 2-bit identifier ([`TAG_BITS`]) written into
//!   Fig. 7 block records and the `.grate` v2 TOC; the table order *is*
//!   the tag assignment, so a new codec plugs in by appending one
//!   [`RegistryEntry`] (and a [`Scheme`] variant) here — nothing outside
//!   `compress/` enumerates codecs.
//! * [`CodecPolicy`] is what every layer of the crate (packer, store
//!   writer, fetcher, pricer, harness, CLI) is parameterised over:
//!   `Fixed(scheme)` — one codec for the whole map (the historical
//!   behaviour) — or `Adaptive` — pick the cheapest codec per
//!   sub-tensor, paying [`TAG_BITS`] per record slot of indexing
//!   overhead (the same trade the paper makes for its index).
//!
//! Adaptive selection is a pure function of the per-codec exact
//! `(words, bits)` sizes ([`Registry::select`]): aligned divisions pay
//! line-rounded words, so the key is `(words, bits)`; the compact
//! Uniform 1×1×8 baseline pays idealised bits, so the key flips to
//! `(bits, words)`. Ties resolve to the lowest tag, which makes the
//! choice deterministic and identical across the packing engine, the
//! seed-oracle packer and the streaming store writer (property-tested).

use super::{Bitmask, Compressor, Dictionary, RawDense, Scheme, Zrlc};
use crate::err;
use crate::util::error::Result;

/// On-format codec tag width in bits (2 bits address all 4 codecs; the
/// registry asserts it never outgrows this).
pub const TAG_BITS: usize = 2;

/// One registered codec: its enum id, canonical name, accepted aliases
/// and the shared compressor instance.
pub struct RegistryEntry {
    pub scheme: Scheme,
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub codec: &'static dyn Compressor,
}

static BITMASK: Bitmask = Bitmask;
static ZRLC: Zrlc = Zrlc;
static DICTIONARY: Dictionary = Dictionary { max_entries: 256 };
static RAW: RawDense = RawDense;

/// The registry table. **Order is the stable on-format tag**: bitmask=0,
/// zrlc=1, dictionary=2, raw=3 (matching the `.grate` v1 scheme byte).
static ENTRIES: [RegistryEntry; 4] = [
    RegistryEntry { scheme: Scheme::Bitmask, name: "bitmask", aliases: &[], codec: &BITMASK },
    RegistryEntry { scheme: Scheme::Zrlc, name: "zrlc", aliases: &[], codec: &ZRLC },
    RegistryEntry {
        scheme: Scheme::Dictionary,
        name: "dictionary",
        aliases: &["dict"],
        codec: &DICTIONARY,
    },
    RegistryEntry { scheme: Scheme::Raw, name: "raw", aliases: &[], codec: &RAW },
];

static GLOBAL: Registry = Registry { entries: &ENTRIES };

/// Name ⇄ tag ⇄ compressor lookup over the registered codecs.
pub struct Registry {
    entries: &'static [RegistryEntry],
}

impl Registry {
    /// The process-wide registry of built-in codecs.
    pub fn global() -> &'static Registry {
        debug_assert!(ENTRIES.len() <= 1 << TAG_BITS, "registry outgrew the 2-bit tag");
        &GLOBAL
    }

    /// All registered codecs, in tag order.
    pub fn entries(&self) -> &'static [RegistryEntry] {
        self.entries
    }

    /// All registered scheme ids, in tag order.
    pub fn schemes(&self) -> Vec<Scheme> {
        self.entries.iter().map(|e| e.scheme).collect()
    }

    /// Stable on-format tag of a scheme (its registry position).
    pub fn tag_of(&self, scheme: Scheme) -> u8 {
        self.entries
            .iter()
            .position(|e| e.scheme == scheme)
            // lint: allow(panic-in-decoder, registry invariant - the global table registers every Scheme variant, not payload data)
            .expect("every Scheme variant is registered") as u8
    }

    /// Scheme for an on-format tag; errors on out-of-range tags (corrupt
    /// container / record data).
    pub fn scheme_of_tag(&self, tag: u8) -> Result<Scheme> {
        self.entries
            .get(tag as usize)
            .map(|e| e.scheme)
            .ok_or_else(|| err!("unknown codec tag {tag} (registry has {})", self.entries.len()))
    }

    /// The shared compressor instance for a scheme.
    pub fn compressor(&self, scheme: Scheme) -> &'static dyn Compressor {
        self.entries[self.tag_of(scheme) as usize].codec
    }

    /// The compressor for an (already validated) on-format tag.
    pub fn compressor_of_tag(&self, tag: u8) -> &'static dyn Compressor {
        self.entries[tag as usize].codec
    }

    /// Canonical name of a scheme.
    pub fn name_of(&self, scheme: Scheme) -> &'static str {
        self.entries[self.tag_of(scheme) as usize].name
    }

    /// Comma-separated valid codec names (for error messages / help).
    pub fn valid_names(&self) -> String {
        let names: Vec<&str> = self.entries.iter().map(|e| e.name).collect();
        names.join(", ")
    }

    /// THE codec-name parser — the single one the CLI, the manifest and
    /// the harness all go through. Unknown names list the valid codecs.
    pub fn parse(&self, s: &str) -> Result<Scheme> {
        self.entries
            .iter()
            .find(|e| e.name == s || e.aliases.contains(&s))
            .map(|e| e.scheme)
            .ok_or_else(|| err!("unknown codec '{s}' (valid: {}, auto)", self.valid_names()))
    }

    /// Parse a codec *policy*: a codec name for `Fixed`, or
    /// `auto`/`adaptive` for per-sub-tensor selection.
    pub fn parse_policy(&self, s: &str) -> Result<CodecPolicy> {
        match s {
            "auto" | "adaptive" => Ok(CodecPolicy::Adaptive),
            other => self.parse(other).map(CodecPolicy::Fixed),
        }
    }

    /// Largest distinct-value capacity any registered codec needs for
    /// exact [`Compressor::sizes_from_stats`] sizing — the adaptive plan
    /// pass tracks this once and sizes every codec from the same stats.
    pub fn max_stats_dict_cap(&self) -> usize {
        self.entries.iter().map(|e| e.codec.stats_dict_cap()).max().unwrap_or(0)
    }

    /// Whether any registered codec cannot size itself from `stats`
    /// alone (and would need the gathered block in
    /// [`Registry::sizes_from`]). Currently always false; exists so
    /// lazy-gathering callers stay correct when a stats-blind codec is
    /// registered.
    pub fn any_stats_blind(&self, stats: &crate::compress::BlockStats) -> bool {
        self.entries.iter().any(|e| e.codec.sizes_from_stats(stats).is_none())
    }

    /// THE adaptive sizing substrate: every registered codec's exact
    /// `(words, bits)` for one sub-tensor, in tag order, written into
    /// `out`. Sizes come from the fused `stats`; a stats-blind codec
    /// falls back to `block` (the gathered elements — pass `None` only
    /// when [`Registry::any_stats_blind`] is false). The packing
    /// engine's plan pass and the streaming store writer both select
    /// through here + [`Registry::select`], so the two can never drift
    /// (the seed-oracle packer deliberately keeps its own
    /// `compressed_sizes`-based path as the independent cross-check).
    pub fn sizes_from(
        &self,
        stats: &crate::compress::BlockStats,
        block: Option<&[f32]>,
        out: &mut Vec<(usize, usize)>,
    ) {
        out.clear();
        for e in self.entries {
            out.push(e.codec.sizes_from_stats(stats).unwrap_or_else(|| {
                e.codec
                    // lint: allow(panic-in-decoder, caller contract on the packing side - sizing never sees payload bytes)
                    .compressed_sizes(block.expect("stats-blind codec needs the gathered block"))
            }));
        }
    }

    /// Pick the cheapest codec for one sub-tensor: `sizes[tag]` is each
    /// registered codec's exact `(words, bits)`. Aligned divisions pay
    /// words (line-rounded, monotone in words) so the key is
    /// `(words, bits)`; the compact baseline pays idealised bits so the
    /// key is `(bits, words)`. Ties take the lowest tag. Returns the
    /// winning tag.
    pub fn select(&self, sizes: &[(usize, usize)], compact: bool) -> u8 {
        debug_assert_eq!(sizes.len(), self.entries.len());
        let key = |&(w, b): &(usize, usize)| if compact { (b, w) } else { (w, b) };
        sizes
            .iter()
            .enumerate()
            // min_by_key keeps the FIRST minimum — lowest tag on ties.
            .min_by_key(|&(_, wb)| key(wb))
            .map(|(i, _)| i as u8)
            // lint: allow(panic-in-decoder, registry invariant - the global table is a non-empty const list)
            .expect("registry is never empty")
    }
}

/// Which codec(s) a map is packed with — the parameter every storage
/// and pricing entry point takes (replacing the bare [`Scheme`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecPolicy {
    /// One codec for every sub-tensor (no tag overhead).
    Fixed(Scheme),
    /// Per-sub-tensor cheapest codec; each Fig. 7 record slot carries a
    /// [`TAG_BITS`]-bit codec tag, accounted as metadata traffic.
    Adaptive,
}

impl CodecPolicy {
    /// Display/CLI name (`auto` for adaptive, the codec name otherwise).
    pub fn name(&self) -> &'static str {
        match self {
            CodecPolicy::Fixed(s) => Registry::global().name_of(*s),
            CodecPolicy::Adaptive => "auto",
        }
    }

    pub fn is_adaptive(&self) -> bool {
        matches!(self, CodecPolicy::Adaptive)
    }
}

impl From<Scheme> for CodecPolicy {
    fn from(s: Scheme) -> CodecPolicy {
        CodecPolicy::Fixed(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_stable_and_roundtrip() {
        let r = Registry::global();
        // The on-format contract: these exact tags are written to disk.
        assert_eq!(r.tag_of(Scheme::Bitmask), 0);
        assert_eq!(r.tag_of(Scheme::Zrlc), 1);
        assert_eq!(r.tag_of(Scheme::Dictionary), 2);
        assert_eq!(r.tag_of(Scheme::Raw), 3);
        for s in r.schemes() {
            assert_eq!(r.scheme_of_tag(r.tag_of(s)).unwrap(), s);
            assert_eq!(r.compressor(s).scheme(), s);
        }
        assert!(r.scheme_of_tag(4).is_err());
        assert!(r.entries().len() <= 1 << TAG_BITS);
    }

    #[test]
    fn parse_names_aliases_and_policy() {
        let r = Registry::global();
        for s in r.schemes() {
            assert_eq!(r.parse(r.name_of(s)).unwrap(), s);
            assert_eq!(r.parse_policy(r.name_of(s)).unwrap(), CodecPolicy::Fixed(s));
        }
        assert_eq!(r.parse("dict").unwrap(), Scheme::Dictionary);
        assert_eq!(r.parse_policy("auto").unwrap(), CodecPolicy::Adaptive);
        assert_eq!(r.parse_policy("adaptive").unwrap(), CodecPolicy::Adaptive);
        let e = r.parse("nope").unwrap_err().to_string();
        assert!(e.contains("bitmask") && e.contains("raw") && e.contains("auto"), "{e}");
        assert!(r.parse_policy("nope").is_err());
    }

    #[test]
    fn select_minimises_the_paid_cost() {
        let r = Registry::global();
        // Aligned: words dominate, bits break ties.
        assert_eq!(r.select(&[(9, 144), (12, 100), (9, 100), (20, 10)], false), 2);
        // Compact: bits dominate.
        assert_eq!(r.select(&[(9, 144), (12, 100), (9, 100), (20, 10)], true), 3);
        // Ties resolve to the lowest tag (deterministic).
        assert_eq!(r.select(&[(5, 80), (5, 80), (5, 80), (5, 80)], false), 0);
    }

    #[test]
    fn policy_names_and_conversion() {
        assert_eq!(CodecPolicy::Adaptive.name(), "auto");
        assert_eq!(CodecPolicy::from(Scheme::Zrlc), CodecPolicy::Fixed(Scheme::Zrlc));
        assert_eq!(CodecPolicy::Fixed(Scheme::Bitmask).name(), "bitmask");
        assert!(CodecPolicy::Adaptive.is_adaptive());
        assert!(!CodecPolicy::Fixed(Scheme::Raw).is_adaptive());
    }

    #[test]
    fn max_stats_dict_cap_is_dictionarys() {
        assert_eq!(Registry::global().max_stats_dict_cap(), 256);
    }
}
