//! Sub-tensor compression substrates (paper Fig. 4 and §V).
//!
//! GrateTile is *independent of the compression algorithm*; the paper
//! evaluates with bitmask compression and mentions ZRLC and
//! dictionary-based codecs in its hardware study. This module implements
//! all of them, bit-exact, over 16-bit (bf16) feature words:
//!
//! * [`Bitmask`] — 1 mask bit per word + packed nonzero values;
//! * [`Zrlc`] — zero run-length coding (5-bit run, 16-bit value tokens);
//! * [`Dictionary`] — per-block value dictionary + index stream;
//! * [`RawDense`] — identity (the uncompressed baseline).
//!
//! Compressed sizes are in 16-bit words; the layout/sim layers round them
//! up to 8-word cache lines. Every codec round-trips exactly
//! (`decompress(compress(x)) == bf16(x)`), enforced by unit + property
//! tests here and by the Pallas/`ref.py` cross-check at build time.

// Decoder surface: unwrap() is a denied panic path in production
// code (tests may unwrap freely).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod bits;
pub mod bitmask;
pub mod cost;
pub mod dictionary;
pub mod hwmodel;
pub mod raw;
pub mod registry;
pub mod stats;
pub mod zrlc;

pub use bitmask::Bitmask;
pub use cost::CodecCost;
pub use dictionary::Dictionary;
pub use raw::RawDense;
pub use registry::{CodecPolicy, Registry, RegistryEntry, TAG_BITS};
pub use stats::{BlockStats, DistinctTracker, StatsAcc};
pub use zrlc::Zrlc;

/// A compressed sub-tensor: an opaque word payload plus element count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompressedBlock {
    /// Number of original elements.
    pub n_elems: usize,
    /// Payload in 16-bit words.
    pub words: Vec<u16>,
}

impl CompressedBlock {
    pub fn compressed_words(&self) -> usize {
        self.words.len()
    }
}

/// Compression scheme identifier (for configs/CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    Bitmask,
    Zrlc,
    Dictionary,
    Raw,
}

impl Scheme {
    /// Canonical name — delegates to the [`Registry`], the single
    /// name ⇄ codec table.
    pub fn name(&self) -> &'static str {
        Registry::global().name_of(*self)
    }

    /// Parse a codec name — the registry's parser, `Option`-shaped for
    /// historical callers. New code should use [`Registry::parse`]
    /// (which lists valid names on failure) or
    /// [`Registry::parse_policy`] (which also accepts `auto`).
    pub fn parse(s: &str) -> Option<Scheme> {
        Registry::global().parse(s).ok()
    }

    /// Construct a boxed instance of this scheme's codec (historical
    /// API; each variant boxes the same configuration the registry's
    /// shared instance uses — `Dictionary::default()` is the 256-entry
    /// registry dictionary). Hot paths should prefer
    /// [`Registry::compressor`], which hands out
    /// `&'static dyn Compressor` without allocating.
    pub fn build(&self) -> Box<dyn Compressor> {
        match self {
            Scheme::Bitmask => Box::new(Bitmask),
            Scheme::Zrlc => Box::new(Zrlc),
            Scheme::Dictionary => Box::new(Dictionary::default()),
            Scheme::Raw => Box::new(RawDense),
        }
    }
}

/// A sub-tensor compressor. Implementations must be deterministic and
/// bit-exact on bf16-quantised inputs.
pub trait Compressor: Send + Sync {
    fn scheme(&self) -> Scheme;

    /// Encode `block` (bf16-quantised f32 words).
    fn compress(&self, block: &[f32]) -> CompressedBlock;

    /// Decode into `out` (must be `n_elems` long).
    fn decompress(&self, comp: &CompressedBlock, out: &mut [f32]);

    /// Exact compressed size in words without materialising the payload
    /// (hot path for the bandwidth simulator). Default: full encode.
    fn compressed_words(&self, block: &[f32]) -> usize {
        self.compress(block).compressed_words()
    }

    /// Idealised compressed size in *bits* (no word padding). This is
    /// what the compact Uniform 1×1×8 upper bound of §IV-B(2) pays per
    /// sub-tensor; word-aligned storage uses [`Compressor::compressed_words`].
    /// Default: `compressed_words × 16`.
    fn compressed_bits(&self, block: &[f32]) -> usize {
        self.compressed_words(block) * 16
    }

    /// Both exact sizes — `(words, idealised bits)` — in one scan where
    /// the codec can manage it. Callers that need both (the reference
    /// packer, size audits) go through here instead of paying two
    /// independent block scans.
    fn compressed_sizes(&self, block: &[f32]) -> (usize, usize) {
        (self.compressed_words(block), self.compressed_bits(block))
    }

    /// Compress and report the idealised bit size of the same block in
    /// a single pass (the streaming writer's hot path; the default pays
    /// an extra sizing scan).
    fn compress_with_bits(&self, block: &[f32]) -> (CompressedBlock, usize) {
        let bits = self.compressed_bits(block);
        (self.compress(block), bits)
    }

    /// Exact `(words, bits)` from fused single-pass [`BlockStats`] —
    /// the packing engine's scan-free sizing. `None` means the codec
    /// cannot size from stats and the planner falls back to a block
    /// gather + [`Compressor::compressed_sizes`].
    fn sizes_from_stats(&self, _stats: &BlockStats) -> Option<(usize, usize)> {
        None
    }

    /// Dictionary capacity the stats pass must track distinct values up
    /// to for [`Compressor::sizes_from_stats`] to be exact; 0 = distinct
    /// tracking not needed (skips the tracker entirely).
    fn stats_dict_cap(&self) -> usize {
        0
    }

    /// Decode only elements `[start, start + out.len())` of `comp` —
    /// the fetcher's partial-window fast path. Returns `false` when the
    /// codec cannot random-access its stream (caller decodes fully).
    fn decompress_span(&self, _comp: &CompressedBlock, _start: usize, _out: &mut [f32]) -> bool {
        false
    }

    /// Count the nonzero elements in `[start, start + len)` from index
    /// metadata alone — **no value decode, no payload-value access**.
    /// This is the zero-skip query of the compute backend: an answer of
    /// `Some(0)` lets a whole im2col row span bypass the GEMM kernel.
    /// `None` means the codec has no random-access occupancy index and
    /// the caller must conservatively assume nonzeros.
    fn span_nonzeros(&self, _comp: &CompressedBlock, _start: usize, _len: usize) -> Option<usize> {
        None
    }

    /// Metadata-only all-zero test for a whole compressed sub-tensor
    /// (`Some(true)` = certainly empty, skip the decode; `None` =
    /// unknown without decoding). Default delegates to
    /// [`Compressor::span_nonzeros`] over the full element range.
    fn is_all_zero(&self, comp: &CompressedBlock) -> Option<bool> {
        self.span_nonzeros(comp, 0, comp.n_elems).map(|nnz| nnz == 0)
    }

    /// Hardware cost proxy for the §V codec comparison.
    fn cost(&self) -> CodecCost;
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::tensor::dense::bf16_quantise;
    use crate::util::SplitMix64;

    /// Random bf16-quantised sparse block for codec tests.
    pub fn random_block(rng: &mut SplitMix64, len: usize, density: f64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.chance(density) {
                    bf16_quantise(rng.next_f32() * 10.0 - 3.0)
                } else {
                    0.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall_res, SparseVecGen};
    use crate::util::SplitMix64;

    fn all_schemes() -> Vec<Scheme> {
        vec![Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw]
    }

    #[test]
    fn scheme_name_parse_roundtrip() {
        for s in all_schemes() {
            assert_eq!(Scheme::parse(s.name()), Some(s));
        }
        assert_eq!(Scheme::parse("nope"), None);
    }

    /// Cross-codec property: every codec round-trips every sparse block
    /// exactly, and `compressed_words` agrees with the actual payload.
    #[test]
    fn all_codecs_roundtrip_property() {
        for scheme in all_schemes() {
            let codec = scheme.build();
            forall_res(
                0xBEEF ^ scheme.name().len() as u64,
                128,
                SparseVecGen { max_len: 600, zero_p: 0.6 },
                |v| {
                    let quant: Vec<f32> =
                        v.iter().map(|&x| crate::tensor::dense::bf16_quantise(x)).collect();
                    let comp = codec.compress(&quant);
                    if comp.compressed_words() != codec.compressed_words(&quant) {
                        return Err(format!(
                            "{}: size fast-path mismatch {} vs {}",
                            scheme.name(),
                            codec.compressed_words(&quant),
                            comp.compressed_words()
                        ));
                    }
                    let mut out = vec![0.0f32; quant.len()];
                    codec.decompress(&comp, &mut out);
                    if out != quant {
                        return Err(format!("{}: roundtrip mismatch", scheme.name()));
                    }
                    Ok(())
                },
            );
        }
    }

    /// Robustness property (ISSUE 8 tentpole): *no* codec may panic on
    /// a corrupted payload — flipped bits, zeroed words, truncated
    /// tails. Garbage output is fine (the integrity layer above flags
    /// it); a panic inside a fetch lane is not.
    #[test]
    fn corrupt_payloads_never_panic_any_codec() {
        let mut rng = SplitMix64::new(0xC0AB);
        for scheme in all_schemes() {
            let codec = scheme.build();
            for &density in &[0.0, 0.3, 1.0] {
                let blk = testutil::random_block(&mut rng, 300, density);
                let clean = codec.compress(&blk);
                let mut out = vec![0.0f32; blk.len()];
                for trial in 0..40 {
                    let mut comp = clean.clone();
                    if comp.words.is_empty() {
                        continue;
                    }
                    match trial % 3 {
                        // Single bit flip (what FaultySource injects).
                        0 => {
                            let w = rng.below(comp.words.len());
                            comp.words[w] ^= 1 << rng.below(16);
                        }
                        // Truncated tail (what a short read leaves).
                        1 => {
                            let keep = rng.below(comp.words.len());
                            comp.words.truncate(keep);
                        }
                        // Zero-filled span (FilePayload's unreadable-
                        // span behaviour).
                        _ => {
                            let from = rng.below(comp.words.len());
                            for w in &mut comp.words[from..] {
                                *w = 0;
                            }
                        }
                    }
                    codec.decompress(&comp, &mut out);
                    let mut span = vec![0.0f32; blk.len() / 2];
                    codec.decompress_span(&comp, 7.min(blk.len() / 2), &mut span);
                    let _ = codec.span_nonzeros(&comp, 0, blk.len());
                    let _ = codec.is_all_zero(&comp);
                }
            }
        }
    }

    /// An all-zero 512-word block must compress to (near) nothing for the
    /// sparse codecs.
    #[test]
    fn all_zero_block_compresses_hard() {
        let zeros = vec![0.0f32; 512];
        assert!(Bitmask.compressed_words(&zeros) <= 32); // mask only
        assert!(Zrlc.compressed_words(&zeros) <= 36);
        assert!(Dictionary::default().compressed_words(&zeros) <= 40);
        assert_eq!(RawDense.compressed_words(&zeros), 512);
    }

    /// On dense data, sparse codecs must not beat raw by much — and
    /// bitmask must cost exactly raw + mask.
    #[test]
    fn dense_block_sizes() {
        let mut rng = SplitMix64::new(1);
        let dense = testutil::random_block(&mut rng, 512, 1.0);
        assert_eq!(Bitmask.compressed_words(&dense), 512 + 32);
        assert!(Zrlc.compressed_words(&dense) >= 512);
        assert_eq!(RawDense.compressed_words(&dense), 512);
    }

    /// The paper's operating point: ~35-40% density should compress to
    /// well under half with bitmask.
    #[test]
    fn bitmask_at_paper_density() {
        let mut rng = SplitMix64::new(2);
        let blk = testutil::random_block(&mut rng, 512, 0.37);
        let words = Bitmask.compressed_words(&blk);
        let ratio = words as f64 / 512.0;
        assert!((0.35..0.50).contains(&ratio), "ratio {ratio}");
    }
}
