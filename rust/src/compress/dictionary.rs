//! Dictionary (vector-quantisation-style) compression.
//!
//! Mentioned in the paper's §V hardware comparison and related work
//! (Wu et al.'s k-means value clustering). Per block: a header word with
//! the entry count, the dictionary of distinct bf16 values, then one
//! bit-packed index per element. Falls back to raw (entry count 0 marker)
//! when the block has more distinct values than [`Dictionary::max_entries`]
//! — on such blocks VQ is counter-productive.

use super::bits::{words_for_bits, BitReader, BitWriter};
use super::stats::BlockStats;
use super::{CodecCost, CompressedBlock, Compressor, Scheme};
use crate::tensor::dense::{bf16_bits, bf16_from_bits};

/// Dictionary codec with a bounded per-block dictionary.
#[derive(Debug, Clone, Copy)]
pub struct Dictionary {
    /// Maximum dictionary entries (index width = ceil(log2(entries))).
    pub max_entries: usize,
}

impl Default for Dictionary {
    fn default() -> Self {
        Self { max_entries: 256 }
    }
}

/// Header encoding: entry count, or RAW_MARKER for the fallback.
const RAW_MARKER: u16 = u16::MAX;

impl Dictionary {
    fn build_dict(&self, block: &[f32]) -> Option<Vec<u16>> {
        let mut dict: Vec<u16> = Vec::new();
        for &v in block {
            let bits = bf16_bits(v);
            if !dict.contains(&bits) {
                if dict.len() == self.max_entries {
                    return None;
                }
                dict.push(bits);
            }
        }
        Some(dict)
    }

    fn index_bits(dict_len: usize) -> usize {
        if dict_len <= 1 {
            1
        } else {
            (usize::BITS - (dict_len - 1).leading_zeros()) as usize
        }
    }
}

impl Compressor for Dictionary {
    fn scheme(&self) -> Scheme {
        Scheme::Dictionary
    }

    fn compress(&self, block: &[f32]) -> CompressedBlock {
        if block.is_empty() {
            return CompressedBlock { n_elems: 0, words: vec![] };
        }
        match self.build_dict(block) {
            Some(dict) => {
                let idx_bits = Self::index_bits(dict.len());
                let mut words = vec![dict.len() as u16];
                words.extend_from_slice(&dict);
                let mut w = BitWriter::new();
                for &v in block {
                    let bits = bf16_bits(v);
                    #[allow(clippy::unwrap_used)] // build_dict collected every distinct value
                    // lint: allow(panic-in-decoder, compress side - build_dict returned a dict containing every value of this very block)
                    let idx = dict.iter().position(|&d| d == bits).unwrap();
                    w.write(idx as u32, idx_bits);
                }
                words.extend(w.finish());
                CompressedBlock { n_elems: block.len(), words }
            }
            None => {
                // Raw fallback: marker + verbatim values.
                let mut words = vec![RAW_MARKER];
                words.extend(block.iter().map(|&v| bf16_bits(v)));
                CompressedBlock { n_elems: block.len(), words }
            }
        }
    }

    fn decompress(&self, comp: &CompressedBlock, out: &mut [f32]) {
        assert_eq!(out.len(), comp.n_elems);
        if comp.n_elems == 0 {
            return;
        }
        // Corruption-tolerant: a flipped header may claim a dictionary
        // larger than the payload, and corrupt indices may point past
        // the dictionary. Decode clamps to what exists and fills the
        // rest with zeros — never panics; the integrity layer above
        // decides whether the bits were trustworthy.
        out.fill(0.0);
        let Some(&header) = comp.words.first() else { return };
        if header == RAW_MARKER {
            // lint: allow(panic-in-decoder, words.first() above proves len >= 1 so [1..] cannot be out of range)
            for (o, &wv) in out.iter_mut().zip(&comp.words[1..]) {
                *o = bf16_from_bits(wv);
            }
            return;
        }
        let dict_len = (header as usize).min(comp.words.len() - 1);
        if dict_len == 0 {
            return;
        }
        // lint: allow(panic-in-decoder, dict_len is clamped to words.len() - 1 two lines up)
        let dict = &comp.words[1..1 + dict_len];
        let idx_bits = Self::index_bits(dict_len);
        // lint: allow(panic-in-decoder, 1 + dict_len <= words.len() by the same clamp)
        let mut r = BitReader::new(&comp.words[1 + dict_len..]);
        for o in out.iter_mut() {
            let idx = (r.read(idx_bits) as usize).min(dict_len - 1);
            *o = bf16_from_bits(dict[idx]);
        }
    }

    fn compressed_words(&self, block: &[f32]) -> usize {
        if block.is_empty() {
            return 0;
        }
        match self.build_dict(block) {
            Some(dict) => {
                1 + dict.len() + words_for_bits(block.len() * Self::index_bits(dict.len()))
            }
            None => 1 + block.len(),
        }
    }

    fn compressed_bits(&self, block: &[f32]) -> usize {
        if block.is_empty() {
            return 0;
        }
        match self.build_dict(block) {
            Some(dict) => {
                16 + dict.len() * 16 + block.len() * Self::index_bits(dict.len())
            }
            None => 16 + block.len() * 16,
        }
    }

    fn compressed_sizes(&self, block: &[f32]) -> (usize, usize) {
        if block.is_empty() {
            return (0, 0);
        }
        // One dictionary build feeds both sizes (the default would
        // build it twice).
        match self.build_dict(block) {
            Some(dict) => {
                let (len, ib) = (dict.len(), Self::index_bits(dict.len()));
                (1 + len + words_for_bits(block.len() * ib), 16 + len * 16 + block.len() * ib)
            }
            None => (1 + block.len(), 16 + block.len() * 16),
        }
    }

    fn compress_with_bits(&self, block: &[f32]) -> (CompressedBlock, usize) {
        // The header word already says which branch the block took.
        let comp = self.compress(block);
        let n = block.len();
        let bits = match comp.words.first().copied() {
            _ if n == 0 => 0,
            // A missing header cannot happen for n > 0 (compress always
            // emits one) — folding it into the raw branch keeps this
            // arithmetic panic-free without an unreachable!().
            Some(RAW_MARKER) | None => 16 + n * 16,
            Some(len) => {
                let len = len as usize;
                16 + len * 16 + n * Self::index_bits(len)
            }
        };
        (comp, bits)
    }

    fn sizes_from_stats(&self, s: &BlockStats) -> Option<(usize, usize)> {
        if s.n_elems == 0 {
            return Some((0, 0));
        }
        // `distinct` saturates at cap + 1, which is exactly the raw
        // fallback condition of `build_dict`.
        if s.distinct <= self.max_entries {
            let ib = Self::index_bits(s.distinct);
            Some((
                1 + s.distinct + words_for_bits(s.n_elems * ib),
                16 + s.distinct * 16 + s.n_elems * ib,
            ))
        } else {
            Some((1 + s.n_elems, 16 + s.n_elems * 16))
        }
    }

    fn stats_dict_cap(&self) -> usize {
        self.max_entries
    }

    fn cost(&self) -> CodecCost {
        // CAM lookup per lane; large area, parallel decode.
        CodecCost { gates_per_lane: 450, enc_cycles_per_word: 2.0, dec_cycles_per_word: 1.0, serial: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::random_block;
    use crate::util::SplitMix64;

    fn roundtrip(blk: &[f32]) -> usize {
        let d = Dictionary::default();
        let c = d.compress(blk);
        let mut out = vec![0.0; blk.len()];
        d.decompress(&c, &mut out);
        assert_eq!(out, blk);
        assert_eq!(c.compressed_words(), d.compressed_words(blk));
        c.compressed_words()
    }

    #[test]
    fn low_cardinality_compresses_well() {
        // 512 words drawn from 4 distinct values -> 2 bits/elem.
        let vals = [0.0f32, 1.0, 2.0, 4.0];
        let mut rng = SplitMix64::new(5);
        let blk: Vec<f32> = (0..512).map(|_| vals[rng.below(4)]).collect();
        let words = roundtrip(&blk);
        assert_eq!(words, 1 + 4 + words_for_bits(512 * 2));
        assert!(words < 100);
    }

    #[test]
    fn high_cardinality_falls_back_to_raw() {
        let small = Dictionary { max_entries: 8 };
        let mut rng = SplitMix64::new(6);
        let blk = random_block(&mut rng, 512, 1.0);
        let c = small.compress(&blk);
        assert_eq!(c.words[0], RAW_MARKER);
        assert_eq!(c.compressed_words(), 513);
        let mut out = vec![0.0; 512];
        small.decompress(&c, &mut out);
        assert_eq!(out, blk);
        assert_eq!(small.compressed_words(&blk), 513);
    }

    #[test]
    fn sparse_blocks_roundtrip() {
        let mut rng = SplitMix64::new(7);
        for &d in &[0.0, 0.2, 0.5] {
            roundtrip(&random_block(&mut rng, 300, d));
        }
    }

    #[test]
    fn single_value_block() {
        let blk = vec![3.5f32; 64];
        let words = roundtrip(&blk);
        // header + 1 entry + 64 x 1 bit = 2 + 4 words.
        assert_eq!(words, 2 + words_for_bits(64));
    }

    #[test]
    fn index_bits_widths() {
        assert_eq!(Dictionary::index_bits(1), 1);
        assert_eq!(Dictionary::index_bits(2), 1);
        assert_eq!(Dictionary::index_bits(3), 2);
        assert_eq!(Dictionary::index_bits(4), 2);
        assert_eq!(Dictionary::index_bits(5), 3);
        assert_eq!(Dictionary::index_bits(256), 8);
    }
}
