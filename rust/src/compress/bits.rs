//! Bit-level packing into 16-bit word streams.
//!
//! ZRLC tokens (21 bits) and dictionary indices (1–15 bits) are not
//! word-aligned; these helpers pack/unpack little-endian bit runs into
//! the `Vec<u16>` payloads used by [`super::CompressedBlock`].

/// Append-only bit writer over 16-bit words (LSB-first within a word).
#[derive(Debug, Default)]
pub struct BitWriter {
    words: Vec<u16>,
    /// Bits already used in the last word (0 when aligned).
    bit_pos: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n ≤ 32).
    pub fn write(&mut self, v: u32, n: usize) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || v < (1u32 << n), "value {v} does not fit {n} bits");
        let mut remaining = n;
        let mut val = v as u64;
        while remaining > 0 {
            if self.bit_pos == 0 {
                self.words.push(0);
            }
            let Some(last) = self.words.last_mut() else { break };
            let space = 16 - self.bit_pos;
            let take = space.min(remaining);
            let mask = if take == 16 { 0xFFFF } else { (1u64 << take) - 1 };
            *last |= (((val & mask) as u16) << self.bit_pos) as u16;
            val >>= take;
            self.bit_pos = (self.bit_pos + take) % 16;
            remaining -= take;
        }
    }

    /// Total bits written so far.
    pub fn bits(&self) -> usize {
        if self.bit_pos == 0 {
            self.words.len() * 16
        } else {
            (self.words.len() - 1) * 16 + self.bit_pos
        }
    }

    /// Finish, returning the padded word vector.
    pub fn finish(self) -> Vec<u16> {
        self.words
    }
}

/// Sequential bit reader matching [`BitWriter`]'s layout.
#[derive(Debug)]
pub struct BitReader<'a> {
    words: &'a [u16],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u16]) -> Self {
        Self { words, pos: 0 }
    }

    /// Read `n` bits (n ≤ 32). Reading past the end of the stream
    /// yields zero bits — corrupt payloads must decode to *something*
    /// (garbage is fine; the integrity layer above decides whether the
    /// bits were trustworthy), never panic. Well-formed streams never
    /// read past their own length.
    pub fn read(&mut self, n: usize) -> u32 {
        debug_assert!(n <= 32);
        let mut out: u64 = 0;
        let mut got = 0;
        while got < n {
            let word_idx = self.pos / 16;
            let bit_idx = self.pos % 16;
            let avail = 16 - bit_idx;
            let take = avail.min(n - got);
            let chunk = (self.words.get(word_idx).copied().unwrap_or(0) >> bit_idx) as u64;
            let mask = if take == 16 { 0xFFFF } else { (1u64 << take) - 1 };
            out |= (chunk & mask) << got;
            got += take;
            self.pos += take;
        }
        out as u32
    }

    pub fn bits_read(&self) -> usize {
        self.pos
    }
}

/// Words needed for `bits` bits.
pub fn words_for_bits(bits: usize) -> usize {
    bits.div_ceil(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn simple_roundtrip() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xFFFF, 16);
        w.write(0, 1);
        w.write(0x1F, 5);
        assert_eq!(w.bits(), 25);
        let words = w.finish();
        assert_eq!(words.len(), 2);
        let mut r = BitReader::new(&words);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(16), 0xFFFF);
        assert_eq!(r.read(1), 0);
        assert_eq!(r.read(5), 0x1F);
    }

    #[test]
    fn word_aligned_values() {
        let mut w = BitWriter::new();
        w.write(0xABCD, 16);
        w.write(0x1234, 16);
        let words = w.finish();
        assert_eq!(words, vec![0xABCD, 0x1234]);
    }

    #[test]
    fn randomized_roundtrip_property() {
        let mut rng = SplitMix64::new(0xB175);
        for _ in 0..200 {
            let n_items = rng.range(1, 100);
            let items: Vec<(u32, usize)> = (0..n_items)
                .map(|_| {
                    let bits = rng.range(1, 24);
                    let v = (rng.next_u64() as u32) & ((1u32 << bits) - 1).max(1);
                    (v.min((1u32 << bits) - 1), bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &items {
                w.write(v, b);
            }
            let words = w.finish();
            let mut r = BitReader::new(&words);
            for &(v, b) in &items {
                assert_eq!(r.read(b), v, "bits={b}");
            }
        }
    }

    #[test]
    fn words_for_bits_rounding() {
        assert_eq!(words_for_bits(0), 0);
        assert_eq!(words_for_bits(1), 1);
        assert_eq!(words_for_bits(16), 1);
        assert_eq!(words_for_bits(17), 2);
        assert_eq!(words_for_bits(21 * 3), 4);
    }
}
