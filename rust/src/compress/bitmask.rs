//! Bitmask compression (paper Fig. 4, used for all §IV experiments).
//!
//! Layout: `ceil(n/16)` mask words (bit i of word j covers element
//! `16*j + i`; 1 = nonzero) followed by the nonzero bf16 values in order.
//! Size is exactly `ceil(n/16) + nnz` words, which makes the simulator's
//! fast path a popcount-free nonzero count.

use super::{CompressedBlock, Compressor, CodecCost, Scheme};
use crate::tensor::dense::{bf16_bits, bf16_from_bits};
use crate::util::ceil_div;

/// The bitmask codec (stateless).
#[derive(Debug, Clone, Copy, Default)]
pub struct Bitmask;

impl Compressor for Bitmask {
    fn scheme(&self) -> Scheme {
        Scheme::Bitmask
    }

    fn compress(&self, block: &[f32]) -> CompressedBlock {
        let n = block.len();
        let mask_words = ceil_div(n, 16);
        let mut words = vec![0u16; mask_words];
        let mut values = Vec::new();
        for (i, &v) in block.iter().enumerate() {
            if v != 0.0 {
                words[i / 16] |= 1 << (i % 16);
                values.push(bf16_bits(v));
            }
        }
        words.extend_from_slice(&values);
        CompressedBlock { n_elems: n, words }
    }

    fn decompress(&self, comp: &CompressedBlock, out: &mut [f32]) {
        assert_eq!(out.len(), comp.n_elems);
        let mask_words = ceil_div(comp.n_elems, 16);
        let (mask, values) = comp.words.split_at(mask_words);
        let mut vi = 0;
        for (i, o) in out.iter_mut().enumerate() {
            if mask[i / 16] >> (i % 16) & 1 == 1 {
                *o = bf16_from_bits(values[vi]);
                vi += 1;
            } else {
                *o = 0.0;
            }
        }
    }

    fn compressed_words(&self, block: &[f32]) -> usize {
        let nnz = block.iter().filter(|&&v| v != 0.0).count();
        ceil_div(block.len(), 16) + nnz
    }

    fn compressed_bits(&self, block: &[f32]) -> usize {
        // Exact: one mask bit per element + 16 bits per nonzero.
        let nnz = block.iter().filter(|&&v| v != 0.0).count();
        block.len() + nnz * 16
    }

    fn cost(&self) -> CodecCost {
        // One comparator + mask register per lane; decompression is a
        // prefix-sum scatter. See `cost.rs` for the model.
        CodecCost { gates_per_lane: 120, enc_cycles_per_word: 1.0, dec_cycles_per_word: 1.0, serial: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::random_block;
    use crate::util::SplitMix64;

    #[test]
    fn roundtrip_exact() {
        let mut rng = SplitMix64::new(1);
        for &d in &[0.0, 0.1, 0.5, 1.0] {
            let blk = random_block(&mut rng, 512, d);
            let c = Bitmask.compress(&blk);
            let mut out = vec![0.0; 512];
            Bitmask.decompress(&c, &mut out);
            assert_eq!(out, blk, "density {d}");
        }
    }

    #[test]
    fn size_formula() {
        let mut blk = vec![0.0f32; 512];
        blk[0] = 1.0;
        blk[100] = 2.0;
        blk[511] = 3.0;
        assert_eq!(Bitmask.compressed_words(&blk), 32 + 3);
        assert_eq!(Bitmask.compress(&blk).compressed_words(), 32 + 3);
    }

    #[test]
    fn non_multiple_of_16_lengths() {
        let mut rng = SplitMix64::new(2);
        for len in [1usize, 15, 17, 100, 511] {
            let blk = random_block(&mut rng, len, 0.4);
            let c = Bitmask.compress(&blk);
            let mut out = vec![0.0; len];
            Bitmask.decompress(&c, &mut out);
            assert_eq!(out, blk, "len {len}");
            assert_eq!(c.compressed_words(), Bitmask.compressed_words(&blk));
        }
    }

    #[test]
    fn empty_block() {
        let c = Bitmask.compress(&[]);
        assert_eq!(c.compressed_words(), 0);
        let mut out: Vec<f32> = vec![];
        Bitmask.decompress(&c, &mut out);
    }

    #[test]
    fn mask_bits_match_layout() {
        // Element 17 nonzero -> bit 1 of word 1.
        let mut blk = vec![0.0f32; 32];
        blk[17] = 1.0;
        let c = Bitmask.compress(&blk);
        assert_eq!(c.words[0], 0);
        assert_eq!(c.words[1], 1 << 1);
    }
}
