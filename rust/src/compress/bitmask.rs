//! Bitmask compression (paper Fig. 4, used for all §IV experiments).
//!
//! Layout: `ceil(n/16)` mask words (bit i of word j covers element
//! `16*j + i`; 1 = nonzero) followed by the nonzero bf16 values in order.
//! Size is exactly `ceil(n/16) + nnz` words, which makes the simulator's
//! fast path a popcount-free nonzero count.

use super::stats::{nnz_of, BlockStats};
use super::{CompressedBlock, Compressor, CodecCost, Scheme};
use crate::tensor::dense::{bf16_bits, bf16_from_bits};
use crate::util::ceil_div;

/// The bitmask codec (stateless).
#[derive(Debug, Clone, Copy, Default)]
pub struct Bitmask;

impl Compressor for Bitmask {
    fn scheme(&self) -> Scheme {
        Scheme::Bitmask
    }

    fn compress(&self, block: &[f32]) -> CompressedBlock {
        let n = block.len();
        let mask_words = ceil_div(n, 16);
        let mut words = vec![0u16; mask_words];
        let mut values = Vec::new();
        for (i, &v) in block.iter().enumerate() {
            if v != 0.0 {
                words[i / 16] |= 1 << (i % 16);
                values.push(bf16_bits(v));
            }
        }
        words.extend_from_slice(&values);
        CompressedBlock { n_elems: n, words }
    }

    fn decompress(&self, comp: &CompressedBlock, out: &mut [f32]) {
        assert_eq!(out.len(), comp.n_elems);
        let mask_words = ceil_div(comp.n_elems, 16);
        // Corruption-tolerant: a flipped mask bit may claim more values
        // than the payload carries, and a truncated payload may be
        // shorter than the mask itself. Decode must produce *something*
        // (zeros for missing values) and never panic — the integrity
        // layer above decides whether the bits were trustworthy.
        let (mask, values) = comp.words.split_at(mask_words.min(comp.words.len()));
        out.fill(0.0);
        // Word-at-a-time scatter: only the set bits (trailing_zeros
        // walk) — all-zero mask words cost one branch instead of 16.
        let mut vi = 0;
        for (wi, &m) in mask.iter().enumerate() {
            let base = wi * 16;
            let lim = (comp.n_elems - base).min(16);
            let chunk = &mut out[base..base + lim];
            let mut bits = m;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                if b < lim {
                    chunk[b] = bf16_from_bits(values.get(vi).copied().unwrap_or(0));
                }
                vi += 1;
                bits &= bits - 1;
            }
        }
    }

    fn compressed_words(&self, block: &[f32]) -> usize {
        ceil_div(block.len(), 16) + nnz_of(block)
    }

    fn compressed_bits(&self, block: &[f32]) -> usize {
        // Exact: one mask bit per element + 16 bits per nonzero.
        block.len() + nnz_of(block) * 16
    }

    fn compressed_sizes(&self, block: &[f32]) -> (usize, usize) {
        let (n, nnz) = (block.len(), nnz_of(block));
        (ceil_div(n, 16) + nnz, n + nnz * 16)
    }

    fn compress_with_bits(&self, block: &[f32]) -> (CompressedBlock, usize) {
        // nnz falls out of the payload length — no second scan.
        let comp = self.compress(block);
        let nnz = comp.words.len() - ceil_div(block.len(), 16);
        (comp, block.len() + nnz * 16)
    }

    fn sizes_from_stats(&self, s: &BlockStats) -> Option<(usize, usize)> {
        Some((ceil_div(s.n_elems, 16) + s.nnz, s.n_elems + s.nnz * 16))
    }

    fn decompress_span(&self, comp: &CompressedBlock, start: usize, out: &mut [f32]) -> bool {
        debug_assert!(start + out.len() <= comp.n_elems);
        let mask_words = ceil_div(comp.n_elems, 16);
        // Same corruption tolerance as `decompress`: short payloads read
        // as zero mask words / zero values instead of panicking.
        let (mask, values) = comp.words.split_at(mask_words.min(comp.words.len()));
        let word = |i: usize| mask.get(i).copied().unwrap_or(0);
        // Value cursor = popcount of the mask bits before `start`.
        let mut vi = 0usize;
        for i in 0..start / 16 {
            vi += word(i).count_ones() as usize;
        }
        let rem = start % 16;
        if rem > 0 {
            vi += (word(start / 16) & ((1u16 << rem) - 1)).count_ones() as usize;
        }
        for (j, o) in out.iter_mut().enumerate() {
            let i = start + j;
            if word(i / 16) >> (i % 16) & 1 == 1 {
                *o = bf16_from_bits(values.get(vi).copied().unwrap_or(0));
                vi += 1;
            } else {
                *o = 0.0;
            }
        }
        true
    }

    fn span_nonzeros(&self, comp: &CompressedBlock, start: usize, len: usize) -> Option<usize> {
        debug_assert!(start + len <= comp.n_elems);
        if len == 0 {
            return Some(0);
        }
        // Popcount over the mask words alone — the value payload after
        // `mask_words` is never read (the whole point of the query).
        // Truncated payloads answer as if the missing mask words were
        // zero (never panic; garbage-in garbage-out).
        let mask_words = ceil_div(comp.n_elems, 16);
        // lint: allow(panic-in-decoder, end of range is clamped to words.len() by the min)
        let mask = &comp.words[..mask_words.min(comp.words.len())];
        let end = start + len;
        let (w0, w1) = (start / 16, end.div_ceil(16));
        let mut nnz = 0usize;
        for wi in w0..w1 {
            let Some(&m) = mask.get(wi) else { break };
            let base = wi * 16;
            let mut bits = m;
            if base < start {
                bits &= !((1u16 << (start - base)) - 1);
            }
            if base + 16 > end {
                bits &= (1u16 << (end - base)) - 1;
            }
            nnz += bits.count_ones() as usize;
        }
        Some(nnz)
    }

    fn is_all_zero(&self, comp: &CompressedBlock) -> Option<bool> {
        // O(1): the payload is exactly `mask_words + nnz` long, so an
        // empty block is one whose payload is the mask alone.
        Some(comp.words.len() == ceil_div(comp.n_elems, 16))
    }

    fn cost(&self) -> CodecCost {
        // One comparator + mask register per lane; decompression is a
        // prefix-sum scatter. See `cost.rs` for the model.
        CodecCost { gates_per_lane: 120, enc_cycles_per_word: 1.0, dec_cycles_per_word: 1.0, serial: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::random_block;
    use crate::util::SplitMix64;

    #[test]
    fn roundtrip_exact() {
        let mut rng = SplitMix64::new(1);
        for &d in &[0.0, 0.1, 0.5, 1.0] {
            let blk = random_block(&mut rng, 512, d);
            let c = Bitmask.compress(&blk);
            let mut out = vec![0.0; 512];
            Bitmask.decompress(&c, &mut out);
            assert_eq!(out, blk, "density {d}");
        }
    }

    #[test]
    fn size_formula() {
        let mut blk = vec![0.0f32; 512];
        blk[0] = 1.0;
        blk[100] = 2.0;
        blk[511] = 3.0;
        assert_eq!(Bitmask.compressed_words(&blk), 32 + 3);
        assert_eq!(Bitmask.compress(&blk).compressed_words(), 32 + 3);
    }

    #[test]
    fn non_multiple_of_16_lengths() {
        let mut rng = SplitMix64::new(2);
        for len in [1usize, 15, 17, 100, 511] {
            let blk = random_block(&mut rng, len, 0.4);
            let c = Bitmask.compress(&blk);
            let mut out = vec![0.0; len];
            Bitmask.decompress(&c, &mut out);
            assert_eq!(out, blk, "len {len}");
            assert_eq!(c.compressed_words(), Bitmask.compressed_words(&blk));
        }
    }

    #[test]
    fn span_decode_matches_full_decode() {
        let mut rng = SplitMix64::new(9);
        for len in [64usize, 100, 511] {
            let blk = random_block(&mut rng, len, 0.35);
            let c = Bitmask.compress(&blk);
            let mut full = vec![0.0; len];
            Bitmask.decompress(&c, &mut full);
            for (start, n) in [(0usize, len), (1, len - 1), (17, 10), (len - 1, 1), (33, 0)] {
                let mut out = vec![9.0f32; n];
                assert!(Bitmask.decompress_span(&c, start, &mut out));
                assert_eq!(out, &full[start..start + n], "len {len} start {start} n {n}");
            }
        }
    }

    #[test]
    fn empty_block() {
        let c = Bitmask.compress(&[]);
        assert_eq!(c.compressed_words(), 0);
        let mut out: Vec<f32> = vec![];
        Bitmask.decompress(&c, &mut out);
    }

    #[test]
    fn span_nonzeros_matches_decoded_count() {
        let mut rng = SplitMix64::new(11);
        for len in [16usize, 64, 100, 511, 512] {
            let blk = random_block(&mut rng, len, 0.3);
            let c = Bitmask.compress(&blk);
            let mut cases = vec![(0usize, len), (1, len - 1), (len - 1, 1), (5, 0)];
            if len > 40 {
                cases.push((17, 23));
            }
            for (start, n) in cases {
                let want = blk[start..start + n].iter().filter(|&&v| v != 0.0).count();
                assert_eq!(
                    Bitmask.span_nonzeros(&c, start, n),
                    Some(want),
                    "len {len} start {start} n {n}"
                );
            }
            let all_zero = blk.iter().all(|&v| v == 0.0);
            assert_eq!(Bitmask.is_all_zero(&c), Some(all_zero));
        }
    }

    /// ISSUE satellite: the occupancy query is metadata-only — it must
    /// never touch (let alone decode) the value payload. Proven by
    /// poisoning every value word after compression: the answers must be
    /// exactly those of the unpoisoned block.
    #[test]
    fn occupancy_query_never_decodes_values() {
        let mut rng = SplitMix64::new(12);
        for &d in &[0.0, 0.25, 0.9] {
            let blk = random_block(&mut rng, 512, d);
            let clean = Bitmask.compress(&blk);
            let mut poisoned = clean.clone();
            let mask_words = ceil_div(poisoned.n_elems, 16);
            for w in &mut poisoned.words[mask_words..] {
                *w = 0xDEAD; // garbage bf16 — a decode would see it
            }
            assert_eq!(Bitmask.is_all_zero(&poisoned), Bitmask.is_all_zero(&clean));
            for (start, n) in [(0usize, 512), (3, 77), (500, 12), (511, 1)] {
                assert_eq!(
                    Bitmask.span_nonzeros(&poisoned, start, n),
                    Bitmask.span_nonzeros(&clean, start, n),
                    "density {d} start {start} n {n}"
                );
            }
        }
    }

    /// The default-trait codecs have no occupancy index: they must
    /// answer `None` (conservative), never a wrong `Some`.
    #[test]
    fn occupancy_defaults_are_conservative() {
        use crate::compress::{Compressor, Zrlc};
        let blk = vec![0.0f32; 64];
        let c = Zrlc.compress(&blk);
        assert_eq!(Zrlc.span_nonzeros(&c, 0, 64), None);
        assert_eq!(Zrlc.is_all_zero(&c), None);
    }

    #[test]
    fn mask_bits_match_layout() {
        // Element 17 nonzero -> bit 1 of word 1.
        let mut blk = vec![0.0f32; 32];
        blk[17] = 1.0;
        let c = Bitmask.compress(&blk);
        assert_eq!(c.words[0], 0);
        assert_eq!(c.words[1], 1 << 1);
    }
}
