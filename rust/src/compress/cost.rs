//! Hardware cost proxy for the §V codec comparison.
//!
//! The paper closes with a qualitative note: its SystemVerilog
//! implementation shows "promising area efficiency compared to ZRLC,
//! bitmask, and dictionary-based algorithms, with better scalability and
//! less serialization". No numbers are given, so this module provides a
//! documented, order-of-magnitude proxy — gate counts per decode lane and
//! cycles per word — so the comparison is *runnable* (`gratetile
//! ablation --codecs`). The absolute values are engineering estimates;
//! the *ordering* (bitmask ≈ cheap/parallel, ZRLC serial, dictionary
//! area-heavy) is what the ablation asserts.

/// Area/throughput proxy for one codec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecCost {
    /// Approximate NAND2-equivalent gates per decode lane.
    pub gates_per_lane: u32,
    /// Encode cycles per word at steady state.
    pub enc_cycles_per_word: f64,
    /// Decode cycles per word at steady state.
    pub dec_cycles_per_word: f64,
    /// Whether decode has a serial dependency chain (limits lane
    /// scaling — the ZRLC drawback the paper calls out).
    pub serial: bool,
}

impl CodecCost {
    /// Effective decode throughput (words/cycle) with `lanes` lanes; a
    /// serial codec cannot scale past ~2 effective lanes.
    pub fn decode_words_per_cycle(&self, lanes: u32) -> f64 {
        let eff_lanes = if self.serial { lanes.min(2) } else { lanes };
        if self.dec_cycles_per_word == 0.0 {
            return f64::INFINITY;
        }
        eff_lanes as f64 / self.dec_cycles_per_word
    }

    /// Area for `lanes` lanes.
    pub fn area_gates(&self, lanes: u32) -> u64 {
        self.gates_per_lane as u64 * lanes as u64
    }

    /// Throughput per area: words/cycle per kilo-gate. The GrateTile §V
    /// figure of merit.
    pub fn throughput_per_kgate(&self, lanes: u32) -> f64 {
        let area = self.area_gates(lanes);
        if area == 0 {
            return f64::INFINITY;
        }
        self.decode_words_per_cycle(lanes) / (area as f64 / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Bitmask, Compressor, Dictionary, Zrlc};

    #[test]
    fn serial_codecs_do_not_scale() {
        let z = Zrlc.cost();
        assert!(z.serial);
        assert_eq!(
            z.decode_words_per_cycle(8),
            z.decode_words_per_cycle(2),
            "serial decode must saturate"
        );
    }

    #[test]
    fn parallel_codecs_scale_linearly() {
        let b = Bitmask.cost();
        assert!(!b.serial);
        assert!((b.decode_words_per_cycle(8) - 4.0 * b.decode_words_per_cycle(2)).abs() < 1e-9);
    }

    #[test]
    fn ordering_matches_paper_qualitative_claim() {
        // At 8 lanes: bitmask beats both ZRLC (serialization) and
        // dictionary (area) on throughput-per-area.
        let bm = Bitmask.cost().throughput_per_kgate(8);
        let zr = Zrlc.cost().throughput_per_kgate(8);
        let di = Dictionary::default().cost().throughput_per_kgate(8);
        assert!(bm > zr, "bitmask {bm} vs zrlc {zr}");
        assert!(bm > di, "bitmask {bm} vs dict {di}");
    }
}
