//! Fused single-pass block statistics — the planner's size substrate.
//!
//! Every codec's *exact* compressed size is a closed-form function of a
//! handful of per-block statistics: the element count, the nonzero count
//! (bitmask), the zero-run token structure (ZRLC) and the distinct-value
//! count up to the dictionary capacity (dictionary; raw needs nothing).
//! [`StatsAcc`] computes all of them in **one** streaming pass over the
//! block — fed row by row straight from the feature map, without ever
//! materialising the block — and [`Compressor::sizes_from_stats`]
//! turns the result into `(words, bits)` per codec. This is what makes
//! the packing engine's plan phase scan-free: the seed packer re-walked
//! each block up to three times (gather, `compressed_bits`,
//! `compressed_words`); the planner walks it once.
//!
//! The per-codec formulas are cross-checked against the real codecs on
//! random blocks by the tests below and by `tests/property.rs`.
//!
//! [`Compressor::sizes_from_stats`]: super::Compressor::sizes_from_stats

use super::zrlc::MAX_RUN;
use crate::tensor::dense::bf16_bits;

/// One block's fused statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockStats {
    /// Total elements scanned.
    pub n_elems: usize,
    /// Nonzero elements (`v != 0.0`; −0.0 counts as zero, exactly like
    /// the bitmask/ZRLC codecs).
    pub nnz: usize,
    /// ZRLC token count (value tokens + long-run fillers; trailing
    /// zeros are free) — [`super::Zrlc`]'s exact token structure.
    pub zrlc_tokens: usize,
    /// Distinct bf16 bit patterns, saturating at `dict_cap + 1` (the
    /// dictionary-overflow marker). 0 when distinct tracking was off.
    pub distinct: usize,
}

/// Reusable distinct-bf16-value tracker: a generation-stamped table over
/// the 2^16 bf16 bit patterns, so per-block resets are O(1) instead of
/// an O(2^16) clear. One per worker thread; ~256 KiB.
#[derive(Debug)]
pub struct DistinctTracker {
    marks: Vec<u32>,
    generation: u32,
}

impl Default for DistinctTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl DistinctTracker {
    pub fn new() -> Self {
        Self { marks: vec![0; 1 << 16], generation: 0 }
    }

    /// Start a new block (invalidates all previous marks in O(1)).
    fn begin(&mut self) {
        if self.generation == u32::MAX {
            self.marks.fill(0);
            self.generation = 0;
        }
        self.generation += 1;
    }

    /// Mark `bits` seen; returns true the first time per block.
    fn insert(&mut self, bits: u16) -> bool {
        let m = &mut self.marks[bits as usize];
        if *m == self.generation {
            false
        } else {
            *m = self.generation;
            true
        }
    }
}

/// Streaming accumulator for [`BlockStats`]: feed the block's elements
/// in storage order (any slice granularity), then [`StatsAcc::finish`].
pub struct StatsAcc<'t> {
    n: usize,
    nnz: usize,
    tokens: usize,
    run: u32,
    distinct: usize,
    dict_cap: usize,
    tracker: Option<&'t mut DistinctTracker>,
}

impl<'t> StatsAcc<'t> {
    /// `dict_cap` > 0 enables distinct tracking (requires `tracker`),
    /// saturating at `dict_cap + 1`; 0 skips it entirely.
    pub fn new(dict_cap: usize, mut tracker: Option<&'t mut DistinctTracker>) -> Self {
        if let Some(t) = tracker.as_mut() {
            t.begin();
        }
        Self { n: 0, nnz: 0, tokens: 0, run: 0, distinct: 0, dict_cap, tracker }
    }

    /// Feed the next `slice` of the block (in element order).
    pub fn feed(&mut self, slice: &[f32]) {
        let track = self.dict_cap > 0;
        for &v in slice {
            if v == 0.0 {
                self.run += 1;
            } else {
                self.nnz += 1;
                // Long runs spend one (MAX_RUN, 0) filler per MAX_RUN+1
                // zeros, then the value token — Zrlc::token_count.
                self.tokens += (self.run / (MAX_RUN + 1)) as usize + 1;
                self.run = 0;
            }
            if track && self.distinct <= self.dict_cap {
                if let Some(t) = self.tracker.as_mut() {
                    if t.insert(bf16_bits(v)) {
                        self.distinct += 1;
                    }
                }
            }
        }
        self.n += slice.len();
    }

    pub fn finish(self) -> BlockStats {
        BlockStats {
            n_elems: self.n,
            nnz: self.nnz,
            zrlc_tokens: self.tokens,
            distinct: self.distinct,
        }
    }
}

/// Nonzero count of a block — the one shared definition the bitmask
/// sizing formulas go through (`compressed_words` / `compressed_bits`
/// used to each run their own scan).
pub fn nnz_of(block: &[f32]) -> usize {
    block.iter().filter(|&&v| v != 0.0).count()
}

/// Convenience: full-block stats in one pass (planner uses the
/// streaming [`StatsAcc`] directly to avoid materialising blocks).
pub fn scan(block: &[f32], dict_cap: usize, tracker: Option<&mut DistinctTracker>) -> BlockStats {
    let mut acc = StatsAcc::new(dict_cap, tracker);
    acc.feed(block);
    acc.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::random_block;
    use crate::compress::{Bitmask, Compressor, Dictionary, RawDense, Zrlc};
    use crate::util::SplitMix64;

    /// THE stats contract: for random blocks at every density, the
    /// stats-derived sizes equal each codec's real compressed sizes.
    #[test]
    fn sizes_from_stats_match_codecs() {
        let mut rng = SplitMix64::new(0x57A7);
        let mut tracker = DistinctTracker::new();
        let codecs: Vec<Box<dyn Compressor>> = vec![
            Box::new(Bitmask),
            Box::new(Zrlc),
            Box::new(Dictionary::default()),
            Box::new(Dictionary { max_entries: 8 }),
            Box::new(RawDense),
        ];
        for trial in 0..200 {
            let len = 1 + (rng.below(700));
            let density = rng.next_f64();
            let blk = random_block(&mut rng, len, density);
            for codec in &codecs {
                let stats = scan(&blk, codec.stats_dict_cap(), Some(&mut tracker));
                let Some((words, bits)) = codec.sizes_from_stats(&stats) else {
                    panic!("{:?} cannot size from stats", codec.scheme());
                };
                assert_eq!(
                    words,
                    codec.compressed_words(&blk),
                    "trial {trial} {:?} words (len {len} d {density:.2})",
                    codec.scheme()
                );
                assert_eq!(
                    bits,
                    codec.compressed_bits(&blk),
                    "trial {trial} {:?} bits",
                    codec.scheme()
                );
            }
        }
    }

    #[test]
    fn streaming_feed_is_slice_granularity_independent() {
        let mut rng = SplitMix64::new(0xFEED);
        let blk = random_block(&mut rng, 513, 0.3);
        let mut tracker = DistinctTracker::new();
        let whole = scan(&blk, 256, Some(&mut tracker));
        let mut acc = StatsAcc::new(256, Some(&mut tracker));
        for chunk in blk.chunks(7) {
            acc.feed(chunk);
        }
        assert_eq!(acc.finish(), whole);
    }

    #[test]
    fn distinct_saturates_at_cap_plus_one() {
        let blk: Vec<f32> = (1..100).map(|i| i as f32).collect();
        let mut tracker = DistinctTracker::new();
        let s = scan(&blk, 8, Some(&mut tracker));
        assert_eq!(s.distinct, 9);
        // A fresh generation starts clean.
        let s2 = scan(&[1.0, 1.0, 2.0], 8, Some(&mut tracker));
        assert_eq!(s2.distinct, 2);
    }

    #[test]
    fn negative_zero_is_a_zero_but_a_distinct_dict_value() {
        let blk = [0.0f32, -0.0, 1.0];
        let mut tracker = DistinctTracker::new();
        let s = scan(&blk, 256, Some(&mut tracker));
        assert_eq!(s.nnz, 1);
        assert_eq!(s.zrlc_tokens, 1);
        // +0.0, -0.0 and 1.0 are three distinct bf16 patterns — exactly
        // what Dictionary::build_dict sees.
        assert_eq!(s.distinct, 3);
    }
}
