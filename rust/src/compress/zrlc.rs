//! Zero run-length coding (ZRLC; paper Fig. 4).
//!
//! Token = 5-bit zero-run count (zeros *preceding* the value) + 16-bit
//! bf16 value, the scheme used by Eyeriss-class accelerators. Runs longer
//! than 31 are split with `(31, 0)` filler tokens; trailing zeros are
//! implicit via `n_elems`. Tokens are bit-packed (21 bits each) into
//! 16-bit words.

use super::bits::{words_for_bits, BitReader, BitWriter};
use super::stats::BlockStats;
use super::{CodecCost, CompressedBlock, Compressor, Scheme};
use crate::tensor::dense::{bf16_bits, bf16_from_bits};

/// Run-length field width (public: the fused stats pass reproduces the
/// token structure, see [`super::stats`]).
pub const RUN_BITS: usize = 5;
pub const MAX_RUN: u32 = (1 << RUN_BITS) - 1; // 31
pub const TOKEN_BITS: usize = RUN_BITS + 16;

/// The ZRLC codec (stateless).
#[derive(Debug, Clone, Copy, Default)]
pub struct Zrlc;

impl Zrlc {
    /// Token count for a block (fast-path size computation). Trailing
    /// zeros are implicit (no filler tokens are spent on them).
    fn token_count(block: &[f32]) -> usize {
        let mut tokens = 0usize;
        let mut run = 0u32;
        for &v in block {
            if v == 0.0 {
                run += 1;
            } else {
                // Fillers for the buffered run, then the value token.
                tokens += (run / (MAX_RUN + 1)) as usize + 1;
                run = 0;
            }
        }
        tokens
    }

    /// Encode `block`, returning the payload and the token count (the
    /// single-pass substrate of both `compress` and
    /// `compress_with_bits`).
    fn encode(block: &[f32]) -> (Vec<u16>, usize) {
        let mut w = BitWriter::new();
        let mut run = 0u32;
        let mut tokens = 0usize;
        for &v in block {
            if v == 0.0 {
                // Buffer the run; fillers are only spent when a value
                // follows, so trailing zeros are free (implicit via
                // `n_elems`).
                run += 1;
            } else {
                while run > MAX_RUN {
                    // Filler token: 31 zeros then an explicit 0 value
                    // (consumes MAX_RUN + 1 zeros total).
                    w.write(MAX_RUN, RUN_BITS);
                    w.write(0, 16);
                    tokens += 1;
                    run -= MAX_RUN + 1;
                }
                w.write(run, RUN_BITS);
                w.write(bf16_bits(v) as u32, 16);
                tokens += 1;
                run = 0;
            }
        }
        (w.finish(), tokens)
    }
}

impl Compressor for Zrlc {
    fn scheme(&self) -> Scheme {
        Scheme::Zrlc
    }

    fn compress(&self, block: &[f32]) -> CompressedBlock {
        let (words, _) = Self::encode(block);
        CompressedBlock { n_elems: block.len(), words }
    }

    fn decompress(&self, comp: &CompressedBlock, out: &mut [f32]) {
        assert_eq!(out.len(), comp.n_elems);
        out.fill(0.0);
        let total_bits = comp.words.len() * 16;
        let mut r = BitReader::new(&comp.words);
        let mut pos = 0usize;
        // Stop when the remaining bits cannot hold a token (tail padding).
        while total_bits - r.bits_read() >= TOKEN_BITS && pos < comp.n_elems {
            let run = r.read(RUN_BITS) as usize;
            let val = r.read(16) as u16;
            pos += run;
            if pos >= comp.n_elems {
                // A corrupt run count overshot the block: stop decoding
                // (the rest stays zero) rather than panic — the
                // integrity layer above decides whether to trust this.
                break;
            }
            if val != 0 {
                out[pos] = bf16_from_bits(val);
            }
            // Filler tokens (val == 0) consume MAX_RUN zeros + one zero.
            pos += 1;
        }
    }

    fn compressed_words(&self, block: &[f32]) -> usize {
        words_for_bits(Self::token_count(block) * TOKEN_BITS)
    }

    fn compressed_bits(&self, block: &[f32]) -> usize {
        Self::token_count(block) * TOKEN_BITS
    }

    fn compressed_sizes(&self, block: &[f32]) -> (usize, usize) {
        let bits = Self::token_count(block) * TOKEN_BITS;
        (words_for_bits(bits), bits)
    }

    fn compress_with_bits(&self, block: &[f32]) -> (CompressedBlock, usize) {
        let (words, tokens) = Self::encode(block);
        (CompressedBlock { n_elems: block.len(), words }, tokens * TOKEN_BITS)
    }

    fn sizes_from_stats(&self, s: &BlockStats) -> Option<(usize, usize)> {
        let bits = s.zrlc_tokens * TOKEN_BITS;
        Some((words_for_bits(bits), bits))
    }

    fn cost(&self) -> CodecCost {
        // Run counter + shifter; decode is inherently serial in the run
        // chain (the paper's §V notes ZRLC's serialization).
        CodecCost { gates_per_lane: 90, enc_cycles_per_word: 1.0, dec_cycles_per_word: 1.6, serial: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::random_block;
    use crate::util::SplitMix64;

    fn roundtrip(blk: &[f32]) {
        let c = Zrlc.compress(blk);
        let mut out = vec![0.0; blk.len()];
        Zrlc.decompress(&c, &mut out);
        assert_eq!(out, blk);
        assert_eq!(c.compressed_words(), Zrlc.compressed_words(blk));
    }

    #[test]
    fn roundtrip_various_densities() {
        let mut rng = SplitMix64::new(3);
        for &d in &[0.0, 0.05, 0.4, 0.9, 1.0] {
            roundtrip(&random_block(&mut rng, 512, d));
        }
    }

    #[test]
    fn long_zero_runs_use_fillers() {
        // 100 zeros then a value: needs 3 fillers (31+1 each = 96) + token.
        let mut blk = vec![0.0f32; 101];
        blk[100] = 1.0;
        let c = Zrlc.compress(&blk);
        // 100 zeros = 3 fillers consuming 96, remaining run 4 on the token.
        assert_eq!(c.words.len(), words_for_bits(4 * TOKEN_BITS));
        roundtrip(&blk);
    }

    #[test]
    fn trailing_zeros_are_free() {
        let mut blk = vec![0.0f32; 512];
        blk[0] = 1.0;
        // One token regardless of the 511 trailing zeros.
        assert_eq!(Zrlc.compressed_words(&blk), words_for_bits(TOKEN_BITS));
        roundtrip(&blk);
    }

    #[test]
    fn all_zero_block_is_empty() {
        let blk = vec![0.0f32; 512];
        assert_eq!(Zrlc.compressed_words(&blk), 0);
        roundtrip(&blk);
    }

    #[test]
    fn dense_block_costs_more_than_raw() {
        let mut rng = SplitMix64::new(4);
        let blk = random_block(&mut rng, 512, 1.0);
        // 21 bits per word vs 16 raw.
        assert!(Zrlc.compressed_words(&blk) > 512);
        roundtrip(&blk);
    }

    #[test]
    fn exact_run_boundary_31_and_32() {
        for zeros in [30usize, 31, 32, 33, 62, 63, 64] {
            let mut blk = vec![0.0f32; zeros + 1];
            blk[zeros] = 2.0;
            roundtrip(&blk);
        }
    }
}
