//! Unified metrics: counters, gauges, log-bucketed histograms, and the
//! shared percentile machinery behind both serving reports.
//!
//! Everything here is integer- or format-deterministic: registries
//! iterate in insertion order, histograms use pure integer bucket
//! math, and the JSON dump is built with the same escaping the bench
//! harness uses — byte-stable across hosts.

/// Nearest-rank index of percentile `p` over `n` sorted samples,
/// clamped to the valid domain: `NaN` and `p < 0` select the minimum,
/// `p > 1` the maximum. Both serving reports ([`crate::coordinator::ServerReport`]
/// and the simulator's) index through this, so an out-of-range `p` can
/// never panic an index computation.
pub fn percentile_index(n: usize, p: f64) -> usize {
    if n == 0 {
        return 0;
    }
    let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 1.0) };
    // p <= 1 ⇒ (n-1)·p rounds to at most n-1: always in bounds.
    (((n - 1) as f64) * p).round() as usize
}

/// A sample set sorted **once** at construction; every percentile is
/// then an O(1) [`percentile_index`] lookup. Replaces the
/// sort-per-percentile-call paths in both serving reports.
#[derive(Debug, Clone, Default)]
pub struct SortedSamples<T> {
    sorted: Vec<T>,
}

impl<T: Ord + Copy> SortedSamples<T> {
    /// Sort `samples` once (unstable — the sample type is totally
    /// ordered, so ties are indistinguishable) and keep them.
    pub fn from_unsorted(mut samples: Vec<T>) -> Self {
        samples.sort_unstable();
        SortedSamples { sorted: samples }
    }

    /// The sample at percentile `p`, or `default` when empty. Exactly
    /// `sorted[percentile_index(len, p)]` — bit-identical to the
    /// historical sort-per-call paths.
    pub fn at_or(&self, p: f64, default: T) -> T {
        if self.sorted.is_empty() {
            return default;
        }
        self.sorted[percentile_index(self.sorted.len(), p)]
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

/// Sub-bucket resolution: each power-of-two octave is split into
/// `2^SUB_BITS = 8` linear sub-buckets.
const SUB_BITS: u32 = 3;

/// A log-bucketed `u64` histogram with a *documented* quantile error
/// bound.
///
/// Values `< 8` get exact unit buckets; larger values land in one of 8
/// linear sub-buckets per power-of-two octave, so a bucket spans at
/// most 1/8 of its lower bound. [`LogHistogram::quantile`] returns the
/// bucket lower bound `q̂` at the nearest rank, giving the two-sided
/// bound **`q̂ ≤ exact ≤ q̂ + (q̂ >> 3)`** (≤ 12.5% relative error;
/// exact for values < 8) against the true sorted-vector quantile at
/// the same [`percentile_index`] rank — property-tested in
/// `tests/obs.rs`.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: Vec::new(), count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index of `v`: identity below 8, then
    /// `(msb - 3) * 8 + 8 + sub` where `sub` is the top 3 bits below
    /// the msb. Maximum index is 495 (for `u64::MAX`).
    fn bucket_of(v: u64) -> usize {
        if v < 8 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let sub = (v >> (msb - SUB_BITS)) - 8;
        ((msb - SUB_BITS) * 8 + 8) as usize + sub as usize
    }

    /// Smallest value mapping to bucket `b` (inverse of [`Self::bucket_of`]).
    fn lower_bound_of(b: usize) -> u64 {
        if b < 8 {
            return b as u64;
        }
        let octave = (b - 8) / 8;
        let sub = ((b - 8) % 8) as u64;
        (8 + sub) << octave
    }

    pub fn observe(&mut self, v: u64) {
        let b = Self::bucket_of(v);
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Lower bound of the bucket holding the nearest-rank sample at
    /// percentile `p` (see the type docs for the error bound). 0 when
    /// empty.
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = percentile_index(self.count as usize, p) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::lower_bound_of(b);
            }
        }
        Self::lower_bound_of(self.counts.len().saturating_sub(1))
    }

    pub fn merge(&mut self, o: &LogHistogram) {
        if self.counts.len() < o.counts.len() {
            self.counts.resize(o.counts.len(), 0);
        }
        for (b, &c) in o.counts.iter().enumerate() {
            self.counts[b] += c;
        }
        self.count += o.count;
        self.sum = self.sum.saturating_add(o.sum);
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest observed value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }
}

/// A registry of named counters, gauges, and histograms. Iteration and
/// JSON order is insertion order — first registration wins the slot —
/// so dumps are byte-stable for a deterministic producer.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, f64)>,
    hists: Vec<(String, LogHistogram)>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter_add(&mut self, name: &str, delta: u64) {
        match self.counters.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v += delta,
            None => self.counters.push((name.to_string(), delta)),
        }
    }

    pub fn gauge_set(&mut self, name: &str, value: f64) {
        match self.gauges.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v = value,
            None => self.gauges.push((name.to_string(), value)),
        }
    }

    pub fn observe(&mut self, name: &str, value: u64) {
        match self.hists.iter_mut().find(|(n, _)| n == name) {
            Some((_, h)) => h.observe(value),
            None => {
                let mut h = LogHistogram::new();
                h.observe(value);
                self.hists.push((name.to_string(), h));
            }
        }
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn counters(&self) -> &[(String, u64)] {
        &self.counters
    }

    pub fn gauges(&self) -> &[(String, f64)] {
        &self.gauges
    }

    /// Deterministic JSON dump: counters as integers, gauges with six
    /// fixed decimals, histograms as count/min/max/sum + p50/p90/p99
    /// summaries. No wall clock, no git rev — safe for golden files.
    pub fn to_json(&self) -> String {
        use crate::util::benchkit::json_escape;
        use std::fmt::Write as _;
        let mut s = String::from("{\n  \"counters\": {");
        for (i, (n, v)) in self.counters.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(s, "{sep}\n    \"{}\": {v}", json_escape(n));
        }
        s.push_str("\n  },\n  \"gauges\": {");
        for (i, (n, v)) in self.gauges.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(s, "{sep}\n    \"{}\": {v:.6}", json_escape(n));
        }
        s.push_str("\n  },\n  \"histograms\": {");
        for (i, (n, h)) in self.hists.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(
                s,
                "{sep}\n    \"{}\": {{\"count\": {}, \"min\": {}, \"max\": {}, \"sum\": {}, \
                 \"p50\": {}, \"p90\": {}, \"p99\": {}}}",
                json_escape(n),
                h.count(),
                h.min(),
                h.max(),
                h.sum(),
                h.quantile(0.50),
                h.quantile(0.90),
                h.quantile(0.99),
            );
        }
        s.push_str("\n  }\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_index_clamps_domain() {
        assert_eq!(percentile_index(0, 0.5), 0);
        assert_eq!(percentile_index(1, f64::NAN), 0);
        assert_eq!(percentile_index(5, -3.0), 0);
        assert_eq!(percentile_index(5, 0.0), 0);
        assert_eq!(percentile_index(5, 0.5), 2);
        assert_eq!(percentile_index(5, 1.0), 4);
        assert_eq!(percentile_index(5, 17.0), 4);
        assert_eq!(percentile_index(5, f64::NAN), 0);
        assert_eq!(percentile_index(5, f64::INFINITY), 4);
        assert_eq!(percentile_index(5, f64::NEG_INFINITY), 0);
    }

    #[test]
    fn sorted_samples_match_sort_per_call() {
        let raw = vec![40u64, 10, 30, 20, 50];
        let ss = SortedSamples::from_unsorted(raw.clone());
        let mut sorted = raw;
        sorted.sort_unstable();
        for &p in &[0.0, 0.25, 0.5, 0.95, 1.0] {
            assert_eq!(ss.at_or(p, 0), sorted[percentile_index(sorted.len(), p)]);
        }
        assert_eq!(SortedSamples::<u64>::from_unsorted(vec![]).at_or(0.5, 7), 7);
    }

    #[test]
    fn histogram_buckets_are_consistent() {
        // Identity below 8; octave boundaries land on fresh buckets.
        for v in 0..8u64 {
            assert_eq!(LogHistogram::bucket_of(v), v as usize);
            assert_eq!(LogHistogram::lower_bound_of(v as usize), v);
        }
        assert_eq!(LogHistogram::bucket_of(8), 8);
        assert_eq!(LogHistogram::bucket_of(15), 15);
        assert_eq!(LogHistogram::bucket_of(16), 16);
        for v in [8u64, 100, 1000, 1 << 20, u64::MAX] {
            let b = LogHistogram::bucket_of(v);
            let lo = LogHistogram::lower_bound_of(b);
            assert!(lo <= v);
            // Bucket width bound: v - lo <= lo/8.
            assert!(v - lo <= (lo >> SUB_BITS));
        }
        assert!(LogHistogram::bucket_of(u64::MAX) <= 495);
    }

    #[test]
    fn histogram_tracks_count_sum_min_max() {
        let mut h = LogHistogram::new();
        assert_eq!((h.count(), h.min(), h.max(), h.sum()), (0, 0, 0, 0));
        assert_eq!(h.quantile(0.5), 0);
        for v in [3u64, 900, 17] {
            h.observe(v);
        }
        assert_eq!((h.count(), h.min(), h.max(), h.sum()), (3, 3, 900, 920));
        // p0 is exact (3 < 8); p100 falls in 900's bucket.
        assert_eq!(h.quantile(0.0), 3);
        let q = h.quantile(1.0);
        assert!(q <= 900 && 900 <= q + (q >> 3));
    }

    #[test]
    fn histogram_merge_equals_combined_observe() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut c = LogHistogram::new();
        for v in [1u64, 50, 2000] {
            a.observe(v);
            c.observe(v);
        }
        for v in [9u64, 9, 123456] {
            b.observe(v);
            c.observe(v);
        }
        a.merge(&b);
        for &p in &[0.0, 0.5, 1.0] {
            assert_eq!(a.quantile(p), c.quantile(p));
        }
        assert_eq!((a.count(), a.sum(), a.min(), a.max()), (c.count(), c.sum(), c.min(), c.max()));
    }

    #[test]
    fn registry_accumulates_and_dumps_in_insertion_order() {
        let mut m = MetricsRegistry::new();
        m.counter_add("b_second", 2);
        m.counter_add("a_first", 1);
        m.counter_add("b_second", 3);
        m.gauge_set("g", 0.25);
        m.observe("lat", 10);
        m.observe("lat", 20);
        assert_eq!(m.counter("b_second"), Some(5));
        assert_eq!(m.counter("a_first"), Some(1));
        assert_eq!(m.counter("missing"), None);
        assert_eq!(m.gauge("g"), Some(0.25));
        assert_eq!(m.histogram("lat").unwrap().count(), 2);
        let json = m.to_json();
        // Insertion order preserved, not alphabetical.
        assert!(json.find("b_second").unwrap() < json.find("a_first").unwrap());
        assert!(json.contains("\"g\": 0.250000"));
        assert!(json.contains("\"count\": 2"));
    }
}
