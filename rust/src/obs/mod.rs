//! Deterministic observability: spans, counters, metrics, and logging.
//!
//! Everything exported from this module is keyed on **simulated
//! cycles**, never wall clock, so every artifact (Chrome trace JSON,
//! metrics dump, rollup tables) is byte-stable across hosts and
//! `--jobs` settings — the same invariant the serving simulator's
//! golden suite already enforces.
//!
//! - [`trace`] — a span/counter recorder ([`trace::TraceRecorder`])
//!   threaded as a plumbed handle (no globals) through the simserver
//!   timing pass and the DRAM model. A disabled recorder is inert: the
//!   `perf_obs` bench gates its overhead on serve and pack at <2%.
//! - [`metrics`] — counters, gauges, and a log-bucketed histogram
//!   ([`metrics::LogHistogram`]) with a documented quantile error
//!   bound, plus the shared [`metrics::percentile_index`] /
//!   [`metrics::SortedSamples`] percentile machinery both serving
//!   reports index through.
//! - [`log`] — a leveled stderr logger (`--verbose`/`--quiet`,
//!   `GRATETILE_LOG`) for diagnostics; study tables stay on stdout.
//! - Export lives in `export.rs` as inherent methods on the recorder:
//!   Chrome trace-event JSON (Perfetto-loadable), an indented text
//!   timeline, and a counter rollup [`crate::util::table::Table`].

mod export;
pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{LogHistogram, MetricsRegistry, SortedSamples};
pub use trace::{Track, TraceRecorder};
