//! Leveled stderr logging for diagnostics.
//!
//! Human-facing study tables and reports stay on **stdout** untouched;
//! everything that used to be a scattered `eprintln!`/progress
//! `println!` goes through [`log_error!`](crate::log_error) /
//! [`log_warn!`](crate::log_warn) / [`log_info!`](crate::log_info) /
//! [`log_debug!`](crate::log_debug) instead.
//!
//! The level is resolved in priority order: an explicit
//! [`set_level`]/[`configure`] call (CLI `--verbose`/`--quiet`), else
//! the `GRATETILE_LOG` environment variable
//! (`error|warn|info|debug|quiet`), else `info`. The logger is the one
//! deliberate piece of global state in `obs` — it writes only to
//! stderr and never into any exported artifact, so determinism of
//! traces/metrics/goldens is unaffected.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Severity, ordered: a message is printed when its level is at or
/// below the configured one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a level name; `quiet` is an alias for `error`.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "quiet" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// `u8::MAX` = "not explicitly set": fall back to the env default.
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn env_default() -> Level {
    static ENV: OnceLock<Level> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("GRATETILE_LOG") {
        Ok(v) => Level::parse(&v).unwrap_or_else(|| {
            // A typo'd level must not silently change verbosity: say so
            // once (OnceLock caches this path) and fall back to info.
            eprintln!(
                "[warn] GRATETILE_LOG={v:?} is not a log level \
                 (error|warn|info|debug|quiet); defaulting to info"
            );
            Level::Info
        }),
        Err(_) => Level::Info,
    })
}

/// Explicitly set the level (overrides `GRATETILE_LOG`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The currently effective level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        u8::MAX => env_default(),
        v => Level::from_u8(v),
    }
}

/// Whether a message at `l` would be printed.
#[inline]
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Apply the CLI flags: `--quiet` wins over `--verbose`; with neither,
/// the env default stands.
pub fn configure(verbose: bool, quiet: bool) {
    if quiet {
        set_level(Level::Error);
    } else if verbose {
        set_level(Level::Debug);
    } else {
        // Resolve (and thereby validate) the env default eagerly: a
        // typo'd GRATETILE_LOG warns once at startup rather than at
        // the first log call — or, on a silent code path, never.
        let _ = level();
    }
}

/// Print `msg` to stderr as `[level] msg` if `l` is enabled. Use the
/// `log_*!` macros rather than calling this directly.
pub fn log(l: Level, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{}] {}", l.name(), msg);
    }
}

/// Log at error level (always printed unless the logger is broken).
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Error, format_args!($($t)*))
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Warn, format_args!($($t)*))
    };
}

/// Log at info level (the default): progress and one-line summaries.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Info, format_args!($($t)*))
    };
}

/// Log at debug level (enabled by `--verbose` / `GRATETILE_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test exercises every level transition: the level is global
    // state, so splitting these into parallel #[test]s would race.
    #[test]
    fn level_parsing_ordering_and_configure() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse(" WARN "), Some(Level::Warn));
        assert_eq!(Level::parse("quiet"), Some(Level::Error));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Debug);

        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));

        // --quiet beats --verbose.
        configure(true, true);
        assert_eq!(level(), Level::Error);
        configure(true, false);
        assert_eq!(level(), Level::Debug);
        // Neither flag: the previous explicit level stands.
        configure(false, false);
        assert_eq!(level(), Level::Debug);

        // Leave a sane default for any other test in this process.
        set_level(Level::Info);
        log(Level::Debug, format_args!("suppressed at info"));
    }
}
