//! Span/counter recorder keyed on simulated cycles.
//!
//! The recorder is a plain value handed down by `&mut` reference (no
//! globals, no interior mutability): whoever owns the run owns the
//! trace. All timestamps are **simulated cycles** — recording the same
//! simulation twice, on any host, at any `--jobs`, yields byte-equal
//! exports.
//!
//! Track layout (process ids are fixed so Perfetto groups stably):
//!
//! | pid | process        | tracks (tid)                         |
//! |-----|----------------|--------------------------------------|
//! | 1   | `workers`      | one per simulated worker             |
//! | 2   | `dram banks`   | one per DRAM bank (`busy` spans)     |
//! | 3   | `admission`    | one per request (`wait` spans)       |
//! | 4   | `counters`     | one per counter series               |

/// Process id for per-worker request/layer spans.
pub const WORKER_PID: u64 = 1;
/// Process id for per-bank DRAM occupancy tracks.
pub const DRAM_PID: u64 = 2;
/// Process id for per-request admission-wait tracks.
pub const ADMISSION_PID: u64 = 3;
/// Process id for counter series.
pub const COUNTER_PID: u64 = 4;

/// A (process, thread) pair identifying one horizontal trace track.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Track {
    pub pid: u64,
    pub tid: u64,
}

/// A closed interval of simulated cycles on one track.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub track: Track,
    pub name: String,
    pub start: u64,
    pub end: u64,
}

/// One sample of a named counter series.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Counter {
    pub name: String,
    pub ts: u64,
    pub value: u64,
}

/// The recorder. Construct with [`TraceRecorder::enabled`] to collect,
/// [`TraceRecorder::disabled`] for a zero-allocation inert handle —
/// every mutator early-returns when disabled, so threading a disabled
/// recorder through a hot loop costs one branch.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    enabled: bool,
    process_names: Vec<(u64, String)>,
    track_names: Vec<(Track, String)>,
    spans: Vec<Span>,
    counters: Vec<Counter>,
}

impl TraceRecorder {
    /// A recorder that collects spans and counters.
    pub fn enabled() -> Self {
        TraceRecorder { enabled: true, ..Default::default() }
    }

    /// An inert recorder: every mutator is a no-op.
    pub fn disabled() -> Self {
        TraceRecorder::default()
    }

    /// Whether this recorder collects anything. Emitters with per-event
    /// setup cost (string formatting, lookups) should guard on this.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Name a process (a Perfetto track group). Idempotent per pid.
    pub fn process(&mut self, pid: u64, name: &str) {
        if !self.enabled || self.process_names.iter().any(|(p, _)| *p == pid) {
            return;
        }
        self.process_names.push((pid, name.to_string()));
    }

    /// Name a track and return its handle. Idempotent per (pid, tid).
    pub fn track(&mut self, pid: u64, tid: u64, name: &str) -> Track {
        let track = Track { pid, tid };
        if self.enabled && !self.track_names.iter().any(|(t, _)| *t == track) {
            self.track_names.push((track, name.to_string()));
        }
        track
    }

    /// Record a span of `[start, end]` simulated cycles on `track`.
    #[inline]
    pub fn span(&mut self, track: Track, name: &str, start: u64, end: u64) {
        if !self.enabled {
            return;
        }
        debug_assert!(start <= end, "span {name} has start {start} > end {end}");
        self.spans.push(Span { track, name: name.to_string(), start, end });
    }

    /// Record one sample of counter series `name` at cycle `ts`.
    #[inline]
    pub fn counter(&mut self, name: &str, ts: u64, value: u64) {
        if !self.enabled {
            return;
        }
        self.counters.push(Counter { name: name.to_string(), ts, value });
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn counters(&self) -> &[Counter] {
        &self.counters
    }

    pub(crate) fn process_names(&self) -> &[(u64, String)] {
        &self.process_names
    }

    pub(crate) fn track_names(&self) -> &[(Track, String)] {
        &self.track_names
    }

    /// The declared name of `track`, if registered.
    pub fn track_name(&self, track: Track) -> Option<&str> {
        self.track_names.iter().find(|(t, _)| *t == track).map(|(_, n)| n.as_str())
    }

    /// Verify that spans are well-nested per track: sorted by
    /// `(start asc, end desc)`, every span must lie entirely within the
    /// enclosing span still open on the stack (equal intervals nest).
    /// Returns the first violation as an error string.
    pub fn check_well_nested(&self) -> Result<(), String> {
        let mut sorted: Vec<&Span> = self.spans.iter().collect();
        sorted.sort_by(|a, b| {
            (a.track, a.start, std::cmp::Reverse(a.end))
                .cmp(&(b.track, b.start, std::cmp::Reverse(b.end)))
        });
        let mut stack: Vec<&Span> = Vec::new();
        let mut cur: Option<Track> = None;
        for s in sorted {
            if s.end < s.start {
                return Err(format!("span '{}' ends before it starts", s.name));
            }
            if cur != Some(s.track) {
                stack.clear();
                cur = Some(s.track);
            }
            while stack.last().is_some_and(|t| t.end <= s.start) {
                stack.pop();
            }
            if let Some(top) = stack.last() {
                if s.end > top.end {
                    return Err(format!(
                        "span '{}' [{}..{}] crosses '{}' [{}..{}] on track {:?}",
                        s.name, s.start, s.end, top.name, top.start, top.end, s.track
                    ));
                }
            }
            stack.push(s);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_collects_nothing() {
        let mut r = TraceRecorder::disabled();
        let t = r.track(WORKER_PID, 0, "worker 0");
        r.process(WORKER_PID, "workers");
        r.span(t, "x", 0, 10);
        r.counter("macs", 5, 100);
        assert!(!r.is_enabled());
        assert!(r.spans().is_empty());
        assert!(r.counters().is_empty());
        assert!(r.process_names().is_empty());
        assert!(r.track_names().is_empty());
    }

    #[test]
    fn track_and_process_registration_dedups() {
        let mut r = TraceRecorder::enabled();
        r.process(DRAM_PID, "dram banks");
        r.process(DRAM_PID, "dram banks again");
        let a = r.track(DRAM_PID, 3, "bank 3");
        let b = r.track(DRAM_PID, 3, "bank 3 again");
        assert_eq!(a, b);
        assert_eq!(r.process_names().len(), 1);
        assert_eq!(r.track_names().len(), 1);
        assert_eq!(r.track_name(a), Some("bank 3"));
    }

    #[test]
    fn well_nested_accepts_containment_rejects_crossing() {
        let mut r = TraceRecorder::enabled();
        let t = r.track(WORKER_PID, 0, "worker 0");
        r.span(t, "parent", 0, 100);
        r.span(t, "child", 0, 40);
        r.span(t, "sibling", 40, 100);
        r.span(t, "grandchild", 10, 40);
        assert!(r.check_well_nested().is_ok());
        r.span(t, "crosser", 30, 60);
        assert!(r.check_well_nested().is_err());
    }

    #[test]
    fn well_nested_is_per_track() {
        let mut r = TraceRecorder::enabled();
        let a = r.track(WORKER_PID, 0, "worker 0");
        let b = r.track(WORKER_PID, 1, "worker 1");
        // Overlapping across *different* tracks is fine.
        r.span(a, "x", 0, 50);
        r.span(b, "y", 25, 75);
        assert!(r.check_well_nested().is_ok());
    }
}
