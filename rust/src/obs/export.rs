//! Trace export: Chrome trace-event JSON (Perfetto-loadable), a text
//! timeline, and a counter rollup table.
//!
//! Determinism rules (golden-tested): every timestamp is a simulated
//! cycle, event order is a pure function of the recorded data (sorted,
//! never hash-ordered), and the artifact carries no wall clock, git
//! rev, or host identity. `displayTimeUnit` is cosmetic — Perfetto
//! renders one cycle as one microsecond.

use super::trace::{Counter, Span, TraceRecorder, COUNTER_PID};
use crate::util::benchkit::json_escape;
use crate::util::table::Table;
use std::cmp::Reverse;
use std::fmt::Write as _;

impl TraceRecorder {
    /// Serialize to Chrome trace-event JSON: metadata events first
    /// (process then thread names, by pid/tid), then complete (`"X"`)
    /// span events sorted by `(track, start)` — so `ts` is monotonic
    /// per track — then counter (`"C"`) events, one series per tid on
    /// [`COUNTER_PID`], sorted by `(tid, ts)`. One event per line.
    pub fn to_chrome_json(&self) -> String {
        let mut s = String::from("{\"traceEvents\":[\n");
        let mut first = true;
        let mut push = |s: &mut String, line: String| {
            if !first {
                s.push_str(",\n");
            }
            first = false;
            s.push_str(&line);
        };

        let mut procs: Vec<&(u64, String)> = self.process_names().iter().collect();
        procs.sort_by_key(|(pid, _)| *pid);
        for (pid, name) in procs {
            push(
                &mut s,
                format!(
                    "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    json_escape(name)
                ),
            );
        }
        let mut tracks: Vec<&(super::trace::Track, String)> = self.track_names().iter().collect();
        tracks.sort_by_key(|(t, _)| *t);
        for (t, name) in tracks {
            push(
                &mut s,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    t.pid,
                    t.tid,
                    json_escape(name)
                ),
            );
        }

        let mut spans: Vec<&Span> = self.spans().iter().collect();
        spans.sort_by_key(|sp| (sp.track, sp.start, Reverse(sp.end)));
        for sp in spans {
            push(
                &mut s,
                format!(
                    "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{}}}",
                    json_escape(&sp.name),
                    sp.track.pid,
                    sp.track.tid,
                    sp.start,
                    sp.end - sp.start
                ),
            );
        }

        // Counter series occupy one tid each on COUNTER_PID, in
        // first-seen order — deterministic because the emitter is the
        // single-threaded timing pass.
        let mut series: Vec<&str> = Vec::new();
        for c in self.counters() {
            if !series.contains(&c.name.as_str()) {
                series.push(&c.name);
            }
        }
        let tid_of = |name: &str| series.iter().position(|n| *n == name).unwrap_or(0) as u64;
        let mut counters: Vec<&Counter> = self.counters().iter().collect();
        counters.sort_by_key(|c| (tid_of(&c.name), c.ts));
        for c in counters {
            push(
                &mut s,
                format!(
                    "{{\"name\":\"{}\",\"ph\":\"C\",\"pid\":{COUNTER_PID},\"tid\":{},\"ts\":{},\
                     \"args\":{{\"{}\":{}}}}}",
                    json_escape(&c.name),
                    tid_of(&c.name),
                    c.ts,
                    json_escape(&c.name),
                    c.value
                ),
            );
        }

        s.push_str(
            "\n],\"displayTimeUnit\":\"ms\",\"otherData\":{\"clock\":\"simulated-cycles\"}}\n",
        );
        s
    }

    /// Render an indented per-track text timeline. `max_lines` bounds
    /// the output (0 = unlimited); a trailing note reports truncation.
    pub fn render_text(&self, max_lines: usize) -> String {
        let mut out = String::new();
        let mut lines = 0usize;
        let mut truncated = 0usize;
        let mut emit = |out: &mut String, line: String| {
            if max_lines > 0 && lines >= max_lines {
                truncated += 1;
                return;
            }
            out.push_str(&line);
            out.push('\n');
            lines += 1;
        };

        let mut tracks: Vec<&(super::trace::Track, String)> = self.track_names().iter().collect();
        tracks.sort_by_key(|(t, _)| *t);
        for (track, tname) in tracks {
            let mut spans: Vec<&Span> =
                self.spans().iter().filter(|sp| sp.track == *track).collect();
            if spans.is_empty() {
                continue;
            }
            spans.sort_by_key(|sp| (sp.start, Reverse(sp.end)));
            emit(&mut out, format!("track {tname} (pid {} tid {})", track.pid, track.tid));
            let mut stack: Vec<u64> = Vec::new();
            for sp in spans {
                while stack.last().is_some_and(|&end| end <= sp.start) {
                    stack.pop();
                }
                let indent = "  ".repeat(stack.len() + 1);
                emit(&mut out, format!("{indent}[{:>8} .. {:>8}] {}", sp.start, sp.end, sp.name));
                stack.push(sp.end);
            }
        }
        if truncated > 0 {
            let _ = writeln!(out, "... {truncated} more lines (raise --limit to see all)");
        }
        out
    }

    /// Rollup of every counter series to its final (cumulative) value
    /// and sample count, in first-seen order — the golden-filed table
    /// behind `gratetile trace`.
    pub fn rollup_table(&self) -> Table {
        let mut t = Table::new("Trace counter rollup (final cumulative values, simulated cycles)")
            .header(vec!["Series", "Final value", "Points"]);
        let mut series: Vec<(&str, u64, u64)> = Vec::new();
        for c in self.counters() {
            match series.iter_mut().find(|(n, _, _)| *n == c.name) {
                Some((_, v, pts)) => {
                    *v = c.value;
                    *pts += 1;
                }
                None => series.push((&c.name, c.value, 1)),
            }
        }
        for (name, last, points) in series {
            t.row(vec![name.to_string(), last.to_string(), points.to_string()]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::super::trace::{DRAM_PID, WORKER_PID};
    use super::*;

    fn sample() -> TraceRecorder {
        let mut r = TraceRecorder::enabled();
        r.process(WORKER_PID, "workers");
        r.process(DRAM_PID, "dram banks");
        let w = r.track(WORKER_PID, 0, "worker 0");
        let b = r.track(DRAM_PID, 0, "bank 0");
        r.span(w, "req 0", 0, 100);
        r.span(w, "L0", 0, 60);
        r.span(w, "L1", 60, 100);
        r.span(b, "busy", 5, 25);
        r.counter("macs", 60, 640);
        r.counter("macs", 100, 1280);
        r.counter("cache_hits", 100, 3);
        r
    }

    #[test]
    fn chrome_json_shape_and_order() {
        let j = sample().to_chrome_json();
        assert!(j.starts_with("{\"traceEvents\":[\n"));
        assert!(j.contains("\"process_name\""));
        assert!(j.contains("\"thread_name\""));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"ph\":\"C\""));
        assert!(j.contains("\"clock\":\"simulated-cycles\""));
        // Metadata precedes spans precedes counters.
        let meta = j.find("process_name").unwrap();
        let x = j.find("\"ph\":\"X\"").unwrap();
        let c = j.find("\"ph\":\"C\"").unwrap();
        assert!(meta < x && x < c);
        // Counter series tids follow first-seen order: macs=0, cache_hits=1.
        assert!(j.contains("{\"name\":\"macs\",\"ph\":\"C\",\"pid\":4,\"tid\":0,"));
        assert!(j.contains("{\"name\":\"cache_hits\",\"ph\":\"C\",\"pid\":4,\"tid\":1,"));
    }

    #[test]
    fn text_timeline_nests_and_truncates() {
        let full = sample().render_text(0);
        assert!(full.contains("track worker 0"));
        // L0 is a child of req 0: one extra indent level.
        assert!(full.contains("\n  [       0 ..      100] req 0"));
        assert!(full.contains("\n    [       0 ..       60] L0"));
        let cut = sample().render_text(2);
        assert!(cut.lines().count() == 3 && cut.contains("more lines"));
    }

    #[test]
    fn rollup_keeps_last_value_and_counts_points() {
        let t = sample().rollup_table();
        let csv = t.render_csv();
        assert!(csv.contains("macs,1280,2"));
        assert!(csv.contains("cache_hits,3,1"));
    }

    #[test]
    fn empty_recorder_exports_cleanly() {
        let r = TraceRecorder::disabled();
        let j = r.to_chrome_json();
        assert!(j.contains("\"traceEvents\":[\n\n]"));
        assert_eq!(r.render_text(0), "");
    }
}
