//! Deterministic fault injection for the store → fetch → serve path.
//!
//! A [`FaultPlan`] describes *what* can go wrong (payload bit-flips and
//! codec-head corruption at the [`PayloadSource`] boundary, DRAM bank
//! latency spikes, worker stalls, arrival bursts at admission) and a
//! seed. Every individual fault decision is a **pure stateless hash** of
//! `(seed, fault class, stable identifiers)` — never a draw from shared
//! mutable RNG state — so a chaos run produces byte-identical reports
//! regardless of `--jobs`, host, or scheduling. The only mutable state
//! is the per-address attempt counter inside [`FaultySource`], which is
//! owned by exactly one fetcher lane and exists so *transient* faults
//! can clear on a retry while *persistent* ones keep failing.
//!
//! Injection sites:
//!
//! * [`FaultySource`] wraps any payload source and corrupts reads; the
//!   fetcher's verify-on-fetch layer
//!   ([`crate::layout::IntegrityPolicy`]) is the matching defense.
//! * [`FaultPlan::bank_spike`] / [`FaultPlan::worker_stall`] /
//!   [`FaultPlan::arrival_burst`] are consulted by the serving
//!   simulator's single-threaded timing pass — faults land as added
//!   simulated cycles there, never inside the shared DRAM model, so the
//!   bank-busy conservation invariant is untouched.

use crate::layout::fetcher::PayloadSource;
use crate::util::rng::SplitMix64;
use std::collections::BTreeMap;

// Distinct per-fault-class salts so the decision streams are
// independent even for equal identifiers.
const SALT_SITE: u64 = 0xFA17_0001;
const SALT_PERSISTENT: u64 = 0xFA17_0002;
const SALT_META: u64 = 0xFA17_0003;
const SALT_WORD: u64 = 0xFA17_0004;
const SALT_BANK: u64 = 0xFA17_0005;
const SALT_STALL: u64 = 0xFA17_0006;
const SALT_BURST: u64 = 0xFA17_0007;

/// Seeded description of an injected-fault mixture. All-zero rates
/// (the [`Default`]) inject nothing; every decision method is a pure
/// function of the plan and its arguments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Root seed; equal seeds reproduce the exact same fault pattern.
    pub seed: u64,
    /// Probability that a payload read *site* (a sub-tensor address) is
    /// corrupted.
    pub payload_flip_rate: f64,
    /// Of the corrupted sites, the fraction whose fault hits the codec
    /// head word (word 0: the bitmask / run-length index — "metadata"
    /// corruption) instead of a uniformly chosen payload word.
    pub metadata_fraction: f64,
    /// Of the corrupted sites, the fraction that stay corrupt on every
    /// re-read (persistent). The rest are transient: the first read is
    /// corrupt, retries come back clean.
    pub persistent_fraction: f64,
    /// Probability a request-layer's DRAM phase suffers a bank latency
    /// spike of [`FaultPlan::bank_spike_cycles`].
    pub bank_spike_rate: f64,
    /// Added simulated cycles per bank spike.
    pub bank_spike_cycles: u64,
    /// Probability a worker stalls before computing a request-layer.
    pub worker_stall_rate: f64,
    /// Added simulated cycles per worker stall.
    pub worker_stall_cycles: u64,
    /// Probability a request arrives in a burst (its arrival gap to the
    /// previous request collapses to zero at admission).
    pub arrival_burst_rate: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0,
            payload_flip_rate: 0.0,
            metadata_fraction: 0.0,
            persistent_fraction: 0.0,
            bank_spike_rate: 0.0,
            bank_spike_cycles: 256,
            worker_stall_rate: 0.0,
            worker_stall_cycles: 2048,
            arrival_burst_rate: 0.0,
        }
    }
}

impl FaultPlan {
    /// The canonical chaos mixture used by the `gratetile chaos` study:
    /// one knob scales payload corruption and timing disturbance
    /// together. A quarter of corrupted sites hit the codec head and a
    /// quarter are persistent (unrecoverable by retry).
    pub fn uniform(seed: u64, rate: f64) -> Self {
        Self {
            seed,
            payload_flip_rate: rate,
            metadata_fraction: 0.25,
            persistent_fraction: 0.25,
            bank_spike_rate: rate,
            worker_stall_rate: rate / 2.0,
            arrival_burst_rate: rate,
            ..Self::default()
        }
    }

    /// True when any fault class has a non-zero rate.
    pub fn is_active(&self) -> bool {
        self.payload_flip_rate > 0.0
            || self.bank_spike_rate > 0.0
            || self.worker_stall_rate > 0.0
            || self.arrival_burst_rate > 0.0
    }

    /// True when payload reads can be corrupted (i.e. wrapping sources
    /// in a [`FaultySource`] would change anything).
    pub fn payload_faults_active(&self) -> bool {
        self.payload_flip_rate > 0.0
    }

    /// Pure mixing core: one well-distributed 64-bit value per
    /// `(seed, class, salt, key)` tuple.
    fn roll(&self, class: u64, salt: u64, key: u64) -> u64 {
        SplitMix64::new(
            self.seed
                ^ class.rotate_left(17)
                ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ key.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        )
        .next_u64()
    }

    /// Stateless Bernoulli draw with probability `p`.
    fn chance(&self, class: u64, salt: u64, key: u64, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        (self.roll(class, salt, key) >> 11) as f64 / (1u64 << 53) as f64 < p
    }

    /// Decide whether the `attempt`-th read of the sub-tensor at `addr`
    /// (under the per-request `salt`) is corrupted, and how: returns the
    /// word offset and XOR mask to apply, or `None` for a clean read.
    pub fn payload_fault(
        &self,
        salt: u64,
        addr: u64,
        attempt: u32,
        n_words: usize,
    ) -> Option<(usize, u16)> {
        if n_words == 0 || !self.chance(SALT_SITE, salt, addr, self.payload_flip_rate) {
            return None;
        }
        let persistent = self.chance(SALT_PERSISTENT, salt, addr, self.persistent_fraction);
        if attempt > 0 && !persistent {
            return None; // transient: the re-read comes back clean
        }
        let meta = self.chance(SALT_META, salt, addr, self.metadata_fraction);
        let r = self.roll(SALT_WORD, salt, addr ^ u64::from(attempt).rotate_left(48));
        let word = if meta { 0 } else { (r as usize) % n_words };
        Some((word, 1u16 << ((r >> 32) & 15)))
    }

    /// Extra DRAM cycles for `(request, layer)` from a bank latency
    /// spike (0 when the draw misses).
    pub fn bank_spike(&self, request: u64, layer: u64) -> u64 {
        if self.chance(SALT_BANK, request, layer, self.bank_spike_rate) {
            self.bank_spike_cycles
        } else {
            0
        }
    }

    /// Extra compute cycles for `(request, layer)` from a worker stall
    /// (0 when the draw misses).
    pub fn worker_stall(&self, request: u64, layer: u64) -> u64 {
        if self.chance(SALT_STALL, request, layer, self.worker_stall_rate) {
            self.worker_stall_cycles
        } else {
            0
        }
    }

    /// Whether `request` arrives in a burst (admission collapses its
    /// arrival gap to zero).
    pub fn arrival_burst(&self, request: u64) -> bool {
        self.chance(SALT_BURST, request, 0, self.arrival_burst_rate)
    }
}

/// [`PayloadSource`] decorator that injects the plan's payload faults.
///
/// Owned by exactly one fetcher lane; the per-address attempt counter
/// is the only mutable state and exists so transient faults clear on
/// the integrity layer's re-read while persistent ones keep failing.
/// Two `FaultySource`s with equal `(plan, salt)` over equal inner
/// sources return bit-identical streams.
pub struct FaultySource<S> {
    inner: S,
    plan: FaultPlan,
    /// Per-request salt: concurrent requests draw independent fault
    /// streams, yet request *k* sees the same faults on every run.
    salt: u64,
    /// Per-address read counters. A `BTreeMap` on principle: the map is
    /// lookup-only (fault decisions are pure hashes of
    /// `(seed, salt, address, attempt)` — see `payload_fault`), but a
    /// deterministic container guarantees no future iteration can leak
    /// hash order into decisions or report bytes.
    attempts: BTreeMap<u64, u32>,
    injected: u64,
}

impl<S: PayloadSource> FaultySource<S> {
    pub fn new(inner: S, plan: FaultPlan, salt: u64) -> Self {
        Self { inner, plan, salt, attempts: BTreeMap::new(), injected: 0 }
    }

    /// Number of reads this source has corrupted so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }
}

impl<S: PayloadSource> PayloadSource for FaultySource<S> {
    fn read_words(&mut self, addr_words: u64, n_words: usize, out: &mut Vec<u16>) {
        let at = out.len();
        self.inner.read_words(addr_words, n_words, out);
        if n_words == 0 {
            return;
        }
        let attempt = self.attempts.entry(addr_words).or_insert(0);
        let a = *attempt;
        *attempt += 1;
        if let Some((word, mask)) = self.plan.payload_fault(self.salt, addr_words, a, n_words) {
            out[at + word] ^= mask;
            self.injected += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::fetcher::SlicePayload;

    fn read(src: &mut impl PayloadSource, addr: u64, n: usize) -> Vec<u16> {
        let mut v = Vec::new();
        src.read_words(addr, n, &mut v);
        v
    }

    #[test]
    fn inactive_plan_is_bit_exact_passthrough() {
        let data: Vec<u16> = (0..256u16).collect();
        let mut f = FaultySource::new(SlicePayload(&data), FaultPlan::default(), 7);
        for a in [0u64, 17, 128] {
            assert_eq!(read(&mut f, a, 32), &data[a as usize..a as usize + 32]);
        }
        assert_eq!(f.injected(), 0);
    }

    #[test]
    fn same_seed_and_salt_reproduce_identical_corruption() {
        let data: Vec<u16> = (0..512u32).map(|i| (i * 37) as u16).collect();
        let plan = FaultPlan::uniform(42, 0.5);
        let mut a = FaultySource::new(SlicePayload(&data), plan, 3);
        let mut b = FaultySource::new(SlicePayload(&data), plan, 3);
        for site in 0..16u64 {
            assert_eq!(read(&mut a, site * 32, 32), read(&mut b, site * 32, 32));
        }
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "rate 0.5 over 16 sites should corrupt something");
    }

    #[test]
    fn fault_decisions_are_independent_of_address_visit_order() {
        // The per-address attempt counter lives in a map; this locks the
        // invariant that map/visit order can never reach fault decisions:
        // the k-th read of an address sees the same corruption no matter
        // how reads of different addresses interleave.
        let data: Vec<u16> = (0..4096u32).map(|i| (i * 13) as u16).collect();
        let plan = FaultPlan::uniform(9, 0.7);
        let addrs = [96u64, 0, 512, 32, 2048];
        let forward: Vec<u64> =
            (0..3).flat_map(|_| addrs.iter().copied()).collect();
        let mut interleaved = forward.clone();
        interleaved.reverse();
        let mut a = FaultySource::new(SlicePayload(&data), plan, 5);
        let mut b = FaultySource::new(SlicePayload(&data), plan, 5);
        let mut seen_a: Vec<(u64, Vec<u16>)> = Vec::new();
        let mut seen_b: Vec<(u64, Vec<u16>)> = Vec::new();
        for &addr in &forward {
            seen_a.push((addr, read(&mut a, addr, 32)));
        }
        for &addr in &interleaved {
            seen_b.push((addr, read(&mut b, addr, 32)));
        }
        // Compare the k-th read of each address across the two orders.
        for &addr in &addrs {
            let ra: Vec<_> = seen_a.iter().filter(|(x, _)| *x == addr).collect();
            let rb: Vec<_> = seen_b.iter().filter(|(x, _)| *x == addr).collect();
            assert_eq!(ra.len(), 3);
            for (x, y) in ra.iter().zip(&rb) {
                assert_eq!(x.1, y.1, "addr {addr}: corruption depends on visit order");
            }
        }
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "rate 0.7 should corrupt something");
    }

    #[test]
    fn different_salts_draw_different_fault_streams() {
        let data = vec![0u16; 4096];
        let plan = FaultPlan::uniform(1, 0.5);
        let mut a = FaultySource::new(SlicePayload(&data), plan, 1);
        let mut b = FaultySource::new(SlicePayload(&data), plan, 2);
        let ra: Vec<_> = (0..64u64).map(|i| read(&mut a, i * 64, 64)).collect();
        let rb: Vec<_> = (0..64u64).map(|i| read(&mut b, i * 64, 64)).collect();
        assert_ne!(ra, rb);
    }

    #[test]
    fn transient_faults_clear_on_retry_persistent_ones_do_not() {
        let data = vec![0x1111u16; 1024];
        let plan = FaultPlan {
            seed: 5,
            payload_flip_rate: 1.0,
            persistent_fraction: 0.5,
            ..FaultPlan::default()
        };
        let mut f = FaultySource::new(SlicePayload(&data), plan, 9);
        let (mut transients, mut persistents) = (0u32, 0u32);
        for site in 0..32u64 {
            let addr = site * 32;
            let first = read(&mut f, addr, 32);
            assert_ne!(first, &data[..32], "rate-1.0 plan must corrupt the first read");
            let retry = read(&mut f, addr, 32);
            if retry == &data[..32] {
                transients += 1;
            } else {
                persistents += 1;
            }
        }
        assert!(transients > 0, "some sites must be transient");
        assert!(persistents > 0, "some sites must be persistent");
    }

    #[test]
    fn metadata_faults_hit_the_codec_head_word() {
        let plan = FaultPlan {
            seed: 8,
            payload_flip_rate: 1.0,
            metadata_fraction: 1.0,
            ..FaultPlan::default()
        };
        let data = vec![0xABCDu16; 256];
        let mut f = FaultySource::new(SlicePayload(&data), plan, 0);
        for site in 0..8u64 {
            let got = read(&mut f, site * 32, 32);
            assert_ne!(got[0], 0xABCD, "metadata fault must corrupt word 0");
            assert_eq!(&got[1..], &data[1..32], "only the head word is touched");
        }
    }

    #[test]
    fn timing_decisions_are_pure_and_rate_scaled() {
        let plan = FaultPlan::uniform(3, 1.0);
        assert!(plan.is_active());
        assert!(plan.payload_faults_active());
        assert_eq!(plan.bank_spike(4, 0), plan.bank_spike_cycles);
        assert_eq!(plan.worker_stall(1, 2), plan.worker_stall(1, 2));
        assert!(plan.arrival_burst(0));
        let zero = FaultPlan::uniform(3, 0.0);
        assert!(!zero.is_active());
        assert_eq!(zero.bank_spike(4, 0), 0);
        assert_eq!(zero.worker_stall(4, 0), 0);
        assert!(!zero.arrival_burst(7));
    }
}
