//! Tuned layer plans and the versioned tuned-manifest text format.
//!
//! A [`LayerPlan`] is one point of the tuner's search space — the
//! `(division mode, codec policy, tile order)` triple the packer,
//! store writer and serving simulator consume per layer. Plans travel
//! as a **tuned manifest**: a line format in the same family as
//! [`crate::runtime::manifest`] (dependency-free, hand-parseable),
//! version-gated so future plan axes can extend it without silently
//! misreading old files:
//!
//! ```text
//! # comments and blank lines ignored
//! tunedv 1
//! tuned <name> mode=<key> codec=<key> order=<key> [cost=<bits>] [sig=<hex16>]
//! ```
//!
//! `mode=` keys go through [`DivisionMode::parse`], `codec=` through the
//! codec registry and `order=` through [`TileOrder::parse`] — the same
//! single parsers as the CLI, so a name accepted anywhere is accepted
//! here. Unknown keys are **errors naming the key and line**, never
//! ignored: a typo'd directive must not silently fall back to defaults.

use crate::compress::{CodecPolicy, Registry};
use crate::sim::metacache::TileOrder;
use crate::tiling::division::DivisionMode;
use crate::util::error::Result;
use crate::{bail, err};

/// Current tuned-manifest format version.
pub const TUNED_MANIFEST_VERSION: u32 = 1;

/// One layer's tuned execution plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerPlan {
    pub mode: DivisionMode,
    pub policy: CodecPolicy,
    pub order: TileOrder,
}

impl LayerPlan {
    /// The repo-wide default plan (GrateTile mod 8, bitmask,
    /// spatial-major) — what every pipeline runs without a tuned
    /// manifest, and the baseline column of the tune study.
    pub fn default_plan() -> LayerPlan {
        LayerPlan {
            mode: DivisionMode::GrateTile { n: 8 },
            policy: CodecPolicy::Fixed(crate::compress::Scheme::Bitmask),
            order: TileOrder::SpatialMajor,
        }
    }

    /// Compact human/machine description: `grate8+auto+spatial`.
    pub fn key(&self) -> String {
        format!("{}+{}+{}", self.mode.key(), self.policy.name(), self.order.key())
    }
}

/// One named entry of a tuned manifest: the plan plus optional search
/// provenance (the priced total and the input-map signature the plan
/// was tuned against — consumers can warn when serving different data).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedEntry {
    pub plan: LayerPlan,
    /// Priced total (fetched + metadata bits) of the plan, if recorded.
    pub cost_bits: Option<u64>,
    /// FNV-1a-64 signature of the feature map the plan was tuned on.
    pub sig: Option<u64>,
}

/// A parsed tuned manifest: ordered (layer name, entry) pairs. Order is
/// load-bearing — `store pack` maps entries onto request indices and
/// the serving simulator onto network layers positionally when names
/// don't match.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TunedManifest {
    pub entries: Vec<(String, TunedEntry)>,
}

impl TunedManifest {
    /// Entry by layer name.
    pub fn get(&self, name: &str) -> Option<&TunedEntry> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, e)| e)
    }

    /// The per-layer plan list in manifest order (what
    /// [`crate::coordinator::LayerRunner`] consumes).
    pub fn plans(&self) -> Vec<LayerPlan> {
        self.entries.iter().map(|(_, e)| e.plan).collect()
    }

    /// Render the versioned text form. Byte-deterministic: entries in
    /// stored order, fixed key order per line.
    pub fn render(&self) -> String {
        let mut out = String::from("# gratetile tuned manifest\n");
        out.push_str(&format!("tunedv {TUNED_MANIFEST_VERSION}\n"));
        for (name, e) in &self.entries {
            out.push_str(&render_tuned_line(name, e));
            out.push('\n');
        }
        out
    }

    /// Parse the text form; rejects unsupported versions and (like every
    /// manifest directive) unknown keys, naming the key and line.
    pub fn parse(text: &str) -> Result<TunedManifest> {
        let mut m = TunedManifest::default();
        let mut version: Option<u32> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("tunedv") => {
                    let v: u32 = parts
                        .next()
                        .ok_or_else(|| err!("line {ln}: tunedv needs a version"))?
                        .parse()
                        .map_err(|e| err!("line {ln}: {e}"))?;
                    if v != TUNED_MANIFEST_VERSION {
                        bail!(
                            "line {ln}: unsupported tuned-manifest version {v} \
                             (this build reads version {TUNED_MANIFEST_VERSION})"
                        );
                    }
                    version = Some(v);
                }
                Some("tuned") => {
                    if version.is_none() {
                        bail!("line {ln}: 'tuned' before 'tunedv' version header");
                    }
                    let (name, entry) = parse_tuned_fields(ln, parts)?;
                    m.entries.push((name, entry));
                }
                Some(other) => bail!("line {ln}: unknown directive {other}"),
                None => {}
            }
        }
        Ok(m)
    }
}

/// Render one `tuned` directive line (no trailing newline).
pub fn render_tuned_line(name: &str, e: &TunedEntry) -> String {
    debug_assert!(!name.contains(char::is_whitespace), "layer names are tokens");
    let mut s = format!(
        "tuned {name} mode={} codec={} order={}",
        e.plan.mode.key(),
        e.plan.policy.name(),
        e.plan.order.key()
    );
    if let Some(c) = e.cost_bits {
        s.push_str(&format!(" cost={c}"));
    }
    if let Some(sig) = e.sig {
        s.push_str(&format!(" sig={sig:016x}"));
    }
    s
}

/// Parse the fields of a `tuned` directive after the keyword — shared
/// between [`TunedManifest::parse`] and the runtime manifest's `tuned`
/// directive ([`crate::runtime::manifest::Manifest`]). `ln` is the
/// 0-based line number for error messages.
pub fn parse_tuned_fields<'a>(
    ln: usize,
    parts: impl Iterator<Item = &'a str>,
) -> Result<(String, TunedEntry)> {
    let mut parts = parts.peekable();
    let name = parts.next().ok_or_else(|| err!("line {ln}: tuned needs a layer name"))?;
    let mut mode = None;
    let mut policy = None;
    let mut order = None;
    let mut cost_bits = None;
    let mut sig = None;
    for kv in parts {
        if let Some(v) = kv.strip_prefix("mode=") {
            mode = Some(DivisionMode::parse(v).map_err(|e| err!("line {ln}: {e}"))?);
        } else if let Some(v) = kv.strip_prefix("codec=") {
            policy = Some(Registry::global().parse_policy(v).map_err(|e| err!("line {ln}: {e}"))?);
        } else if let Some(v) = kv.strip_prefix("order=") {
            order = Some(
                TileOrder::parse(v)
                    .ok_or_else(|| err!("line {ln}: unknown order '{v}' (spatial, channel)"))?,
            );
        } else if let Some(v) = kv.strip_prefix("cost=") {
            cost_bits = Some(v.parse::<u64>().map_err(|e| err!("line {ln}: cost: {e}"))?);
        } else if let Some(v) = kv.strip_prefix("sig=") {
            sig = Some(
                u64::from_str_radix(v, 16).map_err(|e| err!("line {ln}: sig: {e}"))?,
            );
        } else {
            let key = kv.split('=').next().unwrap_or(kv);
            bail!("line {ln}: unknown tuned option '{key}' (mode, codec, order, cost, sig)");
        }
    }
    let entry = TunedEntry {
        plan: LayerPlan {
            mode: mode.ok_or_else(|| err!("line {ln}: tuned '{name}' needs mode="))?,
            policy: policy.ok_or_else(|| err!("line {ln}: tuned '{name}' needs codec="))?,
            order: order.unwrap_or(TileOrder::SpatialMajor),
        },
        cost_bits,
        sig,
    };
    Ok((name.to_string(), entry))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Scheme;

    fn sample() -> TunedManifest {
        TunedManifest {
            entries: vec![
                (
                    "CONV2".into(),
                    TunedEntry {
                        plan: LayerPlan {
                            mode: DivisionMode::GrateTile { n: 8 },
                            policy: CodecPolicy::Adaptive,
                            order: TileOrder::SpatialMajor,
                        },
                        cost_bits: Some(123_456),
                        sig: Some(0xDEAD_BEEF_0123_4567),
                    },
                ),
                (
                    "CONV3".into(),
                    TunedEntry {
                        plan: LayerPlan {
                            mode: DivisionMode::Anchored { edge: 8, anchor: 7 },
                            policy: CodecPolicy::Fixed(Scheme::Zrlc),
                            order: TileOrder::ChannelMajor,
                        },
                        cost_bits: None,
                        sig: None,
                    },
                ),
            ],
        }
    }

    #[test]
    fn render_parse_round_trips() {
        let m = sample();
        let text = m.render();
        let back = TunedManifest::parse(&text).unwrap();
        assert_eq!(back, m);
        // Render is stable: parse → render reproduces the bytes.
        assert_eq!(back.render(), text);
    }

    #[test]
    fn rejects_unsupported_version() {
        let e = TunedManifest::parse("tunedv 2\n").unwrap_err().to_string();
        assert!(e.contains("version 2"), "{e}");
        let e = TunedManifest::parse("tuned L mode=grate8 codec=auto\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("before 'tunedv'"), "{e}");
    }

    /// ISSUE 9 satellite (bugfix regression): a misspelled key is an
    /// error naming the key and line — not a silent default fallback.
    #[test]
    fn unknown_key_rejected_with_key_and_line() {
        let text = "tunedv 1\ntuned L mode=grate8 codecc=auto order=spatial\n";
        let e = TunedManifest::parse(text).unwrap_err().to_string();
        assert!(e.contains("codecc"), "error must name the bad key: {e}");
        assert!(e.contains("line 1"), "error must name the line: {e}");
    }

    #[test]
    fn missing_required_fields_error() {
        assert!(TunedManifest::parse("tunedv 1\ntuned L codec=auto\n").is_err());
        assert!(TunedManifest::parse("tunedv 1\ntuned L mode=grate8\n").is_err());
        // order is optional (defaults spatial).
        let m = TunedManifest::parse("tunedv 1\ntuned L mode=grate8 codec=raw\n").unwrap();
        assert_eq!(m.get("L").unwrap().plan.order, TileOrder::SpatialMajor);
    }

    #[test]
    fn bad_field_values_error_with_line() {
        for text in [
            "tunedv 1\ntuned L mode=diagonal codec=auto\n",
            "tunedv 1\ntuned L mode=grate8 codec=nope\n",
            "tunedv 1\ntuned L mode=grate8 codec=auto order=zigzag\n",
            "tunedv 1\ntuned L mode=grate8 codec=auto cost=abc\n",
            "tunedv 1\ntuned L mode=grate8 codec=auto sig=zz\n",
        ] {
            let e = TunedManifest::parse(text).unwrap_err().to_string();
            assert!(e.contains("line 1"), "{text} -> {e}");
        }
    }
}
