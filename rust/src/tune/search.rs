//! The memoized branch-and-bound plan search.
//!
//! One layer's plan space is `(division candidate) × (codec policy) ×
//! (tile order)`. Every point is priced through the closed forms the
//! rest of the repo already trusts: [`size_all_codecs`] gives every
//! codec's exact per-sub-tensor `(words, bits)` from one fused stats
//! pass per division, and [`LayerPricer::from_grid`] prices any walk
//! over the derived fetch-bits grid in O(tiles). **No packing happens
//! during search** — the payload never materialises.
//!
//! ## Exactness
//!
//! The division axis is split into *preset* candidates (the Table III
//! modes + WholeMap — also the comparison set of the never-worse
//! property) and *extended* candidates (shifted [`DivisionMode::Anchored`]
//! grids — the split-point axis). Presets are always fully evaluated
//! (the study table reports them); extended candidates are pruned with
//! an **admissible lower bound**: the division's walk priced over the
//! grid of per-sub-tensor `min_codec(ideal bits)` with zero record
//! bits. For every policy, the actual fetch cost of a sub-tensor is
//! ≥ its chosen codec's ideal bits ≥ the min-codec ideal bits
//! (line-rounding only adds), the pricer is monotone in the grid, and
//! metadata bits are ≥ 0 — so `lb ≤ total(policy)` pointwise and a
//! pruned division can never hold the optimum. The search is therefore
//! *exact* over its candidate set (cross-checked against brute-force
//! enumeration in `tests/tune.rs`).
//!
//! [`WalkCost`] is tile-order invariant (the priced totals are sums
//! over the same window multiset), so order is decided after the
//! `(mode, policy)` winner by a fixed-size metadata-cache simulation
//! ([`metadata_cache_study`]) — fewer DRAM metadata bits wins, ties to
//! spatial-major.
//!
//! ## Memoization and determinism
//!
//! The memo key is the canonical [`LayerSpec`]: layer geometry ×
//! hardware identity × an FNV-1a-64 signature over the feature map's
//! f32 bit patterns. Identical spec ⇒ identical map bytes ⇒ the search
//! would retrace the exact same deterministic path, so a memo hit
//! returns the cached plan bit-identically (asserted in tests).
//! Layers tune serially; the only parallelism is inside
//! `size_all_codecs`' position-indexed map, so results are byte-stable
//! across `--jobs` like every other subsystem.

use super::plan::{LayerPlan, TunedEntry, TunedManifest};
use crate::compress::{CodecPolicy, Registry};
use crate::config::hardware::Hardware;
use crate::config::layer::ConvLayer;
use crate::layout::metadata::record_bits_for;
use crate::layout::packer::{size_all_codecs, AllCodecSizes};
use crate::sim::metacache::{metadata_cache_study, TileOrder};
use crate::sim::pricer::{LayerPricer, WalkCost};
use crate::sim::walker::TileWalker;
use crate::store::container::{fnv1a64_continue, FNV1A64_OFFSET};
use crate::tensor::FeatureMap;
use crate::tiling::division::{Division, DivisionMode};
use crate::util::round_up;
use std::collections::HashMap;

/// Metadata SRAM cache size (bytes) used for the tile-order tie-break.
/// Fixed so tuned manifests are a pure function of (layer, map, hw).
pub const TUNE_META_CACHE_BYTES: usize = 2048;

/// Canonical memo key: everything the search outcome depends on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LayerSpec {
    pub k: usize,
    pub s: usize,
    pub d: usize,
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    /// Hardware identity (platform name + tile budget pin the tile
    /// shape and metadata widths).
    pub hw_name: &'static str,
    pub tile_budget_words: usize,
    /// FNV-1a-64 over the feature map's f32 bit patterns (row-major).
    pub fm_sig: u64,
}

impl LayerSpec {
    pub fn new(hw: &Hardware, layer: &ConvLayer, fm: &FeatureMap) -> LayerSpec {
        LayerSpec {
            k: layer.k,
            s: layer.s,
            d: layer.d,
            h: layer.h,
            w: layer.w,
            c_in: layer.c_in,
            hw_name: hw.name,
            tile_budget_words: hw.tile_budget_words,
            fm_sig: feature_map_sig(fm),
        }
    }
}

/// FNV-1a-64 signature over a feature map's exact f32 bit patterns.
pub fn feature_map_sig(fm: &FeatureMap) -> u64 {
    let mut h = FNV1A64_OFFSET;
    for dim in [fm.h, fm.w, fm.c] {
        h = fnv1a64_continue(h, &(dim as u64).to_le_bytes());
    }
    for &v in fm.as_slice() {
        h = fnv1a64_continue(h, &v.to_bits().to_le_bytes());
    }
    h
}

/// Outcome of tuning one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TunedResult {
    pub plan: LayerPlan,
    /// Priced walk cost of the winning plan.
    pub cost: WalkCost,
    /// Priced total of the repo default plan (grate8 + bitmask).
    pub default_total: u64,
    /// Best fixed preset (mode ∈ Table III + WholeMap, any codec
    /// policy): the never-worse comparison point.
    pub best_preset: LayerPlan,
    pub best_preset_total: u64,
    /// Search accounting: `(division, policy)` nodes actually priced.
    pub nodes: u64,
    /// Policy nodes skipped by admissible lower-bound pruning.
    pub pruned: u64,
    /// Whether this result came from the memo cache.
    pub memo_hit: bool,
}

impl TunedResult {
    /// The objective the search minimises: payload fetch + metadata
    /// (record + tag) bits over the layer's full tile walk.
    pub fn total_bits(&self) -> u64 {
        self.cost.fetched_bits + self.cost.metadata_bits
    }

    pub fn entry(&self, sig: u64) -> TunedEntry {
        TunedEntry { plan: self.plan, cost_bits: Some(self.total_bits()), sig: Some(sig) }
    }
}

/// Division candidates for one layer, in the fixed deterministic order
/// that also defines tie-breaks (first strict minimum wins):
/// presets first — the repo default GrateTile{8} leads so it seeds a
/// strong incumbent — then the extended anchored split-point probes.
/// The `bool` marks preset candidates (never pruned; reported in the
/// study table).
pub fn candidate_modes(layer: &ConvLayer) -> Vec<(DivisionMode, bool)> {
    let mut out: Vec<(DivisionMode, bool)> = vec![(DivisionMode::GrateTile { n: 8 }, true)];
    for m in DivisionMode::table3_modes() {
        if !out.iter().any(|(o, _)| *o == m) {
            out.push((m, true));
        }
    }
    out.push((DivisionMode::WholeMap, true));
    // Split-point probes: shifted uniform grids. The halo-derived
    // anchor IS Uniform{edge} (see `anchored_at_halo_matches_uniform`),
    // so it is excluded; the rest probe genuinely different cuts,
    // including the deliberately adversarial split-at-1 / split-at-
    // (edge-1) rims.
    for edge in [2usize, 4, 8] {
        let uniform_anchor = crate::util::umod(-(layer.halo() as i64), edge as i64) as usize;
        let mut anchors: Vec<usize> = [0, 1, edge - 1]
            .into_iter()
            .filter(|&a| a != uniform_anchor)
            .collect();
        anchors.sort_unstable();
        anchors.dedup();
        for anchor in anchors {
            out.push((DivisionMode::Anchored { edge, anchor }, false));
        }
    }
    out
}

/// Codec policies in fixed search order: fixed codecs in registry tag
/// order, then adaptive.
pub fn candidate_policies() -> Vec<CodecPolicy> {
    let mut v: Vec<CodecPolicy> =
        Registry::global().schemes().into_iter().map(CodecPolicy::Fixed).collect();
    v.push(CodecPolicy::Adaptive);
    v
}

/// Fetch-bits grid of `division` under `policy`, derived arithmetically
/// from the all-codec sizes (the packer's cost rule: compact maps pay
/// ideal bits, aligned maps pay line-rounded words).
fn fetch_grid(
    division: &Division,
    sizes: &AllCodecSizes,
    policy: CodecPolicy,
    wpl: usize,
    scratch: &mut Vec<(usize, usize)>,
) -> Vec<u64> {
    let reg = Registry::global();
    let n = division.n_subtensors();
    let fixed_tag = match policy {
        CodecPolicy::Fixed(s) => Some(reg.tag_of(s) as usize),
        CodecPolicy::Adaptive => None,
    };
    (0..n)
        .map(|li| {
            let tag = fixed_tag.unwrap_or_else(|| {
                scratch.clear();
                scratch.extend(
                    (0..sizes.n_codecs).map(|t| {
                        let (w, b) = sizes.at(li, t);
                        (w as usize, b as usize)
                    }),
                );
                reg.select(scratch, division.compact) as usize
            });
            let (w, b) = sizes.at(li, tag);
            if division.compact {
                b as u64
            } else {
                (round_up(w as usize, wpl) * 16) as u64
            }
        })
        .collect()
}

/// Admissible per-sub-tensor lower bound: the best codec's *ideal*
/// bits — no line rounding, no tags. Every policy's real fetch cost
/// dominates this pointwise under both cost rules.
fn lower_bound_grid(division: &Division, sizes: &AllCodecSizes) -> Vec<u64> {
    (0..division.n_subtensors())
        .map(|li| (0..sizes.n_codecs).map(|t| sizes.at(li, t).1 as u64).min().unwrap_or(0))
        .collect()
}

/// The memoizing tuner. Layers tune serially (`--jobs`-stable); repeated
/// layer specs across a network — or across networks sharing the tuner —
/// cost one search.
pub struct Tuner {
    hw: Hardware,
    memo: HashMap<LayerSpec, TunedResult>,
    /// Memo hits served since construction.
    pub memo_hits: u64,
}

impl Tuner {
    pub fn new(hw: Hardware) -> Tuner {
        Tuner { hw, memo: HashMap::new(), memo_hits: 0 }
    }

    pub fn hw(&self) -> &Hardware {
        &self.hw
    }

    /// Tune one layer, memoized on its canonical [`LayerSpec`].
    pub fn tune_layer(&mut self, layer: &ConvLayer, fm: &FeatureMap) -> TunedResult {
        let spec = LayerSpec::new(&self.hw, layer, fm);
        if let Some(hit) = self.memo.get(&spec) {
            self.memo_hits += 1;
            let mut r = *hit;
            r.memo_hit = true;
            r.nodes = 0;
            r.pruned = 0;
            return r;
        }
        let r = self.search_layer(layer, fm);
        self.memo.insert(spec, r);
        r
    }

    /// The search itself (cold path; see module docs for the proof
    /// obligations).
    fn search_layer(&self, layer: &ConvLayer, fm: &FeatureMap) -> TunedResult {
        let hw = &self.hw;
        let tile = hw.tile_for_layer(layer);
        let walker = TileWalker::new(*layer, tile);
        let policies = candidate_policies();
        let wpl = hw.words_per_line;
        let mut scratch: Vec<(usize, usize)> = Vec::new();

        let mut best: Option<(LayerPlan, WalkCost, u64)> = None;
        let mut best_preset: Option<(LayerPlan, u64)> = None;
        let mut default_total = u64::MAX;
        let mut nodes = 0u64;
        let mut pruned = 0u64;

        for (mode, is_preset) in candidate_modes(layer) {
            let Ok(division) = Division::build(mode, layer, &tile, hw, fm.h, fm.w, fm.c) else {
                // Table III footnote a — the candidate doesn't exist
                // for this layer/tile; simply absent from the space.
                continue;
            };
            let sizes = size_all_codecs(fm, &division);

            // Bound check (extended candidates only; presets are study
            // rows and always priced). One pricer pass over the ideal
            // grid bounds all |policies| evaluations below.
            if !is_preset {
                if let Some((_, _, incumbent)) = best {
                    let lb_grid = lower_bound_grid(&division, &sizes);
                    let lb = LayerPricer::from_grid(&division, 0, &lb_grid).price(&walker);
                    if lb.fetched_bits >= incumbent {
                        pruned += policies.len() as u64;
                        continue;
                    }
                }
            }

            for &policy in &policies {
                let grid = fetch_grid(&division, &sizes, policy, wpl, &mut scratch);
                let record_bits = record_bits_for(&division, policy) as u64;
                let cost = LayerPricer::from_grid(&division, record_bits, &grid).price(&walker);
                let total = cost.fetched_bits + cost.metadata_bits;
                nodes += 1;

                let plan = LayerPlan { mode, policy, order: TileOrder::SpatialMajor };
                if plan.mode == LayerPlan::default_plan().mode
                    && plan.policy == LayerPlan::default_plan().policy
                {
                    default_total = total;
                }
                if is_preset && best_preset.is_none_or(|(_, t)| total < t) {
                    best_preset = Some((plan, total));
                }
                // Strict `<`: ties keep the earlier candidate, making
                // the fixed enumeration order the deterministic
                // tie-break.
                if best.is_none_or(|(_, _, t)| total < t) {
                    best = Some((plan, cost, total));
                }
            }
        }

        let (mut plan, cost, _) = best.expect("grate8/uniform fallbacks always build");
        let (preset_plan, preset_total) = best_preset.expect("presets always include uniform");

        // Tile order: WalkCost is order-invariant, so the winner is
        // decided by metadata-cache locality under a fixed SRAM budget.
        // Two cache sims; ties (and study errors) keep spatial-major.
        let dram = |order: TileOrder| {
            metadata_cache_study(hw, layer, fm, plan.mode, TUNE_META_CACHE_BYTES, order)
                .map(|s| s.dram_bits)
        };
        if let (Ok(sp), Ok(ch)) = (dram(TileOrder::SpatialMajor), dram(TileOrder::ChannelMajor)) {
            if ch < sp {
                plan.order = TileOrder::ChannelMajor;
            }
        }

        TunedResult {
            plan,
            cost,
            default_total,
            best_preset: preset_plan,
            best_preset_total: preset_total,
            nodes,
            pruned,
            memo_hit: false,
        }
    }

    /// Tune a named-layer network and emit the tuned manifest. Entries
    /// keep input order; names must be whitespace-free tokens.
    pub fn tune_network(
        &mut self,
        layers: &[(String, ConvLayer, FeatureMap)],
    ) -> (TunedManifest, Vec<TunedResult>) {
        let mut manifest = TunedManifest::default();
        let mut results = Vec::with_capacity(layers.len());
        for (name, layer, fm) in layers {
            let r = self.tune_layer(layer, fm);
            manifest.entries.push((name.clone(), r.entry(feature_map_sig(fm))));
            results.push(r);
        }
        (manifest, results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::Platform;
    use crate::tensor::sparsity::{generate, SparsityParams};

    fn fm_for(layer: &ConvLayer, density: f64, seed: u64) -> FeatureMap {
        generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(density, seed))
    }

    #[test]
    fn candidates_are_deduped_and_lead_with_default() {
        let l = ConvLayer::new(1, 1, 56, 56, 64, 64);
        let mods = candidate_modes(&l);
        assert_eq!(mods[0].0, DivisionMode::GrateTile { n: 8 });
        let mut seen = Vec::new();
        for (m, _) in &mods {
            assert!(!seen.contains(m), "duplicate candidate {m:?}");
            seen.push(*m);
        }
        // halo=1 ⇒ uniform anchor is edge-1 ⇒ anchored{e}@{e-1} excluded.
        assert!(!seen.contains(&DivisionMode::Anchored { edge: 8, anchor: 7 }));
        assert!(seen.contains(&DivisionMode::Anchored { edge: 8, anchor: 1 }));
    }

    #[test]
    fn tuned_beats_or_ties_default_and_presets() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let mut tuner = Tuner::new(hw);
        let layer = ConvLayer::new(1, 1, 40, 40, 16, 16);
        let fm = fm_for(&layer, 0.35, 5);
        let r = tuner.tune_layer(&layer, &fm);
        assert!(r.total_bits() <= r.default_total);
        assert!(r.total_bits() <= r.best_preset_total);
        assert!(r.nodes > 0);
        assert!(!r.memo_hit);
    }

    #[test]
    fn memo_hit_is_bit_identical_and_free() {
        let hw = Platform::NvidiaSmallTile.hardware();
        let mut tuner = Tuner::new(hw);
        let layer = ConvLayer::new(1, 1, 32, 32, 16, 16);
        let fm = fm_for(&layer, 0.4, 9);
        let cold = tuner.tune_layer(&layer, &fm);
        let hit = tuner.tune_layer(&layer, &fm);
        assert!(hit.memo_hit);
        assert_eq!(hit.nodes, 0, "memo hits cost no search nodes");
        assert_eq!(hit.plan, cold.plan);
        assert_eq!(hit.cost, cold.cost);
        assert_eq!(tuner.memo_hits, 1);
        // A different map misses.
        let fm2 = fm_for(&layer, 0.4, 10);
        assert!(!tuner.tune_layer(&layer, &fm2).memo_hit);
    }

    #[test]
    fn feature_map_sig_is_content_addressed() {
        let layer = ConvLayer::new(1, 1, 16, 16, 8, 8);
        let a = fm_for(&layer, 0.5, 1);
        let b = fm_for(&layer, 0.5, 1);
        let c = fm_for(&layer, 0.5, 2);
        assert_eq!(feature_map_sig(&a), feature_map_sig(&b));
        assert_ne!(feature_map_sig(&a), feature_map_sig(&c));
    }
}
