//! Auto-tuning plan search over division × codec × tile order.
//!
//! GrateTile's presets (Table III divisions, one codec policy for the
//! whole network) leave per-layer headroom on the table: a layer's best
//! `(division mode, split points, codec, tile order)` depends on its
//! geometry *and* its sparsity pattern. This module searches that space
//! exactly — per layer, through the [`crate::sim::pricer::LayerPricer`]
//! closed forms only (no packing during search) — and emits a versioned
//! **tuned manifest** the store writer and serving simulator consume.
//!
//! * [`plan`] — [`plan::LayerPlan`] / [`plan::TunedManifest`]: the plan
//!   triple and its versioned line format (`tunedv 1` + `tuned` lines).
//! * [`search`] — [`search::Tuner`]: the memoized branch-and-bound
//!   search with an admissible lower bound (exact; never worse than any
//!   preset by construction, property-tested in `tests/tune.rs`).
//!
//! Determinism: candidate order is fixed, ties keep the first-seen
//! winner, layers tune serially, and the memo key is a canonical
//! geometry × density-signature spec — so tuned manifests are
//! byte-identical across `--jobs` and across repeated runs.

pub mod plan;
pub mod search;

pub use plan::{LayerPlan, TunedEntry, TunedManifest, TUNED_MANIFEST_VERSION};
pub use search::{
    candidate_modes, candidate_policies, feature_map_sig, LayerSpec, TunedResult, Tuner,
    TUNE_META_CACHE_BYTES,
};
