//! Minimal scoped-thread data parallelism (offline stand-in for `rayon`).
//!
//! The suite engine fans (platform × mode × layer) pricing units across
//! `std::thread::scope` workers with an atomic work-stealing cursor — no
//! channels, no unsafe, no dependencies. Results come back in input
//! order, so parallel sweeps are bit-identical to sequential ones.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide worker-count override (0 = auto). Set by `--jobs` on the
/// CLI; the `GRATETILE_THREADS` env var is consulted when unset.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

std::thread_local! {
    /// True on threads spawned by this module's pools. Nested sweeps
    /// (a suite unit's pack calling back into `par_map_init`) then run
    /// inline instead of oversubscribing the machine with workers² —
    /// results are identical either way, only scheduling changes.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Set the worker-thread count for all subsequent parallel sweeps
/// (0 restores auto detection).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Worker count for a sweep of `n_items` units: the explicit override,
/// else `GRATETILE_THREADS`, else the machine's available parallelism —
/// never more workers than items.
pub fn threads_for(n_items: usize) -> usize {
    if n_items <= 1 || IN_POOL_WORKER.with(|c| c.get()) {
        return 1;
    }
    let configured = match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => std::env::var("GRATETILE_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }),
        n => n,
    };
    configured.clamp(1, n_items)
}

/// Apply `f` to every item of `items` on a scoped worker pool, returning
/// results in input order. Workers pull the next index from a shared
/// atomic cursor, so uneven unit costs (a 224×224 VGG layer next to a
/// 13×13 AlexNet one) balance automatically.
pub fn par_map<T: Sync, R: Send>(
    items: &[T],
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    par_map_init(items, || (), |_, i, t| f(i, t))
}

/// [`par_map`] with per-worker scratch state: `init` runs once per
/// worker thread and the resulting state is threaded through every unit
/// that worker pulls. The packing engine uses this for its per-thread
/// [`crate::compress::DistinctTracker`] and gather buffers — reusable
/// scratch that must not be shared across workers and is too expensive
/// to build per item.
pub fn par_map_init<T: Sync, R: Send, S>(
    items: &[T],
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    let workers = threads_for(n);
    if workers == 1 {
        let mut state = init();
        return items.iter().enumerate().map(|(i, t)| f(&mut state, i, t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(workers);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    IN_POOL_WORKER.with(|c| c.set(true));
                    let mut state = init();
                    let mut out = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(&mut state, i, &items[i])));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_map worker panicked"));
        }
    });

    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in parts.into_iter().flatten() {
        debug_assert!(slots[i].is_none());
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .map(|s| s.expect("par_map produced no result for an index"))
        .collect()
}

/// Mutate every item of `items` in place on a scoped worker pool, with
/// per-worker scratch state. Items are statically partitioned into one
/// contiguous chunk per worker (the packing engine's execute phase hands
/// each worker disjoint preallocated payload slices of near-equal
/// size, so work-stealing buys nothing there). Results are written only
/// through each item's own `&mut`, so the outcome is identical for
/// every worker count.
pub fn par_for_each_init<T: Send, S>(
    items: &mut [T],
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, usize, &mut T) + Sync,
) {
    let n = items.len();
    let workers = threads_for(n);
    if workers == 1 {
        let mut state = init();
        for (i, t) in items.iter_mut().enumerate() {
            f(&mut state, i, t);
        }
        return;
    }

    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, part) in items.chunks_mut(chunk).enumerate() {
            let (init, f) = (&init, &f);
            s.spawn(move || {
                IN_POOL_WORKER.with(|c| c.set(true));
                let mut state = init();
                for (j, t) in part.iter_mut().enumerate() {
                    f(&mut state, ci * chunk + j, t);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * x
        });
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(&none, |_, &x| x).is_empty());
        assert_eq!(par_map(&[41], |_, &x| x + 1), vec![42]);
    }

    #[test]
    fn threads_for_respects_override() {
        set_threads(3);
        assert_eq!(threads_for(100), 3);
        assert_eq!(threads_for(2), 2); // never more workers than items
        set_threads(0);
        assert!(threads_for(100) >= 1);
        assert_eq!(threads_for(1), 1);
        assert_eq!(threads_for(0), 1);
    }

    #[test]
    fn nested_pools_run_inline() {
        let items: Vec<usize> = (0..16).collect();
        let out = par_map(&items, |_, &x| {
            // On a pool worker, a nested sweep must not fan out again.
            if IN_POOL_WORKER.with(|c| c.get()) {
                assert_eq!(threads_for(1000), 1);
            }
            let inner: Vec<usize> = (0..50).collect();
            par_map(&inner, |_, &y| y).iter().sum::<usize>() + x
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 1225 + i);
        }
    }

    #[test]
    fn par_map_init_state_is_per_worker() {
        // Each worker's counter counts only its own units; the grand
        // total across results equals n regardless of distribution.
        let items: Vec<u32> = (0..97).collect();
        let out = par_map_init(
            &items,
            || 0usize,
            |seen, i, &x| {
                *seen += 1;
                assert_eq!(i as u32, x);
                (*seen, x)
            },
        );
        assert_eq!(out.len(), 97);
        for (i, (seen, x)) in out.iter().enumerate() {
            assert!(*seen >= 1);
            assert_eq!(*x, i as u32);
        }
    }

    // NOTE: worker-count determinism is asserted by the integration
    // property tests (tests/property.rs) in their own process — unit
    // tests here must not toggle the global override concurrently with
    // `threads_for_respects_override`.
    #[test]
    fn par_for_each_init_mutates_every_item_once() {
        let mut items: Vec<u64> = (0..233).collect();
        par_for_each_init(&mut items, || 1u64, |one, i, t| {
            *t = *t * 2 + *one + i as u64;
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, (i as u64) * 3 + 1);
        }
    }

    #[test]
    fn uneven_work_balances() {
        // Mixed-cost units still return ordered results.
        let items: Vec<usize> = (0..64).collect();
        let out = par_map(&items, |_, &x| {
            if x % 7 == 0 {
                // Simulate an expensive unit.
                (0..10_000u64).sum::<u64>() + x as u64
            } else {
                x as u64
            }
        });
        for (i, v) in out.iter().enumerate() {
            let expect = if i % 7 == 0 { 49_995_000 + i as u64 } else { i as u64 };
            assert_eq!(*v, expect);
        }
    }
}
