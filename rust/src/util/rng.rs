//! SplitMix64: a tiny, fast, deterministic PRNG (Steele et al., 2014).
//!
//! Used for synthetic sparsity generation and property-test case
//! generation. Not cryptographic; chosen for reproducibility and zero
//! dependencies.

/// Deterministic 64-bit PRNG with splittable seeding.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in `[0, n)`; `n > 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias is
        // negligible for the n << 2^64 values used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            let v = r.below(8);
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all 8 buckets should be hit");
    }

    #[test]
    fn chance_rate_is_approximately_p() {
        let mut r = SplitMix64::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let mut parent = SplitMix64::new(5);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
